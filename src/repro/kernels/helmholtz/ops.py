"""Jit'd public wrappers for the fused Inverse-Helmholtz kernel.

``inverse_helmholtz(S, D, u)`` picks the best available implementation:
the Pallas kernel on TPU, interpret-mode Pallas when explicitly requested
(CPU validation), and the pure-jnp reference otherwise.  The signature is
what ``repro.core.emit.compile_program(backend='pallas')`` expects as
``pallas_impl`` for the Inverse-Helmholtz program.
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

from .helmholtz import inverse_helmholtz_pallas, DEFAULT_BLOCK_ELEMENTS
from .ref import inverse_helmholtz_ref

Impl = Literal["auto", "pallas", "interpret", "xla"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def block_working_set_bytes(
    p: int, block_elements: int, *, bytes_per_scalar: int = 4
) -> int:
    """VMEM bytes while one element block flows through the fused kernel:
    the u/D/v block slices plus the double-buffered t/r scratch pair
    (Mnemosyne-style sharing keeps two intermediates live), plus the
    resident S operator.  Matches ``memory.layout.block_working_set_bytes``
    on the Inverse-Helmholtz program."""
    return (p * p + 5 * block_elements * p ** 3) * bytes_per_scalar


def block_elements_for_vmem(
    p: int,
    vmem_bytes: int,
    *,
    bytes_per_scalar: int = 4,
    reserve_fraction: float = 0.5,
) -> int:
    """Largest power-of-two element block whose working set fits the
    given on-chip memory (half reserved for the Pallas grid pipeline's
    DMA double buffering).  This is how a MemoryPlan's VMEM budget
    becomes the kernel's ``block_elements``."""
    budget = int(vmem_bytes * reserve_fraction)
    be = 1
    while block_working_set_bytes(
        p, be * 2, bytes_per_scalar=bytes_per_scalar
    ) <= budget:
        be *= 2
    return be


def inverse_helmholtz(
    S: jax.Array,
    D: jax.Array,
    u: jax.Array,
    *,
    impl: Impl = "auto",
    block_elements: int = DEFAULT_BLOCK_ELEMENTS,
) -> jax.Array:
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "xla"
    if impl == "pallas":
        return inverse_helmholtz_pallas(
            S, D, u, block_elements=block_elements
        )
    if impl == "interpret":
        return inverse_helmholtz_pallas(
            S, D, u, block_elements=block_elements, interpret=True
        )
    return jax.jit(inverse_helmholtz_ref)(S, D, u)


def make_pallas_impl(impl: Impl = "auto", block_elements: int = DEFAULT_BLOCK_ELEMENTS):
    """Adapter for core.emit.compile_program(backend='pallas')."""

    def batched_fn(env):
        v = inverse_helmholtz(
            env["S"], env["D"], env["u"], impl=impl,
            block_elements=block_elements,
        )
        return {"v": v}

    return batched_fn
