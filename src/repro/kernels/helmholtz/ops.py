"""Jit'd public wrappers for the fused Inverse-Helmholtz kernel.

``inverse_helmholtz(S, D, u)`` picks the best available implementation:
the Pallas kernel on TPU, interpret-mode Pallas when explicitly requested
(CPU validation), and the pure-jnp reference otherwise.  The signature is
what ``repro.core.emit.compile_program(backend='pallas')`` expects as
``pallas_impl`` for the Inverse-Helmholtz program.
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

from .helmholtz import inverse_helmholtz_pallas, DEFAULT_BLOCK_ELEMENTS
from .ref import inverse_helmholtz_ref

Impl = Literal["auto", "pallas", "interpret", "xla"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def inverse_helmholtz(
    S: jax.Array,
    D: jax.Array,
    u: jax.Array,
    *,
    impl: Impl = "auto",
    block_elements: int = DEFAULT_BLOCK_ELEMENTS,
) -> jax.Array:
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "xla"
    if impl == "pallas":
        return inverse_helmholtz_pallas(
            S, D, u, block_elements=block_elements
        )
    if impl == "interpret":
        return inverse_helmholtz_pallas(
            S, D, u, block_elements=block_elements, interpret=True
        )
    return jax.jit(inverse_helmholtz_ref)(S, D, u)


def make_pallas_impl(impl: Impl = "auto", block_elements: int = DEFAULT_BLOCK_ELEMENTS):
    """Adapter for core.emit.compile_program(backend='pallas')."""

    def batched_fn(env):
        v = inverse_helmholtz(
            env["S"], env["D"], env["u"], impl=impl,
            block_elements=block_elements,
        )
        return {"v": v}

    return batched_fn
