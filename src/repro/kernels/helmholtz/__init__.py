from . import ops, ref
from .helmholtz import inverse_helmholtz_pallas

__all__ = ["ops", "ref", "inverse_helmholtz_pallas"]
