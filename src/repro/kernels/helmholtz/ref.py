"""Pure-jnp oracle for the fused Inverse-Helmholtz kernel.

Shapes: S (p, p) shared; D, u (E, p, p, p) per element; out v (E, p, p, p).
"""
from __future__ import annotations

import jax.numpy as jnp


def inverse_helmholtz_ref(S, D, u):
    t = jnp.einsum("il,jm,kn,elmn->eijk", S, S, S, u)
    r = D * t
    v = jnp.einsum("li,mj,nk,elmn->eijk", S, S, S, r)
    return v
