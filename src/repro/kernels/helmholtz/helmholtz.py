"""Fused Inverse-Helmholtz Pallas TPU kernel -- the paper's dataflow CU.

Adaptation notes (DESIGN.md section 2):

  * The FPGA CU streams one element through 7 pipelined loop nests with
    FIFO links; here a *block of BE elements* flows through the same 7
    stages entirely inside VMEM -- crossing a stage boundary never touches
    HBM, which is the TPU equivalent of the FIFO stream.
  * "Lane packing" (splitting the 256-bit AXI bus into parallel lanes) is
    realized by packing the element axis into the GEMM minor dimension:
    every contraction is one (p x p) x (p x BE*p^2) matmul whose minor dim
    is a multiple of 128, saturating MXU lanes instead of AXI lanes.
  * Host<->HBM double buffering is Pallas grid pipelining: while block g
    computes, block g+1's DMA from HBM is in flight (automatic ping/pong).
  * Mnemosyne-style sharing: the t/r intermediates reuse one VMEM scratch
    allocation (disjoint lifetimes inside a stage chain).

Grid: (E // BE,).  Refs carry one element block; S is re-fetched per step
(index_map pins block 0) which Mosaic keeps resident in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_ELEMENTS = 128


def _contract_first(S, x, p: int, be: int):
    """y[a, e, m, n] = sum_l S[l, a] * x[l, e, m, n] as one MXU GEMM.

    x arrives as (l, BE*p*p) row-major with l major; lhs is (p, p).
    dot_general: contract S dim 0 with x dim 0 -> (a, BE*p*p).
    """
    return jax.lax.dot_general(
        S, x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def _helmholtz_block(S, D, u, p: int, be: int):
    """Compute one element block entirely in registers/VMEM.

    u, D: (BE, p, p, p). Returns v: (BE, p, p, p).

    Each contraction rotates the contracted axis to the front and packs
    (BE, remaining p^2) into the GEMM minor dimension.
    """
    f32 = jnp.float32

    def rotate_contract(M, x):
        # x: (BE, p, p, p) contracting over axis 1 (current leading p).
        # -> (p_l, BE * p * p) GEMM, result axis becomes the *last* p axis,
        # so three applications restore the original axis order.
        xt = jnp.transpose(x, (1, 0, 2, 3))          # (l, BE, p, p)
        xm = xt.reshape(p, be * p * p)               # (l, BE*p*p)
        ym = jax.lax.dot_general(
            M, xm, (((0,), (0,)), ((), ())), preferred_element_type=f32
        )                                            # (a, BE*p*p)
        y = ym.reshape(p, be, p, p)
        return jnp.transpose(y, (1, 2, 3, 0))        # (BE, p, p, a)

    # ---- stage 1-3: t = (S^T (x)3) u  (t_ijk = sum S_il S_jm S_kn u_lmn)
    # contract l with S_il => lhs must be S with its *second* axis as the
    # contracted one: pass S and contract dim 1 == use S^T in rotate form.
    t = u.astype(f32)
    for _ in range(3):
        t = rotate_contract(jnp.transpose(S), t)     # contracts S_il over l
    # ---- stage 4: Hadamard
    r = D.astype(f32) * t
    # ---- stage 5-7: v = (S (x)3) r   (v_ijk = sum S_li S_mj S_nk r_lmn)
    v = r
    for _ in range(3):
        v = rotate_contract(S, v)                    # contracts S_li over l
    return v


def _kernel(S_ref, D_ref, u_ref, v_ref, *, p: int, be: int):
    S = S_ref[...]
    D = D_ref[...]
    u = u_ref[...]
    v_ref[...] = _helmholtz_block(S, D, u, p, be).astype(v_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_elements", "interpret")
)
def inverse_helmholtz_pallas(
    S: jax.Array,
    D: jax.Array,
    u: jax.Array,
    *,
    block_elements: int = DEFAULT_BLOCK_ELEMENTS,
    interpret: bool = False,
) -> jax.Array:
    """Batched fused Inverse Helmholtz.  S: (p,p); D,u: (E,p,p,p)."""
    E, p = u.shape[0], u.shape[1]
    be = min(block_elements, E)
    if E % be != 0:
        raise ValueError(f"element count {E} not divisible by block {be}")

    grid = (E // be,)
    kernel = functools.partial(_kernel, p=p, be=be)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((p, p), lambda g: (0, 0)),          # S resident
            pl.BlockSpec((be, p, p, p), lambda g: (g, 0, 0, 0)),
            pl.BlockSpec((be, p, p, p), lambda g: (g, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((be, p, p, p), lambda g: (g, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(u.shape, u.dtype),
        interpret=interpret,
    )(S, D, u)
