"""CHARM-style two-class tile candidates for the GEMM-chain kernel.

CHARM composes heterogeneous accelerators from two design classes: CDSE
enumerates *large* tile configurations that maximize steady-state
throughput for big operands, CDAC keeps *small* dedicated accelerators
whose latency (fill cost) stays low for small operands.  The TPU analog
of a tile configuration is the kernel's ``block_elements``: big blocks
amortize dispatch overhead and fill the MXU minor dimension, small
blocks keep the VMEM working set (and the per-dispatch latency) low.

``tile_candidates`` enumerates power-of-two blocks, filters them by the
plan's VMEM budget (the resource constraint), splits them into the two
classes, and ranks each by modeled throughput -- the search space the
measured block autotuner (``flow.compile(tune_blocks=True)``) walks
before depositing the measured winner in the profile store.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from .gemm import GemmRecipe
from .ops import block_working_set_bytes

#: Working-set fraction of the VMEM budget separating the two classes:
#: blocks using more than this are "cdse" (large/throughput), the rest
#: "cdac" (small/latency).
LARGE_CLASS_FRACTION = 0.25

#: Default per-dispatch overhead used by the throughput ranking (one
#: kernel launch per block; same order as ``dse.DISPATCH_OVERHEAD_S``).
DEFAULT_OVERHEAD_S = 50e-6


@dataclasses.dataclass(frozen=True)
class TileCandidate:
    """One feasible ``block_elements`` choice for the GEMM-chain kernel."""

    klass: str                  # "cdse" (large) | "cdac" (small)
    block_elements: int
    working_set_bytes: int
    #: modeled elements/second: block roofline plus dispatch overhead
    predicted_throughput: float


def tile_candidates(
    recipe: GemmRecipe,
    *,
    vmem_bytes: int,
    peak_flops: float,
    hbm_bandwidth: float,
    bytes_per_scalar: int = 4,
    overhead_s: float = DEFAULT_OVERHEAD_S,
    reserve_fraction: float = 0.5,
    max_block: int = 2048,
    batch_elements: Optional[int] = None,
) -> List[TileCandidate]:
    """Enumerate, filter, and throughput-rank block-size candidates.

    Power-of-two blocks up to ``max_block`` are kept when their VMEM
    working set fits ``vmem_bytes * reserve_fraction`` (the other half
    is the grid pipeline's DMA double buffer) and, when
    ``batch_elements`` is given, when they divide the batch (the Pallas
    grid requires it).  Each survivor is classed large ("cdse") or small
    ("cdac") by working-set fraction and ranked by modeled throughput:
    ``be / (overhead + flops/peak + io_bytes/bw)``.  Returns candidates
    sorted best-first; empty when even a 1-element block exceeds VMEM.
    """
    budget = int(vmem_bytes * reserve_fraction)
    flops = recipe.flops_per_element()
    out_slots = {slot for _, slot in recipe.outputs}
    import math as _math
    io_scalars = sum(
        _math.prod(shape) for _, shape, is_elem in recipe.inputs if is_elem
    ) + sum(_math.prod(recipe.slot_shape(s)) for s in out_slots)

    out: List[TileCandidate] = []
    be = 1
    while be <= max_block:
        ws = block_working_set_bytes(
            recipe, be, bytes_per_scalar=bytes_per_scalar
        )
        divides = batch_elements is None or batch_elements % be == 0
        fits = ws <= budget and (
            batch_elements is None or be <= batch_elements
        )
        if fits and divides:
            t = (
                overhead_s
                + be * flops / peak_flops
                + be * io_scalars * bytes_per_scalar / hbm_bandwidth
            )
            out.append(TileCandidate(
                klass=(
                    "cdse" if ws > budget * LARGE_CLASS_FRACTION
                    else "cdac"
                ),
                block_elements=be,
                working_set_bytes=ws,
                predicted_throughput=be / t,
            ))
        be *= 2
    out.sort(key=lambda c: -c.predicted_throughput)
    return out
