"""Tiled Pallas GEMM-chain kernel class: shared-matrix mode contractions
plus elementwise ops, fused into one VMEM-resident CU per stage.  See
``gemm`` (kernel + recipe), ``ops`` (public wrappers / block sizing),
``cdse_cdac`` (CHARM-style large/small tile candidate classes)."""
from .gemm import (DEFAULT_BLOCK_ELEMENTS, EWISE_OPS, GemmRecipe,
                   apply_recipe, gemm_chain_pallas, gemm_chain_ref)
from .ops import (block_elements_for_vmem, block_working_set_bytes,
                  gemm_chain, make_pallas_impl)
from .cdse_cdac import LARGE_CLASS_FRACTION, TileCandidate, tile_candidates

__all__ = [
    "DEFAULT_BLOCK_ELEMENTS", "EWISE_OPS", "GemmRecipe", "apply_recipe",
    "gemm_chain_pallas", "gemm_chain_ref", "block_elements_for_vmem",
    "block_working_set_bytes", "gemm_chain", "make_pallas_impl",
    "LARGE_CLASS_FRACTION", "TileCandidate", "tile_candidates",
]
