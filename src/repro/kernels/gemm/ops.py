"""Jit'd public wrappers for the tiled GEMM-chain kernel.

``make_pallas_impl(recipe)`` returns the batched callable
``core.emit.compile_program(backend='pallas')`` expects: the Pallas
kernel on TPU, interpret-mode Pallas when explicitly requested (CPU
validation), and the pure-jnp reference otherwise -- the same dispatch
contract as the Helmholtz kernel's ``ops``."""
from __future__ import annotations

import math
from typing import Dict, Literal

import jax

from .gemm import (DEFAULT_BLOCK_ELEMENTS, GemmRecipe, gemm_chain_pallas,
                   gemm_chain_ref)

Impl = Literal["auto", "pallas", "interpret", "xla"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def block_working_set_bytes(
    recipe: GemmRecipe, block_elements: int, *, bytes_per_scalar: int = 4
) -> int:
    """VMEM bytes while one element block flows through the kernel: the
    element in/out block slices, double-buffered scratch for the largest
    intermediate (two live at a time, Mnemosyne-style), plus the shared
    matrices held resident.  Mirrors
    ``memory.layout.block_working_set_bytes`` on the recipe's program."""
    shared = sum(
        math.prod(shape) for _, shape, is_elem in recipe.inputs
        if not is_elem
    )
    out_slots = {slot for _, slot in recipe.outputs}
    elem = sum(
        math.prod(shape) for _, shape, is_elem in recipe.inputs if is_elem
    ) + sum(math.prod(recipe.slot_shape(s)) for s in out_slots)
    scratch = 2 * max(
        (math.prod(recipe.slot_shape(recipe.n_inputs + k))
         for k in range(len(recipe.ops))),
        default=0,
    )
    return (shared + block_elements * (elem + scratch)) * bytes_per_scalar


def block_elements_for_vmem(
    recipe: GemmRecipe,
    vmem_bytes: int,
    *,
    bytes_per_scalar: int = 4,
    reserve_fraction: float = 0.5,
) -> int:
    """Largest power-of-two element block whose working set fits the
    given on-chip memory (half reserved for the Pallas grid pipeline's
    DMA double buffering) -- how a plan's VMEM budget becomes the
    kernel's ``block_elements``."""
    budget = int(vmem_bytes * reserve_fraction)
    be = 1
    while block_working_set_bytes(
        recipe, be * 2, bytes_per_scalar=bytes_per_scalar
    ) <= budget:
        be *= 2
    return be


def gemm_chain(
    recipe: GemmRecipe,
    env: Dict[str, jax.Array],
    *,
    impl: Impl = "auto",
    block_elements: int = DEFAULT_BLOCK_ELEMENTS,
) -> Dict[str, jax.Array]:
    """Run one GEMM-chain recipe with the best available implementation."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "xla"
    if impl == "pallas":
        return gemm_chain_pallas(
            recipe, env, block_elements=block_elements
        )
    if impl == "interpret":
        return gemm_chain_pallas(
            recipe, env, block_elements=block_elements, interpret=True
        )
    return gemm_chain_ref(recipe, env)


def make_pallas_impl(
    recipe: GemmRecipe,
    impl: Impl = "auto",
    block_elements: int = DEFAULT_BLOCK_ELEMENTS,
):
    """Adapter for ``core.emit.compile_program(backend='pallas')``."""

    def batched_fn(env):
        return gemm_chain(
            recipe, env, impl=impl, block_elements=block_elements
        )

    return batched_fn
