"""Tiled Pallas GEMM-chain kernel: one fused CU for any stage program
made of shared-matrix mode contractions and elementwise ops.

The Helmholtz kernel hand-fuses one fixed 7-stage dataflow.  This module
generalizes the same tiling idiom -- a block of BE elements flows through
the whole op chain inside VMEM, every mode contraction is one
``(p x p) x (p x BE*p^(r-1))`` MXU GEMM -- to *any* recipe extracted from
a stage program by ``flow.patterns.match_gemm_chain``:

  * **contract**: ``y[.., a at mode m, ..] = sum_l M[l, a] * x[.., l, ..]``
    realized by rotating mode ``m`` to the front, packing the remaining
    axes (element axis included) into the GEMM minor dimension, and
    rotating back -- so index order is restored exactly and recipes
    compose without bookkeeping.
  * **ewise**: add/sub/mul/div between element values, plus unary
    neg/scale -- the Hadamard steps of the CFD chain.

One recipe covers the interpolation stage (3 contractions), the gradient
stage (3 outputs sharing an input), any single schedule-derived stage,
and the fully fused pipeline -- which is exactly what the cost-driven
stage fusion pass needs: fused stages re-match to this kernel class
instead of falling back to XLA.

Grid: ``(E // BE,)``.  Shared matrices are pinned to block 0 (Mosaic
keeps them VMEM-resident); element tensors stream one block per step
with the grid pipeline double-buffering the HBM DMA.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_ELEMENTS = 128

#: ewise ops the kernel (and the matcher) accept.
EWISE_OPS = ("add", "sub", "mul", "div", "neg", "scale")


@dataclasses.dataclass(frozen=True)
class GemmRecipe:
    """A hashable, IR-free description of one GEMM-chain stage.

    ``inputs`` lists every program input as ``(name, shape, is_element)``
    -- element tensors are rank-r all-``p`` cubes carrying the batch
    axis, shared inputs are ``(p, p)`` contraction matrices.  Value
    slots number the inputs first (in order) and then one slot per op
    result, so ``ops`` and ``outputs`` reference values positionally:

      * ``("contract", src_slot, mat_slot, mode, mat_dim, perm)`` --
        contract the matrix's ``mat_dim`` axis against tensor mode
        ``mode``, then permute the element-local axes of the in-place
        result by ``perm`` (identity for in-place contractions; the
        gradient einsums move the new free axis to the front);
      * ``("ewise", op, lhs_slot, rhs_slot, const)`` -- ``rhs_slot`` is
        ``-1`` for unary ops, ``const`` is None unless ``op=='scale'``.

    ``outputs`` maps output names to slots.  Built by
    ``flow.patterns.match_gemm_chain``; hashable so compiled kernels
    cache per (recipe, block, interpret).
    """

    p: int
    inputs: Tuple[Tuple[str, Tuple[int, ...], bool], ...]
    ops: Tuple[Tuple, ...]
    outputs: Tuple[Tuple[str, int], ...]

    @property
    def n_inputs(self) -> int:
        return len(self.inputs)

    def slot_shape(self, slot: int) -> Tuple[int, ...]:
        """Element-local shape of a value slot (no batch axis)."""
        shapes = [shape for _, shape, _ in self.inputs]
        for op in self.ops:
            if op[0] == "contract":
                shapes.append(shapes[op[1]])
            else:
                shapes.append(shapes[op[2]])
        return shapes[slot]

    def flops_per_element(self) -> int:
        """Mirror of ``ir.Node.flops`` summed over the recipe."""
        total = 0
        for op in self.ops:
            if op[0] == "contract":
                total += 2 * self.p * math.prod(self.slot_shape(op[1]))
            else:
                total += math.prod(self.slot_shape(op[2]))
        return total


def apply_recipe(recipe: GemmRecipe, vals, *, f32=jnp.float32):
    """Run the op chain over loaded values (index 0 is the batch/block
    axis of element values).  Shared by the Pallas kernel body and the
    XLA reference path -- a block of BE elements and a full batch of E
    elements have the same layout, so the code is identical."""
    p = recipe.p
    vals = list(vals)
    for op in recipe.ops:
        if op[0] == "contract":
            _, src, mat, mode, mat_dim, perm = op
            x, m = vals[src], vals[mat]
            mc = m if mat_dim == 0 else m.T
            ax = mode + 1                       # skip the batch/block axis
            xt = jnp.moveaxis(x, ax, 0)         # (p_l, BE, p, ...)
            xm = xt.reshape(p, -1)              # (p_l, BE * p^(r-1))
            ym = jax.lax.dot_general(
                mc, xm, (((0,), (0,)), ((), ())),
                preferred_element_type=f32,
            )
            y = jnp.moveaxis(ym.reshape(xt.shape), 0, ax)
            if tuple(perm) != tuple(range(len(perm))):
                y = jnp.transpose(y, (0,) + tuple(q + 1 for q in perm))
            vals.append(y)
        else:
            _, eop, lhs, rhs, const = op
            a = vals[lhs]
            if eop == "add":
                y = a + vals[rhs]
            elif eop == "sub":
                y = a - vals[rhs]
            elif eop == "mul":
                y = a * vals[rhs]
            elif eop == "div":
                y = a / vals[rhs]
            elif eop == "neg":
                y = -a
            elif eop == "scale":
                y = a * const
            else:  # pragma: no cover - matcher only emits EWISE_OPS
                raise ValueError(f"unknown ewise op {eop!r}")
            vals.append(y)
    return vals


def _kernel(*refs, recipe: GemmRecipe):
    n_in = recipe.n_inputs
    vals = [refs[i][...].astype(jnp.float32) for i in range(n_in)]
    vals = apply_recipe(recipe, vals)
    for j, (_, slot) in enumerate(recipe.outputs):
        out_ref = refs[n_in + j]
        out_ref[...] = vals[slot].astype(out_ref.dtype)


@functools.lru_cache(maxsize=None)
def _pallas_fn(recipe: GemmRecipe, block_elements: int, interpret: bool):
    """Build (and cache) the jitted pallas_call for one recipe/block."""

    def call(*arrays):
        e = None
        for (_, _, is_elem), a in zip(recipe.inputs, arrays):
            if is_elem:
                e = a.shape[0]
                break
        be = min(block_elements, e)
        if e % be != 0:
            raise ValueError(
                f"element count {e} not divisible by block {be}"
            )
        in_specs = []
        for (_, shape, is_elem) in recipe.inputs:
            if is_elem:
                zeros = (0,) * len(shape)
                in_specs.append(pl.BlockSpec(
                    (be,) + tuple(shape),
                    lambda g, _z=zeros: (g,) + _z,
                ))
            else:                               # shared: pinned to block 0
                zeros = (0,) * len(shape)
                in_specs.append(pl.BlockSpec(
                    tuple(shape), lambda g, _z=zeros: _z,
                ))
        out_dtype = arrays[0].dtype
        out_specs, out_shape = [], []
        for _, slot in recipe.outputs:
            shape = recipe.slot_shape(slot)
            zeros = (0,) * len(shape)
            out_specs.append(pl.BlockSpec(
                (be,) + tuple(shape), lambda g, _z=zeros: (g,) + _z,
            ))
            out_shape.append(
                jax.ShapeDtypeStruct((e,) + tuple(shape), out_dtype)
            )
        got = pl.pallas_call(
            functools.partial(_kernel, recipe=recipe),
            grid=(e // be,),
            in_specs=in_specs,
            out_specs=out_specs,
            out_shape=out_shape,
            interpret=interpret,
        )(*arrays)
        return got

    return jax.jit(call)


def gemm_chain_pallas(
    recipe: GemmRecipe,
    env: Dict[str, jax.Array],
    *,
    block_elements: int = DEFAULT_BLOCK_ELEMENTS,
    interpret: bool = False,
) -> Dict[str, jax.Array]:
    """Run one recipe through the tiled Pallas kernel.  ``env`` maps the
    recipe's input names to arrays (element tensors batched on axis 0)."""
    arrays = tuple(env[name] for name, _, _ in recipe.inputs)
    got = _pallas_fn(recipe, block_elements, interpret)(*arrays)
    return {name: out for (name, _), out in zip(recipe.outputs, got)}


@functools.lru_cache(maxsize=None)
def _ref_fn(recipe: GemmRecipe):
    def call(*arrays):
        vals = [a.astype(jnp.float32) for a in arrays]
        vals = apply_recipe(recipe, vals)
        return [
            vals[slot].astype(arrays[0].dtype)
            for _, slot in recipe.outputs
        ]

    return jax.jit(call)


def gemm_chain_ref(
    recipe: GemmRecipe, env: Dict[str, jax.Array]
) -> Dict[str, jax.Array]:
    """Pure-jnp reference: the same recipe applied to the whole batch
    (element axis 0 plays the block axis)."""
    arrays = tuple(env[name] for name, _, _ in recipe.inputs)
    got = _ref_fn(recipe)(*arrays)
    return {name: out for (name, _), out in zip(recipe.outputs, got)}
