"""Flash attention in pure XLA ops with a custom VJP.

The Pallas kernel (attention.py) is the TPU fast path, but it cannot lower
through the CPU-backed 512-device dry-run.  This module implements the
same online-softmax dataflow with `lax.scan` over KV chunks and a
hand-written backward pass (recompute-per-chunk), so that

  * no (Tq, Tk) score matrix is ever materialized (the memory-roofline
    killer at 4k-32k sequence lengths), and
  * backward memory is O(T d) residuals (q, k, v, o, LSE) instead of the
    O(T^2) softmax residuals XLA would otherwise save.

This is the paper's FIFO-streamed dataflow idea applied to attention:
stage boundaries that would round-trip HBM are collapsed into a scanned
chunk pipeline.  Used by ops.multi_head_attention(impl='xla') for long
sequences and by the dry-run cells.

Layout: q (B, Hq, Tq, d), k/v (B, Hkv, Tk, d); GQA folds the group into
the head dim on entry.  Causal masking assumes queries occupy the LAST
Tq positions of the Tk context (prefill/train: Tq == Tk).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30

#: default KV chunk width; the scan-cost probes temporarily raise this to
#: the full context so the single-trip scan body carries the whole cost
#: (XLA counts while bodies once -- see analysis.scancost).
DEFAULT_CHUNK = 1024


def _chunk(x, n):
    """(B, H, T, d) -> (n_chunks, B, H, W, d)"""
    B, H, T, d = x.shape
    return x.reshape(B, H, n, T // n, d).transpose(2, 0, 1, 3, 4)


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5)
)
def _flash(q, k, v, scale: float, causal: bool, chunk: int):
    o, _ = _flash_fwd_impl(q, k, v, scale, causal, chunk)
    return o


def _flash_fwd_impl(q, k, v, scale, causal, chunk):
    B, H, Tq, d = q.shape
    Tk = k.shape[2]
    n = max(1, Tk // chunk)
    W = Tk // n
    ks = _chunk(k, n)
    vs = _chunk(v, n)
    q_off = Tk - Tq
    qpos = q_off + jnp.arange(Tq)

    def step(carry, inp):
        m, l, acc, j = carry[0], carry[1], carry[2], carry[3]
        kj, vj = inp
        s = jnp.einsum("bhqd,bhkd->bhqk", q, kj,
                       preferred_element_type=jnp.float32) * scale
        if causal:
            kpos = j * W + jnp.arange(W)
            mask = qpos[:, None] >= kpos[None, :]
            s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p.astype(vj.dtype), vj,
            preferred_element_type=jnp.float32,
        )
        return (m_new, l, acc, j + 1), None

    m0 = jnp.full((B, H, Tq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Tq), jnp.float32)
    a0 = jnp.zeros((B, H, Tq, d), jnp.float32)
    (m, l, acc, _), _ = jax.lax.scan(step, (m0, l0, a0, 0), (ks, vs))
    l_safe = jnp.where(l == 0.0, 1.0, l)
    o = (acc / l_safe[..., None]).astype(q.dtype)
    lse = m + jnp.log(l_safe)
    return o, lse


def _flash_fwd(q, k, v, scale, causal, chunk):
    o, lse = _flash_fwd_impl(q, k, v, scale, causal, chunk)
    return o, (q, k, v, o, lse)


def _flash_bwd(scale, causal, chunk, res, do):
    q, k, v, o, lse = res
    B, H, Tq, d = q.shape
    Tk = k.shape[2]
    n = max(1, Tk // chunk)
    W = Tk // n
    ks = _chunk(k, n)
    vs = _chunk(v, n)
    q_off = Tk - Tq
    qpos = q_off + jnp.arange(Tq)
    dof = do.astype(jnp.float32)
    # D_i = rowsum(do * o)
    Dm = jnp.sum(dof * o.astype(jnp.float32), axis=-1)  # (B,H,Tq)

    def step(dq, inp):
        kj, vj, j = inp
        s = jnp.einsum("bhqd,bhkd->bhqk", q, kj,
                       preferred_element_type=jnp.float32) * scale
        if causal:
            kpos = j * W + jnp.arange(W)
            mask = qpos[:, None] >= kpos[None, :]
            s = jnp.where(mask[None, None], s, NEG_INF)
        p = jnp.exp(s - lse[..., None])                  # (B,H,Tq,W)
        dv_j = jnp.einsum("bhqk,bhqd->bhkd", p, dof,
                          preferred_element_type=jnp.float32)
        dp = jnp.einsum("bhqd,bhkd->bhqk", dof, vj.astype(jnp.float32),
                        preferred_element_type=jnp.float32)
        ds = p * (dp - Dm[..., None]) * scale
        dq = dq + jnp.einsum("bhqk,bhkd->bhqd", ds, kj.astype(jnp.float32),
                             preferred_element_type=jnp.float32)
        dk_j = jnp.einsum("bhqk,bhqd->bhkd", ds, q.astype(jnp.float32),
                          preferred_element_type=jnp.float32)
        return dq, (dk_j, dv_j)

    dq0 = jnp.zeros((B, H, Tq, d), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(
        step, dq0, (ks, vs, jnp.arange(n))
    )
    dk = dks.transpose(1, 2, 0, 3, 4).reshape(B, H, Tk, d)
    dv = dvs.transpose(1, 2, 0, 3, 4).reshape(B, H, Tk, d)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention_xla(
    q: jax.Array,   # (B, Hq, Tq, d)
    k: jax.Array,   # (B, Hkv, Tk, d)
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
    chunk: int | None = None,
) -> jax.Array:
    if chunk is None:
        chunk = DEFAULT_CHUNK
    B, Hq, Tq, d = q.shape
    _, Hkv, Tk, _ = k.shape
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    group = Hq // Hkv
    # GQA: repeat KV heads into the group (einsum-level broadcast keeps
    # this a view until the chunked dots consume it)
    if group > 1:
        k = jnp.repeat(k, group, axis=1)
        v = jnp.repeat(v, group, axis=1)
    chunk = min(chunk, Tk)
    if Tk % chunk:
        chunk = Tk  # fallback: single chunk
    return _flash(q, k, v, scale, causal, chunk)
