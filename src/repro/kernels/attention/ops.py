"""Public attention entry point with implementation switch.

Models call ``multi_head_attention`` with (B, T, H, d) tensors; head
folding to the kernel layout happens here.  ``impl='xla'`` (default on
CPU / in dry-runs) evaluates the same math with jnp ops so that the
512-device lowering contains plain dots; ``impl='pallas'`` dispatches the
flash kernel on TPU; ``impl='interpret'`` validates the kernel on CPU.
"""
from __future__ import annotations

import functools
from typing import Literal, Optional

import jax
import jax.numpy as jnp

from .attention import flash_attention_pallas
from .ref import attention_ref
from .xla_flash import flash_attention_xla

Impl = Literal["auto", "pallas", "interpret", "xla", "xla_flash"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _xla_attention(q, k, v, *, causal: bool, scale: float):
    """(B, Hq, Tq, d) x (B, Hkv, Tk, d) GQA attention in plain XLA ops."""
    B, Hq, Tq, d = q.shape
    _, Hkv, Tk, _ = k.shape
    group = Hq // Hkv
    qh = q.reshape(B, Hkv, group, Tq, d)
    s = jnp.einsum(
        "bhgqd,bhkd->bhgqk", qh, k, preferred_element_type=jnp.float32
    ) * scale
    if causal:
        qpos = jnp.arange(Tq)[:, None] + (Tk - Tq)
        kpos = jnp.arange(Tk)[None, :]
        s = jnp.where((qpos >= kpos)[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum(
        "bhgqk,bhkd->bhgqd", p.astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    )
    return o.reshape(B, Hq, Tq, d).astype(q.dtype)


def multi_head_attention(
    q: jax.Array,   # (B, Hq, Tq, d)
    k: jax.Array,   # (B, Hkv, Tk, d)
    v: jax.Array,   # (B, Hkv, Tk, d)
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    impl: Impl = "auto",
    block_q: int = 512,
    block_k: int = 512,
) -> jax.Array:
    B, Hq, Tq, d = q.shape
    _, Hkv, Tk, _ = k.shape
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "xla"
    if impl == "xla_flash":
        return flash_attention_xla(q, k, v, causal=causal, scale=scale)
    if impl == "xla":
        return _xla_attention(q, k, v, causal=causal, scale=scale)

    qf = q.reshape(B * Hq, Tq, d)
    kf = k.reshape(B * Hkv, Tk, d)
    vf = v.reshape(B * Hkv, Tk, d)
    out = flash_attention_pallas(
        qf, kf, vf,
        n_q_heads=Hq, n_kv_heads=Hkv, causal=causal, scale=scale,
        block_q=block_q, block_k=block_k,
        interpret=(impl == "interpret"),
    )
    return out.reshape(B, Hq, Tq, d)
