"""Flash attention Pallas TPU kernel with GQA and causal masking.

Tiling (BlockSpec):
  grid = (G, Tq/bq, Tk/bk) over head-folded arrays
    q (G, Tq, d) blocked (1, bq, d)
    k/v (Gkv, Tk, d) blocked (1, bk, d); the head index_map folds the GQA
    group mapping  g_kv = (g // Hq) * Hkv + (g %% Hq) // (Hq/Hkv)
  o (G, Tq, d) blocked (1, bq, d); written once, on the last kv step.

Running softmax state (m, l, acc) lives in VMEM scratch across the kv
grid dimension (standard online-softmax recurrence).  Causal blocks above
the diagonal are skipped with pl.when -- the Mosaic grid still visits
them, but no compute or DMA-consumed writes are issued.

VMEM budget per step: bq*d + 2*bk*d + bq*bk + bq*d (acc) floats; with
bq=bk=512, d=128 and f32 accumulation that is ~1.4 MB -- far under the
64 MB working budget, leaving room for Mosaic's automatic double
buffering of the k/v streams (the paper's ping/pong, one level down).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, bq: int, bk: int, blocks_k: int,
            q_offset: int):
    ki = pl.program_id(2)
    qi = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal: visit only blocks with any (qpos >= kpos) overlap
    q_end = (qi + 1) * bq - 1 + q_offset
    visit = (q_end >= ki * bk) if causal else (ki >= 0)

    @pl.when(visit)
    def _body():
        q = q_ref[0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0].astype(jnp.float32)          # (bk, d)
        v = v_ref[0].astype(jnp.float32)          # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                  # (bq, bk)
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bk), 0) + q_offset
            kpos = ki * bk + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_scr[...]                        # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                     # (bq, bk)
        corr = jnp.exp(m_prev - m_new)             # (bq, 1)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

    @pl.when(ki == blocks_k - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_q_heads", "n_kv_heads", "causal", "scale", "block_q", "block_k",
        "interpret",
    ),
)
def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    n_q_heads: int,
    n_kv_heads: int,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """q: (G, Tq, d) with G = batch*n_q_heads; k/v: (Gkv, Tk, d)."""
    G, Tq, d = q.shape
    Gkv, Tk, _ = k.shape
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    bq = min(block_q, Tq)
    bk = min(block_k, Tk)
    if Tq % bq or Tk % bk:
        raise ValueError(f"seq lens ({Tq},{Tk}) not divisible by blocks ({bq},{bk})")
    group = n_q_heads // n_kv_heads
    blocks_k = Tk // bk
    q_offset = Tk - Tq  # decode/churn alignment: queries sit at the end

    def kv_head(g):
        return (g // n_q_heads) * n_kv_heads + (g % n_q_heads) // group

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, bq=bq, bk=bk,
        blocks_k=blocks_k, q_offset=q_offset,
    )
    return pl.pallas_call(
        kernel,
        grid=(G, Tq // bq, blocks_k),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda g, qi, ki: (g, qi, 0)),
            pl.BlockSpec((1, bk, d), lambda g, qi, ki: (kv_head(g), ki, 0)),
            pl.BlockSpec((1, bk, d), lambda g, qi, ki: (kv_head(g), ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda g, qi, ki: (g, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max m
            pltpu.VMEM((bq, 1), jnp.float32),   # running denom l
            pltpu.VMEM((bq, d), jnp.float32),   # unnormalized accumulator
        ],
        interpret=interpret,
    )(q, k, v)
