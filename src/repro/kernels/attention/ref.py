"""Pure-jnp oracle for flash attention (GQA + causal).

Shapes (head-folded layout used by the kernel):
  q: (G, Tq, d)  where G = batch * n_q_heads
  k, v: (Gkv, Tk, d) where Gkv = batch * n_kv_heads
"""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, *, n_q_heads: int, n_kv_heads: int,
                  causal: bool = True, scale: float | None = None):
    G, Tq, d = q.shape
    Gkv, Tk, _ = k.shape
    batch = G // n_q_heads
    group = n_q_heads // n_kv_heads
    if scale is None:
        scale = 1.0 / (d ** 0.5)

    qh = q.reshape(batch, n_kv_heads, group, Tq, d)
    kh = k.reshape(batch, n_kv_heads, 1, Tk, d)
    vh = v.reshape(batch, n_kv_heads, 1, Tk, d)
    s = jnp.einsum("bhgqd,bhgkd->bhgqk", qh.astype(jnp.float32),
                   jnp.broadcast_to(kh, qh.shape[:3] + (Tk, d)).astype(jnp.float32))
    s = s * scale
    if causal:
        qpos = jnp.arange(Tq)[:, None] + (Tk - Tq)
        kpos = jnp.arange(Tk)[None, :]
        mask = qpos >= kpos
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhgqk,bhgkd->bhgqd", p,
                   jnp.broadcast_to(vh, qh.shape[:3] + (Tk, d)).astype(jnp.float32))
    return o.reshape(G, Tq, d).astype(q.dtype)
