"""Element-batched simulation driver -- the Olympus system/host layer.

Implements the paper's section 3.1 quantities on the TPU mesh:

  * **batch**: ``E`` elements processed per dispatch.  The paper sizes E
    so a batch fills one 256 MB HBM pseudo-channel; here we size it so a
    batch fills a target fraction of per-device HBM.
  * **N_b = N_eq / E** batches, **I = N_b / N_cu** iterations, where the
    CU count is the number of mesh devices the element axis is sharded
    over (CU replication == data parallelism over elements).
  * **double buffering**: batch k+1 is transferred host->device while
    batch k computes (JAX async dispatch + explicit device_put staging --
    the ping/pong channel pair of Fig. 14a).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .operators import build_inverse_helmholtz, flops_per_element


@dataclasses.dataclass
class SimConfig:
    p: int = 11
    n_eq: int = 2_000_000          # paper: 2M elements simulated
    batch_elements: int = 4096     # E
    policy: str = "float32"
    backend: str = "xla"
    double_buffer: bool = True
    seed: int = 0

    @property
    def n_batches(self) -> int:
        return self.n_eq // self.batch_elements

    def bytes_per_element(self, bytes_per_scalar: int = 4) -> int:
        # u, D in; v out  (S shared, amortized)
        return 3 * self.p ** 3 * bytes_per_scalar

    @classmethod
    def batch_for_channel(cls, p: int, channel_bytes: int = 256 * 2 ** 20,
                          bytes_per_scalar: int = 4) -> int:
        """The paper's E: elements whose I/O fits one HBM channel."""
        return channel_bytes // (3 * p ** 3 * bytes_per_scalar)


def element_mesh(devices=None) -> Mesh:
    """1-D mesh over all local devices: the CU-replication axis."""
    devices = devices if devices is not None else jax.devices()
    return Mesh(np.array(devices), ("elements",))


def _batch_generator(cfg: SimConfig) -> Iterator[Dict[str, np.ndarray]]:
    """Deterministic, resumable synthetic element stream ([-1,1] data,
    matching the paper's range normalization)."""
    p = cfg.p
    for b in range(cfg.n_batches):
        rng = np.random.default_rng(cfg.seed + b)
        yield {
            "D": rng.uniform(-1, 1, (cfg.batch_elements, p, p, p)).astype(np.float32),
            "u": rng.uniform(-1, 1, (cfg.batch_elements, p, p, p)).astype(np.float32),
        }


@dataclasses.dataclass
class SimResult:
    batches: int
    elements: int
    wall_s: float
    checksum: float

    @property
    def gflops(self) -> float:
        return 0.0 if self.wall_s == 0 else (
            self.elements * 1e-9 / self.wall_s
        )


def run_simulation(
    cfg: SimConfig,
    *,
    mesh: Optional[Mesh] = None,
    max_batches: Optional[int] = None,
    S: Optional[np.ndarray] = None,
) -> SimResult:
    """Run the batched Inverse-Helmholtz simulation.

    Returns wall time and a checksum; GFLOPS is derived with the paper's
    op-count model by the caller (benchmarks/).
    """
    mesh = mesh or element_mesh()
    compiled = build_inverse_helmholtz(
        cfg.p, policy=cfg.policy, backend=cfg.backend
    )
    rng = np.random.default_rng(cfg.seed + 2 ** 31)
    if S is None:
        S = rng.uniform(-1, 1, (cfg.p, cfg.p)).astype(np.float32)

    elem_sharding = NamedSharding(mesh, P("elements"))
    repl_sharding = NamedSharding(mesh, P())
    S_dev = jax.device_put(S, repl_sharding)

    n = cfg.n_batches if max_batches is None else min(max_batches, cfg.n_batches)
    gen = _batch_generator(cfg)

    def stage(batch):
        return {
            k: jax.device_put(v, elem_sharding) for k, v in batch.items()
        }

    checksum = 0.0
    t0 = time.perf_counter()
    pending = None
    staged = stage(next(gen))
    for b in range(n):
        nxt = None
        if cfg.double_buffer and b + 1 < n:
            # ping/pong: enqueue next transfer before waiting on compute
            nxt = stage(next(gen))
        out = compiled.batched_fn({"S": S_dev, **staged})
        if pending is not None:
            checksum += float(pending)  # blocks on the *previous* batch
        pending = jnp.sum(out["v"])
        if nxt is None and b + 1 < n:
            nxt = stage(next(gen))
        staged = nxt
    checksum += float(pending)
    wall = time.perf_counter() - t0
    elements = n * cfg.batch_elements
    return SimResult(
        batches=n, elements=elements, wall_s=wall, checksum=checksum
    )


def achieved_gflops(res: SimResult, p: int) -> float:
    """GFLOPS under the paper's Eq. (2)-(3) accounting."""
    n_op = res.elements * flops_per_element(p)
    return n_op / res.wall_s / 1e9 if res.wall_s > 0 else 0.0
