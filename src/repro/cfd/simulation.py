"""Element-batched simulation driver -- the Olympus system/host layer.

Implements the paper's section 3.1 quantities on the TPU mesh:

  * **batch**: ``E`` elements processed per dispatch.  The paper sizes E
    so a batch fills one 256 MB HBM pseudo-channel; here the sizing (and
    every other memory decision) comes from an explicit
    :class:`repro.memory.MemoryPlan` -- the driver holds no hardcoded
    batch size.
  * **N_b = N_eq / E** batches, **I = N_b / N_cu** iterations, where the
    CU count is the number of mesh devices the element axis is sharded
    over (CU replication == data parallelism over elements).
  * **transfer pipelining**: batch k+K..k+1 transfer host->device while
    batch k computes, through the generic K-deep engine in
    ``repro.memory.pipeline`` (K=1 is the ping/pong channel pair of
    Fig. 14a; K=0 is the serial baseline).
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Dict, Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..memory import chain as memchain
from ..memory import channels as memchannels
from ..memory import dse as memdse
from ..memory import pipeline as mempipe
from ..memory.placement import DeviceTopology
from ..memory.plan import MemoryPlan
from .operators import build_inverse_helmholtz, flops_per_element


@dataclasses.dataclass
class SimConfig:
    p: int = 11
    n_eq: int = 2_000_000          # paper: 2M elements simulated
    #: E -- None lets the MemoryPlan auto-size it from the channel model
    batch_elements: Optional[int] = None
    policy: str = "float32"
    backend: str = "xla"
    double_buffer: bool = True
    #: K batches staged ahead; None derives it from ``double_buffer``
    prefetch_depth: Optional[int] = None
    seed: int = 0

    @property
    def depth(self) -> int:
        if self.prefetch_depth is not None:
            return self.prefetch_depth
        return 1 if self.double_buffer else 0

    @property
    def n_batches(self) -> int:
        if self.batch_elements is None:
            raise ValueError(
                "batch_elements unset -- resolve a MemoryPlan first "
                "(simulation.plan_config) or set it explicitly"
            )
        return self.n_eq // self.batch_elements

    def bytes_per_element(self, bytes_per_scalar: int = 4) -> int:
        # u, D in; v out  (S shared, amortized)
        return 3 * self.p ** 3 * bytes_per_scalar

    @classmethod
    def batch_for_channel(cls, p: int, channel_bytes: int = 256 * 2 ** 20,
                          bytes_per_scalar: int = 4) -> int:
        """The paper's E: elements whose I/O fits one HBM channel."""
        return channel_bytes // (3 * p ** 3 * bytes_per_scalar)


def element_mesh(devices=None) -> Mesh:
    """1-D mesh over all local devices: the CU-replication axis."""
    devices = devices if devices is not None else jax.devices()
    return Mesh(np.array(devices), ("elements",))


def plan_config(
    cfg: SimConfig,
    *,
    target: Optional[memchannels.MemoryTarget] = None,
    cu_count: int = 1,
) -> MemoryPlan:
    """Resolve the memory architecture for this simulation config.

    Explicit ``cfg.batch_elements`` is honored; otherwise the planner
    auto-sizes E against the target's pseudo-channel capacity.
    """
    return memdse.make_plan(
        cfg.p,
        target=target if target is not None else memchannels.detect_target(),
        policy=cfg.policy,
        backend=cfg.backend,
        batch_elements=cfg.batch_elements,
        prefetch_depth=cfg.depth,
        cu_count=cu_count,
        n_eq=cfg.n_eq,
    )


def _batch_generator(
    p: int, batch_elements: int, n_batches: int, seed: int
) -> Iterator[Dict[str, np.ndarray]]:
    """Deterministic, resumable synthetic element stream ([-1,1] data,
    matching the paper's range normalization)."""
    for b in range(n_batches):
        rng = np.random.default_rng(seed + b)
        yield {
            "D": rng.uniform(-1, 1, (batch_elements, p, p, p)).astype(np.float32),
            "u": rng.uniform(-1, 1, (batch_elements, p, p, p)).astype(np.float32),
        }


@dataclasses.dataclass
class SimResult:
    batches: int
    elements: int
    wall_s: float
    checksum: float
    plan: Optional[MemoryPlan] = None

    @property
    def gflops(self) -> float:
        return 0.0 if self.wall_s == 0 else (
            self.elements * 1e-9 / self.wall_s
        )


def run_simulation(
    cfg: SimConfig,
    *,
    mesh: Optional[Mesh] = None,
    max_batches: Optional[int] = None,
    S: Optional[np.ndarray] = None,
    plan: Optional[MemoryPlan] = None,
    tracer=None,
) -> SimResult:
    """Run the batched Inverse-Helmholtz simulation under a MemoryPlan.

    The plan supplies E, the prefetch depth, and donation hints; pass one
    explicitly (e.g. a DSE winner) or let ``plan_config`` derive it.
    Returns wall time and a checksum; GFLOPS is derived with the paper's
    op-count model by the caller (benchmarks/).

    ``tracer`` (``repro.trace.Tracer``; None = off) records the staging/
    dispatch/sync spans of the K-deep engine plus per-channel host byte
    counters from the plan's buffer table.
    """
    mesh = mesh or element_mesh()
    if plan is None:
        plan = plan_config(cfg, cu_count=int(mesh.devices.size))
    E = plan.batch_elements
    depth = plan.prefetch_depth

    # donation is an accelerator-path optimization; the CPU runtime warns
    # and ignores it, so only forward the hint off-host.  The plan also
    # supplies the Pallas kernel's VMEM-budgeted block_elements.
    donate = plan.donation if jax.default_backend() != "cpu" else ()
    compiled = build_inverse_helmholtz(
        cfg.p, policy=cfg.policy, backend=cfg.backend, donate_args=donate,
        plan=plan,
    )
    rng = np.random.default_rng(cfg.seed + 2 ** 31)
    if S is None:
        S = rng.uniform(-1, 1, (cfg.p, cfg.p)).astype(np.float32)

    elem_sharding = NamedSharding(mesh, P("elements"))
    repl_sharding = NamedSharding(mesh, P())
    S_dev = jax.device_put(S, repl_sharding)

    n_total = cfg.n_eq // E
    n = n_total if max_batches is None else min(max_batches, n_total)

    def stage(batch):
        return {
            k: jax.device_put(v, elem_sharding) for k, v in batch.items()
        }

    if tracer:
        from ..trace.attribution import (COUNTER_CHANNEL_BYTES,
                                         host_channel_bytes)

        ch_bytes = {
            str(c): float(b)
            for c, b in host_channel_bytes(plan.buffers).items()
        }
        inner_stage = stage

        def stage(batch):
            tracer.bump(COUNTER_CHANNEL_BYTES, ch_bytes)
            return inner_stage(batch)

    def compute(staged):
        return compiled.batched_fn({"S": S_dev, **staged})

    t0 = time.perf_counter()
    sums = mempipe.run_pipelined(
        compute,
        _batch_generator(cfg.p, E, n, cfg.seed),
        stage_fn=stage,
        depth=depth,
        reduce_fn=lambda out: jnp.sum(out["v"]),
        tracer=tracer,
        stage_name=plan.operator,
    )
    wall = time.perf_counter() - t0
    checksum = 0.0
    for s in sums:
        checksum += float(s)
    return SimResult(
        batches=n, elements=n * E, wall_s=wall, checksum=checksum, plan=plan
    )


def achieved_gflops(res: SimResult, p: int) -> float:
    """GFLOPS under the paper's Eq. (2)-(3) accounting."""
    n_op = res.elements * flops_per_element(p)
    return n_op / res.wall_s / 1e9 if res.wall_s > 0 else 0.0


# ---------------------------------------------------------------------------
# multi-operator chain driver (interpolation -> gradient -> Helmholtz)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ChainResult:
    """One run of a whole pipeline off a single ChainPlan."""

    batches: int
    elements: int
    wall_s: float
    checksums: Dict[str, float]
    plan: Optional[memchain.ChainPlan] = None
    #: full chain outputs, qualified "stage.output" (collect_outputs=True)
    outputs: Optional[Dict[str, np.ndarray]] = None
    #: whether stages were cross-batch pipelined (one dispatch ring per
    #: stage) or run back-to-back per batch (the serial baseline)
    pipelined_stages: bool = False
    #: per-stage local device groups the run actually executed on (None
    #: when the placement degenerated to the single global mesh)
    placement_groups: Optional[Tuple[Tuple[int, ...], ...]] = None
    #: batch indices the StepMonitor flagged as stragglers (empty when no
    #: monitor was passed or nothing was flagged)
    straggler_batches: Tuple[int, ...] = ()


def _chain_batch_inputs(
    chain: memchain.ProgramChain,
    E: int,
    n_batches: int,
    seed: int,
    inputs: Optional[Dict[str, np.ndarray]],
) -> Iterator[Dict[str, np.ndarray]]:
    """Per-batch host-streamed inputs, qualified "stage.input".

    ``inputs`` supplies full arrays (element-axis leading) to slice;
    otherwise a deterministic synthetic stream is generated, matching
    ``_batch_generator``'s [-1, 1] normalization."""
    names = [
        f"{s.name}.{n}"
        for i, s in enumerate(chain.stages)
        for n, _ in chain.host_element_inputs(i)
    ]
    shapes = {
        f"{s.name}.{n}": v.shape
        for i, s in enumerate(chain.stages)
        for n, v in chain.host_element_inputs(i)
    }
    for b in range(n_batches):
        if inputs is not None:
            yield {q: inputs[q][b * E:(b + 1) * E] for q in names}
        else:
            rng = np.random.default_rng(seed + b)
            yield {
                q: rng.uniform(-1, 1, (E,) + shapes[q]).astype(np.float32)
                for q in names
            }


def run_chain(
    chain: memchain.ProgramChain,
    plan: Optional[memchain.ChainPlan] = None,
    *,
    n_eq: Optional[int] = None,
    mesh: Optional[Mesh] = None,
    max_batches: Optional[int] = None,
    seed: int = 0,
    inputs: Optional[Dict[str, np.ndarray]] = None,
    shared: Optional[Dict[str, np.ndarray]] = None,
    collect_outputs: bool = False,
    pipeline_stages: Optional[bool] = None,
    tracer=None,
    monitor=None,
    metrics=None,
) -> ChainResult:
    """Execute a whole multi-operator pipeline off one ChainPlan.

    Bound streams (e.g. interpolation's ``w`` into the gradient) never
    leave the device -- exactly the residency the plan prices.  The
    execution schedule comes from the plan's ``pipeline`` spec: in
    pipelined mode each stage gets its own dispatch ring and stage i of
    batch k is dispatched alongside stage i+1 of batch k-1
    (``memory.pipeline.run_stage_pipelined``); in serial mode stages run
    back-to-back per batch -- the paper's baseline, bitwise-equal to the
    pipelined schedule at float32.  ``pipeline_stages`` overrides the
    plan's mode (e.g. to force the serial baseline for an equality
    test or a ladder rung).

    Host-streamed inputs come from ``inputs`` (full arrays, qualified
    "stage.input") or a deterministic synthetic stream; ``shared``
    supplies the batch-invariant operands by bare name (synthesized when
    omitted).

    ``collect_outputs`` returns the concatenated chain outputs for
    verification against an unchained reference; by default only a
    checksum per output crosses back (the plan's host-out streams are
    still priced -- the reduction is a measurement convenience, as in
    ``run_simulation``).

    ``tracer`` (``repro.trace.Tracer``; None = off) records the full
    span hierarchy -- chain run -> per-stage slot -> dispatch/handoff --
    plus per-channel host byte, pad-element and CU-occupancy counters
    from the plan, ready for ``repro.trace.attribution``.  ``monitor``
    (a ``runtime.StepMonitor``) watches per-batch retire times; flagged
    batches are annotated on their sync spans and reported in
    ``ChainResult.straggler_batches``.  ``metrics`` (a ``repro.metrics``
    registry) records the driver's always-on per-stage dispatch/stall
    histograms keyed by the plan signature.  None changes results.
    """
    mesh = mesh or element_mesh()
    if n_eq is None and inputs:
        # the data bounds the problem -- derive n_eq before planning so
        # the auto-sized E can never exceed what the arrays hold
        n_eq = min(v.shape[0] for v in inputs.values())
    local_devices = list(mesh.devices.flatten())
    if plan is None:
        plan = memchain.plan_chain(
            chain, target=memchannels.detect_target(),
            cu_count=len(local_devices),
            # per-device kind derivation: a mixed local pool becomes a
            # grouped topology instead of N copies of device 0's platform
            topology=DeviceTopology.from_jax(local_devices),
            n_eq=n_eq,
        )
    planned = tuple(sp.backend for sp in plan.stages)
    compiled = tuple(s.backend for s in chain.stages)
    if planned != compiled:
        warnings.warn(
            f"run_chain: plan backends {planned} differ from the "
            f"compiled chain's {compiled}; executing the compiled chain. "
            "Rebuild it for the plan (e.g. operators.build_cfd_chain("
            "backends=..., chain_plan=plan)) to run as planned.",
            RuntimeWarning,
        )
    E = plan.batch_elements
    pipe = plan.pipeline
    if pipe is None:  # legacy plan: derive the spec from the stage Ks
        pipe = memchain.derive_pipeline(
            [sp.prefetch_depth for sp in plan.stages]
        )
    stage_depths = list(pipe.stage_depths)
    if len(stage_depths) != len(chain.stages):
        # a plan from a differently-staged compile still executes the
        # compiled chain (warned above): carry the plan's deepest K as
        # host staging and keep its mode with depth-1 rings
        stage_depths = [max(stage_depths)] + (
            [1 if pipe.pipelined else 0] * (len(chain.stages) - 1)
        )
    if pipeline_stages is None:
        pipeline_stages = pipe.pipelined
    if pipeline_stages:
        depths = stage_depths
        # forcing the mode on cannot pipeline a plan with no inter-stage
        # ring depth: execution (and the reported flag) stays serial
        pipeline_stages = len(depths) > 1 and any(d > 0 for d in depths[1:])
    else:
        # serial baseline: host staging only, stages back-to-back
        depths = [max(stage_depths)] + [0] * (len(chain.stages) - 1)
    if n_eq is None:
        n_eq = E * (max_batches if max_batches else 4)
    if inputs is not None:
        avail = min(v.shape[0] for v in inputs.values())
        if E > avail:
            raise ValueError(
                f"plan batch E={E} exceeds the provided input arrays "
                f"({avail} elements); re-plan with n_eq or pass larger "
                "inputs"
            )
        # never slice past the data: an oversized n_eq would otherwise
        # run empty batches while reporting their elements as work done
        n_eq = min(n_eq, avail)
    n_total = max(1, n_eq // E)
    n = n_total if max_batches is None else min(max_batches, n_total)

    elem_sharding = NamedSharding(mesh, P("elements"))
    repl_sharding = NamedSharding(mesh, P())

    # placement execution: one dispatch ring per device group.  A plan
    # whose stage count matches the compiled chain and whose device
    # groups fit the local pool runs each stage element-sharded over its
    # own group's mesh, with the HBM-resident handoff resharded where it
    # crosses groups; every degenerate placement (single device, plan
    # for a bigger machine, stage-count mismatch) falls back to the
    # single global mesh -- the exact pre-placement path.
    place = getattr(plan, "placement", None)
    groups = None
    if place is not None and place.devices_used[-1] >= len(local_devices):
        warnings.warn(
            f"run_chain: plan placement spans "
            f"{place.topology.n_devices} device(s) but only "
            f"{len(local_devices)} are local; executing on the local "
            "mesh instead.",
            RuntimeWarning,
        )
    elif place is not None and place.n_stages == len(chain.stages):
        groups = mempipe.placement_meshes(place, devices=local_devices)
    if groups is not None:
        stage_meshes = [element_mesh(list(g)) for g in groups]
        stage_elem = [NamedSharding(m, P("elements")) for m in stage_meshes]
        stage_repl = [NamedSharding(m, P()) for m in stage_meshes]
    else:
        stage_elem = [elem_sharding] * len(chain.stages)
        stage_repl = [repl_sharding] * len(chain.stages)

    shared_host: Dict[str, np.ndarray] = {}
    for k, (name, node) in enumerate(sorted(chain.shared_operands().items())):
        if shared is not None and name in shared:
            shared_host[name] = np.asarray(shared[name])
        else:
            rng = np.random.default_rng(seed + 2 ** 31 + k)
            shared_host[name] = rng.uniform(
                -1, 1, node.shape
            ).astype(np.float32)
    # batch-invariant operands live replicated once per distinct device
    # group (one copy total on the single global mesh)
    shared_by_group: Dict = {}
    shared_for_stage: List[Dict[str, jax.Array]] = []
    for i in range(len(chain.stages)):
        key = groups[i] if groups is not None else None
        if key not in shared_by_group:
            shared_by_group[key] = {
                name: jax.device_put(h, stage_repl[i])
                for name, h in shared_host.items()
            }
        shared_for_stage.append(shared_by_group[key])

    out_names = [
        f"{s.name}.{n}"
        for i, s in enumerate(chain.stages)
        for n, _ in chain.chain_outputs(i)
    ]
    #: qualified host stream -> consuming stage (its group stages it)
    owner = {
        f"{s.name}.{n}": i
        for i, s in enumerate(chain.stages)
        for n, _ in chain.host_element_inputs(i)
    }

    def stage_batch(batch):
        return {
            k: jax.device_put(v, stage_elem[owner[k]])
            for k, v in batch.items()
        }

    if tracer:
        from ..trace.attribution import (COUNTER_CHANNEL_BYTES,
                                         COUNTER_OCCUPANCY,
                                         COUNTER_PAD_ELEMENTS,
                                         host_channel_bytes)

        tracer.meta.update({
            "chain": plan.chain, "target": plan.target.name,
            "policy": plan.policy, "signature": plan.signature,
            "batch_elements": E,
        })
        tracer.bump(COUNTER_OCCUPANCY, {
            sp.name: float(sp.cu_count) for sp in plan.stages
        })
        ch_bytes = {
            str(c): float(b)
            for c, b in host_channel_bytes(plan.buffers).items()
        }
        pad = plan.batch_pad_elements
        inner_stage_batch = stage_batch

        def stage_batch(batch):
            tracer.bump(COUNTER_CHANNEL_BYTES, ch_bytes)
            if pad:
                tracer.bump(COUNTER_PAD_ELEMENTS, {"pad": float(pad)})
            return inner_stage_batch(batch)

    # per-stage E_s: a heterogeneous plan runs some stages at a smaller
    # batch than the chain E -- the re-blocking handoff slices the chain
    # batch into E_s sub-batches on device and concatenates the outputs
    # (bitwise-equal to the full-batch call: elements are independent)
    stage_es = [
        plan.stage_e(i) if hasattr(plan, "stage_e") else E
        for i in range(len(plan.stages))
    ]
    if len(stage_es) != len(chain.stages):
        stage_es = [E] * len(chain.stages)

    def make_stage_fn(i: int, s: memchain.ChainStage):
        batched_fn = s.compiled.batched_fn
        e_s = stage_es[i]
        if 0 < e_s < E:
            batched_fn = mempipe.reblock_batched_fn(
                batched_fn, tuple(s.program.element_vars), e_s
            )

        def run_stage(staged, carry):
            live: Dict[str, jax.Array] = dict(carry) if carry else {}
            env: Dict[str, jax.Array] = {}
            for name in s.program.inputs:
                if name in chain.resolved[i]:
                    p_idx, out_name = chain.resolved[i][name]
                    env[name] = live[
                        f"{chain.stages[p_idx].name}.{out_name}"
                    ]
                elif name in shared_for_stage[i]:
                    env[name] = shared_for_stage[i][name]
                else:
                    env[name] = staged[f"{s.name}.{name}"]
            outs = batched_fn(env)
            for out_name, val in outs.items():
                live[f"{s.name}.{out_name}"] = val
            return live

        return run_stage

    stage_fns = [
        make_stage_fn(i, s) for i, s in enumerate(chain.stages)
    ]

    # multi-group handoff: before stage i consumes a batch, reshard the
    # HBM-resident streams it reads from producers on *other* groups
    place_fns = None
    if groups is not None:
        def make_place_fn(i: int):
            moves = sorted(
                f"{chain.stages[p].name}.{out}"
                for p, out in chain.resolved[i].values()
                if groups[p] != groups[i]
            )
            if not moves:
                return None
            sh = stage_elem[i]

            def place(staged, carry):
                carry = dict(carry) if carry else {}
                for q in moves:
                    carry[q] = jax.device_put(carry[q], sh)
                return staged, carry

            return place

        place_fns = [make_place_fn(i) for i in range(len(chain.stages))]

    if collect_outputs:
        reduce_fn = lambda live: jax.device_get(
            {q: live[q] for q in out_names}
        )
    else:
        reduce_fn = lambda live: {
            q: jnp.sum(live[q]) for q in out_names
        }

    m_count0 = monitor.count if monitor is not None else 0
    m_flags0 = len(monitor.flags) if monitor is not None else 0
    root = (tracer.begin("run_chain", "run", 0, chain=plan.chain,
                         batches=n, batch_elements=E,
                         pipelined=bool(pipeline_stages))
            if tracer else None)
    t0 = time.perf_counter()
    per_batch = mempipe.run_stage_pipelined(
        stage_fns,
        _chain_batch_inputs(chain, E, n, seed, inputs),
        stage_fn=stage_batch,
        depths=depths,
        reduce_fn=reduce_fn,
        place_fns=place_fns,
        tracer=tracer,
        monitor=monitor,
        stage_names=[s.name for s in chain.stages],
        metrics=metrics,
        metrics_labels={"plan": plan.signature[:12]} if metrics else None,
    )
    wall = time.perf_counter() - t0
    if root is not None:
        tracer.end(root)
    stragglers: Tuple[int, ...] = ()
    if monitor is not None:
        # monitor counts are 1-based record() calls; one call per retired
        # batch in batch order, on top of whatever the monitor saw before
        stragglers = tuple(
            c - 1 - m_count0 for c in monitor.flags[m_flags0:]
        )

    checksums: Dict[str, float] = {q: 0.0 for q in out_names}
    outputs: Optional[Dict[str, np.ndarray]] = None
    if collect_outputs:
        outputs = {
            q: np.concatenate([np.asarray(b[q]) for b in per_batch])
            for q in out_names
        }
        for q in out_names:
            checksums[q] = float(np.sum(outputs[q], dtype=np.float64))
    else:
        for b in per_batch:
            for q, v in b.items():
                checksums[q] += float(v)
    return ChainResult(
        batches=n, elements=n * E, wall_s=wall, checksums=checksums,
        plan=plan, outputs=outputs, pipelined_stages=bool(pipeline_stages),
        placement_groups=(
            tuple(tuple(sp.devices) for sp in place.stages)
            if groups is not None else None
        ),
        straggler_batches=stragglers,
    )
