"""CFD application substrate: the paper's three operators (Inverse
Helmholtz, Interpolation, Gradient), numpy oracles, and the element-
batched simulation driver (batching / double-buffering / CU replication
as mesh sharding)."""
from . import operators, reference, simulation

__all__ = ["operators", "reference", "simulation"]
