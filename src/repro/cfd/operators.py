"""The paper's three CFD operators, built through the DSL-to-executable
flow (core.api), with selectable backend/precision -- the per-kernel
equivalent of the Olympus "Optimize" step -- plus the composed
interpolation -> gradient -> inverse-Helmholtz ProgramChain the chain
planner (repro.memory.chain) sizes as one application.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

from ..core import api, dsl
from ..core.emit import CompiledProgram
from ..core.precision import POLICIES
from ..kernels.helmholtz import ops as helmholtz_ops
from ..memory.chain import ChainPlan, ProgramChain
from ..memory.plan import MemoryPlan


def pallas_block_elements(
    p: int,
    plan: Optional[MemoryPlan] = None,
    *,
    vmem_bytes: Optional[int] = None,
    bytes_per_scalar: int = 4,
) -> int:
    """Resolve the Pallas kernel's block size from a MemoryPlan.

    The plan already carries the VMEM-budgeted block (``block_elements``,
    a divisor of its E); without one, the block is derived directly from
    the given VMEM capacity, and with neither the kernel default stands.
    """
    if plan is not None and plan.block_elements:
        return plan.block_elements
    if vmem_bytes is not None:
        return helmholtz_ops.block_elements_for_vmem(
            p, vmem_bytes, bytes_per_scalar=bytes_per_scalar
        )
    return helmholtz_ops.DEFAULT_BLOCK_ELEMENTS


def build_inverse_helmholtz(
    p: int = 11,
    *,
    policy="float32",
    backend: str = "xla",
    optimize: bool = True,
    max_groups: Optional[int] = None,
    block_elements: Optional[int] = None,
    plan: Optional[MemoryPlan] = None,
    donate_args: Sequence[str] = (),
) -> CompiledProgram:
    """Compile the Inverse Helmholtz operator (paper Fig. 2).

    backend:
      * ``xla``    -- factorized einsum chain, one jitted program.
      * ``staged`` -- one jitted stage per scheduled group (dataflow view).
      * ``pallas`` -- the fused TPU kernel (kernels/helmholtz); on CPU use
        kernel tests' interpret mode instead.  Its ``block_elements``
        defaults to the plan's VMEM-budgeted block when a MemoryPlan is
        given (explicit ``block_elements`` still wins).
    """
    pallas_impl = None
    if backend == "pallas":
        be = (
            block_elements if block_elements is not None
            else pallas_block_elements(p, plan)
        )
        pallas_impl = helmholtz_ops.make_pallas_impl(block_elements=be)
    return api.compile_cfdlang(
        dsl.INVERSE_HELMHOLTZ_SRC.format(p=p),
        element_vars=("u", "D", "v"),
        policy=policy,
        optimize=optimize,
        backend=backend,
        max_groups=max_groups,
        pallas_impl=pallas_impl,
        donate_args=donate_args,
    )


def build_interpolation(
    n: int = 11,
    m: int = 11,
    *,
    policy="float32",
    backend: str = "xla",
    optimize: bool = True,
    max_groups: Optional[int] = None,
) -> CompiledProgram:
    return api.compile_cfdlang(
        dsl.INTERPOLATION_SRC.format(n=n, m=m),
        element_vars=("u", "v"),
        policy=policy,
        optimize=optimize,
        backend=backend,
        max_groups=max_groups,
    )


def build_gradient(
    nx: int = 8,
    ny: int = 7,
    nz: int = 6,
    *,
    policy="float32",
    backend: str = "xla",
    optimize: bool = True,
    max_groups: Optional[int] = None,
) -> CompiledProgram:
    return api.compile_cfdlang(
        dsl.GRADIENT_SRC.format(nx=nx, ny=ny, nz=nz),
        element_vars=("u", "gx", "gy", "gz"),
        policy=policy,
        optimize=optimize,
        backend=backend,
        max_groups=max_groups,
    )


def chain_stage_block_elements(
    chain_plan: Optional[ChainPlan], stage: str
) -> Optional[int]:
    """The VMEM-budgeted block a ChainPlan assigned to one stage (None
    when no plan, or the plan does not know the stage)."""
    if chain_plan is None:
        return None
    for sp in chain_plan.stages:
        if sp.name == stage and sp.block_elements:
            return sp.block_elements
    return None


def build_cfd_chain(
    p: int = 11,
    *,
    policy="float32",
    backends: Union[str, Tuple[str, str, str]] = "xla",
    helmholtz_plan: Optional[MemoryPlan] = None,
    chain_plan: Optional[ChainPlan] = None,
) -> ProgramChain:
    """The paper's full application as one ProgramChain:

        interpolation -> gradient -> inverse Helmholtz

    All stages share the element extent ``p`` so the streams line up:
    interpolation's ``v`` feeds the gradient's ``u``, and the gradient's
    ``gx`` feeds the Helmholtz ``u`` (``gy``/``gz`` stream back to the
    host alongside the Helmholtz ``v``).  The chain planner keeps both
    bound streams resident in HBM -- no host round-trip between stages.

    For a Pallas Helmholtz stage, pass the ChainPlan back in as
    ``chain_plan`` so the kernel's block size comes from the plan's
    per-stage VMEM budget (plan first against a plan-only chain, then
    rebuild the executable chain with the plan):

        ch = build_cfd_chain(p)                       # plan-only (xla)
        plan = chain.plan_chain(ch, backends=("xla", "xla", "pallas"))
        ch = build_cfd_chain(p, backends=("xla", "xla", "pallas"),
                             chain_plan=plan)
        simulation.run_chain(ch, plan)
    """
    if isinstance(backends, str):
        backends = (backends, backends, backends)
    interp = build_interpolation(n=p, m=p, policy=policy, backend=backends[0])
    grad = build_gradient(nx=p, ny=p, nz=p, policy=policy, backend=backends[1])
    helm = build_inverse_helmholtz(
        p, policy=policy, backend=backends[2], plan=helmholtz_plan,
        block_elements=chain_stage_block_elements(chain_plan, "helmholtz"),
    )
    return ProgramChain([
        ("interp", interp),
        ("grad", grad, {"u": "interp.v"}),
        ("helmholtz", helm, {"u": "grad.gx"}),
    ])


def flops_per_element(p: int) -> int:
    """Paper Eq. (2)."""
    return (12 * p + 1) * p ** 3
