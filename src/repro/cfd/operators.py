"""The paper's three CFD operators, built through the DSL-to-executable
flow (core.api), with selectable backend/precision -- the per-kernel
equivalent of the Olympus "Optimize" step.

The composed application (interpolation -> gradient -> inverse
Helmholtz) is no longer hand-wired here: :data:`CFD_PIPELINE_SRC` is the
whole pipeline as one CFDlang program, and :func:`build_cfd_chain`
compiles it through ``repro.flow`` -- the generic tool flow derives the
stage programs, the inter-stage residency, and (for ``pallas`` stages)
the kernel dispatch that ~180 lines of builder code used to encode.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

from .. import flow
from ..core import api, dsl
from ..core.emit import CompiledProgram
from ..core.precision import POLICIES
from ..kernels.helmholtz import ops as helmholtz_ops
from ..memory.chain import ChainPlan, ProgramChain
from ..memory.plan import MemoryPlan


def pallas_block_elements(
    p: int,
    plan: Optional[MemoryPlan] = None,
    *,
    vmem_bytes: Optional[int] = None,
    bytes_per_scalar: int = 4,
) -> int:
    """Resolve the Pallas kernel's block size from a MemoryPlan.

    The plan already carries the VMEM-budgeted block (``block_elements``,
    a divisor of its E); without one, the block is derived directly from
    the given VMEM capacity, and with neither the kernel default stands.
    """
    if plan is not None and plan.block_elements:
        return plan.block_elements
    if vmem_bytes is not None:
        return helmholtz_ops.block_elements_for_vmem(
            p, vmem_bytes, bytes_per_scalar=bytes_per_scalar
        )
    return helmholtz_ops.DEFAULT_BLOCK_ELEMENTS


def build_inverse_helmholtz(
    p: int = 11,
    *,
    policy="float32",
    backend: str = "xla",
    optimize: bool = True,
    max_groups: Optional[int] = None,
    block_elements: Optional[int] = None,
    plan: Optional[MemoryPlan] = None,
    donate_args: Sequence[str] = (),
) -> CompiledProgram:
    """Compile the Inverse Helmholtz operator (paper Fig. 2).

    backend:
      * ``xla``    -- factorized einsum chain, one jitted program.
      * ``staged`` -- one jitted stage per scheduled group (dataflow view).
      * ``pallas`` -- the fused TPU kernel (kernels/helmholtz); on CPU use
        kernel tests' interpret mode instead.  Its ``block_elements``
        defaults to the plan's VMEM-budgeted block when a MemoryPlan is
        given (explicit ``block_elements`` still wins).
    """
    pallas_impl = None
    if backend == "pallas":
        be = (
            block_elements if block_elements is not None
            else pallas_block_elements(p, plan)
        )
        pallas_impl = helmholtz_ops.make_pallas_impl(block_elements=be)
    return api.compile_cfdlang(
        dsl.INVERSE_HELMHOLTZ_SRC.format(p=p),
        element_vars=("u", "D", "v"),
        policy=policy,
        optimize=optimize,
        backend=backend,
        max_groups=max_groups,
        pallas_impl=pallas_impl,
        donate_args=donate_args,
    )


def build_interpolation(
    n: int = 11,
    m: int = 11,
    *,
    policy="float32",
    backend: str = "xla",
    optimize: bool = True,
    max_groups: Optional[int] = None,
) -> CompiledProgram:
    return api.compile_cfdlang(
        dsl.INTERPOLATION_SRC.format(n=n, m=m),
        element_vars=("u", "v"),
        policy=policy,
        optimize=optimize,
        backend=backend,
        max_groups=max_groups,
    )


def build_gradient(
    nx: int = 8,
    ny: int = 7,
    nz: int = 6,
    *,
    policy="float32",
    backend: str = "xla",
    optimize: bool = True,
    max_groups: Optional[int] = None,
) -> CompiledProgram:
    return api.compile_cfdlang(
        dsl.GRADIENT_SRC.format(nx=nx, ny=ny, nz=nz),
        element_vars=("u", "gx", "gy", "gz"),
        policy=policy,
        optimize=optimize,
        backend=backend,
        max_groups=max_groups,
    )


def chain_stage_block_elements(
    chain_plan: Optional[ChainPlan], stage: str
) -> Optional[int]:
    """The VMEM-budgeted block a ChainPlan assigned to one stage (None
    when no plan, or the plan does not know the stage)."""
    if chain_plan is None:
        return None
    for sp in chain_plan.stages:
        if sp.name == stage and sp.block_elements:
            return sp.block_elements
    return None


#: The paper's full application as ONE CFDlang program: interpolation
#: (A), gradient (Dx/Dy/Dz), and inverse Helmholtz (S, D) over a shared
#: element stream.  ``repro.flow`` cuts it into the three pipeline
#: stages at the declared temporaries -- no builder code per operator.
CFD_PIPELINE_SRC = """
var input  A  : [{p} {p}]
var input  Dx : [{p} {p}]
var input  Dy : [{p} {p}]
var input  Dz : [{p} {p}]
var input  S  : [{p} {p}]
var input elem u  : [{p} {p} {p}]
var input elem D  : [{p} {p} {p}]
var output elem gy : [{p} {p} {p}]
var output elem gz : [{p} {p} {p}]
var output elem v  : [{p} {p} {p}]
var w  : [{p} {p} {p}]
var gx : [{p} {p} {p}]
var t  : [{p} {p} {p}]
var r  : [{p} {p} {p}]
w = A # A # A # u . [[1 6][3 7][5 8]]
gx = Dx # w . [[1 2]]
gy = Dy # w . [[1 3]]
gz = Dz # w . [[1 4]]
t = S # S # S # gx . [[1 6][3 7][5 8]]
r = D * t
v = S # S # S # r . [[0 6][2 7][4 8]]
"""

#: The canonical stage cuts: interpolation owns ``w``, the gradient its
#: three derivatives, the Helmholtz stage the final solve.
CFD_PIPELINE_STAGES = (
    ("interp", ("w",)),
    ("grad", ("gx", "gy", "gz")),
    ("helmholtz", ("v",)),
)


def compile_cfd_pipeline(
    p: int = 11,
    *,
    policy="float32",
    backends: Union[str, Tuple[str, str, str]] = "xla",
    stage_blocks=None,
    **flow_kwargs,
) -> "flow.CompiledSystem":
    """Compile the whole CFD application through ``repro.flow`` at the
    paper's operator-granularity stage cuts."""
    if isinstance(backends, str):
        backends = (backends, backends, backends)
    return flow.compile(
        CFD_PIPELINE_SRC.format(p=p),
        name=f"cfd_pipeline_p{p}",
        policy=policy,
        stages=CFD_PIPELINE_STAGES,
        backends=backends,
        stage_blocks=stage_blocks,
        **flow_kwargs,
    )


def build_cfd_chain(
    p: int = 11,
    *,
    policy="float32",
    backends: Union[str, Tuple[str, str, str]] = "xla",
    helmholtz_plan: Optional[MemoryPlan] = None,
    chain_plan: Optional[ChainPlan] = None,
) -> ProgramChain:
    """The paper's full application as one ProgramChain:

        interpolation -> gradient -> inverse Helmholtz

    Compiled end-to-end from :data:`CFD_PIPELINE_SRC` by ``repro.flow``:
    the flow extracts the three stage programs, wires interpolation's
    ``w`` into the gradient and the gradient's ``gx`` into the Helmholtz
    solve (both HBM-resident -- no host round-trip), and streams
    ``gy``/``gz``/``v`` back to the host.

    For a Pallas Helmholtz stage, pass the ChainPlan back in as
    ``chain_plan`` so the kernel's block size comes from the plan's
    per-stage VMEM budget (plan first against a plan-only chain, then
    rebuild the executable chain with the plan):

        ch = build_cfd_chain(p)                       # plan-only (xla)
        plan = chain.plan_chain(ch, backends=("xla", "xla", "pallas"))
        ch = build_cfd_chain(p, backends=("xla", "xla", "pallas"),
                             chain_plan=plan)
        simulation.run_chain(ch, plan)
    """
    blocks = {}
    blk = chain_stage_block_elements(chain_plan, "helmholtz")
    if blk is None and helmholtz_plan is not None and (
            helmholtz_plan.block_elements):
        blk = helmholtz_plan.block_elements
    if blk:
        blocks["helmholtz"] = blk
    return compile_cfd_pipeline(
        p, policy=policy, backends=backends, stage_blocks=blocks
    ).chain


def flops_per_element(p: int) -> int:
    """Paper Eq. (2)."""
    return (12 * p + 1) * p ** 3
