"""The paper's three CFD operators, built through the DSL-to-executable
flow (core.api), with selectable backend/precision -- the per-kernel
equivalent of the Olympus "Optimize" step.
"""
from __future__ import annotations

from typing import Optional, Sequence

from ..core import api, dsl
from ..core.emit import CompiledProgram
from ..core.precision import POLICIES
from ..kernels.helmholtz import ops as helmholtz_ops


def build_inverse_helmholtz(
    p: int = 11,
    *,
    policy="float32",
    backend: str = "xla",
    optimize: bool = True,
    max_groups: Optional[int] = None,
    block_elements: int = 128,
    donate_args: Sequence[str] = (),
) -> CompiledProgram:
    """Compile the Inverse Helmholtz operator (paper Fig. 2).

    backend:
      * ``xla``    -- factorized einsum chain, one jitted program.
      * ``staged`` -- one jitted stage per scheduled group (dataflow view).
      * ``pallas`` -- the fused TPU kernel (kernels/helmholtz); on CPU use
        kernel tests' interpret mode instead.
    """
    pallas_impl = None
    if backend == "pallas":
        pallas_impl = helmholtz_ops.make_pallas_impl(
            block_elements=block_elements
        )
    return api.compile_cfdlang(
        dsl.INVERSE_HELMHOLTZ_SRC.format(p=p),
        element_vars=("u", "D", "v"),
        policy=policy,
        optimize=optimize,
        backend=backend,
        max_groups=max_groups,
        pallas_impl=pallas_impl,
        donate_args=donate_args,
    )


def build_interpolation(
    n: int = 11,
    m: int = 11,
    *,
    policy="float32",
    backend: str = "xla",
    optimize: bool = True,
    max_groups: Optional[int] = None,
) -> CompiledProgram:
    return api.compile_cfdlang(
        dsl.INTERPOLATION_SRC.format(n=n, m=m),
        element_vars=("u", "v"),
        policy=policy,
        optimize=optimize,
        backend=backend,
        max_groups=max_groups,
    )


def build_gradient(
    nx: int = 8,
    ny: int = 7,
    nz: int = 6,
    *,
    policy="float32",
    backend: str = "xla",
    optimize: bool = True,
    max_groups: Optional[int] = None,
) -> CompiledProgram:
    return api.compile_cfdlang(
        dsl.GRADIENT_SRC.format(nx=nx, ny=ny, nz=nz),
        element_vars=("u", "gx", "gy", "gz"),
        policy=policy,
        optimize=optimize,
        backend=backend,
        max_groups=max_groups,
    )


def flops_per_element(p: int) -> int:
    """Paper Eq. (2)."""
    return (12 * p + 1) * p ** 3
