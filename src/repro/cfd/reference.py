"""Pure-numpy oracles for the paper's operators (ground truth for tests).

These implement equations (1a)-(1c) of the paper literally, in float64.
"""
from __future__ import annotations

import numpy as np


def inverse_helmholtz(S: np.ndarray, D: np.ndarray, u: np.ndarray) -> np.ndarray:
    """Eq. (1a)-(1c): t = (Sᵀ⊗Sᵀ⊗Sᵀ)u, r = D∘t, v = (S⊗S⊗S)r.

    Note Sᵀ_li = S_il, so (1a) is t_ijk = Σ S_il S_jm S_kn u_lmn and
    (1c) is v_ijk = Σ S_li S_mj S_nk r_lmn -- matching the CFDlang
    contraction pairs [[1 6][3 7][5 8]] and [[0 6][2 7][4 8]].
    """
    t = np.einsum("il,jm,kn,lmn->ijk", S, S, S, u)
    r = D * t
    v = np.einsum("li,mj,nk,lmn->ijk", S, S, S, r)
    return v


def inverse_helmholtz_batch(S, D, u):
    t = np.einsum("il,jm,kn,elmn->eijk", S, S, S, u)
    r = D * t
    v = np.einsum("li,mj,nk,elmn->eijk", S, S, S, r)
    return v


def interpolation(A: np.ndarray, u: np.ndarray) -> np.ndarray:
    """u' (M,M,M) = (A ⊗ A ⊗ A) u with A in R^{M x N}."""
    return np.einsum("il,jm,kn,lmn->ijk", A, A, A, u)


def interpolation_batch(A, u):
    return np.einsum("il,jm,kn,elmn->eijk", A, A, A, u)


def gradient(Dx, Dy, Dz, u):
    """∇u in the CFDlang layout convention (see dsl.GRADIENT_SRC):
    gx: (nx,ny,nz), gy: (ny,nx,nz), gz: (nz,nx,ny)."""
    gx = np.einsum("xl,lyz->xyz", Dx, u)
    gy = np.einsum("ym,xmz->yxz", Dy, u)
    gz = np.einsum("zn,xyn->zxy", Dz, u)
    return gx, gy, gz


def paper_flops_per_element(p: int) -> int:
    """Paper Eq. (2): N_op_el = (12p + 1) * p^3."""
    return (12 * p + 1) * p ** 3
