"""State-space / recurrent blocks: Mamba (S6, for Jamba) and xLSTM
(sLSTM + mLSTM).

Recurrences are data-dependent over time, outside the tensor-expression
(teil) semantics, so these are native JAX with `lax.scan` (compact HLO --
important for the 512-device dry-run).  Decode is O(1): the "cache" is
the fixed-size recurrent state, which is what makes the `long_500k` shape
runnable for these families (DESIGN.md shape-skip notes).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from . import layers

Params = Dict[str, Any]


# =============================================================================
# Mamba (S6) -- used by the Jamba hybrid
# =============================================================================

def mamba_init(key, cfg: ModelConfig, dtype) -> Params:
    m = cfg.mamba
    d = cfg.d_model
    d_in = m.expand * d
    dtr = m.dt_rank or -(-d // 16)
    ks = jax.random.split(key, 7)
    s = 1.0 / math.sqrt(d)
    p = {
        "in_proj": layers.dense_init(ks[0], d, 2 * d_in, dtype),
        "conv_w": (jax.random.normal(ks[1], (m.d_conv, d_in), jnp.float32)
                   * (1.0 / math.sqrt(m.d_conv))).astype(dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        "x_proj": layers.dense_init(ks[2], d_in, dtr + 2 * m.d_state, dtype),
        "dt_proj": layers.dense_init(ks[3], dtr, d_in, dtype, bias=True),
        "A_log": jnp.log(
            jnp.broadcast_to(
                jnp.arange(1, m.d_state + 1, dtype=jnp.float32)[None, :],
                (d_in, m.d_state),
            )
        ).astype(jnp.float32),
        "D": jnp.ones((d_in,), jnp.float32),
        "out_proj": layers.dense_init(ks[4], d_in, d, dtype,
                                      scale=1.0 / math.sqrt(d_in * 2 * cfg.n_layers)),
    }
    return p


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None):
    """Depthwise causal conv1d.  x: (B, T, C), w: (K, C).

    Returns (y, new_state) where state is the last K-1 inputs."""
    B, T, C = x.shape
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((B, K - 1, C), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)         # (B, T+K-1, C)
    y = jnp.zeros((B, T, C), jnp.float32)
    for i in range(K):
        y = y + xp[:, i:i + T, :].astype(jnp.float32) * w[i].astype(jnp.float32)
    y = y + b.astype(jnp.float32)
    new_state = xp[:, T:, :] if K > 1 else jnp.zeros((B, 0, C), x.dtype)
    return y.astype(x.dtype), new_state


def mamba_apply(
    p: Params,
    x: jax.Array,                     # (B, T, d)
    cfg: ModelConfig,
    *,
    state: Optional[Dict[str, jax.Array]] = None,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    m = cfg.mamba
    B, T, d = x.shape
    d_in = m.expand * d
    dtr = m.dt_rank or -(-d // 16)
    cd = jnp.dtype(cfg.compute_dtype)

    xz = layers.dense_apply(p["in_proj"], x, cd)
    xs, z = jnp.split(xz, 2, axis=-1)              # (B, T, d_in) each

    conv_state = state["conv"] if state is not None else None
    xs, new_conv = _causal_conv(xs, p["conv_w"], p["conv_b"], conv_state)
    xs = jax.nn.silu(xs.astype(jnp.float32)).astype(cd)

    dbc = layers.dense_apply(p["x_proj"], xs, cd)
    dt, Bc, Cc = jnp.split(dbc, [dtr, dtr + m.d_state], axis=-1)
    dt = layers.dense_apply(p["dt_proj"], dt, cd)  # (B, T, d_in)
    dt = jax.nn.softplus(dt.astype(jnp.float32))   # (B, T, d_in)
    A = -jnp.exp(p["A_log"])                        # (d_in, S)

    h0 = (state["ssm"] if state is not None
          else jnp.zeros((B, d_in, m.d_state), jnp.float32))

    # selective scan: h_t = exp(dt*A) h_{t-1} + dt * B_t * x_t.
    # dA/dBx are formed PER STEP inside the scan (never materializing the
    # (B, T, d_in, S) tensor -- at jamba's train_4k shape that would be
    # ~1 TB global), and y_t = C_t . h_t is contracted inside the step so
    # only (B, T, d_in) activations cross the scan boundary.
    def step(h, inputs):
        dt_t, b_t, c_t, x_t = inputs      # (B,d_in),(B,S),(B,S),(B,d_in)
        dA_t = jnp.exp(dt_t[..., None] * A[None])            # (B,d_in,S)
        dBx_t = (dt_t * x_t)[..., None] * b_t[:, None, :]
        h = dA_t * h + dBx_t
        y_t = jnp.einsum("bds,bs->bd", h, c_t)
        return h, y_t

    xs_f32 = xs.astype(jnp.float32)
    hT, ys = jax.lax.scan(
        step, h0,
        (
            jnp.moveaxis(dt, 1, 0),
            jnp.moveaxis(Bc.astype(jnp.float32), 1, 0),
            jnp.moveaxis(Cc.astype(jnp.float32), 1, 0),
            jnp.moveaxis(xs_f32, 1, 0),
        ),
    )                                                # ys: (T, B, d_in)
    y = jnp.moveaxis(ys, 0, 1)
    y = y + p["D"].astype(jnp.float32) * xs_f32
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = layers.dense_apply(p["out_proj"], y.astype(cd), cd)
    new_state = {"conv": new_conv, "ssm": hT} if state is not None else None
    return out.astype(x.dtype), new_state


def mamba_init_state(cfg: ModelConfig, batch: int) -> Dict[str, jax.Array]:
    m = cfg.mamba
    d_in = m.expand * cfg.d_model
    return {
        "conv": jnp.zeros((batch, m.d_conv - 1, d_in), jnp.dtype(cfg.compute_dtype)),
        "ssm": jnp.zeros((batch, d_in, m.d_state), jnp.float32),
    }


# =============================================================================
# xLSTM: mLSTM (matrix memory) + sLSTM (scalar memory)
# =============================================================================

#: Chunkwise-parallel mLSTM switch (None = exact recurrent scan).  Set by
#: the dry-run/launchers for the optimized path: the matrix memory C is
#: then read/written once per chunk instead of once per step, cutting
#: state HBM traffic by the chunk width (the dominant memory-roofline
#: term for xlstm-125m train_4k -- see EXPERIMENTS.md section Perf).
MLSTM_CHUNK = None


def mlstm_init(key, cfg: ModelConfig, dtype) -> Params:
    d, hd, H = cfg.d_model, cfg.hd, cfg.n_heads
    ks = jax.random.split(key, 6)
    return {
        "wq": layers.dense_init(ks[0], d, H * hd, dtype),
        "wk": layers.dense_init(ks[1], d, H * hd, dtype),
        "wv": layers.dense_init(ks[2], d, H * hd, dtype),
        "wi": layers.dense_init(ks[3], d, H, dtype, bias=True),
        "wf": layers.dense_init(ks[4], d, H, dtype, bias=True),
        "wo": layers.dense_init(ks[5], H * hd, d, dtype,
                                scale=1.0 / math.sqrt(H * hd * 2 * cfg.n_layers)),
    }


def mlstm_apply(
    p: Params,
    x: jax.Array,                 # (B, T, d)
    cfg: ModelConfig,
    *,
    state: Optional[Dict[str, jax.Array]] = None,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    B, T, d = x.shape
    hd, H = cfg.hd, cfg.n_heads
    cd = jnp.dtype(cfg.compute_dtype)
    q = layers.dense_apply(p["wq"], x, cd).reshape(B, T, H, hd)
    k = layers.dense_apply(p["wk"], x, cd).reshape(B, T, H, hd) / math.sqrt(hd)
    v = layers.dense_apply(p["wv"], x, cd).reshape(B, T, H, hd)
    i_pre = layers.dense_apply(p["wi"], x, jnp.float32)  # (B, T, H)
    f_pre = layers.dense_apply(p["wf"], x, jnp.float32)

    if state is None:
        C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        n0 = jnp.zeros((B, H, hd), jnp.float32)
        m0 = jnp.zeros((B, H), jnp.float32)
    else:
        C0, n0, m0 = state["C"], state["n"], state["m"]

    def step(carry, inputs):
        C, n, m = carry
        qt, kt, vt, it, ft = inputs  # (B,H,hd)x3, (B,H)x2
        m_new = jnp.maximum(ft + m, it)               # stabilizer
        i_g = jnp.exp(it - m_new)
        f_g = jnp.exp(ft + m - m_new)
        C = f_g[..., None, None] * C + i_g[..., None, None] * (
            kt[..., :, None] * vt[..., None, :]
        )
        n = f_g[..., None] * n + i_g[..., None] * kt
        num = jnp.einsum("bhkv,bhk->bhv", C, qt.astype(jnp.float32))
        den = jnp.abs(jnp.einsum("bhk,bhk->bh", n, qt.astype(jnp.float32)))
        h = num / jnp.maximum(den, 1.0)[..., None]
        return (C, n, m_new), h

    # reorder to (T, B, H, hd)
    qs = jnp.moveaxis(q.astype(jnp.float32), 1, 0)
    ks_ = jnp.moveaxis(k.astype(jnp.float32), 1, 0)
    vs = jnp.moveaxis(v.astype(jnp.float32), 1, 0)
    is_ = jnp.moveaxis(i_pre, 1, 0)
    fs = jnp.moveaxis(jax.nn.log_sigmoid(f_pre), 1, 0)

    (CT, nT, mT), hs = jax.lax.scan(step, (C0, n0, m0), (qs, ks_, vs, is_, fs))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, T, H * hd)   # (B, T, H*hd)
    out = layers.dense_apply(p["wo"], h.astype(cd), cd)
    new_state = ({"C": CT, "n": nT, "m": mT} if state is not None else None)
    return out.astype(x.dtype), new_state


def _mlstm_chunk_body(q, k, v, i_pre, f_log, C, n, m, *, W: int):
    """One chunk of the chunkwise-parallel stabilized mLSTM.

    q/k/v: (B, H, W, hd) f32; i_pre/f_log: (B, H, W); carry (C, n, m).
    Exactly equivalent to W recurrent steps (same stabilizer convention:
    the carried C/n are scaled by exp(-m)).
    """
    F = jnp.cumsum(f_log, axis=-1)                      # (B,H,W)
    a = i_pre - F
    M = jnp.maximum(
        m[..., None], jax.lax.cummax(a, axis=a.ndim - 1)
    )                                                    # (B,H,W)
    # intra-chunk scores with per-(t,s) decay, causal within the chunk
    S = jnp.einsum("bhtd,bhsd->bhts", q, k,
                   preferred_element_type=jnp.float32)
    decay = jnp.exp(a[..., None, :] - M[..., :, None])   # (B,H,t,s)
    tri = jnp.tril(jnp.ones((W, W), bool))
    St = jnp.where(tri[None, None], S * decay, 0.0)
    num = jnp.einsum("bhts,bhsv->bhtv", St, v,
                     preferred_element_type=jnp.float32)
    den = jnp.sum(St, axis=-1)                           # (B,H,t)
    # inter-chunk (previous state) contribution
    inter_w = jnp.exp(m[..., None] - M)                  # (B,H,t)
    num = num + inter_w[..., None] * jnp.einsum(
        "bhkv,bhtk->bhtv", C, q, preferred_element_type=jnp.float32
    )
    den = den + inter_w * jnp.einsum(
        "bhk,bhtk->bht", n, q, preferred_element_type=jnp.float32
    )
    h = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]
    # end-of-chunk state update (C/n touched ONCE per chunk)
    M_W = M[..., -1]
    F_W = F[..., -1]
    w_s = jnp.exp(a - M_W[..., None])                    # (B,H,s)
    carry_w = jnp.exp(m - M_W)
    C_new = jnp.einsum("bhs,bhsk,bhsv->bhkv", w_s, k, v,
                       preferred_element_type=jnp.float32) \
        + carry_w[..., None, None] * C
    n_new = jnp.einsum("bhs,bhsk->bhk", w_s, k,
                       preferred_element_type=jnp.float32) \
        + carry_w[..., None] * n
    m_new = F_W + M_W
    return h, (C_new, n_new, m_new)


def mlstm_apply_chunked(
    p: Params,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    chunk: int,
    state: Optional[Dict[str, jax.Array]] = None,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    B, T, d = x.shape
    hd, H = cfg.hd, cfg.n_heads
    cd = jnp.dtype(cfg.compute_dtype)
    W = chunk
    if T % W:
        return mlstm_apply(p, x, cfg, state=state)  # ragged: fall back
    q = layers.dense_apply(p["wq"], x, cd).reshape(B, T, H, hd)
    k = layers.dense_apply(p["wk"], x, cd).reshape(B, T, H, hd) / math.sqrt(hd)
    v = layers.dense_apply(p["wv"], x, cd).reshape(B, T, H, hd)
    i_pre = layers.dense_apply(p["wi"], x, jnp.float32)
    f_log = jax.nn.log_sigmoid(layers.dense_apply(p["wf"], x, jnp.float32))

    def to_chunks(t):  # (B,T,H,*) -> (n, B, H, W, *)
        t = jnp.moveaxis(t, 2, 1)                        # (B,H,T,*)
        t = t.reshape(t.shape[:2] + (T // W, W) + t.shape[3:])
        return jnp.moveaxis(t, 2, 0)

    qs = to_chunks(q.astype(jnp.float32))
    ks_ = to_chunks(k.astype(jnp.float32))
    vs = to_chunks(v.astype(jnp.float32))
    # gates: (B,T,H) -> (n_chunks, B, H, W)
    ii = jnp.moveaxis(i_pre, 1, 2).reshape(B, H, T // W, W)
    ii = jnp.moveaxis(ii, 2, 0)
    ff = jnp.moveaxis(f_log, 1, 2).reshape(B, H, T // W, W)
    ff = jnp.moveaxis(ff, 2, 0)

    if state is None:
        C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        n0 = jnp.zeros((B, H, hd), jnp.float32)
        m0 = jnp.zeros((B, H), jnp.float32)
    else:
        C0, n0, m0 = state["C"], state["n"], state["m"]

    def step(carry, inp):
        C, n, m = carry
        qc, kc, vc, ic, fc = inp
        h, (C, n, m) = _mlstm_chunk_body(
            qc, kc, vc, ic, fc, C, n, m, W=W
        )
        return (C, n, m), h

    (CT, nT, mT), hs = jax.lax.scan(step, (C0, n0, m0), (qs, ks_, vs, ii, ff))
    # hs: (n, B, H, W, hd) -> (B, T, H*hd)
    h = jnp.moveaxis(hs, 0, 2).reshape(B, H, T, hd)
    h = jnp.moveaxis(h, 1, 2).reshape(B, T, H * hd)
    out = layers.dense_apply(p["wo"], h.astype(cd), cd)
    new_state = ({"C": CT, "n": nT, "m": mT} if state is not None else None)
    return out.astype(x.dtype), new_state


def slstm_init(key, cfg: ModelConfig, dtype) -> Params:
    d, hd, H = cfg.d_model, cfg.hd, cfg.n_heads
    ks = jax.random.split(key, 6)
    return {
        "wz": layers.dense_init(ks[0], d, H * hd, dtype, bias=True),
        "wi": layers.dense_init(ks[1], d, H * hd, dtype, bias=True),
        "wf": layers.dense_init(ks[2], d, H * hd, dtype, bias=True),
        "wo_gate": layers.dense_init(ks[3], d, H * hd, dtype, bias=True),
        "wo": layers.dense_init(ks[4], H * hd, d, dtype,
                                scale=1.0 / math.sqrt(H * hd * 2 * cfg.n_layers)),
    }


def slstm_apply(
    p: Params,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    state: Optional[Dict[str, jax.Array]] = None,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    B, T, d = x.shape
    hd, H = cfg.hd, cfg.n_heads
    D = H * hd
    cd = jnp.dtype(cfg.compute_dtype)
    z = jnp.tanh(layers.dense_apply(p["wz"], x, jnp.float32))
    i_pre = layers.dense_apply(p["wi"], x, jnp.float32)
    f_pre = jax.nn.log_sigmoid(layers.dense_apply(p["wf"], x, jnp.float32))
    o = jax.nn.sigmoid(layers.dense_apply(p["wo_gate"], x, jnp.float32))

    if state is None:
        c0 = jnp.zeros((B, D), jnp.float32)
        n0 = jnp.zeros((B, D), jnp.float32)
        m0 = jnp.full((B, D), -1e30, jnp.float32)
    else:
        c0, n0, m0 = state["c"], state["n"], state["m"]

    def step(carry, inputs):
        c, n, m = carry
        zt, it, ft = inputs
        m_new = jnp.maximum(ft + m, it)
        i_g = jnp.exp(it - m_new)
        f_g = jnp.exp(ft + m - m_new)
        c = f_g * c + i_g * zt
        n = f_g * n + i_g
        h = c / jnp.maximum(n, 1.0)
        return (c, n, m_new), h

    zs = jnp.moveaxis(z, 1, 0)
    is_ = jnp.moveaxis(i_pre, 1, 0)
    fs = jnp.moveaxis(f_pre, 1, 0)
    (cT, nT, mT), hs = jax.lax.scan(step, (c0, n0, m0), (zs, is_, fs))
    h = jnp.moveaxis(hs, 0, 1) * o                   # (B, T, D)
    out = layers.dense_apply(p["wo"], h.astype(cd), cd)
    new_state = ({"c": cT, "n": nT, "m": mT} if state is not None else None)
    return out.astype(x.dtype), new_state


def xlstm_block_kind(layer_idx: int, cfg: ModelConfig) -> str:
    every = cfg.xlstm.slstm_every
    return "slstm" if (every > 0 and layer_idx % every == 0) else "mlstm"


def xlstm_init_state(cfg: ModelConfig, batch: int, kind: str):
    hd, H = cfg.hd, cfg.n_heads
    if kind == "mlstm":
        return {
            "C": jnp.zeros((batch, H, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, H, hd), jnp.float32),
            "m": jnp.zeros((batch, H), jnp.float32),
        }
    return {
        "c": jnp.zeros((batch, H * hd), jnp.float32),
        "n": jnp.zeros((batch, H * hd), jnp.float32),
        "m": jnp.full((batch, H * hd), -1e30, jnp.float32),
    }
