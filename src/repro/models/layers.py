"""Shared neural-net layers (pure functional JAX, dict params).

Conventions:
  * params are nested dicts of jnp arrays; leaves use cfg.param_dtype.
  * activations use cfg.compute_dtype with f32 accumulation on matmuls
    (the precision-policy split from core.precision applied to LM archs).
  * every init_* function returns (params); every apply is pure.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels.attention import ops as attn_ops
from .config import ModelConfig

Params = Dict[str, Any]

#: When True, matmul partial sums are produced in the compute dtype so
#: cross-shard (TP) all-reduces move bf16 instead of f32 -- halves the
#: activation-collective bytes at the cost of one extra rounding per
#: 16-way reduction.  Set by the dry-run/launchers (--bf16-reduce).
REDUCE_IN_COMPUTE_DTYPE = False


# -- initializers -----------------------------------------------------------

def _normal(key, shape, dtype, scale):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def dense_init(key, d_in: int, d_out: int, dtype, *, bias: bool = False,
               scale: Optional[float] = None) -> Params:
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": _normal(key, (d_in, d_out), dtype, scale)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense_apply(p: Params, x: jax.Array, compute_dtype) -> jax.Array:
    acc = (
        jnp.dtype(compute_dtype) if REDUCE_IN_COMPUTE_DTYPE
        else jnp.float32
    )
    y = jnp.einsum(
        "...i,io->...o", x.astype(compute_dtype), p["w"].astype(compute_dtype),
        preferred_element_type=acc,
    )
    if "b" in p:
        y = y + p["b"].astype(acc)
    return y.astype(compute_dtype)


# -- norms --------------------------------------------------------------------

def norm_init(d: int, kind: str, dtype) -> Params:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def norm_apply(p: Params, x: jax.Array, kind: str, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# -- rotary embeddings --------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., T, d) with d even; positions: (..., T) int32."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., T, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# -- attention ----------------------------------------------------------------

def attention_init(key, cfg: ModelConfig, dtype) -> Params:
    d, hd, Hq, Hkv = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, Hq * hd, dtype, bias=cfg.qkv_bias),
        "wk": dense_init(ks[1], d, Hkv * hd, dtype, bias=cfg.qkv_bias),
        "wv": dense_init(ks[2], d, Hkv * hd, dtype, bias=cfg.qkv_bias),
        "wo": dense_init(ks[3], Hq * hd, d, dtype,
                         scale=1.0 / math.sqrt(Hq * hd * 2 * cfg.n_layers)),
    }
    if cfg.qk_norm:
        p["q_norm"] = norm_init(hd, "rmsnorm", dtype)
        p["k_norm"] = norm_init(hd, "rmsnorm", dtype)
    return p


def attention_apply(
    p: Params,
    x: jax.Array,                     # (B, T, d)
    cfg: ModelConfig,
    *,
    positions: jax.Array,             # (B, T)
    kv: Optional[Tuple[jax.Array, jax.Array]] = None,   # cross-attn K/V src
    cache: Optional[Dict[str, jax.Array]] = None,       # decode KV cache
    cache_index: Optional[jax.Array] = None,
    causal: bool = True,
    attn_impl: str = "auto",
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    B, T, d = x.shape
    hd, Hq, Hkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    cd = jnp.dtype(cfg.compute_dtype)

    q = dense_apply(p["wq"], x, cd).reshape(B, T, Hq, hd)
    if kv is None:
        k = dense_apply(p["wk"], x, cd).reshape(B, T, Hkv, hd)
        v = dense_apply(p["wv"], x, cd).reshape(B, T, Hkv, hd)
    else:
        src_k, src_v = kv
        Ts = src_k.shape[1]
        k = dense_apply(p["wk"], src_k, cd).reshape(B, Ts, Hkv, hd)
        v = dense_apply(p["wv"], src_v, cd).reshape(B, Ts, Hkv, hd)

    if cfg.qk_norm:
        q = norm_apply(p["q_norm"], q, "rmsnorm", cfg.norm_eps)
        k = norm_apply(p["k_norm"], k, "rmsnorm", cfg.norm_eps)

    if kv is None and cfg.rope_theta > 0:
        q = rope(q.swapaxes(1, 2), positions[:, None], cfg.rope_theta).swapaxes(1, 2)
        kpos = positions
        k = rope(k.swapaxes(1, 2), kpos[:, None], cfg.rope_theta).swapaxes(1, 2)

    new_cache = None
    per_slot = (
        cache_index is not None
        and isinstance(cache_index, jax.Array)
        and cache_index.ndim == 1
    )
    if cache is not None:
        # write the new K/V at cache_index (decode: T == 1; prefill: T == n)
        idx = cache_index if cache_index is not None else 0
        if per_slot:
            # continuous batching: every sequence decodes at its own
            # position (T must be 1)
            bidx = jnp.arange(B)
            ck = cache["k"].at[bidx, idx].set(k[:, 0].astype(cache["k"].dtype))
            cv = cache["v"].at[bidx, idx].set(v[:, 0].astype(cache["v"].dtype))
        else:
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, idx, 0, 0)
            )
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, idx, 0, 0)
            )
        new_cache = {"k": ck, "v": cv}
        k, v = ck, cv
        Tk = k.shape[1]
        # mask out unwritten cache slots via additive bias in xla impl
        if per_slot:
            valid = jnp.arange(Tk)[None, :] <= idx[:, None]  # (B, Tk)
        else:
            valid = jnp.arange(Tk)[None, :] <= (idx + T - 1)
    else:
        Tk = k.shape[1]
        valid = None

    qh = q.swapaxes(1, 2)  # (B, Hq, T, hd)
    kh = k.swapaxes(1, 2)  # (B, Hkv, Tk, hd)
    vh = v.swapaxes(1, 2)

    if cache is not None or kv is not None:
        # decode / cross path: plain XLA attention with validity mask.
        # per-slot decode: the validity mask subsumes causality (query sits
        # at its own cache position).
        o = _masked_attention(qh, kh, vh,
                              causal=causal and kv is None and not per_slot,
                              valid=valid, q_offset=(0 if kv is not None else None),
                              cache_index=cache_index, t=T)
    else:
        o = attn_ops.multi_head_attention(
            qh, kh, vh, causal=causal, impl=attn_impl
        )
    o = o.swapaxes(1, 2).reshape(B, T, Hq * hd)
    out = dense_apply(p["wo"], o, cd)
    return out, new_cache


def _masked_attention(q, k, v, *, causal: bool, valid, q_offset,
                      cache_index, t: int):
    """GQA attention with an explicit validity/causal mask (cache path).

    Sharding note: with the KV cache sharded along its sequence axis the
    reductions below lower to partial reduce + all-reduce, i.e. the
    flash-decoding combine falls out of GSPMD (DESIGN.md section 5).
    """
    B, Hq, T, hd = q.shape
    _, Hkv, Tk, _ = k.shape
    group = Hq // Hkv
    qg = q.reshape(B, Hkv, group, T, hd)
    s = jnp.einsum(
        "bhgqd,bhkd->bhgqk", qg.astype(jnp.float32), k.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ) / math.sqrt(hd)
    mask = None
    if causal:
        qpos = (cache_index if cache_index is not None else 0) + jnp.arange(T)
        kpos = jnp.arange(Tk)
        mask = qpos[:, None] >= kpos[None, :]
    if valid is not None:
        vmask = jnp.broadcast_to(valid[:, None, :], (B, T, Tk))
        mask = vmask if mask is None else (mask[None] & vmask)
    if mask is not None:
        if mask.ndim == 2:
            mask = mask[None, None, None]
        else:  # (B, T, Tk)
            mask = mask[:, None, None]
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum(
        "bhgqk,bhkd->bhgqd", p.astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    )
    return o.reshape(B, Hq, T, hd).astype(q.dtype)


# -- MLP -----------------------------------------------------------------------

def mlp_init(key, cfg: ModelConfig, dtype, d_ff: Optional[int] = None) -> Params:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.act == "swiglu":
        return {
            "gate": dense_init(ks[0], d, ff, dtype, bias=cfg.mlp_bias),
            "up": dense_init(ks[1], d, ff, dtype, bias=cfg.mlp_bias),
            "down": dense_init(ks[2], ff, d, dtype, bias=cfg.mlp_bias,
                               scale=1.0 / math.sqrt(ff * 2 * cfg.n_layers)),
        }
    return {
        "up": dense_init(ks[0], d, ff, dtype, bias=cfg.mlp_bias),
        "down": dense_init(ks[1], ff, d, dtype, bias=cfg.mlp_bias,
                           scale=1.0 / math.sqrt(ff * 2 * cfg.n_layers)),
    }


def mlp_apply(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    cd = jnp.dtype(cfg.compute_dtype)
    if cfg.act == "swiglu":
        g = dense_apply(p["gate"], x, cd)
        u = dense_apply(p["up"], x, cd)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(cd) * u
    else:
        u = dense_apply(p["up"], x, cd)
        h = jax.nn.gelu(u.astype(jnp.float32)).astype(cd)
    return dense_apply(p["down"], h, cd)


# -- embeddings -----------------------------------------------------------------

def embed_init(key, cfg: ModelConfig, dtype) -> Params:
    p = {"tok": _normal(key, (cfg.vocab, cfg.d_model), dtype, 1.0)}
    return p


def embed_apply(p: Params, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    return p["tok"].astype(cfg.compute_dtype)[tokens]


def unembed_apply(p_embed: Params, p_head: Optional[Params], x: jax.Array,
                  cfg: ModelConfig) -> jax.Array:
    cd = jnp.dtype(cfg.compute_dtype)
    w = (p_embed["tok"] if p_head is None else p_head["w"])
    if p_head is None:
        logits = jnp.einsum(
            "...d,vd->...v", x.astype(cd), w.astype(cd),
            preferred_element_type=jnp.float32,
        )
    else:
        logits = jnp.einsum(
            "...d,dv->...v", x.astype(cd), w.astype(cd),
            preferred_element_type=jnp.float32,
        )
    return logits


def sinusoidal_positions(n: int, d: int) -> jax.Array:
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, dim / d)
    pe = jnp.zeros((n, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(ang))
    pe = pe.at[:, 1::2].set(jnp.cos(ang))
    return pe
