"""Unified model facade: build_model(cfg) -> Model with init / forward /
prefill / decode, dispatching on the architecture family.

The facade is what the launchers, dry-run driver, and tests consume; each
family keeps its own module underneath (transformer / moe / ssm / hybrid).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import hybrid, transformer
from .config import ModelConfig

Params = Dict[str, Any]


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    init: Callable[[jax.Array], Params]
    #: forward(params, batch) -> logits ; batch is a dict of arrays
    forward: Callable[..., jax.Array]
    init_cache: Optional[Callable[..., Any]] = None
    prefill: Optional[Callable[..., Tuple[jax.Array, Any]]] = None
    decode_step: Optional[Callable[..., Tuple[jax.Array, Any]]] = None

    @property
    def arch_id(self) -> str:
        return self.cfg.arch_id


def build_model(cfg: ModelConfig, *, attn_impl: str = "auto") -> Model:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        def fwd(params, batch, moe_capacity=None):
            return transformer.decoder_forward(
                params, batch["tokens"], cfg, attn_impl=attn_impl,
                moe_capacity=moe_capacity,
            )

        def prefill(params, batch, cache, moe_capacity=None):
            return transformer.decoder_prefill(
                params, batch["tokens"], cache, cfg,
                moe_capacity=moe_capacity,
            )

        def decode(params, token, cache, cache_index, moe_capacity=None):
            return transformer.decoder_decode_step(
                params, token, cache, cache_index, cfg,
                moe_capacity=moe_capacity,
            )

        return Model(
            cfg=cfg,
            init=lambda key: transformer.decoder_init(cfg, key),
            forward=fwd,
            init_cache=lambda batch, max_len: transformer.decoder_init_cache(
                cfg, batch, max_len
            ),
            prefill=prefill,
            decode_step=decode,
        )

    if fam == "hybrid_jamba":
        def fwd(params, batch, moe_capacity=None):
            return hybrid.hybrid_forward(
                params, batch["tokens"], cfg, attn_impl=attn_impl,
                moe_capacity=moe_capacity,
            )

        def prefill(params, batch, cache, moe_capacity=None):
            return hybrid.hybrid_prefill(
                params, batch["tokens"], cache, cfg,
                moe_capacity=moe_capacity,
            )

        def decode(params, token, cache, cache_index, moe_capacity=None):
            return hybrid.hybrid_decode_step(
                params, token, cache, cache_index, cfg,
                moe_capacity=moe_capacity,
            )

        return Model(
            cfg=cfg,
            init=lambda key: hybrid.hybrid_init(cfg, key),
            forward=fwd,
            init_cache=lambda batch, max_len: hybrid.hybrid_init_cache(
                cfg, batch, max_len
            ),
            prefill=prefill,
            decode_step=decode,
        )

    if fam == "ssm_xlstm":
        def fwd(params, batch, moe_capacity=None):
            return transformer.xlstm_forward(params, batch["tokens"], cfg)

        def prefill(params, batch, cache, moe_capacity=None):
            logits, states = transformer.xlstm_forward(
                params, batch["tokens"], cfg, states=cache
            )
            return logits[:, -1], states

        def decode(params, token, cache, cache_index, moe_capacity=None):
            logits, states = transformer.xlstm_forward(
                params, token[:, None], cfg, states=cache
            )
            return logits[:, -1], states

        return Model(
            cfg=cfg,
            init=lambda key: transformer.xlstm_init(cfg, key),
            forward=fwd,
            init_cache=lambda batch, max_len: transformer.xlstm_init_states(
                cfg, batch
            ),
            prefill=prefill,
            decode_step=decode,
        )

    if fam == "encdec":
        def fwd(params, batch, moe_capacity=None):
            return transformer.encdec_forward(
                params, batch["frames"], batch["tokens"], cfg,
                attn_impl=attn_impl,
            )

        def prefill(params, batch, cache, moe_capacity=None):
            return transformer.encdec_prefill(
                params, batch["frames"], batch["tokens"], cache, cfg,
            )

        def decode(params, token, cache, cache_index, moe_capacity=None):
            return transformer.encdec_decode_step(
                params, token, cache, cache_index, cfg
            )

        return Model(
            cfg=cfg,
            init=lambda key: transformer.encdec_init(cfg, key),
            forward=fwd,
            init_cache=lambda batch, max_len: transformer.encdec_init_cache(
                cfg, batch, max_len
            ),
            prefill=prefill,
            decode_step=decode,
        )

    raise ValueError(f"unknown family {fam!r}")
