"""Jamba-style hybrid: Mamba+attention 1:7 interleave with MoE FFNs.

Structure (period of ``attn_period`` layers, scanned over periods for a
compact HLO):

  layer i in period:  mixer = attention  if i == attn_period-1 else mamba
                      ffn   = MoE        if i odd else dense MLP

For jamba-1.5-large: 72 layers = 9 periods of 8; one attention layer per
period (1:7), MoE on every other layer -- matching the published layout.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import layers, moe as moe_mod, ssm
from .config import ModelConfig

Params = Dict[str, Any]


def _sub_init(key, cfg: ModelConfig, idx_in_period: int, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    is_attn = idx_in_period == cfg.attn_period - 1
    p: Params = {"ln1": layers.norm_init(cfg.d_model, cfg.norm, dtype),
                 "ln2": layers.norm_init(cfg.d_model, cfg.norm, dtype)}
    if is_attn:
        p["attn"] = layers.attention_init(k1, cfg, dtype)
    else:
        p["mamba"] = ssm.mamba_init(k1, cfg, dtype)
    if idx_in_period % 2 == 1:
        p["moe"] = moe_mod.moe_init(k2, cfg, dtype)
    else:
        p["mlp"] = layers.mlp_init(k2, cfg, dtype)
    return p


def hybrid_init(cfg: ModelConfig, key) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    n_periods = cfg.n_layers // cfg.attn_period
    k_emb, k_per = jax.random.split(key)

    def period_init(k):
        ks = jax.random.split(k, cfg.attn_period)
        return {
            f"sub{i}": _sub_init(ks[i], cfg, i, dtype)
            for i in range(cfg.attn_period)
        }

    period_keys = jax.random.split(k_per, n_periods)
    periods = jax.vmap(period_init)(period_keys)
    return {
        "embed": layers.embed_init(k_emb, cfg, dtype),
        "periods": periods,
        "ln_f": layers.norm_init(cfg.d_model, cfg.norm, dtype),
    }


def _period_apply(pp, x, cfg, *, positions, attn_impl, moe_capacity,
                  cache=None, cache_index=None):
    """Apply one period (attn_period sub-layers).  cache: dict with
    'k'/'v' (attention) and 'conv'/'ssm' stacked over the mamba slots."""
    new_kv = None
    new_mamba = {"conv": [], "ssm": []} if cache is not None else None
    mamba_slot = 0
    for i in range(cfg.attn_period):
        sp = pp[f"sub{i}"]
        h = layers.norm_apply(sp["ln1"], x, cfg.norm, cfg.norm_eps)
        if "attn" in sp:
            c = None
            if cache is not None:
                c = {"k": cache["k"], "v": cache["v"]}
            a, nc = layers.attention_apply(
                sp["attn"], h, cfg, positions=positions, cache=c,
                cache_index=cache_index, causal=True, attn_impl=attn_impl,
            )
            if cache is not None:
                new_kv = nc
        else:
            st = None
            if cache is not None:
                st = {
                    "conv": cache["conv"][mamba_slot],
                    "ssm": cache["ssm"][mamba_slot],
                }
            a, nst = ssm.mamba_apply(sp["mamba"], h, cfg, state=st)
            if cache is not None:
                new_mamba["conv"].append(nst["conv"])
                new_mamba["ssm"].append(nst["ssm"])
                mamba_slot += 1
        x = x + a
        h = layers.norm_apply(sp["ln2"], x, cfg.norm, cfg.norm_eps)
        if "moe" in sp:
            f = moe_mod.moe_apply(sp["moe"], h, cfg, capacity=moe_capacity)
        else:
            f = layers.mlp_apply(sp["mlp"], h, cfg)
        x = x + f
    if cache is None:
        return x, None
    new_cache = {
        "k": new_kv["k"], "v": new_kv["v"],
        "conv": jnp.stack(new_mamba["conv"]),
        "ssm": jnp.stack(new_mamba["ssm"]),
    }
    return x, new_cache


def hybrid_forward(
    params: Params,
    tokens: jax.Array,
    cfg: ModelConfig,
    *,
    attn_impl: str = "auto",
    moe_capacity: Optional[int] = None,
) -> jax.Array:
    B, T = tokens.shape
    x = layers.embed_apply(params["embed"], tokens, cfg)
    positions = jnp.broadcast_to(jnp.arange(T)[None], (B, T))

    def body(h, pp):
        h, _ = _period_apply(
            pp, h, cfg, positions=positions, attn_impl=attn_impl,
            moe_capacity=moe_capacity,
        )
        return h, None

    if cfg.remat == "block":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["periods"])
    x = layers.norm_apply(params["ln_f"], x, cfg.norm, cfg.norm_eps)
    return layers.unembed_apply(params["embed"], None, x, cfg)


def hybrid_init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    n_periods = cfg.n_layers // cfg.attn_period
    n_mamba = cfg.attn_period - 1
    m = cfg.mamba
    d_in = m.expand * cfg.d_model
    dt = jnp.dtype(cfg.compute_dtype)
    return {
        "k": jnp.zeros((n_periods, batch, max_len, cfg.n_kv_heads, cfg.hd), dt),
        "v": jnp.zeros((n_periods, batch, max_len, cfg.n_kv_heads, cfg.hd), dt),
        "conv": jnp.zeros((n_periods, n_mamba, batch, m.d_conv - 1, d_in), dt),
        "ssm": jnp.zeros((n_periods, n_mamba, batch, d_in, m.d_state),
                         jnp.float32),
    }


def _cached_apply(params, x, positions, cache, cache_index, cfg,
                  moe_capacity=None):
    def body(h, xs):
        pp, c = xs
        h, nc = _period_apply(
            pp, h, cfg, positions=positions, attn_impl="xla",
            moe_capacity=moe_capacity, cache=c, cache_index=cache_index,
        )
        return h, nc

    x, new_cache = jax.lax.scan(body, x, (params["periods"], cache))
    return x, new_cache


def hybrid_prefill(params, tokens, cache, cfg, *, moe_capacity=None):
    B, T = tokens.shape
    x = layers.embed_apply(params["embed"], tokens, cfg)
    positions = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    x, new_cache = _cached_apply(
        params, x, positions, cache, jnp.int32(0), cfg,
        moe_capacity=moe_capacity,
    )
    x = layers.norm_apply(params["ln_f"], x, cfg.norm, cfg.norm_eps)
    logits = layers.unembed_apply(params["embed"], None, x[:, -1:], cfg)
    return logits[:, 0], new_cache


def hybrid_decode_step(params, token, cache, cache_index, cfg,
                       *, moe_capacity=None):
    B = token.shape[0]
    x = layers.embed_apply(params["embed"], token[:, None], cfg)
    positions = jnp.broadcast_to(cache_index[None, None], (B, 1))
    x, new_cache = _cached_apply(
        params, x, positions, cache, cache_index, cfg,
        moe_capacity=moe_capacity,
    )
    x = layers.norm_apply(params["ln_f"], x, cfg.norm, cfg.norm_eps)
    logits = layers.unembed_apply(params["embed"], None, x, cfg)
    return logits[:, 0], new_cache
