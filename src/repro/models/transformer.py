"""Decoder-only transformer (dense / MoE / VLM), encoder-decoder
(whisper), and the xLSTM stack.

Layer stacks use ``lax.scan`` over stacked parameters wherever the blocks
are uniform (dense/MoE decoders) to keep the lowered HLO compact for the
512-device dry-run; small non-uniform stacks (whisper 4+4, xLSTM 12) use
python loops.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import layers, moe as moe_mod, ssm
from .config import ModelConfig

Params = Dict[str, Any]


# =============================================================================
# Uniform decoder block (dense or MoE FFN)
# =============================================================================

def block_init(key, cfg: ModelConfig, dtype) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "ln1": layers.norm_init(cfg.d_model, cfg.norm, dtype),
        "attn": layers.attention_init(k1, cfg, dtype),
        "ln2": layers.norm_init(cfg.d_model, cfg.norm, dtype),
    }
    if cfg.family == "moe" or (cfg.moe is not None and cfg.moe.layout == "all"):
        p["moe"] = moe_mod.moe_init(k2, cfg, dtype)
    else:
        p["mlp"] = layers.mlp_init(k3, cfg, dtype)
    return p


def block_apply(
    p: Params,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    positions: jax.Array,
    cache: Optional[Dict[str, jax.Array]] = None,
    cache_index: Optional[jax.Array] = None,
    attn_impl: str = "auto",
    moe_capacity: Optional[int] = None,
):
    h = layers.norm_apply(p["ln1"], x, cfg.norm, cfg.norm_eps)
    a, new_cache = layers.attention_apply(
        p["attn"], h, cfg, positions=positions, cache=cache,
        cache_index=cache_index, causal=True, attn_impl=attn_impl,
    )
    x = x + a
    h = layers.norm_apply(p["ln2"], x, cfg.norm, cfg.norm_eps)
    if "moe" in p:
        f = moe_mod.moe_apply(p["moe"], h, cfg, capacity=moe_capacity)
    else:
        f = layers.mlp_apply(p["mlp"], h, cfg)
    return x + f, new_cache


# =============================================================================
# Decoder-only model (dense | moe | vlm)
# =============================================================================

def decoder_init(cfg: ModelConfig, key) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    k_emb, k_blocks, k_head = jax.random.split(key, 3)
    block_keys = jax.random.split(k_blocks, cfg.n_layers)
    blocks = jax.vmap(lambda k: block_init(k, cfg, dtype))(block_keys)
    p = {
        "embed": layers.embed_init(k_emb, cfg, dtype),
        "blocks": blocks,
        "ln_f": layers.norm_init(cfg.d_model, cfg.norm, dtype),
    }
    if not cfg.tie_embeddings:
        p["head"] = layers.dense_init(
            k_head, cfg.d_model, cfg.vocab, dtype,
            scale=1.0 / math.sqrt(cfg.d_model),
        )
    return p


def _scan_blocks(params_blocks, x, cfg, *, positions, attn_impl,
                 moe_capacity, caches=None, cache_index=None):
    """scan over stacked block params (and stacked caches, if serving)."""

    def body(carry, xs):
        h = carry
        if caches is None:
            bp = xs
            h, _ = block_apply(
                bp, h, cfg, positions=positions, attn_impl=attn_impl,
                moe_capacity=moe_capacity,
            )
            return h, None
        bp, c = xs
        h, new_c = block_apply(
            bp, h, cfg, positions=positions, cache=c,
            cache_index=cache_index, attn_impl=attn_impl,
            moe_capacity=moe_capacity,
        )
        return h, new_c

    if cfg.remat == "block":
        body = jax.checkpoint(body)

    xs = params_blocks if caches is None else (params_blocks, caches)
    x, new_caches = jax.lax.scan(body, x, xs)
    return x, new_caches


def decoder_forward(
    params: Params,
    tokens: jax.Array,                 # (B, T)
    cfg: ModelConfig,
    *,
    attn_impl: str = "auto",
    moe_capacity: Optional[int] = None,
) -> jax.Array:
    B, T = tokens.shape
    x = layers.embed_apply(params["embed"], tokens, cfg)
    positions = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    x, _ = _scan_blocks(
        params["blocks"], x, cfg, positions=positions, attn_impl=attn_impl,
        moe_capacity=moe_capacity,
    )
    x = layers.norm_apply(params["ln_f"], x, cfg.norm, cfg.norm_eps)
    return layers.unembed_apply(
        params["embed"], params.get("head"), x, cfg
    )


def decoder_init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.hd)
    dt = jnp.dtype(cfg.compute_dtype)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def decoder_prefill(
    params: Params,
    tokens: jax.Array,
    cache: Params,
    cfg: ModelConfig,
    *,
    attn_impl: str = "auto",
    moe_capacity: Optional[int] = None,
) -> Tuple[jax.Array, Params]:
    """Run the prompt; returns (last-position logits, filled cache)."""
    B, T = tokens.shape
    x = layers.embed_apply(params["embed"], tokens, cfg)
    positions = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    caches = {"k": cache["k"], "v": cache["v"]}
    # scan wants per-layer leading axis on cache
    x, new_caches = _scan_blocks(
        params["blocks"], x, cfg, positions=positions, attn_impl=attn_impl,
        moe_capacity=moe_capacity,
        caches=caches, cache_index=jnp.int32(0),
    )
    x = layers.norm_apply(params["ln_f"], x, cfg.norm, cfg.norm_eps)
    logits = layers.unembed_apply(
        params["embed"], params.get("head"), x[:, -1:], cfg
    )
    return logits[:, 0], new_caches


def decoder_decode_step(
    params: Params,
    token: jax.Array,                  # (B,) int32
    cache: Params,
    cache_index: jax.Array,            # scalar int32: write position
    cfg: ModelConfig,
    *,
    moe_capacity: Optional[int] = None,
) -> Tuple[jax.Array, Params]:
    B = token.shape[0]
    x = layers.embed_apply(params["embed"], token[:, None], cfg)
    if isinstance(cache_index, jax.Array) and cache_index.ndim == 1:
        positions = cache_index[:, None]                    # per-slot decode
    else:
        positions = jnp.broadcast_to(cache_index[None, None], (B, 1))
    x, new_caches = _scan_blocks(
        params["blocks"], x, cfg, positions=positions, attn_impl="xla",
        moe_capacity=moe_capacity,
        caches=cache, cache_index=cache_index,
    )
    x = layers.norm_apply(params["ln_f"], x, cfg.norm, cfg.norm_eps)
    logits = layers.unembed_apply(
        params["embed"], params.get("head"), x, cfg
    )
    return logits[:, 0], new_caches


# =============================================================================
# Encoder-decoder (whisper backbone; conv frontend stubbed per assignment)
# =============================================================================

def encdec_init(cfg: ModelConfig, key) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, 6)

    def enc_block(k):
        k1, k2 = jax.random.split(k)
        return {
            "ln1": layers.norm_init(cfg.d_model, cfg.norm, dtype),
            "attn": layers.attention_init(k1, cfg, dtype),
            "ln2": layers.norm_init(cfg.d_model, cfg.norm, dtype),
            "mlp": layers.mlp_init(k2, cfg, dtype),
        }

    def dec_block(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "ln1": layers.norm_init(cfg.d_model, cfg.norm, dtype),
            "self_attn": layers.attention_init(k1, cfg, dtype),
            "ln_x": layers.norm_init(cfg.d_model, cfg.norm, dtype),
            "cross_attn": layers.attention_init(k2, cfg, dtype),
            "ln2": layers.norm_init(cfg.d_model, cfg.norm, dtype),
            "mlp": layers.mlp_init(k3, cfg, dtype),
        }

    enc_keys = jax.random.split(keys[0], cfg.n_encoder_layers)
    dec_keys = jax.random.split(keys[1], cfg.n_layers)
    return {
        "embed": layers.embed_init(keys[2], cfg, dtype),
        "enc_blocks": [enc_block(k) for k in enc_keys],
        "dec_blocks": [dec_block(k) for k in dec_keys],
        "ln_enc": layers.norm_init(cfg.d_model, cfg.norm, dtype),
        "ln_f": layers.norm_init(cfg.d_model, cfg.norm, dtype),
    }


def encode(params: Params, frames: jax.Array, cfg: ModelConfig,
           *, attn_impl: str = "auto") -> jax.Array:
    """frames: (B, n_frames, d_model) -- precomputed stub embeddings."""
    B, Tf, _ = frames.shape
    pe = layers.sinusoidal_positions(Tf, cfg.d_model)
    x = frames.astype(cfg.compute_dtype) + pe.astype(cfg.compute_dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(Tf)[None], (B, Tf))

    def enc_block(bp, x):
        h = layers.norm_apply(bp["ln1"], x, cfg.norm, cfg.norm_eps)
        a, _ = layers.attention_apply(
            bp["attn"], h, cfg, positions=positions, causal=False,
            attn_impl=attn_impl,
        )
        x = x + a
        h = layers.norm_apply(bp["ln2"], x, cfg.norm, cfg.norm_eps)
        return x + layers.mlp_apply(bp["mlp"], h, cfg)

    if cfg.remat == "block":
        enc_block = jax.checkpoint(enc_block)
    for bp in params["enc_blocks"]:
        x = enc_block(bp, x)
    return layers.norm_apply(params["ln_enc"], x, cfg.norm, cfg.norm_eps)


def encdec_forward(
    params: Params,
    frames: jax.Array,                  # (B, n_frames, d_model)
    tokens: jax.Array,                  # (B, T)
    cfg: ModelConfig,
    *,
    attn_impl: str = "auto",
) -> jax.Array:
    enc = encode(params, frames, cfg, attn_impl=attn_impl)
    B, T = tokens.shape
    x = layers.embed_apply(params["embed"], tokens, cfg)
    pe = layers.sinusoidal_positions(T, cfg.d_model).astype(x.dtype)
    x = x + pe[None]
    positions = jnp.broadcast_to(jnp.arange(T)[None], (B, T))

    def dec_block(bp, x):
        h = layers.norm_apply(bp["ln1"], x, cfg.norm, cfg.norm_eps)
        a, _ = layers.attention_apply(
            bp["self_attn"], h, cfg, positions=positions, causal=True,
            attn_impl=attn_impl,
        )
        x = x + a
        h = layers.norm_apply(bp["ln_x"], x, cfg.norm, cfg.norm_eps)
        a, _ = layers.attention_apply(
            bp["cross_attn"], h, cfg, positions=positions, kv=(enc, enc),
            causal=False, attn_impl=attn_impl,
        )
        x = x + a
        h = layers.norm_apply(bp["ln2"], x, cfg.norm, cfg.norm_eps)
        return x + layers.mlp_apply(bp["mlp"], h, cfg)

    if cfg.remat == "block":
        dec_block = jax.checkpoint(dec_block)
    for bp in params["dec_blocks"]:
        x = dec_block(bp, x)
    x = layers.norm_apply(params["ln_f"], x, cfg.norm, cfg.norm_eps)
    return layers.unembed_apply(params["embed"], None, x, cfg)


def encdec_init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    dt = jnp.dtype(cfg.compute_dtype)
    per_layer = {
        "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.hd), dt),
        "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.hd), dt),
    }
    return {
        "self": [dict(per_layer) for _ in range(cfg.n_layers)],
        # encoder output buffer; overwritten at prefill
        "enc": jnp.zeros((batch, cfg.n_audio_frames, cfg.d_model), dt),
    }


def encdec_prefill(params, frames, tokens, cache, cfg,
                   *, attn_impl: str = "auto"):
    enc = encode(params, frames, cfg, attn_impl=attn_impl)
    cache = dict(cache)
    cache["enc"] = enc
    B, T = tokens.shape
    x = layers.embed_apply(params["embed"], tokens, cfg)
    pe = layers.sinusoidal_positions(T, cfg.d_model).astype(x.dtype)
    x = x + pe[None]
    positions = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    new_self = []
    for bp, c in zip(params["dec_blocks"], cache["self"]):
        h = layers.norm_apply(bp["ln1"], x, cfg.norm, cfg.norm_eps)
        a, nc = layers.attention_apply(
            bp["self_attn"], h, cfg, positions=positions, cache=c,
            cache_index=jnp.int32(0), causal=True, attn_impl="xla",
        )
        new_self.append(nc)
        x = x + a
        h = layers.norm_apply(bp["ln_x"], x, cfg.norm, cfg.norm_eps)
        a, _ = layers.attention_apply(
            bp["cross_attn"], h, cfg, positions=positions, kv=(enc, enc),
            causal=False, attn_impl="xla",
        )
        x = x + a
        h = layers.norm_apply(bp["ln2"], x, cfg.norm, cfg.norm_eps)
        x = x + layers.mlp_apply(bp["mlp"], h, cfg)
    x = layers.norm_apply(params["ln_f"], x, cfg.norm, cfg.norm_eps)
    logits = layers.unembed_apply(params["embed"], None, x[:, -1:], cfg)
    cache["self"] = new_self
    return logits[:, 0], cache


def encdec_decode_step(params, token, cache, cache_index, cfg):
    B = token.shape[0]
    enc = cache["enc"]
    x = layers.embed_apply(params["embed"], token[:, None], cfg)
    Tmax = cache["self"][0]["k"].shape[1]
    pe = layers.sinusoidal_positions(Tmax, cfg.d_model).astype(x.dtype)
    x = x + jax.lax.dynamic_slice_in_dim(pe, cache_index, 1, 0)[None]
    positions = jnp.broadcast_to(cache_index[None, None], (B, 1))
    new_self = []
    for bp, c in zip(params["dec_blocks"], cache["self"]):
        h = layers.norm_apply(bp["ln1"], x, cfg.norm, cfg.norm_eps)
        a, nc = layers.attention_apply(
            bp["self_attn"], h, cfg, positions=positions, cache=c,
            cache_index=cache_index, causal=True, attn_impl="xla",
        )
        new_self.append(nc)
        x = x + a
        h = layers.norm_apply(bp["ln_x"], x, cfg.norm, cfg.norm_eps)
        a, _ = layers.attention_apply(
            bp["cross_attn"], h, cfg, positions=positions, kv=(enc, enc),
            causal=False, attn_impl="xla",
        )
        x = x + a
        h = layers.norm_apply(bp["ln2"], x, cfg.norm, cfg.norm_eps)
        x = x + layers.mlp_apply(bp["mlp"], h, cfg)
    x = layers.norm_apply(params["ln_f"], x, cfg.norm, cfg.norm_eps)
    logits = layers.unembed_apply(params["embed"], None, x, cfg)
    new_cache = dict(cache)
    new_cache["self"] = new_self
    return logits[:, 0], new_cache


# =============================================================================
# xLSTM stack (12 small layers: python loop)
# =============================================================================

def xlstm_init(cfg: ModelConfig, key) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, cfg.n_layers + 2)
    blocks = []
    for i in range(cfg.n_layers):
        kind = ssm.xlstm_block_kind(i, cfg)  # static per index: not stored
        init = ssm.slstm_init if kind == "slstm" else ssm.mlstm_init
        blocks.append({
            "ln": layers.norm_init(cfg.d_model, cfg.norm, dtype),
            "core": init(keys[i], cfg, dtype),
        })
    return {
        "embed": layers.embed_init(keys[-2], cfg, dtype),
        "blocks": blocks,
        "ln_f": layers.norm_init(cfg.d_model, cfg.norm, dtype),
    }


def xlstm_forward(params, tokens, cfg, *, states=None):
    """states=None: training fwd.  Otherwise a list of per-layer recurrent
    states (the O(1) 'cache'); returns (logits, new_states)."""
    x = layers.embed_apply(params["embed"], tokens, cfg)
    new_states = [] if states is not None else None
    for i, bp in enumerate(params["blocks"]):
        kind = ssm.xlstm_block_kind(i, cfg)
        h = layers.norm_apply(bp["ln"], x, cfg.norm, cfg.norm_eps)
        if kind == "slstm":
            apply = ssm.slstm_apply
        elif ssm.MLSTM_CHUNK and tokens.shape[1] > ssm.MLSTM_CHUNK:
            import functools as _ft
            apply = _ft.partial(
                ssm.mlstm_apply_chunked, chunk=ssm.MLSTM_CHUNK
            )
        else:
            apply = ssm.mlstm_apply
        st = states[i] if states is not None else None
        y, new_st = apply(bp["core"], h, cfg, state=st)
        if states is not None:
            new_states.append(new_st)
        x = x + y
    x = layers.norm_apply(params["ln_f"], x, cfg.norm, cfg.norm_eps)
    logits = layers.unembed_apply(params["embed"], None, x, cfg)
    if states is not None:
        return logits, new_states
    return logits


def xlstm_init_states(cfg: ModelConfig, batch: int):
    return [
        ssm.xlstm_init_state(cfg, batch, ssm.xlstm_block_kind(i, cfg))
        for i in range(cfg.n_layers)
    ]
