"""Mixture-of-Experts FFN with capacity-based dispatch (EP-shardable).

Routing is data-dependent top-k -- outside the teil/tensor-expression
semantics (DESIGN.md section Arch-applicability), so it is implemented
natively.  The expert GEMMs themselves are dense contractions the group
scheduler understands.

Dispatch uses the Switch/GShard capacity formulation:
  * capacity C = ceil(tokens * top_k / E) * capacity_factor;
  * position-in-expert via a cumulative-sum rank over the flattened
    (token, k) assignment list; tokens beyond capacity are dropped
    (standard on TPU -- keeps all shapes static);
  * dispatch/combine are scatter/gather, which GSPMD converts into
    all_to_all when the expert axis is sharded over the "model"/"expert"
    mesh axis while tokens are sharded over "data".
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .config import ModelConfig
from . import layers

Params = Dict[str, Any]

#: GShard-style grouped dispatch.  Tokens are reshaped to (G, N/G, d) with
#: the group axis sharded over the DP mesh axes; ALL routing (sort, rank,
#: gather) is batched per group, so it stays shard-local.  Without this
#: the (E, C, d) buffer either replicates across the data axis (every
#: data shard computing the FULL global capacity per expert -- measured
#: 14x the useful expert flops at dbrx's train shape) or, if naively
#: constrained, forces a giant cross-shard gather.  Set by launchers /
#: dry-run via :func:`set_ep_sharding`; default: 1 group, no annotation
#: (single-device tests).
_EP_SPEC: Optional[Tuple[str, Tuple[str, ...]]] = None
_NUM_GROUPS: int = 1
#: "gather" (token-side, baseline) | "scatter" (expert-side partial sum)
COMBINE_MODE: str = "gather"


def set_ep_sharding(expert_axis: Optional[str] = "model",
                    token_axes: Optional[Sequence[str]] = ("data",),
                    num_groups: int = 1) -> None:
    """expert_axis=None + num_groups>1: grouped dispatch with fully
    replicated experts (pure-DP MoE for small models)."""
    global _EP_SPEC, _NUM_GROUPS
    if expert_axis is None and not token_axes:
        _EP_SPEC = None
        _NUM_GROUPS = max(1, num_groups)
    else:
        _EP_SPEC = (expert_axis, tuple(token_axes) if token_axes else ())
        _NUM_GROUPS = max(1, num_groups)


def _constrain_buf(x: jax.Array) -> jax.Array:
    """x: (G, E, C, d) -> groups over DP axes, experts over the EP axis."""
    if _EP_SPEC is None:
        return x
    e_ax, t_ax = _EP_SPEC
    try:
        return jax.lax.with_sharding_constraint(
            x, P(t_ax if t_ax else None, e_ax, *(None,) * (x.ndim - 2))
        )
    except Exception:  # no ambient mesh: leave unconstrained
        return x


def _ep_mode() -> Optional[Tuple[Optional[str], Tuple[str, ...]]]:
    return _EP_SPEC


def moe_init(key, cfg: ModelConfig, dtype) -> Params:
    m = cfg.moe
    d, ff, E = cfg.d_model, m.d_ff_expert, m.n_experts
    ks = jax.random.split(key, 4)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(ff * 2 * cfg.n_layers)

    def stack(k, shape, scale):
        return (jax.random.normal(k, shape, jnp.float32) * scale).astype(dtype)

    p = {
        "router": layers.dense_init(ks[0], d, E, dtype),
        "w_gate": stack(ks[1], (E, d, ff), s_in),
        "w_up": stack(ks[2], (E, d, ff), s_in),
        "w_down": stack(ks[3], (E, ff, d), s_out),
    }
    return p


def moe_apply(
    p: Params,
    x: jax.Array,          # (B, T, d)
    cfg: ModelConfig,
    *,
    capacity: Optional[int] = None,
) -> jax.Array:
    """Grouped capacity dispatch.

    ``capacity`` is the GLOBAL capacity (slots per expert across all
    groups); it is divided across groups internally.
    """
    m = cfg.moe
    B, T, d = x.shape
    E, K = m.n_experts, m.top_k
    N = B * T
    G = _NUM_GROUPS if N % _NUM_GROUPS == 0 else 1
    Ng = N // G
    cd = jnp.dtype(cfg.compute_dtype)
    xt = x.reshape(G, Ng, d)

    # ---- router ----------------------------------------------------------
    logits = layers.dense_apply(p["router"], xt, jnp.float32)  # (G, Ng, E)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate, eidx = jax.lax.top_k(probs, K)                        # (G, Ng, K)
    gate = gate / jnp.clip(jnp.sum(gate, -1, keepdims=True), 1e-9)

    if capacity is None:
        capacity = int(math.ceil(N * K / E * m.capacity_factor))
        capacity = max(capacity, 8)
    cap_g = max(8, capacity // G)

    # ---- rank within expert via per-group stable sort (NOT one_hot +
    # cumsum: GSPMD lowers a sharded-axis cumsum into a reduce-window --
    # measured 17x the expert GEMM flops at olmoe's train shape) ----------
    NKg = Ng * K
    flat_e = eidx.reshape(G, NKg)
    sorted_idx = jnp.argsort(flat_e, axis=1, stable=True)        # (G, NKg)
    sorted_e = jnp.take_along_axis(flat_e, sorted_idx, axis=1)
    # first occurrence of each expert per group, via batched searchsorted
    first = jax.vmap(lambda se: jnp.searchsorted(se, jnp.arange(E)))(
        sorted_e
    )                                                            # (G, E)
    rank_sorted = jnp.arange(NKg)[None] - jnp.take_along_axis(
        first, sorted_e, axis=1
    )
    flat_pos = jnp.zeros((G, NKg), jnp.int32).at[
        jnp.arange(G)[:, None], sorted_idx
    ].set(rank_sorted.astype(jnp.int32))
    keep = flat_pos < cap_g
    flat_gate = gate.reshape(G, NKg) * keep.astype(gate.dtype)

    # ---- dispatch by gather: slot (g, e, c) pulls its token directly ------
    ends = jnp.concatenate([first[:, 1:], jnp.full((G, 1), NKg)], axis=1)
    grid = first[:, :, None] + jnp.arange(cap_g)[None, None, :]  # (G, E, C)
    slot_valid = grid < ends[:, :, None]
    slot_src = jnp.where(slot_valid, jnp.clip(grid, 0, NKg - 1), 0)
    slot_assign = jnp.take_along_axis(
        sorted_idx, slot_src.reshape(G, E * cap_g), axis=1
    )
    slot_token = slot_assign // K                                # (G, E*C)
    buf = jnp.take_along_axis(xt, slot_token[..., None], axis=1).astype(cd)
    buf = buf.reshape(G, E, cap_g, d) * slot_valid[..., None].astype(cd)
    buf = _constrain_buf(buf)   # groups over DP, experts over EP

    # ---- expert compute (batched GEMMs over the expert axis) --------------
    acc = cd if layers.REDUCE_IN_COMPUTE_DTYPE else jnp.float32
    wg, wu, wd = (p["w_gate"].astype(cd), p["w_up"].astype(cd),
                  p["w_down"].astype(cd))
    if cfg.act == "swiglu":
        g = jnp.einsum("gecd,edf->gecf", buf, wg,
                       preferred_element_type=acc)
        u = jnp.einsum("gecd,edf->gecf", buf, wu,
                       preferred_element_type=acc)
        h = (jax.nn.silu(g.astype(jnp.float32))).astype(cd) * u.astype(cd)
    else:
        u = jnp.einsum("gecd,edf->gecf", buf, wu,
                       preferred_element_type=acc)
        h = jax.nn.gelu(u.astype(jnp.float32)).astype(cd)
    out_buf = jnp.einsum("gecf,efd->gecd", h, wd,
                         preferred_element_type=acc).astype(cd)
    out_buf = _constrain_buf(out_buf)

    # ---- combine ------------------------------------------------------------
    if COMBINE_MODE == "scatter":
        # expert-side scatter-add: each expert shard pushes its slots'
        # contributions into a partial y; GSPMD sums partials with one
        # all-reduce of (G, Ng, d) -- ~10x less wire traffic than
        # all-gathering the padded (E, C, d) buffer per group row, and
        # the backward (gather from the replicated dy) needs none.
        slot_gate = jnp.take_along_axis(
            flat_gate, slot_assign, axis=1
        ).reshape(G, E * cap_g)
        contrib = out_buf.reshape(G, E * cap_g, d) * (
            slot_gate[..., None].astype(cd)
            * slot_valid.reshape(G, E * cap_g)[..., None].astype(cd)
        )
        y = jnp.zeros((G, Ng, d), cd).at[
            jnp.arange(G)[:, None], slot_token
        ].add(contrib)
    else:
        # token-side gather (baseline): every token reads its k slots
        safe_pos = jnp.where(keep, flat_pos, cap_g - 1)
        flat_slot = flat_e * cap_g + safe_pos                    # (G, NKg)
        out_flat = out_buf.reshape(G, E * cap_g, d)
        gathered = jnp.take_along_axis(
            out_flat, flat_slot[..., None], axis=1
        )                                                        # (G, NKg, d)
        weighted = gathered * flat_gate[..., None].astype(cd)
        y = jnp.sum(weighted.reshape(G, Ng, K, d), axis=2)
    return y.reshape(B, T, d).astype(x.dtype)


def aux_load_balance_loss(logits: jax.Array, eidx: jax.Array, E: int) -> jax.Array:
    """Switch-style auxiliary loss (fraction * probability per expert)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    frac = jnp.mean(
        jax.nn.one_hot(eidx[..., 0], E, dtype=jnp.float32), axis=0
    )
    imp = jnp.mean(probs, axis=0)
    return E * jnp.sum(frac * imp)
