"""Assigned-architecture model zoo (pure JAX, scan-over-layers)."""
from . import api, config, hybrid, layers, moe, ssm, transformer
from .api import Model, build_model
from .config import MambaConfig, ModelConfig, MoEConfig, XLSTMConfig

__all__ = [
    "api", "config", "hybrid", "layers", "moe", "ssm", "transformer",
    "Model", "build_model", "MambaConfig", "ModelConfig", "MoEConfig",
    "XLSTMConfig",
]
