"""Model configuration shared by every assigned architecture."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    #: which decoder layers carry a MoE FFN ("all", "odd", "none")
    layout: str = "all"


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    #: place an sLSTM block every N layers (others are mLSTM)
    slstm_every: int = 4
    proj_factor: float = 2.0


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str  # dense | moe | ssm_xlstm | hybrid_jamba | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: int = 0  # 0 -> d_model // n_heads
    qk_norm: bool = False
    qkv_bias: bool = False
    mlp_bias: bool = False
    act: str = "swiglu"  # swiglu | gelu
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    norm_eps: float = 1e-5

    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    xlstm: Optional[XLSTMConfig] = None

    #: hybrid (jamba): attention once per this many layers (else mamba)
    attn_period: int = 0

    #: encoder-decoder (whisper): encoder depth + frame count (stub frontend)
    n_encoder_layers: int = 0
    n_audio_frames: int = 1500

    #: dtypes
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    #: remat policy for scan-over-layers: "none" | "block"
    remat: str = "block"

    #: sub-quadratic attention available (drives long_500k applicability)
    @property
    def subquadratic(self) -> bool:
        return self.family in ("ssm_xlstm", "hybrid_jamba")

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_encdec(self) -> bool:
        return self.family == "encdec"

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks + head)."""
        d, ff, V = self.d_model, self.d_ff, self.vocab
        hd, Hq, Hkv = self.hd, self.n_heads, self.n_kv_heads
        attn = d * hd * Hq + 2 * d * hd * Hkv + hd * Hq * d
        dense_mlp = 3 * d * ff if self.act == "swiglu" else 2 * d * ff
        total = V * d * (1 if self.tie_embeddings else 2)
        if self.family in ("dense", "vlm"):
            total += self.n_layers * (attn + dense_mlp)
        elif self.family == "moe":
            m = self.moe
            expert = (3 if self.act == "swiglu" else 2) * d * m.d_ff_expert
            total += self.n_layers * (attn + m.n_experts * expert + d * m.n_experts)
        elif self.family == "hybrid_jamba":
            m = self.mamba
            d_in = m.expand * d
            dtr = m.dt_rank or -(-d // 16)
            mamba_p = (
                d * 2 * d_in + d_in * m.d_conv
                + d_in * (dtr + 2 * m.d_state) + dtr * d_in
                + d_in * m.d_state + d_in + d_in * d
            )
            n_attn = self.n_layers // self.attn_period
            n_mamba = self.n_layers - n_attn
            mo = self.moe
            expert = (3 if self.act == "swiglu" else 2) * d * mo.d_ff_expert
            n_moe = self.n_layers // 2
            n_dense = self.n_layers - n_moe
            total += (
                n_attn * attn + n_mamba * mamba_p
                + n_moe * (mo.n_experts * expert + d * mo.n_experts)
                + n_dense * dense_mlp
            )
        elif self.family == "ssm_xlstm":
            # rough: mLSTM qkv + gates + out
            x = self.xlstm
            d_in = int(x.proj_factor * d)
            per = d * d_in * 2 + d_in * d + 3 * d_in * hd * Hq // max(Hq, 1)
            total += self.n_layers * (per + dense_mlp if ff else per)
        elif self.family == "encdec":
            total += (self.n_layers + self.n_encoder_layers) * (
                attn + dense_mlp
            ) + self.n_layers * attn  # cross attention
        return total
