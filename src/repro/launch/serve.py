"""Serving launcher: slot-based batched engine over a selected arch.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --smoke
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from .. import configs
from ..models import build_model
from ..runtime.serve import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b",
                    choices=configs.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new-tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    if cfg.family in ("hybrid_jamba", "ssm_xlstm", "encdec"):
        raise SystemExit(
            "the slot engine drives dense-decoder archs; use the dryrun "
            "decode cells for SSM/hybrid serving analysis"
        )
    model = build_model(cfg, attn_impl="auto")
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(
        model, params, slots=args.slots, max_len=args.max_len
    )
    rng = np.random.default_rng(0)
    reqs = [
        Request(
            rid=i,
            prompt=rng.integers(
                1, cfg.vocab, size=int(rng.integers(3, 12))
            ).astype(np.int32),
            max_new_tokens=args.max_new_tokens,
        )
        for i in range(args.requests)
    ]
    for r in reqs:
        engine.submit(r)
    engine.run_until_drained()
    for r in reqs:
        print(f"request {r.rid}: {len(r.prompt)} prompt toks -> {r.output}")


if __name__ == "__main__":
    main()
