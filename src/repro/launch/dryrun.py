import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first
# initialization.  The 512 placeholder host devices exist only for this
# driver; tests/benchmarks see the real device count.

"""Multi-pod dry-run driver.

For every (architecture x input-shape) cell, build the appropriate step
function (train_step / prefill / decode), lower it with production
in/out shardings on the single-pod 16x16 mesh and the 2x16x16 multi-pod
mesh, ``.compile()`` it, and record:

  * ``memory_analysis()``  -- proves the partitioned program fits;
  * ``cost_analysis()``    -- per-device FLOPs / bytes for the roofline;
  * collective bytes parsed from the post-SPMD HLO.

Results land in ``results/dryrun/<arch>__<shape>__<mesh>.json`` and feed
EXPERIMENTS.md sections Dry-run / Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch internlm2-1.8b \
      --shape train_4k --mesh single           # one cell
  PYTHONPATH=src python -m repro.launch.dryrun --all                 # sweep
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from .. import configs
from ..analysis import roofline, scancost
from ..configs import shapes as shape_mod
from ..distributed import sharding as shard_rules
from ..models import build_model
from ..models.config import ModelConfig
from ..optim import AdamWConfig
from ..runtime.train import make_train_step
from . import mesh as mesh_mod

RESULTS_DIR = os.path.join("results", "dryrun")


_CAP_FACTOR_OVERRIDE: Optional[float] = None


def _moe_capacity(cfg: ModelConfig, n_tokens: int) -> Optional[int]:
    if cfg.moe is None:
        return None
    m = cfg.moe
    f = _CAP_FACTOR_OVERRIDE if _CAP_FACTOR_OVERRIDE else m.capacity_factor
    cap = int(n_tokens * m.top_k / m.n_experts * f)
    return max(cap, 8)


def _active_params(cfg: ModelConfig) -> int:
    """Active parameter count for MODEL_FLOPS (MoE: top_k of n_experts)."""
    total = cfg.param_count()
    if cfg.moe is None:
        return total
    # subtract inactive expert fraction
    m = cfg.moe
    d = cfg.d_model
    expert = (3 if cfg.act == "swiglu" else 2) * d * m.d_ff_expert
    if cfg.family == "moe":
        n_moe_layers = cfg.n_layers
    else:  # jamba: MoE on odd layers
        n_moe_layers = cfg.n_layers // 2
    inactive = n_moe_layers * (m.n_experts - m.top_k) * expert
    return total - inactive


def build_cell(cfg: ModelConfig, shape_name: str, mesh,
               attn_impl: str = "xla",
               grad_accum: int = 1) -> Dict[str, Any]:
    """Returns dict with 'fn', 'args' (ShapeDtypeStructs), 'in_shardings',
    'out_shardings', 'model_flops'."""
    spec = shape_mod.SHAPES[shape_name]
    model = build_model(cfg, attn_impl=attn_impl)
    key = jax.random.PRNGKey(0)

    params_shape = jax.eval_shape(model.init, key)
    params_sh = shard_rules.param_shardings(params_shape, mesh)
    batch_specs = shape_mod.input_specs(cfg, shape_name)
    n_tokens = spec.global_batch * spec.seq_len
    cap = _moe_capacity(cfg, n_tokens)

    if spec.kind == "train":
        opt = AdamWConfig()
        step = make_train_step(
            model, opt, moe_capacity=cap, grad_accum=grad_accum
        )

        def state_shape():
            from ..optim import adamw_init
            opt_shape = jax.eval_shape(adamw_init, params_shape)
            return {
                "params": params_shape,
                "opt_state": opt_shape,
                "step": jax.ShapeDtypeStruct((), jnp.int32),
            }

        st_shape = state_shape()
        # ZeRO-1: optimizer moments additionally sharded over the DP axes
        # (the stacked-layer axis usually absorbs it).
        moments_sh = shard_rules.extend_with_dp(params_sh, params_shape, mesh)
        # FSDP the params themselves when TP-only residency is too large
        if not shard_rules.params_fit_replicated_dp(params_shape, mesh):
            params_sh = moments_sh
        opt_sh = {
            "mu": moments_sh,
            "nu": moments_sh,
            "step": shard_rules.replicated(mesh),
        }
        state_sh = {
            "params": params_sh,
            "opt_state": opt_sh,
            "step": shard_rules.replicated(mesh),
        }
        batch_sh = shard_rules.batch_shardings(batch_specs, mesh)
        return {
            "fn": step,
            "args": (st_shape, batch_specs),
            "in_shardings": (state_sh, batch_sh),
            "out_shardings": (state_sh, None),
            "donate_argnums": (0,),
            "model_flops": roofline.model_flops(
                params=cfg.param_count(), tokens=n_tokens, kind="train",
                active_params=_active_params(cfg),
            ),
        }

    # serving cells: weight-gathered (FSDP-style) placement when the model
    # is too large for TP-only residency
    if not shard_rules.params_fit_replicated_dp(params_shape, mesh):
        params_sh = shard_rules.extend_with_dp(params_sh, params_shape, mesh)

    if spec.kind == "prefill":
        cache_shape = jax.eval_shape(
            lambda: model.init_cache(spec.global_batch, spec.seq_len)
        )
        cache_sh = shard_rules.cache_shardings(
            cache_shape, cfg, mesh, batch=spec.global_batch
        )

        def prefill(params, batch, cache):
            return model.prefill(params, batch, cache, moe_capacity=cap)

        batch_sh = shard_rules.batch_shardings(batch_specs, mesh)
        return {
            "fn": prefill,
            "args": (params_shape, batch_specs, cache_shape),
            "in_shardings": (params_sh, batch_sh, cache_sh),
            "out_shardings": (None, cache_sh),
            "donate_argnums": (2,),
            "model_flops": roofline.model_flops(
                params=cfg.param_count(), tokens=n_tokens, kind="prefill",
                active_params=_active_params(cfg),
            ),
        }

    # decode: one token against a seq_len cache
    cache_shape = jax.eval_shape(
        lambda: model.init_cache(spec.global_batch, spec.seq_len)
    )
    cache_sh = shard_rules.cache_shardings(
        cache_shape, cfg, mesh, batch=spec.global_batch
    )
    token_spec = jax.ShapeDtypeStruct((spec.global_batch,), jnp.int32)
    idx_spec = jax.ShapeDtypeStruct((), jnp.int32)
    dcap = _moe_capacity(cfg, spec.global_batch)

    def decode(params, token, cache, cache_index):
        return model.decode_step(
            params, token, cache, cache_index, moe_capacity=dcap
        )

    tok_sh = shard_rules.batch_shardings(
        {"token": token_spec}, mesh
    )["token"]
    return {
        "fn": decode,
        "args": (params_shape, token_spec, cache_shape, idx_spec),
        "in_shardings": (
            params_sh, tok_sh, cache_sh, shard_rules.replicated(mesh)
        ),
        "out_shardings": (None, cache_sh),
        "donate_argnums": (2,),
        "model_flops": roofline.model_flops(
            params=cfg.param_count(), tokens=spec.global_batch,
            kind="decode", active_params=_active_params(cfg),
        ),
    }


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             *, results_dir: str = RESULTS_DIR,
             attn_impl: str = "xla",
             mlstm_chunk: Optional[int] = None,
             grad_accum: int = 1,
             dp_only: bool = False,
             variant: str = "baseline") -> Dict[str, Any]:
    cfg = configs.get(arch)
    skip = shape_mod.applicable(cfg, shape_name)
    record: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "variant": variant, "attn_impl": attn_impl,
        "mlstm_chunk": mlstm_chunk,
    }
    from ..models import ssm as ssm_mod
    ssm_mod.MLSTM_CHUNK = mlstm_chunk
    if skip is not None:
        record["status"] = "skipped"
        record["reason"] = skip
        _write(record, results_dir)
        return record

    mesh = mesh_mod.make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    chips = mesh.devices.size
    # EP annotation: grouped dispatch -- one group per DP shard, experts
    # over the model axis (GShard 2D layout)
    from ..models import moe as moe_mod
    import numpy as _np
    dp_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp_total = int(_np.prod([sizes[a] for a in dp_axes])) if dp_axes else 1
    if dp_only:
        # model axis re-purposed as DP: replicated experts, local dispatch
        # (groups sharded over EVERY axis; experts unsharded)
        moe_mod.set_ep_sharding(
            None, tuple(mesh.axis_names), num_groups=mesh.devices.size
        )
        shard_rules.DP_ONLY = True
    else:
        moe_mod.set_ep_sharding("model", dp_axes, num_groups=dp_total)
        shard_rules.DP_ONLY = False
    t0 = time.time()
    try:
        cell = build_cell(cfg, shape_name, mesh, attn_impl=attn_impl,
                          grad_accum=grad_accum)
        with mesh:
            jitted = jax.jit(
                cell["fn"],
                in_shardings=cell["in_shardings"],
                out_shardings=cell["out_shardings"],
                donate_argnums=cell.get("donate_argnums", ()),
            )
            lowered = jitted.lower(*cell["args"])
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        ma = compiled.memory_analysis()
        # scan-body cost correction (XLA counts while bodies once)
        model = build_model(cfg, attn_impl=attn_impl)
        params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        spec = shape_mod.SHAPES[shape_name]
        corr = scancost.corrections(
            cfg, shape_name, mesh, model, params_shape,
            moe_capacity=_moe_capacity(
                cfg, spec.global_batch * spec.seq_len
            ) if spec.kind != "decode" else _moe_capacity(
                cfg, spec.global_batch
            ),
            attn_impl=attn_impl,
        )
        report = roofline.analyze(
            compiled, arch=arch, shape=shape_name, mesh_name=mesh_kind,
            chips=chips, model_flops_value=cell["model_flops"],
            extra_flops=corr["flops"], extra_bytes=corr["bytes"],
        )
        report.coll_bytes += corr.get("coll", 0.0)
        record["scan_correction"] = {
            "flops": corr["flops"], "bytes": corr["bytes"],
            "coll": corr.get("coll", 0.0), "detail": corr["detail"],
        }
        record.update(
            status="ok",
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            memory_analysis={
                "argument_size_in_bytes": ma.argument_size_in_bytes,
                "output_size_in_bytes": ma.output_size_in_bytes,
                "temp_size_in_bytes": ma.temp_size_in_bytes,
                "alias_size_in_bytes": ma.alias_size_in_bytes,
            },
            roofline=report.to_dict(),
        )
        print(
            f"[ok] {arch} {shape_name} {mesh_kind}: "
            f"t_comp={report.t_compute:.4g}s t_mem={report.t_memory:.4g}s "
            f"t_coll={report.t_collective:.4g}s bound={report.bottleneck} "
            f"mem/dev={record['memory_analysis']['argument_size_in_bytes']/2**30:.2f}+"
            f"{record['memory_analysis']['temp_size_in_bytes']/2**30:.2f} GiB "
            f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)",
            flush=True,
        )
    except Exception as e:  # a failing cell is a bug in the system
        record["status"] = "error"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
        print(f"[ERROR] {arch} {shape_name} {mesh_kind}: {e}", flush=True)
    _write(record, results_dir)
    return record


def _write(record: Dict[str, Any], results_dir: str) -> None:
    os.makedirs(results_dir, exist_ok=True)
    name = f"{record['arch']}__{record['shape']}__{record['mesh']}.json"
    with open(os.path.join(results_dir, name), "w") as f:
        json.dump(record, f, indent=1, default=str)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(shape_mod.SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multipod"])
    ap.add_argument("--all", action="store_true",
                    help="sweep every (arch, shape) on both meshes")
    ap.add_argument("--results", default=RESULTS_DIR)
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--attn-impl", default="xla",
                    choices=["xla", "xla_flash"])
    ap.add_argument("--mlstm-chunk", type=int, default=None)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--moe-combine", default="gather",
                    choices=["gather", "scatter"])
    ap.add_argument("--moe-cap-factor", type=float, default=None)
    ap.add_argument("--bf16-reduce", action="store_true")
    ap.add_argument("--dp-only", action="store_true",
                    help="map the model axis as extra DP (small models): "
                         "replicated params, batch over every mesh axis")
    ap.add_argument("--variant", default="baseline")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in configs.ARCH_IDS:
            for shape in shape_mod.SHAPES:
                for mesh_kind in ("single", "multipod"):
                    cells.append((arch, shape, mesh_kind))
    else:
        if not args.arch:
            ap.error("--arch required unless --all")
        shapes_ = [args.shape] if args.shape else list(shape_mod.SHAPES)
        cells = [(args.arch, s, args.mesh) for s in shapes_]

    from ..models import moe as _moe, layers as _layers
    _moe.COMBINE_MODE = args.moe_combine
    _layers.REDUCE_IN_COMPUTE_DTYPE = args.bf16_reduce
    global _CAP_FACTOR_OVERRIDE
    _CAP_FACTOR_OVERRIDE = args.moe_cap_factor

    n_ok = n_skip = n_err = 0
    for arch, shape, mesh_kind in cells:
        out = os.path.join(
            args.results, f"{arch}__{shape}__{mesh_kind}.json"
        )
        if args.skip_existing and os.path.exists(out):
            with open(out) as f:
                prev = json.load(f)
            if prev.get("status") in ("ok", "skipped"):
                continue
        rec = run_cell(
            arch, shape, mesh_kind, results_dir=args.results,
            attn_impl=args.attn_impl, mlstm_chunk=args.mlstm_chunk,
            grad_accum=args.grad_accum, dp_only=args.dp_only,
            variant=args.variant,
        )
        st = rec["status"]
        n_ok += st == "ok"
        n_skip += st == "skipped"
        n_err += st == "error"
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_err} errors", flush=True)


if __name__ == "__main__":
    main()
