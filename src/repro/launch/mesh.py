"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state; the dry-run driver sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import and then calls :func:`make_production_mesh`.

Mesh axes:
  * ``pod``   -- DCN-class axis across pods (data parallel by default;
    the pipeline module can claim it for PP stages).
  * ``data``  -- in-pod data parallelism (batch / CFD elements).
  * ``model`` -- tensor parallelism (heads / ffn / vocab / experts).
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

try:  # AxisType landed after jax 0.4.x; Auto matches the old default
    from jax.sharding import AxisType
except ImportError:
    AxisType = None


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(
        shape, axes, axis_types=(AxisType.Auto,) * len(axes)
    )


def make_local_mesh(model_axis: int = 1) -> Mesh:
    """Small mesh over whatever devices exist (tests / CPU smoke)."""
    n = len(jax.devices())
    data = n // model_axis
    devs = np.array(jax.devices()[: data * model_axis]).reshape(
        data, model_axis
    )
    return Mesh(devs, ("data", "model"))


def data_axes(mesh: Mesh) -> tuple:
    """The axes a global batch dimension shards over."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
