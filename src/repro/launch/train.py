"""Production training launcher: pick an architecture + mesh, build the
sharded train step, and run the fault-tolerant loop.

On real hardware this runs under the cluster's process launcher (one
process per host, jax.distributed.initialize handled by the wrapper); on
this container it runs single-process on however many devices exist.

  PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
      --smoke --steps 20
"""
from __future__ import annotations

import argparse

import jax

from .. import configs
from ..checkpoint import CheckpointManager
from ..data import PrefetchPipeline, TokenStream
from ..distributed import sharding as shard_rules
from ..models import build_model
from ..optim import AdamWConfig
from ..runtime.train import (LoopConfig, TrainLoop, init_train_state,
                             make_train_step)
from . import mesh as mesh_mod


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b",
                    choices=configs.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--attn-impl", default="auto",
                    choices=["auto", "xla", "xla_flash", "pallas"])
    ap.add_argument("--mlstm-chunk", type=int, default=None,
                    help="chunkwise-parallel mLSTM width (xlstm archs)")
    ap.add_argument("--grad-accum", type=int, default=1)
    args = ap.parse_args()

    from ..models import ssm as ssm_mod
    ssm_mod.MLSTM_CHUNK = args.mlstm_chunk

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    model = build_model(cfg, attn_impl=args.attn_impl)
    mesh = mesh_mod.make_local_mesh(model_axis=args.model_axis)
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}")

    state = init_train_state(model, jax.random.PRNGKey(0))
    params_sh = shard_rules.param_shardings(state["params"], mesh)
    state_sh = {
        "params": params_sh,
        "opt_state": {
            "mu": params_sh, "nu": params_sh,
            "step": shard_rules.replicated(mesh),
        },
        "step": shard_rules.replicated(mesh),
    }
    state = jax.device_put(state, state_sh)
    opt = AdamWConfig(lr=3e-4, warmup_steps=10, total_steps=args.steps)
    with mesh:
        step = jax.jit(
            make_train_step(model, opt, grad_accum=args.grad_accum),
            in_shardings=(state_sh, None),
            donate_argnums=(0,),
        )
        ckpt = CheckpointManager(args.ckpt_dir)
        start = 0
        if args.resume and ckpt.latest_step() is not None:
            like = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state
            )
            state = ckpt.restore(like, shardings=state_sh)
            start = int(state["step"])
            print(f"resumed at step {start}")
        stream = TokenStream(
            vocab=cfg.vocab, batch=args.batch, seq_len=args.seq_len,
            cfg=cfg, start_step=start,
        )
        data = PrefetchPipeline(stream)
        loop = TrainLoop(
            step, state, data,
            cfg=LoopConfig(total_steps=args.steps, checkpoint_every=25),
            checkpointer=ckpt,
        )
        loop.run()
        data.close()
    if loop.history:
        print(f"steps {loop.history[0]['step']}..{loop.history[-1]['step']}: "
              f"loss {loop.history[0]['loss']:.4f} -> "
              f"{loop.history[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
