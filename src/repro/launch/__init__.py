"""Launchers: mesh construction, the multi-pod dry-run driver, and the
train entry point.  (Serving lives in :mod:`repro.serve`.)"""
