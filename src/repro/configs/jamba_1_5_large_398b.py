"""jamba-1.5-large-398b [hybrid]: Mamba+attn 1:7 interleave, MoE.
[arXiv:2403.19887; hf]
72L d_model=8192 64H (kv=8) d_ff=24576 vocab=65536, MoE 16e top-2."""
from ..models.config import MambaConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="jamba-1.5-large-398b",
    family="hybrid_jamba",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab=65536,
    act="swiglu",
    norm="rmsnorm",
    rope_theta=0.0,          # jamba: no positional encoding (mamba provides order)
    attn_period=8,           # 1 attention layer per 8 (1:7)
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=24576, layout="odd"),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
)

SMOKE = ModelConfig(
    arch_id="jamba-1.5-large-398b-smoke",
    family="hybrid_jamba",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=96,
    vocab=128,
    act="swiglu",
    norm="rmsnorm",
    rope_theta=0.0,
    attn_period=4,
    moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=96, layout="odd"),
    mamba=MambaConfig(d_state=4, d_conv=4, expand=2),
    param_dtype="float32",
    compute_dtype="float32",
)
