"""Assigned input shapes and their applicability rules.

  train_4k     seq 4,096   global_batch 256   (training)
  prefill_32k  seq 32,768  global_batch 32    (inference prefill)
  decode_32k   seq 32,768  global_batch 128   (decode: 1 new token, KV cache)
  long_500k    seq 524,288 global_batch 1     (long-context decode)

``long_500k`` requires sub-quadratic attention: it runs only for the
SSM/hybrid families (xlstm, jamba); the skip for pure full-attention archs
is recorded in DESIGN.md and surfaced by :func:`applicable`.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str           # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def applicable(cfg: ModelConfig, shape_name: str) -> Optional[str]:
    """Return None if the (arch, shape) cell runs, else the skip reason."""
    spec = SHAPES[shape_name]
    if spec.name == "long_500k" and not cfg.subquadratic:
        return (
            "long_500k needs sub-quadratic attention; "
            f"{cfg.arch_id} is full-attention (see DESIGN.md)"
        )
    return None


def input_specs(cfg: ModelConfig, shape_name: str) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    For ``[audio]``/``[vlm]`` archs the modality frontend is a stub: specs
    provide precomputed frame embeddings / fused token ids directly.
    """
    spec = SHAPES[shape_name]
    B, T = spec.global_batch, spec.seq_len
    i32 = jnp.int32
    if spec.kind == "train":
        out = {
            "tokens": jax.ShapeDtypeStruct((B, T), i32),
            "labels": jax.ShapeDtypeStruct((B, T), i32),
        }
        if cfg.is_encdec:
            out["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.n_audio_frames, cfg.d_model),
                jnp.dtype(cfg.compute_dtype),
            )
        return out
    if spec.kind == "prefill":
        out = {"tokens": jax.ShapeDtypeStruct((B, T), i32)}
        if cfg.is_encdec:
            out["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.n_audio_frames, cfg.d_model),
                jnp.dtype(cfg.compute_dtype),
            )
        return out
    # decode: one new token against a seq_len cache
    out = {
        "token": jax.ShapeDtypeStruct((B,), i32),
        "cache_index": jax.ShapeDtypeStruct((), i32),
    }
    return out
