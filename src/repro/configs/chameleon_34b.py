"""chameleon-34b [vlm]: early-fusion, VQ image tokens (stubbed -- specs
deliver fused token ids).  [arXiv:2405.09818; unverified]
48L d_model=8192 64H (kv=8) d_ff=22016 vocab=65536."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab=65536,
    qk_norm=True,            # chameleon's qk-norm stabilization
    act="swiglu",
    norm="rmsnorm",
    rope_theta=10_000.0,
)

SMOKE = ModelConfig(
    arch_id="chameleon-34b-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=128,
    qk_norm=True,
    act="swiglu",
    norm="rmsnorm",
    param_dtype="float32",
    compute_dtype="float32",
)
