"""xlstm-125m [ssm]: sLSTM + mLSTM blocks.  [arXiv:2405.04517; unverified]
12L d_model=768 4H (kv=4) d_ff=0 vocab=50304 (no separate FFN; capacity
lives in the blocks' internal projections)."""
from ..models.config import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    arch_id="xlstm-125m",
    family="ssm_xlstm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    norm="rmsnorm",
    rope_theta=0.0,
    tie_embeddings=True,
    xlstm=XLSTMConfig(slstm_every=4),
)

SMOKE = ModelConfig(
    arch_id="xlstm-125m-smoke",
    family="ssm_xlstm",
    n_layers=4,
    d_model=32,
    n_heads=2,
    n_kv_heads=2,
    d_ff=0,
    vocab=96,
    norm="rmsnorm",
    rope_theta=0.0,
    tie_embeddings=True,
    xlstm=XLSTMConfig(slstm_every=4),
    param_dtype="float32",
    compute_dtype="float32",
)
