"""command-r-plus-104b [dense]: GQA, no-bias.
[hf:CohereForAI/c4ai-command-r-v01; unverified]
64L d_model=12288 96H (kv=8) d_ff=33792 vocab=256000."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=33792,
    vocab=256000,
    act="swiglu",
    norm="layernorm",
    rope_theta=75_000.0,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    arch_id="command-r-plus-104b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=128,
    act="swiglu",
    norm="layernorm",
    tie_embeddings=True,
    param_dtype="float32",
    compute_dtype="float32",
)
