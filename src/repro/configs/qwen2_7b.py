"""qwen2-7b [dense]: GQA, QKV bias.  [arXiv:2407.10671; hf]
28L d_model=3584 28H (kv=4) d_ff=18944 vocab=152064."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2-7b",
    family="dense",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab=152064,
    qkv_bias=True,
    act="swiglu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    arch_id="qwen2-7b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=128,
    qkv_bias=True,
    act="swiglu",
    norm="rmsnorm",
    param_dtype="float32",
    compute_dtype="float32",
)
