"""olmoe-1b-7b [moe]: 64 experts top-8.  [arXiv:2409.02060; hf]
16L d_model=2048 16H (kv=16) d_ff=1024 vocab=50304, MoE 64e top-8."""
from ..models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab=50304,
    act="swiglu",
    norm="rmsnorm",
    rope_theta=10_000.0,
    moe=MoEConfig(n_experts=64, top_k=8, d_ff_expert=1024),
)

SMOKE = ModelConfig(
    arch_id="olmoe-1b-7b-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=64,
    vocab=128,
    act="swiglu",
    norm="rmsnorm",
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=64),
    param_dtype="float32",
    compute_dtype="float32",
)
