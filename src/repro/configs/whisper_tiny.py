"""whisper-tiny [audio]: enc-dec, conv frontend stubbed.
[arXiv:2212.04356; unverified]  4L dec (+4L enc) d_model=384 6H (kv=6)
d_ff=1536 vocab=51865."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-tiny",
    family="encdec",
    n_layers=4,
    n_encoder_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab=51865,
    act="gelu",
    norm="layernorm",
    rope_theta=0.0,          # sinusoidal absolute positions
    tie_embeddings=True,
    n_audio_frames=1500,
)

SMOKE = ModelConfig(
    arch_id="whisper-tiny-smoke",
    family="encdec",
    n_layers=2,
    n_encoder_layers=2,
    d_model=32,
    n_heads=2,
    n_kv_heads=2,
    d_ff=64,
    vocab=96,
    act="gelu",
    norm="layernorm",
    rope_theta=0.0,
    tie_embeddings=True,
    n_audio_frames=12,
    param_dtype="float32",
    compute_dtype="float32",
)
