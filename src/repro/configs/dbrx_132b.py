"""dbrx-132b [moe]: 16 experts top-4, fine-grained.
[hf:databricks/dbrx-base; unverified]
40L d_model=6144 48H (kv=8) d_ff=10752 vocab=100352, MoE 16e top-4."""
from ..models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab=100352,
    act="swiglu",
    norm="layernorm",
    rope_theta=500_000.0,
    moe=MoEConfig(n_experts=16, top_k=4, d_ff_expert=10752),
)

SMOKE = ModelConfig(
    arch_id="dbrx-132b-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=96,
    vocab=128,
    act="swiglu",
    norm="layernorm",
    moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=96),
    param_dtype="float32",
    compute_dtype="float32",
)
