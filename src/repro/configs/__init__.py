"""Architecture registry: the 10 assigned architectures (+ the paper's
own CFD operator configs live in repro.cfd).

Use ``get(arch_id)`` for the full config and ``get_smoke(arch_id)`` for
the reduced same-family smoke config.
"""
from __future__ import annotations

from typing import Dict, List

from ..models.config import ModelConfig
from . import (
    chameleon_34b,
    command_r_plus_104b,
    dbrx_132b,
    internlm2_1_8b,
    jamba_1_5_large_398b,
    olmoe_1b_7b,
    qwen2_7b,
    qwen3_14b,
    shapes,
    whisper_tiny,
    xlstm_125m,
)

_MODULES = {
    "whisper-tiny": whisper_tiny,
    "command-r-plus-104b": command_r_plus_104b,
    "internlm2-1.8b": internlm2_1_8b,
    "qwen3-14b": qwen3_14b,
    "qwen2-7b": qwen2_7b,
    "dbrx-132b": dbrx_132b,
    "olmoe-1b-7b": olmoe_1b_7b,
    "xlstm-125m": xlstm_125m,
    "jamba-1.5-large-398b": jamba_1_5_large_398b,
    "chameleon-34b": chameleon_34b,
}

ARCH_IDS: List[str] = list(_MODULES)


def get(arch_id: str) -> ModelConfig:
    return _MODULES[arch_id].CONFIG


def get_smoke(arch_id: str) -> ModelConfig:
    return _MODULES[arch_id].SMOKE


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get(a) for a in ARCH_IDS}
