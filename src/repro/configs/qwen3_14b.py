"""qwen3-14b [dense]: qk_norm, GQA.  [hf:Qwen/Qwen3-8B; hf]
40L d_model=5120 40H (kv=8) d_ff=17408 vocab=151936."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen3-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=17408,
    vocab=151936,
    head_dim=128,
    qk_norm=True,
    act="swiglu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    arch_id="qwen3-14b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=128,
    head_dim=16,
    qk_norm=True,
    act="swiglu",
    norm="rmsnorm",
    param_dtype="float32",
    compute_dtype="float32",
)
