"""Parameter / activation sharding rules (GSPMD PartitionSpecs).

Megatron-style TP over the ``model`` axis, DP over ``pod``+``data``:

  * embeddings & LM head: vocab-sharded (vocab-parallel cross entropy
    falls out of GSPMD's handling of the sharded log_softmax reductions);
  * attention: head-sharded QKV (column) / output row-sharded;
  * MLP: column-parallel up/gate, row-parallel down;
  * MoE: expert-parallel (experts over ``model``) -- dispatch/combine
    scatter-gathers become all_to_all;
  * mamba/xLSTM: inner-dim column/row split, state sharded on the inner
    dim;
  * norms/scalars: replicated.

Rules are matched against flattened parameter path names, and specs are
left-padded with None to the leaf rank (stacked-layer leading axes stay
unsharded).
"""
from __future__ import annotations

import re
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig

#: (path regex, spec for trailing dims)
PARAM_RULES: List[Tuple[str, Tuple]] = [
    # embeddings / head
    (r"embed/tok$", ("model", None)),
    (r"head/w$", (None, "model")),
    # attention
    (r"(attn|self_attn|cross_attn)/wq/w$", (None, "model")),
    (r"(attn|self_attn|cross_attn)/wk/w$", (None, "model")),
    (r"(attn|self_attn|cross_attn)/wv/w$", (None, "model")),
    (r"(attn|self_attn|cross_attn)/w[qkv]/b$", ("model",)),
    (r"(attn|self_attn|cross_attn)/wo/w$", ("model", None)),
    (r"(attn|self_attn|cross_attn)/wo/b$", (None,)),
    (r"(q_norm|k_norm)/scale$", (None,)),
    # dense MLP
    (r"mlp/(gate|up)/w$", (None, "model")),
    (r"mlp/(gate|up)/b$", ("model",)),
    (r"mlp/down/w$", ("model", None)),
    (r"mlp/down/b$", (None,)),
    # MoE: expert parallel
    (r"moe/router/w$", (None, None)),
    (r"moe/w_(gate|up)$", ("model", None, None)),
    (r"moe/w_down$", ("model", None, None)),
    # mamba
    (r"mamba/in_proj/w$", (None, "model")),
    (r"mamba/conv_w$", (None, "model")),
    (r"mamba/conv_b$", ("model",)),
    (r"mamba/x_proj/w$", ("model", None)),
    (r"mamba/dt_proj/w$", (None, "model")),
    (r"mamba/dt_proj/b$", ("model",)),
    (r"mamba/A_log$", ("model", None)),
    (r"mamba/D$", ("model",)),
    (r"mamba/out_proj/w$", ("model", None)),
    # xLSTM
    (r"core/w[zqkv]/w$", (None, "model")),
    (r"core/w(i|f|o_gate)/w$", (None, "model")),
    (r"core/w(i|f|o_gate|z|q|k|v)/b$", ("model",)),
    (r"core/wo/w$", ("model", None)),
    # norms and anything else scalar-ish: replicated (fallback below)
]


#: when True (set by the dry-run --dp-only), params replicate and the
#: batch shards over EVERY mesh axis -- the right mapping for models too
#: small to amortize TP collectives (see EXPERIMENTS.md section Perf).
DP_ONLY = False


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def spec_for_param(path_str: str, ndim: int, mesh: Mesh) -> P:
    if DP_ONLY:
        return P()
    axis_ok = set(mesh.axis_names)
    for pat, trailing in PARAM_RULES:
        if re.search(pat, path_str):
            t = tuple(a if (a in axis_ok) else None for a in trailing)
            pad = (None,) * (ndim - len(t))
            return P(*(pad + t))
    return P()  # replicated


def _divisible(shape, spec: P, mesh: Mesh) -> P:
    """Drop sharding on axes that do not divide evenly (e.g. 6 heads on a
    16-way model axis for whisper-tiny): correctness first, GSPMD would
    otherwise error."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    fixed = []
    for dim, s in zip(shape, tuple(spec) + (None,) * (len(shape) - len(tuple(spec)))):
        if s is None:
            fixed.append(None)
            continue
        axes = s if isinstance(s, tuple) else (s,)
        total = int(np.prod([sizes[a] for a in axes]))
        fixed.append(s if dim % total == 0 else None)
    return P(*fixed)


def param_shardings(params_shape: Any, mesh: Mesh) -> Any:
    """Map a params pytree (of ShapeDtypeStruct or arrays) to NamedShardings."""

    def one(path, leaf):
        ps = _path_str(path)
        spec = spec_for_param(ps, len(leaf.shape), mesh)
        spec = _divisible(leaf.shape, spec, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_shape)


def batch_shardings(batch_shape: Any, mesh: Mesh) -> Any:
    """Shard the leading (global-batch) axis over the DP axes."""
    dp = (tuple(mesh.axis_names) if DP_ONLY
          else tuple(a for a in mesh.axis_names if a in ("pod", "data")))

    def one(leaf):
        if len(leaf.shape) == 0:
            return NamedSharding(mesh, P())
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        total = int(np.prod([sizes[a] for a in dp]))
        if leaf.shape[0] % total == 0:
            return NamedSharding(
                mesh, P(dp, *([None] * (len(leaf.shape) - 1)))
            )
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map(one, batch_shape)


def cache_shardings(cache_shape: Any, cfg: ModelConfig, mesh: Mesh,
                    *, batch: int) -> Any:
    """KV-cache / recurrent-state shardings for serving.

    Preference order per leaf: shard batch over DP if divisible; shard the
    kv-head axis over ``model`` if divisible; otherwise shard the longest
    (sequence) axis over ``model`` (flash-decoding combine), else
    replicate.  For batch=1 long-context decode this naturally picks the
    sequence axis.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dp_total = int(np.prod([sizes[a] for a in dp]))
    m = sizes.get("model", 1)

    def one(path, leaf):
        shape = leaf.shape
        if len(shape) == 0:
            return NamedSharding(mesh, P())
        spec: List = [None] * len(shape)
        # find the batch axis: the first axis equal to `batch`
        b_ax = next((i for i, d in enumerate(shape) if d == batch), None)
        used_model = False
        if b_ax is not None and batch % dp_total == 0 and batch >= dp_total:
            spec[b_ax] = dp
        # kv-head / feature axis over model: prefer an axis == n_kv_heads
        for i, d in enumerate(shape):
            if i == b_ax:
                continue
            if d == cfg.n_kv_heads and d % m == 0:
                spec[i] = "model"
                used_model = True
                break
        if not used_model:
            # longest remaining axis over model (sequence, inner dim, ...)
            cand = max(
                (d, i) for i, d in enumerate(shape) if i != b_ax
            )[1] if len(shape) > (0 if b_ax is None else 1) else None
            if cand is not None and shape[cand] % m == 0 and shape[cand] >= m:
                spec[cand] = "model"
        # batch not shardable over full dp: try just "data"
        if b_ax is not None and spec[b_ax] is None:
            d_sz = sizes.get("data", 1)
            if batch % d_sz == 0 and batch >= d_sz:
                spec[b_ax] = "data"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def extend_with_dp(shardings: Any, shapes: Any, mesh: Mesh) -> Any:
    """Add data-parallel sharding on top of the TP specs (ZeRO/FSDP).

    For each leaf, the first dimension that is still unsharded and divides
    by the DP degree gets the DP axes.  Used for optimizer moments
    (ZeRO-1) and for weight-gathered serving of very large models: the
    stacked-layer leading axis usually absorbs it (e.g. 64 layers over 16
    data shards), otherwise a feature dim does.
    """
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp_total = int(np.prod([sizes[a] for a in dp]))

    def one(sh, leaf):
        spec = list(tuple(sh.spec) + (None,) * (len(leaf.shape) - len(tuple(sh.spec))))
        for i, d in enumerate(leaf.shape):
            if spec[i] is None and d % dp_total == 0 and d >= dp_total:
                spec[i] = dp if len(dp) > 1 else dp[0]
                break
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map(one, shardings, shapes)


def params_fit_replicated_dp(params_shape: Any, mesh: Mesh,
                             hbm_budget: int = 8 * 2 ** 30) -> bool:
    """True if TP-only params fit the per-chip budget (else use FSDP)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    m = sizes.get("model", 1)
    total = sum(
        int(np.prod(l.shape)) * jax.numpy.dtype(l.dtype).itemsize
        for l in jax.tree_util.tree_leaves(params_shape)
    )
    return total / m <= hbm_budget
