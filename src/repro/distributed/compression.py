"""Gradient compression: int8 quantized all-reduce with error feedback.

Cross-pod (DCN) gradient reduction is the bandwidth-constrained collective
at 1000+-node scale; 4x compression there is a standard distributed-
optimization trick.  Design:

  * per-tensor symmetric int8 quantization (scale = max|g| / 127);
  * error feedback: the quantization residual is carried into the next
    step's gradient (Karimireddy et al.), keeping SGD/Adam convergence;
  * the reduce itself runs in int32 to avoid overflow, then dequantizes.

``compressed_psum`` is used inside shard_map over the ``pod`` axis by the
explicit-DP train-step variant (runtime/train.py); the default GSPMD path
leaves reduction to XLA and skips compression.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_with_feedback(g: jax.Array, err: jax.Array):
    """Returns (q, scale, new_err)."""
    g_corr = g + err
    q, scale = quantize(g_corr)
    new_err = g_corr - dequantize(q, scale)
    return q, scale, new_err


def compressed_psum(g: jax.Array, err: jax.Array, axis: str):
    """int8-quantized psum over ``axis`` with error feedback.

    Scales are psum-maxed first so every participant uses a common scale;
    the int reduce then runs losslessly in int32.
    """
    g_corr = g + err
    local_scale = jnp.maximum(jnp.max(jnp.abs(g_corr)), 1e-30) / 127.0
    scale = jax.lax.pmax(local_scale, axis)
    q = jnp.clip(jnp.round(g_corr / scale), -127, 127).astype(jnp.int8)
    new_err = g_corr - q.astype(jnp.float32) * scale
    total = jax.lax.psum(q.astype(jnp.int32), axis)
    n = jax.lax.psum(jnp.ones((), jnp.int32), axis)
    mean = total.astype(jnp.float32) * scale / n.astype(jnp.float32)
    return mean.astype(g.dtype), new_err


def tree_compressed_psum(grads: Any, errs: Any, axis: str):
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(errs)
    out_g, out_e = [], []
    for g, e in zip(flat_g, flat_e):
        m, ne = compressed_psum(g, e, axis)
        out_g.append(m)
        out_e.append(ne)
    return treedef.unflatten(out_g), treedef.unflatten(out_e)


def init_error_feedback(grads_shape: Any) -> Any:
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, jnp.float32), grads_shape
    )
