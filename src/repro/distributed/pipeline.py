"""Pipeline parallelism (GPipe schedule) via shard_map + ppermute.

The uniform decoder's stacked blocks (L, ...) are sharded over a ``stage``
mesh axis (typically the ``pod`` axis: PP across the slow DCN links is the
classic multi-pod layout, keeping high-bandwidth TP inside a pod).

Schedule: M microbatches through S stages in M + S - 1 ticks.  Every tick,
activations hop stage i -> i+1 with ppermute; stage 0 feeds new
microbatches; the last stage collects outputs.  Bubble fraction is
(S-1)/(M+S-1) -- the launcher picks M >= 4*S by default.

This module is deliberately generic: it takes any ``block_apply``-style
stage function, so tests drive it with tiny MLPs and the launcher can wrap
transformer blocks.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import inspect

import jax
import jax.numpy as jnp

try:  # jax <= 0.4.x ships shard_map under experimental
    from jax.experimental.shard_map import shard_map as _shard_map
except ImportError:  # newer jax promoted it to the top level
    from jax import shard_map as _shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

#: replication-check kwarg was renamed check_rep -> check_vma
_CHECK_KW = (
    "check_vma"
    if "check_vma" in inspect.signature(_shard_map).parameters
    else "check_rep"
)


def shard_map(f, *args, check_vma=None, **kwargs):
    """Version-portable ``shard_map``: forwards positionals untouched and
    renames the replication-check kwarg to whatever this jax expects."""
    if check_vma is not None:
        kwargs[_CHECK_KW] = check_vma
    return _shard_map(f, *args, **kwargs)


def pipeline_forward(
    stage_fn: Callable[[Any, jax.Array], jax.Array],
    stage_params: Any,
    x: jax.Array,                       # (M, mb, ...) microbatched input
    *,
    mesh: Mesh,
    stage_axis: str = "pod",
) -> jax.Array:
    """Run x through S pipeline stages; returns (M, mb, ...) outputs.

    ``stage_params`` leaves must have a leading stage axis of size S
    (sharded over ``stage_axis``); ``stage_fn(local_params, x)`` applies
    one stage's layers.
    """
    S = mesh.shape[stage_axis]
    M = x.shape[0]

    def per_stage(params_local, x_local):
        # params_local leaves: (1, ...) -- this stage's slice
        params_here = jax.tree.map(lambda a: a[0], params_local)
        stage = jax.lax.axis_index(stage_axis)
        mb_shape = x_local.shape[1:]
        out_buf = jnp.zeros((M,) + mb_shape, x_local.dtype)
        carry = jnp.zeros(mb_shape, x_local.dtype)

        def tick(t, state):
            carry, out_buf = state
            # stage 0 ingests microbatch t (if any); others take the wire
            mb_idx = jnp.clip(t, 0, M - 1)
            fresh = jax.lax.dynamic_index_in_dim(
                x_local, mb_idx, axis=0, keepdims=False
            )
            inp = jnp.where(stage == 0, fresh, carry)
            active = (t - stage >= 0) & (t - stage < M)
            y = stage_fn(params_here, inp)
            y = jnp.where(active, y, jnp.zeros_like(y))
            # collect on the last stage
            out_idx = jnp.clip(t - stage, 0, M - 1)
            collect = active & (stage == S - 1)
            cur = jax.lax.dynamic_index_in_dim(
                out_buf, out_idx, axis=0, keepdims=False
            )
            upd = jnp.where(collect, y, cur)
            out_buf = jax.lax.dynamic_update_index_in_dim(
                out_buf, upd, out_idx, axis=0
            )
            # ship activations forward (ring; last->0 ignored)
            perm = [(i, (i + 1) % S) for i in range(S)]
            carry = jax.lax.ppermute(y, stage_axis, perm)
            return (carry, out_buf)

        carry, out_buf = jax.lax.fori_loop(
            0, M + S - 1, tick, (carry, out_buf)
        )
        # broadcast the last stage's outputs to every stage (psum of a
        # single non-zero contribution; ppermute requires unique sources)
        contrib = jnp.where(stage == S - 1, out_buf, jnp.zeros_like(out_buf))
        return jax.lax.psum(contrib, stage_axis)

    other_axes = tuple(a for a in mesh.axis_names if a != stage_axis)
    pspec = jax.tree.map(
        lambda a: P(stage_axis, *([None] * (a.ndim - 1))), stage_params
    )
    return shard_map(
        per_stage,
        mesh=mesh,
        in_specs=(pspec, P()),
        out_specs=P(),
        check_vma=False,
    )(stage_params, x)


def microbatch(x: jax.Array, n: int) -> jax.Array:
    """(B, ...) -> (n, B/n, ...)"""
    B = x.shape[0]
    if B % n:
        raise ValueError(f"batch {B} not divisible into {n} microbatches")
    return x.reshape((n, B // n) + x.shape[1:])


def unmicrobatch(x: jax.Array) -> jax.Array:
    return x.reshape((-1,) + x.shape[2:])
