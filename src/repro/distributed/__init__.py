"""Distribution layer: sharding rules (DP/TP/EP/SP), pipeline parallelism,
and gradient compression."""
from . import compression, pipeline, sharding

__all__ = ["compression", "pipeline", "sharding"]
