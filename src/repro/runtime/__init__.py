"""Training/serving runtime: step builders, fault-tolerant loop,
monitoring."""
from . import losses, monitor, serve, train

__all__ = ["losses", "monitor", "serve", "train"]
