"""Training/serving runtime: step builders, fault-tolerant loop,
monitoring."""
from . import losses, monitor, train

__all__ = ["losses", "monitor", "train"]
