"""Losses.  Cross entropy is written as plain log_softmax so that with a
vocab-sharded head GSPMD lowers the reductions into partial-reduce +
all-reduce (vocab-parallel CE) -- no bespoke collective code needed."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  ignore_id: int = -1) -> jax.Array:
    """logits: (B, T, V) f32; labels: (B, T) int32.

    The label pick is a masked reduction (iota == label) rather than a
    gather: with a vocab-sharded V axis, take_along_axis forces GSPMD to
    all-gather the full logits, while the masked sum keeps every term a
    partial-reduce + scalar all-reduce (vocab-parallel CE)."""
    lf = logits.astype(jnp.float32)
    m = jnp.max(lf, axis=-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]
    V = lf.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
    picked = jnp.sum(
        jnp.where(iota == labels[..., None], lf, 0.0), axis=-1
    )
    ll = picked - lse
    mask = (labels != ignore_id).astype(jnp.float32)
    return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def next_token_loss(logits: jax.Array, tokens: jax.Array) -> jax.Array:
    """Shifted LM loss when only tokens are provided."""
    return cross_entropy(logits[:, :-1], tokens[:, 1:])
