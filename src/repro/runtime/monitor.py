"""Step-time monitoring: straggler detection + elastic re-mesh hooks.

At pod scale, a slow host (thermal throttling, failing NIC) shows up as a
step-time outlier on every worker because SPMD steps are synchronous.  The
monitor keeps an EWMA of step time and flags steps slower than
``straggler_factor`` x EWMA; the runtime's ``on_straggler`` hook can then
evict the host / trigger elastic re-meshing (``plan_elastic_remesh``).

:class:`RequestLatency` is the serving-side sibling: per-request
submit-to-complete latency, summarized over a bounded recent window so a
long-lived ``repro.serve`` engine can report p50/p95 without unbounded
history.  Both delegate their distribution bookkeeping to
:class:`repro.metrics.Histogram` -- one quantile implementation in the
codebase, shared with the always-on metrics layer.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from ..metrics import Histogram


@dataclasses.dataclass
class StepMonitor:
    straggler_factor: float = 3.0
    alpha: float = 0.1            # EWMA weight
    warmup: int = 3               # ignore compile-dominated first steps
    #: EWMA weight on *flagged* steps: damped so one outlier cannot poison
    #: the mean, but nonzero so a persistent slowdown eventually moves the
    #: baseline instead of flagging every step forever.
    flagged_alpha: float = 0.02

    def __post_init__(self) -> None:
        self.ewma: Optional[float] = None
        self.count = 0
        self.flags: List[int] = []
        #: every recorded step time (warmup included) -- the flag-stat
        #: summary and any external scrape read quantiles off this
        self.steps = Histogram(name="step_seconds")

    def record(self, dt: float) -> bool:
        self.count += 1
        self.steps.observe(dt)
        if self.count <= self.warmup:
            return False
        if self.ewma is None:
            self.ewma = dt
            return False
        flagged = dt > self.straggler_factor * self.ewma
        w = self.flagged_alpha if flagged else self.alpha
        self.ewma = (1 - w) * self.ewma + w * dt
        if flagged:
            self.flags.append(self.count)
        return flagged

    def summary(self) -> Dict[str, float]:
        """Step-time distribution plus flag stats, histogram-backed."""
        s = self.steps.summary()
        return {
            "count": float(self.count),
            "mean_s": s.get("mean", 0.0),
            "p50_s": s.get("p50", 0.0),
            "p95_s": s.get("p95", 0.0),
            "max_s": s.get("max", 0.0),
            "flagged": float(len(self.flags)),
            "flag_rate": len(self.flags) / self.count if self.count else 0.0,
        }


@dataclasses.dataclass
class RequestLatency:
    """Submit-to-complete latency tracker for the serving engine.

    Exact count/mean/max over the whole run; percentiles over the most
    recent ``window`` requests (a serving engine outlives any full-
    history quantile structure worth carrying here).  A thin facade over
    :class:`repro.metrics.Histogram` -- same counts, same window, same
    nearest-rank quantile -- kept for its serving-flavored ``summary()``
    keys and so callers need no registry.
    """

    window: int = 1024

    def __post_init__(self) -> None:
        self._hist = Histogram(
            name="request_latency_seconds", window=self.window
        )

    def record(self, latency_s: float) -> None:
        self._hist.observe(latency_s)

    @property
    def count(self) -> int:
        return self._hist.count

    @property
    def total_s(self) -> float:
        return self._hist.sum

    @property
    def max_s(self) -> float:
        return self._hist.max if self._hist.count else 0.0

    def quantile(self, q: float) -> float:
        """q-quantile (nearest-rank) over the recent window; 0 if empty."""
        return self._hist.quantile(q)

    def summary(self) -> Dict[str, float]:
        return {
            "count": float(self.count),
            "mean_s": self.total_s / self.count if self.count else 0.0,
            "p50_s": self.quantile(0.50),
            "p95_s": self.quantile(0.95),
            "max_s": self.max_s,
        }


def plan_elastic_remesh(
    n_healthy: int, *, model_axis: int
) -> Tuple[int, ...]:
    """Given the surviving device count, pick the largest (data, model)
    mesh that preserves the TP degree (params reshard along data only --
    cheapest recovery path).  Returns the new mesh shape.

    E.g. 256 devices, model=16 -> (16, 16); after losing a host of 8:
    248 -> (15, 16) needs 240; we round data down.
    """
    if n_healthy < model_axis:
        raise ValueError("fewer devices than the TP degree: cold restart")
    data = n_healthy // model_axis
    return (data, model_axis)


def rebalance_batch(global_batch: int, data_axis: int) -> int:
    """Largest per-step batch divisible by the new data axis (keeps the
    optimizer's effective batch as close as possible after re-meshing)."""
    return (global_batch // data_axis) * data_axis
