"""Step-time monitoring: straggler detection + elastic re-mesh hooks.

At pod scale, a slow host (thermal throttling, failing NIC) shows up as a
step-time outlier on every worker because SPMD steps are synchronous.  The
monitor keeps an EWMA of step time and flags steps slower than
``straggler_factor`` x EWMA; the runtime's ``on_straggler`` hook can then
evict the host / trigger elastic re-meshing (``plan_elastic_remesh``).

:class:`RequestLatency` is the serving-side sibling: per-request
submit-to-complete latency, summarized over a bounded recent window so a
long-lived ``repro.serve`` engine can report p50/p95 without unbounded
history.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass
class StepMonitor:
    straggler_factor: float = 3.0
    alpha: float = 0.1            # EWMA weight
    warmup: int = 3               # ignore compile-dominated first steps
    #: EWMA weight on *flagged* steps: damped so one outlier cannot poison
    #: the mean, but nonzero so a persistent slowdown eventually moves the
    #: baseline instead of flagging every step forever.
    flagged_alpha: float = 0.02

    def __post_init__(self) -> None:
        self.ewma: Optional[float] = None
        self.count = 0
        self.flags: List[int] = []

    def record(self, dt: float) -> bool:
        self.count += 1
        if self.count <= self.warmup:
            return False
        if self.ewma is None:
            self.ewma = dt
            return False
        flagged = dt > self.straggler_factor * self.ewma
        w = self.flagged_alpha if flagged else self.alpha
        self.ewma = (1 - w) * self.ewma + w * dt
        if flagged:
            self.flags.append(self.count)
        return flagged


@dataclasses.dataclass
class RequestLatency:
    """Submit-to-complete latency tracker for the serving engine.

    Exact count/mean/max over the whole run; percentiles over the most
    recent ``window`` requests (a serving engine outlives any full-
    history quantile structure worth carrying here).
    """

    window: int = 1024

    def __post_init__(self) -> None:
        self.count = 0
        self.total_s = 0.0
        self.max_s = 0.0
        self._recent: deque = deque(maxlen=self.window)

    def record(self, latency_s: float) -> None:
        self.count += 1
        self.total_s += latency_s
        self.max_s = max(self.max_s, latency_s)
        self._recent.append(latency_s)

    def quantile(self, q: float) -> float:
        """q-quantile (nearest-rank) over the recent window; 0 if empty."""
        if not self._recent:
            return 0.0
        xs = sorted(self._recent)
        return xs[min(len(xs) - 1, int(q * len(xs)))]

    def summary(self) -> Dict[str, float]:
        return {
            "count": float(self.count),
            "mean_s": self.total_s / self.count if self.count else 0.0,
            "p50_s": self.quantile(0.50),
            "p95_s": self.quantile(0.95),
            "max_s": self.max_s,
        }


def plan_elastic_remesh(
    n_healthy: int, *, model_axis: int
) -> Tuple[int, ...]:
    """Given the surviving device count, pick the largest (data, model)
    mesh that preserves the TP degree (params reshard along data only --
    cheapest recovery path).  Returns the new mesh shape.

    E.g. 256 devices, model=16 -> (16, 16); after losing a host of 8:
    248 -> (15, 16) needs 240; we round data down.
    """
    if n_healthy < model_axis:
        raise ValueError("fewer devices than the TP degree: cold restart")
    data = n_healthy // model_axis
    return (data, model_axis)


def rebalance_batch(global_batch: int, data_axis: int) -> int:
    """Largest per-step batch divisible by the new data axis (keeps the
    optimizer's effective batch as close as possible after re-meshing)."""
    return (global_batch // data_axis) * data_axis
