"""Step-time monitoring: straggler detection + elastic re-mesh hooks.

At pod scale, a slow host (thermal throttling, failing NIC) shows up as a
step-time outlier on every worker because SPMD steps are synchronous.  The
monitor keeps an EWMA of step time and flags steps slower than
``straggler_factor`` x EWMA; the runtime's ``on_straggler`` hook can then
evict the host / trigger elastic re-meshing (``plan_elastic_remesh``).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple


@dataclasses.dataclass
class StepMonitor:
    straggler_factor: float = 3.0
    alpha: float = 0.1            # EWMA weight
    warmup: int = 3               # ignore compile-dominated first steps
    #: EWMA weight on *flagged* steps: damped so one outlier cannot poison
    #: the mean, but nonzero so a persistent slowdown eventually moves the
    #: baseline instead of flagging every step forever.
    flagged_alpha: float = 0.02

    def __post_init__(self) -> None:
        self.ewma: Optional[float] = None
        self.count = 0
        self.flags: List[int] = []

    def record(self, dt: float) -> bool:
        self.count += 1
        if self.count <= self.warmup:
            return False
        if self.ewma is None:
            self.ewma = dt
            return False
        flagged = dt > self.straggler_factor * self.ewma
        w = self.flagged_alpha if flagged else self.alpha
        self.ewma = (1 - w) * self.ewma + w * dt
        if flagged:
            self.flags.append(self.count)
        return flagged


def plan_elastic_remesh(
    n_healthy: int, *, model_axis: int
) -> Tuple[int, ...]:
    """Given the surviving device count, pick the largest (data, model)
    mesh that preserves the TP degree (params reshard along data only --
    cheapest recovery path).  Returns the new mesh shape.

    E.g. 256 devices, model=16 -> (16, 16); after losing a host of 8:
    248 -> (15, 16) needs 240; we round data down.
    """
    if n_healthy < model_axis:
        raise ValueError("fewer devices than the TP degree: cold restart")
    data = n_healthy // model_axis
    return (data, model_axis)


def rebalance_batch(global_batch: int, data_axis: int) -> int:
    """Largest per-step batch divisible by the new data axis (keeps the
    optimizer's effective batch as close as possible after re-meshing)."""
    return (global_batch // data_axis) * data_axis
