"""Serving runtime: prefill/decode steps + a slot-based batch scheduler.

The scheduler is a small continuous-batching engine: requests claim cache
slots; each engine tick runs one batched decode step over every active
slot; finished slots are recycled and newly queued prompts are prefilled
into free slots.  Prefill and decode are separate jitted programs
(the assigned ``prefill_32k`` / ``decode_32k`` shapes lower exactly these
two step functions).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.api import Model


def make_prefill_step(model: Model, *, moe_capacity=None) -> Callable:
    def prefill(params, batch, cache):
        return model.prefill(params, batch, cache, moe_capacity=moe_capacity)

    return prefill


def make_decode_step(model: Model, *, moe_capacity=None) -> Callable:
    def decode(params, token, cache, cache_index):
        return model.decode_step(
            params, token, cache, cache_index, moe_capacity=moe_capacity
        )

    return decode


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (T,) int32
    max_new_tokens: int = 16
    frames: Optional[np.ndarray] = None
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Single-host batched serving over a fixed slot count.

    For simplicity each slot has its own cache (batch axis of the shared
    cache pytree); prompts in one admission wave are padded to a common
    length and prefilled together.
    """

    def __init__(self, model: Model, params, *, slots: int = 4,
                 max_len: int = 256, temperature: float = 0.0) -> None:
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.temperature = temperature
        self.cache = model.init_cache(slots, max_len)
        # identify each cache leaf's slot axis structurally (leaf sizes can
        # collide with the slot count, e.g. n_layers == slots)
        sa = jax.eval_shape(lambda: model.init_cache(slots, max_len))
        sb = jax.eval_shape(lambda: model.init_cache(slots + 1, max_len))
        self._slot_axis = jax.tree.map(
            lambda a, b: next(
                (i for i, (x, y) in enumerate(zip(a.shape, b.shape))
                 if x != y), None,
            ),
            sa, sb,
        )
        self._slot_axis_leaves = jax.tree.leaves(self._slot_axis)
        self.lengths = np.zeros(slots, np.int32)
        self.active: List[Optional[Request]] = [None] * slots
        self.queue: List[Request] = []
        self._prefill = jax.jit(make_prefill_step(model))
        self._decode = jax.jit(make_decode_step(model))
        self._next_tok = np.zeros(slots, np.int32)

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        free = [i for i, a in enumerate(self.active) if a is None]
        wave = []
        while free and self.queue:
            slot = free.pop(0)
            req = self.queue.pop(0)
            self.active[slot] = req
            wave.append((slot, req))
        if not wave:
            return
        # pad the wave to a common prompt length, prefill slot-by-slot
        # (per-slot prefill keeps cache indices exact; a production engine
        # would batch same-length buckets)
        for slot, req in wave:
            T = len(req.prompt)
            tokens = jnp.asarray(req.prompt[None, :], jnp.int32)
            batch = {"tokens": tokens}
            if req.frames is not None:
                batch["frames"] = jnp.asarray(req.frames[None])
            one_cache = self.model.init_cache(1, self.max_len)
            logits, one_cache = self._prefill(self.params, batch, one_cache)
            self._write_slot(one_cache, slot)
            self.lengths[slot] = T
            self._next_tok[slot] = int(jnp.argmax(logits[0]))

    def _write_slot(self, one_cache, slot: int) -> None:
        flat_full, treedef = jax.tree.flatten(self.cache)
        flat_one = treedef.flatten_up_to(one_cache)

        out = []
        for full, one, ax in zip(
            flat_full, flat_one, self._slot_axis_leaves
        ):
            if ax is None:
                out.append(full)
                continue
            out.append(
                jax.lax.dynamic_update_slice_in_dim(
                    full, jax.numpy.asarray(one, full.dtype), slot, axis=ax
                )
            )
        self.cache = treedef.unflatten(out)

    def step(self) -> None:
        """One engine tick: admit new requests, decode all active slots."""
        self._admit()
        live = [i for i, a in enumerate(self.active) if a is not None]
        if not live:
            return
        # batched decode over all slots at their own cache positions
        # (continuous batching; idle slots write to their stale position,
        # harmless since their outputs are discarded)
        idx = jnp.asarray(self.lengths, jnp.int32)
        tok = jnp.asarray(self._next_tok, jnp.int32)
        logits, self.cache = self._decode(self.params, tok, self.cache, idx)
        nxt = np.asarray(jnp.argmax(logits, -1), np.int32)
        for i in live:
            req = self.active[i]
            req.output.append(int(self._next_tok[i]))
            self.lengths[i] += 1
            self._next_tok[i] = nxt[i]
            if (
                len(req.output) >= req.max_new_tokens
                or self.lengths[i] >= self.max_len - 1
            ):
                req.done = True
                self.active[i] = None

    def run_until_drained(self, max_ticks: int = 1000) -> None:
        for _ in range(max_ticks):
            if not self.queue and all(a is None for a in self.active):
                return
            self.step()
