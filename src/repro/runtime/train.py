"""Train-step builders and the fault-tolerant training loop.

``make_train_step``: the canonical GSPMD path -- one jitted step, params
sharded per distributed.sharding rules, gradient reduction left to XLA
(reduce_scatter/all_reduce over the DP axes).

``make_compressed_train_step``: explicit cross-pod DP via shard_map with
int8 error-feedback gradient compression on the ``pod`` axis (the DCN
bandwidth saver, DESIGN.md section 5).

``TrainLoop``: checkpoint/restart, straggler monitoring, preemption-signal
handling, and resumable data -- the pieces that make the thing runnable on
a real cluster rather than a notebook.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.api import Model
from ..optim import AdamWConfig, adamw_init, adamw_update
from . import losses
from .monitor import StepMonitor


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------


def make_loss_fn(model: Model, *, moe_capacity: Optional[int] = None):
    def loss_fn(params, batch):
        logits = model.forward(params, batch, moe_capacity=moe_capacity)
        if "labels" in batch:
            return losses.cross_entropy(logits, batch["labels"])
        return losses.next_token_loss(logits, batch["tokens"])

    return loss_fn


def make_train_step(
    model: Model,
    opt_cfg: AdamWConfig,
    *,
    moe_capacity: Optional[int] = None,
    grad_accum: int = 1,
) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics)."""
    loss_fn = make_loss_fn(model, moe_capacity=moe_capacity)

    def one_grad(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch)

    def train_step(state: Dict[str, Any], batch: Dict[str, jax.Array]):
        params = state["params"]
        if grad_accum == 1:
            loss, grads = one_grad(params, batch)
        else:
            # microbatch accumulation: lets XLA overlap grad collectives
            # of microbatch k with compute of k+1
            def split(x):
                return x.reshape((grad_accum, -1) + x.shape[1:])

            mbatches = jax.tree.map(split, batch)

            def body(carry, mb):
                acc_loss, acc_grads = carry
                l, g = one_grad(params, mb)
                return (
                    acc_loss + l,
                    jax.tree.map(jnp.add, acc_grads, g),
                ), None

            zero_grads = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), zero_grads), mbatches
            )
            loss = loss / grad_accum
            grads = jax.tree.map(lambda g: g / grad_accum, grads)

        new_params, new_opt, metrics = adamw_update(
            opt_cfg, grads, state["opt_state"], params
        )
        new_state = {
            "params": new_params,
            "opt_state": new_opt,
            "step": state["step"] + 1,
        }
        metrics = dict(metrics, loss=loss)
        return new_state, metrics

    return train_step


def init_train_state(model: Model, key: jax.Array) -> Dict[str, Any]:
    params = model.init(key)
    return {
        "params": params,
        "opt_state": adamw_init(params),
        "step": jnp.zeros((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# fault-tolerant loop
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    log_every: int = 10
    straggler_factor: float = 3.0   # flag steps slower than f x EWMA
    max_retries: int = 2            # per-step retry on transient failure


class PreemptionGuard:
    """SIGTERM -> finish the current step, checkpoint, exit cleanly."""

    def __init__(self) -> None:
        self.requested = False
        try:
            self._prev = signal.signal(signal.SIGTERM, self._handler)
        except ValueError:  # non-main thread (tests)
            self._prev = None

    def _handler(self, signum, frame):  # pragma: no cover - signal path
        self.requested = True


class TrainLoop:
    def __init__(
        self,
        train_step: Callable,
        state: Dict[str, Any],
        data_iter,
        *,
        cfg: LoopConfig = LoopConfig(),
        checkpointer=None,
        on_straggler: Optional[Callable[[int, float], None]] = None,
    ) -> None:
        self.train_step = train_step
        self.state = state
        self.data_iter = data_iter
        self.cfg = cfg
        self.checkpointer = checkpointer
        self.monitor = StepMonitor(straggler_factor=cfg.straggler_factor)
        self.on_straggler = on_straggler
        self.guard = PreemptionGuard()
        self.history: list = []

    def run(self) -> Dict[str, Any]:
        start = int(self.state["step"])
        for step in range(start, self.cfg.total_steps):
            batch = next(self.data_iter)
            t0 = time.perf_counter()
            for attempt in range(self.cfg.max_retries + 1):
                try:
                    self.state, metrics = self.train_step(self.state, batch)
                    loss = float(metrics["loss"])  # blocks; surfaces faults
                    break
                except Exception:
                    if attempt == self.cfg.max_retries:
                        # persist progress before propagating
                        if self.checkpointer is not None:
                            self.checkpointer.save(self.state, step=step)
                        raise
            dt = time.perf_counter() - t0
            flagged = self.monitor.record(dt)
            if flagged and self.on_straggler is not None:
                self.on_straggler(step, dt)
            self.history.append({"step": step, "loss": loss, "dt": dt})
            if (
                self.checkpointer is not None
                and (step + 1) % self.cfg.checkpoint_every == 0
            ):
                self.checkpointer.save(self.state, step=step + 1)
            if self.guard.requested:
                if self.checkpointer is not None:
                    self.checkpointer.save(self.state, step=step + 1)
                break
        return self.state
