"""The end-to-end tool flow: CFDlang source in, planned executable
memory architecture out (the paper's headline pipeline, Fig. 5).

``compile()`` wires the repo's two halves together with no per-operator
hand-written builder code:

  1. **front-end**   -- ``core.dsl`` parses the source (``elem`` markers
     or ``element_vars`` name the batched streams);
  2. **middle-end**  -- ``core.rewrite`` factorizes/CSEs the tensor
     expressions;
  3. **schedule**    -- ``core.schedule`` partitions the value graph into
     dataflow groups; ``stage_partition`` turns group boundaries into
     pipeline-stage boundaries (or explicit named cuts are honored);
  4. **liveness**    -- ``core.liveness.classify_boundary_streams``
     decides which cross-stage values stay HBM-resident and which cross
     the host link;
  5. **backend**     -- each stage is compiled by ``core.emit`` (XLA /
     staged / Pallas via structural pattern dispatch, ``flow.patterns``);
  6. **memory**      -- the derived :class:`ProgramChain` is planned by
     ``memory.plan_chain`` (optionally swept by ``dse.explore_chain``).

The result is a :class:`CompiledSystem`: per-stage callables, the
:class:`ChainPlan`, and a human-readable system report -- the generated-
architecture description the paper's flow emits.  ``CompiledSystem.run``
executes the artifact through the K-deep chain pipeline driver.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple, Union

from ..core import dsl, emit, ir, liveness, rewrite
from ..core.schedule import (Group, Schedule, schedule as make_schedule,
                             stage_partition)
from ..core.precision import POLICIES
from ..memory import channels, layout
from ..memory.chain import ChainPlan, ChainStage, ProgramChain, plan_chain
from ..memory.fusion import FusionSpec, fuse_chain_auto
from ..memory.fusion import _collapse, _collapse_backends
from ..memory.placement import DeviceTopology
from . import patterns


class FlowError(ValueError):
    """Raised when a program cannot be lowered to a pipelined system."""


#: Explicit stage cuts: ``(stage_name, (value_name, ...))`` where value
#: names refer to the program's declared temporaries/outputs.
StageSpec = Sequence[Tuple[str, Sequence[str]]]


def resolve_target(
    target: Union[None, str, channels.MemoryTarget],
) -> channels.MemoryTarget:
    """None -> detect; str -> datasheet lookup ('alveo_u280' ~ 'alveo-u280').

    Delegates to :func:`repro.memory.channels.resolve_target` so the CLI,
    the library API, and the benchmarks all normalize names identically;
    typos raise a FlowError listing the known targets."""
    try:
        return channels.resolve_target(target)
    except channels.UnknownTargetError as e:
        raise FlowError(str(e)) from e


# ---------------------------------------------------------------------------
# compile-identity fingerprints (the serving layer's plan-cache key)
# ---------------------------------------------------------------------------


def program_fingerprint(prog: ir.Program) -> str:
    """Canonical sha1 of a program's structure.

    Node uids and einsum index ids are process-global fresh counters, so
    two parses of the same source produce different raw objects; this
    renumbers both (nodes in topological order, einsum ids per node in
    first-use order) so equal graphs hash equal while any structural
    change -- shapes, ops, bindings, outputs, element marking -- does
    not.  Fingerprint the *post-rewrite* program to key a plan cache:
    sources that optimize to the same graph then share one entry.
    """
    import hashlib

    topo = prog.toposort()
    num = {n.uid: i for i, n in enumerate(topo)}
    parts: List[str] = []
    for n in topo:
        if isinstance(n, ir.Input):
            parts.append(f"in:{n.name}:{tuple(n.shape)}")
        elif isinstance(n, ir.Einsum):
            ids: Dict[int, int] = {}

            def ren(j: int) -> int:
                return ids.setdefault(j, len(ids))

            subs = ";".join(
                ",".join(str(ren(j)) for j in s) for s in n.in_subs
            )
            out = ",".join(str(ren(j)) for j in n.out_subs)
            ops = ",".join(str(num[o.uid]) for o in n.ops)
            parts.append(f"ein:{ops}:{subs}->{out}:{tuple(n.shape)}")
        elif isinstance(n, ir.Ewise):
            ops = ",".join(str(num[o.uid]) for o in n.operands())
            parts.append(f"ew:{n.op}:{ops}:{n.const}:{tuple(n.shape)}")
        else:  # future node kinds still hash deterministically
            ops = ",".join(str(num[o.uid]) for o in n.operands())
            parts.append(f"{type(n).__name__}:{ops}:{tuple(n.shape)}")
    parts.append("outs:" + ",".join(
        f"{name}={num[v.uid]}" for name, v in sorted(prog.outputs.items())
    ))
    parts.append("elem:" + ",".join(sorted(prog.element_vars)))
    return hashlib.sha1("|".join(parts).encode()).hexdigest()


def topology_fingerprint(
    devices: Union[None, int, str, DeviceTopology],
) -> str:
    """The cache-key view of ``compile(devices=...)``: what machine the
    placement was co-scheduled for.  ``0`` (detect) resolves the local
    pool *now*, so a cache entry can never leak across pool changes.
    Heterogeneous specs (``"cpu:2,tpu:4"`` strings or explicit
    :class:`DeviceTopology` values) hash their full per-group layout via
    ``spec_string()`` -- two fleets with the same device count but
    different kind mixes never share a plan-cache entry."""
    if devices is None:
        return "auto"
    if isinstance(devices, DeviceTopology):
        return devices.spec_string()
    if isinstance(devices, str):
        return DeviceTopology.parse(devices).spec_string()
    if devices == 0:
        t = DeviceTopology.detect()
        return t.spec_string()
    return f"{devices}xgeneric"


def cache_key(
    source: str,
    *,
    element_vars: Sequence[str] = (),
    target: Union[None, str, channels.MemoryTarget] = None,
    policy: Union[str, object] = "float32",
    optimize: bool = True,
    devices: Union[None, int, str, DeviceTopology] = None,
    **kwargs,
) -> str:
    """The plan-cache key for one :func:`compile` call: ``(sha of the
    post-rewrite program, target name, policy, topology fingerprint)``
    plus a digest of every remaining compile knob, ``/``-joined.

    Runs only the front/middle-end (parse + rewrite) -- the expensive
    planning/DSE work is exactly what a cache hit skips.  Knobs that are
    ``None`` (the compile defaults) are excluded from the digest, so
    spelling a default out does not split the cache; the serving layer
    passes one normalized kwarg dict for the rest.
    """
    import hashlib

    pol = policy if isinstance(policy, str) else policy.name
    tgt = resolve_target(target)
    prog = dsl.parse(source, element_vars=element_vars)
    if optimize:
        prog = rewrite.optimize(prog)
    extra = hashlib.sha1(repr(sorted(
        (k, repr(v)) for k, v in kwargs.items()
        if v is not None and k not in ("name", "profile")
    )).encode()).hexdigest()[:12]
    return "/".join([
        program_fingerprint(prog), tgt.name, pol,
        topology_fingerprint(devices), extra,
    ])


# ---------------------------------------------------------------------------
# stage extraction
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Stage:
    """One extracted pipeline stage, pre-compilation."""

    name: str
    nodes: List[ir.Node]           # slice of the whole program, topo order
    program: ir.Program            # standalone rebuilt subprogram
    bindings: Dict[str, str]       # input name -> "producer.output"
    group: Group                   # report view (streams/flops/liveness)


@dataclasses.dataclass(frozen=True)
class StreamInfo:
    """One cross-stage value and where it lives."""

    name: str
    klass: str                     # liveness.STREAM_{RESIDENT,HOST,BOTH}
    bytes_per_element: int
    producer: str
    consumers: Tuple[str, ...]     # empty for host-only outputs


def _named_partitions(
    prog: ir.Program, stages: StageSpec
) -> List[Tuple[str, List[ir.Node]]]:
    """Partition the program at explicit named cuts: each stage owns the
    nodes needed for its named values that no earlier stage claimed."""
    by_name: Dict[str, ir.Node] = dict(prog.temps)
    by_name.update(prog.outputs)
    topo = prog.toposort()
    topo_pos = {n.uid: i for i, n in enumerate(topo)}
    input_uids = {v.uid for v in prog.inputs.values()}
    claimed: set = set()
    parts: List[Tuple[str, List[ir.Node]]] = []
    seen_names: set = set()
    for name, value_names in stages:
        if not name or "." in name or name in seen_names:
            raise FlowError(f"bad or duplicate stage name {name!r}")
        seen_names.add(name)
        nodes: List[ir.Node] = []
        stack = []
        for vn in value_names:
            if vn not in by_name:
                raise FlowError(
                    f"stage {name!r}: unknown value {vn!r} (stage cuts "
                    "name declared temporaries or outputs)"
                )
            stack.append(by_name[vn])
        while stack:
            n = stack.pop()
            if n.uid in claimed or n.uid in input_uids:
                continue
            claimed.add(n.uid)
            nodes.append(n)
            stack.extend(n.operands())
        if not nodes:
            raise FlowError(
                f"stage {name!r} is empty: its values are computed by "
                "earlier stages (cut order conflicts with the dataflow)"
            )
        nodes.sort(key=lambda n: topo_pos[n.uid])
        parts.append((name, nodes))
    for out_name, v in prog.outputs.items():
        if v.uid not in claimed:
            raise FlowError(
                f"stage cuts do not cover output {out_name!r}"
            )
    return parts


def _stream_namer(prog: ir.Program):
    """Deterministic cross-stage stream names: declared temp names where
    available, else t0, t1, ... in topological order (uids never leak
    into reports)."""
    taken = set(prog.inputs) | set(prog.outputs) | set(prog.temps)
    temp_of = {v.uid: k for k, v in prog.temps.items()}
    fresh = iter(range(10 ** 6))
    cache: Dict[int, str] = {}

    def name_of(node: ir.Node) -> str:
        if node.uid not in cache:
            got = temp_of.get(node.uid)
            if got is None:
                got = f"t{next(fresh)}"
                while got in taken:
                    got = f"t{next(fresh)}"
                taken.add(got)
            cache[node.uid] = got
        return cache[node.uid]

    return name_of


def _extract_stages(
    prog: ir.Program,
    parts: List[Tuple[str, List[ir.Node]]],
    bytes_per_scalar: int,
) -> Tuple[List[_Stage], List[StreamInfo]]:
    """Turn a node partition into standalone stage programs + bindings.

    Cross-stage values become the producer stage's outputs and fresh
    inputs of each consumer (same stream name on both sides, so chain
    bindings are by construction never dangling).  A program output that
    later stages also consume is exported twice: under its output name
    (host stream) and under a ``<name>_res`` alias (the HBM-resident
    copy consumers bind to), so the host still receives every program
    output.
    """
    elem_dep = prog.element_dependent_uids()
    classes = liveness.classify_boundary_streams(
        prog, [nodes for _, nodes in parts]
    )
    out_names: Dict[int, List[str]] = {}
    for name, v in prog.outputs.items():
        out_names.setdefault(v.uid, []).append(name)
    input_name_of = {v.uid: k for k, v in prog.inputs.items()}
    stream_name = _stream_namer(prog)

    stage_of: Dict[int, int] = {}
    for i, (_, nodes) in enumerate(parts):
        for n in nodes:
            stage_of[n.uid] = i

    # pre-name pure-resident streams in topo order for determinism
    stream_name_by_uid: Dict[int, str] = {}
    for _, nodes in parts:
        for n in nodes:
            if (n.uid in classes
                    and classes[n.uid] == liveness.STREAM_RESIDENT
                    and n.uid not in out_names):
                stream_name_by_uid[n.uid] = stream_name(n)

    def export_name(uid: int) -> str:
        """The producer-side output name consumers bind to."""
        if classes[uid] == liveness.STREAM_BOTH:
            return f"{out_names[uid][0]}_res"
        if uid in out_names:
            return out_names[uid][0]
        return stream_name_by_uid[uid]

    stages: List[_Stage] = []
    consumers: Dict[int, List[str]] = {}
    for i, (name, nodes) in enumerate(parts):
        node_uids = {n.uid for n in nodes}
        # --- boundary inputs ------------------------------------------------
        inputs: Dict[str, ir.Node] = {}
        bindings: Dict[str, str] = {}
        in_elem: List[str] = []
        for n in nodes:
            for op in n.operands():
                if op.uid in node_uids:
                    continue
                if op.uid in input_name_of:        # whole-program input
                    in_name = input_name_of[op.uid]
                    src = None
                else:                               # earlier stage's value
                    in_name = (
                        stream_name_by_uid.get(op.uid)
                        or out_names[op.uid][0]
                    )
                    p = stage_of[op.uid]
                    src = f"{parts[p][0]}.{export_name(op.uid)}"
                if in_name in inputs:
                    continue
                inputs[in_name] = op
                if src is not None:
                    bindings[in_name] = src
                    consumers.setdefault(op.uid, []).append(name)
                if op.uid in elem_dep:
                    in_elem.append(in_name)
        # --- boundary outputs ----------------------------------------------
        outputs: Dict[str, ir.Node] = {}
        out_elem: List[str] = []
        for n in nodes:
            klass = classes.get(n.uid)
            if klass is None:
                continue
            names: List[str] = list(out_names.get(n.uid, ()))
            if klass == liveness.STREAM_BOTH:
                names.append(f"{out_names[n.uid][0]}_res")
            elif klass == liveness.STREAM_RESIDENT and n.uid not in out_names:
                names = [stream_name_by_uid[n.uid]]
            for nm in names:
                outputs[nm] = n
                if n.uid in elem_dep:
                    out_elem.append(nm)
            if n.uid not in elem_dep:
                raise FlowError(
                    f"stream {names[0]!r} does not depend on any element "
                    "input; the flow pipelines element streams only "
                    "(precompute shared values on the host instead)"
                )
        stage_prog = ir.subprogram(
            nodes, inputs, outputs, element_vars=in_elem + out_elem
        )
        group = Group(
            nodes=nodes,
            in_streams=list(inputs.values()),
            out_streams=[prog_out for prog_out in dict.fromkeys(
                outputs.values()
            )],
            name=name,
            bytes_per_scalar=bytes_per_scalar,
        )
        stages.append(_Stage(
            name=name, nodes=nodes, program=stage_prog,
            bindings=bindings, group=group,
        ))

    streams = [
        StreamInfo(
            name=(
                out_names[uid][0] if uid in out_names
                else stream_name_by_uid[uid]
            ),
            klass=klass,
            bytes_per_element=(
                next(n for n in parts[stage_of[uid]][1] if n.uid == uid).size
                * bytes_per_scalar
            ),
            producer=parts[stage_of[uid]][0],
            consumers=tuple(consumers.get(uid, ())),
        )
        for uid, klass in sorted(
            classes.items(),
            key=lambda kv: (stage_of[kv[0]], kv[0]),
        )
    ]
    return stages, streams


# ---------------------------------------------------------------------------
# stage compilation (with Pallas pattern dispatch)
# ---------------------------------------------------------------------------


def _compile_stages(
    stages: List[_Stage],
    policy,
    backends: Sequence[str],
    stage_blocks: Mapping[str, int],
) -> Tuple[List[ChainStage], Tuple[str, ...]]:
    """Compile every stage program; ``pallas`` stages are structurally
    matched against hand-tiled kernels and fall back to ``xla`` when no
    kernel fits.  Returns the chain stages + effective backends."""
    chain_stages: List[ChainStage] = []
    effective: List[str] = []
    for st, backend in zip(stages, backends):
        pallas_impl = None
        if backend == "pallas":
            pallas_impl = patterns.pallas_impl_for(
                st.program, block_elements=stage_blocks.get(st.name)
            )
            if pallas_impl is None:
                backend = "xla"
        compiled = emit.compile_program(
            st.program, policy=policy, backend=backend,
            pallas_impl=pallas_impl,
        )
        chain_stages.append(ChainStage(st.name, compiled, dict(st.bindings)))
        effective.append(backend)
    return chain_stages, tuple(effective)


def _tune_stage_blocks(
    stage_specs: List[_Stage],
    effective: Sequence[str],
    plan: ChainPlan,
    policy,
    target: channels.MemoryTarget,
    profile,
) -> Dict[str, int]:
    """Measured block-size autotuning for the plan's Pallas stages.

    For each Pallas stage, candidate ``block_elements`` come from the
    CHARM-style tile classes (``kernels.gemm.tile_candidates``: VMEM-
    filtered, large/small split, throughput-ranked) when the stage fits
    the GEMM-chain class, else from the power-of-two blocks under the
    stage's VMEM cap.  Each candidate is compiled and timed on synthetic
    data at the plan's E; the fastest wins.  Winners (with their
    predicted-vs-measured sample) are deposited in the profile store
    keyed by the plan's signature, so later sessions start from the
    measured choice.  Returns ``{stage name: winning block}``.
    """
    import time

    import jax
    import numpy as np
    import jax.numpy as jnp

    from ..kernels import gemm

    bps = policy.bits // 8
    e = plan.batch_elements
    sp_by_name = {sp.name: sp for sp in plan.stages}
    winners: Dict[str, int] = {}
    samples = []
    for st, backend in zip(stage_specs, effective):
        if backend != "pallas":
            continue
        recipe = patterns.match_gemm_chain(st.program)
        if recipe is not None:
            cands = [
                c.block_elements for c in gemm.tile_candidates(
                    recipe, vmem_bytes=target.vmem_bytes,
                    peak_flops=target.peak_flops,
                    hbm_bandwidth=target.hbm_bw,
                    bytes_per_scalar=bps, batch_elements=e,
                )
            ]
        else:
            cap = layout.vmem_block_elements(
                st.program, target, bytes_per_scalar=bps
            )
            cands, be = [], 1
            while be <= min(cap, e):
                if e % be == 0:
                    cands.append(be)
                be *= 2
        cands = sorted({b for b in cands if b <= e and e % b == 0})
        if len(cands) < 2:
            continue
        rng = np.random.default_rng(0)
        elem = set(st.program.element_vars)
        env = {
            n: jnp.asarray(
                rng.standard_normal(
                    ((e,) + tuple(v.shape)) if n in elem
                    else tuple(v.shape)
                ),
                jnp.float32,
            )
            for n, v in st.program.inputs.items()
        }
        best = None
        for be in cands:
            impl = patterns.pallas_impl_for(
                st.program, block_elements=be
            )
            if impl is None:
                break
            fn = emit.compile_program(
                st.program, policy=policy, backend="pallas",
                pallas_impl=impl,
            ).batched_fn
            jax.tree_util.tree_map(
                lambda a: a.block_until_ready(), fn(env)
            )  # compile outside the timed reps
            t = min(
                _timed(fn, env) for _ in range(3)
            )
            if best is None or t < best[1]:
                best = (be, t)
        if best is None:
            continue
        winners[st.name] = best[0]
        sp = sp_by_name.get(st.name)
        if sp is not None:
            samples.append({
                "name": f"tune:{st.name}",
                "scope": "tune",
                "predicted_s": max(
                    sp.cost.t_compute, sp.cost.t_hbm, sp.cost.t_host
                ),
                "measured_s": best[1],
                "block_elements": best[0],
            })
    if samples and profile is not None:
        from ..trace.profile import ProfileStore  # lazy: no import cycle

        store = ProfileStore.open(profile)
        if store is not None:
            store.record(target.name, plan.signature, samples)
    return winners


def _timed(fn, env) -> float:
    """One timed call, outputs synced."""
    import time

    import jax

    t0 = time.perf_counter()
    jax.tree_util.tree_map(lambda a: a.block_until_ready(), fn(env))
    return time.perf_counter() - t0


# ---------------------------------------------------------------------------
# the compiled artifact
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CompiledSystem:
    """Everything the flow generates for one program: the executable
    chain, its memory architecture, and the derivation record."""

    name: str
    source: str
    policy: str
    target: channels.MemoryTarget
    program: ir.Program            # whole program after rewrites
    schedule: Schedule
    chain: ProgramChain
    plan: ChainPlan
    backends: Tuple[str, ...]      # effective per-stage backends
    streams: Tuple[StreamInfo, ...]
    sharing: Dict[str, "liveness.SharingPlan"]
    stage_groups: Tuple[Group, ...]
    candidates: Optional[list] = None   # ChainCandidate ranking (dse=True)

    @property
    def stage_names(self) -> Tuple[str, ...]:
        """Planned stage names, in execution order (post-fusion)."""
        return tuple(s.name for s in self.chain.stages)

    def run(self, **kwargs):
        """Execute the system through the chain pipeline driver: the
        plan's ``pipeline`` spec decides whether stages are cross-batch
        pipelined (one dispatch ring per stage) or run back-to-back
        (pass ``pipeline_stages=False`` to force the serial baseline;
        see ``repro.cfd.simulation.run_chain`` for all arguments).
        ``tracer=repro.trace.Tracer()`` records the run's span/counter
        trace; ``monitor=runtime.StepMonitor()`` watches for straggler
        batches -- both pass straight through to ``run_chain``."""
        from ..cfd.simulation import run_chain  # lazy: cfd builds on flow

        return run_chain(self.chain, self.plan, **kwargs)

    def report(self, tracer=None) -> str:
        """The generated-architecture description (golden-checked).

        Pass the tracer of a completed ``run(tracer=...)`` to append the
        ``measured:`` section -- the per-stage predicted-vs-measured
        attribution table (``repro.trace.attribution_report``)."""
        prog = self.program
        elem = set(prog.element_vars)
        n_elem_in = sum(1 for n in prog.inputs if n in elem)
        bps = self.schedule.bytes_per_scalar
        fu = self.plan.fusion
        if fu is None:
            fusion_line = (
                "  fusion: off (fuse='auto' merges stages whose handoff "
                "the cost model prices above their combined roofline)"
            )
        elif fu.fused:
            fusion_line = (
                f"  fusion: {fu.mode} ({fu.n_stages_before} -> "
                f"{fu.n_stages_after} stages)"
            )
        else:
            fusion_line = (
                f"  fusion: {fu.mode} (kept all {fu.n_stages_after} "
                "stages)"
            )
        lines = [
            f"repro.flow system: {self.name}",
            "  pipeline: DSL source -> teil IR -> schedule -> chain -> "
            "plan -> execute",
            f"  target={self.target.name}  policy={self.policy}  "
            f"stages={len(self.chain.stages)}",
            f"  program: {len(prog.inputs)} inputs ({n_elem_in} element), "
            f"{len(prog.outputs)} outputs, "
            f"{sum(1 for n in prog.toposort() if not isinstance(n, ir.Input))}"
            f" ir nodes, {prog.total_flops()} flops/element",
            f"  schedule: {len(self.schedule.groups)} groups -> "
            f"{len(self.chain.stages)} stages",
            fusion_line,
            "",
            f"  {'stage':<12} {'backend':<8} {'nodes':>5} "
            f"{'flops/elem':>12} {'in B/elem':>10} {'out B/elem':>10} "
            f"{'sharing':>8}",
        ]
        for g, backend in zip(self.stage_groups, self.backends):
            share = self.sharing[g.name]
            lines.append(
                f"  {g.name:<12} {backend:<8} {len(g.nodes):>5} "
                f"{g.flops:>12} {g.in_stream_bytes(bps):>10} "
                f"{g.out_stream_bytes(bps):>10} "
                f"{share.savings_frac * 100:>7.1f}%"
            )
        lines += [
            "",
            f"  {'stream':<12} {'class':<9} {'B/elem':>8}  route",
        ]
        for s in self.streams:
            route = s.producer + " -> " + (
                ", ".join(s.consumers) if s.consumers else "host"
            )
            if s.klass == liveness.STREAM_BOTH:
                route += " + host"
            lines.append(
                f"  {s.name:<12} {s.klass:<9} "
                f"{s.bytes_per_element:>8}  {route}"
            )
        lines += ["", self.plan.report()]
        if tracer:
            from ..trace.attribution import attribution_report

            lines += ["", attribution_report(tracer, self.plan)]
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# the entry point
# ---------------------------------------------------------------------------


def compile(
    source: str,
    *,
    name: str = "program",
    element_vars: Sequence[str] = (),
    stages: Optional[StageSpec] = None,
    target: Union[None, str, channels.MemoryTarget] = None,
    policy: Union[str, object] = "float32",
    backend: str = "xla",
    backends: Optional[Sequence[str]] = None,
    stage_blocks: Optional[Mapping[str, int]] = None,
    optimize: bool = True,
    max_stages: Optional[int] = None,
    vmem_budget: Optional[int] = None,
    batch_elements: Optional[int] = None,
    prefetch_depth: Union[int, Sequence[int]] = 1,
    cu_count: Union[int, Sequence[int]] = 1,
    devices: Union[None, int, str, DeviceTopology] = None,
    n_eq: Optional[int] = None,
    channel_bytes: Optional[int] = None,
    dse: bool = False,
    dse_space=None,
    measure_top: int = 0,
    profile=None,
    fuse: Optional[str] = None,
    tune_blocks: bool = False,
) -> CompiledSystem:
    """Compile a CFDlang program end-to-end into a planned, executable
    memory architecture.

    Args:
        source: CFDlang program text (``var input/output [elem]`` decls
            plus tensor statements).
        name: Label used in reports and the serving plan cache.
        element_vars: Names of batched streams when the source does not
            mark them with ``elem``.
        stages: Explicit named cuts (:data:`StageSpec`); ``None``
            derives the pipeline from the scheduler's dataflow groups.
        target: Memory datasheet -- a :class:`~repro.memory.channels.
            MemoryTarget`, a name like ``'tpu-v5e'``, or ``None`` to
            detect.
        policy: Numeric precision policy name (or policy object).
        backend: Backend for every stage unless ``backends`` is given.
        backends: Per-stage backend overrides; ``pallas`` stages are
            structurally matched to hand-tiled kernels and fall back to
            ``xla`` when nothing fits.
        stage_blocks: Per-stage VMEM ``block_elements`` pins for Pallas
            kernels (e.g. from a prior plan).
        optimize: Run the middle-end rewrites (factorize/CSE) first.
        max_stages: With ``stages=None``, cap the schedule's stage
            count; values below the natural count also imply cost-driven
            fusion (see ``fuse``).
        vmem_budget: Override the scheduler's on-chip working-set budget.
        batch_elements: Explicit E; ``None`` co-sizes it per the
            paper's channel rule.
        prefetch_depth: Pipeline depth K, one value or one per stage.
        cu_count: Compute units per stage, one value or one per stage.
        devices: Device topology the stage CU groups are placed on: an
            int (homogeneous pool of that size; ``0`` = detect the
            local JAX pool, including mixed-kind fleets), a spec string
            like ``"cpu:2,tpu:4"`` (heterogeneous groups, each priced
            against its own datasheet), or an explicit
            :class:`~repro.memory.placement.DeviceTopology`.
        n_eq: Total equations/elements the plan should assume.
        channel_bytes: Override the target's pseudo-channel capacity.
        dse: Sweep chain design points and adopt the best feasible plan,
            recompiling stages if the winning backends or blocks differ.
        dse_space: A :class:`~repro.memory.dse.ChainDesignSpace`
            restricting that sweep.
        measure_top: Verify the k best candidates by measurement.
        profile: Profile store (store, path, or ``True``) that
            warm-starts the DSE ranking and records measurements --
            exactly ``explore_chain(profile=...)``; also receives the
            ``tune_blocks`` winners.
        fuse: ``'auto'`` makes the stage count itself a design axis:
            after scheduling, adjacent stages are greedily merged
            whenever the planner prices the HBM handoff between them
            above the fused stage's combined roofline
            (:mod:`repro.memory.fusion`); merged stages re-enter Pallas
            pattern matching.  Explicit ``stages`` cuts are barriers --
            fusion never merges across a named cut.  ``'off'``/``None``
            keeps every boundary.
        tune_blocks: Measure candidate VMEM block sizes for each Pallas
            stage (CHARM-style large/small tile classes filtered by the
            plan's VMEM budget), adopt the fastest, and deposit the
            winners in the profile store keyed by the plan signature.

    Returns:
        A :class:`CompiledSystem`: per-stage callables, the
        :class:`~repro.memory.chain.ChainPlan` (``plan.fusion`` records
        the fusion decision when ``fuse`` ran), and the derivation
        record rendered by :meth:`CompiledSystem.report`.

    Raises:
        FlowError: On parse errors, unknown targets/policies/backends,
            malformed stage cuts, or non-element outputs.
    """
    if isinstance(policy, str):
        if policy not in POLICIES:
            raise FlowError(
                f"unknown policy {policy!r}; known: {sorted(POLICIES)}"
            )
        pol = POLICIES[policy]
    else:
        pol = policy
    bps = pol.bits // 8
    target = resolve_target(target)

    prog = dsl.parse(source, element_vars=element_vars)
    if not prog.outputs:
        raise FlowError("program has no outputs; nothing to compile")
    if not prog.element_vars:
        raise FlowError(
            "program has no element-marked streams; qualify batched "
            "inputs/outputs with 'elem' (or pass element_vars=...)"
        )
    if optimize:
        prog = rewrite.optimize(prog)
    elem_dep = prog.element_dependent_uids()
    for out_name, v in prog.outputs.items():
        if v.uid not in elem_dep:
            raise FlowError(
                f"output {out_name!r} does not depend on any element "
                "input; the flow pipelines element streams only"
            )

    sched_kwargs = {}
    if vmem_budget is not None:
        sched_kwargs["vmem_budget"] = vmem_budget
    if max_stages is not None:
        sched_kwargs["max_groups"] = max_stages
    sched = make_schedule(prog, bytes_per_scalar=bps, **sched_kwargs)

    if stages is None:
        parts = [
            (f"s{i}", nodes)
            for i, nodes in enumerate(stage_partition(sched))
        ]
    else:
        parts = _named_partitions(prog, stages)

    stage_specs, streams = _extract_stages(prog, parts, bps)

    if backends is None:
        backends = [backend] * len(stage_specs)
    if len(backends) != len(stage_specs):
        raise FlowError(
            f"need {len(stage_specs)} per-stage backends "
            f"({', '.join(s.name for s in stage_specs)}), "
            f"got {len(backends)}"
        )
    stage_blocks = dict(stage_blocks or {})
    chain_stages, effective = _compile_stages(
        stage_specs, pol, backends, stage_blocks
    )
    chain = ProgramChain(chain_stages)

    if devices is None:
        topology = None  # plan_chain sizes it to the widest stage
    elif isinstance(devices, DeviceTopology):
        topology = devices
    elif isinstance(devices, str):
        try:
            topology = DeviceTopology.parse(devices)
        except ValueError as e:
            raise FlowError(str(e)) from e
    elif devices == 0:
        topology = DeviceTopology.detect()
    else:
        topology = DeviceTopology.homogeneous(devices)

    plan = plan_chain(
        chain, target=target, policy=pol.name, backends=effective,
        batch_elements=batch_elements, prefetch_depth=prefetch_depth,
        cu_count=cu_count, topology=topology, n_eq=n_eq,
        channel_bytes=channel_bytes,
    )

    if fuse not in (None, "off", "auto"):
        raise FlowError(f"unknown fuse mode {fuse!r}; use 'auto' or 'off'")
    fusion_spec = None
    if fuse == "auto":
        if stages is not None or len(chain.stages) == 1:
            # every explicit named cut is a barrier: fusion is a no-op
            fusion_spec = FusionSpec(
                mode="auto",
                groups=tuple((s.name,) for s in chain.stages),
                n_stages_before=len(chain.stages),
                n_stages_after=len(chain.stages),
                t_unfused=plan.cost.t_pipelined,
                t_fused=plan.cost.t_pipelined,
                saved_handoff_bytes=0,
                barriers=(
                    tuple(s.name for s in chain.stages)
                    if stages is not None else ()
                ),
            )
        else:
            decision = fuse_chain_auto(
                chain, mode="auto", target=target, policy=pol.name,
                backends=effective, batch_elements=batch_elements,
                prefetch_depth=prefetch_depth, cu_count=cu_count,
                topology=topology, n_eq=n_eq, channel_bytes=channel_bytes,
            ).fusion
            fusion_spec = dataclasses.replace(decision, chain=None)
            if decision.fused:
                # rebuild the flow's own stages over the merged
                # partition, so streams/groups/reports stay native and
                # the merged programs re-enter Pallas pattern matching
                idx_of = {pname: i for i, (pname, _) in enumerate(parts)}
                groups_idx = [
                    tuple(idx_of[n] for n in g) for g in decision.groups
                ]
                topo_pos = {
                    n.uid: i for i, n in enumerate(prog.toposort())
                }
                parts = [
                    (
                        "+".join(names),
                        sorted(
                            (n for i in g for n in parts[i][1]),
                            key=lambda n: topo_pos[n.uid],
                        ),
                    )
                    for g, names in zip(groups_idx, decision.groups)
                ]
                stage_specs, streams = _extract_stages(prog, parts, bps)
                prefetch_depth = _collapse(prefetch_depth, groups_idx)
                cu_count = _collapse(cu_count, groups_idx)
                chain_stages, effective = _compile_stages(
                    stage_specs, pol,
                    _collapse_backends(list(backends), groups_idx),
                    stage_blocks,
                )
                chain = ProgramChain(chain_stages)
                plan = plan_chain(
                    chain, target=target, policy=pol.name,
                    backends=effective, batch_elements=batch_elements,
                    prefetch_depth=prefetch_depth, cu_count=cu_count,
                    topology=topology, n_eq=n_eq,
                    channel_bytes=channel_bytes,
                )
                fusion_spec = dataclasses.replace(
                    fusion_spec, t_fused=plan.cost.t_pipelined
                )

    candidates = None
    if dse:
        from ..memory import dse as dse_mod  # lazy: dse measures via cfd

        space = dse_space or dse_mod.ChainDesignSpace(policies=(pol.name,))
        candidates = dse_mod.explore_chain(
            chain, target=target, n_eq=n_eq if n_eq else 1 << 16,
            space=space, topology=topology, measure_top=measure_top,
            profile=profile,
        )
        winner = next((c for c in candidates if c.plan.feasible), None)
        if winner is not None:
            plan = winner.plan
            won = tuple(sp.backend for sp in plan.stages)
            won_pol = (
                POLICIES[plan.policy] if plan.policy != pol.name else pol
            )
            # a Pallas stage bakes its VMEM block into the compiled
            # kernel, so a winner that differs only in E/block (same
            # backends + policy) still forces a recompile -- otherwise
            # the kernel's block and the plan's block_elements diverge
            blocks_stale = any(
                be == "pallas" and sp.block_elements
                and st.name not in stage_blocks
                for st, be, sp in zip(stage_specs, effective, plan.stages)
            )
            if won != effective or won_pol is not pol or blocks_stale:
                blocks = dict(stage_blocks)
                for sp in plan.stages:
                    if sp.block_elements:
                        blocks.setdefault(sp.name, sp.block_elements)
                chain_stages, effective = _compile_stages(
                    stage_specs, won_pol, won, blocks
                )
                chain = ProgramChain(chain_stages)
                pol = won_pol
            if won != effective:
                # the winning combo asked for a kernel no stage matches
                # (e.g. 'pallas' on a non-Helmholtz stage): re-plan at
                # the winner's design point with the backends that
                # actually compiled, so plan and executable agree
                plan = plan_chain(
                    chain, target=target, policy=pol.name,
                    backends=effective,
                    batch_elements=plan.batch_elements,
                    placement=plan.placement, n_eq=n_eq,
                    channel_bytes=channel_bytes,
                )

    if tune_blocks:
        winners = _tune_stage_blocks(
            stage_specs, effective, plan, pol, target, profile
        )
        stale = {
            name: be for name, be in winners.items()
            if any(
                sp.name == name and sp.block_elements != be
                for sp in plan.stages
            )
        }
        if stale:
            blocks = dict(stage_blocks)
            blocks.update(winners)
            chain_stages, effective = _compile_stages(
                stage_specs, pol, effective, blocks
            )
            chain = ProgramChain(chain_stages)
            plan = dataclasses.replace(plan, stages=tuple(
                dataclasses.replace(
                    sp,
                    block_elements=stale[sp.name],
                    block_working_set_bytes=layout.block_working_set_bytes(
                        st.program, stale[sp.name], bytes_per_scalar=bps
                    ),
                ) if sp.name in stale else sp
                for sp, st in zip(plan.stages, stage_specs)
            ))

    if fusion_spec is not None:
        plan = dataclasses.replace(
            plan, fusion=dataclasses.replace(fusion_spec, chain=chain)
        )

    sharing = liveness.plan_program(
        [s.group for s in stage_specs], bytes_per_scalar=bps
    )
    return CompiledSystem(
        name=name, source=source, policy=pol.name, target=target,
        program=prog, schedule=sched, chain=chain, plan=plan,
        backends=effective, streams=tuple(streams), sharing=sharing,
        stage_groups=tuple(s.group for s in stage_specs),
        candidates=candidates,
    )
