"""``python -m repro.flow`` entry point (see flow.cli)."""
import sys

from .cli import main

if __name__ == "__main__":
    sys.exit(main())
