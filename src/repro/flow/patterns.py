"""Pallas pattern dispatch: match a stage program against hand-tiled
kernels (the paper's "Optimize" step picking a specialized CU).

``core.emit`` compiles ``backend='pallas'`` only when handed a concrete
``pallas_impl``; this module supplies it by *structural* matching -- a
stage program whose IR is isomorphic to a known kernel's program (same
einsum/ewise graph, same shapes, any input names) is dispatched to that
kernel, with the stage's actual input/output names adapted.  Unmatched
stages fall back to ``xla``, exactly as emit's docstring promises.

Matching is name-insensitive: the flow's stage extraction renames
streams (the Fig. 2 ``u`` arrives as ``gx`` inside the CFD pipeline), so
signatures canonicalize subscripts and identify inputs positionally by
topological order.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Optional, Tuple

from ..core import dsl, ir, rewrite
from ..core.emit import einsum_spec
from ..kernels.helmholtz import ops as helmholtz_ops


def program_signature(prog: ir.Program) -> Tuple:
    """A name-insensitive structural key for a program.

    Two programs share a signature iff their value graphs are isomorphic
    with identical shapes and einsum/ewise semantics -- the input *names*
    are deliberately excluded so renamed streams still match.
    """
    order = prog.toposort()
    idx = {n.uid: i for i, n in enumerate(order)}
    sig = []
    for n in order:
        if isinstance(n, ir.Input):
            sig.append(("input", n.shape))
        elif isinstance(n, ir.Einsum):
            sig.append((
                "einsum", einsum_spec(n),
                tuple(idx[o.uid] for o in n.ops), n.shape,
            ))
        elif isinstance(n, ir.Ewise):
            sig.append((
                "ewise", n.op, n.const,
                tuple(idx[o.uid] for o in n.operands()), n.shape,
            ))
        else:  # pragma: no cover - no other node kinds exist
            sig.append(("other", n.shape))
    outs = tuple(idx[v.uid] for v in prog.outputs.values())
    return (tuple(sig), outs)


def _inputs_by_position(prog: ir.Program) -> Tuple[str, ...]:
    """Input names in topological (first-use) order -- the positional
    role order both sides of a signature match share."""
    name_of = {v.uid: k for k, v in prog.inputs.items()}
    return tuple(
        name_of[n.uid] for n in prog.toposort() if isinstance(n, ir.Input)
    )


@functools.lru_cache(maxsize=None)
def _helmholtz_reference(p: int) -> Tuple[Tuple, Tuple[str, ...]]:
    prog = rewrite.optimize(
        dsl.parse(
            dsl.INVERSE_HELMHOLTZ_SRC.format(p=p),
            element_vars=("u", "D", "v"),
        )
    )
    return program_signature(prog), _inputs_by_position(prog)


def match_inverse_helmholtz(
    prog: ir.Program,
) -> Optional[Tuple[Dict[str, str], str]]:
    """Does ``prog`` compute the fused Inverse-Helmholtz operator?

    Returns ``(rename, out_name)`` where ``rename`` maps the kernel's
    canonical input roles (``S``/``D``/``u``) to the program's actual
    input names, or None when the structure differs.
    """
    if len(prog.outputs) != 1 or len(prog.inputs) != 3:
        return None
    out_shape = next(iter(prog.outputs.values())).shape
    if len(out_shape) != 3 or len(set(out_shape)) != 1:
        return None
    p = out_shape[0]
    ref_sig, ref_roles = _helmholtz_reference(p)
    if program_signature(prog) != ref_sig:
        return None
    rename = dict(zip(ref_roles, _inputs_by_position(prog)))
    return rename, next(iter(prog.outputs))


def pallas_impl_for(
    prog: ir.Program,
    *,
    block_elements: Optional[int] = None,
) -> Optional[Callable]:
    """A batched ``pallas_impl`` for ``core.emit.compile_program``, or
    None when no hand-tiled kernel matches the program."""
    matched = match_inverse_helmholtz(prog)
    if matched is None:
        return None
    rename, out_name = matched
    inner = helmholtz_ops.make_pallas_impl(
        block_elements=(
            block_elements if block_elements
            else helmholtz_ops.DEFAULT_BLOCK_ELEMENTS
        )
    )

    def impl(env):
        out = inner({
            "S": env[rename["S"]],
            "D": env[rename["D"]],
            "u": env[rename["u"]],
        })
        return {out_name: out["v"]}

    return impl
