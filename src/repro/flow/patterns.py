"""Pallas pattern dispatch: match a stage program against hand-tiled
kernels (the paper's "Optimize" step picking a specialized CU).

``core.emit`` compiles ``backend='pallas'`` only when handed a concrete
``pallas_impl``; this module supplies it by *structural* matching -- a
stage program whose IR is isomorphic to a known kernel's program (same
einsum/ewise graph, same shapes, any input names) is dispatched to that
kernel, with the stage's actual input/output names adapted.  Unmatched
stages fall back to ``xla``, exactly as emit's docstring promises.

Matching is name-insensitive: the flow's stage extraction renames
streams (the Fig. 2 ``u`` arrives as ``gx`` inside the CFD pipeline), so
signatures canonicalize subscripts and identify inputs positionally by
topological order.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Optional, Tuple

from ..core import dsl, ir, rewrite
from ..core.emit import einsum_spec
from ..kernels import gemm as gemm_kernels
from ..kernels.helmholtz import ops as helmholtz_ops


def program_signature(prog: ir.Program) -> Tuple:
    """A name-insensitive structural key for a program.

    Two programs share a signature iff their value graphs are isomorphic
    with identical shapes and einsum/ewise semantics -- the input *names*
    are deliberately excluded so renamed streams still match.
    """
    order = prog.toposort()
    idx = {n.uid: i for i, n in enumerate(order)}
    sig = []
    for n in order:
        if isinstance(n, ir.Input):
            sig.append(("input", n.shape))
        elif isinstance(n, ir.Einsum):
            sig.append((
                "einsum", einsum_spec(n),
                tuple(idx[o.uid] for o in n.ops), n.shape,
            ))
        elif isinstance(n, ir.Ewise):
            sig.append((
                "ewise", n.op, n.const,
                tuple(idx[o.uid] for o in n.operands()), n.shape,
            ))
        else:  # pragma: no cover - no other node kinds exist
            sig.append(("other", n.shape))
    outs = tuple(idx[v.uid] for v in prog.outputs.values())
    return (tuple(sig), outs)


def _inputs_by_position(prog: ir.Program) -> Tuple[str, ...]:
    """Input names in topological (first-use) order -- the positional
    role order both sides of a signature match share."""
    name_of = {v.uid: k for k, v in prog.inputs.items()}
    return tuple(
        name_of[n.uid] for n in prog.toposort() if isinstance(n, ir.Input)
    )


@functools.lru_cache(maxsize=None)
def _helmholtz_reference(p: int) -> Tuple[Tuple, Tuple[str, ...]]:
    prog = rewrite.optimize(
        dsl.parse(
            dsl.INVERSE_HELMHOLTZ_SRC.format(p=p),
            element_vars=("u", "D", "v"),
        )
    )
    return program_signature(prog), _inputs_by_position(prog)


def match_inverse_helmholtz(
    prog: ir.Program,
) -> Optional[Tuple[Dict[str, str], str]]:
    """Does ``prog`` compute the fused Inverse-Helmholtz operator?

    Returns ``(rename, out_name)`` where ``rename`` maps the kernel's
    canonical input roles (``S``/``D``/``u``) to the program's actual
    input names, or None when the structure differs.
    """
    if len(prog.outputs) != 1 or len(prog.inputs) != 3:
        return None
    out_shape = next(iter(prog.outputs.values())).shape
    if len(out_shape) != 3 or len(set(out_shape)) != 1:
        return None
    p = out_shape[0]
    ref_sig, ref_roles = _helmholtz_reference(p)
    if program_signature(prog) != ref_sig:
        return None
    rename = dict(zip(ref_roles, _inputs_by_position(prog)))
    return rename, next(iter(prog.outputs))


def match_gemm_chain(
    prog: ir.Program,
) -> Optional[gemm_kernels.GemmRecipe]:
    """Does ``prog`` fit the tiled GEMM-chain kernel class?

    The class covers any stage whose nodes are (a) einsums contracting a
    shared ``(p, p)`` input matrix against one mode of an element-
    dependent all-``p`` tensor (output in the same index order), or (b)
    elementwise ops between already-matched values -- the interpolation
    and gradient stages, every schedule-derived single-contraction
    stage, and the stages the fusion pass merges.  Returns the kernel's
    :class:`~repro.kernels.gemm.GemmRecipe` (slots in topological
    order), or None when any node falls outside the class (the stage
    then falls back to ``xla``).
    """
    elem_dep = prog.element_dependent_uids()
    input_name = {v.uid: k for k, v in prog.inputs.items()}
    order = prog.toposort()

    # one p from the element inputs; every tensor axis must equal it
    p = None
    for n in order:
        if isinstance(n, ir.Input) and n.uid in elem_dep:
            if not n.shape or len(set(n.shape)) != 1:
                return None
            p = n.shape[0]
            break
    if p is None or p < 2:
        return None

    # recipe slots number every input first, then one slot per op, so
    # assign input slots up front (toposort interleaves the two)
    slots: Dict[int, int] = {}
    inputs = []
    for n in order:
        if isinstance(n, ir.Input):
            if any(d != p for d in n.shape):
                return None
            slots[n.uid] = len(slots)
            inputs.append((
                input_name[n.uid], tuple(n.shape), n.uid in elem_dep
            ))
    ops = []
    n_ops = 0

    for n in order:
        if isinstance(n, ir.Input):
            continue
        if isinstance(n, ir.Einsum):
            if len(n.ops) != 2 or n.uid not in elem_dep:
                return None
            # identify the shared (p, p) matrix operand
            mat_i = None
            for i, o in enumerate(n.ops):
                if (isinstance(o, ir.Input) and o.uid not in elem_dep
                        and o.shape == (p, p)):
                    mat_i = i
            if mat_i is None:
                return None
            x = n.ops[1 - mat_i]
            if x.uid not in slots or x.uid not in elem_dep:
                return None
            mat_subs = n.in_subs[mat_i]
            x_subs = n.in_subs[1 - mat_i]
            common = set(mat_subs) & set(x_subs)
            if len(common) != 1 or len(set(mat_subs)) != 2:
                return None
            (c,) = common
            if x_subs.count(c) != 1 or c in n.out_subs:
                return None
            f = mat_subs[0] if mat_subs[1] == c else mat_subs[1]
            mode = x_subs.index(c)
            in_place = [f if j == c else j for j in x_subs]
            out = tuple(n.out_subs)
            if sorted(out) != sorted(in_place) or len(set(out)) != len(out):
                return None
            perm = tuple(in_place.index(j) for j in out)
            if n.shape != x.shape:
                return None
            ops.append((
                "contract", slots[x.uid],
                slots[n.ops[mat_i].uid], mode,
                tuple(mat_subs).index(c), perm,
            ))
        elif isinstance(n, ir.Ewise):
            if n.op not in gemm_kernels.EWISE_OPS or n.uid not in elem_dep:
                return None
            operands = n.operands()
            if any(o.uid not in slots for o in operands):
                return None
            rhs = slots[operands[1].uid] if len(operands) > 1 else -1
            ops.append((
                "ewise", n.op, slots[operands[0].uid], rhs, n.const,
            ))
        else:
            return None
        slots[n.uid] = len(slots)
        n_ops += 1

    if not n_ops or not any(is_elem for _, _, is_elem in inputs):
        return None
    outputs = tuple(
        (name, slots[v.uid]) for name, v in prog.outputs.items()
    )
    return gemm_kernels.GemmRecipe(
        p=p, inputs=tuple(inputs), ops=tuple(ops), outputs=outputs,
    )


def pallas_impl_for(
    prog: ir.Program,
    *,
    block_elements: Optional[int] = None,
) -> Optional[Callable]:
    """A batched ``pallas_impl`` for ``core.emit.compile_program``, or
    None when no hand-tiled kernel matches the program.

    Dispatch order: the hand-fused Inverse-Helmholtz kernel first (its
    Mnemosyne-style scratch sharing is tighter than the generic chain),
    then the tiled GEMM-chain kernel class for everything else the class
    covers -- including stages the fusion pass merged.
    """
    matched = match_inverse_helmholtz(prog)
    if matched is not None:
        rename, out_name = matched
        inner = helmholtz_ops.make_pallas_impl(
            block_elements=(
                block_elements if block_elements
                else helmholtz_ops.DEFAULT_BLOCK_ELEMENTS
            )
        )

        def impl(env):
            out = inner({
                "S": env[rename["S"]],
                "D": env[rename["D"]],
                "u": env[rename["u"]],
            })
            return {out_name: out["v"]}

        return impl

    recipe = match_gemm_chain(prog)
    if recipe is None:
        return None
    return gemm_kernels.make_pallas_impl(
        recipe,
        block_elements=(
            block_elements if block_elements
            else gemm_kernels.DEFAULT_BLOCK_ELEMENTS
        ),
    )
