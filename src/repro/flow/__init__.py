"""repro.flow -- the end-to-end tool flow (the paper's Fig. 5 pipeline).

One call compiles *any* CFDlang program into a planned, executable
memory architecture, with no hand-written per-operator code::

    from repro import flow
    system = flow.compile(open("prog.cfd").read(), target="alveo-u280")
    print(system.report())      # the generated-architecture description
    result = system.run(max_batches=4)

  build     -- compile(): parse -> rewrite -> schedule -> stage
               extraction -> chain -> plan (-> optional DSE)
  patterns  -- structural Pallas kernel dispatch for matched stages
  cli       -- ``python -m repro.flow prog.cfd --target alveo_u280``
"""
from . import build, cli, patterns
from .build import CompiledSystem, FlowError, StreamInfo, compile, resolve_target

__all__ = [
    "build", "cli", "patterns",
    "compile", "CompiledSystem", "FlowError", "StreamInfo",
    "resolve_target",
]
