"""Command-line entry point for the tool flow::

    python -m repro.flow prog.cfd --target alveo_u280 --dse

Reads a CFDlang source file, compiles it end-to-end (parse -> rewrite ->
schedule -> chain -> plan), and prints the generated-architecture report.
``--run`` additionally executes a smoke run of the planned system on
synthetic data through the chain pipeline driver.
"""
from __future__ import annotations

import argparse
import sys
from typing import Optional, Sequence

from ..core.dsl import ParseError
from ..core.ir import IRError
from . import build


def _parse_args(argv: Optional[Sequence[str]]) -> argparse.Namespace:
    ap = argparse.ArgumentParser(
        prog="python -m repro.flow",
        description="CFDlang source -> planned, executable memory "
        "architecture (the paper's automated tool flow).",
        formatter_class=argparse.RawDescriptionHelpFormatter,
        epilog=(
            "per-stage vectors:\n"
            "  --cu-count and --prefetch-depth accept one int for the\n"
            "  whole chain or a comma-separated per-stage vector, e.g.\n"
            "  '--cu-count 1,2,1' gives the middle stage two CUs and\n"
            "  '--prefetch-depth 2,1,1' runs stage 0 two host batches\n"
            "  ahead. Vector length must match the planned stage count\n"
            "  (after --fuse auto merges, one entry per ORIGINAL stage;\n"
            "  merged stages take the max of their members).\n"
            "\n"
            "worked examples and the full CLI tour (repro.flow,\n"
            "repro.serve, repro.metrics, repro.trace): docs/CLI.md\n"
        ),
    )
    ap.add_argument("source", help="CFDlang program file ('-' for stdin)")
    ap.add_argument("--target", default=None,
                    help="memory datasheet (alveo-u280, tpu-v5e, cpu-host;"
                    " default: detect)")
    ap.add_argument("--policy", default="float32")
    ap.add_argument("--backend", default="xla",
                    help="stage backend: xla | staged | pallas "
                    "(pallas falls back to xla when no kernel matches)")
    ap.add_argument("--backends", default=None,
                    help="comma-separated per-stage backends")
    ap.add_argument("--element-vars", default="",
                    help="comma-separated element vars (for sources "
                    "without 'elem' markers)")
    ap.add_argument("--max-stages", type=int, default=None,
                    help="collapse the schedule to at most this many "
                    "stages (paper's 1/2/3/7-module sweeps)")
    ap.add_argument("--fuse", choices=("auto", "off"), default=None,
                    help="'auto' makes the stage count a design axis: "
                    "adjacent stages merge whenever the planner prices "
                    "their HBM handoff above the fused roofline "
                    "(explicit cuts are never merged across)")
    ap.add_argument("--tune-blocks", action="store_true",
                    help="measure candidate VMEM block sizes per Pallas "
                    "stage and adopt the fastest (winners go to the "
                    "--profile store when given)")
    ap.add_argument("--batch-elements", type=int, default=None,
                    help="override E (default: planner auto-sizes + pads)")
    ap.add_argument("--prefetch-depth", default="1",
                    help="dispatch-ring depth per stage: one int "
                    "(chain-wide) or a comma-separated per-stage vector")
    ap.add_argument("--cu-count", default="1",
                    help="CUs per stage: one int (chain-wide) or a "
                    "comma-separated per-stage vector")
    ap.add_argument("--devices", default=None,
                    help="device topology the stage CU groups are "
                    "placed on: a size like '4', a heterogeneous spec "
                    "like 'cpu:2,tpu:4' (each group priced against its "
                    "own datasheet), or 0 to detect the local JAX "
                    "device pool (default: just enough for the widest "
                    "stage)")
    ap.add_argument("--n-eq", type=int, default=None)
    ap.add_argument("--dse", action="store_true",
                    help="sweep chain design points, adopt the best "
                    "feasible plan, and print the ranking")
    ap.add_argument("--run", action="store_true",
                    help="execute a smoke run on synthetic data")
    ap.add_argument("--max-batches", type=int, default=2,
                    help="batches for --run (default 2)")
    ap.add_argument("--serial-stages", action="store_true",
                    help="force the back-to-back stage schedule for "
                    "--run (the paper's baseline; default: the plan's "
                    "pipeline mode)")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="trace the executed run and write Chrome-trace "
                    "JSON viewable in Perfetto (implies --run); also "
                    "prints the measured: pred-vs-measured attribution")
    ap.add_argument("--profile", default=None, nargs="?", const="",
                    metavar="PATH",
                    help="persistent profile store (default path, or "
                    "$REPRO_PROFILE, when PATH is omitted): with "
                    "--trace, record the traced run into it; with "
                    "--dse, warm-start the ranking from it; requires "
                    "at least one of the two")
    ap.add_argument("--metrics", default=None, metavar="OUT.json",
                    help="meter the executed run (repro.metrics) and "
                    "write the snapshot JSON (implies --run; validate "
                    "with python -m repro.metrics)")
    return ap.parse_args(argv)


def _parse_devices(raw):
    """``None`` -> None; ``"4"`` -> 4; ``"cpu:2,tpu:4"`` passes through
    as a heterogeneous topology spec for ``build.compile`` to parse."""
    if raw is None:
        return None
    raw = str(raw).strip()
    try:
        return int(raw)
    except ValueError:
        return raw


def _parse_per_stage(raw, flag: str):
    """``"2"`` -> 2; ``"2,1,1"`` -> [2, 1, 1]; junk -> ValueError naming
    the flag (both --cu-count and --prefetch-depth accept either)."""
    try:
        parts = [c.strip() for c in str(raw).split(",")]
        return (int(parts[0]) if len(parts) == 1
                else [int(c) for c in parts])
    except ValueError:
        raise ValueError(f"bad {flag} {raw!r}") from None


def main(argv: Optional[Sequence[str]] = None) -> int:
    """CLI driver: compile/plan, then --dse/--run/--trace/--metrics as
    requested.  Exit 0 ok, 1 flow failure, 2 usage error."""
    args = _parse_args(argv)
    try:
        if args.source == "-":
            source = sys.stdin.read()
            prog_name = "stdin"
        else:
            with open(args.source) as f:
                source = f.read()
            prog_name = args.source.rsplit("/", 1)[-1]
            if prog_name.endswith(".cfd"):
                prog_name = prog_name[:-4]
    except OSError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    element_vars = tuple(
        v.strip() for v in args.element_vars.split(",") if v.strip()
    )
    backends = None
    if args.backends:
        backends = tuple(b.strip() for b in args.backends.split(","))
    try:
        cu_count = _parse_per_stage(args.cu_count, "--cu-count")
        prefetch_depth = _parse_per_stage(
            args.prefetch_depth, "--prefetch-depth"
        )
        devices = _parse_devices(args.devices)
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    if (args.profile is not None and not args.trace and not args.dse
            and not args.tune_blocks):
        # a silently inert flag is worse than an error: recording needs a
        # traced run, warm-starting needs a DSE sweep or a block tune
        print(
            "error: --profile does nothing without --trace (record the "
            "run), --dse (warm-start the ranking), or --tune-blocks "
            "(record the winners)",
            file=sys.stderr,
        )
        return 2
    profile = (args.profile or True) if args.profile is not None else None
    try:
        system = build.compile(
            source,
            name=prog_name,
            element_vars=element_vars,
            target=args.target,
            policy=args.policy,
            backend=args.backend,
            backends=backends,
            max_stages=args.max_stages,
            batch_elements=args.batch_elements,
            prefetch_depth=prefetch_depth,
            cu_count=cu_count,
            devices=devices,
            n_eq=args.n_eq,
            dse=args.dse,
            fuse=args.fuse,
            tune_blocks=args.tune_blocks,
            profile=(
                profile if (args.dse or args.tune_blocks) else None
            ),
        )
    except (ParseError, build.FlowError, IRError, ValueError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    print(system.report())
    if args.dse and system.candidates is not None:
        from ..memory.dse import format_chain_ranking

        print()
        print("dse ranking (top 10):")
        print(format_chain_ranking(system.candidates, limit=10))
    if args.run or args.trace or args.metrics:
        tracer = None
        if args.trace:
            from .. import trace as trace_mod

            tracer = trace_mod.Tracer()
        metrics = None
        if args.metrics:
            from .. import metrics as metrics_mod

            metrics = metrics_mod.MetricsRegistry()
        res = system.run(
            max_batches=args.max_batches,
            pipeline_stages=False if args.serial_stages else None,
            tracer=tracer,
            metrics=metrics,
        )
        print()
        print(
            f"ran {res.batches} batches x {res.plan.batch_elements} "
            f"elements in {res.wall_s:.3f}s "
            f"({'stage-pipelined' if res.pipelined_stages else 'serial'} "
            "schedule)"
        )
        for q, v in sorted(res.checksums.items()):
            print(f"  checksum {q} = {v:.6g}")
        if tracer is not None:
            trace_mod.write_chrome(
                tracer, args.trace, metadata={"source": prog_name}
            )
            print()
            print(
                f"trace written to {args.trace} "
                "(load in Perfetto / chrome://tracing)"
            )
            print()
            print(trace_mod.attribution_report(tracer, system.plan))
            if args.profile is not None:
                store = trace_mod.ProfileStore(path=args.profile or None)
                got = store.record_trace(tracer, system.plan)
                print()
                print(
                    f"profile: recorded {got} samples -> {store.path}"
                )
        if metrics is not None:
            from ..metrics import write_snapshot

            snap = write_snapshot(metrics, args.metrics)
            print()
            print(
                f"metrics written to {args.metrics} "
                f"({len(snap['metrics'])} series)"
            )
    return 0
