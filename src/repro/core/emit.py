"""Backend: IR -> executable JAX (the C99-emission analogue).

Where the paper emits HLS-ready C99 + pragmas and lets Vitis build the CU,
we emit JAX callables and let XLA (or Pallas, for matched patterns) build
the TPU program.  Three backends, mirroring the paper's design space:

  * ``xla``     -- the whole program as one jitted function (XLA fuses
    freely).  This is the default production path.
  * ``staged``  -- one jitted function *per scheduled group*, executed in
    sequence with materialized intermediates.  This models the FIFO-
    streamed dataflow CU and is what the per-stage analysis/benchmarks
    inspect (paper's Dataflow 1/2/3/7-compute experiments).
  * ``pallas``  -- groups whose pattern matches a hand-tiled kernel are
    dispatched to it (the fused Inverse-Helmholtz CU); everything else
    falls back to ``xla``.

Batching over the implicit element loop is vmap over axis 0 of the
element-marked inputs/outputs; sharding the element axis over the mesh is
layered on top by ``repro.cfd.simulation`` / the launchers (the paper's CU
replication).
"""
from __future__ import annotations

import dataclasses
import string
from functools import partial
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from . import ir
from .precision import FixedPointPolicy, FloatPolicy
from .schedule import Schedule, schedule as make_schedule

_LETTERS = string.ascii_letters


def einsum_spec(node: ir.Einsum) -> str:
    """Render integer index ids as an einsum subscript string."""
    ids: List[int] = []
    for subs in node.in_subs:
        for i in subs:
            if i not in ids:
                ids.append(i)
    if len(ids) > len(_LETTERS):
        raise ir.IRError("einsum with > 52 distinct indices")
    letter = {i: _LETTERS[k] for k, i in enumerate(ids)}
    ins = ",".join("".join(letter[i] for i in subs) for subs in node.in_subs)
    out = "".join(letter[i] for i in node.out_subs)
    return f"{ins}->{out}"


# ---------------------------------------------------------------------------
# node evaluation
# ---------------------------------------------------------------------------


def _eval_einsum_float(node: ir.Einsum, args: Sequence[jax.Array], policy: FloatPolicy):
    spec = einsum_spec(node)
    kwargs = {}
    if policy.accum_dtype is not None:
        kwargs["preferred_element_type"] = jnp.dtype(policy.accum_dtype)
    out = jnp.einsum(spec, *args, **kwargs)
    return out.astype(policy.dtype)


def _eval_einsum_fixed(node: ir.Einsum, args, policy: FixedPointPolicy):
    spec = einsum_spec(node)
    if len(args) == 1:
        # transpose/diag/reduce: integer-safe through jnp.einsum
        return jnp.einsum(spec, args[0])
    if len(args) == 2:
        return policy.contract(args[0], args[1], spec)
    # n-ary: left-fold (the rewriter normally factorizes these away)
    raise ir.IRError(
        "fixed-point backend requires factorized (binary) einsums; "
        "run rewrite.optimize first"
    )


def _eval_ewise(node: ir.Ewise, args, policy):
    if isinstance(policy, FixedPointPolicy):
        if node.op == "add":
            return policy.fadd(*args)
        if node.op == "sub":
            return policy.fsub(*args)
        if node.op == "mul":
            return policy.fmul(*args)
        if node.op == "div":
            return policy.fdiv(*args)
        raise ir.IRError(f"fixed-point ewise {node.op} unsupported")
    a = args[0]
    if node.op == "add":
        return a + args[1]
    if node.op == "sub":
        return a - args[1]
    if node.op == "mul":
        return a * args[1]
    if node.op == "div":
        return a / args[1]
    if node.op == "neg":
        return -a
    if node.op == "scale":
        return a * node.const
    raise ir.IRError(f"unknown ewise op {node.op}")


def evaluate(
    prog: ir.Program,
    env: Dict[str, jax.Array],
    policy=FloatPolicy("float32"),
) -> Dict[str, jax.Array]:
    """Evaluate the program for ONE element, given named input arrays."""
    vals: Dict[int, jax.Array] = {}
    for name, inp in prog.inputs.items():
        if name not in env:
            raise KeyError(f"missing input {name!r}")
        x = env[name]
        if isinstance(policy, FloatPolicy):
            x = jnp.asarray(x, policy.dtype)
        vals[inp.uid] = x

    for node in prog.toposort():
        if node.uid in vals:
            continue
        args = [vals[o.uid] for o in node.operands()]
        if isinstance(node, ir.Einsum):
            if isinstance(policy, FixedPointPolicy):
                vals[node.uid] = _eval_einsum_fixed(node, args, policy)
            else:
                vals[node.uid] = _eval_einsum_float(node, args, policy)
        elif isinstance(node, ir.Ewise):
            vals[node.uid] = _eval_ewise(node, args, policy)
        else:
            raise ir.IRError(f"cannot evaluate {node!r}")
    return {name: vals[n.uid] for name, n in prog.outputs.items()}


# ---------------------------------------------------------------------------
# compiled artifacts
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CompiledProgram:
    """A compiled tensor-expression program.

    ``element_fn``  -- single-element callable (dict -> dict).
    ``batched_fn``  -- vmapped over the element axis of element vars.
    ``stage_fns``   -- per-group callables (staged backend only).
    """

    program: ir.Program
    policy: object
    element_fn: Callable[..., Dict[str, jax.Array]]
    batched_fn: Callable[..., Dict[str, jax.Array]]
    schedule: Optional[Schedule] = None
    stage_fns: Optional[List[Callable]] = None
    backend: str = "xla"
    #: inputs whose device buffers XLA may reuse for outputs (a
    #: MemoryPlan hint; the driver must not reuse them after a call)
    donate_args: Tuple[str, ...] = ()

    def __call__(self, **env):
        return self.batched_fn(env)


def _element_callable(prog: ir.Program, policy) -> Callable:
    def fn(env: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
        return evaluate(prog, env, policy)

    return fn


def _batched_callable(
    prog: ir.Program,
    policy,
    *,
    donate_args: Sequence[str] = (),
    jit: bool = True,
) -> Callable:
    """Batched callable; with ``jit`` the list-form function is jitted so
    per-array donation hints (from a MemoryPlan) can be applied."""
    names = list(prog.inputs)
    elem = set(prog.element_vars)
    unknown = [n for n in donate_args if n not in names]
    if unknown:
        raise ValueError(f"donate_args not program inputs: {unknown}")

    def list_fn(*arrays):
        env = dict(zip(names, arrays))
        return evaluate(prog, env, policy)

    in_axes = tuple(0 if n in elem else None for n in names)
    vfn = jax.vmap(list_fn, in_axes=in_axes, out_axes=0)
    if jit:
        donate = tuple(i for i, n in enumerate(names) if n in donate_args)
        vfn = jax.jit(vfn, donate_argnums=donate)

    def fn(env: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
        return vfn(*[env[n] for n in names])

    return fn


def _staged_callables(
    prog: ir.Program, sched: Schedule, policy
) -> Tuple[List[Callable], Callable]:
    """One jitted fn per group; driver threads streams between them."""
    name_of: Dict[int, str] = {v.uid: k for k, v in prog.inputs.items()}

    stage_fns: List[Callable] = []
    stage_sigs: List[Tuple[List[int], List[int]]] = []
    for group in sched.groups:
        in_uids = [n.uid for n in group.in_streams]
        out_uids = [n.uid for n in group.out_streams]
        nodes = list(group.nodes)

        def run_group(args: List[jax.Array], *, _nodes=nodes, _in=tuple(in_uids)):
            vals: Dict[int, jax.Array] = dict(zip(_in, args))
            for node in _nodes:
                a = [vals[o.uid] for o in node.operands()]
                if isinstance(node, ir.Einsum):
                    if isinstance(policy, FixedPointPolicy):
                        vals[node.uid] = _eval_einsum_fixed(node, a, policy)
                    else:
                        vals[node.uid] = _eval_einsum_float(node, a, policy)
                else:
                    vals[node.uid] = _eval_ewise(node, a, policy)
            return vals

        def stage(args, _run=run_group, _out=tuple(out_uids)):
            vals = _run(list(args))
            return [vals[u] for u in _out]

        stage_fns.append(jax.jit(stage))
        stage_sigs.append((in_uids, out_uids))

    def element_fn(env: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
        live: Dict[int, jax.Array] = {}
        for k, v in prog.inputs.items():
            x = env[k]
            if isinstance(policy, FloatPolicy):
                x = jnp.asarray(x, policy.dtype)
            live[v.uid] = x
        for fn, (in_uids, out_uids) in zip(stage_fns, stage_sigs):
            outs = fn([live[u] for u in in_uids])
            live.update(dict(zip(out_uids, outs)))
        return {name: live[n.uid] for name, n in prog.outputs.items()}

    return stage_fns, element_fn


def compile_program(
    prog: ir.Program,
    *,
    policy=FloatPolicy("float32"),
    backend: str = "xla",
    vmem_budget: Optional[int] = None,
    max_groups: Optional[int] = None,
    pallas_impl: Optional[Callable] = None,
    jit: bool = True,
    donate_args: Sequence[str] = (),
) -> CompiledProgram:
    """Compile an IR program to an executable (the Olympus entry point).

    ``pallas_impl``: a callable ``(env) -> outputs`` implementing the whole
    batched program as a fused kernel; used when ``backend='pallas'``.

    ``donate_args``: input names whose buffers XLA may alias for outputs
    (a ``repro.memory`` MemoryPlan hint; ``xla`` backend only).
    """
    if donate_args and (backend != "xla" or not jit):
        raise ValueError(
            "donate_args requires the jitted 'xla' backend "
            f"(got backend={backend!r}, jit={jit})"
        )
    sched = None
    if backend in ("staged",) or vmem_budget is not None or max_groups is not None:
        kwargs = {}
        if vmem_budget is not None:
            kwargs["vmem_budget"] = vmem_budget
        if max_groups is not None:
            kwargs["max_groups"] = max_groups
        bps = policy.bits // 8
        sched = make_schedule(prog, bytes_per_scalar=bps, **kwargs)

    if backend == "pallas":
        if pallas_impl is None:
            raise ValueError("backend='pallas' requires pallas_impl")
        batched = pallas_impl
        element = _element_callable(prog, policy)
        return CompiledProgram(
            program=prog, policy=policy, element_fn=element,
            batched_fn=jax.jit(batched) if jit else batched,
            schedule=sched, backend="pallas",
        )

    if backend == "staged":
        stage_fns, element = _staged_callables(prog, sched, policy)
        names = list(prog.inputs)
        elem = set(prog.element_vars)

        def list_fn(*arrays):
            return element(dict(zip(names, arrays)))

        in_axes = tuple(0 if n in elem else None for n in names)
        vfn = jax.vmap(list_fn, in_axes=in_axes, out_axes=0)

        def batched(env):
            return vfn(*[env[n] for n in names])

        return CompiledProgram(
            program=prog, policy=policy, element_fn=element,
            batched_fn=batched, schedule=sched, stage_fns=stage_fns,
            backend="staged",
        )

    # default: xla
    element = _element_callable(prog, policy)
    batched = _batched_callable(prog, policy, donate_args=donate_args, jit=jit)
    return CompiledProgram(
        program=prog, policy=policy, element_fn=element,
        batched_fn=batched, schedule=sched, backend="xla",
        donate_args=tuple(donate_args),
    )
