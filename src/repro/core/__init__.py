"""The paper's primary contribution as a composable JAX module:

DSL front-end (`dsl`), value-based tensor IR (`ir`), middle-end rewrites
(`rewrite`: contraction factorization / CSE), dataflow-group scheduling
(`schedule`), buffer-liveness sharing (`liveness`), scalar precision
policies (`precision`), and the JAX/Pallas backend (`emit`, `api`).
"""
from . import api, dsl, emit, ir, liveness, precision, rewrite, schedule

__all__ = [
    "api", "dsl", "emit", "ir", "liveness", "precision", "rewrite",
    "schedule",
]
