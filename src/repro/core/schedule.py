"""Operator scheduling: partition the tensor value graph into dataflow
groups (paper section 3.4.3).

The paper's heuristic, ported to the TPU cost model:

  * start with the most aggressive partition -- one group per tensor value;
  * collapse chains greedily under a *memory budget* (PLM/DSP on the FPGA,
    VMEM bytes here) because fewer stages use fewer resources;
  * the group with the longest interval (cycle estimate ~ sum of trip
    counts ~ FLOPs here) lower-bounds the pipeline latency, so that
    interval is used as the collapse budget: merging must never create a
    group longer than the current critical group.

On TPU the "streams" between groups are HBM round-trips (group boundary =
materialized intermediate), while everything inside one group stays in
VMEM of a single fused kernel.  So the schedule directly controls the
memory-roofline term; the perf loop (EXPERIMENTS.md section Perf) iterates
on this structure.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Set, Tuple

from . import ir

#: Default budget: a fused group's working set must fit comfortably in
#: TPU v5e VMEM (128 MiB per core; keep half for double buffering).
DEFAULT_VMEM_BUDGET = 64 * 1024 * 1024


@dataclasses.dataclass
class Group:
    """One dataflow stage: a connected set of IR nodes.

    ``bytes_per_scalar`` records the scalar width of the policy the
    schedule was built for; byte-count methods default to it, so a
    bfloat16 schedule reports 2-byte streams without every caller having
    to re-thread the width (historically they defaulted to 4, silently
    disagreeing with low-precision policies).
    """

    nodes: List[ir.Node]
    #: values flowing in from other groups or program inputs
    in_streams: List[ir.Node]
    #: values consumed by later groups or program outputs
    out_streams: List[ir.Node]
    name: str = ""
    bytes_per_scalar: int = 4

    @property
    def flops(self) -> int:
        return sum(n.flops() for n in self.nodes)

    def _bps(self, bytes_per_scalar: int | None) -> int:
        return (
            self.bytes_per_scalar
            if bytes_per_scalar is None else bytes_per_scalar
        )

    def working_set(self, bytes_per_scalar: int | None = None) -> int:
        """Bytes resident while the group executes: inputs + outputs +
        internal temporaries (before liveness sharing)."""
        bps = self._bps(bytes_per_scalar)
        vals: Set[int] = set()
        total = 0
        for n in list(self.nodes) + list(self.in_streams):
            if n.uid not in vals:
                vals.add(n.uid)
                total += n.size * bps
        return total

    def in_stream_bytes(self, bytes_per_scalar: int | None = None) -> int:
        """Bytes flowing into this group per element (HBM reads)."""
        return sum(n.size for n in self.in_streams) * self._bps(
            bytes_per_scalar
        )

    def out_stream_bytes(self, bytes_per_scalar: int | None = None) -> int:
        """Bytes this group materializes per element (HBM writes)."""
        return sum(n.size for n in self.out_streams) * self._bps(
            bytes_per_scalar
        )


@dataclasses.dataclass
class Schedule:
    groups: List[Group]
    program: ir.Program
    #: scalar width the schedule was built for (policy.bits // 8); byte
    #: methods use it when no explicit width is passed
    bytes_per_scalar: int = 4

    @property
    def critical_flops(self) -> int:
        """The longest group bounds pipeline throughput (paper 3.4.3)."""
        return max(g.flops for g in self.groups) if self.groups else 0

    def _bps(self, bytes_per_scalar: int | None) -> int:
        return (
            self.bytes_per_scalar
            if bytes_per_scalar is None else bytes_per_scalar
        )

    def stream_bytes(
        self, bytes_per_scalar: int | None = None
    ) -> Dict[str, int]:
        """Bytes each group materializes across its boundary, per element
        (the HBM round-trip cost the memory planner prices)."""
        bps = self._bps(bytes_per_scalar)
        return {
            g.name: g.out_stream_bytes(bps) for g in self.groups
        }

    def stream_io_bytes(
        self, bytes_per_scalar: int | None = None
    ) -> Dict[str, Tuple[int, int]]:
        """Per-group (in, out) stream bytes per element -- the planner's
        view of every dataflow edge (paper Fig. 14's FIFO widths)."""
        bps = self._bps(bytes_per_scalar)
        return {
            g.name: (
                g.in_stream_bytes(bps),
                g.out_stream_bytes(bps),
            )
            for g in self.groups
        }

    def summary(self, bytes_per_scalar: int | None = None) -> str:
        bps = self._bps(bytes_per_scalar)
        lines = [
            f"{'group':<12} {'nodes':>5} {'flops':>12} {'ws_bytes':>10} "
            f"{'in_B':>8} {'out_B':>8}"
        ]
        for g in self.groups:
            lines.append(
                f"{g.name:<12} {len(g.nodes):>5} {g.flops:>12} "
                f"{g.working_set(bps):>10} "
                f"{g.in_stream_bytes(bps):>8} "
                f"{g.out_stream_bytes(bps):>8}"
            )
        return "\n".join(lines)


def schedule(
    prog: ir.Program,
    *,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
    bytes_per_scalar: int = 4,
    max_groups: int | None = None,
) -> Schedule:
    """Greedy chain-collapse scheduling (paper heuristic).

    ``max_groups`` optionally forces further collapsing (the paper's
    1/2/3/7-compute-module experiments are reproduced by sweeping it).
    """
    order = [n for n in prog.toposort() if not isinstance(n, ir.Input)]
    if not order:
        return Schedule(
            groups=[], program=prog, bytes_per_scalar=bytes_per_scalar
        )

    # --- initial partition: one group per value --------------------------
    group_of: Dict[int, int] = {n.uid: i for i, n in enumerate(order)}
    members: Dict[int, List[ir.Node]] = {i: [n] for i, n in enumerate(order)}

    uses: Dict[int, List[ir.Node]] = {}
    for n in order:
        for op in n.operands():
            uses.setdefault(op.uid, []).append(n)
    outputs = {v.uid for v in prog.outputs.values()}

    def group_flops(gid: int) -> int:
        return sum(n.flops() for n in members[gid])

    def group_ws(gid: int) -> int:
        vals: Set[int] = set()
        tot = 0
        node_uids = {n.uid for n in members[gid]}
        for n in members[gid]:
            for v in (n, *n.operands()):
                if v.uid not in vals:
                    vals.add(v.uid)
                    tot += v.size * bytes_per_scalar
        return tot

    critical = max(group_flops(i) for i in members)

    # --- collapse chains: producer feeding a single consumer -------------
    def try_merge(budget_flops: int) -> bool:
        merged_any = False
        for n in order:
            gid = group_of[n.uid]
            users = [u for u in uses.get(n.uid, []) if group_of[u.uid] != gid]
            distinct = {group_of[u.uid] for u in users}
            if len(distinct) != 1 or n.uid in outputs:
                continue
            tgt = distinct.pop()
            combined_flops = group_flops(gid) + group_flops(tgt)
            if combined_flops > budget_flops:
                continue
            # memory check on the union
            union_nodes = members[gid] + members[tgt]
            vals: Set[int] = set()
            ws = 0
            for m in union_nodes:
                for v in (m, *m.operands()):
                    if v.uid not in vals:
                        vals.add(v.uid)
                        ws += v.size * bytes_per_scalar
            if ws > vmem_budget:
                continue
            for m in members[gid]:
                group_of[m.uid] = tgt
            members[tgt] = members[gid] + members[tgt]
            del members[gid]
            merged_any = True
        return merged_any

    # collapse under the critical interval first (never lengthen the
    # critical path), then, if a stage-count target is given, relax.
    while try_merge(critical):
        pass
    if max_groups is not None:
        budget = critical
        while len(members) > max_groups:
            budget *= 2
            if not try_merge(budget):
                if budget > sum(n.flops() for n in order) * 4:
                    break

    # --- build Group objects in topo order --------------------------------
    gids_in_order: List[int] = []
    for n in order:
        gid = group_of[n.uid]
        if gid not in gids_in_order:
            gids_in_order.append(gid)

    groups: List[Group] = []
    for idx, gid in enumerate(gids_in_order):
        nodes = [n for n in order if group_of[n.uid] == gid]
        node_uids = {n.uid for n in nodes}
        ins: List[ir.Node] = []
        seen_in: Set[int] = set()
        for n in nodes:
            for op in n.operands():
                if op.uid not in node_uids and op.uid not in seen_in:
                    seen_in.add(op.uid)
                    ins.append(op)
        outs: List[ir.Node] = []
        for n in nodes:
            external_use = any(
                group_of[u.uid] != gid for u in uses.get(n.uid, [])
            )
            if external_use or n.uid in outputs:
                outs.append(n)
        groups.append(
            Group(nodes=nodes, in_streams=ins, out_streams=outs,
                  name=f"g{idx}", bytes_per_scalar=bytes_per_scalar)
        )

    # human-friendly names for the paper's canonical 3-stage split
    if len(groups) == 3:
        groups[0].name, groups[1].name, groups[2].name = (
            "gemm", "mmult", "gemm_inv",
        )
    return Schedule(
        groups=groups, program=prog, bytes_per_scalar=bytes_per_scalar
    )


def stage_partition(sched: Schedule) -> List[List[ir.Node]]:
    """Scheduled groups as pipeline-stage node lists (the ``repro.flow``
    stage-extraction hook).

    Group boundaries become chain-stage boundaries, with one adjustment:
    a group containing no element-dependent work (a pure function of
    shared operands, e.g. a precomputed operator product) cannot stream
    batches on its own, so its nodes are duplicated into *every* group
    that consumes one of its values -- folding into only the earliest
    consumer would leave the later consumers reading an element-free
    cross-stage stream, which the flow rejects (it pipelines element
    streams only).  The recompute is batch-invariant and tiny, exactly
    the paper's precomputed-operand case.  Node order inside each stage
    follows the program's topological order.
    """
    prog = sched.program
    elem_dep = prog.element_dependent_uids()
    topo_pos = {n.uid: i for i, n in enumerate(prog.toposort())}

    stages: List[List[ir.Node]] = [list(g.nodes) for g in sched.groups]
    # fold element-free groups forward, last-to-first so cascades settle
    for i in range(len(stages) - 1, -1, -1):
        if any(n.uid in elem_dep for n in stages[i]):
            continue
        produced = {n.uid for n in stages[i]}
        consumers = [
            j for j in range(i + 1, len(stages))
            if any(
                op.uid in produced
                for n in stages[j] for op in n.operands()
            )
        ]
        if not consumers:
            continue  # feeds nothing later (an element-free output)
        for j in consumers:
            stages[j] = stages[i] + stages[j]
        stages[i] = []
    out: List[List[ir.Node]] = []
    for s in stages:
        if not s:
            continue
        dedup = list({n.uid: n for n in s}.values())
        out.append(sorted(dedup, key=lambda n: topo_pos[n.uid]))
    return out
