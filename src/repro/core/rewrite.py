"""Middle-end rewrites (the `teil` transformation analogue).

The centerpiece is *contraction factorization* (paper Fig. 10): a
contraction applied to a chain of outer products, e.g. the Inverse
Helmholtz stage ``(S (x) S (x) S (x) u)`` contracted over three index
pairs, is O(p^6) if evaluated literally.  Associativity/distributivity let
the contraction be pulled down onto the factors, yielding a chain of three
O(p^4) GEMMs.  We implement this as:

  1. ``flatten_products``  -- inline pure-product operands into their
     consuming einsum, producing one multi-operand einsum ("operator
     graph" view);
  2. ``factorize``         -- optimal binary contraction tree via
     dynamic programming over operand subsets (exact for <= 10 operands,
     greedy beyond), replacing the node with a chain of binary einsums;
  3. ``cse`` / dead code   -- hash-consing; DCE is implicit (programs are
     traversed from outputs).

All rewrites are semantics-preserving over R (abstract scalars), mirroring
teil's "strictly beneficial mathematical identities".
"""
from __future__ import annotations

import itertools
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

from . import ir

# ---------------------------------------------------------------------------
# flatten: inline contraction-free einsum operands (outer products, diags,
# transposes) into the consuming einsum.
# ---------------------------------------------------------------------------


def _is_contraction_free(e: ir.Einsum) -> bool:
    return not e.contracted_ids()


def _flatten_node(n: ir.Node) -> ir.Node:
    if not isinstance(n, ir.Einsum):
        return n
    changed = True
    node = n
    while changed:
        changed = False
        for k, op in enumerate(node.ops):
            if not isinstance(op, ir.Einsum) or not _is_contraction_free(op):
                continue
            # map: child's output axis -> parent id for that axis
            axis_to_parent = dict(zip(op.out_subs, node.in_subs[k]))
            # child ids all appear in child's out_subs (contraction-free)
            new_ops: List[ir.Node] = list(node.ops[:k]) + list(op.ops) + list(
                node.ops[k + 1:]
            )
            new_subs: List[Tuple[int, ...]] = list(node.in_subs[:k])
            for child_op, child_subs in zip(op.ops, op.in_subs):
                new_subs.append(
                    tuple(axis_to_parent[cid] for cid in child_subs)
                )
            new_subs.extend(node.in_subs[k + 1:])
            node = ir.Einsum(
                shape=node.shape,
                ops=tuple(new_ops),
                in_subs=tuple(new_subs),
                out_subs=node.out_subs,
            )
            changed = True
            break
    return node


def flatten_products(prog: ir.Program) -> ir.Program:
    mapping: Dict[int, ir.Node] = {}
    for n in prog.toposort():
        if isinstance(n, ir.Einsum):
            flat = _flatten_node(n)
            if flat is not n:
                mapping[n.uid] = flat
    return prog.replace(mapping) if mapping else prog


# ---------------------------------------------------------------------------
# factorize: optimal pairwise contraction ordering (Held-Karp style DP).
# ---------------------------------------------------------------------------


def _lower_diagonals(e: ir.Einsum) -> ir.Einsum:
    """Ensure every operand has distinct subscript ids by extracting
    diagonals into unary einsums, so the DP can treat terms as id-sets."""
    new_ops: List[ir.Node] = []
    new_subs: List[Tuple[int, ...]] = []
    for op, subs in zip(e.ops, e.in_subs):
        if len(set(subs)) == len(subs):
            new_ops.append(op)
            new_subs.append(subs)
            continue
        # unary einsum taking the diagonal: keep first occurrence of each id
        kept: List[int] = []
        for s in subs:
            if s not in kept:
                kept.append(s)
        sizes = dict(zip(subs, op.shape))
        diag_node = ir.Einsum(
            shape=tuple(sizes[i] for i in kept),
            ops=(op,),
            in_subs=(subs,),
            out_subs=tuple(kept),
        )
        new_ops.append(diag_node)
        new_subs.append(tuple(kept))
    return ir.Einsum(
        shape=e.shape, ops=tuple(new_ops), in_subs=tuple(new_subs),
        out_subs=e.out_subs,
    )


def _pair_cost(
    ids_a: FrozenSet[int],
    ids_b: FrozenSet[int],
    needed_later: FrozenSet[int],
    sizes: Dict[int, int],
) -> Tuple[int, FrozenSet[int]]:
    union = ids_a | ids_b
    out = frozenset(i for i in union if i in needed_later)
    flops = 2
    for i in union:
        flops *= sizes[i]
    return flops, out


def _optimal_path(
    term_ids: List[FrozenSet[int]],
    out_ids: FrozenSet[int],
    sizes: Dict[int, int],
) -> List[Tuple[int, int]]:
    """Return a list of (i, j) merges over term indices (Held-Karp DP).

    After each merge the combined term replaces index i and index j is
    removed; indices refer to the current term list (like np.einsum_path).
    For > 10 terms fall back to greedy cheapest-pair.
    """
    n = len(term_ids)
    if n <= 1:
        return []
    if n > 10:
        return _greedy_path(term_ids, out_ids, sizes)

    full = (1 << n) - 1

    def needed_later(subset: int) -> FrozenSet[int]:
        """Ids needed outside ``subset``: program outputs + other terms."""
        need = set(out_ids)
        for k in range(n):
            if not subset & (1 << k):
                need |= term_ids[k]
        return frozenset(need)

    # DP over subsets: best[(subset)] = (cost, ids, tree)
    best: Dict[int, Tuple[int, FrozenSet[int], object]] = {}
    for k in range(n):
        best[1 << k] = (0, term_ids[k], k)
    subsets_by_size: Dict[int, List[int]] = {}
    for s in range(1, full + 1):
        subsets_by_size.setdefault(bin(s).count("1"), []).append(s)
    for size in range(2, n + 1):
        for s in subsets_by_size[size]:
            need = needed_later(s)
            best_here: Optional[Tuple[int, FrozenSet[int], object]] = None
            # iterate proper sub-splits (canonical: lowest bit stays left)
            sub = (s - 1) & s
            while sub:
                other = s ^ sub
                if sub & (s & -s):  # dedupe mirrored splits
                    if sub in best and other in best:
                        ca, ia, ta = best[sub]
                        cb, ib, tb = best[other]
                        fl, out = _pair_cost(ia, ib, need, sizes)
                        tot = ca + cb + fl
                        if best_here is None or tot < best_here[0]:
                            best_here = (tot, out, (ta, tb))
                sub = (sub - 1) & s
            assert best_here is not None
            best[s] = best_here

    # unparse tree into merge list over dynamic indices
    merges: List[Tuple[int, int]] = []

    def emit(tree: object) -> int:
        if isinstance(tree, int):
            return tree
        a, b = tree  # type: ignore[misc]
        ia, ib = emit(a), emit(b)
        merges.append((ia, ib))
        return ia

    emit(best[full][2])
    return merges


def _greedy_path(
    term_ids: List[FrozenSet[int]],
    out_ids: FrozenSet[int],
    sizes: Dict[int, int],
) -> List[Tuple[int, int]]:
    alive = {k: term_ids[k] for k in range(len(term_ids))}
    merges: List[Tuple[int, int]] = []
    while len(alive) > 1:
        best = None
        keys = sorted(alive)
        for i, j in itertools.combinations(keys, 2):
            need = set(out_ids)
            for k, ids in alive.items():
                if k != i and k != j:
                    need |= ids
            fl, out = _pair_cost(alive[i], alive[j], frozenset(need), sizes)
            if best is None or fl < best[0]:
                best = (fl, i, j, out)
        _, i, j, out = best  # type: ignore[misc]
        merges.append((i, j))
        alive[i] = out
        del alive[j]
    return merges


def _factorize_node(e: ir.Einsum) -> ir.Node:
    if len(e.ops) <= 2:
        return e
    e = _lower_diagonals(e)
    sizes = e.index_sizes()
    terms: List[ir.Node] = list(e.ops)
    ids: List[FrozenSet[int]] = [frozenset(s) for s in e.in_subs]
    subs: List[Tuple[int, ...]] = list(e.in_subs)
    out_ids = frozenset(e.out_subs)
    merges = _optimal_path(ids, out_ids, sizes)
    for i, j in merges:
        need = set(out_ids)
        for k in range(len(terms)):
            if k != i and k != j and terms[k] is not None:
                need |= ids[k]
        union_ids = ids[i] | ids[j]
        keep = tuple(sorted(x for x in union_ids if x in need))
        shape = tuple(sizes[x] for x in keep)
        node = ir.Einsum(
            shape=shape,
            ops=(terms[i], terms[j]),
            in_subs=(subs[i], subs[j]),
            out_subs=keep,
        )
        terms[i], ids[i], subs[i] = node, frozenset(keep), keep
        terms[j] = None  # type: ignore[assignment]
    root_idx = merges[-1][0] if merges else 0
    root = terms[root_idx]
    # final transpose/selection to requested output order
    if subs[root_idx] != e.out_subs:
        root = ir.Einsum(
            shape=e.shape,
            ops=(root,),
            in_subs=(subs[root_idx],),
            out_subs=e.out_subs,
        )
    return root


def factorize(prog: ir.Program) -> ir.Program:
    mapping: Dict[int, ir.Node] = {}
    for n in prog.toposort():
        if isinstance(n, ir.Einsum) and len(n.ops) > 2:
            fac = _factorize_node(n)
            if fac is not n:
                mapping[n.uid] = fac
    return prog.replace(mapping) if mapping else prog


# ---------------------------------------------------------------------------
# CSE: hash-cons structurally identical nodes (S appears three times in the
# Helmholtz chain; the rebuilt GEMM stages share it automatically).
# ---------------------------------------------------------------------------


def _canon_einsum_key(e: ir.Einsum, op_keys: Tuple[int, ...]) -> tuple:
    remap: Dict[int, int] = {}

    def c(i: int) -> int:
        if i not in remap:
            remap[i] = len(remap)
        return remap[i]

    subs = tuple(tuple(c(i) for i in s) for s in e.in_subs)
    out = tuple(c(i) for i in e.out_subs)
    return ("einsum", op_keys, subs, out, e.shape)


def cse(prog: ir.Program) -> ir.Program:
    key_to_node: Dict[tuple, ir.Node] = {}
    node_key: Dict[int, tuple] = {}
    mapping: Dict[int, ir.Node] = {}

    def keyof(n: ir.Node) -> tuple:
        return node_key[n.uid]

    for n in prog.toposort():
        if isinstance(n, ir.Input):
            k = ("input", n.name, n.shape)
        elif isinstance(n, ir.Einsum):
            k = _canon_einsum_key(n, tuple(id(key_to_node[keyof(o)]) for o in n.ops))
        elif isinstance(n, ir.Ewise):
            ops = tuple(id(key_to_node[keyof(o)]) for o in n.operands())
            k = ("ewise", n.op, n.const, ops, n.shape)
        else:
            k = ("other", n.uid)
        node_key[n.uid] = k
        if k in key_to_node:
            if key_to_node[k] is not n:
                mapping[n.uid] = key_to_node[k]
        else:
            key_to_node[k] = n
    return prog.replace(mapping) if mapping else prog


# ---------------------------------------------------------------------------
# Pipeline entry point
# ---------------------------------------------------------------------------


def optimize(prog: ir.Program, *, factorize_contractions: bool = True) -> ir.Program:
    """The standard middle-end pipeline: flatten -> factorize -> cse.

    With ``factorize_contractions=False`` the program stays in its literal
    (paper 'naive O(p^6)') form -- used as the unoptimized baseline.
    """
    prog = flatten_products(prog)
    if factorize_contractions:
        prog = factorize(prog)
    prog = cse(prog)
    return prog
