"""Public API of the tensor-expression compiler (DSL-to-executable flow).

The one-call path from CFDlang source to a batched, optimized executable::

    from repro.core import api
    compiled = api.compile_cfdlang(src, element_vars=("u", "D", "v"))
    out = compiled(S=S, D=D, u=u)        # D, u carry a leading element axis

mirroring the paper's Figure 5 (DSL-to-C generation + C-to-system
generation), with the compiler passes selectable the same way Olympus
exposes its optimizations.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

from . import dsl, emit, ir, rewrite
from .precision import F32, F64, BF16, FIXED32, FIXED64, POLICIES


def compile_cfdlang(
    src: str,
    *,
    element_vars: Sequence[str] = (),
    policy=F32,
    optimize: bool = True,
    backend: str = "xla",
    vmem_budget: Optional[int] = None,
    max_groups: Optional[int] = None,
    pallas_impl: Optional[Callable] = None,
    jit: bool = True,
    donate_args: Sequence[str] = (),
) -> emit.CompiledProgram:
    """Parse, optimize, schedule, and compile a CFDlang program."""
    if isinstance(policy, str):
        policy = POLICIES[policy]
    prog = dsl.parse(src, element_vars=element_vars)
    if optimize:
        prog = rewrite.optimize(prog)
    return emit.compile_program(
        prog,
        policy=policy,
        backend=backend,
        vmem_budget=vmem_budget,
        max_groups=max_groups,
        pallas_impl=pallas_impl,
        jit=jit,
        donate_args=donate_args,
    )


def compile_ir(
    prog: ir.Program,
    *,
    policy=F32,
    optimize: bool = True,
    backend: str = "xla",
    vmem_budget: Optional[int] = None,
    max_groups: Optional[int] = None,
    pallas_impl: Optional[Callable] = None,
    jit: bool = True,
    donate_args: Sequence[str] = (),
) -> emit.CompiledProgram:
    if isinstance(policy, str):
        policy = POLICIES[policy]
    if optimize:
        prog = rewrite.optimize(prog)
    return emit.compile_program(
        prog,
        policy=policy,
        backend=backend,
        vmem_budget=vmem_budget,
        max_groups=max_groups,
        pallas_impl=pallas_impl,
        jit=jit,
        donate_args=donate_args,
    )
