"""Value-based tensor IR (the `teil` analogue).

Tensors are immutable values produced by nodes; there is no aliasing and no
array materialization at this level (buffers are assigned later, by the
scheduler + liveness passes).  The op vocabulary is intentionally small,
mirroring TeIL:

  * ``Input``  -- a named program input.
  * ``Einsum`` -- generalized product/contract/diag/transpose.  ``prod``,
    ``cont``, ``diag``, ``red`` and ``transpose`` from the paper all lower
    onto this single node.
  * ``Ewise``  -- element-wise arithmetic between same-shape values (the
    Hadamard product in the Inverse Helmholtz operator) or with a scalar.

Index bookkeeping uses integer "index ids" rather than letters so programs
are not limited to 52 axes.  Every node knows its output shape; shape
errors are raised at construction time (mirroring MLIR verifier behavior).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

Shape = Tuple[int, ...]


class IRError(ValueError):
    """Raised on malformed IR construction (the 'verifier')."""


_node_counter = itertools.count()


@dataclasses.dataclass(eq=False)
class Node:
    """Base class for IR values."""

    shape: Shape

    def __post_init__(self) -> None:
        self.uid: int = next(_node_counter)

    # -- structural helpers -------------------------------------------------
    @property
    def rank(self) -> int:
        return len(self.shape)

    @property
    def size(self) -> int:
        n = 1
        for d in self.shape:
            n *= d
        return n

    def operands(self) -> Tuple["Node", ...]:
        return ()

    def flops(self) -> int:
        """FLOPs to produce this value from its operands (not transitive)."""
        return 0


@dataclasses.dataclass(eq=False)
class Input(Node):
    name: str = ""

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"%{self.uid} = input {self.name!r} : {list(self.shape)}"


@dataclasses.dataclass(eq=False)
class Einsum(Node):
    """Generalized einsum: multiply operands, sum over non-output ids.

    ``in_subs[k]`` gives one integer id per axis of operand ``k``;
    ``out_subs`` lists the ids of the result axes, in order.  Ids occurring
    in any ``in_subs`` but not in ``out_subs`` are contracted (summed).
    Repeated ids within one operand take the diagonal (teil.diag).
    """

    ops: Tuple[Node, ...] = ()
    in_subs: Tuple[Tuple[int, ...], ...] = ()
    out_subs: Tuple[int, ...] = ()

    def __post_init__(self) -> None:
        super().__post_init__()
        if len(self.ops) != len(self.in_subs):
            raise IRError("einsum: one subscript tuple per operand required")
        dims: Dict[int, int] = {}
        for op, subs in zip(self.ops, self.in_subs):
            if len(subs) != op.rank:
                raise IRError(
                    f"einsum: operand rank {op.rank} vs subscript rank {len(subs)}"
                )
            for idx, d in zip(subs, op.shape):
                if dims.setdefault(idx, d) != d:
                    raise IRError(
                        f"einsum: index {idx} bound to both {dims[idx]} and {d}"
                    )
        for idx in self.out_subs:
            if idx not in dims:
                raise IRError(f"einsum: output index {idx} unbound")
        expected = tuple(dims[i] for i in self.out_subs)
        if self.shape != expected:
            raise IRError(f"einsum: shape {self.shape} != inferred {expected}")
        self._dims = dims

    # -- analysis ------------------------------------------------------------
    def index_sizes(self) -> Dict[int, int]:
        return dict(self._dims)

    def contracted_ids(self) -> Tuple[int, ...]:
        seen = set(self.out_subs)
        return tuple(sorted(set(self._dims) - seen))

    def flops(self) -> int:
        """2 * prod(all index sizes) for true contractions (mul+add),
        1 * for pure products/transposes (mul only / free)."""
        total = 1
        for d in self._dims.values():
            total *= d
        if self.contracted_ids():
            return 2 * total
        if len(self.ops) > 1:
            return total  # pure (outer/Hadamard-like) product: one mul each
        return 0  # transpose / diagonal extraction

    def operands(self) -> Tuple[Node, ...]:
        return self.ops

    def __repr__(self) -> str:  # pragma: no cover
        subs = ",".join("".join(f"[{i}]" for i in s) for s in self.in_subs)
        out = "".join(f"[{i}]" for i in self.out_subs)
        return f"%{self.uid} = einsum {subs} -> {out} : {list(self.shape)}"


_EWISE_OPS = ("add", "sub", "mul", "div", "neg", "scale")


@dataclasses.dataclass(eq=False)
class Ewise(Node):
    op: str = "add"
    lhs: Optional[Node] = None
    rhs: Optional[Node] = None  # None for unary ops
    const: Optional[float] = None  # for 'scale'

    def __post_init__(self) -> None:
        super().__post_init__()
        if self.op not in _EWISE_OPS:
            raise IRError(f"ewise: unknown op {self.op}")
        if self.lhs is None:
            raise IRError("ewise: lhs required")
        if self.op in ("add", "sub", "mul", "div"):
            if self.rhs is None or self.rhs.shape != self.lhs.shape:
                raise IRError(
                    f"ewise {self.op}: shape mismatch "
                    f"{self.lhs.shape} vs {None if self.rhs is None else self.rhs.shape}"
                )
        if self.shape != self.lhs.shape:
            raise IRError("ewise: output shape must equal operand shape")

    def flops(self) -> int:
        return self.size

    def operands(self) -> Tuple[Node, ...]:
        if self.rhs is None:
            return (self.lhs,)
        return (self.lhs, self.rhs)

    def __repr__(self) -> str:  # pragma: no cover
        return f"%{self.uid} = ewise.{self.op} : {list(self.shape)}"


# ---------------------------------------------------------------------------
# Convenience constructors mirroring the teil vocabulary
# ---------------------------------------------------------------------------

def _fresh_ids(n: int, start: int = 0) -> List[int]:
    return list(range(start, start + n))


def prod(a: Node, b: Node) -> Einsum:
    """teil.prod: outer product, shape = a.shape + b.shape."""
    ia = _fresh_ids(a.rank)
    ib = _fresh_ids(b.rank, start=a.rank)
    return Einsum(
        shape=a.shape + b.shape,
        ops=(a, b),
        in_subs=(tuple(ia), tuple(ib)),
        out_subs=tuple(ia + ib),
    )


def cont(x: Node, pairs: Sequence[Tuple[int, int]]) -> Einsum:
    """CFDlang '.' contraction over axis pairs of ``x`` (sum the diagonal).

    Axis numbers refer to ``x``'s axes.  Result drops both axes of each
    pair, keeping the remaining axes in order.
    """
    ids = _fresh_ids(x.rank)
    dropped = set()
    for i, j in pairs:
        if not (0 <= i < x.rank and 0 <= j < x.rank) or i == j:
            raise IRError(f"cont: bad pair ({i},{j}) for rank {x.rank}")
        if x.shape[i] != x.shape[j]:
            raise IRError(
                f"cont: axis sizes differ {x.shape[i]} vs {x.shape[j]}"
            )
        ids[j] = ids[i]
        dropped.add(i)
        dropped.add(j)
    out = tuple(ids[k] for k in range(x.rank) if k not in dropped)
    return Einsum(
        shape=tuple(x.shape[k] for k in range(x.rank) if k not in dropped),
        ops=(x,),
        in_subs=(tuple(ids),),
        out_subs=out,
    )


def diag(x: Node, i: int, j: int) -> Einsum:
    """teil.diag: identify axes i and j (keep axis i, drop axis j)."""
    if x.shape[i] != x.shape[j]:
        raise IRError("diag: axis sizes differ")
    ids = _fresh_ids(x.rank)
    ids[j] = ids[i]
    out = tuple(ids[k] for k in range(x.rank) if k != j)
    return Einsum(
        shape=tuple(x.shape[k] for k in range(x.rank) if k != j),
        ops=(x,),
        in_subs=(tuple(ids),),
        out_subs=out,
    )


def red(x: Node, axis: int) -> Einsum:
    """teil.red add: sum-reduce over ``axis``."""
    ids = _fresh_ids(x.rank)
    out = tuple(ids[k] for k in range(x.rank) if k != axis)
    return Einsum(
        shape=tuple(x.shape[k] for k in range(x.rank) if k != axis),
        ops=(x,),
        in_subs=(tuple(ids),),
        out_subs=out,
    )


def transpose(x: Node, perm: Sequence[int]) -> Einsum:
    ids = _fresh_ids(x.rank)
    return Einsum(
        shape=tuple(x.shape[p] for p in perm),
        ops=(x,),
        in_subs=(tuple(ids),),
        out_subs=tuple(ids[p] for p in perm),
    )


def add(a: Node, b: Node) -> Ewise:
    return Ewise(shape=a.shape, op="add", lhs=a, rhs=b)


def sub(a: Node, b: Node) -> Ewise:
    return Ewise(shape=a.shape, op="sub", lhs=a, rhs=b)


def mul(a: Node, b: Node) -> Ewise:
    return Ewise(shape=a.shape, op="mul", lhs=a, rhs=b)


def div(a: Node, b: Node) -> Ewise:
    return Ewise(shape=a.shape, op="div", lhs=a, rhs=b)


# ---------------------------------------------------------------------------
# Program container
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Program:
    """A tensor-expression program (one CFDlang translation unit).

    ``element_vars`` marks which inputs carry a leading implicit element
    axis when batched (the paper's implicit outer element loop); the rest
    (e.g. the spectral operator ``S``) are shared across elements.
    """

    inputs: Dict[str, Input]
    outputs: Dict[str, Node]
    element_vars: Tuple[str, ...] = ()
    temps: Dict[str, Node] = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        for v in self.element_vars:
            if v not in self.inputs and v not in self.outputs:
                raise IRError(f"element var {v!r} is not an input or output")

    # -- traversal -----------------------------------------------------------
    def toposort(self) -> List[Node]:
        """All nodes reachable from outputs, topologically ordered."""
        order: List[Node] = []
        seen = set()

        def visit(n: Node) -> None:
            if n.uid in seen:
                return
            seen.add(n.uid)
            for op in n.operands():
                visit(op)
            order.append(n)

        for out in self.outputs.values():
            visit(out)
        return order

    def total_flops(self) -> int:
        return sum(n.flops() for n in self.toposort())

    def element_dependent_uids(self) -> set:
        """Uids of values that (transitively) depend on an element-marked
        input -- i.e. values that carry the implicit element axis when the
        program is batched.  Everything else is batch-invariant (computed
        once from shared operands, like the paper's S matrix)."""
        dep = {
            v.uid for n, v in self.inputs.items() if n in self.element_vars
        }
        for node in self.toposort():
            if any(op.uid in dep for op in node.operands()):
                dep.add(node.uid)
        return dep

    def replace(self, mapping: Dict[int, Node]) -> "Program":
        """Return a program with nodes substituted per ``mapping`` (uid->node),
        rebuilding downstream nodes so operand links stay consistent."""
        cache: Dict[int, Node] = {}

        def rebuild(n: Node) -> Node:
            if n.uid in cache:
                return cache[n.uid]
            if n.uid in mapping and mapping[n.uid] is not n:
                # Rebuild *through* the replacement: its operands may refer
                # to nodes that are themselves mapped (e.g. a factorized
                # einsum consuming another rewritten value).
                rep = rebuild(mapping[n.uid])
                cache[n.uid] = rep
                return rep
            ops = n.operands()
            new_ops = tuple(rebuild(o) for o in ops)
            if all(a is b for a, b in zip(new_ops, ops)):
                cache[n.uid] = n
                return n
            if isinstance(n, Einsum):
                rep = Einsum(
                    shape=n.shape, ops=new_ops, in_subs=n.in_subs,
                    out_subs=n.out_subs,
                )
            elif isinstance(n, Ewise):
                rep = Ewise(
                    shape=n.shape, op=n.op, lhs=new_ops[0],
                    rhs=new_ops[1] if len(new_ops) > 1 else None,
                    const=n.const,
                )
            else:  # Input has no operands; unreachable
                rep = n
            cache[n.uid] = rep
            return rep

        new_outputs = {k: rebuild(v) for k, v in self.outputs.items()}
        return Program(
            inputs=self.inputs,
            outputs=new_outputs,
            element_vars=self.element_vars,
            temps={k: rebuild(v) for k, v in self.temps.items()},
        )

    def pretty(self) -> str:
        lines = []
        names = {v.uid: f"@{k}" for k, v in self.inputs.items()}
        for n in self.toposort():
            tag = names.get(n.uid, "")
            lines.append(f"{n!r} {tag}")
        for k, v in self.outputs.items():
            lines.append(f"yield @{k} = %{v.uid}")
        return "\n".join(lines)


def subprogram(
    nodes: Sequence[Node],
    inputs: Dict[str, Node],
    outputs: Dict[str, Node],
    element_vars: Sequence[str] = (),
) -> Program:
    """Rebuild a slice of a larger program as a standalone :class:`Program`.

    ``nodes`` are the slice's computation (topologically ordered);
    ``inputs`` names every boundary value the slice consumes (original
    program inputs or values produced outside the slice) -- each becomes a
    fresh :class:`Input` of the same shape; ``outputs`` names the slice's
    boundary results.  This is what the ``repro.flow`` stage extraction
    uses to turn scheduled groups into chain-stage programs.
    """
    placeholders: Dict[int, Node] = {
        v.uid: Input(shape=v.shape, name=name) for name, v in inputs.items()
    }
    rebuilt: Dict[int, Node] = dict(placeholders)
    for n in nodes:
        if n.uid in rebuilt:
            continue
        try:
            new_ops = tuple(rebuilt[op.uid] for op in n.operands())
        except KeyError as e:
            raise IRError(
                f"subprogram: node %{n.uid} consumes a value "
                f"({e.args[0]}) that is neither in the slice nor a "
                "declared boundary input"
            ) from e
        if isinstance(n, Einsum):
            rebuilt[n.uid] = Einsum(
                shape=n.shape, ops=new_ops, in_subs=n.in_subs,
                out_subs=n.out_subs,
            )
        elif isinstance(n, Ewise):
            rebuilt[n.uid] = Ewise(
                shape=n.shape, op=n.op, lhs=new_ops[0],
                rhs=new_ops[1] if len(new_ops) > 1 else None,
                const=n.const,
            )
        else:
            raise IRError(f"subprogram: cannot rebuild {n!r}")
    new_outputs: Dict[str, Node] = {}
    for name, v in outputs.items():
        if v.uid not in rebuilt:
            raise IRError(
                f"subprogram: output {name!r} is not produced by the slice"
            )
        new_outputs[name] = rebuilt[v.uid]
    return Program(
        inputs={name: placeholders[v.uid] for name, v in inputs.items()},
        outputs=new_outputs,
        element_vars=tuple(element_vars),
    )
