"""Buffer liveness + sharing plan (the Mnemosyne analogue).

Mnemosyne assigns kernel-internal arrays with disjoint lifetimes to the
same physical BRAM banks.  On TPU the scarce tier is VMEM scratch inside a
fused kernel (and, at the XLA level, donated HBM buffers).  We compute the
same interval-graph coloring:

  * linear-scan liveness over the topological order of a group;
  * greedy first-fit assignment of values to *slots*, where a slot can be
    reused once every reader of its previous occupant has executed;
  * slots are size-classed by byte size (a value only reuses a slot at
    least as large as itself).

The resulting plan feeds (a) `scratch_shapes` sizing for fused Pallas
kernels and (b) the memory-sharing numbers reported in the benchmarks
(paper Table 3, "Mem Sharing" row).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

from . import ir
from .schedule import Group


@dataclasses.dataclass
class SharingPlan:
    #: value uid -> slot index
    slot_of: Dict[int, int]
    #: slot index -> byte size
    slot_bytes: List[int]
    #: total bytes without sharing
    naive_bytes: int

    @property
    def shared_bytes(self) -> int:
        return sum(self.slot_bytes)

    @property
    def savings_frac(self) -> float:
        if self.naive_bytes == 0:
            return 0.0
        return 1.0 - self.shared_bytes / self.naive_bytes


def liveness_intervals(
    nodes: Sequence[ir.Node],
) -> Dict[int, Tuple[int, int]]:
    """[def, last_use] index intervals over the given order."""
    pos = {n.uid: i for i, n in enumerate(nodes)}
    last_use: Dict[int, int] = {n.uid: pos[n.uid] for n in nodes}
    for i, n in enumerate(nodes):
        for op in n.operands():
            if op.uid in last_use:
                last_use[op.uid] = max(last_use[op.uid], i)
    return {uid: (pos[uid], last_use[uid]) for uid in pos}


def plan_sharing(group: Group, bytes_per_scalar: int = 4) -> SharingPlan:
    """First-fit interval packing of the group's internal values.

    Streams (group inputs/outputs) are excluded: they are pinned for the
    whole stage, exactly as Mnemosyne only shares kernel-local buffers.
    """
    pinned = {n.uid for n in group.in_streams} | {
        n.uid for n in group.out_streams
    }
    internal = [n for n in group.nodes if n.uid not in pinned]
    intervals = liveness_intervals(group.nodes)

    slot_of: Dict[int, int] = {}
    slot_bytes: List[int] = []
    slot_free_at: List[int] = []  # order index after which the slot is free
    naive = 0
    for n in sorted(internal, key=lambda m: intervals[m.uid][0]):
        size = n.size * bytes_per_scalar
        naive += size
        start, end = intervals[n.uid]
        placed = False
        for s in range(len(slot_bytes)):
            if slot_free_at[s] < start and slot_bytes[s] >= size:
                slot_of[n.uid] = s
                slot_free_at[s] = end
                placed = True
                break
        if not placed:
            slot_of[n.uid] = len(slot_bytes)
            slot_bytes.append(size)
            slot_free_at.append(end)
    return SharingPlan(slot_of=slot_of, slot_bytes=slot_bytes, naive_bytes=naive)


def plan_program(groups: Sequence[Group], bytes_per_scalar: int = 4) -> Dict[str, SharingPlan]:
    return {g.name: plan_sharing(g, bytes_per_scalar) for g in groups}


# ---------------------------------------------------------------------------
# cross-stage stream classification (the repro.flow residency hook)
# ---------------------------------------------------------------------------

#: classification labels for values crossing a stage boundary
STREAM_RESIDENT = "resident"   # consumed by a later stage only: stays in HBM
STREAM_HOST = "host"           # program output only: crosses the host link
STREAM_BOTH = "both"           # program output also consumed downstream


def classify_boundary_streams(
    prog, stage_nodes: Sequence[Sequence["ir.Node"]]
) -> Dict[int, str]:
    """Classify every value that crosses a stage boundary.

    Given a partition of the program's nodes into pipeline stages (see
    ``schedule.stage_partition``), the liveness of each produced value
    decides where it lives: a value whose only readers are later stages
    never needs the host link (``resident`` -- the chain planner prices
    it as an HBM round-trip), a program output with no later readers is
    ``host``-streamed, and an output that later stages also read is
    ``both``.  Values consumed only inside their producing stage do not
    appear in the result.
    """
    stage_of: Dict[int, int] = {}
    stage_sets = [
        {n.uid for n in nodes} for nodes in stage_nodes
    ]
    for i, nodes in enumerate(stage_nodes):
        for n in nodes:
            stage_of[n.uid] = i
    output_uids = {v.uid for v in prog.outputs.values()}
    crossers: Dict[int, str] = {}
    for i, nodes in enumerate(stage_nodes):
        for n in nodes:
            for op in n.operands():
                if op.uid in stage_sets[i]:
                    # produced in this very stage (possibly a duplicated
                    # element-free node): no boundary crossing
                    continue
                if op.uid in stage_of:
                    crossers[op.uid] = (
                        STREAM_BOTH if op.uid in output_uids
                        else STREAM_RESIDENT
                    )
    for uid in output_uids:
        if uid in stage_of and uid not in crossers:
            crossers[uid] = STREAM_HOST
    return crossers
