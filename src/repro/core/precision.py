"""Scalar-type policies (the `base2` dialect analogue).

The paper treats the scalar representation as a compiler knob: double,
then fixed-point ap_fixed<64,24> (Q24.40) and ap_fixed<32,8> (Q8.24),
validated at MSE 9.39e-22 and 3.58e-12 respectively on [-1, 1]-normalized
CFD data.  We keep the exact Q-formats, implemented with JAX integer
arithmetic, plus the TPU-native float ladder (f64/f32/bf16) which is the
MXU's own "cheap multiplier" analogue.

Fixed-point evaluation requires 64-bit integers and therefore runs under
``jax.enable_x64`` (the emitter wraps calls).  Like the paper, the
conversion from/to double lives on the host side of the boundary
(``encode``/``decode``), and the compute graph stays in integer form.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

try:  # newer jax exposes the x64 context manager at top level
    enable_x64 = jax.enable_x64
except AttributeError:
    from jax.experimental import enable_x64


@dataclasses.dataclass(frozen=True)
class FloatPolicy:
    """Plain float computation at a given dtype."""

    dtype: str = "float32"  # float64 | float32 | bfloat16
    accum_dtype: Optional[str] = None  # einsum accumulation type

    @property
    def name(self) -> str:
        return self.dtype

    @property
    def is_fixed_point(self) -> bool:
        return False

    @property
    def bits(self) -> int:
        return jnp.dtype(self.dtype).itemsize * 8


@dataclasses.dataclass(frozen=True)
class FixedPointPolicy:
    """Qm.n fixed point: ``total_bits`` storage with ``frac_bits`` fraction.

    The paper's formats:
      * fixed64 = Q24.40 -> FixedPointPolicy(64, 40)
      * fixed32 = Q8.24  -> FixedPointPolicy(32, 24)

    Values are assumed range-normalized (|x| bounded by the integer part),
    matching the paper's observation that the physical quantities can be
    rescaled into [-1, 1].
    """

    total_bits: int = 32
    frac_bits: int = 24

    def __post_init__(self) -> None:
        if self.total_bits not in (32, 64):
            raise ValueError("fixed point storage must be int32 or int64")
        if not 0 < self.frac_bits < self.total_bits:
            raise ValueError("frac_bits out of range")

    @property
    def name(self) -> str:
        m = self.total_bits - self.frac_bits
        return f"fixed{self.total_bits}_q{m}.{self.frac_bits}"

    @property
    def is_fixed_point(self) -> bool:
        return True

    @property
    def bits(self) -> int:
        return self.total_bits

    @property
    def storage_dtype(self):
        return jnp.int32 if self.total_bits == 32 else jnp.int64

    @property
    def scale(self) -> float:
        return float(2 ** self.frac_bits)

    # -- host-side conversions (paper: done in host code, saves FPGA area) --
    def encode(self, x) -> jax.Array:
        scaled = jnp.round(jnp.asarray(x, jnp.float64) * self.scale)
        return scaled.astype(self.storage_dtype)

    def decode(self, q) -> jax.Array:
        return q.astype(jnp.float64) / self.scale

    # -- device-side arithmetic ---------------------------------------------
    def fadd(self, a, b):
        return a + b

    def fsub(self, a, b):
        return a - b

    def fmul(self, a, b):
        """(a * b) >> frac_bits with a wide intermediate, round-to-nearest.

        int32 storage: exact via an int64 intermediate.
        int64 storage: the 128-bit product is emulated by a 32/32 limb
        split.  ``al*bl`` is computed in uint64 (exact: both < 2^32);
        cross terms fit signed int64 while |q-values| < 2^31 on the high
        limb, i.e. decoded magnitudes < 2^23 for Q24.40 -- exactly the
        headroom the paper's 24 integer bits provide.
        """
        f = self.frac_bits
        if self.total_bits == 32:
            wide = a.astype(jnp.int64) * b.astype(jnp.int64)
            wide = wide + (np.int64(1) << (f - 1))  # round to nearest
            return (wide >> f).astype(self.storage_dtype)
        # int64 path: a = ah*2^32 + al, b = bh*2^32 + bl (al, bl unsigned).
        mask = (np.int64(1) << 32) - 1
        ah, al = a >> 32, (a & mask).astype(jnp.uint64)
        bh, bl = b >> 32, (b & mask).astype(jnp.uint64)
        lo = ((al * bl) >> np.uint64(f)).astype(jnp.int64)  # exact in uint64
        cross = ah * bl.astype(jnp.int64) + al.astype(jnp.int64) * bh
        shift = f - 32  # f > 32 for Q24.40
        cross = (cross + (np.int64(1) << (shift - 1))) >> shift
        hi = (ah * bh) << (64 - f)
        return hi + cross + lo

    def fdiv(self, a, b):
        wide_a = a.astype(jnp.int64) << self.frac_bits if self.total_bits == 32 else a << 0
        if self.total_bits == 32:
            return (wide_a // b.astype(jnp.int64)).astype(self.storage_dtype)
        # 64-bit: divide via float64 reciprocal (documented approximation)
        rec = 1.0 / (b.astype(jnp.float64) / self.scale)
        return self.encode(self.decode(a) * rec)

    def contract(self, a, b, subscripts: str):
        """Fixed-point einsum: per-product rescale, then integer sum.

        Products are shifted *before* accumulation so partial sums stay in
        range (the HLS flow sizes its accumulators identically).  The
        contraction is expressed as broadcast-multiply + sum, acceptable
        at CFD operator sizes (p <= 16)."""
        in_spec, out_spec = subscripts.split("->")
        sa, sb = in_spec.split(",")
        # broadcast to the union index space
        union = sa + "".join(c for c in sb if c not in sa)
        dims = {}
        for c, d in zip(sa, a.shape):
            dims[c] = d
        for c, d in zip(sb, b.shape):
            dims[c] = d
        def expand(x, s):
            shape = tuple(dims[c] if c in s else 1 for c in union)
            perm_src = [s.index(c) for c in union if c in s]
            x = jnp.transpose(x, perm_src)
            return jnp.reshape(x, shape)

        prod = self.fmul(
            jnp.broadcast_to(expand(a, sa), tuple(dims[c] for c in union)),
            jnp.broadcast_to(expand(b, sb), tuple(dims[c] for c in union)),
        )
        sum_axes = tuple(i for i, c in enumerate(union) if c not in out_spec)
        res = jnp.sum(prod, axis=sum_axes, dtype=self.storage_dtype)
        # reorder to out_spec
        remaining = [c for c in union if c in out_spec]
        perm = [remaining.index(c) for c in out_spec]
        return jnp.transpose(res, perm)


Policy = object  # FloatPolicy | FixedPointPolicy

F64 = FloatPolicy("float64")
F32 = FloatPolicy("float32")
BF16 = FloatPolicy("bfloat16", accum_dtype="float32")
FIXED64 = FixedPointPolicy(64, 40)  # the paper's ap_fixed<64,24> (Q24.40)
FIXED32 = FixedPointPolicy(32, 24)  # the paper's ap_fixed<32,8>  (Q8.24)

POLICIES = {p.name: p for p in (F64, F32, BF16, FIXED64, FIXED32)}
