"""CFDlang front-end (the `cfdlang` dialect analogue).

Parses the concrete syntax from the paper (Fig. 2)::

    var input  S : [11 11]
    var input  D : [11 11 11]
    var input  u : [11 11 11]
    var output v : [11 11 11]
    var t : [11 11 11]
    var r : [11 11 11]
    t = S # S # S # u . [[1 6][3 7][5 8]]
    r = D * t
    v = S # S # S # r . [[0 6][2 7][4 8]]

Grammar (whitespace-separated tokens; ``//`` comments to end of line)::

    program := stmt*
    stmt    := 'var' ('input'|'output')? 'elem'? NAME ':' shape
             | NAME '=' expr
    shape   := '[' INT+ ']'
    expr    := term (('+'|'-') term)*
    term    := factor (('*'|'/') factor)*          # Hadamard product
    factor  := atom ('#' atom)* ('.' pairs)?       # outer product + contraction
    pairs   := '[' ('[' INT INT ']')+ ']'
    atom    := NAME | '(' expr ')'

The ``elem`` qualifier marks an input/output as carrying the implicit
element axis (the paper's outer element loop) directly in the source, so
a ``.cfd`` file is self-contained for the ``repro.flow`` tool flow; the
``element_vars`` argument of :func:`parse` remains available for sources
without markers.

Like the cfdlang MLIR dialect, the parser performs no canonicalization --
it maps language elements 1:1 onto IR nodes and leaves rewriting to the
middle-end (``repro.core.rewrite``).
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Sequence, Tuple

from . import ir


class ParseError(ValueError):
    pass


_TOKEN_RE = re.compile(
    r"\s*(?:(?P<comment>//[^\n]*)|(?P<num>\d+)|(?P<name>[A-Za-z_]\w*)"
    r"|(?P<sym>[\[\]():=#*+/.-]))"
)


def _tokenize(src: str) -> List[str]:
    toks: List[str] = []
    pos = 0
    while pos < len(src):
        m = _TOKEN_RE.match(src, pos)
        if not m:
            if src[pos:].strip() == "":
                break
            raise ParseError(f"unexpected character at {src[pos:pos+20]!r}")
        pos = m.end()
        if m.lastgroup != "comment":
            toks.append(m.group(m.lastgroup))
    return toks


class _Parser:
    def __init__(self, toks: List[str]):
        self.toks = toks
        self.i = 0
        self.decls: Dict[str, Tuple[ir.Shape, str]] = {}  # name -> (shape, kind)
        self.values: Dict[str, ir.Node] = {}
        self.order: List[str] = []  # statement order for outputs
        self.elem_decls: List[str] = []  # 'elem'-qualified declarations

    # -- token helpers ----
    def peek(self) -> Optional[str]:
        return self.toks[self.i] if self.i < len(self.toks) else None

    def next(self) -> str:
        if self.i >= len(self.toks):
            raise ParseError("unexpected end of input")
        t = self.toks[self.i]
        self.i += 1
        return t

    def expect(self, t: str) -> None:
        got = self.next()
        if got != t:
            raise ParseError(f"expected {t!r}, got {got!r}")

    # -- grammar ----
    def parse(self) -> "ir.Program":
        while self.peek() is not None:
            if self.peek() == "var":
                self._parse_decl()
            else:
                self._parse_assign()
        inputs = {
            n: self.values[n]
            for n, (_, kind) in self.decls.items()
            if kind == "input"
        }
        outputs = {}
        for n, (shape, kind) in self.decls.items():
            if kind != "output":
                continue
            if n not in self.values or isinstance(self.values[n], ir.Input):
                raise ParseError(f"output {n!r} never assigned")
            node = self.values[n]
            if node.shape != shape:
                raise ParseError(
                    f"output {n!r}: declared {shape}, computed {node.shape}"
                )
            outputs[n] = node
        temps = {
            n: self.values[n]
            for n, (_, kind) in self.decls.items()
            if kind == "temp" and not isinstance(self.values.get(n), ir.Input)
        }
        return ir.Program(inputs=inputs, outputs=outputs, temps=temps)

    def _int(self, what: str) -> int:
        t = self.next()
        if not t.isdigit():
            raise ParseError(
                f"expected {what}, got {t!r} (CFDlang integers are "
                "unsigned; '-' is a binary operator only)"
            )
        return int(t)

    def _parse_decl(self) -> None:
        self.expect("var")
        kind = "temp"
        if self.peek() in ("input", "output"):
            kind = self.next()
        elem = False
        if self.peek() == "elem" and self.toks[self.i + 1:self.i + 2] != [":"]:
            self.next()
            elem = True
            if kind == "temp":
                raise ParseError(
                    "'elem' qualifies inputs/outputs only (temporaries "
                    "never cross the host link)"
                )
        name = self.next()
        self.expect(":")
        self.expect("[")
        dims: List[int] = []
        while self.peek() != "]":
            dims.append(self._int("dimension"))
        self.expect("]")
        if name in self.decls:
            raise ParseError(f"duplicate declaration of {name!r}")
        shape = tuple(dims)
        self.decls[name] = (shape, kind)
        if elem:
            self.elem_decls.append(name)
        if kind == "input":
            self.values[name] = ir.Input(shape=shape, name=name)

    def _parse_assign(self) -> None:
        name = self.next()
        if name not in self.decls:
            raise ParseError(f"assignment to undeclared {name!r}")
        self.expect("=")
        node = self._expr()
        declared = self.decls[name][0]
        if node.shape != declared:
            raise ParseError(
                f"{name!r}: declared shape {declared}, expression {node.shape}"
            )
        self.values[name] = node
        self.order.append(name)

    def _expr(self) -> ir.Node:
        node = self._term()
        while self.peek() in ("+", "-"):
            op = self.next()
            rhs = self._term()
            node = ir.add(node, rhs) if op == "+" else ir.sub(node, rhs)
        return node

    def _term(self) -> ir.Node:
        node = self._factor()
        while self.peek() in ("*", "/"):
            op = self.next()
            rhs = self._factor()
            node = ir.mul(node, rhs) if op == "*" else ir.div(node, rhs)
        return node

    def _factor(self) -> ir.Node:
        node = self._atom()
        while self.peek() == "#":
            self.next()
            rhs = self._atom()
            node = ir.prod(node, rhs)
        if self.peek() == ".":
            self.next()
            pairs = self._pairs()
            try:
                node = ir.cont(node, pairs)
            except ir.IRError as e:  # surface as a front-end diagnostic
                raise ParseError(str(e)) from e
        return node

    def _pairs(self) -> List[Tuple[int, int]]:
        self.expect("[")
        pairs: List[Tuple[int, int]] = []
        while self.peek() == "[":
            self.next()
            a = self._int("axis number")
            b = self._int("axis number")
            self.expect("]")
            pairs.append((a, b))
        self.expect("]")
        if not pairs:
            raise ParseError("empty contraction pair list")
        return pairs

    def _atom(self) -> ir.Node:
        t = self.next()
        if t == "(":
            node = self._expr()
            self.expect(")")
            return node
        if t in ("+", "-"):
            # a stray leading sign used to cascade into a confusing
            # "unknown identifier" chain; reject it at the source
            raise ParseError(
                f"{t!r} is a binary operator in CFDlang; unary signs are "
                "not part of the grammar (write '0 - x' via a declared "
                "zero operand, or fold the sign into the data)"
            )
        if t in self.values:
            return self.values[t]
        if t in self.decls:
            raise ParseError(f"use of {t!r} before assignment")
        raise ParseError(f"unknown identifier {t!r}")


def parse(src: str, element_vars: Sequence[str] = ()) -> ir.Program:
    """Parse CFDlang source into an IR Program.

    ``element_vars`` marks inputs/outputs that carry the implicit element
    axis (the paper's outer element loop); e.g. for the Inverse Helmholtz
    operator: ``("u", "D", "v")`` -- the operator matrix ``S`` is shared.
    Sources may equivalently carry ``elem`` qualifiers on declarations;
    both spellings are merged (declaration order first).
    """
    toks = _tokenize(src)
    if not toks:
        raise ParseError(
            "empty program: no declarations or statements "
            "(comment-only/blank source)"
        )
    parser = _Parser(toks)
    prog = parser.parse()
    merged = list(parser.elem_decls)
    merged += [v for v in element_vars if v not in merged]
    return ir.Program(
        inputs=prog.inputs,
        outputs=prog.outputs,
        element_vars=tuple(merged),
        temps=prog.temps,
    )


# ---------------------------------------------------------------------------
# Python builder API (for programs generated programmatically, e.g. the
# LM MLP blocks routed through the scheduler).
# ---------------------------------------------------------------------------


class Builder:
    """Programmatic front end producing the same IR as :func:`parse`."""

    def __init__(self) -> None:
        self._inputs: Dict[str, ir.Input] = {}
        self._outputs: Dict[str, ir.Node] = {}
        self._element_vars: List[str] = []

    def input(self, name: str, shape: Sequence[int], element: bool = False) -> ir.Input:
        if name in self._inputs:
            raise ParseError(f"duplicate input {name!r}")
        node = ir.Input(shape=tuple(shape), name=name)
        self._inputs[name] = node
        if element:
            self._element_vars.append(name)
        return node

    def output(self, name: str, node: ir.Node, element: bool = False) -> None:
        self._outputs[name] = node
        if element:
            self._element_vars.append(name)

    # thin wrappers so user code reads like the DSL
    prod = staticmethod(ir.prod)
    cont = staticmethod(ir.cont)
    diag = staticmethod(ir.diag)
    red = staticmethod(ir.red)
    transpose = staticmethod(ir.transpose)
    add = staticmethod(ir.add)
    sub = staticmethod(ir.sub)
    mul = staticmethod(ir.mul)
    div = staticmethod(ir.div)

    def matmul(self, a: ir.Node, b: ir.Node) -> ir.Node:
        """GEMM as prod+cont (the teil encoding from the paper's Fig. 8b)."""
        if a.rank != 2 or b.rank != 2:
            raise ParseError("matmul expects rank-2 operands")
        return ir.cont(ir.prod(a, b), [(1, 2)])

    def program(self) -> ir.Program:
        return ir.Program(
            inputs=self._inputs,
            outputs=self._outputs,
            element_vars=tuple(self._element_vars),
        )


#: The paper's running example (Fig. 2), exposed for tests and examples.
INVERSE_HELMHOLTZ_SRC = """
var input S : [{p} {p}]
var input D : [{p} {p} {p}]
var input u : [{p} {p} {p}]
var output v : [{p} {p} {p}]
var t : [{p} {p} {p}]
var r : [{p} {p} {p}]
t = S # S # S # u . [[1 6][3 7][5 8]]
r = D * t
v = S # S # S # r . [[0 6][2 7][4 8]]
"""


def inverse_helmholtz_program(p: int = 11) -> ir.Program:
    return parse(INVERSE_HELMHOLTZ_SRC.format(p=p), element_vars=("u", "D", "v"))


INTERPOLATION_SRC = """
var input A : [{m} {n}]
var input u : [{n} {n} {n}]
var output v : [{m} {m} {m}]
v = A # A # A # u . [[1 6][3 7][5 8]]
"""


def interpolation_program(n: int = 11, m: int = 11) -> ir.Program:
    return parse(
        INTERPOLATION_SRC.format(n=n, m=m), element_vars=("u", "v")
    )


# Note on layouts: CFDlang's '.' contraction keeps the remaining axes in
# their original order, so the y/z gradients come out with the derivative
# axis leading (the paper's flow would equally emit layout metadata for the
# host; see Olympus host-code specialization, paper section 3.6.2).
GRADIENT_SRC = """
var input Dx : [{nx} {nx}]
var input Dy : [{ny} {ny}]
var input Dz : [{nz} {nz}]
var input u : [{nx} {ny} {nz}]
var output gx : [{nx} {ny} {nz}]
var output gy : [{ny} {nx} {nz}]
var output gz : [{nz} {nx} {ny}]
gx = Dx # u . [[1 2]]
gy = Dy # u . [[1 3]]
gz = Dz # u . [[1 4]]
"""


def gradient_program(nx: int = 8, ny: int = 7, nz: int = 6) -> ir.Program:
    return parse(
        GRADIENT_SRC.format(nx=nx, ny=ny, nz=nz),
        element_vars=("u", "gx", "gy", "gz"),
    )
