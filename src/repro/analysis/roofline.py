"""Three-term roofline analysis from compiled dry-run artifacts.

Terms (seconds), per the TPU v5e target constants:

  compute    = device_FLOPs / peak_FLOP/s            (197 TF/s bf16 / chip)
  memory     = device_HBO_bytes / HBM_bw             (819 GB/s / chip)
  collective = device_collective_bytes / link_bw     (~50 GB/s / link ICI)

Sources: ``compiled.cost_analysis()`` reports per-device FLOPs and bytes
(the executable is the per-device SPMD program -- verified empirically);
collective bytes are parsed from the post-optimization HLO
(``compiled.as_text()``), summing the RESULT buffer size of every
all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute.
Result-size is a within-2x proxy for wire traffic (ring all-gather moves
(n-1)/n of the result; all-reduce ~2x its operand); we use it consistently
so perf iterations compare like against like.

MODEL_FLOPS sanity: 6*N*D for dense training (N params, D tokens), 2*N*D
for inference; MoE uses active parameters.  The ratio MODEL_FLOPS /
(chips x device_FLOPs) flags remat/redundancy waste.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, Optional

# ---- TPU v5e target constants --------------------------------------------
# Shared with the memory planner: repro.memory.channels.TPU_V5E is the
# single source of truth, so roofline analysis and MemoryPlan costing can
# never disagree on peak numbers.
from ..memory.channels import TPU_V5E as _TPU_V5E

PEAK_FLOPS_BF16 = _TPU_V5E.peak_flops   # per chip
HBM_BW = _TPU_V5E.hbm_bw                # bytes/s per chip
ICI_LINK_BW = _TPU_V5E.ici_bw           # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# result type on the LHS: %name = f32[128,256]{1,0} all-reduce(
_LINE_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([\d,]*)\][^=]*?\s"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)
# tuple-result collectives: (f32[8,128], f32[8,128]) all-to-all(...)
_TUPLE_RE = re.compile(
    r"=\s*\(([^)]*)\)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)
_TYPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def _type_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum collective result-buffer bytes per op kind (per device)."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        if not any(c in line for c in _COLLECTIVES):
            continue
        if "-done(" in line:
            continue  # async pair: count the -start only
        m = _TUPLE_RE.search(line)
        if m:
            types, kind = m.groups()
            for dtype, dims in _TYPE_RE.findall(types):
                out[kind] += _type_bytes(dtype, dims)
            continue
        m = _LINE_RE.search(line)
        if m:
            dtype, dims, kind = m.groups()
            out[kind] += _type_bytes(dtype, dims)
    return out


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    device_flops: float
    device_bytes: float
    coll_bytes: float
    coll_breakdown: Dict[str, int]
    bytes_per_device: int          # peak live memory (args+temps+outputs)
    model_flops: float             # analytic useful flops (global)

    @property
    def t_compute(self) -> float:
        return self.device_flops / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.device_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.device_flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """compute-term share of the critical term: 1.0 means the program
        is exactly compute-bound with zero overhead above the MXU floor."""
        t_max = max(self.t_compute, self.t_memory, self.t_collective)
        return self.t_compute / t_max if t_max else 0.0

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(
            t_compute=self.t_compute,
            t_memory=self.t_memory,
            t_collective=self.t_collective,
            bottleneck=self.bottleneck,
            useful_flops_ratio=self.useful_flops_ratio,
            roofline_fraction=self.roofline_fraction,
        )
        return d


def model_flops(
    *, params: int, tokens: int, kind: str, active_params: Optional[int] = None
) -> float:
    """6ND (train) / 2ND (inference) with MoE active-param correction."""
    n = active_params if active_params is not None else params
    if kind == "train":
        return 6.0 * n * tokens
    return 2.0 * n * tokens


def analyze(
    compiled,
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    chips: int,
    model_flops_value: float,
    extra_flops: float = 0.0,
    extra_bytes: float = 0.0,
) -> RooflineReport:
    """``extra_flops``/``extra_bytes``: scan-body corrections from
    analysis.scancost (XLA counts while bodies once)."""
    ca = compiled.cost_analysis()
    ma = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    bytes_dev = (
        ma.argument_size_in_bytes
        + ma.output_size_in_bytes
        + ma.temp_size_in_bytes
    )
    return RooflineReport(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        chips=chips,
        device_flops=float(ca.get("flops", 0.0)) + extra_flops,
        device_bytes=float(ca.get("bytes accessed", 0.0)) + extra_bytes,
        coll_bytes=float(sum(coll.values())),
        coll_breakdown=coll,
        bytes_per_device=int(bytes_dev),
        model_flops=model_flops_value,
    )


def format_table(reports) -> str:
    hdr = (
        f"{'arch':<24} {'shape':<12} {'mesh':<10} {'t_comp(s)':>10} "
        f"{'t_mem(s)':>10} {'t_coll(s)':>10} {'bound':>10} {'useful':>7} "
        f"{'frac':>6} {'GB/dev':>7}"
    )
    lines = [hdr, "-" * len(hdr)]
    for r in reports:
        lines.append(
            f"{r.arch:<24} {r.shape:<12} {r.mesh:<10} {r.t_compute:>10.4g} "
            f"{r.t_memory:>10.4g} {r.t_collective:>10.4g} {r.bottleneck:>10} "
            f"{r.useful_flops_ratio:>7.3f} {r.roofline_fraction:>6.3f} "
            f"{r.bytes_per_device/2**30:>7.2f}"
        )
    return "\n".join(lines)
