"""Aggregate dry-run JSON records into the EXPERIMENTS.md tables.

  PYTHONPATH=src python -m repro.analysis.aggregate [results/dryrun]
"""
from __future__ import annotations

import json
import os
import sys
from typing import Dict, List

ORDER_SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(results_dir: str) -> List[dict]:
    recs = []
    for name in sorted(os.listdir(results_dir)):
        if name.endswith(".json"):
            with open(os.path.join(results_dir, name)) as f:
                recs.append(json.load(f))
    return recs


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.3g}us"
    if x < 1:
        return f"{x*1e3:.3g}ms"
    return f"{x:.3g}s"


def roofline_table(recs: List[dict], mesh: str = "single") -> str:
    rows = [r for r in recs if r["mesh"] == mesh]
    rows.sort(key=lambda r: (r["arch"], ORDER_SHAPES.index(r["shape"])))
    out = [
        "| arch | shape | t_comp | t_mem | t_coll | bound | useful | "
        "frac | GiB/dev (arg+tmp) |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["status"] == "skipped":
            out.append(
                f"| {r['arch']} | {r['shape']} | -- | -- | -- | "
                f"*skipped* | -- | -- | {r['reason'].split(';')[0]} |"
            )
            continue
        if r["status"] != "ok":
            out.append(
                f"| {r['arch']} | {r['shape']} | ERROR | | | | | | "
                f"{r.get('error','')[:60]} |"
            )
            continue
        rf = r["roofline"]
        ma = r["memory_analysis"]
        out.append(
            "| {arch} | {shape} | {tc} | {tm} | {tx} | {b} | {u:.2f} | "
            "{f:.2f} | {a:.1f}+{t:.1f} |".format(
                arch=r["arch"], shape=r["shape"],
                tc=fmt_s(rf["t_compute"]), tm=fmt_s(rf["t_memory"]),
                tx=fmt_s(rf["t_collective"]), b=rf["bottleneck"],
                u=rf["useful_flops_ratio"], f=rf["roofline_fraction"],
                a=ma["argument_size_in_bytes"] / 2 ** 30,
                t=ma["temp_size_in_bytes"] / 2 ** 30,
            )
        )
    return "\n".join(out)


def dryrun_summary(recs: List[dict]) -> str:
    ok = sum(r["status"] == "ok" for r in recs)
    sk = sum(r["status"] == "skipped" for r in recs)
    er = sum(r["status"] == "error" for r in recs)
    lines = [f"cells: {ok} compiled ok, {sk} ruled skips, {er} errors"]
    for mesh in ("single", "multipod"):
        rows = [r for r in recs if r["mesh"] == mesh and r["status"] == "ok"]
        if rows:
            ct = sum(r.get("compile_s", 0) for r in rows)
            lines.append(
                f"  {mesh}: {len(rows)} cells, total compile {ct:.0f}s"
            )
    return "\n".join(lines)


def main() -> None:
    d = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    recs = load(d)
    print(dryrun_summary(recs))
    print("\n## single-pod (16x16 = 256 chips)\n")
    print(roofline_table(recs, "single"))
    print("\n## multi-pod (2x16x16 = 512 chips)\n")
    print(roofline_table(recs, "multipod"))


if __name__ == "__main__":
    main()
