"""Scan-aware cost composition.

XLA's ``cost_analysis`` counts a while-loop body ONCE regardless of trip
count (verified empirically -- see EXPERIMENTS.md section Roofline), so a
scan-over-layers program under-reports FLOPs/bytes by ~n_layers.  We
correct by compiling each scanned body *standalone on the same mesh with
the same shardings* and composing:

    total = outer_hlo + sum_scans (trips - 1) x body_hlo

with one level of recursion for nested scans (jamba's period scan contains
mamba's time scan; xlstm's layers each contain a time scan).

The probes measure post-SPMD per-device costs, so the composition stays a
"from the compiled artifact" measurement, just assembled per loop.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs import shapes as shape_mod
from ..distributed import sharding as shard_rules
from ..models import hybrid as hybrid_mod
from ..models import ssm, transformer
from ..models.config import ModelConfig

SDS = jax.ShapeDtypeStruct


def _cost(fn, arg_specs, in_shardings, mesh) -> Tuple[float, float, float]:
    from .roofline import collective_bytes
    with mesh:
        c = jax.jit(fn, in_shardings=in_shardings).lower(*arg_specs).compile()
    ca = c.cost_analysis()
    coll = float(sum(collective_bytes(c.as_text()).values()))
    return (float(ca.get("flops", 0.0)),
            float(ca.get("bytes accessed", 0.0)), coll)


def _dp_axes(mesh):
    if shard_rules.DP_ONLY:
        return tuple(mesh.axis_names)
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _named(mesh, *spec):
    from jax.sharding import NamedSharding, PartitionSpec as P
    return NamedSharding(mesh, P(*spec))


def _block_param_specs(params_shape, mesh, key: str = "blocks"):
    """Single-layer slice of the stacked block params + its shardings."""
    stacked = params_shape[key]
    one = jax.tree.map(lambda l: SDS(l.shape[1:], l.dtype), stacked)
    sh = shard_rules.param_shardings(one, mesh)
    return one, sh


def corrections(
    cfg: ModelConfig,
    shape_name: str,
    mesh,
    model,
    params_shape,
    *,
    moe_capacity: Optional[int],
    attn_impl: str = "xla",
) -> Dict[str, Any]:
    """Returns {'flops': extra_flops, 'bytes': extra_bytes, 'detail': {...}}
    to ADD to the outer compiled costs."""
    spec = shape_mod.SHAPES[shape_name]
    B, T = spec.global_batch, spec.seq_len
    dp = _dp_axes(mesh)
    # flash probes: single-trip KV scan so the body carries the full cost
    from ..kernels.attention import xla_flash as _xf
    _saved_chunk = _xf.DEFAULT_CHUNK
    if attn_impl == "xla_flash":
        _xf.DEFAULT_CHUNK = max(T, 1)
    cd = jnp.dtype(cfg.compute_dtype)
    detail: Dict[str, Any] = {}
    extra_f = 0.0
    extra_b = 0.0
    extra_c = 0.0

    batch_shardable = B % int(
        jnp.prod(jnp.array([dict(zip(mesh.axis_names, mesh.devices.shape))[a]
                            for a in dp]))
    ) == 0 if dp else False
    x_sh = _named(mesh, dp if batch_shardable else None, None, None)
    pos_sh = _named(mesh, dp if batch_shardable else None, None)

    if cfg.family in ("dense", "moe", "vlm"):
        L = cfg.n_layers
        bp_shape, bp_sh = _block_param_specs(params_shape, mesh)
        t_eff = 1 if spec.kind == "decode" else T
        x_spec = SDS((B, t_eff, cfg.d_model), cd)
        pos_spec = SDS((B, t_eff), jnp.int32)

        if spec.kind == "train":
            def fwd(bp, x, pos):
                y, _ = transformer.block_apply(
                    bp, x, cfg, positions=pos, attn_impl=attn_impl,
                    moe_capacity=moe_capacity,
                )
                return y

            def fwd_bwd(bp, x, pos):
                def loss(xx):
                    y, _ = transformer.block_apply(
                        bp, xx, cfg, positions=pos, attn_impl=attn_impl,
                        moe_capacity=moe_capacity,
                    )
                    return jnp.sum(y.astype(jnp.float32))
                l, g = jax.value_and_grad(loss)(x)
                return l, g

            cf, bf, xf = _cost(fwd, (bp_shape, x_spec, pos_spec),
                               (bp_sh, x_sh, pos_sh), mesh)
            cfb, bfb, xfb = _cost(fwd_bwd, (bp_shape, x_spec, pos_spec),
                                  (bp_sh, x_sh, pos_sh), mesh)
            extra_f = (L - 1) * (cf + cfb)
            extra_b = (L - 1) * (bf + bfb)
            extra_c = (L - 1) * (xf + xfb)
            detail = {"per_layer_fwd": cf, "per_layer_fwd_bwd": cfb,
                      "per_layer_coll": xf + xfb, "layers": L}
        else:
            cache_spec = {
                "k": SDS((B, T, cfg.n_kv_heads, cfg.hd), cd),
                "v": SDS((B, T, cfg.n_kv_heads, cfg.hd), cd),
            }
            cache_sh = shard_rules.cache_shardings(
                cache_spec, cfg, mesh, batch=B
            )
            idx_spec = SDS((), jnp.int32)

            def fwd_cache(bp, x, pos, cache, idx):
                y, nc = transformer.block_apply(
                    bp, x, cfg, positions=pos, cache=cache,
                    cache_index=idx, attn_impl="xla",
                    moe_capacity=moe_capacity,
                )
                return y, nc

            cf, bf, xf = _cost(
                fwd_cache,
                (bp_shape, x_spec, pos_spec, cache_spec, idx_spec),
                (bp_sh, x_sh, pos_sh, cache_sh, _named(mesh)),
                mesh,
            )
            extra_f = (L - 1) * cf
            extra_b = (L - 1) * bf
            extra_c = (L - 1) * xf
            detail = {"per_layer": cf, "per_layer_coll": xf, "layers": L}

    elif cfg.family == "hybrid_jamba":
        P_n = cfg.n_layers // cfg.attn_period
        pp_shape, pp_sh = _block_param_specs(params_shape, mesh, "periods")
        t_eff = 1 if spec.kind == "decode" else T
        x_spec = SDS((B, t_eff, cfg.d_model), cd)
        pos_spec = SDS((B, t_eff), jnp.int32)

        # mamba time-step probe (inner scan body)
        m = cfg.mamba
        d_in = m.expand * cfg.d_model
        h_spec = SDS((B, d_in, m.d_state), jnp.float32)
        step_in = (
            SDS((B, d_in), jnp.float32), SDS((B, m.d_state), jnp.float32),
            SDS((B, m.d_state), jnp.float32), SDS((B, d_in), jnp.float32),
        )
        b_only = _named(mesh, dp if batch_shardable else None, None)
        b3 = _named(mesh, dp if batch_shardable else None, None, None)
        A_spec = SDS((d_in, m.d_state), jnp.float32)

        def mamba_step(h, A, dt_t, b_t, c_t, x_t):
            dA_t = jnp.exp(dt_t[..., None] * A[None])
            dBx_t = (dt_t * x_t)[..., None] * b_t[:, None, :]
            h = dA_t * h + dBx_t
            y_t = jnp.einsum("bds,bs->bd", h, c_t)
            return h, y_t

        cstep, bstep, xstep = _cost(
            mamba_step,
            (h_spec, A_spec) + step_in,
            (b3, _named(mesh, None, None), b_only, b_only, b_only, b_only),
            mesh,
        )
        n_mamba = cfg.attn_period - 1

        if spec.kind == "train":
            def fwd(pp, x, pos):
                y, _ = hybrid_mod._period_apply(
                    pp, x, cfg, positions=pos, attn_impl=attn_impl,
                    moe_capacity=moe_capacity,
                )
                return y

            def fwd_bwd(pp, x, pos):
                def loss(xx):
                    y, _ = hybrid_mod._period_apply(
                        pp, xx, cfg, positions=pos, attn_impl=attn_impl,
                        moe_capacity=moe_capacity,
                    )
                    return jnp.sum(y.astype(jnp.float32))
                return jax.value_and_grad(loss)(x)

            cf, bf, xf = _cost(fwd, (pp_shape, x_spec, pos_spec),
                               (pp_sh, x_sh, pos_sh), mesh)
            cfb, bfb, xfb = _cost(fwd_bwd, (pp_shape, x_spec, pos_spec),
                                  (pp_sh, x_sh, pos_sh), mesh)
            # correct each period body for its 7 inner time scans
            # (fwd once + recompute/bwd ~ 3x step cost per extra timestep)
            inner_f = n_mamba * (t_eff - 1) * cstep
            inner_b = n_mamba * (t_eff - 1) * bstep
            cf_c, cfb_c = cf + inner_f, cfb + 3 * inner_f
            bf_c, bfb_c = bf + inner_b, bfb + 3 * inner_b
            extra_f = (P_n - 1) * (cf_c + cfb_c) + (inner_f + 3 * inner_f)
            extra_b = (P_n - 1) * (bf_c + bfb_c) + (inner_b + 3 * inner_b)
            extra_c = (P_n - 1) * (xf + xfb)
            detail = {"per_period_fwd": cf, "per_period_fwd_bwd": cfb,
                      "mamba_step": cstep, "periods": P_n}
        else:
            cache_spec = {
                "k": SDS((B, T, cfg.n_kv_heads, cfg.hd), cd),
                "v": SDS((B, T, cfg.n_kv_heads, cfg.hd), cd),
                "conv": SDS((n_mamba, B, m.d_conv - 1, d_in), cd),
                "ssm": SDS((n_mamba, B, d_in, m.d_state), jnp.float32),
            }
            cache_sh = shard_rules.cache_shardings(
                cache_spec, cfg, mesh, batch=B
            )
            idx_spec = SDS((), jnp.int32)

            def fwd_cache(pp, x, pos, cache, idx):
                return hybrid_mod._period_apply(
                    pp, x, cfg, positions=pos, attn_impl="xla",
                    moe_capacity=moe_capacity, cache=cache, cache_index=idx,
                )

            cf, bf, xf = _cost(
                fwd_cache,
                (pp_shape, x_spec, pos_spec, cache_spec, idx_spec),
                (pp_sh, x_sh, pos_sh, cache_sh, _named(mesh)),
                mesh,
            )
            inner_f = n_mamba * (t_eff - 1) * cstep
            inner_b = n_mamba * (t_eff - 1) * bstep
            extra_f = (P_n - 1) * (cf + inner_f) + inner_f
            extra_b = (P_n - 1) * (bf + inner_b) + inner_b
            extra_c = (P_n - 1) * xf
            detail = {"per_period": cf, "mamba_step": cstep, "periods": P_n}

    elif cfg.family == "ssm_xlstm":
        # python loop over layers (outer counts each once); correct the
        # inner time scans only.
        t_eff = 1 if spec.kind == "decode" else T
        if t_eff > 1 and ssm.MLSTM_CHUNK and t_eff > ssm.MLSTM_CHUNK:
            # chunkwise-parallel mLSTM: scan over T/W chunks
            W = ssm.MLSTM_CHUNK
            H, hd = cfg.n_heads, cfg.hd
            bdp = dp if batch_shardable else None

            def chunk_body(q, k, v, ip, fl, C, n, m):
                h, (C, n, m) = ssm._mlstm_chunk_body(
                    q, k, v, ip, fl, C, n, m, W=W
                )
                return h, C, n, m

            specs = (
                SDS((B, H, W, hd), jnp.float32),
                SDS((B, H, W, hd), jnp.float32),
                SDS((B, H, W, hd), jnp.float32),
                SDS((B, H, W), jnp.float32), SDS((B, H, W), jnp.float32),
                SDS((B, H, hd, hd), jnp.float32),
                SDS((B, H, hd), jnp.float32), SDS((B, H), jnp.float32),
            )
            shs = tuple(
                _named(mesh, *((bdp,) + (None,) * (len(s.shape) - 1)))
                for s in specs
            )
            cc, bc, _x = _cost(chunk_body, specs, shs, mesh)

            def chunk_vjp(q, k, v, ip, fl, C, n, m):
                def loss(qq):
                    h, _ = ssm._mlstm_chunk_body(
                        qq, k, v, ip, fl, C, n, m, W=W
                    )
                    return jnp.sum(h)
                return jax.value_and_grad(loss)(q)

            cvj, bvj, _x2 = _cost(chunk_vjp, specs, shs, mesh)
            n_s = sum(
                1 for i in range(cfg.n_layers)
                if ssm.xlstm_block_kind(i, cfg) == "slstm"
            )
            n_m = cfg.n_layers - n_s
            trips = t_eff // W
            if spec.kind == "train":
                per = (trips - 1) * (cc + cvj)
                per_b = (trips - 1) * (bc + bvj)
            else:
                per = (trips - 1) * cc
                per_b = (trips - 1) * bc
            # sLSTM layers stay recurrent: reuse the step-probe path below
            extra_f = n_m * per
            extra_b = n_m * per_b
            detail = {"mlstm_chunk": cc, "chunks": trips,
                      "layers_m": n_m, "layers_s": n_s,
                      "note": "slstm steps uncorrected (3 tiny layers)"}
        elif t_eff > 1:
            H, hd = cfg.n_heads, cfg.hd
            bdp = dp if batch_shardable else None

            def mlstm_step(C, n, m_, qt, kt, vt, it, ft):
                m_new = jnp.maximum(ft + m_, it)
                i_g = jnp.exp(it - m_new)
                f_g = jnp.exp(ft + m_ - m_new)
                C = f_g[..., None, None] * C + i_g[..., None, None] * (
                    kt[..., :, None] * vt[..., None, :]
                )
                n = f_g[..., None] * n + i_g[..., None] * kt
                num = jnp.einsum("bhkv,bhk->bhv", C, qt)
                den = jnp.abs(jnp.einsum("bhk,bhk->bh", n, qt))
                h = num / jnp.maximum(den, 1.0)[..., None]
                return C, n, m_new, h

            specs = (
                SDS((B, H, hd, hd), jnp.float32),
                SDS((B, H, hd), jnp.float32), SDS((B, H), jnp.float32),
                SDS((B, H, hd), jnp.float32), SDS((B, H, hd), jnp.float32),
                SDS((B, H, hd), jnp.float32), SDS((B, H), jnp.float32),
                SDS((B, H), jnp.float32),
            )
            shs = tuple(
                _named(mesh, *( (bdp,) + (None,) * (len(s.shape) - 1) ))
                for s in specs
            )
            cm, bm, _xm = _cost(mlstm_step, specs, shs, mesh)

            def slstm_step(c, n, m_, zt, it, ft):
                m_new = jnp.maximum(ft + m_, it)
                i_g = jnp.exp(it - m_new)
                f_g = jnp.exp(ft + m_ - m_new)
                c = f_g * c + i_g * zt
                n = f_g * n + i_g
                return c, n, m_new, c / jnp.maximum(n, 1.0)

            D = H * hd
            s2 = tuple(SDS((B, D), jnp.float32) for _ in range(6))
            sh2 = tuple(_named(mesh, bdp, None) for _ in range(6))
            cs, bs, _xs = _cost(slstm_step, s2, sh2, mesh)

            n_s = sum(
                1 for i in range(cfg.n_layers)
                if ssm.xlstm_block_kind(i, cfg) == "slstm"
            )
            n_m = cfg.n_layers - n_s
            mult = 4.0 if spec.kind == "train" else 1.0  # fwd + ~3x bwd
            extra_f = mult * (t_eff - 1) * (n_m * cm + n_s * cs)
            extra_b = mult * (t_eff - 1) * (n_m * bm + n_s * bs)
            detail = {"mlstm_step": cm, "slstm_step": cs,
                      "layers_m": n_m, "layers_s": n_s}

    # encdec (whisper): python loops, no scans -> no correction
    if attn_impl == "xla_flash":
        _xf.DEFAULT_CHUNK = _saved_chunk
    return {"flops": extra_f, "bytes": extra_b, "coll": extra_c,
            "detail": detail}
