"""The flow-native serving engine: admission waves through the plan's
stage-pipelined dispatch rings.

One engine wraps one :class:`~repro.flow.build.CompiledSystem` and keeps
its :class:`~repro.memory.pipeline.StagePipelineDriver` -- the same
skewed ring ``run_chain`` uses for batch jobs -- alive across requests:

  * :meth:`submit` validates a request's element rows and pushes it on
    the :class:`~repro.serve.queue.AdmissionQueue`; waves of exactly the
    plan's ``E`` elements are fed to the ring as they fill (or when the
    max-latency knob flushes a padded partial wave);
  * the ring holds at most ``window`` waves in flight -- derived from
    the placement's prefetch depths (host staging + pipeline fill) --
    and a submit that would exceed it blocks on ring progress, or
    raises :class:`Backpressure` when ``reject=True``;
  * :meth:`drain` force-flushes and runs the ring dry within a tick
    budget, raising :class:`DrainTimeout` with the undrained requests
    rather than returning silently with work still queued;
    :meth:`shutdown` surfaces :class:`EngineShutdown` on every
    unfinished request instead of wedging them.

Per-wave stage errors are captured by the driver (``capture_errors``)
and land on the affected requests' ``error`` field -- one poisoned wave
never takes down the ring or unrelated requests.

Execution is the single-mesh path of ``cfd.simulation.run_chain``
(shared operands replicated once, element axis sharded over the local
mesh), so engine outputs are bitwise-identical to per-request serial
runs of the same system; multi-group placement execution remains the
batch driver's job.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..memory import chain as memchain
from ..memory.pipeline import StagePipelineDriver
from .queue import AdmissionQueue, ServeRequest, Wave


class Backpressure(RuntimeError):
    """submit() would exceed the in-flight window (reject mode)."""


class EngineShutdown(RuntimeError):
    """The engine shut down with this request still unfinished."""


class DrainTimeout(RuntimeError):
    """drain() exhausted its tick budget with requests still in flight.

    ``undrained`` holds the affected :class:`ServeRequest` objects --
    the caller decides whether to extend the budget or shut down."""

    def __init__(self, undrained: List[ServeRequest]) -> None:
        self.undrained = list(undrained)
        rids = ", ".join(f"r{r.rid}" for r in self.undrained)
        super().__init__(
            f"drain tick budget exhausted with {len(self.undrained)} "
            f"request(s) unfinished: {rids}"
        )


class ServeEngine:
    """Long-running request service over one compiled system.

    ``window=None`` derives the bounded in-flight window from the plan's
    pipeline spec: ``depths[0]`` host-staged waves + the fill/drain
    skew + 2 live waves.  ``reject=True`` turns a full window into
    :class:`Backpressure` instead of blocking on ring progress.
    ``max_wait_s`` is the coalescing latency knob: an undersized wave is
    flushed (padded) once its oldest request has waited that long.
    ``tracer`` records per-request spans plus the standard ring spans
    and the serving counters; ``monitor``/``latency`` observe retire
    cadence and request latency.  ``seed`` fixes the synthesized
    batch-invariant shared operands (pass ``shared`` to pin them).

    ``metrics`` (a :class:`repro.metrics.MetricsRegistry`; None or
    :data:`~repro.metrics.NULL_REGISTRY` = off) turns on the always-on
    telemetry: request lifecycle counters, in-flight/queue gauges, and
    per-request latency *decomposed* into queue-wait (submit to first
    wave fed) vs wave-execution (first feed to retire), with the
    execution share attributable to zero-padding tracked separately.
    ``slo`` (a :class:`repro.metrics.SLOTracker`) is fed every finished
    request.  Both only observe -- outputs stay bitwise-identical to an
    unmetered engine.
    """

    def __init__(self, system, *, window: Optional[int] = None,
                 reject: bool = False, max_wait_s: Optional[float] = None,
                 tracer=None, monitor=None, latency=None, seed: int = 0,
                 shared: Optional[Dict[str, np.ndarray]] = None,
                 clock=time.monotonic, metrics=None, slo=None) -> None:
        from ..cfd.simulation import element_mesh  # lazy: cfd builds on flow

        self.system = system
        chain: memchain.ProgramChain = system.chain
        plan: memchain.ChainPlan = system.plan
        self.chain = chain
        self.plan = plan
        self.tracer = tracer
        self.latency = latency
        self.metrics = metrics
        self.slo = slo
        E = plan.batch_elements
        self.batch_elements = E
        self._m_req = self._m_lat = self._m_pad = None
        self._m_waves = self._m_ticks = self._m_admitted_elems = None
        self._m_pad_overhead = None
        self._g_inflight_req = self._g_inflight_waves = None
        if metrics:
            self._m_req = {
                e: metrics.counter(
                    "serve_requests_total",
                    "Requests by lifecycle event (admitted counts "
                    "requests whose last slice entered a wave).",
                    event=e)
                for e in ("submitted", "admitted", "completed",
                          "failed", "rejected")
            }
            self._m_waves = metrics.counter(
                "serve_waves_total", "Coalesced E-element waves fed.")
            self._m_ticks = metrics.counter(
                "serve_ticks_total", "Ring ticks driven by the engine.")
            self._m_admitted_elems = metrics.counter(
                "serve_admitted_elements_total",
                "Real (non-pad) element rows fed across all waves.")
            self._m_pad = {
                kind: metrics.counter(
                    "serve_pad_elements_total",
                    "Zero-pad rows fed: wave = undersized admission "
                    "waves, plan = the plan's own E block padding.",
                    kind=kind)
                for kind in ("wave", "plan")
            }
            metrics.gauge(
                "serve_batch_elements",
                "The plan's wave size E in element rows.").set(float(E))
            self._g_inflight_req = metrics.gauge(
                "serve_in_flight_requests",
                "Submitted requests not yet finished.")
            self._g_inflight_waves = metrics.gauge(
                "serve_in_flight_waves", "Waves currently in the ring.")
            self._m_lat = {
                phase: metrics.histogram(
                    "serve_request_latency_seconds",
                    "Per-request latency, decomposed: total = queue "
                    "(submit to first feed) + execute (first feed to "
                    "retire).", phase=phase)
                for phase in ("total", "queue", "execute")
            }
            self._m_pad_overhead = metrics.histogram(
                "serve_request_pad_overhead_seconds",
                "Execution time attributable to wave zero-padding: each "
                "of a request's waves charges pad/E of its wall time.")

        pipe = plan.pipeline
        if pipe is None:  # legacy plan: derive from the stage Ks
            pipe = memchain.derive_pipeline(
                [sp.prefetch_depth for sp in plan.stages]
            )
        depths = list(pipe.stage_depths)
        if len(depths) != len(chain.stages):
            raise ValueError(
                f"plan has {len(depths)} stage depths but the compiled "
                f"chain has {len(chain.stages)} stages; serve the system "
                "the flow actually compiled"
            )
        pipelined = (pipe.pipelined and len(depths) > 1
                     and any(d > 0 for d in depths[1:]))
        if not pipelined:  # serial schedule: host staging only
            depths = [max(depths)] + [0] * (len(chain.stages) - 1)
        self.pipelined = pipelined
        if window is None:
            window = depths[0] + pipe.fill_batches + 2
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        self.window = window
        self.reject = reject

        # -- expected request shape -----------------------------------------
        self.in_specs: Dict[str, tuple] = {
            f"{s.name}.{n}": tuple(node.shape)
            for i, s in enumerate(chain.stages)
            for n, node in chain.host_element_inputs(i)
        }
        self.out_names = [
            f"{s.name}.{n}"
            for i, s in enumerate(chain.stages)
            for n, _ in chain.chain_outputs(i)
        ]

        # -- the single-mesh execution substrate (run_chain's fallback) -----
        mesh = element_mesh()
        elem_sharding = NamedSharding(mesh, P("elements"))
        repl_sharding = NamedSharding(mesh, P())
        self.shared_host: Dict[str, np.ndarray] = {}
        for k, (name, node) in enumerate(
                sorted(chain.shared_operands().items())):
            if shared is not None and name in shared:
                self.shared_host[name] = np.asarray(shared[name])
            else:
                rng = np.random.default_rng(seed + 2 ** 31 + k)
                self.shared_host[name] = rng.uniform(
                    -1, 1, node.shape
                ).astype(np.float32)
        shared_dev = {
            name: jax.device_put(h, repl_sharding)
            for name, h in self.shared_host.items()
        }

        def stage_batch(batch):
            if tracer:
                from ..trace.attribution import (COUNTER_CHANNEL_BYTES,
                                                 COUNTER_PAD_ELEMENTS,
                                                 host_channel_bytes)

                tracer.bump(COUNTER_CHANNEL_BYTES, {
                    str(c): float(b)
                    for c, b in host_channel_bytes(plan.buffers).items()
                })
                if plan.batch_pad_elements:
                    tracer.bump(COUNTER_PAD_ELEMENTS, {
                        "pad": float(plan.batch_pad_elements)
                    })
            return {
                k: jax.device_put(v, elem_sharding)
                for k, v in batch.items()
            }

        def make_stage_fn(i: int, s: memchain.ChainStage):
            def run_stage(staged, carry):
                live: Dict[str, jax.Array] = dict(carry) if carry else {}
                env: Dict[str, jax.Array] = {}
                for name in s.program.inputs:
                    if name in chain.resolved[i]:
                        p_idx, out_name = chain.resolved[i][name]
                        env[name] = live[
                            f"{chain.stages[p_idx].name}.{out_name}"
                        ]
                    elif name in shared_dev:
                        env[name] = shared_dev[name]
                    else:
                        env[name] = staged[f"{s.name}.{name}"]
                outs = s.compiled.batched_fn(env)
                for out_name, val in outs.items():
                    live[f"{s.name}.{out_name}"] = val
                return live

            return run_stage

        out_names = self.out_names
        self.driver = StagePipelineDriver(
            [make_stage_fn(i, s) for i, s in enumerate(chain.stages)],
            stage_fn=stage_batch,
            depths=depths,
            reduce_fn=lambda live: {q: live[q] for q in out_names},
            tracer=tracer,
            monitor=monitor,
            stage_names=[s.name for s in chain.stages],
            capture_errors=True,
            metrics=metrics,
            metrics_labels={"plan": plan.signature[:12]},
        )

        self.queue = AdmissionQueue(E, max_wait_s=max_wait_s, clock=clock,
                                    metrics=metrics)
        #: batch index -> (wave parts, feed timestamp, wave pad rows)
        self._wave_parts: Dict[int, tuple] = {}
        self._spans: Dict[int, Any] = {}
        self._request_track = 1 + len(chain.stages)
        self._next_rid = 0
        self._closed = False
        #: running tallies (also exported as counters when traced)
        self.stats: Dict[str, int] = {
            "submitted": 0, "completed": 0, "failed": 0, "rejected": 0,
            "waves": 0, "pad_elements": 0, "plan_pad_elements": 0,
            "ticks": 0,
        }

    # -- submission ----------------------------------------------------------
    def submit(self, inputs: Dict[str, np.ndarray]) -> ServeRequest:
        """Queue one request; admits any waves that are due.

        ``inputs`` maps every qualified host stream name to an array of
        ``n`` element rows (the request's size; any ``n >= 1`` works --
        coalescing and padding are the engine's job).  Returns the
        :class:`ServeRequest` to poll for ``outputs``/``error``.
        """
        if self._closed:
            raise RuntimeError("engine is shut down")
        got, want = set(inputs), set(self.in_specs)
        if got != want:
            raise ValueError(
                f"request inputs {sorted(got)} != chain host streams "
                f"{sorted(want)}"
            )
        rows = {q: np.asarray(v, np.float32) for q, v in inputs.items()}
        sizes = {v.shape[0] for v in rows.values()}
        if len(sizes) != 1 or min(sizes) < 1:
            raise ValueError(
                f"request inputs disagree on element count: "
                f"{ {q: v.shape[0] for q, v in rows.items()} }"
            )
        for q, v in rows.items():
            if v.shape[1:] != self.in_specs[q]:
                raise ValueError(
                    f"request input {q!r} rows have shape {v.shape[1:]}, "
                    f"chain expects {self.in_specs[q]}"
                )
        n = sizes.pop()
        req = ServeRequest(rid=self._next_rid, inputs=rows, n_elements=n)
        self._next_rid += 1
        self.queue.push(req)
        self.stats["submitted"] += 1
        self._bump_requests("submitted")
        if self._g_inflight_req is not None:
            self._g_inflight_req.inc()
        if self.tracer:
            from ..trace.attribution import CAT_REQUEST

            track = self._request_track + req.rid
            self.tracer.name_track(track, f"request r{req.rid}")
            self._spans[req.rid] = self.tracer.begin(
                f"r{req.rid}", CAT_REQUEST, track, elements=n
            )
        self._admit(block=not self.reject, rejectable=req)
        self._tick()
        return req

    def poll(self) -> None:
        """One service beat for a long-running loop: admit any due wave
        (max-latency flushes included) and advance the ring one tick."""
        self._admit(block=not self.reject)
        self._tick()

    # -- draining ------------------------------------------------------------
    def drain(self, max_ticks: Optional[int] = None) -> None:
        """Flush partial waves and run the ring dry.

        Every submitted request is finished (``outputs`` or ``error``)
        on return.  If ``max_ticks`` is exhausted first, raises
        :class:`DrainTimeout` carrying the undrained requests -- never
        a silent return with work still queued."""
        if max_ticks is None:
            waves_left = (len(self._wave_parts)
                          + -(-max(1, self.queue.pending_elements)
                              // self.batch_elements))
            max_ticks = 8 * (waves_left + self.window + 4) + 16
        ticks = 0
        while True:
            while (self.queue.ready(force=True)
                   and len(self._wave_parts) < self.window):
                wave = self.queue.pop_wave(force=True)
                self._feed(wave)
            if self.driver.idle and not self.queue.pending_requests:
                self._collect()
                return
            if ticks >= max_ticks:
                raise DrainTimeout(
                    [r for r in self._live_requests() if not r.done]
                )
            self._tick()
            ticks += 1

    def shutdown(self) -> List[ServeRequest]:
        """Stop serving now.  Unfinished requests -- queued or mid-ring
        -- get :class:`EngineShutdown` as their error and are returned;
        nothing is left silently wedged.  (Call :meth:`drain` first for
        a graceful stop.)"""
        self._collect()
        leftovers = [r for r in self._live_requests() if not r.done]
        for r in leftovers:
            r.error = EngineShutdown(
                f"engine shut down with request r{r.rid} unfinished"
            )
            r.parts_done = r.parts
            self._finish(r)
        self._wave_parts.clear()
        self.queue._q.clear()
        self.queue._gauge_depth()
        if self._g_inflight_waves is not None:
            self._g_inflight_waves.set(0.0)
        self.driver.close()
        self._closed = True
        return leftovers

    # -- internals -----------------------------------------------------------
    def _live_requests(self) -> List[ServeRequest]:
        seen: Dict[int, ServeRequest] = {}
        for parts, _, _ in self._wave_parts.values():
            for part in parts:
                seen.setdefault(part.request.rid, part.request)
        for r in self.queue.pending_requests:
            seen.setdefault(r.rid, r)
        return [seen[rid] for rid in sorted(seen)]

    def _admit(self, *, block: bool,
               rejectable: Optional[ServeRequest] = None) -> None:
        while self.queue.ready():
            self._collect()
            if len(self._wave_parts) >= self.window:
                if not block:
                    if rejectable is not None and self.queue.remove(
                            rejectable):
                        rejectable.error = Backpressure(
                            f"in-flight window full "
                            f"({self.window} waves)"
                        )
                        self.stats["rejected"] += 1
                        self._bump_requests("rejected")
                        self._finish(rejectable, count=False)
                        raise rejectable.error
                    return
                self._tick()  # ring progress frees a window slot
                continue
            self._feed(self.queue.pop_wave())

    def _feed(self, wave: Wave) -> None:
        E = self.batch_elements
        batch = {
            q: np.zeros((E,) + shape, np.float32)
            for q, shape in self.in_specs.items()
        }
        for part in wave.parts:
            for q, arr in part.request.inputs.items():
                batch[q][part.dst:part.dst + part.n] = arr[part.lo:part.hi]
        feed_t = self.queue.clock()
        for part in wave.parts:
            if part.request.admitted_s == 0.0:
                part.request.admitted_s = feed_t
        k = self.driver.feed(batch)
        self._wave_parts[k] = (wave.parts, feed_t, wave.pad_elements)
        self.stats["waves"] += 1
        self.stats["pad_elements"] += wave.pad_elements
        self.stats["plan_pad_elements"] += self.plan.batch_pad_elements
        fully_admitted = sum(
            1 for p in wave.parts if p.hi == p.request.n_elements
        )
        if self._m_waves is not None:
            self._m_waves.inc()
            self._m_admitted_elems.inc(float(E - wave.pad_elements))
            if wave.pad_elements:
                self._m_pad["wave"].inc(float(wave.pad_elements))
            if self.plan.batch_pad_elements:
                self._m_pad["plan"].inc(float(self.plan.batch_pad_elements))
            self._g_inflight_waves.set(float(len(self._wave_parts)))
        if self.tracer:
            from ..trace.attribution import (COUNTER_PAD_ELEMENTS,
                                             COUNTER_SERVE_WAVES)

            self.tracer.bump(COUNTER_SERVE_WAVES, {"waves": 1.0})
            if wave.pad_elements:
                self.tracer.bump(COUNTER_PAD_ELEMENTS, {
                    "wave": float(wave.pad_elements)
                })
        if fully_admitted:
            self._bump_requests("admitted", float(fully_admitted))

    def _tick(self) -> None:
        self.driver.tick()
        self.stats["ticks"] += 1
        if self._m_ticks is not None:
            self._m_ticks.inc()
        self._collect()

    def _collect(self) -> None:
        retired = False
        for k, value in self.driver.take():
            retired = True
            parts, feed_t, pad = self._wave_parts.pop(k)
            if pad:
                # charge each rider its share of the wave's wall time
                # spent computing zero rows: pad/E of (feed -> retire)
                wave_wall = self.queue.clock() - feed_t
                for part in parts:
                    part.request.pad_overhead_s += (
                        wave_wall * pad / self.batch_elements
                    )
            failed = isinstance(value, BaseException)
            for part in parts:
                req = part.request
                if failed:
                    if req.error is None:
                        req.error = value
                else:
                    if req.outputs is None:
                        req.outputs = {
                            q: np.empty(
                                (req.n_elements,) + v.shape[1:], v.dtype
                            )
                            for q, v in value.items()
                        }
                    for q, v in value.items():
                        req.outputs[q][part.lo:part.hi] = (
                            v[part.dst:part.dst + part.n]
                        )
                req.parts_done += 1
                if req.done:
                    self._finish(req)
        if retired and self._g_inflight_waves is not None:
            self._g_inflight_waves.set(float(len(self._wave_parts)))

    def _finish(self, req: ServeRequest, *, count: bool = True) -> None:
        if req.completed_s:
            return
        req.completed_s = self.queue.clock()
        total_s = req.completed_s - req.submitted_s
        if self.latency is not None and req.error is None:
            self.latency.record(total_s)
        if self.slo is not None and count:
            self.slo.observe(total_s, error=req.error is not None)
        if self._m_lat is not None and count and req.error is None:
            # decomposition: total == queue + execute by construction
            # (admitted_s sits between submit and complete)
            admitted = req.admitted_s or req.completed_s
            self._m_lat["queue"].observe(admitted - req.submitted_s)
            self._m_lat["execute"].observe(req.completed_s - admitted)
            self._m_lat["total"].observe(total_s)
            self._m_pad_overhead.observe(req.pad_overhead_s)
        if count:
            what = "failed" if req.error is not None else "completed"
            self.stats[what] += 1
            self._bump_requests(what)
        if self._g_inflight_req is not None:
            self._g_inflight_req.dec()
        sp = self._spans.pop(req.rid, None)
        if sp is not None:
            if req.error is not None:
                sp.args["error"] = type(req.error).__name__
            self.tracer.end(sp)

    def _bump_requests(self, what: str, n: float = 1.0) -> None:
        if self._m_req is not None:
            self._m_req[what].inc(n)
        if self.tracer:
            from ..trace.attribution import COUNTER_SERVE_REQUESTS

            self.tracer.bump(COUNTER_SERVE_REQUESTS, {what: n})
