"""repro.serve -- the long-running service around a compiled system.

Three layers, one per module:

  * :mod:`repro.serve.cache` -- :class:`PlanCache`: compile calls keyed
    by ``(post-rewrite program sha, target, policy, topology, knobs)``;
    repeat compiles return the cached
    :class:`~repro.flow.build.CompiledSystem` (DSE winner included)
    without re-planning.
  * :mod:`repro.serve.queue` -- :class:`AdmissionQueue`: FIFO
    coalescing of :class:`ServeRequest` element rows into planner-sized
    ``E``-element waves, padded (and pad-accounted) when the
    max-latency knob flushes an undersized wave.
  * :mod:`repro.serve.engine` -- :class:`ServeEngine`: waves feed the
    plan's stage-pipelined dispatch ring with a bounded in-flight
    window; :class:`Backpressure` / :class:`DrainTimeout` /
    :class:`EngineShutdown` give submit/drain/shutdown defined
    semantics instead of wedging the ring.

``python -m repro.serve prog.cfd --requests 32 --smoke`` runs the
whole stack against per-request serial execution (bitwise equality).
"""
from .cache import PlanCache
from .cli import main
from .engine import (Backpressure, DrainTimeout, EngineShutdown,
                     ServeEngine)
from .queue import AdmissionQueue, ServeRequest, Wave, WavePart

__all__ = [
    "AdmissionQueue",
    "Backpressure",
    "DrainTimeout",
    "EngineShutdown",
    "PlanCache",
    "ServeEngine",
    "ServeRequest",
    "Wave",
    "WavePart",
    "main",
]
