"""Plan/system cache: compile once, serve forever.

The flow's expensive half is planning -- ``plan_chain`` plus the
optional DSE sweep -- and a serving process sees the same program
compiled over and over.  :class:`PlanCache` keys each
:func:`repro.flow.build.compile` call by
``(sha of the post-rewrite program, target name, policy, topology
fingerprint, knob digest)`` (:func:`repro.flow.build.cache_key`) and
returns the cached :class:`~repro.flow.build.CompiledSystem` -- stage
callables, plan, *and* the DSE winner/ranking it was adopted from -- on
a repeat.  Only the front/middle-end (parse + rewrite, needed to
fingerprint the program) re-runs on a hit; ``plan_chain`` does not.

Hit/miss counts export through the standard counter machinery
(``trace.attribution.COUNTER_PLAN_CACHE``) when a tracer is attached.
"""
from __future__ import annotations

import time
from typing import Dict, Optional

from ..flow import build


class PlanCache:
    """In-process compile cache over :func:`repro.flow.build.compile`.

    ``max_systems`` FIFO-bounds the cache (a CompiledSystem holds jitted
    stage callables; a long-lived server should not grow one per novel
    program without bound).  ``metrics`` (a ``repro.metrics`` registry)
    adds hit/miss counters and a compile-seconds histogram on top of the
    tracer's ``COUNTER_PLAN_CACHE``.
    """

    def __init__(self, tracer=None, max_systems: int = 64,
                 metrics=None) -> None:
        if max_systems < 1:
            raise ValueError(f"max_systems must be >= 1, got {max_systems}")
        self.tracer = tracer
        self.max_systems = max_systems
        self._systems: Dict[str, build.CompiledSystem] = {}
        self.hits = 0
        self.misses = 0
        self._m_events = self._m_compile = None
        if metrics:
            self._m_events = {
                event: metrics.counter(
                    "plan_cache_total",
                    "Compile calls served from cache (hit) vs compiled "
                    "fresh (miss).", event=event)
                for event in ("hit", "miss")
            }
            self._m_compile = metrics.histogram(
                "plan_cache_compile_seconds",
                "Wall seconds per cache-miss flow compile.")

    def key(self, source: str, **compile_kwargs) -> str:
        return build.cache_key(source, **compile_kwargs)

    def lookup(self, source: str,
               **compile_kwargs) -> Optional[build.CompiledSystem]:
        """The cached system for this compile call, or None.  Does not
        count as a hit/miss (use :meth:`get_or_compile` to serve)."""
        return self._systems.get(self.key(source, **compile_kwargs))

    def get_or_compile(self, source: str,
                       **compile_kwargs) -> build.CompiledSystem:
        """Serve one compile call through the cache.

        Accepts exactly :func:`repro.flow.build.compile`'s keyword
        arguments; on a miss they are forwarded verbatim and the result
        is cached under the call's key.
        """
        key = self.key(source, **compile_kwargs)
        system = self._systems.get(key)
        if system is not None:
            self.hits += 1
            self._bump("hit")
            return system
        self.misses += 1
        self._bump("miss")
        t0 = time.perf_counter()
        system = build.compile(source, **compile_kwargs)
        if self._m_compile is not None:
            self._m_compile.observe(time.perf_counter() - t0)
        self._systems[key] = system
        while len(self._systems) > self.max_systems:
            self._systems.pop(next(iter(self._systems)))
        return system

    def _bump(self, what: str) -> None:
        if self._m_events is not None:
            self._m_events[what].inc()
        if self.tracer:
            from ..trace.attribution import COUNTER_PLAN_CACHE

            self.tracer.bump(COUNTER_PLAN_CACHE, {what: 1.0})

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    def __len__(self) -> int:
        return len(self._systems)
