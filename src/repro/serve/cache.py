"""Plan/system cache: compile once, serve forever.

The flow's expensive half is planning -- ``plan_chain`` plus the
optional DSE sweep -- and a serving process sees the same program
compiled over and over.  :class:`PlanCache` keys each
:func:`repro.flow.build.compile` call by
``(sha of the post-rewrite program, target name, policy, topology
fingerprint, knob digest)`` (:func:`repro.flow.build.cache_key`) and
returns the cached :class:`~repro.flow.build.CompiledSystem` -- stage
callables, plan, *and* the DSE winner/ranking it was adopted from -- on
a repeat.  Only the front/middle-end (parse + rewrite, needed to
fingerprint the program) re-runs on a hit; ``plan_chain`` does not.

Hit/miss counts export through the standard counter machinery
(``trace.attribution.COUNTER_PLAN_CACHE``) when a tracer is attached.
"""
from __future__ import annotations

import time
from typing import Dict, Optional

from ..flow import build


class PlanCache:
    """In-process compile cache over :func:`repro.flow.build.compile`.

    ``max_systems`` FIFO-bounds the cache (a CompiledSystem holds jitted
    stage callables; a long-lived server should not grow one per novel
    program without bound).  ``metrics`` (a ``repro.metrics`` registry)
    adds hit/miss counters and a compile-seconds histogram on top of the
    tracer's ``COUNTER_PLAN_CACHE``.
    """

    def __init__(self, tracer=None, max_systems: int = 64,
                 metrics=None) -> None:
        if max_systems < 1:
            raise ValueError(f"max_systems must be >= 1, got {max_systems}")
        self.tracer = tracer
        self.max_systems = max_systems
        self._systems: Dict[str, build.CompiledSystem] = {}
        self.hits = 0
        self.misses = 0
        self._m_events = self._m_compile = None
        if metrics:
            self._m_events = {
                event: metrics.counter(
                    "plan_cache_total",
                    "Compile calls served from cache (hit) vs compiled "
                    "fresh (miss).", event=event)
                for event in ("hit", "miss")
            }
            self._m_compile = metrics.histogram(
                "plan_cache_compile_seconds",
                "Wall seconds per cache-miss flow compile.")

    def key(self, source: str, **compile_kwargs) -> str:
        return build.cache_key(source, **compile_kwargs)

    def lookup(self, source: str,
               **compile_kwargs) -> Optional[build.CompiledSystem]:
        """The cached system for this compile call, or None.  Does not
        count as a hit/miss (use :meth:`get_or_compile` to serve)."""
        return self._systems.get(self.key(source, **compile_kwargs))

    def get_or_compile(self, source: str,
                       **compile_kwargs) -> build.CompiledSystem:
        """Serve one compile call through the cache.

        Accepts exactly :func:`repro.flow.build.compile`'s keyword
        arguments; on a miss they are forwarded verbatim and the result
        is cached under the call's key.

        ``profile=`` threads through warm hits too: the key excludes it
        (a profile store refines ranking, it does not change what is
        being compiled), so a hit re-applies the store's *current*
        correction to the cached DSE ranking -- traced runs recorded
        since the entry was compiled still reach the served candidates.
        If the refit flips the feasible winner, the entry is stale and
        is recompiled in place.
        """
        key = self.key(source, **compile_kwargs)
        system = self._systems.get(key)
        if system is not None and self._still_fresh(
                system, compile_kwargs.get("profile")):
            self.hits += 1
            self._bump("hit")
            return system
        self.misses += 1
        self._bump("miss")
        t0 = time.perf_counter()
        system = build.compile(source, **compile_kwargs)
        if self._m_compile is not None:
            self._m_compile.observe(time.perf_counter() - t0)
        self._systems[key] = system
        while len(self._systems) > self.max_systems:
            self._systems.pop(next(iter(self._systems)))
        return system

    def _still_fresh(self, system: build.CompiledSystem,
                     profile) -> bool:
        """Re-apply the profile store's current correction to a cached
        entry's DSE ranking (in place).  True unless the refit promotes
        a *different* feasible plan to the top -- then the cached system
        no longer matches what a fresh compile would serve."""
        if profile is None or not system.candidates:
            return True
        from ..memory import dse as dse_mod
        from ..trace.profile import ProfileStore

        store = ProfileStore.open(profile)
        if store is None:
            return True
        dse_mod.apply_correction(
            system.candidates, store.correction(system.target.name)
        )
        winner = next(
            (c for c in system.candidates if c.plan.feasible), None
        )
        return (winner is None
                or winner.plan.signature == system.plan.signature)

    def _bump(self, what: str) -> None:
        if self._m_events is not None:
            self._m_events[what].inc()
        if self.tracer:
            from ..trace.attribution import COUNTER_PLAN_CACHE

            self.tracer.bump(COUNTER_PLAN_CACHE, {what: 1.0})

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    def __len__(self) -> int:
        return len(self._systems)
