"""Command-line entry point for the serving layer::

    python -m repro.serve prog.cfd --requests 32 --smoke

Compiles the program through the :class:`~repro.serve.cache.PlanCache`
(twice, to demonstrate a cache hit), stands up a
:class:`~repro.serve.engine.ServeEngine`, submits synthetic requests of
mixed element counts, drains, and reports cache/coalescing/latency
stats.  ``--smoke`` additionally re-serves every request one at a time
through a second engine and fails loudly unless the coalesced outputs
are bitwise-identical to the per-request serial runs -- the CI gate.
"""
from __future__ import annotations

import argparse
import sys
from typing import Optional, Sequence

import numpy as np

from ..core.dsl import ParseError
from ..core.ir import IRError
from ..flow import build
from ..flow.cli import _parse_per_stage
from ..runtime.monitor import RequestLatency
from .cache import PlanCache
from .engine import ServeEngine


def _parse_args(argv: Optional[Sequence[str]]) -> argparse.Namespace:
    ap = argparse.ArgumentParser(
        prog="python -m repro.serve",
        description="Long-running request service over a compiled "
        "CFDlang system: plan cache + admission coalescing + "
        "stage-pipelined dispatch.",
    )
    ap.add_argument("source", help="CFDlang program file")
    ap.add_argument("--target", default=None)
    ap.add_argument("--policy", default="float32")
    ap.add_argument("--element-vars", default="")
    ap.add_argument("--max-stages", type=int, default=None)
    ap.add_argument("--batch-elements", type=int, default=None)
    ap.add_argument("--prefetch-depth", default="1",
                    help="dispatch-ring depth per stage: one int or a "
                    "comma-separated per-stage vector")
    ap.add_argument("--cu-count", default="1",
                    help="CUs per stage: one int or a per-stage vector")
    ap.add_argument("--n-eq", type=int, default=None)
    ap.add_argument("--requests", type=int, default=32,
                    help="synthetic requests to serve (default 32)")
    ap.add_argument("--window", type=int, default=None,
                    help="in-flight wave window (default: derived from "
                    "the plan's prefetch depths)")
    ap.add_argument("--max-wait-s", type=float, default=None,
                    help="flush an undersized wave after this long")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="verify coalesced outputs are bitwise-identical "
                    "to per-request serial runs (exit 1 on mismatch)")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="write a Chrome-trace JSON of the served run")
    ap.add_argument("--metrics", default=None, metavar="OUT.json",
                    help="meter the run (repro.metrics registry) and "
                    "write the snapshot JSON, SLO verdict included "
                    "(validate with python -m repro.metrics --check)")
    ap.add_argument("--slo-p95-s", type=float, default=5.0,
                    help="SLO: target p95 request latency in seconds "
                    "(default 5.0; used with --metrics)")
    ap.add_argument("--slo-error-rate", type=float, default=0.01,
                    help="SLO: request error-rate budget (default 0.01)")
    return ap.parse_args(argv)


def _synth_requests(engine: ServeEngine, n: int, seed: int):
    """Mixed-size synthetic requests: a spread of 1..~1.5E element
    counts so waves coalesce small requests AND split large ones."""
    rng = np.random.default_rng(seed + 17)
    E = engine.batch_elements
    hi = max(2, E + E // 2 + 1)
    reqs = []
    for _ in range(n):
        k = int(rng.integers(1, hi))
        reqs.append({
            q: rng.uniform(-1, 1, (k,) + shape).astype(np.float32)
            for q, shape in sorted(engine.in_specs.items())
        })
    return reqs


def main(argv: Optional[Sequence[str]] = None) -> int:
    args = _parse_args(argv)
    try:
        with open(args.source) as f:
            source = f.read()
    except OSError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    prog_name = args.source.rsplit("/", 1)[-1]
    if prog_name.endswith(".cfd"):
        prog_name = prog_name[:-4]
    if args.requests < 1:
        print("error: --requests must be >= 1", file=sys.stderr)
        return 2

    element_vars = tuple(
        v.strip() for v in args.element_vars.split(",") if v.strip()
    )
    try:
        cu_count = _parse_per_stage(args.cu_count, "--cu-count")
        prefetch_depth = _parse_per_stage(
            args.prefetch_depth, "--prefetch-depth"
        )
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    tracer = None
    if args.trace:
        from .. import trace as trace_mod

        tracer = trace_mod.Tracer()

    metrics = slo = None
    if args.metrics:
        from .. import metrics as metrics_mod

        metrics = metrics_mod.MetricsRegistry()
        slo = metrics_mod.SLOTracker(
            args.slo_p95_s, args.slo_error_rate, registry=metrics
        )

    cache = PlanCache(tracer=tracer, metrics=metrics)
    kwargs = dict(
        name=prog_name,
        element_vars=element_vars,
        target=args.target,
        policy=args.policy,
        max_stages=args.max_stages,
        batch_elements=args.batch_elements,
        prefetch_depth=prefetch_depth,
        cu_count=cu_count,
        n_eq=args.n_eq,
    )
    if args.n_eq is None and args.batch_elements is None:
        # the planner's auto-sized E fills the target's HBM channels --
        # right for batch jobs, absurd as one serving wave; size the
        # batch to the offered load instead
        kwargs["n_eq"] = max(64, 2 * args.requests)
    try:
        system = cache.get_or_compile(source, **kwargs)
        # a serving process sees the same program again and again; the
        # repeat compile must come from the cache (hit rate > 0)
        again = cache.get_or_compile(source, **kwargs)
    except (ParseError, build.FlowError, IRError, ValueError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    if again is not system:
        print("error: plan cache returned a different system for an "
              "identical compile call", file=sys.stderr)
        return 1
    print(system.plan.report())
    print()
    print(
        f"plan_cache: hits={cache.hits} misses={cache.misses} "
        f"hit_rate={cache.hit_rate:.2f}"
    )

    latency = RequestLatency()
    engine = ServeEngine(
        system, window=args.window, max_wait_s=args.max_wait_s,
        tracer=tracer, latency=latency, seed=args.seed,
        metrics=metrics, slo=slo,
    )
    request_inputs = _synth_requests(engine, args.requests, args.seed)
    served = [engine.submit(inp) for inp in request_inputs]
    engine.drain()
    failed = [r for r in served if r.error is not None]
    if failed:
        for r in failed:
            print(f"error: request r{r.rid} failed: {r.error!r}",
                  file=sys.stderr)
        return 1
    st = engine.stats
    lat = latency.summary()
    print(
        f"served {st['completed']} requests in {st['waves']} waves of "
        f"{engine.batch_elements} elements (wave pad {st['pad_elements']} "
        f"elem, plan pad {st['plan_pad_elements']} elem, "
        f"{st['ticks']} ticks)"
    )
    print(
        f"latency: mean {lat['mean_s'] * 1e3:.3f} ms   "
        f"p95 {lat['p95_s'] * 1e3:.3f} ms   "
        f"max {lat['max_s'] * 1e3:.3f} ms"
    )
    if slo is not None:
        v = slo.verdict()
        print(
            f"slo: verdict={v['verdict']} "
            f"p95 {v['p95_s'] * 1e3:.3f} ms "
            f"(target {v['target_p95_s'] * 1e3:.0f} ms)   "
            f"latency_burn {v['latency_burn']:.2f}   "
            f"error_burn {v['error_burn']:.2f}"
        )

    ok = True
    if args.smoke:
        serial = ServeEngine(system, seed=args.seed)
        mismatches = 0
        for r, inp in zip(served, request_inputs):
            ref = serial.submit(inp)
            serial.drain()
            if ref.error is not None:
                print(f"error: serial r{r.rid} failed: {ref.error!r}",
                      file=sys.stderr)
                mismatches += 1
                continue
            for q in engine.out_names:
                if not np.array_equal(r.outputs[q], ref.outputs[q]):
                    print(
                        f"error: r{r.rid} output {q} differs from the "
                        "per-request serial run", file=sys.stderr,
                    )
                    mismatches += 1
        ok = mismatches == 0 and cache.hit_rate > 0
        verdict = "ok" if ok else f"FAILED ({mismatches} mismatches)"
        print(
            f"serve-smoke: {len(served)} coalesced requests vs serial "
            f"-> bitwise {verdict}"
        )

    if tracer is not None:
        from .. import trace as trace_mod

        trace_mod.write_chrome(
            tracer, args.trace, metadata={"source": prog_name}
        )
        print(f"trace written to {args.trace}")
    if metrics is not None:
        from ..metrics import write_snapshot

        snap = write_snapshot(
            metrics, args.metrics, extra={"slo": slo.verdict()}
        )
        print(
            f"metrics written to {args.metrics} "
            f"({len(snap['metrics'])} series)"
        )
    return 0 if ok else 1
