"""Admission queue: requests in, planner-sized waves out.

The planner sizes one dispatch batch -- ``E`` elements -- to fill the
target's HBM pseudo-channels; callers arrive with whatever element
count their problem has.  The queue coalesces submitted requests, in
FIFO order, into *waves* of exactly ``E`` elements: a large request
spans several waves, several small requests share one, and an
undersized final wave is zero-padded (the pad is accounted, never
silent -- the same ``batch_pad_elements`` discipline the planner applies
when it snaps ``E`` to a block size).

A wave is only formed when ``E`` elements are pending, except when the
max-latency knob (``max_wait_s``) says the oldest request has waited
long enough, or the caller forces a flush (drain/shutdown) -- then a
padded partial wave goes out.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class ServeRequest:
    """One submitted request: per-element input rows in, output rows out.

    ``inputs`` maps the chain's qualified host stream names
    (``"stage.input"``) to arrays with a leading element axis of
    ``n_elements`` rows.  ``outputs`` fills in as the request's waves
    retire; ``error`` is set instead when any of its waves failed or the
    engine shut down with the request in flight.
    """

    rid: int
    inputs: Dict[str, np.ndarray]
    n_elements: int
    submitted_s: float = 0.0
    #: when the request's first slice was fed to the ring -- the
    #: queue-wait / wave-execution boundary of the latency decomposition
    admitted_s: float = 0.0
    completed_s: float = 0.0
    #: execution time attributable to wave zero-padding: each of the
    #: request's waves charges pad/E of its wall time here
    pad_overhead_s: float = 0.0
    outputs: Optional[Dict[str, np.ndarray]] = None
    error: Optional[BaseException] = None
    #: wave-slices this request was split into / already retired
    parts: int = 0
    parts_done: int = 0

    @property
    def done(self) -> bool:
        """Finished -- successfully (``outputs``) or not (``error``)."""
        return self.error is not None or (
            self.parts > 0 and self.parts_done >= self.parts
        )


@dataclasses.dataclass(frozen=True)
class WavePart:
    """One request's element slice ``[lo:hi)`` placed at ``dst`` in the
    wave's E-sized batch."""

    request: ServeRequest
    lo: int
    hi: int
    dst: int

    @property
    def n(self) -> int:
        return self.hi - self.lo


@dataclasses.dataclass(frozen=True)
class Wave:
    """One coalesced admission: parts covering ``E - pad_elements``
    rows, the rest zero-padding."""

    parts: tuple
    pad_elements: int


class AdmissionQueue:
    """FIFO element coalescer over :class:`ServeRequest`.

    ``clock`` is injectable for tests (defaults to ``time.monotonic``).
    ``metrics`` (a ``repro.metrics`` registry; None/NULL = off) records
    queue-depth gauges, wave size/fill-ratio/wait-age histograms, and a
    per-reason flush counter -- every wave is credited to exactly one of
    ``full`` (E pending), ``max_wait`` (latency knob expired), or
    ``force`` (drain/shutdown).
    """

    def __init__(self, batch_elements: int, *,
                 max_wait_s: Optional[float] = None,
                 clock=time.monotonic, metrics=None) -> None:
        if batch_elements < 1:
            raise ValueError(
                f"batch_elements must be >= 1, got {batch_elements}"
            )
        self.batch_elements = batch_elements
        self.max_wait_s = max_wait_s
        self.clock = clock
        #: (request, next element offset) cursors, FIFO
        self._q: deque = deque()
        self._m = None
        if metrics:
            from ..metrics import linear_buckets

            E = batch_elements
            self._m = {
                "depth_requests": metrics.gauge(
                    "admission_queue_depth_requests",
                    "Requests with unadmitted elements still queued."),
                "depth_elements": metrics.gauge(
                    "admission_queue_depth_elements",
                    "Element rows pending admission."),
                "wave_size": metrics.histogram(
                    "admission_wave_size_elements",
                    "Real (non-pad) element rows per admitted wave.",
                    buckets=linear_buckets(0, E, min(E, 16))),
                "fill": metrics.histogram(
                    "admission_wave_fill_ratio",
                    "Wave fill: real rows / E (1.0 = no padding).",
                    buckets=linear_buckets(0.0, 1.0, 10)),
                "wait": metrics.histogram(
                    "admission_wait_age_seconds",
                    "Age of the oldest queued request at wave admission."),
                "flush": {
                    reason: metrics.counter(
                        "admission_flush_total",
                        "Admitted waves by trigger: full E pending, "
                        "max_wait_s expiry, or forced (drain/shutdown).",
                        reason=reason)
                    for reason in ("full", "max_wait", "force")
                },
            }

    def _gauge_depth(self) -> None:
        if self._m is not None:
            self._m["depth_requests"].set(float(len(self._q)))
            self._m["depth_elements"].set(float(self.pending_elements))

    def push(self, req: ServeRequest) -> None:
        req.submitted_s = self.clock()
        self._q.append([req, 0])
        self._gauge_depth()

    def remove(self, req: ServeRequest) -> bool:
        """Drop a request that has not been (partially) admitted yet --
        the reject path.  Returns False if admission already began."""
        for entry in self._q:
            if entry[0] is req:
                if entry[1] != 0:
                    return False
                self._q.remove(entry)
                self._gauge_depth()
                return True
        return False

    @property
    def pending_elements(self) -> int:
        return sum(r.n_elements - off for r, off in self._q)

    @property
    def pending_requests(self) -> List[ServeRequest]:
        return [r for r, _ in self._q]

    def ready(self, *, force: bool = False) -> bool:
        """Is a wave due?  A full ``E`` is pending, or the oldest
        request has outwaited ``max_wait_s``, or the caller forces."""
        if not self._q:
            return False
        if self.pending_elements >= self.batch_elements:
            return True
        if force:
            return True
        if self.max_wait_s is not None:
            return self.clock() - self._q[0][0].submitted_s >= self.max_wait_s
        return False

    def pop_wave(self, *, force: bool = False) -> Optional[Wave]:
        """Assemble the next wave, or None when none is due.

        Requests are consumed strictly FIFO; a request larger than the
        remaining room contributes a slice and keeps its place at the
        head for the next wave.
        """
        if not self.ready(force=force):
            return None
        E = self.batch_elements
        reason, age = "force", 0.0
        if self._m is not None:
            age = self.clock() - self._q[0][0].submitted_s
            if self.pending_elements >= E:
                reason = "full"
            elif (self.max_wait_s is not None
                  and age >= self.max_wait_s):
                reason = "max_wait"
        parts: List[WavePart] = []
        dst = 0
        while self._q and dst < E:
            req, off = self._q[0]
            take = min(req.n_elements - off, E - dst)
            parts.append(WavePart(req, off, off + take, dst))
            req.parts += 1
            dst += take
            if off + take >= req.n_elements:
                self._q.popleft()
            else:
                self._q[0][1] = off + take
        if self._m is not None:
            self._m["wave_size"].observe(float(dst))
            self._m["fill"].observe(dst / E)
            self._m["wait"].observe(age)
            self._m["flush"][reason].inc()
            self._gauge_depth()
        return Wave(parts=tuple(parts), pad_elements=E - dst)
