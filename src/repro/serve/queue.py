"""Admission queue: requests in, planner-sized waves out.

The planner sizes one dispatch batch -- ``E`` elements -- to fill the
target's HBM pseudo-channels; callers arrive with whatever element
count their problem has.  The queue coalesces submitted requests, in
FIFO order, into *waves* of exactly ``E`` elements: a large request
spans several waves, several small requests share one, and an
undersized final wave is zero-padded (the pad is accounted, never
silent -- the same ``batch_pad_elements`` discipline the planner applies
when it snaps ``E`` to a block size).

A wave is only formed when ``E`` elements are pending, except when the
max-latency knob (``max_wait_s``) says the oldest request has waited
long enough, or the caller forces a flush (drain/shutdown) -- then a
padded partial wave goes out.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class ServeRequest:
    """One submitted request: per-element input rows in, output rows out.

    ``inputs`` maps the chain's qualified host stream names
    (``"stage.input"``) to arrays with a leading element axis of
    ``n_elements`` rows.  ``outputs`` fills in as the request's waves
    retire; ``error`` is set instead when any of its waves failed or the
    engine shut down with the request in flight.
    """

    rid: int
    inputs: Dict[str, np.ndarray]
    n_elements: int
    submitted_s: float = 0.0
    completed_s: float = 0.0
    outputs: Optional[Dict[str, np.ndarray]] = None
    error: Optional[BaseException] = None
    #: wave-slices this request was split into / already retired
    parts: int = 0
    parts_done: int = 0

    @property
    def done(self) -> bool:
        """Finished -- successfully (``outputs``) or not (``error``)."""
        return self.error is not None or (
            self.parts > 0 and self.parts_done >= self.parts
        )


@dataclasses.dataclass(frozen=True)
class WavePart:
    """One request's element slice ``[lo:hi)`` placed at ``dst`` in the
    wave's E-sized batch."""

    request: ServeRequest
    lo: int
    hi: int
    dst: int

    @property
    def n(self) -> int:
        return self.hi - self.lo


@dataclasses.dataclass(frozen=True)
class Wave:
    """One coalesced admission: parts covering ``E - pad_elements``
    rows, the rest zero-padding."""

    parts: tuple
    pad_elements: int


class AdmissionQueue:
    """FIFO element coalescer over :class:`ServeRequest`.

    ``clock`` is injectable for tests (defaults to ``time.monotonic``).
    """

    def __init__(self, batch_elements: int, *,
                 max_wait_s: Optional[float] = None,
                 clock=time.monotonic) -> None:
        if batch_elements < 1:
            raise ValueError(
                f"batch_elements must be >= 1, got {batch_elements}"
            )
        self.batch_elements = batch_elements
        self.max_wait_s = max_wait_s
        self.clock = clock
        #: (request, next element offset) cursors, FIFO
        self._q: deque = deque()

    def push(self, req: ServeRequest) -> None:
        req.submitted_s = self.clock()
        self._q.append([req, 0])

    def remove(self, req: ServeRequest) -> bool:
        """Drop a request that has not been (partially) admitted yet --
        the reject path.  Returns False if admission already began."""
        for entry in self._q:
            if entry[0] is req:
                if entry[1] != 0:
                    return False
                self._q.remove(entry)
                return True
        return False

    @property
    def pending_elements(self) -> int:
        return sum(r.n_elements - off for r, off in self._q)

    @property
    def pending_requests(self) -> List[ServeRequest]:
        return [r for r, _ in self._q]

    def ready(self, *, force: bool = False) -> bool:
        """Is a wave due?  A full ``E`` is pending, or the oldest
        request has outwaited ``max_wait_s``, or the caller forces."""
        if not self._q:
            return False
        if self.pending_elements >= self.batch_elements:
            return True
        if force:
            return True
        if self.max_wait_s is not None:
            return self.clock() - self._q[0][0].submitted_s >= self.max_wait_s
        return False

    def pop_wave(self, *, force: bool = False) -> Optional[Wave]:
        """Assemble the next wave, or None when none is due.

        Requests are consumed strictly FIFO; a request larger than the
        remaining room contributes a slice and keeps its place at the
        head for the next wave.
        """
        if not self.ready(force=force):
            return None
        E = self.batch_elements
        parts: List[WavePart] = []
        dst = 0
        while self._q and dst < E:
            req, off = self._q[0]
            take = min(req.n_elements - off, E - dst)
            parts.append(WavePart(req, off, off + take, dst))
            req.parts += 1
            dst += take
            if off + take >= req.n_elements:
                self._q.popleft()
            else:
                self._q[0][1] = off + take
        return Wave(parts=tuple(parts), pad_elements=E - dst)
