"""Design-space exploration over memory architectures (CHARM-style CDSE).

Sweeps the planner's knobs -- backend, precision policy, batch size E,
prefetch depth K, CU replication -- and scores every candidate plan with
a three-term analytic cost model (compute / device-memory / host-link,
the same terms as ``analysis.roofline`` and sharing its target constants
through ``memory.channels``).  Returns a ranked candidate list plus the
Pareto front over (predicted time, resident device memory); the top
candidates can optionally be *verified by measurement* through the real
simulation driver, mirroring the paper's predict-then-build loop.

The model is deliberately monotone: more bandwidth or more FLOP/s never
predicts a slower plan (tested), so sweeps over hypothetical machines
(``MemoryTarget.with_``) are safe to reason about directionally.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

from ..core import dsl, ir, rewrite
from ..core.precision import POLICIES
from ..core.schedule import Schedule, schedule as make_schedule
from . import layout
from .channels import MemoryTarget, detect_target
from .plan import (BufferSpec, CostBreakdown, MemoryPlan, channels_used,
                   hbm_stream_bytes, host_stream_bytes)

#: Cost-model epoch.  Bump this whenever the analytic model's terms
#: change meaning (new term, re-derived constant, different bottleneck
#: attribution): ``trace.ProfileStore`` stamps every recorded sample
#: with the epoch and a ``correction()`` refit ignores samples recorded
#: under any other epoch, so measured/predicted ratios from an obsolete
#: model can never steer the current one.
COST_MODEL_VERSION = 1

#: Throughput of each scalar policy relative to the target's native
#: matmul peak (TPU: bf16 MXU; f32 runs at half rate, f64 and the
#: integer-emulated fixed-point formats far below).
POLICY_EFFICIENCY = {
    "bfloat16": 1.0,
    "float32": 0.5,
    "float64": 0.125,
    "fixed32_q8.24": 0.25,
    "fixed64_q24.40": 0.0625,
}


def _resolve_program(
    p_or_prog: Union[int, ir.Program], operator_name: Optional[str]
) -> Tuple[ir.Program, str]:
    """An int selects the paper's Inverse-Helmholtz operator at degree p."""
    if isinstance(p_or_prog, ir.Program):
        return p_or_prog, operator_name or "program"
    p = int(p_or_prog)
    prog = rewrite.optimize(
        dsl.parse(
            dsl.INVERSE_HELMHOLTZ_SRC.format(p=p),
            element_vars=("u", "D", "v"),
        )
    )
    return prog, operator_name or f"inverse_helmholtz_p{p}"


def predict_cost(
    target: MemoryTarget,
    *,
    policy: str,
    batch_elements: int,
    flops_per_element: int,
    host_bytes: int,
    hbm_bytes: int,
    channels_used: int,
    prefetch_depth: int,
    cu_count: int,
    n_batches: Optional[int] = None,
) -> CostBreakdown:
    """Per-batch time under the three-term overlap model.

    Device bandwidth is what the *assigned channels* deliver (the paper's
    point: unmapped pseudo-channels are wasted bandwidth); the host link
    is shared across replicated CUs.
    """
    eff = POLICY_EFFICIENCY.get(policy, 0.25)
    t_compute = (
        batch_elements * flops_per_element / (target.peak_flops * eff * cu_count)
    )
    bw = target.channel_bw * min(max(1, channels_used), target.n_channels)
    t_hbm = hbm_bytes / (bw * cu_count)
    t_host = host_bytes / target.host_link_bw
    t_over = target.dispatch_overhead_s
    t_serial = t_host + max(t_compute, t_hbm) + t_over
    if prefetch_depth == 0:
        t_pipelined = t_serial
    else:
        t_pipelined = max(t_host, t_compute, t_hbm) + t_over
        if n_batches:
            # pipeline fill: K transfers before the first compute (never
            # more than the batches that exist beyond the first)
            fill = min(prefetch_depth, n_batches - 1)
            t_pipelined += fill * t_host / n_batches
    return CostBreakdown(
        t_compute=t_compute, t_hbm=t_hbm, t_host=t_host, t_overhead=t_over,
        t_serial=t_serial, t_pipelined=t_pipelined,
    )


def make_plan(
    p_or_prog: Union[int, ir.Program],
    *,
    target: Optional[MemoryTarget] = None,
    policy: str = "float32",
    backend: str = "xla",
    batch_elements: Optional[int] = None,
    prefetch_depth: int = 1,
    cu_count: int = 1,
    n_eq: Optional[int] = None,
    channel_bytes: Optional[int] = None,
    operator_name: Optional[str] = None,
    _schedule: Optional[Schedule] = None,
) -> MemoryPlan:
    """Plan the memory architecture for one design point.

    ``batch_elements=None`` auto-sizes E from the channel capacity (the
    paper's rule); ``channel_bytes`` overrides the target's channel size
    (e.g. the paper's 256 MB).  Deterministic: same arguments, same plan.
    """
    target = target if target is not None else detect_target()
    if isinstance(policy, str):
        if policy not in POLICIES:
            raise ValueError(
                f"unknown policy {policy!r}; known: {sorted(POLICIES)}"
            )
        pol = POLICIES[policy]
    else:
        pol = policy
    bps = pol.bits // 8
    prog, name = _resolve_program(p_or_prog, operator_name)

    sched = _schedule
    if sched is None and backend == "staged":
        sched = make_schedule(prog, bytes_per_scalar=bps)

    blk_cap = layout.vmem_block_elements(prog, target, bytes_per_scalar=bps)
    pad = 0
    if batch_elements is not None:
        e = batch_elements
    else:
        e = layout.auto_batch_elements(
            prog, target, bytes_per_scalar=bps,
            channel_bytes=channel_bytes, n_eq=n_eq,
        )
        # auto-sized E is padded to a block multiple so a prime-ish
        # channel quotient never forces the Pallas block divisor tiny
        e, pad = layout.pad_batch_for_block(e, blk_cap, limit=n_eq)
    e = max(1, int(e))
    if n_eq is not None:
        e = min(e, max(1, n_eq))  # a batch never exceeds the problem
    bufs = layout.build_buffers(
        prog, target, bytes_per_scalar=bps, batch_elements=e,
        prefetch_depth=prefetch_depth, schedule=sched,
    )

    flops_pe = prog.total_flops()
    n_batches = max(1, n_eq // e) if n_eq else None
    cost = predict_cost(
        target, policy=pol.name, batch_elements=e,
        flops_per_element=flops_pe, host_bytes=host_stream_bytes(bufs),
        hbm_bytes=hbm_stream_bytes(bufs), channels_used=channels_used(bufs),
        prefetch_depth=prefetch_depth, cu_count=cu_count,
        n_batches=n_batches,
    )

    # on-chip block: largest divisor of E whose fused-kernel working set
    # fits the VMEM budget (drives the Pallas kernel's block_elements)
    blk = layout.largest_divisor_leq(e, blk_cap)
    blk_ws = layout.block_working_set_bytes(prog, blk, bytes_per_scalar=bps)

    feasible, reason = True, ""
    resident = sum(b.resident_bytes for b in bufs)
    if resident > target.usable_hbm_bytes:
        feasible = False
        reason = (
            f"resident {resident / 2**20:.0f} MiB exceeds usable HBM "
            f"{target.usable_hbm_bytes / 2**20:.0f} MiB"
        )
    elif blk_ws > target.vmem_bytes:
        # even the BE=1 floor cannot fit on-chip: no fused kernel can run
        feasible = False
        reason = (
            f"block working set {blk_ws} B (BE={blk}) exceeds on-chip "
            f"{target.vmem_bytes} B"
        )
    elif sched is not None:
        ws = max(g.working_set(bps) for g in sched.groups)
        if ws > target.vmem_bytes:
            feasible = False
            reason = (
                f"stage working set {ws} B exceeds on-chip "
                f"{target.vmem_bytes} B"
            )

    return MemoryPlan(
        operator=name, target=target, policy=pol.name, backend=backend,
        batch_elements=e, prefetch_depth=prefetch_depth, cu_count=cu_count,
        buffers=bufs, cost=cost, feasible=feasible,
        infeasible_reason=reason, flops_per_element=flops_pe,
        block_elements=blk, block_working_set_bytes=blk_ws,
        batch_pad_elements=pad,
    )


# ---------------------------------------------------------------------------
# exploration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DesignSpace:
    """The sweep axes (defaults mirror the paper's evaluation grid)."""

    backends: Tuple[str, ...] = ("xla", "staged")
    policies: Tuple[str, ...] = ("float32", "bfloat16")
    #: divisors of the auto-sized E to try (1 = the paper's full channel)
    batch_divisors: Tuple[int, ...] = (1, 2, 4)
    prefetch_depths: Tuple[int, ...] = (0, 1, 2, 4)
    cu_counts: Tuple[int, ...] = (1, 2, 4)


@dataclasses.dataclass
class Candidate:
    """One explored design point, ranked by predicted time/element."""

    plan: MemoryPlan
    predicted_s_per_element: float
    measured_s_per_element: Optional[float] = None
    #: prediction after the measured-feedback correction (calibrate=True)
    corrected_s_per_element: Optional[float] = None

    @property
    def verified(self) -> bool:
        """True once this design point has a measured run behind it."""
        return self.measured_s_per_element is not None


@dataclasses.dataclass(frozen=True)
class CostCorrection:
    """Measured-feedback correction for the analytic model, learned *per
    cost term* from measured ladders (the ROADMAP's split of the old
    single scalar): candidates whose measured runs were bottlenecked on
    the host link calibrate ``host_factor``, HBM-bound runs calibrate
    ``hbm_factor``, compute-bound runs ``compute_factor`` -- each the
    geometric mean of measured/predicted ratios over that class.
    ``factor`` is the overall geometric mean and the fallback for terms
    the ladder never exercised.  All factors are positive multipliers,
    so the model's monotonicity guarantees survive correction."""

    factor: float = 1.0
    n_samples: int = 0
    host_factor: Optional[float] = None
    hbm_factor: Optional[float] = None
    compute_factor: Optional[float] = None

    def factor_for(self, bottleneck: Optional[str] = None) -> float:
        """The multiplier for a prediction dominated by ``bottleneck``
        (a ``CostBreakdown.bottleneck`` label); overall factor when the
        term was never measured (or no term is given)."""
        per_term = {
            "host-link": self.host_factor,
            "hbm": self.hbm_factor,
            "compute": self.compute_factor,
        }.get(bottleneck)
        return per_term if per_term is not None else self.factor

    def corrected(
        self, predicted_s: float, bottleneck: Optional[str] = None
    ) -> float:
        """The prediction rescaled by its bottleneck's fitted factor."""
        return predicted_s * self.factor_for(bottleneck)


def _geomean(ratios: Sequence[float]) -> float:
    import math

    return math.exp(sum(math.log(r) for r in ratios) / len(ratios))


def fit_correction(cands: Sequence[Candidate]) -> CostCorrection:
    """Fit the per-term correction from every measured candidate
    (identity when nothing was measured).  Each measured run's
    measured/predicted ratio is attributed to the cost term its plan
    predicts as the bottleneck.  Accepts single-op and chain candidates
    alike: a ChainPlan's ``cost.bottleneck`` is its bottleneck stage's
    dominating term."""
    ratios: List[float] = []
    by_term: Dict[str, List[float]] = {}
    for c in cands:
        if not c.verified or c.predicted_s_per_element <= 0:
            continue
        r = c.measured_s_per_element / c.predicted_s_per_element
        ratios.append(r)
        by_term.setdefault(c.plan.cost.bottleneck, []).append(r)
    if not ratios:
        return CostCorrection()
    term = {
        k: _geomean(v) if v else None
        for k, v in (
            ("host-link", by_term.get("host-link")),
            ("hbm", by_term.get("hbm")),
            ("compute", by_term.get("compute")),
        )
    }
    return CostCorrection(
        factor=_geomean(ratios), n_samples=len(ratios),
        host_factor=term["host-link"], hbm_factor=term["hbm"],
        compute_factor=term["compute"],
    )


def apply_correction(
    cands: List[Candidate], correction: CostCorrection
) -> List[Candidate]:
    """Annotate every candidate with its corrected prediction (scaled by
    the factor of the term its own cost model says dominates) and
    re-rank (measured values, where present, outrank corrected
    predictions)."""
    for c in cands:
        c.corrected_s_per_element = correction.corrected(
            c.predicted_s_per_element, c.plan.cost.bottleneck
        )
    cands.sort(
        key=lambda c: (
            not c.plan.feasible,
            (c.measured_s_per_element
             if c.measured_s_per_element is not None
             else c.corrected_s_per_element),
            c.plan.resident_bytes,
        )
    )
    return cands


def explore(
    p_or_prog: Union[int, ir.Program] = 11,
    *,
    target: Optional[MemoryTarget] = None,
    n_eq: int = 1 << 16,
    space: Optional[DesignSpace] = None,
    measure_top: int = 0,
    measure_batches: int = 4,
    operator_name: Optional[str] = None,
    calibrate: bool = False,
) -> List[Candidate]:
    """Sweep the design space; return candidates ranked best-first.

    Infeasible plans rank after all feasible ones (kept for the report).
    ``measure_top`` verifies the k best measurable candidates against the
    real simulation driver and stores seconds/element alongside the
    prediction.  ``calibrate`` additionally fits the measured-feedback
    :class:`CostCorrection` from those runs and re-ranks every candidate
    by its corrected prediction (the paper's predict-then-build loop).
    """
    if calibrate and not measure_top:
        raise ValueError(
            "calibrate=True fits the correction from measured runs; "
            "set measure_top > 0"
        )
    target = target if target is not None else detect_target()
    space = space or DesignSpace()
    prog, name = _resolve_program(p_or_prog, operator_name)

    sched_cache: Dict[int, Schedule] = {}
    cands: List[Candidate] = []
    for policy in space.policies:
        bps = POLICIES[policy].bits // 8
        auto_e = layout.auto_batch_elements(
            prog, target, bytes_per_scalar=bps, n_eq=n_eq
        )
        # the sweep explores divisors of the *padded* auto-E, so every
        # candidate batch stays block-composite
        auto_e, _ = layout.pad_batch_for_block(
            auto_e,
            layout.vmem_block_elements(prog, target, bytes_per_scalar=bps),
            limit=n_eq,
        )
        e_cands = sorted({max(1, auto_e // d) for d in space.batch_divisors})
        for backend in space.backends:
            sched = None
            if backend == "staged":
                if bps not in sched_cache:
                    sched_cache[bps] = make_schedule(
                        prog, bytes_per_scalar=bps
                    )
                sched = sched_cache[bps]
            for e in e_cands:
                for depth in space.prefetch_depths:
                    for cu in space.cu_counts:
                        plan = make_plan(
                            prog, target=target, policy=policy,
                            backend=backend, batch_elements=e,
                            prefetch_depth=depth, cu_count=cu, n_eq=n_eq,
                            operator_name=name, _schedule=sched,
                        )
                        cands.append(
                            Candidate(
                                plan=plan,
                                predicted_s_per_element=(
                                    plan.cost.t_pipelined / plan.batch_elements
                                ),
                            )
                        )

    cands.sort(
        key=lambda c: (
            not c.plan.feasible,
            c.predicted_s_per_element,
            c.plan.resident_bytes,
        )
    )
    if measure_top:
        _measure_candidates(
            cands, p_or_prog, measure_top, n_eq=n_eq,
            max_batches=measure_batches,
        )
        if calibrate:
            apply_correction(cands, fit_correction(cands))
    return cands


# ---------------------------------------------------------------------------
# chain exploration (multi-operator programs)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ChainDesignSpace:
    """Sweep axes for a ProgramChain: per-stage backends are crossed
    (every combination up to ``max_backend_combos``), E divisors divide
    the co-sized chain E, and ``prefetch_depths`` x ``cu_counts`` form
    the *per-stage* placement menu: besides the chain-wide uniform
    sweep, :func:`explore_chain` searches joint per-stage
    ``(cu_count, prefetch_depth)`` vectors over the topology, keeping
    the ``max_placements`` best under a monotone-pruned frontier."""

    backends: Tuple[str, ...] = ("xla", "staged")
    policies: Tuple[str, ...] = ("float32",)
    batch_divisors: Tuple[int, ...] = (1, 2, 4)
    prefetch_depths: Tuple[int, ...] = (0, 1, 2)
    cu_counts: Tuple[int, ...] = (1,)
    max_backend_combos: int = 16
    #: joint per-stage placements kept per (policy, backends, E) point
    max_placements: int = 16
    #: branch-and-bound expansion cap (safety valve for deep chains)
    max_search_nodes: int = 20000


@dataclasses.dataclass
class ChainCandidate:
    """One explored chain design point (ranked like Candidate; the
    ``plan`` attribute makes :func:`pareto_front` and the measured-
    feedback :func:`apply_correction` work unchanged -- ``ChainCost``
    exposes the bottleneck stage's dominating term as its
    ``bottleneck``)."""

    plan: "chain_mod.ChainPlan"
    predicted_s_per_element: float
    measured_s_per_element: Optional[float] = None
    #: prediction after the measured-feedback correction (calibrate=True)
    corrected_s_per_element: Optional[float] = None

    @property
    def verified(self) -> bool:
        """True once this design point has a measured run behind it."""
        return self.measured_s_per_element is not None


def measure_chain_plan(
    chain: "chain_mod.ProgramChain",
    plan: "chain_mod.ChainPlan",
    *,
    max_batches: int = 4,
) -> Optional[float]:
    """Verify a chain plan by running the real pipeline driver; seconds
    per element.  Returns None when the plan is not runnable here (the
    placement spans more devices than are local -- run_chain would fall
    back to the single mesh and the measurement would belong to a
    different configuration -- planned backends differ from how the
    chain was compiled, or the runtime rejects it)."""
    import jax

    from ..cfd.simulation import run_chain  # lazy: no cycle

    if plan.placement.devices_used[-1] >= len(jax.devices()):
        return None
    compiled_backends = tuple(s.backend for s in chain.stages)
    if tuple(sp.backend for sp in plan.stages) != compiled_backends:
        return None  # would measure a different program than planned
    try:
        run_chain(chain, plan, max_batches=1)  # warm compile
        res = run_chain(chain, plan, max_batches=max_batches)
    except Exception:
        return None
    return res.wall_s / res.elements if res.elements else None


def _search_stage_placements(
    stage_costs: Sequence[CostBreakdown],
    space: ChainDesignSpace,
    topology,
    batch_elements: int,
) -> List[Tuple[Tuple[int, ...], Tuple[int, ...]]]:
    """Branch-and-bound over joint per-stage ``(cu, depth)`` vectors.

    ``stage_costs`` are the per-stage cost terms at ``cu=1`` (from one
    reference plan); a stage's device terms scale as ``1/cu`` and its
    contention comes from the topology assignment, so candidate vectors
    are scored without re-planning.  The frontier prune is *monotone*:
    extending a partial vector can only raise its max per-stage time,
    and every final score (back-to-back sum, or contended steady state)
    is bounded below by that max -- so a partial vector whose optimistic
    max already matches the k-th best completed score cannot improve the
    kept set and its whole subtree is cut.  Returns the up-to-
    ``max_placements`` best ``(cu_counts, prefetch_depths)`` vectors.
    """
    from .placement import place_chain

    n = len(stage_costs)
    # branch on cu only: the proxy score depends on depths solely
    # through "is any inter-stage ring open", so enumerating per-stage
    # depth permutations would burn the node budget |depths|-fold on
    # score-identical siblings.  Depth shapes are attached at the
    # leaves instead (serial / staging-only / uniform pipelined) and
    # priced exactly by plan_chain afterwards.
    opts: List[List[Tuple[float, int]]] = []
    for c in stage_costs:
        o: List[Tuple[float, int]] = []
        for cu in sorted(set(space.cu_counts)):
            if cu < 1 or cu > topology.n_devices or batch_elements % cu:
                continue
            t = max(c.t_host, max(c.t_compute, c.t_hbm) / cu) + c.t_overhead
            o.append((t, cu))
        if not o:
            o = [(
                max(c.t_host, max(c.t_compute, c.t_hbm)) + c.t_overhead, 1,
            )]
        o.sort()
        opts.append(o)

    def score(cus: Tuple[int, ...], pipelined: bool) -> float:
        place = place_chain(topology, cus, 1, n_stages=n)
        cont = place.contention
        b2b, steady = 0.0, 0.0
        for i, c in enumerate(stage_costs):
            dev = max(c.t_compute, c.t_hbm) / place.cu_counts[i]
            b2b += max(c.t_host, dev) + c.t_overhead
            steady = max(
                steady, max(c.t_host, cont[i] * dev) + c.t_overhead
            )
        return min(b2b, steady) if pipelined and n > 1 else b2b

    K = max(1, space.max_placements)
    best: List[Tuple[float, Tuple[int, ...]]] = []
    visited = 0

    def dfs(i: int, cus: List[int], partial_max: float) -> None:
        nonlocal visited
        visited += 1
        if visited > space.max_search_nodes:
            return
        if len(best) >= K and partial_max >= best[-1][0]:
            return  # monotone prune: no completion can beat the kept set
        if i == n:
            vec = tuple(cus)
            best.append((score(vec, pipelined=True), vec))
            best.sort(key=lambda x: x[0])
            del best[K:]
            return
        for t, cu in opts[i]:
            cus.append(cu)
            dfs(i + 1, cus, max(partial_max, t))
            cus.pop()

    dfs(0, [], 0.0)

    # canonical depth shapes per kept cu vector: pure serial, staging-
    # only (host rings deep, stages back-to-back -- a non-uniform
    # vector), and uniform pipelined at each positive swept depth
    positive = sorted({d for d in space.prefetch_depths if d > 0})
    shapes: List[Tuple[Tuple[int, ...], bool]] = []
    if 0 in space.prefetch_depths:
        shapes.append(((0,) * n, False))
    if positive:
        shapes.append(((max(positive),) + (0,) * (n - 1), False))
        shapes += [((d,) * n, True) for d in positive]
    if not shapes:
        shapes = [((0,) * n, False)]
    scored = [
        (score(cus, pipelined), cus, depths)
        for _, cus in best
        for depths, pipelined in shapes
    ]
    scored.sort(key=lambda x: x[0])
    # fair truncation across depth shapes: keep the best vectors of
    # every schedule shape, not K copies of the uniform-pipelined one
    # -- the proxy cannot price fill/residency, so the exact planner
    # must see serial and staging-only candidates too
    buckets = [
        [s for s in scored if s[2] == depths] for depths, _ in shapes
    ]
    kept: List[Tuple[float, Tuple[int, ...], Tuple[int, ...]]] = []
    while len(kept) < K and any(buckets):
        for b in buckets:
            if b and len(kept) < K:
                kept.append(b.pop(0))
    kept.sort(key=lambda x: x[0])
    return [(cus, depths) for _, cus, depths in kept]


def _search_hetero_placements(
    group_costs: Dict[int, Sequence[CostBreakdown]],
    space: ChainDesignSpace,
    topology,
    batch_elements: int,
) -> List[Tuple[Tuple[int, ...], Tuple[int, ...], Tuple[int, ...],
                Tuple[int, ...]]]:
    """Branch-and-bound over joint per-stage ``(group, cu, E_s)``
    assignments on a heterogeneous topology.

    ``group_costs[gi]`` holds the per-stage cost terms of a reference
    plan with every stage pinned to kind group ``gi`` at ``cu=1`` and
    the chain E -- so each stage's candidate options are priced against
    the datasheet it would actually land on.  An option's proxy time is
    ``max(t_host, dev/cu) + m * t_overhead`` with ``m = E / E_s`` (a
    smaller E_s buys nothing in the proxy but lets small-memory groups
    pass the exact planner's residency/VMEM checks, which is why it is
    an axis at all).  The prune is the same monotone argument as the
    homogeneous search: every completed score is bounded below by the
    partial per-stage max.  Depth shapes are attached at the leaves and
    re-block costs are left to the exact planner -- the frontier is a
    menu, ``plan_chain`` is the judge.  Returns up to ``max_placements``
    ``(cu_counts, prefetch_depths, stage_groups, stage_elements)``.
    """
    from . import chain as chain_mod  # lazy: chain imports predict_cost
    from .placement import place_chain

    if not group_costs:
        return []
    n = len(next(iter(group_costs.values())))
    e = batch_elements
    divisors = sorted({max(1, int(d)) for d in space.batch_divisors})

    # per-stage option menu: (proxy time, group, cu, E_s), best first,
    # truncated so deep chains cannot blow up the search tree
    opts: List[List[Tuple[float, int, int, int]]] = []
    for i in range(n):
        o: Dict[Tuple[int, int, int], float] = {}
        for gi, costs in sorted(group_costs.items()):
            c = costs[i]
            size = topology.groups[gi].n_devices
            dev = max(c.t_compute, c.t_hbm)
            for cu in sorted(set(space.cu_counts)):
                if cu < 1 or cu > size or e % cu:
                    continue
                for d in divisors:
                    e_s = chain_mod.snap_stage_elements(
                        e, max(1, e // d), cu
                    )
                    m = max(1, e // e_s)
                    t = max(c.t_host, dev / cu) + m * c.t_overhead
                    key = (gi, cu, e_s)
                    if key not in o or t < o[key]:
                        o[key] = t
        lst = sorted((t, gi, cu, es) for (gi, cu, es), t in o.items())
        if not lst:
            gi = min(group_costs)
            c = group_costs[gi][i]
            lst = [(
                max(c.t_host, max(c.t_compute, c.t_hbm)) + c.t_overhead,
                gi, 1, e,
            )]
        opts.append(lst[:12])

    def score(
        gis: Tuple[int, ...], cus: Tuple[int, ...],
        es: Tuple[int, ...], pipelined: bool,
    ) -> float:
        place = place_chain(
            topology, cus, 1, n_stages=n, stage_groups=gis
        )
        cont = place.contention
        b2b, steady = 0.0, 0.0
        for i in range(n):
            c = group_costs[gis[i]][i]
            m = max(1, e // es[i])
            dev = max(c.t_compute, c.t_hbm) / place.cu_counts[i]
            b2b += max(c.t_host, dev) + m * c.t_overhead
            steady = max(
                steady, max(c.t_host, cont[i] * dev) + m * c.t_overhead
            )
        return min(b2b, steady) if pipelined and n > 1 else b2b

    K = max(1, space.max_placements)
    best: List[Tuple[float, Tuple[int, ...], Tuple[int, ...],
                     Tuple[int, ...]]] = []
    visited = 0

    def dfs(
        i: int, gis: List[int], cus: List[int], es: List[int],
        partial_max: float,
    ) -> None:
        nonlocal visited
        visited += 1
        if visited > space.max_search_nodes:
            return
        if len(best) >= K and partial_max >= best[-1][0]:
            return  # monotone prune, as in the homogeneous search
        if i == n:
            g, c, s = tuple(gis), tuple(cus), tuple(es)
            best.append((score(g, c, s, pipelined=True), g, c, s))
            best.sort(key=lambda x: x[0])
            del best[K:]
            return
        for t, gi, cu, e_s in opts[i]:
            gis.append(gi); cus.append(cu); es.append(e_s)
            dfs(i + 1, gis, cus, es, max(partial_max, t))
            gis.pop(); cus.pop(); es.pop()

    dfs(0, [], [], [], 0.0)

    positive = sorted({d for d in space.prefetch_depths if d > 0})
    shapes: List[Tuple[Tuple[int, ...], bool]] = []
    if 0 in space.prefetch_depths:
        shapes.append(((0,) * n, False))
    if positive:
        shapes.append(((max(positive),) + (0,) * (n - 1), False))
        shapes += [((d,) * n, True) for d in positive]
    if not shapes:
        shapes = [((0,) * n, False)]
    scored = [
        (score(gis, cus, es, pipelined), cus, depths, gis, es)
        for _, gis, cus, es in best
        for depths, pipelined in shapes
    ]
    scored.sort(key=lambda x: x[0])
    buckets = [
        [s for s in scored if s[2] == depths] for depths, _ in shapes
    ]
    kept: List = []
    while len(kept) < K and any(buckets):
        for b in buckets:
            if b and len(kept) < K:
                kept.append(b.pop(0))
    kept.sort(key=lambda x: x[0])
    return [(cus, depths, gis, es) for _, cus, depths, gis, es in kept]


def explore_chain(
    chain: "chain_mod.ProgramChain",
    *,
    target: Optional[MemoryTarget] = None,
    n_eq: int = 1 << 16,
    space: Optional[ChainDesignSpace] = None,
    topology=None,
    measure_top: int = 0,
    measure_batches: int = 4,
    calibrate: bool = False,
    profile=None,
    fuse: Optional[str] = None,
    max_stages: Optional[int] = None,
    fuse_barriers: Sequence[str] = (),
) -> List[ChainCandidate]:
    """Sweep chain plans: per-stage backend combinations and *joint
    per-stage placements* under one shared (divisor-scaled) E.

    ``fuse='auto'`` (or a ``max_stages`` budget below the stage count)
    first runs the cost-driven fusion pass
    (:func:`repro.memory.fusion.fuse_chain_auto`) with default knobs and
    then sweeps the *fused* chain -- so every candidate shares one stage
    structure and the ranking stays homogeneous; each candidate's plan
    carries the fusion decision as ``plan.fusion``.  ``fuse_barriers``
    names stages whose downstream boundary fusion must keep.

    Every
    (policy, backends, E) point contributes the classic chain-wide
    uniform (cu, depth) grid plus the ``max_placements`` best joint
    per-stage vectors found by :func:`_search_stage_placements` over
    ``topology`` (default: just enough devices for the largest swept CU
    count).  Ranked best-first with infeasible plans last, exactly like
    :func:`explore`.  Depth>0 candidates are priced with the
    contention-aware cross-batch overlap term
    (``ChainCost.t_overlapped``: slowest contended stage + amortized
    fill/drain), so replication and stage pipelining competing for the
    same devices is weighed exactly as the executor delivers it.

    On a heterogeneous topology (kind groups with their own datasheets)
    the joint search instead co-varies per-stage ``(group, cu, E_s)``
    via :func:`_search_hetero_placements`; every kind group's
    single-group uniform grid is also swept explicitly, so the winner is
    never worse than the best homogeneous-restricted plan on the same
    device budget.

    ``measure_top`` verifies the k best feasible candidates whose
    planned backends match the chain's compiled ones by running the real
    ``run_chain`` driver (others cannot be measured as-planned).
    ``calibrate`` additionally fits the per-term :class:`CostCorrection`
    from those measured runs (each ratio attributed to the bottleneck
    stage's dominating term) and re-ranks every candidate by its
    corrected prediction.

    ``profile`` warm-starts the ranking from the persistent per-machine
    profile store (``repro.trace.ProfileStore``): pass a store, a path,
    or ``True`` for the default location.  Candidates are re-ranked by
    corrected predictions refit from this machine's recorded samples
    *before* any measurement (so ``measure_top`` verifies the profile-
    guided leaders), and every run measured here is recorded back into
    the store.  ``calibrate``'s freshly-fit correction still wins last
    when both are given."""
    import itertools

    from . import chain as chain_mod  # local: chain imports predict_cost
    from .placement import DeviceTopology

    if calibrate and not measure_top:
        raise ValueError(
            "calibrate=True fits the correction from measured runs; "
            "set measure_top > 0"
        )
    target = target if target is not None else detect_target()
    space = space or ChainDesignSpace()
    if topology is None:
        topology = DeviceTopology.homogeneous(max(1, max(space.cu_counts)))
    hetero = len(topology.groups) > 1

    fusion_spec = None
    if fuse == "auto" or (
        fuse != "off" and max_stages is not None
        and max_stages < len(chain.stages)
    ):
        from .fusion import fuse_chain_auto  # lazy: fusion imports chain

        fused_plan = fuse_chain_auto(
            chain, mode="auto", max_stages=max_stages,
            barriers=tuple(fuse_barriers), target=target,
            topology=topology, n_eq=n_eq,
        )
        fusion_spec = fused_plan.fusion
        chain = fusion_spec.chain
    n_stages = len(chain.stages)

    combos = list(
        itertools.islice(
            itertools.product(space.backends, repeat=n_stages),
            space.max_backend_combos,
        )
    )
    sched_cache: Dict = {}  # (stage idx, bps) -> Schedule, shared by all points
    cands: List[ChainCandidate] = []
    for policy in space.policies:
        bps = POLICIES[policy].bits // 8
        auto_e = chain.auto_batch_elements(
            target, bytes_per_scalar=bps, n_eq=n_eq
        )
        stage_caps = [
            layout.vmem_block_elements(
                s.program, target, bytes_per_scalar=bps
            )
            for s in chain.stages
        ]
        auto_e, _ = layout.pad_batch_for_block(
            auto_e, max(stage_caps), limit=n_eq, caps=stage_caps
        )
        e_cands = sorted({max(1, auto_e // d) for d in space.batch_divisors})
        for backends in combos:
            for e in e_cands:
                def make_plan_at(cus, depths, groups=None, stage_es=None):
                    return chain_mod.plan_chain(
                        chain, target=target, policy=policy,
                        backends=backends, batch_elements=e,
                        prefetch_depth=list(depths), cu_count=list(cus),
                        topology=topology, n_eq=n_eq,
                        stage_groups=(
                            list(groups) if groups is not None else None
                        ),
                        stage_batch_elements=(
                            list(stage_es) if stage_es is not None
                            else None
                        ),
                        _sched_cache=sched_cache,
                    )

                # reference plan: per-stage cost terms at cu=1 feed the
                # placement search (device terms scale as 1/cu)
                ref = make_plan_at((1,) * n_stages, (1,) * n_stages)
                vectors = {
                    ((1,) * n_stages, (1,) * n_stages, None, None): ref,
                }
                # the classic chain-wide uniform sweep is kept verbatim
                for depth in space.prefetch_depths:
                    for cu in space.cu_counts:
                        cu = max(1, min(cu, topology.n_devices))
                        vectors.setdefault(
                            ((cu,) * n_stages, (depth,) * n_stages,
                             None, None),
                            None,
                        )
                if hetero:
                    # per-group references: every stage priced on each
                    # kind group's own datasheet at cu=1
                    group_refs = {
                        gi: make_plan_at(
                            (1,) * n_stages, (1,) * n_stages,
                            groups=(gi,) * n_stages,
                        )
                        for gi in range(len(topology.groups))
                    }
                    # single-group-restricted uniforms are explicit
                    # candidates, so the heterogeneous winner can never
                    # rank behind the best homogeneous-restricted plan
                    # on the same device budget
                    for gi, gspec in enumerate(topology.groups):
                        for depth in space.prefetch_depths:
                            for cu in space.cu_counts:
                                cu = max(1, min(cu, gspec.n_devices))
                                vectors.setdefault(
                                    ((cu,) * n_stages,
                                     (depth,) * n_stages,
                                     (gi,) * n_stages, None),
                                    None,
                                )
                    # plus the joint per-stage (group, cu, E_s) frontier
                    for cus, depths, gis, es in _search_hetero_placements(
                        {
                            gi: [sp.cost for sp in r.stages]
                            for gi, r in group_refs.items()
                        },
                        space, topology, e,
                    ):
                        vectors.setdefault((cus, depths, gis, es), None)
                else:
                    # the joint per-stage frontier over the topology
                    for cus, depths in _search_stage_placements(
                        [sp.cost for sp in ref.stages], space, topology, e
                    ):
                        vectors.setdefault((cus, depths, None, None), None)
                for (cus, depths, gis, es), plan in vectors.items():
                    if plan is None:
                        plan = make_plan_at(
                            cus, depths, groups=gis, stage_es=es
                        )
                    if fusion_spec is not None:
                        plan = dataclasses.replace(
                            plan, fusion=fusion_spec
                        )
                    cands.append(
                        ChainCandidate(
                            plan=plan,
                            predicted_s_per_element=(
                                plan.cost.t_pipelined
                                / plan.batch_elements
                            ),
                        )
                    )
    cands.sort(
        key=lambda c: (
            not c.plan.feasible,
            c.predicted_s_per_element,
            c.plan.resident_bytes,
        )
    )
    store = None
    if profile is not None:
        from ..trace.profile import ProfileStore  # lazy: no import cycle

        store = ProfileStore.open(profile)
    if store is not None:
        corr = store.correction(target.name)
        if corr.n_samples:
            apply_correction(cands, corr)
    if measure_top:
        measured = 0
        for c in cands:
            if measured >= measure_top:
                break
            if not c.plan.feasible:
                continue
            got = measure_chain_plan(
                chain, c.plan, max_batches=measure_batches
            )
            if got is not None:
                c.measured_s_per_element = got
                measured += 1
        if store is not None and measured:
            for c in cands:
                if c.measured_s_per_element is not None:
                    store.record_measurement(
                        c.plan, c.predicted_s_per_element,
                        c.measured_s_per_element, scope="dse", save=False,
                    )
            store.save()
        if calibrate:
            apply_correction(cands, fit_correction(cands))
    return cands


def pareto_front(cands: Sequence[Candidate]) -> List[Candidate]:
    """Feasible candidates not dominated in (predicted time, resident
    bytes): the plan menu the operator actually chooses from."""
    feas = [c for c in cands if c.plan.feasible]
    front: List[Candidate] = []
    for c in feas:
        dominated = any(
            (o.predicted_s_per_element <= c.predicted_s_per_element
             and o.plan.resident_bytes <= c.plan.resident_bytes
             and (o.predicted_s_per_element < c.predicted_s_per_element
                  or o.plan.resident_bytes < c.plan.resident_bytes))
            for o in feas
        )
        if not dominated:
            front.append(c)
    return front


def measure_plan(
    plan: MemoryPlan,
    p: int,
    *,
    n_eq: Optional[int] = None,
    max_batches: int = 4,
) -> Optional[float]:
    """Verify a plan by running the real driver; seconds per element.

    Returns None when the plan is not runnable here (CU count exceeds
    local devices, or the policy has no runtime on this backend).
    """
    import jax

    from ..cfd.simulation import SimConfig, run_simulation  # lazy: no cycle

    if plan.cu_count > len(jax.devices()):
        return None
    cfg = SimConfig(
        p=p, n_eq=n_eq or plan.batch_elements * max_batches,
        batch_elements=plan.batch_elements, policy=plan.policy,
        backend=plan.backend, prefetch_depth=plan.prefetch_depth,
    )
    try:
        run_simulation(cfg, plan=plan, max_batches=1)  # warm compile
        res = run_simulation(cfg, plan=plan, max_batches=max_batches)
    except Exception:
        return None  # e.g. bf16 dot unsupported on the CPU runtime
    return res.wall_s / res.elements if res.elements else None


def _measure_candidates(
    cands: List[Candidate],
    p_or_prog,
    top_k: int,
    *,
    n_eq: int,
    max_batches: int,
) -> None:
    if not isinstance(p_or_prog, int):
        return  # measurement needs the named operator builder
    measured = 0
    for c in cands:
        if measured >= top_k:
            break
        if not c.plan.feasible:
            continue
        got = measure_plan(
            c.plan, p_or_prog,
            n_eq=min(n_eq, c.plan.batch_elements * max_batches),
            max_batches=max_batches,
        )
        if got is not None:
            c.measured_s_per_element = got
            measured += 1


def format_chain_ranking(
    cands: Sequence[ChainCandidate], limit: int = 10
) -> str:
    """Compact leaderboard for chain sweeps (per-stage backends and
    per-stage (cu, depth) placements)."""
    hdr = (
        f"{'#':>3} {'backends':<28} {'policy':<10} {'E':>8} "
        f"{'K':<8} {'CU':<8} "
        f"{'pred us/elem':>13} {'meas us/elem':>13} "
        f"{'resident MiB':>13} {'feasible':>9}"
    )
    lines = [hdr, "-" * len(hdr)]

    def vec(vals):
        s = ",".join(str(v) for v in vals)
        if len(set(vals)) == 1:
            s = str(vals[0])
        return s if len(s) <= 8 else s[:5] + "..."

    for i, c in enumerate(cands[:limit]):
        p = c.plan
        meas = (
            f"{c.measured_s_per_element * 1e6:13.4f}"
            if c.measured_s_per_element is not None else f"{'-':>13}"
        )
        backends = ",".join(sp.backend for sp in p.stages)
        if len(backends) > 28:
            backends = backends[:25] + "..."
        lines.append(
            f"{i:>3} {backends:<28} {p.policy:<10} {p.batch_elements:>8} "
            f"{vec([sp.prefetch_depth for sp in p.stages]):<8} "
            f"{vec(list(p.cu_counts)):<8} "
            f"{c.predicted_s_per_element * 1e6:>13.4f} "
            f"{meas} {p.resident_bytes / 2**20:>13.1f} "
            f"{'yes' if p.feasible else 'no':>9}"
        )
    return "\n".join(lines)


def format_ranking(cands: Sequence[Candidate], limit: int = 10) -> str:
    """Compact leaderboard for logs/benchmarks."""
    hdr = (
        f"{'#':>3} {'backend':<8} {'policy':<16} {'E':>8} {'K':>2} "
        f"{'CU':>3} {'pred us/elem':>13} {'meas us/elem':>13} "
        f"{'resident MiB':>13} {'feasible':>9}"
    )
    lines = [hdr, "-" * len(hdr)]
    for i, c in enumerate(cands[:limit]):
        meas = (
            f"{c.measured_s_per_element * 1e6:13.4f}"
            if c.measured_s_per_element is not None else f"{'-':>13}"
        )
        lines.append(
            f"{i:>3} {c.plan.backend:<8} {c.plan.policy:<16} "
            f"{c.plan.batch_elements:>8} {c.plan.prefetch_depth:>2} "
            f"{c.plan.cu_count:>3} {c.predicted_s_per_element * 1e6:>13.4f} "
            f"{meas} {c.plan.resident_bytes / 2**20:>13.1f} "
            f"{'yes' if c.plan.feasible else 'no':>9}"
        )
    return "\n".join(lines)
