"""Automatic memory-architecture planning (the paper's core contribution).

Turns a compiled tensor program + schedule into an explicit
:class:`~repro.memory.plan.MemoryPlan`: which pseudo-channel each stream
lives in, how big a batch (E) is, how deep the prefetch pipeline runs,
and what it is predicted to cost -- then explores that design space
CHARM-style and verifies the winners by measurement.

  channels  -- per-target memory datasheets (shared with analysis.roofline)
  layout    -- stream->buffer assignment, packing, auto batch sizing,
               VMEM block sizing (the Pallas kernel's block_elements)
  pipeline  -- generic K-deep prefetch/double-buffer transfer engine
  chain     -- multi-operator ProgramChain planning (inter-stage streams
               stay resident in HBM; one co-sized E for the pipeline)
  fusion    -- cost-driven stage fusion: the stage count as a DSE axis
               (merge adjacent stages when the handoff beats the roofline)
  dse       -- design-space explorer + analytic cost model + the
               measured-feedback CostCorrection
  plan      -- the MemoryPlan dataclasses and the Fig.-14-style report
"""
from . import chain, channels, dse, fusion, layout, pipeline, placement, plan
from .chain import (ChainPlan, ChainStage, PipelineSpec, ProgramChain,
                    apply_profile_contention, derive_pipeline,
                    fit_contention, plan_chain)
from .fusion import FusionSpec, fuse_chain, fuse_chain_auto
from .channels import (ALVEO_U280, CPU_HOST, TPU_V5E, MemoryTarget,
                       UnknownTargetError, detect_target, resolve_target)
from .placement import (DeviceTopology, PlacementError, PlacementPlan,
                        StagePlacement, place_chain)
from .dse import (Candidate, ChainCandidate, ChainDesignSpace,
                  CostCorrection, DesignSpace, explore, explore_chain,
                  fit_correction, format_chain_ranking, make_plan,
                  measure_chain_plan, pareto_front)
from .plan import BufferSpec, CostBreakdown, MemoryPlan

__all__ = [
    "chain", "channels", "dse", "layout", "pipeline", "placement", "plan",
    "MemoryTarget", "ALVEO_U280", "TPU_V5E", "CPU_HOST", "detect_target",
    "UnknownTargetError", "resolve_target",
    "DeviceTopology", "PlacementError", "PlacementPlan", "StagePlacement",
    "place_chain",
    "PipelineSpec", "derive_pipeline",
    "Candidate", "DesignSpace", "explore", "make_plan", "pareto_front",
    "ChainCandidate", "ChainDesignSpace", "CostCorrection",
    "explore_chain", "fit_correction", "format_chain_ranking",
    "measure_chain_plan",
    "ProgramChain", "ChainStage", "ChainPlan", "plan_chain",
    "fit_contention", "apply_profile_contention",
    "FusionSpec", "fuse_chain", "fuse_chain_auto", "fusion",
    "BufferSpec", "CostBreakdown", "MemoryPlan",
]
