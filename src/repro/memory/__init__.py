"""Automatic memory-architecture planning (the paper's core contribution).

Turns a compiled tensor program + schedule into an explicit
:class:`~repro.memory.plan.MemoryPlan`: which pseudo-channel each stream
lives in, how big a batch (E) is, how deep the prefetch pipeline runs,
and what it is predicted to cost -- then explores that design space
CHARM-style and verifies the winners by measurement.

  channels  -- per-target memory datasheets (shared with analysis.roofline)
  layout    -- stream->buffer assignment, packing, auto batch sizing
  pipeline  -- generic K-deep prefetch/double-buffer transfer engine
  dse       -- design-space explorer + analytic cost model
  plan      -- the MemoryPlan dataclasses and the Fig.-14-style report
"""
from . import channels, dse, layout, pipeline, plan
from .channels import ALVEO_U280, CPU_HOST, TPU_V5E, MemoryTarget, detect_target
from .dse import Candidate, DesignSpace, explore, make_plan, pareto_front
from .plan import BufferSpec, CostBreakdown, MemoryPlan

__all__ = [
    "channels", "dse", "layout", "pipeline", "plan",
    "MemoryTarget", "ALVEO_U280", "TPU_V5E", "CPU_HOST", "detect_target",
    "Candidate", "DesignSpace", "explore", "make_plan", "pareto_front",
    "BufferSpec", "CostBreakdown", "MemoryPlan",
]
