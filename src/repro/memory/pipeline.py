"""Transfer + stage pipelining: the generalized ping/pong engine.

The paper overlaps host->device transfer of batch k+1 with compute of
batch k through a pair of HBM channel buffers (Fig. 14a), and its
multi-accelerator system keeps *every* pipeline stage busy on a
different batch simultaneously.  JAX gives the same overlap for free
*if* the driver (1) enqueues ``jax.device_put`` of upcoming batches
before blocking on results, (2) defers the host sync by one batch so
the dispatch queue never drains, and (3) dispatches the stages of a
multi-operator chain *skewed* -- stage i of batch k in the same breath
as stage i+1 of batch k-1 -- so no stage's dispatch ring ever idles
waiting for the whole previous batch to finish.  This module packages
those tricks behind two generic drivers so every workload (CFD
simulation, benchmarks, tests) uses the identical machinery instead of
hand-rolling the loop.

``depth`` is the plan's prefetch K: 0 = fully serial (stage, compute,
sync -- the paper's baseline), 1 = classic double buffering, K>1 = deeper
staging that also rides out host-side jitter.

:func:`run_pipelined` is the single-stage K-deep engine;
:func:`run_stage_pipelined` generalizes it to a whole chain with one
dispatch ring per stage (per-stage depths), handing HBM-resident
inter-stage values from producer to consumer without host round-trips.
Its multi-device mode (``place_fns``, built from a
:class:`~repro.memory.placement.PlacementPlan` via
:func:`placement_meshes`) runs one dispatch ring per *device group*:
each stage shards its element batch over its own group's mesh and the
HBM-resident handoff is resharded between groups as it crosses.

:class:`StagePipelineDriver` is the reentrant core both build on: the
same skewed ring as a feed/tick state machine, so a long-running caller
(``repro.serve``) can push batches as they arrive, idle the ring dry,
and resume -- with optional per-batch error capture instead of the
batch-job raise-through.
"""
from __future__ import annotations

import time
from collections import deque
from typing import (Any, Callable, Dict, Iterable, Iterator, List, Optional,
                    Sequence, Tuple, Union)

import jax

# Span categories (the ``repro.trace.attribution`` vocabulary).  The
# tracer is duck-typed -- any object with begin/end/span/name_track/bump,
# falsy when disabled -- so this module never imports ``repro.trace``
# and the executors stay import-light.
_CAT_SLOT = "slot"
_CAT_DISPATCH = "dispatch"
_CAT_HANDOFF = "handoff"
_CAT_STAGE_HOST = "stage-host"
_CAT_SYNC = "sync"
_HOST_TRACK = 0


def prefetch(
    batches: Iterable[Any],
    stage_fn: Callable[[Any], Any],
    depth: int,
) -> Iterator[Any]:
    """Yield staged batches while keeping up to ``depth`` staged ahead.

    ``stage_fn`` starts the (async) host->device transfer; with JAX's
    asynchronous dispatch the transfer of staged-ahead batches proceeds
    while the consumer computes on the current one.
    """
    if depth < 0:
        raise ValueError(f"prefetch depth must be >= 0, got {depth}")
    q: deque = deque()
    for item in batches:
        q.append(stage_fn(item))
        if len(q) > depth:
            yield q.popleft()
    while q:
        yield q.popleft()


def _traced_stage_fn(stage_fn: Callable[[Any], Any], tracer) -> Callable:
    """Wrap the staging fn so each host->device stage gets a host-track
    span (batch index = call order, which is staging order)."""
    counter = [0]

    def staged(item: Any) -> Any:
        j = counter[0]
        counter[0] += 1
        with tracer.span(f"stage b{j}", _CAT_STAGE_HOST, _HOST_TRACK,
                         batch=j):
            return stage_fn(item)

    return staged


def run_pipelined(
    compute_fn: Callable[[Any], Any],
    batches: Iterable[Any],
    *,
    stage_fn: Callable[[Any], Any] = lambda x: x,
    depth: int = 1,
    reduce_fn: Optional[Callable[[Any], Any]] = None,
    defer_sync: Optional[bool] = None,
    tracer=None,
    stage_name: str = "compute",
) -> List[Any]:
    """Run every batch through ``compute_fn`` with K-deep staging.

    Returns the realized (host-side) per-batch results, in order.

    ``reduce_fn`` maps a device result to the (small) value to realize --
    e.g. a checksum scalar -- so full batches never transfer back.
    ``defer_sync`` delays each host sync by one batch so compute k+1 is
    enqueued before blocking on k (defaults to on whenever ``depth > 0``;
    forcing it off gives the paper's serial baseline).

    ``tracer`` (a ``repro.trace.Tracer``; None/NULL = off) records one
    staging span per batch on the host track, one dispatch span per
    batch on track 1, and one sync span per retire.  Disabled tracing
    costs one truthiness check per site -- results are identical either
    way (spans only observe).
    """
    if defer_sync is None:
        defer_sync = depth > 0
    if tracer:
        tracer.name_track(_HOST_TRACK, "host")
        tracer.name_track(1, stage_name)
        stage_fn = _traced_stage_fn(stage_fn, tracer)

    def sync_get(value: Any, j: int) -> Any:
        if tracer:
            with tracer.span(f"sync b{j}", _CAT_SYNC, _HOST_TRACK, batch=j):
                return jax.device_get(value)
        return jax.device_get(value)

    results: List[Any] = []
    pending: Optional[Tuple[Any, int]] = None
    for j, staged in enumerate(prefetch(batches, stage_fn, depth)):
        sp = (tracer.begin(f"b{j}", _CAT_DISPATCH, 1, batch=j)
              if tracer else None)
        out = compute_fn(staged)
        if reduce_fn is not None:
            out = reduce_fn(out)
        if sp is not None:
            tracer.end(sp)
        if not defer_sync:
            results.append(sync_get(out, j))
            continue
        if pending is not None:
            results.append(sync_get(*pending))
        pending = (out, j)
    if pending is not None:
        results.append(sync_get(*pending))
    return results


def placement_meshes(
    placement, devices: Optional[Sequence[Any]] = None
) -> Optional[List[Tuple[Any, ...]]]:
    """Per-stage local device groups for a PlacementPlan.

    Maps each stage's topology device ids onto the local JAX devices
    (``devices`` defaults to ``jax.devices()``).  Returns None when the
    placement does not fit the local pool (too few devices) or is the
    degenerate single-group case -- callers then fall back to today's
    single-mesh execution, which is bitwise-identical by construction.
    """
    if placement is None:
        return None
    devices = list(devices) if devices is not None else list(jax.devices())
    used = placement.devices_used
    if not used or used[-1] >= len(devices):
        return None  # placement planned for a bigger machine than this
    groups = [
        tuple(devices[d] for d in sp.devices) for sp in placement.stages
    ]
    if len({g for g in groups}) == 1 and len(groups[0]) == 1:
        return None  # every stage on one device: today's path exactly
    return groups


def reblock_batched_fn(
    fn: Callable[[Dict[str, Any]], Dict[str, Any]],
    element_keys: Sequence[str],
    sub_elements: int,
) -> Callable[[Dict[str, Any]], Dict[str, Any]]:
    """Re-blocking handoff: run a batched dict->dict stage fn at its own
    (smaller) E_s inside a chain batch of E elements.

    The wrapper slices every element-keyed array along the leading batch
    axis into ``sub_elements`` chunks, runs ``fn`` per chunk (shared
    operands pass through whole), and concatenates the outputs back to
    the chain batch -- all on device, so the handoff stays HBM-resident.
    Elements are independent along the batch axis (the same property the
    element-sharded meshes rely on), so the result is bitwise-equal to
    one full-batch call; only the dispatch granularity changes.  A batch
    no larger than ``sub_elements`` calls ``fn`` untouched."""
    import jax.numpy as jnp

    keys = frozenset(element_keys)
    sub = max(1, int(sub_elements))

    def reblocked(env: Dict[str, Any]) -> Dict[str, Any]:
        n = next(
            (env[k].shape[0] for k in env if k in keys), None
        )
        if n is None or n <= sub:
            return fn(env)
        outs = []
        for lo in range(0, n, sub):
            outs.append(fn({
                k: (v[lo:lo + sub] if k in keys else v)
                for k, v in env.items()
            }))
        return {
            k: jnp.concatenate([o[k] for o in outs], axis=0)
            for k in outs[0]
        }

    return reblocked


def stage_skews(depths: Sequence[int]) -> List[int]:
    """How many batches each stage lags behind stage 0.

    ``depths[0]`` is the host staging depth (it skews nothing -- staging
    runs *ahead*); ``depths[i>0]`` is the dispatch-ring depth between
    stage i-1 and stage i, i.e. how many batches of the inter-stage
    stream may be in flight before stage i consumes the oldest.  Skews
    accumulate: with per-ring depth 1 on a 3-stage chain, stage 2 works
    on batch k-2 while stage 0 works on batch k.
    """
    skews = [0] * len(depths)
    for i in range(1, len(depths)):
        skews[i] = skews[i - 1] + depths[i]
    return skews


def run_stage_pipelined(
    stage_fns: Sequence[Callable[[Any, Any], Any]],
    batches: Iterable[Any],
    *,
    stage_fn: Callable[[Any], Any] = lambda x: x,
    depths: Union[int, Sequence[int]] = 1,
    reduce_fn: Optional[Callable[[Any], Any]] = None,
    defer_sync: Optional[bool] = None,
    place_fns: Optional[Sequence[Optional[Callable[[Any, Any],
                                                   Any]]]] = None,
    tracer=None,
    monitor=None,
    stage_names: Optional[Sequence[str]] = None,
    metrics=None,
    metrics_labels: Optional[Dict[str, str]] = None,
) -> List[Any]:
    """Run every batch through a chain of stages, cross-batch pipelined.

    Each ``stage_fns[i]`` is called as ``fn(staged, carry)`` where
    ``staged`` is the batch's staged host input and ``carry`` is the
    value returned by stage i-1 for the same batch (``None`` for stage
    0); its return value is handed to stage i+1 *on device* -- the
    HBM-resident inter-stage stream.  The last stage's carry is realized
    (via ``reduce_fn``, then ``jax.device_get``) and the per-batch
    results are returned in batch order.

    ``depths`` is one dispatch-ring depth per stage (an int applies
    chain-wide): ``depths[0]`` stages host batches ahead exactly like
    :func:`run_pipelined`; ``depths[i>0]`` lets stage i run that many
    batches behind stage i-1, so with any positive inter-stage depth the
    dispatch order interleaves stage i of batch k with stage i+1 of
    batch k-1 (software pipelining).  All inter-stage depths 0 degrades
    to the back-to-back schedule of :func:`run_pipelined`.

    Every batch still passes through every stage exactly once with
    identical inputs, so results are bitwise-equal to the serial
    schedule -- only the dispatch interleaving changes.

    ``place_fns`` is the multi-device hook: ``place_fns[i](staged,
    carry)`` runs right before stage i consumes a batch and returns the
    ``(staged, carry)`` pair moved onto stage i's device group (e.g.
    ``jax.device_put`` of the HBM-resident handoff onto the consumer's
    element-sharded mesh).  ``None`` entries (or ``place_fns=None``)
    leave the record untouched -- the single-device fallback.

    ``tracer`` (``repro.trace.Tracer``; None/NULL = off) gives each
    stage its own track: every (stage, batch) dispatch becomes a *slot*
    span carrying ``stage``/``batch``/``tick`` args, with the reshard
    handoff and the stage-fn dispatch as nested children; host staging
    and retire syncs land on the host track.  ``monitor`` (a
    ``runtime.StepMonitor``) is fed the wall time between consecutive
    batch retirements; flagged steps annotate the retire's sync span
    with ``straggler=True``.  Both only observe -- per-batch results are
    identical with or without them.

    ``metrics`` (a ``repro.metrics`` registry; None/NULL = off) records
    per-stage dispatch/handoff time histograms, stall counters, and a
    tick histogram, labeled with ``metrics_labels`` (the serve engine
    passes the plan signature) -- always-on telemetry next to the
    tracer's bounded spans.  Observation only, like the tracer.
    """
    driver = StagePipelineDriver(
        stage_fns, stage_fn=stage_fn, depths=depths, reduce_fn=reduce_fn,
        defer_sync=defer_sync, place_fns=place_fns, tracer=tracer,
        monitor=monitor, stage_names=stage_names,
        metrics=metrics, metrics_labels=metrics_labels,
    )
    it = iter(batches)
    while True:
        while driver.wants_input:
            try:
                driver.feed(next(it))
            except StopIteration:
                driver.close()
                break
        if driver.idle:
            break
        driver.tick()
    return [v for _, v in driver.take()]


class _Poison:
    """A captured per-batch failure riding the carry slot: downstream
    stages skip the batch and retire delivers the error in its place."""

    __slots__ = ("error",)

    def __init__(self, error: BaseException) -> None:
        self.error = error


class StagePipelineDriver:
    """The skewed dispatch ring of :func:`run_stage_pipelined` as a
    reentrant feed/tick state machine.

    :func:`run_stage_pipelined` drives it feed-while-hungry/tick-until-
    dry over a finite batch source and is tick-for-tick identical to the
    closed-form loop it replaced.  A long-running caller (the
    ``repro.serve`` engine) instead interleaves :meth:`feed` and
    :meth:`tick` as admission waves arrive: each fed batch remembers the
    tick it *entered* the ring, and stage ``i`` dispatches batch ``k``
    once (a) stage ``i-1`` has finished it and (b) ``skews[i]`` ticks
    have passed since entry -- so a ring that went idle resumes with the
    same per-stage skew for the batches that follow, no global restart.

    ``capture_errors=True`` turns the batch-job raise-through into
    per-batch delivery: a stage/place/reduce/sync failure poisons that
    batch's record, downstream stages skip it, and :meth:`take` yields
    ``(k, exception)`` for it -- the ring itself never wedges.  The
    default (``False``) propagates, exactly like the batch driver.
    """

    def __init__(
        self,
        stage_fns: Sequence[Callable[[Any, Any], Any]],
        *,
        stage_fn: Callable[[Any], Any] = lambda x: x,
        depths: Union[int, Sequence[int]] = 1,
        reduce_fn: Optional[Callable[[Any], Any]] = None,
        defer_sync: Optional[bool] = None,
        place_fns: Optional[Sequence[Optional[Callable[[Any, Any],
                                                       Any]]]] = None,
        tracer=None,
        monitor=None,
        stage_names: Optional[Sequence[str]] = None,
        capture_errors: bool = False,
        metrics=None,
        metrics_labels: Optional[Dict[str, str]] = None,
    ) -> None:
        stage_fns = list(stage_fns)
        n_stages = len(stage_fns)
        if n_stages == 0:
            raise ValueError("need at least one stage")
        if place_fns is not None and len(place_fns) != n_stages:
            raise ValueError(
                f"need {n_stages} place fns, got {len(place_fns)}"
            )
        if isinstance(depths, int):
            depths = [depths] * n_stages
        else:
            depths = list(depths)
        if len(depths) != n_stages:
            raise ValueError(
                f"need {n_stages} stage depths, got {len(depths)}"
            )
        if any(d < 0 for d in depths):
            raise ValueError(f"stage depths must be >= 0, got {depths}")
        if defer_sync is None:
            defer_sync = any(d > 0 for d in depths)
        names = (list(stage_names) if stage_names
                 else [f"stage{i}" for i in range(n_stages)])
        if len(names) != n_stages:
            raise ValueError(
                f"need {n_stages} stage names, got {len(names)}"
            )
        if tracer:
            tracer.name_track(_HOST_TRACK, "host")
            for i, nm in enumerate(names):
                tracer.name_track(1 + i, nm)
            stage_fn = _traced_stage_fn(stage_fn, tracer)
        self.stage_fns = stage_fns
        self.stage_fn = stage_fn
        self.depths = depths
        self.skews = stage_skews(depths)
        self.reduce_fn = reduce_fn
        self.defer_sync = defer_sync
        self.place_fns = place_fns
        self.tracer = tracer
        self.monitor = monitor
        self.names = names
        self.capture_errors = capture_errors
        # -- always-on metrics (duck-typed like the tracer: this module
        # never imports repro.metrics; any registry-shaped object works,
        # and a falsy one -- None or NULL_REGISTRY -- costs one check
        # here and nothing per tick) ----------------------------------------
        self._m_tick = self._m_dispatch = self._m_handoff = None
        self._m_stall = None
        if metrics:
            lab = dict(metrics_labels or {})
            self._m_tick = metrics.histogram(
                "pipeline_tick_seconds",
                "One driver tick: enter/dispatch-all-stages/retire.", **lab)
            self._m_dispatch = [
                metrics.histogram(
                    "pipeline_stage_dispatch_seconds",
                    "One (stage, batch) dispatch slot, handoff included.",
                    stage=nm, **lab)
                for nm in names
            ]
            self._m_handoff = [
                metrics.histogram(
                    "pipeline_stage_handoff_seconds",
                    "Cross-group reshard of the HBM-resident handoff.",
                    stage=nm, **lab)
                for nm in names
            ]
            self._m_stall = [
                {
                    reason: metrics.counter(
                        "pipeline_stall_total",
                        "Skipped stage dispatches by cause: ring skew "
                        "not yet satisfied, or producer stage behind.",
                        stage=nm, reason=reason, **lab)
                    for reason in ("skew", "producer")
                }
                for nm in names
            ]
        # -- ring state ------------------------------------------------------
        self._staged: deque = deque()       # staged, not yet entered
        #: batch k -> [staged, carry]; held from entry until retire (the
        #: window the planner prices as ring replicas)
        self._records: Dict[int, List[Any]] = {}
        self._entry_tick: Dict[int, int] = {}
        self._done = [0] * n_stages         # next batch stage i dispatches
        self._retire_next = 0
        self._entered = 0                   # batches entered into the ring
        self._accepted = 0                  # batches fed (entered + staged)
        self._t = 0
        self._pending: deque = deque()      # deferred (value, k) syncs
        self._out: deque = deque()          # retired (k, result) in order
        self._closed = False
        self._last_retire = (
            [time.perf_counter()] if monitor is not None else None
        )

    # -- feeding -------------------------------------------------------------
    @property
    def wants_input(self) -> bool:
        """True while the host staging window (``depths[0]`` ahead plus
        the one entering this tick) has room and the source isn't closed."""
        return not self._closed and len(self._staged) <= self.depths[0]

    @property
    def in_flight(self) -> int:
        """Batches accepted but not yet delivered through :meth:`take`."""
        return (len(self._staged) + len(self._records)
                + len(self._pending) + len(self._out))

    @property
    def accepted(self) -> int:
        """Total batches fed so far (the next :meth:`feed`'s index)."""
        return self._accepted

    @property
    def idle(self) -> bool:
        """True when nothing is staged, in the ring, or pending sync."""
        return not (self._staged or self._records or self._pending)

    def feed(self, item: Any) -> int:
        """Stage one batch into the ring; returns its batch index."""
        if self._closed:
            raise RuntimeError("driver is closed")
        k = self._accepted
        try:
            self._staged.append(self.stage_fn(item))
        except Exception as e:
            if not self.capture_errors:
                raise
            self._staged.append(_Poison(e))
        self._accepted += 1
        return k

    def close(self) -> None:
        """No more batches will be fed; remaining ticks drain the ring."""
        self._closed = True

    # -- the tick ------------------------------------------------------------
    def tick(self) -> bool:
        """Advance the ring one tick: enter at most one staged batch,
        give every stage its one skew-scheduled dispatch, retire at most
        one finished batch.  Returns False once nothing progressed (ring
        dry -- feed more or stop)."""
        tracer = self.tracer
        tick_t0 = time.perf_counter() if self._m_tick is not None else 0.0
        progressed = False
        if self._staged:
            k = self._entered
            staged = self._staged.popleft()
            if isinstance(staged, _Poison):
                self._records[k] = [None, staged]
            else:
                self._records[k] = [staged, None]
            self._entry_tick[k] = self._t
            self._entered += 1
            progressed = True
        t = self._t
        for i, fn in enumerate(self.stage_fns):
            k = self._done[i]
            if k not in self._records or k >= self._entered:
                continue
            if t - self._entry_tick[k] < self.skews[i]:
                if self._m_stall is not None:
                    self._m_stall[i]["skew"].inc()
                continue  # ring depth: stage i lags entry by skews[i]
            if i > 0 and self._done[i - 1] <= k:
                if self._m_stall is not None:
                    self._m_stall[i]["producer"].inc()
                continue  # producer stage hasn't finished this batch
            self._done[i] = k + 1
            progressed = True
            rec = self._records[k]
            if isinstance(rec[1], _Poison):
                continue  # upstream failure: skip, deliver at retire
            slot = (tracer.begin(f"b{k}", _CAT_SLOT, 1 + i,
                                 stage=i, batch=k, tick=t)
                    if tracer else None)
            slot_t0 = (time.perf_counter()
                       if self._m_dispatch is not None else 0.0)
            try:
                if self.place_fns is not None and self.place_fns[i] is not None:
                    hand_t0 = (time.perf_counter()
                               if self._m_handoff is not None else 0.0)
                    if tracer:
                        with tracer.span(f"reshard b{k}", _CAT_HANDOFF,
                                         1 + i, stage=i, batch=k):
                            rec[0], rec[1] = self.place_fns[i](rec[0], rec[1])
                    else:
                        rec[0], rec[1] = self.place_fns[i](rec[0], rec[1])
                    if self._m_handoff is not None:
                        self._m_handoff[i].observe(
                            time.perf_counter() - hand_t0)
                if tracer:
                    with tracer.span(self.names[i], _CAT_DISPATCH, 1 + i,
                                     stage=i, batch=k):
                        rec[1] = fn(rec[0], rec[1])
                else:
                    rec[1] = fn(rec[0], rec[1])
            except Exception as e:
                if not self.capture_errors:
                    raise
                rec[1] = _Poison(e)
            if self._m_dispatch is not None:
                self._m_dispatch[i].observe(time.perf_counter() - slot_t0)
            if slot is not None:
                tracer.end(slot)
        k = self._retire_next
        if k in self._records and self._done[-1] > k:
            rec = self._records.pop(k)
            del self._entry_tick[k]
            self._retire_next += 1
            self._retire(rec[1], k)
            progressed = True
        if not self._records and not self._staged:
            while self._pending:
                self._flush_one()
        self._t += 1
        if self._m_tick is not None:
            self._m_tick.observe(time.perf_counter() - tick_t0)
        return progressed

    # -- retire / sync -------------------------------------------------------
    def _retire(self, carry: Any, k: int) -> None:
        if isinstance(carry, _Poison):
            self._out.append((k, carry.error))
            return
        try:
            value = (self.reduce_fn(carry)
                     if self.reduce_fn is not None else carry)
        except Exception as e:
            if not self.capture_errors:
                raise
            self._out.append((k, e))
            return
        if not self.defer_sync:
            self._deliver_sync(value, k)
            return
        self._pending.append((value, k))
        if len(self._pending) > 1:
            self._flush_one()

    def _flush_one(self) -> None:
        self._deliver_sync(*self._pending.popleft())

    def _deliver_sync(self, value: Any, k: int) -> None:
        try:
            self._out.append((k, self._sync_get(value, k)))
        except Exception as e:
            if not self.capture_errors:
                raise
            self._out.append((k, e))

    def _sync_get(self, value: Any, k: int) -> Any:
        tracer = self.tracer
        sp = (tracer.begin(f"sync b{k}", _CAT_SYNC, _HOST_TRACK, batch=k)
              if tracer else None)
        try:
            got = jax.device_get(value)
        except Exception:
            if self.capture_errors and sp is not None:
                tracer.end(sp)
            raise
        if self.monitor is not None:
            now = time.perf_counter()
            flagged = self.monitor.record(now - self._last_retire[0])
            self._last_retire[0] = now
            if flagged and sp is not None:
                sp.args["straggler"] = True
        if sp is not None:
            tracer.end(sp)
        return got

    # -- results -------------------------------------------------------------
    def take(self) -> List[Tuple[int, Any]]:
        """Drain the delivered results: ``(batch index, realized value)``
        pairs in batch order (the value is the captured exception for a
        poisoned batch under ``capture_errors``)."""
        out = list(self._out)
        self._out.clear()
        return out
