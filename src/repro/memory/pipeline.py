"""K-deep transfer pipelining: the generalized ping/pong engine.

The paper overlaps host->device transfer of batch k+1 with compute of
batch k through a pair of HBM channel buffers (Fig. 14a).  JAX gives the
same overlap for free *if* the driver (1) enqueues ``jax.device_put`` of
upcoming batches before blocking on results and (2) defers the host sync
by one batch so the dispatch queue never drains.  This module packages
those two tricks behind one generic driver so every workload (CFD
simulation, benchmarks, tests) uses the identical machinery instead of
hand-rolling the loop.

``depth`` is the plan's prefetch K: 0 = fully serial (stage, compute,
sync -- the paper's baseline), 1 = classic double buffering, K>1 = deeper
staging that also rides out host-side jitter.
"""
from __future__ import annotations

from collections import deque
from typing import Any, Callable, Iterable, Iterator, List, Optional

import jax


def prefetch(
    batches: Iterable[Any],
    stage_fn: Callable[[Any], Any],
    depth: int,
) -> Iterator[Any]:
    """Yield staged batches while keeping up to ``depth`` staged ahead.

    ``stage_fn`` starts the (async) host->device transfer; with JAX's
    asynchronous dispatch the transfer of staged-ahead batches proceeds
    while the consumer computes on the current one.
    """
    if depth < 0:
        raise ValueError(f"prefetch depth must be >= 0, got {depth}")
    q: deque = deque()
    for item in batches:
        q.append(stage_fn(item))
        if len(q) > depth:
            yield q.popleft()
    while q:
        yield q.popleft()


def run_pipelined(
    compute_fn: Callable[[Any], Any],
    batches: Iterable[Any],
    *,
    stage_fn: Callable[[Any], Any] = lambda x: x,
    depth: int = 1,
    reduce_fn: Optional[Callable[[Any], Any]] = None,
    defer_sync: Optional[bool] = None,
) -> List[Any]:
    """Run every batch through ``compute_fn`` with K-deep staging.

    Returns the realized (host-side) per-batch results, in order.

    ``reduce_fn`` maps a device result to the (small) value to realize --
    e.g. a checksum scalar -- so full batches never transfer back.
    ``defer_sync`` delays each host sync by one batch so compute k+1 is
    enqueued before blocking on k (defaults to on whenever ``depth > 0``;
    forcing it off gives the paper's serial baseline).
    """
    if defer_sync is None:
        defer_sync = depth > 0
    results: List[Any] = []
    pending = None
    for staged in prefetch(batches, stage_fn, depth):
        out = compute_fn(staged)
        if reduce_fn is not None:
            out = reduce_fn(out)
        if not defer_sync:
            results.append(jax.device_get(out))
            continue
        if pending is not None:
            results.append(jax.device_get(pending))
        pending = out
    if pending is not None:
        results.append(jax.device_get(pending))
    return results
