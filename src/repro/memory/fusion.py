"""Cost-driven stage fusion: make the stage count itself a DSE axis.

The scheduler's auto-partition and the flow's named cuts fix the chain's
stage boundaries *before* the memory planner prices them -- but every
boundary has a concrete HBM cost the planner can already see: the
producer writes the handoff stream once, the consumer reads it once
(``BufferSpec`` role ``resident``), and the boundary adds a pipeline
fill/drain step plus a dispatch.  Whenever that handoff traffic costs
more than the merged stage's added device time (the two rooflines
combined), the boundary should not exist.

This module erases such boundaries *after* scheduling and *before* the
final plan, by greedy pairwise merging:

  * :func:`fuse_chain` mechanically merges arbitrary groups of adjacent
    stages of a :class:`~repro.memory.chain.ProgramChain` into single
    stages -- stitching the member programs together at their bound
    streams, dropping handoffs that become internal, and re-qualifying
    every binding that crosses a group edge.
  * :func:`fuse_chain_auto` is the decision procedure: starting from the
    unfused chain it prices every adjacent-pair merge with the real
    planner (:func:`~repro.memory.chain.plan_chain` on the candidate
    chain -- the exact ``ChainCost`` handoff-vs-roofline comparison, not
    a proxy) and keeps merging while the predicted pipelined time
    improves, or while a ``max_stages`` budget forces it.  Explicit
    ``barriers`` (named cuts) are never merged across.

Merged stages re-enter pattern matching (``flow.patterns``), so a fused
interpolation+gradient chain still dispatches to the tiled Pallas GEMM
kernel instead of falling back to XLA.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from ..core import ir
from .chain import ChainStage, ProgramChain


@dataclasses.dataclass(frozen=True)
class FusionSpec:
    """What the fusion pass decided, attached to the resulting plan.

    ``groups`` records the original stage names merged into each fused
    stage (singleton tuples for stages left alone).  ``t_unfused`` /
    ``t_fused`` are the planner's predicted pipelined seconds per batch
    before and after; ``saved_handoff_bytes`` is the per-batch
    inter-stage resident traffic the merges removed.  ``chain`` carries
    the fused :class:`ProgramChain` for execution; it is excluded from
    equality so plans stay comparable across recompiles.
    """

    mode: str
    groups: Tuple[Tuple[str, ...], ...]
    n_stages_before: int
    n_stages_after: int
    t_unfused: float
    t_fused: float
    saved_handoff_bytes: int
    barriers: Tuple[str, ...] = ()
    chain: Optional[ProgramChain] = dataclasses.field(
        default=None, compare=False, repr=False
    )

    @property
    def fused(self) -> bool:
        """True when at least one boundary was erased."""
        return self.n_stages_after < self.n_stages_before

    def describe(self) -> str:
        """One-line summary for plan reports."""
        mib = 2 ** 20
        groups = "".join(
            "[" + "+".join(g) + "]" for g in self.groups if len(g) > 1
        )
        return (
            f"fusion: mode={self.mode}   {self.n_stages_before} -> "
            f"{self.n_stages_after} stages{' ' + groups if groups else ''}"
            f"   saved handoff {self.saved_handoff_bytes / mib:.1f} "
            f"MiB/batch   predicted {self.t_unfused * 1e3:.3f} -> "
            f"{self.t_fused * 1e3:.3f} ms/batch"
        )


def _merge_group(
    chain: ProgramChain, group: Tuple[int, ...]
) -> Tuple[ir.Program, Dict[str, Tuple[int, str]], Dict[Tuple[int, str], str]]:
    """Stitch consecutive stages ``group`` into one program.

    Returns ``(program, binding_sources, out_map)``: the merged program,
    each merged input's origin ``(producer stage index, output name)``
    for inputs still bound outside the group, and the new name of every
    surviving member output (handoffs consumed only inside the group are
    dropped -- that is the fusion).  Unbound inputs (host element
    streams and shared operands) are deduplicated group-wide by bare
    name, matching the chain's shared-operand convention.
    """
    gset = set(group)
    produced: Dict[Tuple[int, str], ir.Node] = {}
    new_inputs: Dict[str, ir.Input] = {}
    by_source: Dict[Tuple[int, str], str] = {}
    by_name: Dict[str, str] = {}
    binding_sources: Dict[str, Tuple[int, str]] = {}
    elem_inputs: List[str] = []
    used = set()

    def uniq(base: str) -> str:
        name, k = base, 2
        while name in used:
            name = f"{base}_{k}"
            k += 1
        used.add(name)
        return name

    for i in group:
        prog = chain.stages[i].program
        elem = set(prog.element_vars)
        mapping: Dict[int, ir.Node] = {}
        for in_name, node in prog.inputs.items():
            src = chain.resolved[i].get(in_name)
            if src is not None and src[0] in gset:
                mapping[node.uid] = produced[src]
            elif src is not None:
                if src not in by_source:
                    name = uniq(in_name)
                    inp = ir.Input(shape=node.shape, name=name)
                    new_inputs[name] = inp
                    by_source[src] = name
                    binding_sources[name] = src
                    elem_inputs.append(name)
                mapping[node.uid] = new_inputs[by_source[src]]
            else:
                if in_name not in by_name:
                    name = uniq(in_name)
                    inp = ir.Input(shape=node.shape, name=name)
                    new_inputs[name] = inp
                    by_name[in_name] = name
                    if in_name in elem:
                        elem_inputs.append(name)
                mapping[node.uid] = new_inputs[by_name[in_name]]
        rebuilt = prog.replace(mapping)
        for out_name, out_node in rebuilt.outputs.items():
            produced[(i, out_name)] = out_node

    out_map: Dict[Tuple[int, str], str] = {}
    merged_outputs: Dict[str, ir.Node] = {}
    out_elem: List[str] = []
    for i in group:
        s = chain.stages[i]
        for out_name in s.program.outputs:
            key = (i, out_name)
            consumed_outside = any(
                src == key
                for j, binds in enumerate(chain.resolved)
                if j not in gset
                for src in binds.values()
            )
            if not consumed_outside and key in chain.consumed:
                continue                    # internal handoff: fused away
            name = (
                out_name if out_name not in merged_outputs
                else f"{s.name}_{out_name}"
            )
            merged_outputs[name] = produced[key]
            out_map[key] = name
            if out_name in s.program.element_vars:
                out_elem.append(name)

    merged = ir.Program(
        inputs=dict(new_inputs),
        outputs=merged_outputs,
        element_vars=tuple(elem_inputs) + tuple(out_elem),
    )
    return merged, binding_sources, out_map


def _compile_merged(merged: ir.Program, members: Sequence[ChainStage]):
    """Compile a merged program, re-running Pallas pattern matching.

    Backend choice: if every member used the same backend it is kept;
    any ``pallas`` member makes the merged stage *try* the kernel
    matchers again (``flow.patterns.pallas_impl_for``) and fall back to
    ``xla`` when the fused program no longer fits a kernel class.
    """
    from ..core import emit
    policy = members[0].compiled.policy
    backends = {s.backend for s in members}
    if "pallas" in backends:
        from ..flow import patterns  # lazy: flow imports memory
        impl = patterns.pallas_impl_for(merged)
        if impl is not None:
            return emit.compile_program(
                merged, policy=policy, backend="pallas", pallas_impl=impl
            )
        return emit.compile_program(merged, policy=policy, backend="xla")
    backend = backends.pop() if len(backends) == 1 else "xla"
    return emit.compile_program(merged, policy=policy, backend=backend)


def fuse_chain(
    chain: ProgramChain, groups: Sequence[Tuple[int, ...]]
) -> ProgramChain:
    """Merge adjacent-stage ``groups`` of a chain into single stages.

    ``groups`` must partition ``range(len(chain.stages))`` into runs of
    consecutive indices, in order.  Singleton groups keep their compiled
    program untouched (bindings are re-qualified only); multi-stage
    groups are stitched by :func:`_merge_group` and recompiled, with
    Pallas pattern matching re-run on the merged program.  Raises
    ``ValueError`` on a malformed grouping.
    """
    flat = [i for g in groups for i in g]
    if flat != list(range(len(chain.stages))):
        raise ValueError(
            f"groups {list(groups)} must partition "
            f"0..{len(chain.stages) - 1} in order"
        )

    metas = []  # (name, compiled, binding_sources, out_map)
    for g in groups:
        members = [chain.stages[i] for i in g]
        name = "+".join(s.name for s in members)
        if len(g) == 1:
            i = g[0]
            srcs = dict(chain.resolved[i])
            out_map = {
                (i, o): o for o in chain.stages[i].program.outputs
            }
            metas.append((name, members[0].compiled, srcs, out_map))
        else:
            merged, srcs, out_map = _merge_group(chain, tuple(g))
            metas.append(
                (name, _compile_merged(merged, members), srcs, out_map)
            )

    out_name_of: Dict[Tuple[int, str], Tuple[str, str]] = {}
    for name, _, _, out_map in metas:
        for src, new_out in out_map.items():
            out_name_of[src] = (name, new_out)

    new_stages = []
    for name, compiled, srcs, _ in metas:
        binds = {}
        for in_name, src in srcs.items():
            p_name, p_out = out_name_of[src]
            binds[in_name] = f"{p_name}.{p_out}"
        new_stages.append(ChainStage(name, compiled, binds))
    return ProgramChain(new_stages)


def _collapse(value, groups):
    """Collapse a per-original-stage vector knob group-wise (by max)."""
    if isinstance(value, (list, tuple)):
        return [max(value[i] for i in g) for g in groups]
    return value


def _collapse_backends(backends, groups):
    if backends is None:
        return None
    out = []
    for g in groups:
        got = {backends[i] for i in g}
        if len(got) == 1:
            out.append(got.pop())
        elif "pallas" in got:
            out.append("pallas")
        else:
            out.append("xla")
    return out


def fuse_chain_auto(
    chain: ProgramChain,
    *,
    mode: str = "auto",
    max_stages: Optional[int] = None,
    barriers: Sequence[str] = (),
    target=None,
    policy: str = "float32",
    backends: Optional[Sequence[str]] = None,
    batch_elements: Optional[int] = None,
    prefetch_depth=1,
    cu_count=1,
    topology=None,
    n_eq: Optional[int] = None,
    channel_bytes: Optional[int] = None,
    profile=None,
):
    """Greedy cost-driven fusion: merge stages while the planner agrees.

    Starting from the unfused chain, every adjacent-pair merge candidate
    is priced by planning the *actual* fused chain (cheap: compilation
    is lazy, planning is analytic), and the best one is adopted while it
    strictly improves the predicted pipelined time -- i.e. while the
    HBM-resident handoff plus its fill/drain and dispatch cost more than
    the merged stage's combined roofline.  With ``max_stages`` set,
    least-harm merges continue past the profit point until the stage
    budget is met (``max_stages=1`` fully fuses).  Boundaries after a
    stage named in ``barriers`` are never merged.

    Remaining keyword arguments mirror
    :func:`~repro.memory.chain.plan_chain`; per-original-stage vector
    knobs (``prefetch_depth``, ``cu_count``, ``backends``) are collapsed
    group-wise as stages merge.  Returns the fused chain's
    :class:`~repro.memory.chain.ChainPlan` with a :class:`FusionSpec`
    attached (``plan.fusion``), spec'd against the unfused baseline.
    """
    from .chain import apply_profile_contention, plan_chain

    n = len(chain.stages)
    barrier_set = set(barriers)
    unknown = barrier_set - {s.name for s in chain.stages}
    if unknown:
        raise ValueError(
            f"fusion barriers name unknown stages: {sorted(unknown)}"
        )

    def plan_for(fused_chain, groups):
        return plan_chain(
            fused_chain,
            target=target,
            policy=policy,
            backends=_collapse_backends(backends, groups),
            batch_elements=batch_elements,
            prefetch_depth=_collapse(prefetch_depth, groups),
            cu_count=_collapse(cu_count, groups),
            topology=topology,
            n_eq=n_eq,
            channel_bytes=channel_bytes,
        )

    def score(plan):
        return (not plan.feasible, plan.cost.t_pipelined)

    groups: List[Tuple[int, ...]] = [(i,) for i in range(n)]
    cur_chain = chain
    cur_plan = plan_for(chain, groups)
    base_plan = cur_plan
    want = max(1, max_stages) if max_stages is not None else None

    while len(groups) > 1:
        best = None
        for k in range(len(groups) - 1):
            if chain.stages[groups[k][-1]].name in barrier_set:
                continue
            cand_groups = (
                groups[:k] + [groups[k] + groups[k + 1]] + groups[k + 2:]
            )
            cand_chain = fuse_chain(chain, cand_groups)
            cand_plan = plan_for(cand_chain, cand_groups)
            if best is None or score(cand_plan) < score(best[1]):
                best = (cand_groups, cand_plan, cand_chain)
        if best is None:
            break                              # every boundary is a barrier
        improves = score(best[1]) < score(cur_plan)
        forced = want is not None and len(groups) > want
        if not improves and not forced:
            break
        groups, cur_plan, cur_chain = best

    spec = FusionSpec(
        mode=mode,
        groups=tuple(
            tuple(chain.stages[i].name for i in g) for g in groups
        ),
        n_stages_before=n,
        n_stages_after=len(groups),
        t_unfused=base_plan.cost.t_pipelined,
        t_fused=cur_plan.cost.t_pipelined,
        saved_handoff_bytes=max(
            0,
            base_plan.resident_stream_bytes
            - cur_plan.resident_stream_bytes,
        ),
        barriers=tuple(sorted(barrier_set)),
        chain=cur_chain,
    )
    plan = dataclasses.replace(cur_plan, fusion=spec)
    if profile is not None:
        plan = apply_profile_contention(plan, profile)
    return plan
