"""Placement: co-scheduling CU replication and stage pipelining over an
explicit device topology.

The paper's generator allocates HBM pseudo-channels and compute units
*jointly*: replicated CUs and the streaming pipeline contend for the
same physical resources, and the tool flow prices that contention before
any hardware is generated.  This module is the execution-substrate half
of that decision for the JAX port:

  * :class:`DeviceTopology` -- the machine the chain will actually run
    on (local JAX devices, or a hypothetical machine for planning),
  * :class:`StagePlacement` -- one stage's resource grant: how many CUs
    (mesh devices) it shards elements over, how deep its dispatch ring
    runs, and *which* devices it owns,
  * :class:`PlacementPlan` -- the per-stage vector plus the stage ->
    device-group assignment, with the structural quantity the cost model
    prices: **contention**, the number of pipeline stages whose device
    groups overlap a given stage's group.  Under cross-batch stage
    pipelining every stage is live on a different batch simultaneously,
    so stages sharing a device time-slice it -- replication and overlap
    compete for the same devices (ROADMAP, PR-4 next steps).

Placement is pure data (frozen dataclasses), deterministic, and cheap:
``plan_chain`` derives one per plan, ``dse.explore_chain`` searches the
joint per-stage ``(cu_count, prefetch_depth)`` space over a fixed
topology, and ``cfd.simulation.run_chain`` executes the winning plan
(one dispatch ring per device group, element-sharded intra-stage,
HBM-resident handoffs resharded between groups).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple, Union


class PlacementError(ValueError):
    """Raised on malformed placements (bad groups, topology mismatch)."""


@dataclasses.dataclass(frozen=True)
class DeviceTopology:
    """The devices a chain executes on, grouped into CU groups.

    ``n_devices`` counts interchangeable accelerator devices (JAX local
    devices here; CU sites on the paper's FPGA).  A hypothetical
    topology (for planning a machine you are not on) is just a different
    ``n_devices`` -- placement and pricing never touch the runtime.
    """

    n_devices: int
    device_kind: str = "generic"

    def __post_init__(self):
        if self.n_devices < 1:
            raise PlacementError(
                f"topology needs >= 1 device, got {self.n_devices}"
            )

    @classmethod
    def detect(cls) -> "DeviceTopology":
        """The local JAX device pool (import deferred: planning stays
        importable without a runtime)."""
        import jax

        devs = jax.devices()
        return cls(n_devices=len(devs), device_kind=devs[0].platform)

    @classmethod
    def homogeneous(cls, n_devices: int,
                    device_kind: str = "generic") -> "DeviceTopology":
        """A flat topology of ``n_devices`` identical devices."""
        return cls(n_devices=n_devices, device_kind=device_kind)


@dataclasses.dataclass(frozen=True)
class StagePlacement:
    """One stage's resource grant on the topology."""

    cu_count: int               # devices the stage shards elements over
    prefetch_depth: int         # dispatch-ring depth (stage 0: host K)
    devices: Tuple[int, ...]    # topology device ids the stage owns

    def __post_init__(self):
        if self.cu_count < 1:
            raise PlacementError(f"cu_count must be >= 1, got {self.cu_count}")
        if self.prefetch_depth < 0:
            raise PlacementError(
                f"prefetch_depth must be >= 0, got {self.prefetch_depth}"
            )
        if len(self.devices) != self.cu_count:
            raise PlacementError(
                f"stage owns {len(self.devices)} devices but cu_count="
                f"{self.cu_count}"
            )
        if len(set(self.devices)) != len(self.devices):
            raise PlacementError(f"duplicate devices in group {self.devices}")


@dataclasses.dataclass(frozen=True)
class PlacementPlan:
    """Per-stage ``(cu_count, prefetch_depth)`` vector plus the stage ->
    device-group assignment over one topology."""

    topology: DeviceTopology
    stages: Tuple[StagePlacement, ...]

    def __post_init__(self):
        if not self.stages:
            raise PlacementError("placement needs >= 1 stage")
        for i, sp in enumerate(self.stages):
            bad = [d for d in sp.devices if not 0 <= d < self.topology.n_devices]
            if bad:
                raise PlacementError(
                    f"stage {i} placed on devices {bad} outside the "
                    f"{self.topology.n_devices}-device topology"
                )

    # -- vector views --------------------------------------------------------
    @property
    def n_stages(self) -> int:
        """Number of placed stages."""
        return len(self.stages)

    @property
    def cu_counts(self) -> Tuple[int, ...]:
        """Per-stage CU replication vector."""
        return tuple(sp.cu_count for sp in self.stages)

    @property
    def prefetch_depths(self) -> Tuple[int, ...]:
        """Per-stage dispatch-ring depth vector."""
        return tuple(sp.prefetch_depth for sp in self.stages)

    @property
    def max_cu_count(self) -> int:
        """Widest stage's CU count (the legacy chain-wide scalar)."""
        return max(self.cu_counts)

    @property
    def device_groups(self) -> Tuple[Tuple[int, ...], ...]:
        """Per-stage device-id groups, as placed."""
        return tuple(sp.devices for sp in self.stages)

    @property
    def devices_used(self) -> Tuple[int, ...]:
        """Sorted distinct device ids any stage occupies."""
        used = sorted({d for sp in self.stages for d in sp.devices})
        return tuple(used)

    # -- the quantity the cost model prices ---------------------------------
    @property
    def contention(self) -> Tuple[int, ...]:
        """Per stage: how many stages (itself included) own at least one
        of its devices.  Under stage pipelining every stage is live
        simultaneously, so overlapping groups time-slice their shared
        devices; disjoint groups (contention 1) pipeline freely."""
        sets = [set(sp.devices) for sp in self.stages]
        return tuple(
            sum(1 for other in sets if mine & other) for mine in sets
        )

    def disjoint(self) -> bool:
        """True when no two stages share a device (free pipelining)."""
        return all(c == 1 for c in self.contention)

    # -- report --------------------------------------------------------------
    def describe(self) -> List[str]:
        """The golden-checked ``placement:`` report lines."""
        groups = " | ".join(
            ",".join(str(d) for d in sp.devices) for sp in self.stages
        )
        return [
            f"  placement: {self.topology.n_devices} device(s)   "
            f"per-stage cu [{','.join(str(c) for c in self.cu_counts)}]   "
            f"contention [{','.join(str(c) for c in self.contention)}]",
            f"    stage device groups [{groups}]",
        ]


def assign_device_groups(
    topology: DeviceTopology, cu_counts: Sequence[int]
) -> List[Tuple[int, ...]]:
    """Deterministic stage -> device-group assignment: contiguous blocks
    laid out round-robin over the topology.  When the stages' combined
    CU demand fits the device pool the groups come out disjoint
    (contention 1 everywhere); otherwise they wrap and overlap, and the
    resulting contention is exactly what :class:`ChainCost` prices."""
    n = topology.n_devices
    groups: List[Tuple[int, ...]] = []
    offset = 0
    for g in cu_counts:
        g = max(1, min(int(g), n))
        groups.append(tuple((offset + k) % n for k in range(g)))
        offset = (offset + g) % n
    return groups


def place_chain(
    topology: DeviceTopology,
    cu_counts: Union[int, Sequence[int]],
    prefetch_depths: Union[int, Sequence[int]],
    *,
    n_stages: Optional[int] = None,
) -> PlacementPlan:
    """Build the PlacementPlan for per-stage CU counts and ring depths.

    Scalars broadcast chain-wide (``n_stages`` then sizes the vector);
    CU counts are clamped to the topology -- the topology *bounds*
    replication, which is the point of making it explicit."""
    if isinstance(cu_counts, int):
        if n_stages is None:
            raise PlacementError("scalar cu_counts needs n_stages")
        cu_counts = [cu_counts] * n_stages
    else:
        cu_counts = list(cu_counts)
    if isinstance(prefetch_depths, int):
        prefetch_depths = [prefetch_depths] * len(cu_counts)
    else:
        prefetch_depths = list(prefetch_depths)
    if len(prefetch_depths) != len(cu_counts):
        raise PlacementError(
            f"{len(cu_counts)} cu counts vs {len(prefetch_depths)} depths"
        )
    groups = assign_device_groups(topology, cu_counts)
    return PlacementPlan(
        topology=topology,
        stages=tuple(
            StagePlacement(
                cu_count=len(g), prefetch_depth=max(0, int(d)), devices=g
            )
            for g, d in zip(groups, prefetch_depths)
        ),
    )
