"""Placement: co-scheduling CU replication and stage pipelining over an
explicit device topology.

The paper's generator allocates HBM pseudo-channels and compute units
*jointly*: replicated CUs and the streaming pipeline contend for the
same physical resources, and the tool flow prices that contention before
any hardware is generated.  This module is the execution-substrate half
of that decision for the JAX port:

  * :class:`DeviceTopology` -- the machine the chain will actually run
    on (local JAX devices, or a hypothetical machine for planning).  A
    topology is an ordered list of :class:`DeviceGroupSpec` groups, each
    carrying a device *kind* and (for known kinds) the
    :class:`~repro.memory.channels.MemoryTarget` datasheet that prices
    it -- so one plan can span a mixed CPU/TPU/FPGA fleet and each
    stage is priced against the memory system it actually lands on,
  * :class:`StagePlacement` -- one stage's resource grant: how many CUs
    (mesh devices) it shards elements over, how deep its dispatch ring
    runs, and *which* devices it owns,
  * :class:`PlacementPlan` -- the per-stage vector plus the stage ->
    device-group assignment, with the structural quantity the cost model
    prices: **contention**, the number of pipeline stages whose device
    groups overlap a given stage's group.  Under cross-batch stage
    pipelining every stage is live on a different batch simultaneously,
    so stages sharing a device time-slice it -- replication and overlap
    compete for the same devices (ROADMAP, PR-4 next steps).

Placement is pure data (frozen dataclasses), deterministic, and cheap:
``plan_chain`` derives one per plan, ``dse.explore_chain`` searches the
joint per-stage ``(group, cu_count, prefetch_depth, E_s)`` space over a
fixed topology, and ``cfd.simulation.run_chain`` executes the winning
plan (one dispatch ring per device group, element-sharded intra-stage,
HBM-resident handoffs resharded -- and re-blocked -- between groups).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple, Union

from .channels import MemoryTarget, TARGETS, canonical_target_name


class PlacementError(ValueError):
    """Raised on malformed placements (bad groups, topology mismatch)."""


#: Spellings accepted for a device kind (CLI ``--devices cpu:2,tpu:4``,
#: JAX platform names from ``from_jax``, and the datasheet names
#: themselves).  Unknown kinds stay as-is with no datasheet attached.
KIND_ALIASES = {
    "cpu": "cpu-host",
    "host": "cpu-host",
    "cpu-host": "cpu-host",
    "tpu": "tpu-v5e",
    "tpu-v5e": "tpu-v5e",
    "fpga": "alveo-u280",
    "alveo": "alveo-u280",
    "u280": "alveo-u280",
    "alveo-u280": "alveo-u280",
}


def resolve_kind_target(kind: str) -> Optional[MemoryTarget]:
    """The ``channels.py`` datasheet a device kind prices against, or
    None for kinds with no datasheet (``generic``, ``gpu``, ...) --
    those fall back to the plan-wide target."""
    key = KIND_ALIASES.get(canonical_target_name(kind))
    return TARGETS.get(key) if key else None


@dataclasses.dataclass(frozen=True)
class DeviceGroupSpec:
    """One contiguous run of same-kind devices in a topology.

    ``target`` is the memory datasheet stages placed here are priced
    against; ``None`` means "use the plan-wide target" (the homogeneous
    legacy behavior, and the fallback for unknown kinds)."""

    kind: str
    n_devices: int
    target: Optional[MemoryTarget] = None

    def __post_init__(self):
        if self.n_devices < 1:
            raise PlacementError(
                f"device group {self.kind!r} needs >= 1 device, "
                f"got {self.n_devices}"
            )


@dataclasses.dataclass(frozen=True)
class DeviceTopology:
    """The devices a chain executes on, grouped into kind groups.

    ``n_devices`` counts accelerator devices (JAX local devices here; CU
    sites on the paper's FPGA).  ``groups`` partitions them into
    contiguous same-kind runs; a topology built the legacy way (just
    ``n_devices`` + ``device_kind``) synthesizes a single group, so
    every homogeneous call site keeps working unchanged.  A hypothetical
    topology (for planning a machine you are not on) is just a different
    spec -- placement and pricing never touch the runtime.
    """

    n_devices: int
    device_kind: str = "generic"
    groups: Tuple[DeviceGroupSpec, ...] = ()

    def __post_init__(self):
        if self.n_devices < 1:
            raise PlacementError(
                f"topology needs >= 1 device, got {self.n_devices}"
            )
        if not self.groups:
            object.__setattr__(self, "groups", (
                DeviceGroupSpec(kind=self.device_kind,
                                n_devices=self.n_devices),
            ))
        else:
            total = sum(g.n_devices for g in self.groups)
            if total != self.n_devices:
                raise PlacementError(
                    f"groups sum to {total} devices but topology has "
                    f"{self.n_devices}"
                )
            if self.device_kind == "generic":
                kinds = [g.kind for g in self.groups]
                object.__setattr__(
                    self, "device_kind",
                    kinds[0] if len(set(kinds)) == 1 else "mixed",
                )

    # -- constructors --------------------------------------------------------
    @classmethod
    def detect(cls) -> "DeviceTopology":
        """The local JAX device pool (import deferred: planning stays
        importable without a runtime)."""
        import jax

        return cls.from_jax(jax.devices())

    @classmethod
    def from_jax(cls, devs: Sequence) -> "DeviceTopology":
        """Derive the topology from a JAX device list, *per device* --
        a mixed pool becomes one group per contiguous same-platform run
        (instead of assuming ``devs[0].platform`` fleet-wide).  Mixed
        pools resolve each kind's datasheet; interleaved kinds (a kind
        recurring after another kind) are rejected -- the executor
        shards a stage over one contiguous group only."""
        if not devs:
            raise PlacementError("from_jax needs >= 1 device")
        kinds = [str(getattr(d, "platform", "generic")) for d in devs]
        runs: List[Tuple[str, int]] = []
        for k in kinds:
            if runs and runs[-1][0] == k:
                runs[-1] = (k, runs[-1][1] + 1)
            else:
                runs.append((k, 1))
        seen = [k for k, _ in runs]
        if len(seen) != len(set(seen)):
            raise PlacementError(
                f"unsupported device mix: kinds interleave ({kinds}); "
                "group same-kind devices contiguously"
            )
        if len(runs) == 1:
            # homogeneous pool: the legacy single group, no datasheet
            # attached (pricing keeps following the plan-wide target)
            return cls(n_devices=len(devs), device_kind=runs[0][0])
        groups = []
        for kind, n in runs:
            target = resolve_kind_target(kind)
            if target is None:
                raise PlacementError(
                    f"unsupported device mix: no memory datasheet for "
                    f"kind {kind!r} (known: "
                    f"{', '.join(sorted(set(KIND_ALIASES.values())))})"
                )
            groups.append(
                DeviceGroupSpec(kind=target.name, n_devices=n,
                                target=target)
            )
        return cls(n_devices=len(devs), groups=tuple(groups))

    @classmethod
    def homogeneous(cls, n_devices: int,
                    device_kind: str = "generic") -> "DeviceTopology":
        """A flat topology of ``n_devices`` identical devices."""
        return cls(n_devices=n_devices, device_kind=device_kind)

    @classmethod
    def heterogeneous(
        cls, specs: Sequence[Tuple[str, int]]
    ) -> "DeviceTopology":
        """A mixed fleet from ``[(kind, n), ...]`` -- kinds resolve to
        their ``channels.py`` datasheets (aliases accepted)."""
        if not specs:
            raise PlacementError("heterogeneous topology needs >= 1 group")
        groups = []
        for kind, n in specs:
            target = resolve_kind_target(kind)
            groups.append(DeviceGroupSpec(
                kind=target.name if target else canonical_target_name(kind),
                n_devices=int(n), target=target,
            ))
        return cls(
            n_devices=sum(g.n_devices for g in groups),
            groups=tuple(groups),
        )

    @classmethod
    def parse(cls, spec: str) -> "DeviceTopology":
        """Topology from a CLI spec: ``"cpu:2,tpu:4"`` (or ``"4"`` for
        four generic devices).  Kind aliases: cpu/host, tpu, fpga/alveo/
        u280, plus the canonical datasheet names."""
        spec = str(spec).strip()
        if not spec:
            raise PlacementError("empty device spec")
        if spec.isdigit():
            return cls.homogeneous(int(spec))
        parts = []
        for tok in spec.split(","):
            tok = tok.strip()
            if not tok:
                continue
            kind, sep, n = tok.partition(":")
            if not sep or not n.strip().isdigit() or not kind.strip():
                raise PlacementError(
                    f"bad device spec token {tok!r} in {spec!r} "
                    "(want 'kind:count', e.g. 'cpu:2,tpu:4')"
                )
            parts.append((kind.strip(), int(n.strip())))
        if not parts:
            raise PlacementError(f"empty device spec {spec!r}")
        return cls.heterogeneous(parts)

    # -- group/device views --------------------------------------------------
    @property
    def heterogeneous_kinds(self) -> bool:
        """True when the topology mixes more than one device kind."""
        return len({g.kind for g in self.groups}) > 1

    def spec_string(self) -> str:
        """Canonical spelling for fingerprints and cache keys: the
        legacy ``"<n>x<kind>"`` for a single group, else the full
        ``"kind:n+kind:n"`` hetero spec."""
        if len(self.groups) == 1:
            return f"{self.n_devices}x{self.device_kind}"
        return "+".join(f"{g.kind}:{g.n_devices}" for g in self.groups)

    def group_base(self, gi: int) -> int:
        """First global device id of group ``gi``."""
        return sum(g.n_devices for g in self.groups[:gi])

    def group_device_ids(self, gi: int) -> Tuple[int, ...]:
        """Global device ids belonging to group ``gi``."""
        base = self.group_base(gi)
        return tuple(range(base, base + self.groups[gi].n_devices))

    def group_of_device(self, d: int) -> int:
        """Index of the group owning global device id ``d``."""
        if not 0 <= d < self.n_devices:
            raise PlacementError(
                f"device {d} outside the {self.n_devices}-device topology"
            )
        base = 0
        for gi, g in enumerate(self.groups):
            if d < base + g.n_devices:
                return gi
            base += g.n_devices
        raise PlacementError(f"device {d} not covered by any group")

    def device_target(
        self, d: int, default: Optional[MemoryTarget] = None
    ) -> Optional[MemoryTarget]:
        """The datasheet pricing device ``d`` (``default`` when its
        group carries none)."""
        t = self.groups[self.group_of_device(d)].target
        return t if t is not None else default

    def total_channels(self, default: MemoryTarget) -> int:
        """Pseudo-channels across the whole fleet (the plan report's
        denominator): each group contributes its own datasheet's count,
        target-less groups contribute the plan-wide target's."""
        if len(self.groups) == 1:
            g = self.groups[0]
            return (g.target or default).n_channels
        return sum((g.target or default).n_channels for g in self.groups)


@dataclasses.dataclass(frozen=True)
class StagePlacement:
    """One stage's resource grant on the topology."""

    cu_count: int               # devices the stage shards elements over
    prefetch_depth: int         # dispatch-ring depth (stage 0: host K)
    devices: Tuple[int, ...]    # topology device ids the stage owns

    def __post_init__(self):
        if self.cu_count < 1:
            raise PlacementError(f"cu_count must be >= 1, got {self.cu_count}")
        if self.prefetch_depth < 0:
            raise PlacementError(
                f"prefetch_depth must be >= 0, got {self.prefetch_depth}"
            )
        if len(self.devices) != self.cu_count:
            raise PlacementError(
                f"stage owns {len(self.devices)} devices but cu_count="
                f"{self.cu_count}"
            )
        if len(set(self.devices)) != len(self.devices):
            raise PlacementError(f"duplicate devices in group {self.devices}")


@dataclasses.dataclass(frozen=True)
class PlacementPlan:
    """Per-stage ``(cu_count, prefetch_depth)`` vector plus the stage ->
    device-group assignment over one topology."""

    topology: DeviceTopology
    stages: Tuple[StagePlacement, ...]

    def __post_init__(self):
        if not self.stages:
            raise PlacementError("placement needs >= 1 stage")
        for i, sp in enumerate(self.stages):
            bad = [d for d in sp.devices if not 0 <= d < self.topology.n_devices]
            if bad:
                raise PlacementError(
                    f"stage {i} placed on devices {bad} outside the "
                    f"{self.topology.n_devices}-device topology"
                )
            if len(self.topology.groups) > 1:
                gis = {self.topology.group_of_device(d) for d in sp.devices}
                if len(gis) > 1:
                    raise PlacementError(
                        f"stage {i} spans kind groups {sorted(gis)}; a "
                        "stage shards within one device kind only"
                    )

    # -- vector views --------------------------------------------------------
    @property
    def n_stages(self) -> int:
        """Number of placed stages."""
        return len(self.stages)

    @property
    def cu_counts(self) -> Tuple[int, ...]:
        """Per-stage CU replication vector."""
        return tuple(sp.cu_count for sp in self.stages)

    @property
    def prefetch_depths(self) -> Tuple[int, ...]:
        """Per-stage dispatch-ring depth vector."""
        return tuple(sp.prefetch_depth for sp in self.stages)

    @property
    def max_cu_count(self) -> int:
        """Widest stage's CU count (the legacy chain-wide scalar)."""
        return max(self.cu_counts)

    @property
    def device_groups(self) -> Tuple[Tuple[int, ...], ...]:
        """Per-stage device-id groups, as placed."""
        return tuple(sp.devices for sp in self.stages)

    @property
    def devices_used(self) -> Tuple[int, ...]:
        """Sorted distinct device ids any stage occupies."""
        used = sorted({d for sp in self.stages for d in sp.devices})
        return tuple(used)

    # -- per-stage kind/target views (heterogeneous pricing) ----------------
    def stage_group_index(self, i: int) -> int:
        """Topology group owning stage ``i``'s devices."""
        return self.topology.group_of_device(self.stages[i].devices[0])

    @property
    def stage_group_indices(self) -> Tuple[int, ...]:
        """Per-stage topology group index."""
        return tuple(
            self.stage_group_index(i) for i in range(len(self.stages))
        )

    def stage_kind(self, i: int) -> str:
        """Device kind stage ``i`` is placed on."""
        return self.topology.groups[self.stage_group_index(i)].kind

    def stage_target(
        self, i: int, default: Optional[MemoryTarget] = None
    ) -> Optional[MemoryTarget]:
        """The datasheet pricing stage ``i`` (``default`` when its
        group carries none -- the homogeneous legacy)."""
        t = self.topology.groups[self.stage_group_index(i)].target
        return t if t is not None else default

    # -- the quantity the cost model prices ---------------------------------
    @property
    def contention(self) -> Tuple[int, ...]:
        """Per stage: how many stages (itself included) own at least one
        of its devices.  Under stage pipelining every stage is live
        simultaneously, so overlapping groups time-slice their shared
        devices; disjoint groups (contention 1) pipeline freely."""
        sets = [set(sp.devices) for sp in self.stages]
        return tuple(
            sum(1 for other in sets if mine & other) for mine in sets
        )

    def disjoint(self) -> bool:
        """True when no two stages share a device (free pipelining)."""
        return all(c == 1 for c in self.contention)

    # -- report --------------------------------------------------------------
    def describe(
        self,
        stage_names: Optional[Sequence[str]] = None,
        stage_elements: Optional[Sequence[int]] = None,
        stage_channels: Optional[Sequence[Sequence[int]]] = None,
        stage_kinds: Optional[Sequence[str]] = None,
    ) -> List[str]:
        """The golden-checked ``placement:`` report lines.

        With per-stage annotations (names, batch elements, channel ids
        from the chain plan) each stage also gets a
        ``kind / E / channels`` line -- the placement-aware channel map
        the heterogeneous planner decides."""
        groups = " | ".join(
            ",".join(str(d) for d in sp.devices) for sp in self.stages
        )
        lines = [
            f"  placement: {self.topology.n_devices} device(s)   "
            f"per-stage cu [{','.join(str(c) for c in self.cu_counts)}]   "
            f"contention [{','.join(str(c) for c in self.contention)}]",
            f"    stage device groups [{groups}]",
        ]
        if stage_names is not None:
            n = len(self.stages)
            es = list(stage_elements or [0] * n)
            chans = list(stage_channels or [()] * n)
            kinds = list(stage_kinds) if stage_kinds else [
                self.stage_kind(i) for i in range(n)
            ]
            for i, name in enumerate(stage_names):
                ch = format_channel_ids(chans[i])
                lines.append(
                    f"    stage {name}: kind={kinds[i]}  "
                    f"E={es[i]}  channels {len(tuple(chans[i]))} {ch}"
                )
        return lines


def format_channel_ids(ids: Sequence[int]) -> str:
    """Compact run-length spelling of a channel id set: ``[0-6,9]``."""
    ids = sorted(set(int(i) for i in ids))
    if not ids:
        return "[]"
    runs: List[Tuple[int, int]] = []
    for i in ids:
        if runs and i == runs[-1][1] + 1:
            runs[-1] = (runs[-1][0], i)
        else:
            runs.append((i, i))
    return "[" + ",".join(
        f"{a}" if a == b else f"{a}-{b}" for a, b in runs
    ) + "]"


def assign_device_groups(
    topology: DeviceTopology,
    cu_counts: Sequence[int],
    stage_groups: Optional[Sequence[int]] = None,
) -> List[Tuple[int, ...]]:
    """Deterministic stage -> device-group assignment.

    Single-group topologies keep the legacy rule exactly: contiguous
    blocks laid out round-robin over the whole pool.  When the stages'
    combined CU demand fits the device pool the groups come out disjoint
    (contention 1 everywhere); otherwise they wrap and overlap, and the
    resulting contention is exactly what :class:`ChainCost` prices.

    Multi-group (heterogeneous) topologies place each stage *within one
    kind group*: ``stage_groups`` names the group per stage (the DSE's
    placement axis); by default each stage goes to the least-loaded
    group (ties: the one with the higher datasheet peak, then the lower
    index), wrapping round-robin inside it."""
    n = topology.n_devices
    if len(topology.groups) == 1:
        groups: List[Tuple[int, ...]] = []
        offset = 0
        for g in cu_counts:
            g = max(1, min(int(g), n))
            groups.append(tuple((offset + k) % n for k in range(g)))
            offset = (offset + g) % n
        return groups

    specs = topology.groups
    if stage_groups is not None:
        if len(stage_groups) != len(cu_counts):
            raise PlacementError(
                f"{len(cu_counts)} cu counts vs {len(stage_groups)} "
                "stage groups"
            )
        chosen = [int(g) for g in stage_groups]
        for g in chosen:
            if not 0 <= g < len(specs):
                raise PlacementError(
                    f"stage group {g} outside the {len(specs)}-group "
                    "topology"
                )
    else:
        chosen = []
        load = [0] * len(specs)
        for cu in cu_counts:
            gi = min(
                range(len(specs)),
                key=lambda j: (
                    load[j] / specs[j].n_devices,
                    -(specs[j].target.peak_flops if specs[j].target else 0.0),
                    j,
                ),
            )
            chosen.append(gi)
            load[gi] += max(1, min(int(cu), specs[gi].n_devices))

    groups = []
    offsets = [0] * len(specs)
    for cu, gi in zip(cu_counts, chosen):
        size = specs[gi].n_devices
        base = topology.group_base(gi)
        g = max(1, min(int(cu), size))
        off = offsets[gi]
        groups.append(tuple(base + (off + k) % size for k in range(g)))
        offsets[gi] = (off + g) % size
    return groups


def place_chain(
    topology: DeviceTopology,
    cu_counts: Union[int, Sequence[int]],
    prefetch_depths: Union[int, Sequence[int]],
    *,
    n_stages: Optional[int] = None,
    stage_groups: Optional[Sequence[int]] = None,
) -> PlacementPlan:
    """Build the PlacementPlan for per-stage CU counts and ring depths.

    Scalars broadcast chain-wide (``n_stages`` then sizes the vector);
    CU counts are clamped to the topology -- the topology *bounds*
    replication, which is the point of making it explicit.  On a
    heterogeneous topology ``stage_groups`` pins each stage to a kind
    group (clamping then bounds CU at that group's size)."""
    if isinstance(cu_counts, int):
        if n_stages is None:
            raise PlacementError("scalar cu_counts needs n_stages")
        cu_counts = [cu_counts] * n_stages
    else:
        cu_counts = list(cu_counts)
    if isinstance(prefetch_depths, int):
        prefetch_depths = [prefetch_depths] * len(cu_counts)
    else:
        prefetch_depths = list(prefetch_depths)
    if len(prefetch_depths) != len(cu_counts):
        raise PlacementError(
            f"{len(cu_counts)} cu counts vs {len(prefetch_depths)} depths"
        )
    groups = assign_device_groups(topology, cu_counts, stage_groups)
    return PlacementPlan(
        topology=topology,
        stages=tuple(
            StagePlacement(
                cu_count=len(g), prefetch_depth=max(0, int(d)), devices=g
            )
            for g, d in zip(groups, prefetch_depths)
        ),
    )
