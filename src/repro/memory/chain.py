"""Multi-operator program planning: one memory architecture for a whole
CFD pipeline (paper Sec. 5 -- the headline numbers come from composed
applications, not single operators).

A :class:`ProgramChain` is an ordered sequence of compiled programs
(e.g. interpolation -> gradient -> inverse Helmholtz) with *bindings*
that wire a producer stage's output to a consumer stage's input.  The
chain planner then makes the three decisions the single-program planner
cannot:

  * **inter-stage residency** -- a bound producer->consumer stream never
    crosses the host link: it is written to HBM once by the producer and
    read once by the consumer (buffer role ``resident``).  Only the
    chain's fringe (unbound inputs, unconsumed outputs) is host-streamed.
  * **co-sized E** -- one batch size is chosen so that *every* stage's
    per-batch stream I/O fits one pseudo-channel (the paper's rule,
    applied to the worst stage), so a batch flows through the whole
    pipeline without re-blocking.
  * **conflict-free placement** -- all stages' buffers share one
    round-robin :class:`~repro.memory.layout.ChannelAllocator`; shared
    (batch-invariant) operands with the same name are placed once.

The result is a :class:`ChainPlan`: per-stage buffers/costs plus chain
aggregates, rendered by ``report()`` like the single-program plan.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple, Union

from ..core import ir
from ..core.emit import CompiledProgram
from ..core.precision import POLICIES
from ..core.schedule import Schedule, schedule as make_schedule
from . import layout
from .channels import MemoryTarget, detect_target
from .placement import DeviceTopology, PlacementPlan, place_chain
from .plan import (BufferSpec, CostBreakdown, channels_used,
                   hbm_stream_bytes, host_stream_bytes)


@dataclasses.dataclass
class ChainStage:
    """One pipeline stage: a compiled program plus input bindings.

    ``bindings`` maps this stage's input names to a *qualified* earlier
    output, ``"<stage>.<output>"``.  Inputs left unbound are either
    host-streamed (element vars) or shared operands (matched chain-wide
    by bare name).
    """

    name: str
    compiled: CompiledProgram
    bindings: Dict[str, str] = dataclasses.field(default_factory=dict)

    @property
    def program(self) -> ir.Program:
        """The stage's standalone IR program."""
        return self.compiled.program

    @property
    def backend(self) -> str:
        """The backend the stage compiled to (xla/staged/pallas)."""
        return self.compiled.backend


StageLike = Union[ChainStage, Tuple[str, CompiledProgram],
                  Tuple[str, CompiledProgram, Dict[str, str]]]


class ChainError(ValueError):
    """Raised on malformed chains (bad bindings, shape mismatches)."""


class ProgramChain:
    """An ordered multi-operator program with producer->consumer wiring.

    Stages may be :class:`ChainStage` objects or ``(name, compiled)`` /
    ``(name, compiled, bindings)`` tuples.  Unqualified input names that
    match an earlier stage's output name are auto-bound to the most
    recent such producer.
    """

    def __init__(self, stages: Sequence[StageLike]):
        self.stages: List[ChainStage] = []
        for s in stages:
            if isinstance(s, ChainStage):
                self.stages.append(s)
            else:
                name, compiled = s[0], s[1]
                bindings = dict(s[2]) if len(s) > 2 else {}
                self.stages.append(ChainStage(name, compiled, bindings))
        if not self.stages:
            raise ChainError("empty chain")
        self._validate_names()
        #: per stage: input name -> (producer stage index, output name)
        self.resolved: List[Dict[str, Tuple[int, str]]] = (
            self._resolve_bindings()
        )
        #: (stage index, output name) consumed by a later stage
        self.consumed: set = {
            src for binds in self.resolved for src in binds.values()
        }
        self._validate_shared()

    # -- construction helpers ------------------------------------------------
    def _validate_names(self) -> None:
        seen = set()
        for s in self.stages:
            if not s.name or "." in s.name:
                raise ChainError(f"bad stage name {s.name!r}")
            if s.name in seen:
                raise ChainError(f"duplicate stage name {s.name!r}")
            seen.add(s.name)

    def _resolve_bindings(self) -> List[Dict[str, Tuple[int, str]]]:
        idx_of = {s.name: i for i, s in enumerate(self.stages)}
        resolved: List[Dict[str, Tuple[int, str]]] = []
        for i, s in enumerate(self.stages):
            elem = set(s.program.element_vars)
            binds: Dict[str, Tuple[int, str]] = {}
            for in_name, src in s.bindings.items():
                if in_name not in s.program.inputs:
                    raise ChainError(
                        f"{s.name}: binding for unknown input {in_name!r}"
                    )
                if "." not in src:
                    raise ChainError(
                        f"{s.name}.{in_name}: binding {src!r} must be "
                        "qualified '<stage>.<output>'"
                    )
                p_name, out_name = src.split(".", 1)
                if p_name not in idx_of or idx_of[p_name] >= i:
                    raise ChainError(
                        f"{s.name}.{in_name}: producer {p_name!r} is not "
                        "an earlier stage"
                    )
                p = idx_of[p_name]
                if out_name not in self.stages[p].program.outputs:
                    raise ChainError(
                        f"{s.name}.{in_name}: {p_name!r} has no output "
                        f"{out_name!r}"
                    )
                binds[in_name] = (p, out_name)
            # auto-bind: unbound element inputs matching an earlier
            # stage's output name (most recent producer wins)
            for in_name in s.program.inputs:
                if in_name in binds or in_name not in elem:
                    continue
                for p in range(i - 1, -1, -1):
                    if in_name in self.stages[p].program.outputs:
                        binds[in_name] = (p, in_name)
                        break
            # validate shapes + element-var discipline
            for in_name, (p, out_name) in binds.items():
                src_node = self.stages[p].program.outputs[out_name]
                dst_node = s.program.inputs[in_name]
                if src_node.shape != dst_node.shape:
                    raise ChainError(
                        f"{s.name}.{in_name}: shape {dst_node.shape} != "
                        f"{self.stages[p].name}.{out_name} "
                        f"{src_node.shape}"
                    )
                if (in_name not in elem
                        or out_name not in
                        self.stages[p].program.element_vars):
                    raise ChainError(
                        f"{s.name}.{in_name}: chain streams must be "
                        "element vars on both sides"
                    )
            resolved.append(binds)
        return resolved

    def _validate_shared(self) -> None:
        shapes: Dict[str, Tuple[int, ...]] = {}
        for name, node in self.shared_operands().items():
            shapes[name] = node.shape
        for i, s in enumerate(self.stages):
            elem = set(s.program.element_vars)
            for name, node in s.program.inputs.items():
                if name in elem or name in self.resolved[i]:
                    continue
                if node.shape != shapes[name]:
                    raise ChainError(
                        f"shared operand {name!r}: conflicting shapes "
                        f"{shapes[name]} vs {node.shape}"
                    )

    # -- structure queries ---------------------------------------------------
    @property
    def name(self) -> str:
        """Chain id: stage names joined in execution order."""
        return "->".join(s.name for s in self.stages)

    def host_element_inputs(self, i: int) -> List[Tuple[str, ir.Node]]:
        """Stage i's element inputs streamed from the host (unbound)."""
        s = self.stages[i]
        elem = set(s.program.element_vars)
        return [
            (n, v) for n, v in s.program.inputs.items()
            if n in elem and n not in self.resolved[i]
        ]

    def resident_outputs(self, i: int) -> List[Tuple[str, ir.Node]]:
        """Stage i's outputs consumed by a later stage (HBM-resident)."""
        return [
            (n, v) for n, v in self.stages[i].program.outputs.items()
            if (i, n) in self.consumed
        ]

    def chain_outputs(self, i: int) -> List[Tuple[str, ir.Node]]:
        """Stage i's outputs streamed back to the host (unconsumed)."""
        return [
            (n, v) for n, v in self.stages[i].program.outputs.items()
            if (i, n) not in self.consumed
        ]

    def shared_operands(self) -> Dict[str, ir.Node]:
        """Batch-invariant operands, deduplicated chain-wide by name
        (same name => one resident buffer, one host array)."""
        shared: Dict[str, ir.Node] = {}
        for i, s in enumerate(self.stages):
            elem = set(s.program.element_vars)
            for name, node in s.program.inputs.items():
                if name in elem or name in self.resolved[i]:
                    continue
                shared.setdefault(name, node)
        return shared

    def stage_stream_bytes_per_element(
        self, i: int, bytes_per_scalar: int
    ) -> int:
        """Per-element bytes stage i moves through HBM per batch (host
        streams + resident reads/writes) -- the quantity the paper's
        channel rule divides a pseudo-channel by."""
        total = sum(
            v.size for _, v in self.host_element_inputs(i)
        ) + sum(v.size for _, v in self.chain_outputs(i))
        total += sum(v.size for _, v in self.resident_outputs(i))
        for in_name, (p, out_name) in self.resolved[i].items():
            total += self.stages[p].program.outputs[out_name].size
        return total * bytes_per_scalar

    def auto_batch_elements(
        self,
        target: MemoryTarget,
        *,
        bytes_per_scalar: int,
        channel_bytes: Optional[int] = None,
        n_eq: Optional[int] = None,
    ) -> int:
        """Co-sized E: the largest batch whose stream I/O fits one
        pseudo-channel for *every* stage (min over stages)."""
        cb = channel_bytes if channel_bytes is not None else target.channel_bytes
        e = None
        for i in range(len(self.stages)):
            per = self.stage_stream_bytes_per_element(i, bytes_per_scalar)
            ei = max(1, cb // per) if per else cb
            e = ei if e is None else min(e, ei)
        if n_eq is not None:
            e = min(e, max(1, n_eq))
        return int(max(1, e))


# ---------------------------------------------------------------------------
# the chain plan
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StagePlan:
    """One stage's slice of the chain plan (buffers it introduces)."""

    name: str
    backend: str
    prefetch_depth: int
    flops_per_element: int
    buffers: Tuple[BufferSpec, ...]
    cost: CostBreakdown
    block_elements: int = 0
    block_working_set_bytes: int = 0
    #: CUs (mesh devices) the stage shards its element batch over, and
    #: the topology device ids it owns (from the plan's placement).
    cu_count: int = 1
    devices: Tuple[int, ...] = (0,)
    #: the stage's own batch size E_s (0 = the chain-wide E).  On a
    #: heterogeneous topology each stage runs at the E natural to *its*
    #: memory system; E_s always divides the chain E, and the executor
    #: re-blocks (slice/concat) at handoffs where it changes.
    batch_elements: int = 0
    #: device kind the stage is placed on ("" = the plan target's).
    kind: str = ""


@dataclasses.dataclass(frozen=True)
class PipelineSpec:
    """How the chain executor overlaps stages *across batches*.

    ``mode == "pipelined"`` runs one dispatch ring per stage: stage i of
    batch k is dispatched in the same tick as stage i+1 of batch k-1
    (``memory.pipeline.run_stage_pipelined``), with the HBM-resident
    inter-stage streams handed off on device.  ``mode == "serial"`` is
    the paper's baseline: stages back-to-back per batch (host prefetch
    only), kept for bitwise-equality tests and as the ladder's rung.
    """

    mode: str                       # "pipelined" | "serial"
    stage_depths: Tuple[int, ...]   # dispatch-ring depth per stage
    stage_skews: Tuple[int, ...]    # batches stage i lags behind stage 0
    fill_batches: int               # pipeline fill (= drain) in batches

    @property
    def pipelined(self) -> bool:
        """True when any stage runs batches ahead (cross-batch mode)."""
        return self.mode == "pipelined"


def derive_pipeline(depths: Sequence[int]) -> PipelineSpec:
    """The execution mode a per-stage depth vector implies: any positive
    inter-stage ring depth turns cross-batch stage pipelining on."""
    from . import pipeline as pipe_mod

    skews = pipe_mod.stage_skews(depths)
    pipelined = len(depths) > 1 and any(d > 0 for d in depths[1:])
    return PipelineSpec(
        mode="pipelined" if pipelined else "serial",
        stage_depths=tuple(depths),
        stage_skews=tuple(skews),
        fill_batches=skews[-1],
    )


@dataclasses.dataclass(frozen=True)
class ChainCost:
    """Per-batch chain timing.

    ``pipelined_stages=False`` prices the back-to-back schedule (stages
    sequential per batch, each with its own transfer overlap);
    ``pipelined_stages=True`` prices cross-batch stage pipelining: the
    steady-state batch rate is set by the *slowest* stage alone, and the
    first batch's full chain latency (fill + drain) is amortized over
    ``n_batches``.  ``contention`` (from the plan's
    :class:`~repro.memory.placement.PlacementPlan`) is the number of
    stages sharing each stage's device group: under stage pipelining all
    stages are live on different batches simultaneously, so a stage's
    device-side terms (compute, HBM) are time-sliced ``contention``-fold
    -- this is how replication and overlap competing for the same
    devices is priced *before* execution.  When measured per-stage
    samples exist in a profile store, :func:`fit_contention` replaces
    the structural count with the multiplier the measurements imply
    (``contention_fit``) -- the same slot, learned instead of assumed.
    """

    stages: Tuple[CostBreakdown, ...]
    #: cross-batch mode: per-stage dispatch rings overlap stage i of
    #: batch k with stage i+1 of batch k-1
    pipelined_stages: bool = False
    #: pipeline fill in batches (the last stage's skew); reporting only
    fill_batches: int = 0
    n_batches: Optional[int] = None
    #: per-stage device-sharing multiplier (empty = disjoint groups)
    contention: Tuple[int, ...] = ()
    #: per-stage contention *measured* on this machine, fitted from
    #: profile-store stage samples by :func:`fit_contention` (0.0 =
    #: no device-bound evidence for that stage; fall back to the
    #: structural ``contention`` count).  Empty = no profile consulted.
    contention_fit: Tuple[float, ...] = ()
    #: per-stage re-block handoff cost (seconds per chain batch) billed
    #: to the *consumer*: when adjacent stages run at different E_s --
    #: or on different device kinds -- the handoff's bytes move through
    #: the slower side's link before the consumer can start.  Empty =
    #: no handoff re-blocks (the homogeneous shared-E legacy).
    t_reblock: Tuple[float, ...] = ()

    def _contention(self, i: int) -> float:
        if self.contention_fit and self.contention_fit[i] > 0.0:
            return self.contention_fit[i]
        return float(self.contention[i]) if self.contention else 1.0

    def _reblock(self, i: int) -> float:
        return self.t_reblock[i] if self.t_reblock else 0.0

    @property
    def t_reblock_total(self) -> float:
        """Chain-wide re-block seconds per batch (0 when E is shared)."""
        return sum(self.t_reblock) if self.t_reblock else 0.0

    @property
    def t_serial(self) -> float:
        """Fully serial chain time per batch (no overlap anywhere)."""
        return sum(c.t_serial for c in self.stages) + self.t_reblock_total

    @property
    def t_back_to_back(self) -> float:
        """Stages sequential per batch, per-stage transfer overlap."""
        return (
            sum(c.t_pipelined for c in self.stages) + self.t_reblock_total
        )

    @property
    def stage_steady_times(self) -> Tuple[float, ...]:
        """Per-stage steady-state time under stage pipelining: the
        stage's roofline with its device terms scaled by how many
        pipeline stages time-slice its devices, plus the re-block cost
        of its incoming handoffs (paid every batch before the stage can
        run).  The host link is billed uncontended -- it is shared
        chain-wide in every schedule."""
        out = []
        for i, c in enumerate(self.stages):
            k = self._contention(i) if self.pipelined_stages else 1
            out.append(
                max(c.t_host, k * max(c.t_compute, c.t_hbm))
                + c.t_overhead + self._reblock(i)
            )
        return tuple(out)

    @property
    def t_steady(self) -> float:
        """Steady-state batch rate under stage pipelining: the slowest
        *contended* stage -- every other stage hides behind it."""
        return max(self.stage_steady_times)

    @property
    def t_fill(self) -> float:
        """Amortized fill+drain cost per batch: the first batch pays the
        full back-to-back chain latency before steady state, spread over
        the run (0 when the batch count is unknown -- steady state)."""
        if not self.n_batches:
            return 0.0
        return (self.t_back_to_back - self.t_steady) / self.n_batches

    @property
    def t_overlapped(self) -> float:
        """Cross-batch pipelined time per batch: never worse than
        back-to-back (n_batches=1 degenerates to it exactly)."""
        return min(self.t_back_to_back, self.t_steady + self.t_fill)

    @property
    def t_pipelined(self) -> float:
        """Effective predicted time per batch under the plan's mode."""
        return (
            self.t_overlapped if self.pipelined_stages
            else self.t_back_to_back
        )

    @property
    def bottleneck_stage(self) -> int:
        """Index of the stage dominating the pipelined chain time."""
        times = (
            self.stage_steady_times if self.pipelined_stages
            else [c.t_pipelined for c in self.stages]
        )
        return list(times).index(max(times))

    @property
    def bottleneck(self) -> str:
        """The dominating stage's dominating cost term (the label the
        measured-feedback CostCorrection attributes ratios to)."""
        return self.stages[self.bottleneck_stage].bottleneck

    @property
    def overlap_speedup(self) -> float:
        """Predicted speedup of the plan's mode over fully serial."""
        return self.t_serial / self.t_pipelined if self.t_pipelined else 1.0

    @property
    def stage_overlap_speedup(self) -> float:
        """What cross-batch stage pipelining alone buys over the
        back-to-back schedule."""
        return (
            self.t_back_to_back / self.t_overlapped
            if self.t_overlapped else 1.0
        )


def fit_contention(
    cost: ChainCost,
    stage_names: Sequence[str],
    samples: Sequence[Dict[str, float]],
) -> Tuple[float, ...]:
    """Per-stage contention multipliers fitted from measured samples.

    The steady-state model prices stage i as
    ``max(t_host, k * max(t_compute, t_hbm)) + t_overhead`` with ``k``
    the *structural* device-sharing count from the placement.  Each
    profile-store sample with ``scope == "stage:<name>"`` carries that
    stage's measured per-batch time, so the model inverts directly:
    ``k_est = (measured - t_overhead) / max(t_compute, t_hbm)``.  Only
    samples with device-bound evidence count -- when
    ``measured - t_overhead <= t_host`` the host link hides the device
    terms and the measurement says nothing about ``k``.  Per stage the
    estimates combine by geometric mean (ratios), clamped to >= 1.0
    (devices cannot be less than uncontended).  Stages without usable
    samples get 0.0, meaning "keep the structural count".  Returns ()
    when no stage could be fitted, so callers can skip the replace.
    """
    n = len(cost.stages)
    if len(stage_names) != n:
        raise ValueError(
            f"cost has {n} stages, got {len(stage_names)} names"
        )
    by_stage: Dict[str, List[float]] = {}
    for s in samples:
        scope = s.get("scope", "")
        m = s.get("measured_s")
        if not isinstance(scope, str) or not scope.startswith("stage:"):
            continue
        if not isinstance(m, (int, float)) or m <= 0:
            continue
        by_stage.setdefault(scope[len("stage:"):], []).append(float(m))

    fit: List[float] = []
    for i, nm in enumerate(stage_names):
        c = cost.stages[i]
        dev = max(c.t_compute, c.t_hbm)
        ks: List[float] = []
        if dev > 0:
            for m in by_stage.get(nm, ()):
                dev_part = m - c.t_overhead
                if dev_part <= c.t_host:
                    continue        # host-bound sample: no evidence on k
                ks.append(dev_part / dev)
        if ks:
            k = math.exp(sum(math.log(x) for x in ks) / len(ks))
            fit.append(max(1.0, k))
        else:
            fit.append(0.0)
    return tuple(fit) if any(k > 0.0 for k in fit) else ()


def apply_profile_contention(plan: "ChainPlan", profile) -> "ChainPlan":
    """Re-price a plan's steady-state times from measured contention.

    ``profile`` is anything :meth:`repro.trace.ProfileStore.open`
    accepts (a store, a path, ``True`` for the default location).  Pulls
    this machine's current-epoch stage samples for the plan's signature
    (target-wide fallback) and swaps the fitted multipliers into the
    plan's :class:`ChainCost`.  A cold store -- or one with only
    host-bound / chain-level samples -- returns the plan unchanged.
    """
    from ..trace.profile import ProfileStore  # lazy: no import cycle

    store = ProfileStore.open(profile)
    if store is None:
        return plan
    samples = store.samples(plan.target.name, plan.signature)
    fit = fit_contention(
        plan.cost, [sp.name for sp in plan.stages], samples
    )
    if not fit:
        return plan
    return dataclasses.replace(
        plan, cost=dataclasses.replace(plan.cost, contention_fit=fit)
    )


@dataclasses.dataclass(frozen=True)
class ChainPlan:
    """The complete memory architecture for a multi-operator program."""

    chain: str                  # e.g. "interp->grad->helmholtz"
    target: MemoryTarget
    policy: str
    batch_elements: int         # shared E, co-sized over all stages
    #: per-stage (cu_count, prefetch_depth) + stage -> device-group
    #: assignment over the explicit topology the plan was made for
    placement: PlacementPlan
    stages: Tuple[StagePlan, ...]
    cost: ChainCost
    feasible: bool = True
    infeasible_reason: str = ""
    #: elements added to (negative: trimmed from) the auto-sized E so it
    #: is a multiple of every stage's VMEM block (0 for explicit E).
    batch_pad_elements: int = 0
    #: cross-batch stage pipelining spec the executor runs off (derived
    #: from the per-stage prefetch depths; None only on legacy plans).
    pipeline: Optional[PipelineSpec] = None
    #: what the cost-driven fusion pass decided (None when planning ran
    #: with fusion off); ``fusion.chain`` holds the fused chain.
    fusion: Optional["FusionSpec"] = None
    #: per-stage batch size E_s (empty = every stage runs the chain E).
    #: Each E_s divides the chain E and shards evenly on its stage's CU
    #: group; the executor re-blocks at handoffs where E_s changes.
    stage_batch_elements: Tuple[int, ...] = ()

    def stage_e(self, i: int) -> int:
        """Stage ``i``'s effective batch size (the chain E unless a
        per-stage vector was planned)."""
        if self.stage_batch_elements:
            return self.stage_batch_elements[i]
        return self.batch_elements

    @property
    def uniform_batch(self) -> bool:
        """True when every stage runs the chain-wide E (no re-blocking
        handoffs; the executor may use the single-mesh fast path)."""
        return all(
            es == self.batch_elements for es in self.stage_batch_elements
        )

    @property
    def cu_count(self) -> int:
        """Devices the plan needs locally: the widest stage group (the
        historical chain-wide scalar, now derived from the placement)."""
        return self.placement.max_cu_count

    @property
    def cu_counts(self) -> Tuple[int, ...]:
        """Per-stage CU replication, from the placement."""
        return self.placement.cu_counts

    @property
    def buffers(self) -> Tuple[BufferSpec, ...]:
        """Every stage's buffers, flattened in chain order."""
        return tuple(b for s in self.stages for b in s.buffers)

    @property
    def resident_bytes(self) -> int:
        """Total HBM bytes held resident across the chain."""
        return sum(b.resident_bytes for b in self.buffers)

    @property
    def host_stream_bytes(self) -> int:
        """Host-link bytes per batch across the whole chain -- the number
        the paper's residency optimization shrinks."""
        return host_stream_bytes(self.buffers)

    @property
    def hbm_stream_bytes(self) -> int:
        """Device-memory bytes streamed per batch, chain-wide."""
        return hbm_stream_bytes(self.buffers)

    @property
    def channels_used(self) -> int:
        """Distinct pseudo-channels the chain's buffers map to."""
        return channels_used(self.buffers)

    @property
    def resident_stream_bytes(self) -> int:
        """Per-batch bytes kept on-device between stages (the traffic a
        stage-by-stage host round-trip would have added to the link)."""
        return sum(
            b.batch_bytes for b in self.buffers if b.role == "resident"
        )

    def batches_for(self, n_eq: int) -> int:
        """Batches needed to cover an ``n_eq``-element problem."""
        return max(1, n_eq // self.batch_elements)

    @property
    def signature(self) -> str:
        """Stable short id of *what would execute*: stage names/backends/
        flops, per-stage (K, CU), policy and E -- the profile-store key
        that groups measured runs of equivalent plans across processes."""
        import hashlib

        parts = [self.chain, self.policy, str(self.batch_elements)]
        parts += [
            f"{sp.name}:{sp.backend}:{sp.flops_per_element}:"
            f"{sp.prefetch_depth}:{sp.cu_count}"
            for sp in self.stages
        ]
        # heterogeneous extensions only when they change what executes,
        # so every homogeneous shared-E plan keeps its historical
        # signature (and its accumulated profile-store samples)
        if not self.uniform_batch:
            parts.append(
                "E:" + ",".join(
                    str(es) for es in self.stage_batch_elements
                )
            )
        if len(self.placement.topology.groups) > 1:
            parts.append(self.placement.topology.spec_string())
            parts.append(
                "G:" + ",".join(
                    str(g) for g in self.placement.stage_group_indices
                )
            )
        return hashlib.sha1("|".join(parts).encode()).hexdigest()[:12]

    def report(self) -> str:
        """Human-readable plan description: stages, buffers per
        channel, the cost prediction, and the fusion decision."""
        t = self.target
        mib = 2 ** 20
        lines = [
            f"ChainPlan {self.chain}  target={t.name}  policy={self.policy}",
            f"  E={self.batch_elements} elements/batch (co-sized)   "
            f"CUs=[{','.join(str(c) for c in self.cu_counts)}]   "
            f"feasible={'yes' if self.feasible else 'NO: ' + self.infeasible_reason}",
            f"  channels: {self.channels_used}/"
            f"{self.placement.topology.total_channels(t)} used   "
            f"resident {self.resident_bytes / mib:.1f} MiB "
            f"of {t.usable_hbm_bytes / mib:.0f} MiB usable",
            f"  host stream {self.host_stream_bytes / mib:.1f} MiB/batch   "
            f"inter-stage resident {self.resident_stream_bytes / mib:.1f} "
            f"MiB/batch   hbm traffic "
            f"{self.hbm_stream_bytes / mib:.1f} MiB/batch",
        ]
        if self.batch_pad_elements:
            lines.append(
                f"  E auto-padded {self.batch_pad_elements:+d} elements "
                f"(from {self.batch_elements - self.batch_pad_elements}) "
                "to keep every stage's VMEM block divisor composite"
            )
        for sp in self.stages:
            c = sp.cost
            lines += [
                "",
                f"  stage {sp.name}  backend={sp.backend}  "
                f"K={sp.prefetch_depth}  CU={sp.cu_count}  "
                f"BE={sp.block_elements} "
                f"(vmem ws {sp.block_working_set_bytes / mib:.2f} MiB)",
                f"    {'buffer':<20} {'role':<9} {'elem B':>7} "
                f"{'padded':>7} {'batch MiB':>10} {'repl':>5}  channels",
            ]
            for b in sp.buffers:
                ch = ",".join(str(i) for i in b.channels[:6])
                if len(b.channels) > 6:
                    ch += f",..x{len(b.channels)}"
                lines.append(
                    f"    {b.name:<20} {b.role:<9} {b.element_bytes:>7} "
                    f"{b.padded_bytes:>7} {b.batch_bytes / mib:>10.2f} "
                    f"{b.replicas:>5}  [{ch}]"
                )
            lines.append(
                f"    predicted/batch: compute {c.t_compute * 1e3:.3f} ms  "
                f"hbm {c.t_hbm * 1e3:.3f} ms  host {c.t_host * 1e3:.3f} ms"
                f"  -> {c.bottleneck}-bound"
            )
        cc = self.cost
        lines.append("")
        lines += self.placement.describe(
            stage_names=[sp.name for sp in self.stages],
            stage_elements=[
                self.stage_e(i) for i in range(len(self.stages))
            ],
            stage_channels=[
                sorted({c for b in sp.buffers for c in b.channels})
                for sp in self.stages
            ],
            stage_kinds=[sp.kind or t.name for sp in self.stages],
        )
        if cc.t_reblock and any(r > 0 for r in cc.t_reblock):
            vec = ",".join(f"{r * 1e3:.3f}" for r in cc.t_reblock)
            lines.append(
                f"  re-block handoffs: [{vec}] ms/batch per consumer "
                "stage (E or kind changes across the boundary)"
            )
        if cc.contention_fit:
            vec = ",".join(
                f"{k:.2f}" if k > 0.0 else "-" for k in cc.contention_fit
            )
            lines.append(
                f"  contention fitted from profile: [{vec}]   "
                "(- = no device-bound samples; structural count kept)"
            )
        if self.pipeline is not None:
            pp = self.pipeline
            lines.append(
                f"  pipeline: mode={pp.mode}   stage depths "
                f"[{','.join(str(d) for d in pp.stage_depths)}]   skews "
                f"[{','.join(str(s) for s in pp.stage_skews)}]   "
                f"fill/drain {pp.fill_batches} batches"
            )
            if pp.pipelined:
                lines.append(
                    f"    steady {cc.t_steady * 1e3:.3f} ms/batch + fill "
                    f"{cc.t_fill * 1e3:.3f} ms/batch amortized   "
                    f"(predicted stage-overlap speedup "
                    f"{cc.stage_overlap_speedup:.2f}x over back-to-back "
                    f"{cc.t_back_to_back * 1e3:.3f} ms/batch)"
                )
        if self.fusion is not None:
            lines.append("  " + self.fusion.describe())
        lines.append(
            f"  chain serial {cc.t_serial * 1e3:.3f} ms/batch   "
            f"pipelined {cc.t_pipelined * 1e3:.3f} ms/batch   "
            f"(overlap speedup {cc.overlap_speedup:.2f}x, bottleneck "
            f"stage {self.stages[cc.bottleneck_stage].name})"
        )
        return "\n".join(lines)


def snap_stage_elements(e: int, requested: int, cu: int) -> int:
    """Snap a stage's requested E_s to the largest value that divides
    the chain batch ``e``, shards evenly over ``cu`` devices, and does
    not exceed the request.  Falls back to ``cu`` (the smallest legal
    sub-batch) and finally to ``e`` itself -- so when ``cu`` divides
    ``e`` a legal E_s always exists."""
    e, cu = max(1, int(e)), max(1, int(cu))
    req = max(1, min(int(requested), e))
    best = 0
    d = 1
    while d * d <= e:
        if e % d == 0:
            for cand in (d, e // d):
                if cand <= req and cand % cu == 0:
                    best = max(best, cand)
        d += 1
    if best:
        return best
    return cu if e % cu == 0 else e


def _scale_cost(cost: CostBreakdown, m: int) -> CostBreakdown:
    """A stage running ``m`` sub-batches per chain batch pays every cost
    term ``m`` times (including dispatch overhead -- sub-batching is not
    free, which is exactly the tension the per-stage-E search prices)."""
    if m <= 1:
        return cost
    return dataclasses.replace(
        cost,
        t_compute=cost.t_compute * m, t_hbm=cost.t_hbm * m,
        t_host=cost.t_host * m, t_overhead=cost.t_overhead * m,
        t_serial=cost.t_serial * m, t_pipelined=cost.t_pipelined * m,
    )


def plan_chain(
    chain: ProgramChain,
    *,
    target: Optional[MemoryTarget] = None,
    policy: str = "float32",
    backends: Optional[Sequence[str]] = None,
    batch_elements: Optional[int] = None,
    prefetch_depth: Union[int, Sequence[int]] = 1,
    cu_count: Union[int, Sequence[int]] = 1,
    topology: Optional[DeviceTopology] = None,
    placement: Optional[PlacementPlan] = None,
    stage_groups: Optional[Sequence[int]] = None,
    stage_batch_elements: Optional[Sequence[int]] = None,
    n_eq: Optional[int] = None,
    channel_bytes: Optional[int] = None,
    profile=None,
    fuse: Optional[str] = None,
    max_stages: Optional[int] = None,
    fuse_barriers: Sequence[str] = (),
    _sched_cache: Optional[Dict[Tuple[int, int], Schedule]] = None,
) -> ChainPlan:
    """Plan one memory architecture for a whole ProgramChain.

    ``fuse='auto'`` makes the stage count itself a design axis: the
    cost-driven fusion pass (:mod:`repro.memory.fusion`) greedily merges
    adjacent stages whenever the HBM-resident handoff between them costs
    more than the fused stage's combined roofline, then plans the fused
    chain (the returned plan carries the decision as ``plan.fusion``).
    ``max_stages`` forces least-harm merges down to a stage budget
    (``max_stages=1`` fully fuses) and implies fusion unless
    ``fuse='off'``; ``fuse_barriers`` names stages whose downstream
    boundary must survive (the flow's explicit named cuts).

    ``backends`` overrides each stage's backend for planning (the DSE
    sweeps hypothetical per-stage backends this way); ``prefetch_depth``
    and ``cu_count`` may be one value for the whole chain or one per
    stage -- stage 0's K stages host batches ahead, stage i>0's K is its
    dispatch-ring depth behind stage i-1, and any positive inter-stage
    depth turns on cross-batch stage pipelining (the plan's ``pipeline``
    spec, priced by ``ChainCost.t_overlapped``: makespan set by the
    slowest *contended* stage plus amortized fill/drain instead of the
    per-batch stage sum).  The per-stage CU counts and ring depths are
    co-scheduled over an explicit :class:`DeviceTopology` (default: just
    enough devices for the widest stage, so element sharding and the
    pipeline's dispatch rings visibly compete for them); pass a larger
    ``topology`` -- or a full ``placement`` -- to plan disjoint device
    groups.  Deterministic: same arguments, same plan.  ``profile``
    (anything :meth:`repro.trace.ProfileStore.open` accepts) re-prices
    the finished plan's steady-state times from this machine's measured
    per-stage contention via :func:`apply_profile_contention`.
    ``_sched_cache`` (keyed by stage index and scalar width) lets sweeps
    reuse staged-backend schedules across design points instead of
    re-partitioning per candidate.

    On a heterogeneous topology (``DeviceTopology.parse("cpu:2,tpu:4")``
    or ``from_jax`` over a mixed pool) every stage is priced against the
    datasheet of the kind group it lands on: ``stage_groups`` pins
    stages to groups (default: least-loaded), buffers draw channel ids
    from the owning group's pseudo-channels, and ``stage_batch_elements``
    gives each stage its own E_s (snapped to divide the chain E and
    shard on its group).  Handoffs whose E_s -- or device kind --
    changes are priced as an explicit re-block term billed to the
    consumer (bytes through the slower side's link).
    """
    # local import: dse depends on this module for chain exploration
    from .dse import predict_cost

    if fuse not in (None, "off", "auto"):
        raise ValueError(f"unknown fuse mode {fuse!r}; use 'auto' or 'off'")
    if fuse != "off" and (
        fuse == "auto"
        or (max_stages is not None and max_stages < len(chain.stages))
    ):
        from .fusion import fuse_chain_auto  # lazy: fusion imports chain

        if placement is not None:
            raise ValueError(
                "an explicit placement is per-stage and cannot survive "
                "fusion; pass a topology instead"
            )
        if stage_groups is not None or stage_batch_elements is not None:
            raise ValueError(
                "per-stage groups/batch sizes cannot survive fusion; "
                "plan the fused chain first, then pin stages"
            )
        return fuse_chain_auto(
            chain,
            mode="auto",
            max_stages=max_stages,
            barriers=tuple(fuse_barriers),
            target=target,
            policy=policy,
            backends=backends,
            batch_elements=batch_elements,
            prefetch_depth=prefetch_depth,
            cu_count=cu_count,
            topology=topology,
            n_eq=n_eq,
            channel_bytes=channel_bytes,
            profile=profile,
        )

    target = target if target is not None else detect_target()
    if policy not in POLICIES:
        raise ValueError(f"unknown policy {policy!r}; known: {sorted(POLICIES)}")
    pol = POLICIES[policy]
    bps = pol.bits // 8
    n_stages = len(chain.stages)

    if backends is None:
        backends = [s.backend for s in chain.stages]
    if len(backends) != n_stages:
        raise ValueError(f"need {n_stages} backends, got {len(backends)}")
    if placement is not None:
        if placement.n_stages != n_stages:
            raise ValueError(
                f"placement has {placement.n_stages} stages, chain has "
                f"{n_stages}"
            )
        place = placement
    else:
        if isinstance(cu_count, int):
            cus = [cu_count] * n_stages
        else:
            cus = list(cu_count)
            if len(cus) != n_stages:
                raise ValueError(f"need {n_stages} cu counts, got {len(cus)}")
        if isinstance(prefetch_depth, int):
            depth_vec = [prefetch_depth] * n_stages
        else:
            depth_vec = list(prefetch_depth)
            if len(depth_vec) != n_stages:
                raise ValueError(f"need {n_stages} prefetch depths")
        if topology is None:
            topology = DeviceTopology.homogeneous(max(1, max(cus)))
        place = place_chain(
            topology, cus, depth_vec, stage_groups=stage_groups
        )
    depths = list(place.prefetch_depths)
    any_prefetch = any(d > 0 for d in depths)
    # per-stage pricing targets: each stage is costed (and its buffers
    # burst-padded, channel-mapped, VMEM-bounded) against the datasheet
    # of the kind group that owns it; target-less groups (the
    # homogeneous legacy) fall back to the plan-wide target
    stage_ts = [place.stage_target(i, target) for i in range(n_stages)]

    pad = 0
    blk_align = 1
    if batch_elements is not None:
        e = batch_elements
    else:
        e = chain.auto_batch_elements(
            target, bytes_per_scalar=bps,
            channel_bytes=channel_bytes, n_eq=n_eq,
        )
        # co-sized E is padded to a multiple of the largest stage block
        # cap (caps are powers of two, so every stage's divides too);
        # all caps are passed so a small-cap stage cannot stay starved
        caps = [
            layout.vmem_block_elements(
                s.program, stage_ts[i], bytes_per_scalar=bps
            )
            for i, s in enumerate(chain.stages)
        ]
        blk_align = max(caps)
        e, pad = layout.pad_batch_for_block(
            e, blk_align, limit=n_eq, caps=caps
        )
    e = max(1, int(e))
    if n_eq is not None:
        e = min(e, max(1, n_eq))
    # element sharding: every stage splits the batch evenly over its CU
    # group, so E must be a multiple of every group size.  Auto-sized E
    # is snapped down (the trim is reported via batch_pad_elements),
    # preserving the VMEM block alignment just established where it can
    # -- snapping to a bare multiple of the shard would collapse every
    # stage's Pallas block divisor (the pad_batch_for_block regression).
    # An explicit indivisible E is reported infeasible below.
    shard = 1
    for g in place.cu_counts:
        shard = shard * g // math.gcd(shard, g)
    if e % shard and batch_elements is None and e > shard:
        align = shard * blk_align // math.gcd(shard, blk_align)
        snap = align if e >= align else shard
        trim = e % snap
        e -= trim
        pad -= trim
    n_batches = max(1, n_eq // e) if n_eq else None

    # per-stage E_s: every stage runs the chain E unless a vector was
    # requested; requests snap to divide E and shard on the stage's CU
    # group (the executor re-blocks at handoffs where E_s changes)
    if stage_batch_elements is not None:
        if len(stage_batch_elements) != n_stages:
            raise ValueError(
                f"need {n_stages} stage batch sizes, got "
                f"{len(stage_batch_elements)}"
            )
        stage_es = [
            snap_stage_elements(e, req, place.stages[i].cu_count)
            for i, req in enumerate(stage_batch_elements)
        ]
    else:
        stage_es = [e] * n_stages

    # placement-aware channel assignment: one round-robin allocator per
    # kind group, offset into a global id space, so every stream draws
    # from the pseudo-channels of the group owning its producing stage
    # (a single-group topology degenerates to the legacy shared
    # allocator exactly)
    allocs: Dict[int, layout.ChannelAllocator] = {}
    ch_base = 0
    for gi, gspec in enumerate(place.topology.groups):
        g_t = gspec.target if gspec.target is not None else target
        allocs[gi] = layout.ChannelAllocator(g_t.n_channels, base=ch_base)
        ch_base += g_t.n_channels
    shared_ops = chain.shared_operands()
    placed_shared: Dict[str, BufferSpec] = {}
    resident_spec: Dict[Tuple[int, str], BufferSpec] = {}
    stage_plans: List[StagePlan] = []
    max_stage_ws = 0
    max_stage_ws_vmem = target.vmem_bytes

    reblock: List[float] = [0.0] * n_stages
    for i, stage in enumerate(chain.stages):
        prog = stage.program
        backend = backends[i]
        depth = depths[i]
        stage_t = stage_ts[i]
        e_s = stage_es[i]
        m = max(1, e // e_s)          # sub-batches per chain batch
        in_repl = depth + 2 if depth > 0 else 1
        io_repl = 2 if any_prefetch else 1
        alloc = allocs[place.stage_group_index(i)]
        bufs: List[BufferSpec] = []

        def add(name, node, role, replicas, group=""):
            b = layout.make_buffer(
                name, node, role, replicas, target=stage_t,
                bytes_per_scalar=bps, batch_elements=e_s,
                alloc=alloc, group=group,
            )
            bufs.append(b)
            return b

        for name, node in chain.host_element_inputs(i):
            add(f"{stage.name}.{name}", node, "in", in_repl)
        for name, node in chain.resident_outputs(i):
            resident_spec[(i, name)] = add(
                f"{stage.name}.{name}", node, "resident", io_repl
            )
        for name, node in chain.chain_outputs(i):
            add(f"{stage.name}.{name}", node, "out", io_repl)
        for name, node in prog.inputs.items():
            if (name in prog.element_vars or name in chain.resolved[i]
                    or name in placed_shared):
                continue
            if name in shared_ops:
                placed_shared[name] = add(name, node, "shared", 1)

        sched: Optional[Schedule] = None
        if backend == "staged":
            key = (i, bps)
            if _sched_cache is not None and key in _sched_cache:
                sched = _sched_cache[key]
            else:
                sched = make_schedule(prog, bytes_per_scalar=bps)
                if _sched_cache is not None:
                    _sched_cache[key] = sched
            out_uids = {v.uid for v in prog.outputs.values()}
            input_uids = {v.uid for v in prog.inputs.values()}
            for g in sched.groups:
                streamed = [
                    n for n in g.out_streams
                    if n.uid not in out_uids and n.uid not in input_uids
                ]
                for k, node in enumerate(streamed):
                    add(f"{stage.name}.{g.name}.s{k}", node, "inter", 1,
                        group=g.name)
            ws = max(g.working_set(bps) for g in sched.groups)
            if ws > max_stage_ws:
                max_stage_ws = ws
                max_stage_ws_vmem = stage_t.vmem_bytes

        # stage cost: host link carries only this stage's in/out streams;
        # HBM carries those plus resident reads/writes and 2x inter
        stage_hbm = hbm_stream_bytes(bufs)
        for in_name, (p, out_name) in chain.resolved[i].items():
            # consumer-side read of a resident buffer placed by stage p
            # (the write half is already billed to the producer's hbm
            # count above, via the 2x resident rule on its own buffer);
            # read at *this* stage's E_s -- one sub-batch per dispatch
            spec = resident_spec[(p, out_name)]
            stage_hbm += spec.padded_bytes * e_s
            # re-block handoff: when the boundary changes E_s or device
            # kind, the chain batch's bytes cross the slower side's
            # link before this stage can consume them
            if stage_es[p] != e_s or place.stage_kind(p) != place.stage_kind(i):
                hand_bytes = spec.padded_bytes * e
                p_t, i_t = stage_ts[p], stage_t
                if place.stage_kind(p) != place.stage_kind(i):
                    bw = min(p_t.host_link_bw, i_t.host_link_bw)
                else:
                    bw = min(p_t.hbm_bw, i_t.hbm_bw)
                reblock[i] += hand_bytes / bw if bw > 0 else 0.0
        # a producer's resident buffer counts write-only for itself
        stage_hbm -= sum(
            b.batch_bytes for b in bufs if b.role == "resident"
        )
        # channels this stage touches: its own buffers, the resident
        # streams it reads, and the shared operands it consumes
        touched = list(bufs)
        touched += [
            resident_spec[src] for src in chain.resolved[i].values()
        ]
        touched += [
            placed_shared[n] for n in prog.inputs
            if n in placed_shared
        ]
        cost = _scale_cost(
            predict_cost(
                stage_t, policy=pol.name, batch_elements=e_s,
                flops_per_element=prog.total_flops(),
                host_bytes=host_stream_bytes(bufs),
                hbm_bytes=stage_hbm,
                channels_used=channels_used(touched),
                prefetch_depth=depth, cu_count=place.stages[i].cu_count,
                n_batches=n_batches,
            ),
            m,
        )
        blk_cap = layout.vmem_block_elements(
            prog, stage_t, bytes_per_scalar=bps
        )
        blk = layout.largest_divisor_leq(e_s, blk_cap)
        stage_plans.append(
            StagePlan(
                name=stage.name, backend=backend, prefetch_depth=depth,
                flops_per_element=prog.total_flops(),
                buffers=tuple(bufs), cost=cost,
                block_elements=blk,
                block_working_set_bytes=layout.block_working_set_bytes(
                    prog, blk, bytes_per_scalar=bps
                ),
                cu_count=place.stages[i].cu_count,
                devices=place.stages[i].devices,
                batch_elements=e_s,
                kind=stage_t.name,
            )
        )

    pipeline = derive_pipeline(depths)
    plan = ChainPlan(
        chain=chain.name, target=target, policy=pol.name,
        batch_elements=e, placement=place,
        stages=tuple(stage_plans),
        cost=ChainCost(
            stages=tuple(sp.cost for sp in stage_plans),
            pipelined_stages=pipeline.pipelined,
            fill_batches=pipeline.fill_batches,
            n_batches=n_batches,
            contention=place.contention,
            t_reblock=(
                tuple(reblock) if any(r > 0 for r in reblock) else ()
            ),
        ),
        batch_pad_elements=pad,
        pipeline=pipeline,
        stage_batch_elements=(
            tuple(stage_es) if any(es != e for es in stage_es) else ()
        ),
    )
    # VMEM bounds are per stage against the stage's own datasheet
    # (identical to the plan-wide target on a homogeneous topology)
    worst_blk, worst_blk_vmem = 0, target.vmem_bytes
    for i, sp in enumerate(stage_plans):
        if sp.block_working_set_bytes > worst_blk:
            worst_blk = sp.block_working_set_bytes
            worst_blk_vmem = stage_ts[i].vmem_bytes
    # resident HBM is a per-group budget: each kind group holds only
    # the buffers of the stages placed on it
    group_resident: Dict[int, int] = {}
    for i, sp in enumerate(stage_plans):
        gi = place.stage_group_index(i)
        group_resident[gi] = group_resident.get(gi, 0) + sum(
            b.resident_bytes for b in sp.buffers
        )
    resident_excess = ""
    for gi, rb in sorted(group_resident.items()):
        g_t = place.topology.groups[gi].target or target
        if rb > g_t.usable_hbm_bytes:
            resident_excess = (
                f"resident {rb / 2**20:.0f} MiB exceeds "
                f"usable HBM {g_t.usable_hbm_bytes / 2**20:.0f} MiB"
            )
            if len(place.topology.groups) > 1:
                resident_excess += (
                    f" on group {gi} ({place.topology.groups[gi].kind})"
                )
            break
    feasible, reason = True, ""
    if e % shard:
        feasible = False
        reason = (
            f"batch E={e} does not shard evenly over the stage CU "
            f"groups (needs a multiple of {shard})"
        )
    elif resident_excess:
        feasible = False
        reason = resident_excess
    elif worst_blk > worst_blk_vmem:
        feasible = False
        reason = (
            f"stage block working set {worst_blk} B exceeds on-chip "
            f"{worst_blk_vmem} B"
        )
    elif max_stage_ws > max_stage_ws_vmem:
        feasible = False
        reason = (
            f"stage working set {max_stage_ws} B exceeds on-chip "
            f"{max_stage_ws_vmem} B"
        )
    if not feasible:
        plan = dataclasses.replace(
            plan, feasible=False, infeasible_reason=reason
        )
    if profile is not None:
        plan = apply_profile_contention(plan, profile)
    return plan
