"""MemoryPlan: the explicit memory architecture for one compiled program.

This is the artifact the paper's Olympus flow produces implicitly when it
instantiates Fig. 14: which array lives in which pseudo-channel, how many
ping/pong replicas each stream keeps resident, how big a batch (E) is,
and what the transfer/compute overlap is predicted to cost.  The plan is
pure data (frozen dataclasses) so it can be diffed, cached, and compared
across DSE candidates; ``report()`` renders the human-readable dump.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional, Tuple

from .channels import MemoryTarget


def host_stream_bytes(buffers: Iterable["BufferSpec"]) -> int:
    """Host-link bytes moved per batch (in + out streams, padded)."""
    return sum(b.batch_bytes for b in buffers if b.role in ("in", "out"))


def hbm_stream_bytes(buffers: Iterable["BufferSpec"]) -> int:
    """Device-memory traffic per batch: every stream crosses HBM once;
    stage intermediates and chain-resident streams cross twice (the
    producer writes, the consumer reads back) -- but never the host
    link."""
    total = 0
    for b in buffers:
        if b.role in ("in", "out"):
            total += b.batch_bytes
        elif b.role in ("inter", "resident"):
            total += 2 * b.batch_bytes
    return total


def channels_used(buffers: Iterable["BufferSpec"]) -> int:
    """Distinct pseudo-channel ids the buffers map to."""
    used = set()
    for b in buffers:
        used.update(b.channels)
    return len(used)


@dataclasses.dataclass(frozen=True)
class BufferSpec:
    """One device-resident buffer and its pseudo-channel placement.

    Roles:
      * ``in``     -- host-streamed input (E-element batch; a K-deep
                      prefetch pipeline keeps K+2 replicas resident:
                      K staged + 1 computing + 1 retiring -- Fig. 14a's
                      ping/pong pair generalized, plus the slot JAX
                      frees only when the async compute completes).
      * ``out``    -- device-produced batch streamed back / reduced.
      * ``shared`` -- batch-invariant operand (the paper's S matrix),
                      resident once.
      * ``inter``  -- scheduled-group intermediate (staged backend): an
                      HBM round-trip between dataflow stages.
      * ``resident`` -- chain stream (``memory.chain``): a producer
                      stage's output consumed by a later stage of the
                      same ProgramChain.  It stays in HBM -- written once,
                      read once, never crossing the host link.
    """

    name: str
    role: str
    shape: Tuple[int, ...]      # per-element shape (element axis excluded)
    element_bytes: int          # unpadded bytes per element record
    padded_bytes: int           # after burst/word packing
    batch_bytes: int            # padded_bytes * E (shared: padded_bytes)
    replicas: int               # concurrently-resident copies
    channels: Tuple[int, ...]   # assigned pseudo-channel ids
    group: str = ""             # producing schedule group (inter only)

    @property
    def resident_bytes(self) -> int:
        """HBM footprint: one batch per ping/pong replica."""
        return self.batch_bytes * self.replicas

    @property
    def padding_overhead(self) -> float:
        """Fraction of the buffer that is alignment padding."""
        if self.element_bytes == 0:
            return 0.0
        return self.padded_bytes / self.element_bytes - 1.0


@dataclasses.dataclass(frozen=True)
class CostBreakdown:
    """Predicted per-batch seconds under the three-term transfer model."""

    t_compute: float     # FLOPs / (peak * policy efficiency * CUs)
    t_hbm: float         # device-memory traffic / assigned-channel bw
    t_host: float        # host->device stream / host link bw
    t_overhead: float    # per-dispatch launch/sync cost
    t_serial: float      # no overlap: host + max(compute, hbm) + overhead
    t_pipelined: float   # K-deep overlap: max(host, compute, hbm) + overhead

    @property
    def bottleneck(self) -> str:
        """The dominating term's label (the correction-fit key)."""
        terms = {
            "compute": self.t_compute,
            "hbm": self.t_hbm,
            "host-link": self.t_host,
        }
        return max(terms, key=terms.get)

    @property
    def overlap_speedup(self) -> float:
        """Predicted serial/pipelined ratio for this stage."""
        return self.t_serial / self.t_pipelined if self.t_pipelined else 1.0


@dataclasses.dataclass(frozen=True)
class MemoryPlan:
    """The complete memory architecture for one operator + target."""

    operator: str               # e.g. "inverse_helmholtz_p11"
    target: MemoryTarget
    policy: str
    backend: str
    batch_elements: int         # E -- elements per dispatched batch
    prefetch_depth: int         # K -- batches staged ahead (0 = serial)
    cu_count: int               # replicated compute units (mesh devices)
    buffers: Tuple[BufferSpec, ...]
    cost: CostBreakdown
    feasible: bool = True
    infeasible_reason: str = ""
    flops_per_element: int = 0
    #: largest element block whose fused-kernel working set fits on-chip
    #: memory (drives the Pallas kernel's ``block_elements``); divides E.
    block_elements: int = 0
    block_working_set_bytes: int = 0
    #: elements added to (or, negative, trimmed from) the auto-sized E so
    #: it is a multiple of the VMEM block (0 when E was given explicitly
    #: or already composite).  Padded tail elements are host-side filler.
    batch_pad_elements: int = 0

    # -- aggregates ---------------------------------------------------------
    @property
    def resident_bytes(self) -> int:
        """Device memory held while the pipeline is in flight."""
        return sum(b.resident_bytes for b in self.buffers)

    @property
    def host_stream_bytes(self) -> int:
        """Host-link bytes moved per batch (in + out streams, padded)."""
        return host_stream_bytes(self.buffers)

    @property
    def hbm_stream_bytes(self) -> int:
        """Device-memory traffic per batch (intermediates cross twice)."""
        return hbm_stream_bytes(self.buffers)

    @property
    def channels_used(self) -> int:
        """Distinct pseudo-channels this plan's buffers map to."""
        return channels_used(self.buffers)

    @property
    def signature(self) -> str:
        """Stable short id of what would execute (operator, backend,
        policy, E, K, CU) -- the profile-store key for single-op runs."""
        import hashlib

        parts = [
            self.operator, self.backend, self.policy,
            str(self.batch_elements), str(self.prefetch_depth),
            str(self.cu_count), str(self.flops_per_element),
        ]
        return hashlib.sha1("|".join(parts).encode()).hexdigest()[:12]

    @property
    def donation(self) -> Tuple[str, ...]:
        """Input buffers safe to donate to XLA (each staged batch is
        consumed exactly once, so its device buffer can be reused for
        outputs).  Only meaningful for the jitted ``xla`` backend."""
        if self.backend != "xla":
            return ()
        return tuple(sorted(b.name for b in self.buffers if b.role == "in"))

    def batches_for(self, n_eq: int) -> int:
        """Batches needed to cover an ``n_eq``-element problem."""
        return max(1, n_eq // self.batch_elements)

    # -- the "Fig. 14" dump -------------------------------------------------
    def report(self) -> str:
        """Human-readable plan dump (the paper's Fig. 14 analog)."""
        t = self.target
        c = self.cost
        mib = 2 ** 20
        lines = [
            f"MemoryPlan {self.operator}  target={t.name}  "
            f"backend={self.backend}  policy={self.policy}",
            f"  E={self.batch_elements} elements/batch   "
            f"prefetch K={self.prefetch_depth}   CUs={self.cu_count}   "
            f"feasible={'yes' if self.feasible else 'NO: ' + self.infeasible_reason}",
            f"  channels: {self.channels_used}/{t.n_channels} used "
            f"({t.channel_bytes // mib} MiB each)   "
            f"resident {self.resident_bytes / mib:.1f} MiB "
            f"of {t.usable_hbm_bytes / mib:.0f} MiB usable",
            f"  host stream {self.host_stream_bytes / mib:.1f} MiB/batch   "
            f"hbm traffic {self.hbm_stream_bytes / mib:.1f} MiB/batch",
        ]
        if self.block_elements:
            lines.append(
                f"  vmem block BE={self.block_elements} elements   "
                f"working set {self.block_working_set_bytes / mib:.2f} MiB "
                f"of {t.vmem_bytes / mib:.0f} MiB VMEM"
            )
        if self.batch_pad_elements:
            lines.append(
                f"  E auto-padded {self.batch_pad_elements:+d} elements "
                f"(from {self.batch_elements - self.batch_pad_elements}) "
                "to keep the VMEM block divisor composite"
            )
        lines += [
            "",
            f"  {'buffer':<14} {'role':<7} {'elem B':>7} {'padded':>7} "
            f"{'batch MiB':>10} {'repl':>5}  channels",
        ]
        for b in self.buffers:
            ch = ",".join(str(i) for i in b.channels[:6])
            if len(b.channels) > 6:
                ch += f",..x{len(b.channels)}"
            lines.append(
                f"  {b.name:<14} {b.role:<7} {b.element_bytes:>7} "
                f"{b.padded_bytes:>7} {b.batch_bytes / mib:>10.2f} "
                f"{b.replicas:>5}  [{ch}]"
            )
        lines += [
            "",
            f"  predicted/batch: compute {c.t_compute * 1e3:.3f} ms   "
            f"hbm {c.t_hbm * 1e3:.3f} ms   host {c.t_host * 1e3:.3f} ms"
            f"   -> {c.bottleneck}-bound",
            f"  serial {c.t_serial * 1e3:.3f} ms/batch   "
            f"pipelined {c.t_pipelined * 1e3:.3f} ms/batch   "
            f"(overlap speedup {c.overlap_speedup:.2f}x)",
        ]
        return "\n".join(lines)
