"""Device-memory model: the HBM pseudo-channel abstraction (paper Fig. 14).

The paper's Olympus flow sizes every host<->accelerator stream against a
concrete memory architecture: 32 HBM2 pseudo-channels of 256 MB each on
the Alveo U280, a PCIe host link, and on-chip PLM (BRAM/URAM).  This
module is the portable version of that datasheet: a frozen
:class:`MemoryTarget` per device family, used by

  * ``memory.layout``   -- buffer placement / batch sizing (E),
  * ``memory.dse``      -- the design-space cost model,
  * ``analysis.roofline`` -- which imports its TPU constants from here so
    the planner and the roofline can never disagree on peak numbers.

Targets are plain data -- hypothetical machines are made with
:meth:`MemoryTarget.with_` (the DSE bandwidth sweeps do exactly that).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

#: The paper's pseudo-channel capacity (HBM2 on the Alveo U280).
PAPER_CHANNEL_BYTES = 256 * 2 ** 20


@dataclasses.dataclass(frozen=True)
class MemoryTarget:
    """One accelerator's memory datasheet (per compute unit / chip)."""

    name: str
    peak_flops: float          # peak FLOP/s per CU (native matmul precision)
    hbm_bytes: int             # device memory capacity
    hbm_bw: float              # aggregate device-memory bandwidth, bytes/s
    n_channels: int            # pseudo-channels the capacity is split into
    host_link_bw: float        # host->device transfer bandwidth, bytes/s
    vmem_bytes: int            # on-chip scratch (PLM / VMEM) per CU
    ici_bw: float = 50e9       # inter-CU link bandwidth, bytes/s
    burst_bytes: int = 64      # transfer/pack quantum (AXI burst, TPU lane)
    usable_hbm_fraction: float = 0.9   # leave headroom for the runtime
    dispatch_overhead_s: float = 20e-6  # per-batch launch/sync cost

    @property
    def channel_bytes(self) -> int:
        """Capacity of one pseudo-channel (paper: 256 MB)."""
        return self.hbm_bytes // self.n_channels

    @property
    def channel_bw(self) -> float:
        """Bandwidth of one pseudo-channel."""
        return self.hbm_bw / self.n_channels

    @property
    def usable_hbm_bytes(self) -> int:
        """HBM capacity after the reserved fraction is held back."""
        return int(self.hbm_bytes * self.usable_hbm_fraction)

    def with_(self, **overrides) -> "MemoryTarget":
        """A modified copy -- the DSE's what-if machine generator."""
        return dataclasses.replace(self, **overrides)


#: The paper's board: Alveo U280, 8 GiB HBM2 in 32 x 256 MiB
#: pseudo-channels at 460 GB/s, PCIe gen3 x16 host link, ~43 MB PLM.
ALVEO_U280 = MemoryTarget(
    name="alveo-u280",
    peak_flops=0.6e12,
    hbm_bytes=8 * 2 ** 30,
    hbm_bw=460e9,
    n_channels=32,
    host_link_bw=15.75e9,
    vmem_bytes=43 * 2 ** 20,
    ici_bw=0.0,               # single-FPGA target
    burst_bytes=64,           # 512-bit AXI beat
    dispatch_overhead_s=50e-6,
)

#: TPU v5e chip -- the repo's production target.  819 GB/s HBM2e modeled
#: as 32 pseudo-channels (512 MiB each); 128 MiB VMEM (schedule.py keeps
#: half for double buffering); ICI at 50 GB/s per link.
TPU_V5E = MemoryTarget(
    name="tpu-v5e",
    peak_flops=197e12,        # bf16 MXU peak (roofline's PEAK_FLOPS_BF16)
    hbm_bytes=16 * 2 ** 30,
    hbm_bw=819e9,
    n_channels=32,
    host_link_bw=32e9,
    vmem_bytes=128 * 2 ** 20,
    ici_bw=50e9,
    burst_bytes=512,          # 128-lane f32 vector
    dispatch_overhead_s=20e-6,
)

#: The CPU container the tests run on: host RAM plays HBM, a memcpy
#: plays the host link.  Numbers are deliberately conservative.
CPU_HOST = MemoryTarget(
    name="cpu-host",
    peak_flops=50e9,
    hbm_bytes=4 * 2 ** 30,
    hbm_bw=20e9,
    n_channels=4,
    host_link_bw=12e9,
    vmem_bytes=16 * 2 ** 20,  # ~L3 slice
    ici_bw=5e9,
    burst_bytes=64,
    dispatch_overhead_s=200e-6,
)

TARGETS = {t.name: t for t in (ALVEO_U280, TPU_V5E, CPU_HOST)}


class UnknownTargetError(ValueError):
    """A target name that matches no datasheet (after normalization)."""


def canonical_target_name(name: str) -> str:
    """One spelling per datasheet: case-insensitive, underscores and
    dashes interchangeable (CI passes ``alveo-u280``, the Python API
    historically used ``alveo_u280`` -- both must resolve)."""
    return str(name).strip().lower().replace("_", "-")


def resolve_target(target) -> MemoryTarget:
    """None -> detect; MemoryTarget -> itself; str -> datasheet lookup
    under :func:`canonical_target_name`.  Unknown names raise
    :class:`UnknownTargetError` listing every known target, with a
    did-you-mean suggestion for near misses (surfaced verbatim by the
    CLIs' error path, exit code 2)."""
    if target is None:
        return detect_target()
    if isinstance(target, MemoryTarget):
        return target
    key = canonical_target_name(target)
    if key not in TARGETS:
        import difflib

        close = difflib.get_close_matches(key, sorted(TARGETS), n=1)
        hint = f" -- did you mean {close[0]!r}?" if close else ""
        raise UnknownTargetError(
            f"unknown target {target!r}; known targets: "
            f"{', '.join(sorted(TARGETS))} (underscores and dashes are "
            f"interchangeable){hint}"
        )
    return TARGETS[key]


def detect_target() -> MemoryTarget:
    """Pick the target matching the current JAX backend."""
    import jax

    backend = jax.default_backend()
    if backend == "tpu":
        return TPU_V5E
    return CPU_HOST


def pad_to_burst(nbytes: int, target: MemoryTarget) -> int:
    """Round a record up to the target's transfer quantum (the paper
    packs p^3 scalars into 256-bit HBM words; the remainder is padding)."""
    q = target.burst_bytes
    return ((nbytes + q - 1) // q) * q


def channels_for(nbytes: int, target: MemoryTarget) -> int:
    """Pseudo-channels needed to hold ``nbytes`` (>= 1)."""
    cb = target.channel_bytes
    return max(1, -(-nbytes // cb))
