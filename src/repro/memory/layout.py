"""Buffer layout: map a program's streams onto the channel model.

Performs the paper's section-3 sizing decisions explicitly:

  * **stream discovery** -- element-streamed inputs/outputs vs. shared
    (batch-invariant) operands, straight from ``ir.Program.element_vars``;
    with a staged schedule, per-group intermediates become HBM round-trip
    buffers too (``core.schedule`` exposes their byte counts).
  * **packing/padding** -- each element record is padded to the target's
    burst quantum (the paper packs p^3 scalars into 256-bit HBM words).
  * **batch sizing** -- E is derived so one batch's combined stream I/O
    fills one pseudo-channel, exactly the rule behind
    ``SimConfig.batch_for_channel`` but computed from the program instead
    of hardcoded in the driver.
  * **channel assignment** -- round-robin placement of every replica
    (ping/pong copies for a K-deep prefetch) over the pseudo-channels.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

from ..core import ir
from ..core.schedule import Schedule
from .channels import MemoryTarget, channels_for, pad_to_burst
from .plan import BufferSpec


def element_streams(prog: ir.Program):
    """Split program arrays into (element inputs, element outputs, shared).

    Element arrays carry the implicit leading batch axis; shared arrays
    (the paper's S operator) are broadcast across the batch.
    """
    elem = set(prog.element_vars)
    ins = [(n, v) for n, v in prog.inputs.items() if n in elem]
    outs = [(n, v) for n, v in prog.outputs.items() if n in elem]
    shared = [(n, v) for n, v in prog.inputs.items() if n not in elem]
    return ins, outs, shared


def stream_bytes_per_element(prog: ir.Program, bytes_per_scalar: int) -> int:
    """Unpadded host-stream bytes per element (in + out), the quantity
    ``SimConfig.batch_for_channel`` divides a channel by."""
    ins, outs, _ = element_streams(prog)
    return sum(v.size for _, v in ins + outs) * bytes_per_scalar


def auto_batch_elements(
    prog: ir.Program,
    target: MemoryTarget,
    *,
    bytes_per_scalar: int,
    channel_bytes: Optional[int] = None,
    n_eq: Optional[int] = None,
) -> int:
    """The paper's E: largest batch whose stream I/O fits one channel.

    ``n_eq`` caps E at the problem size (no point staging a batch larger
    than the whole simulation).
    """
    cb = channel_bytes if channel_bytes is not None else target.channel_bytes
    per = stream_bytes_per_element(prog, bytes_per_scalar)
    e = max(1, cb // per)
    if n_eq is not None:
        e = min(e, max(1, n_eq))
    return int(e)


class _ChannelAllocator:
    """Round-robin pseudo-channel assignment (Fig. 14's array->channel
    map).  A buffer spanning more channels than exist wraps -- capacity
    feasibility is checked globally by the DSE, not here."""

    def __init__(self, n_channels: int):
        self.n = n_channels
        self.next = 0

    def take(self, count: int) -> Tuple[int, ...]:
        count = max(1, count)
        ids = tuple((self.next + i) % self.n for i in range(min(count, self.n)))
        self.next = (self.next + count) % self.n
        return ids


def build_buffers(
    prog: ir.Program,
    target: MemoryTarget,
    *,
    bytes_per_scalar: int,
    batch_elements: int,
    prefetch_depth: int,
    schedule: Optional[Schedule] = None,
) -> Tuple[BufferSpec, ...]:
    """Assign every stream of the program to sized, channel-mapped buffers."""
    ins, outs, shared = element_streams(prog)
    alloc = _ChannelAllocator(target.n_channels)
    bufs: List[BufferSpec] = []

    # K-deep prefetch keeps K staged batches, one computing, and -- since
    # JAX allocates fresh buffers instead of swapping a ping/pong pair in
    # place -- one retiring batch whose async compute has not yet freed
    # it.  Peak input residency is therefore K+2 (K=1 is the paper's
    # ping/pong pair plus the retiring slot).
    in_replicas = prefetch_depth + 2 if prefetch_depth > 0 else 1
    out_replicas = 2 if prefetch_depth > 0 else 1  # result drains while next computes

    def add(name, node, role, replicas, group=""):
        eb = node.size * bytes_per_scalar
        pb = pad_to_burst(eb, target)
        bb = pb * batch_elements if role != "shared" else pb
        ch = alloc.take(replicas * channels_for(bb, target))
        bufs.append(
            BufferSpec(
                name=name, role=role, shape=tuple(node.shape),
                element_bytes=eb, padded_bytes=pb, batch_bytes=bb,
                replicas=replicas, channels=ch, group=group,
            )
        )

    for name, node in ins:
        add(name, node, "in", in_replicas)
    for name, node in outs:
        add(name, node, "out", out_replicas)
    for name, node in shared:
        add(name, node, "shared", 1)

    # staged backend: group-boundary intermediates are HBM round-trips
    if schedule is not None:
        out_uids = {v.uid for v in prog.outputs.values()}
        input_uids = {v.uid for v in prog.inputs.values()}
        for g in schedule.groups:
            streamed = [
                n for n in g.out_streams
                if n.uid not in out_uids and n.uid not in input_uids
            ]
            for i, node in enumerate(streamed):
                add(f"{g.name}.s{i}", node, "inter", 1, group=g.name)
    return tuple(bufs)
