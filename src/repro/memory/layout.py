"""Buffer layout: map a program's streams onto the channel model.

Performs the paper's section-3 sizing decisions explicitly:

  * **stream discovery** -- element-streamed inputs/outputs vs. shared
    (batch-invariant) operands, straight from ``ir.Program.element_vars``;
    with a staged schedule, per-group intermediates become HBM round-trip
    buffers too (``core.schedule`` exposes their byte counts).
  * **packing/padding** -- each element record is padded to the target's
    burst quantum (the paper packs p^3 scalars into 256-bit HBM words).
  * **batch sizing** -- E is derived so one batch's combined stream I/O
    fills one pseudo-channel, exactly the rule behind
    ``SimConfig.batch_for_channel`` but computed from the program instead
    of hardcoded in the driver.
  * **channel assignment** -- round-robin placement of every replica
    (ping/pong copies for a K-deep prefetch) over the pseudo-channels.
  * **VMEM block sizing** -- the largest per-dispatch element block whose
    working set fits the target's on-chip memory, which is what drives
    the Pallas kernel's ``block_elements`` (the paper's PLM sizing).

``ProgramChain`` planning (``memory.chain``) reuses these primitives with
a shared :class:`ChannelAllocator` so all stages of a multi-operator
program place their buffers without conflicts.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

from ..core import ir
from ..core.schedule import Schedule
from .channels import MemoryTarget, channels_for, pad_to_burst
from .plan import BufferSpec


def element_streams(prog: ir.Program):
    """Split program arrays into (element inputs, element outputs, shared).

    Element arrays carry the implicit leading batch axis; shared arrays
    (the paper's S operator) are broadcast across the batch.
    """
    elem = set(prog.element_vars)
    ins = [(n, v) for n, v in prog.inputs.items() if n in elem]
    outs = [(n, v) for n, v in prog.outputs.items() if n in elem]
    shared = [(n, v) for n, v in prog.inputs.items() if n not in elem]
    return ins, outs, shared


def stream_bytes_per_element(prog: ir.Program, bytes_per_scalar: int) -> int:
    """Unpadded host-stream bytes per element (in + out), the quantity
    ``SimConfig.batch_for_channel`` divides a channel by."""
    ins, outs, _ = element_streams(prog)
    return sum(v.size for _, v in ins + outs) * bytes_per_scalar


def auto_batch_elements(
    prog: ir.Program,
    target: MemoryTarget,
    *,
    bytes_per_scalar: int,
    channel_bytes: Optional[int] = None,
    n_eq: Optional[int] = None,
) -> int:
    """The paper's E: largest batch whose stream I/O fits one channel.

    ``n_eq`` caps E at the problem size (no point staging a batch larger
    than the whole simulation).
    """
    cb = channel_bytes if channel_bytes is not None else target.channel_bytes
    per = stream_bytes_per_element(prog, bytes_per_scalar)
    e = max(1, cb // per)
    if n_eq is not None:
        e = min(e, max(1, n_eq))
    return int(e)


class ChannelAllocator:
    """Round-robin pseudo-channel assignment (Fig. 14's array->channel
    map).  A buffer spanning more channels than exist wraps -- capacity
    feasibility is checked globally by the DSE, not here.  One take never
    repeats a channel (no double-booking within one replica set); chain
    planning shares a single allocator across all stages so no two
    stages' hot streams pile onto channel 0.

    ``base`` offsets the allotted ids into a global channel namespace:
    heterogeneous chain planning runs one allocator per device group, so
    a stream lands on the pseudo-channels of the group that owns its
    producing stage (group 0 gets ids ``[0, n0)``, group 1 gets
    ``[n0, n0+n1)``, ...)."""

    def __init__(self, n_channels: int, base: int = 0):
        self.n = n_channels
        self.base = base
        self.next = 0

    def take(self, count: int) -> Tuple[int, ...]:
        """Allot the next ``count`` channel ids round-robin (capped at
        the channel count -- wide buffers stripe what exists)."""
        count = max(1, count)
        ids = tuple(
            self.base + (self.next + i) % self.n
            for i in range(min(count, self.n))
        )
        self.next = (self.next + count) % self.n
        return ids


#: Backwards-compatible alias (pre-chain name).
_ChannelAllocator = ChannelAllocator


def make_buffer(
    name: str,
    node: ir.Node,
    role: str,
    replicas: int,
    *,
    target: MemoryTarget,
    bytes_per_scalar: int,
    batch_elements: int,
    alloc: ChannelAllocator,
    group: str = "",
) -> BufferSpec:
    """Size, pad, and channel-assign one stream (shared by single-program
    and chain planning)."""
    eb = node.size * bytes_per_scalar
    pb = pad_to_burst(eb, target)
    bb = pb * batch_elements if role != "shared" else pb
    ch = alloc.take(replicas * channels_for(bb, target))
    return BufferSpec(
        name=name, role=role, shape=tuple(node.shape),
        element_bytes=eb, padded_bytes=pb, batch_bytes=bb,
        replicas=replicas, channels=ch, group=group,
    )


def build_buffers(
    prog: ir.Program,
    target: MemoryTarget,
    *,
    bytes_per_scalar: int,
    batch_elements: int,
    prefetch_depth: int,
    schedule: Optional[Schedule] = None,
) -> Tuple[BufferSpec, ...]:
    """Assign every stream of the program to sized, channel-mapped buffers."""
    ins, outs, shared = element_streams(prog)
    alloc = ChannelAllocator(target.n_channels)
    bufs: List[BufferSpec] = []

    # K-deep prefetch keeps K staged batches, one computing, and -- since
    # JAX allocates fresh buffers instead of swapping a ping/pong pair in
    # place -- one retiring batch whose async compute has not yet freed
    # it.  Peak input residency is therefore K+2 (K=1 is the paper's
    # ping/pong pair plus the retiring slot).
    in_replicas = prefetch_depth + 2 if prefetch_depth > 0 else 1
    out_replicas = 2 if prefetch_depth > 0 else 1  # result drains while next computes

    def add(name, node, role, replicas, group=""):
        bufs.append(
            make_buffer(
                name, node, role, replicas, target=target,
                bytes_per_scalar=bytes_per_scalar,
                batch_elements=batch_elements, alloc=alloc, group=group,
            )
        )

    for name, node in ins:
        add(name, node, "in", in_replicas)
    for name, node in outs:
        add(name, node, "out", out_replicas)
    for name, node in shared:
        add(name, node, "shared", 1)

    # staged backend: group-boundary intermediates are HBM round-trips
    if schedule is not None:
        out_uids = {v.uid for v in prog.outputs.values()}
        input_uids = {v.uid for v in prog.inputs.values()}
        for g in schedule.groups:
            streamed = [
                n for n in g.out_streams
                if n.uid not in out_uids and n.uid not in input_uids
            ]
            for i, node in enumerate(streamed):
                add(f"{g.name}.s{i}", node, "inter", 1, group=g.name)
    return tuple(bufs)


# ---------------------------------------------------------------------------
# on-chip (VMEM / PLM) block sizing -- what drives the Pallas kernel's
# block_elements (the paper sizes its PLM buffers the same way)
# ---------------------------------------------------------------------------


def block_working_set_bytes(
    prog: ir.Program, block_elements: int, *, bytes_per_scalar: int
) -> int:
    """On-chip bytes while one element block flows through the fused
    kernel: every element stream's block slice, double-buffered scratch
    for the largest intermediate (Mnemosyne-style t/r sharing keeps two
    live), plus the batch-invariant operands held resident."""
    ins, outs, shared = element_streams(prog)
    elem = sum(v.size for _, v in ins + outs)
    scratch = 2 * max(
        (n.size for n in prog.toposort() if not isinstance(n, ir.Input)),
        default=0,
    )
    shared_b = sum(v.size for _, v in shared)
    return (shared_b + block_elements * (elem + scratch)) * bytes_per_scalar


def vmem_block_elements(
    prog: ir.Program,
    target: MemoryTarget,
    *,
    bytes_per_scalar: int,
    reserve_fraction: float = 0.5,
) -> int:
    """Largest power-of-two element block whose working set fits the
    target's on-chip memory (half is reserved for the grid pipeline's
    DMA double buffering, mirroring ``core.schedule``'s VMEM budget)."""
    budget = int(target.vmem_bytes * reserve_fraction)
    be = 1
    while block_working_set_bytes(
        prog, be * 2, bytes_per_scalar=bytes_per_scalar
    ) <= budget:
        be *= 2
    return be


def pad_batch_for_block(
    e: int,
    block_cap: int,
    *,
    limit: Optional[int] = None,
    caps: Optional[Sequence[int]] = None,
) -> Tuple[int, int]:
    """Auto-pad E to a block-composite size (ROADMAP: a prime-ish
    natural E must never force the Pallas block divisor tiny).

    Rounds E up to the next multiple of the (power-of-two) VMEM block
    cap, so ``largest_divisor_leq(E, cap) == cap`` -- the paper pads the
    tail batch the same way it pads records to HBM words.  E is left
    alone when its natural block is already at least half the cap (no
    filler for a near-optimal divisor); for chain planning, pass every
    stage's cap via ``caps`` so that check covers the *smallest* stage
    too (a multiple of the largest power-of-two cap divides the rest).
    ``limit`` (the problem size ``n_eq``) bounds the padded batch: when
    rounding up would exceed it, E snaps *down* to the nearest block
    multiple instead (never below one block).  Returns ``(padded_e,
    pad)`` with ``pad = padded_e - e`` (negative when snapped down);
    the plan reports the pad so the host knows how many tail elements
    per batch are filler.
    """
    all_caps = [block_cap] + [c for c in (caps or ())]
    block_cap = max(all_caps)
    if block_cap <= 1 or e <= block_cap:
        return e, 0
    if all(
        c <= 1 or e <= c or largest_divisor_leq(e, c) * 2 >= c
        for c in all_caps
    ):
        return e, 0  # natural E already composite enough: no filler
    up = -(-e // block_cap) * block_cap
    if limit is None or up <= limit:
        return up, up - e
    down = (e // block_cap) * block_cap
    if down >= block_cap:
        return down, down - e
    return e, 0


def largest_divisor_leq(n: int, bound: int) -> int:
    """Largest divisor of ``n`` that is <= ``bound`` (>= 1).  Pallas grids
    require block_elements to divide the batch, so the VMEM-derived block
    is snapped to the nearest feasible divisor of E."""
    n, bound = max(1, n), max(1, bound)
    best = 1
    d = 1
    while d * d <= n:
        if n % d == 0:
            if d <= bound:
                best = max(best, d)
            if n // d <= bound:
                best = max(best, n // d)
        d += 1
    return best
