"""Always-on serving telemetry: registry, SLOs, exposition, invariants.

The live complement to ``repro.trace``'s bounded after-the-fact traces:

  * :class:`MetricsRegistry` hands out :class:`Counter` /
    :class:`Gauge` / :class:`Histogram` series by (name, labels)
    identity; :data:`NULL_REGISTRY` is the falsy no-op twin (the
    ``trace.NULL`` pattern), so unmetered hot paths cost one truthiness
    check.
  * :class:`SLOTracker` turns a p95 latency target and an error budget
    into windowed burn rates and an ``ok``/``warn``/``breach`` verdict.
  * :func:`export_prometheus` / :func:`write_snapshot` expose the
    registry as Prometheus text or snapshot JSON;
    :func:`check_snapshot` enforces the serving conservation laws and
    reconciles against the trace counters (``python -m repro.metrics``).

Wired through ``repro.serve`` (engine/queue/cache ``metrics=``,
``--metrics out.json`` on the serve and flow CLIs) and duck-typed into
``memory.pipeline.StagePipelineDriver`` exactly like the tracer.
"""
from .check import (check_snapshot, check_structure, diff_snapshots,
                    trace_counter_totals)
from .expo import export_prometheus, write_snapshot
from .registry import (DEFAULT_TIME_BUCKETS, Counter, Gauge, Histogram,
                       MetricsError, MetricsRegistry, NULL_REGISTRY,
                       NullRegistry, linear_buckets, log_buckets)
from .slo import SLOTracker, VERDICTS

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsError", "MetricsRegistry",
    "NullRegistry", "NULL_REGISTRY", "DEFAULT_TIME_BUCKETS",
    "log_buckets", "linear_buckets", "SLOTracker", "VERDICTS",
    "export_prometheus", "write_snapshot", "check_snapshot",
    "check_structure", "diff_snapshots", "trace_counter_totals",
]
