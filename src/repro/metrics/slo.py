"""SLO tracking: latency + error-rate targets with windowed burn rates.

An SLO here is two targets -- a p95 latency bound and an error-rate
budget -- and the tracker answers one question continuously: *how fast
is recent traffic burning the budget?*  Following the standard burn-rate
formulation, each target implies an allowance (5% of requests may
exceed a p95 target; ``target_error_rate`` of requests may fail) and
the burn rate is the windowed violation rate over that allowance:
1.0 means budget is being consumed exactly as provisioned, above it the
SLO breaches if the window's behaviour persists.

``verdict()`` folds both burns into ``ok`` / ``warn`` / ``breach``.
When a registry is supplied the tracker also exports its state as
gauges (``slo_latency_burn``, ``slo_error_burn``, ``slo_verdict``) so a
snapshot carries the verdict without a side channel.
"""
from __future__ import annotations

import math
from collections import deque
from typing import Any, Dict, Optional

from .registry import Histogram, MetricsError

#: verdict ordering for the exported gauge (and severity comparisons)
VERDICTS = ("ok", "warn", "breach")


class SLOTracker:
    """Track one serving SLO: p95 latency target + error-rate budget.

    ``window`` bounds the burn-rate computation to recent requests (a
    long-lived engine answers "are we breaching *now*", not "did we ever
    breach").  ``warn_ratio`` is the burn fraction that turns the
    verdict to ``warn``; ``min_count`` withholds judgement until the
    window has evidence.  Latency observations flow through a standard
    :class:`~repro.metrics.registry.Histogram`, so the p95 reported in
    the verdict is the same quantile implementation the rest of the
    codebase uses.
    """

    def __init__(self, target_p95_s: float, target_error_rate: float = 0.01,
                 *, window: int = 256, warn_ratio: float = 0.5,
                 min_count: int = 8, registry=None) -> None:
        if target_p95_s <= 0:
            raise MetricsError(
                f"target_p95_s must be > 0, got {target_p95_s}"
            )
        if not 0.0 <= target_error_rate < 1.0:
            raise MetricsError(
                f"target_error_rate must be in [0, 1), got {target_error_rate}"
            )
        self.target_p95_s = target_p95_s
        self.target_error_rate = target_error_rate
        self.warn_ratio = warn_ratio
        self.min_count = min_count
        self.latency = Histogram(
            name="slo_latency_seconds", window=max(window, 1024)
        )
        self._win: deque = deque(maxlen=window)  # (error, over_target)
        self.errors = 0
        self._g_latency_burn = self._g_error_burn = self._g_verdict = None
        if registry:
            self._g_latency_burn = registry.gauge(
                "slo_latency_burn",
                "Windowed latency-budget burn rate (>= 1.0 breaches).")
            self._g_error_burn = registry.gauge(
                "slo_error_burn",
                "Windowed error-budget burn rate (>= 1.0 breaches).")
            self._g_verdict = registry.gauge(
                "slo_verdict", "0 = ok, 1 = warn, 2 = breach.")
            registry.gauge(
                "slo_target_p95_seconds", "Configured p95 latency target."
            ).set(target_p95_s)
            registry.gauge(
                "slo_target_error_rate", "Configured error-rate budget."
            ).set(target_error_rate)

    def observe(self, latency_s: float, *, error: bool = False) -> None:
        """One finished request: its latency, and whether it failed."""
        self.latency.observe(latency_s)
        if error:
            self.errors += 1
        self._win.append((error, latency_s > self.target_p95_s))
        if self._g_verdict is not None:
            self.verdict()  # refresh the exported gauges

    @property
    def count(self) -> int:
        return self.latency.count

    def burn_rates(self) -> Dict[str, float]:
        """Windowed burn per budget.  Latency budget: 5% of requests may
        exceed the p95 target.  Error budget: ``target_error_rate``.  A
        zero budget burns infinitely on the first violation."""
        n = len(self._win)
        if not n:
            return {"latency_burn": 0.0, "error_burn": 0.0,
                    "window_error_rate": 0.0, "window_over_rate": 0.0}
        err = sum(1 for e, _ in self._win if e) / n
        over = sum(1 for _, o in self._win if o) / n
        err_burn = (err / self.target_error_rate if self.target_error_rate
                    else (math.inf if err else 0.0))
        return {
            "latency_burn": over / 0.05,
            "error_burn": err_burn,
            "window_error_rate": err,
            "window_over_rate": over,
        }

    def verdict(self) -> Dict[str, Any]:
        """The SLO state now: ``ok`` / ``warn`` / ``breach`` plus the
        numbers behind it (p95 over the recent window, burn rates)."""
        burns = self.burn_rates()
        worst = max(burns["latency_burn"], burns["error_burn"])
        if self.count < self.min_count:
            state = "ok"  # not enough evidence to judge
        elif worst >= 1.0:
            state = "breach"
        elif worst >= self.warn_ratio:
            state = "warn"
        else:
            state = "ok"
        if self._g_latency_burn is not None:
            self._g_latency_burn.set(burns["latency_burn"])
            self._g_error_burn.set(
                burns["error_burn"] if burns["error_burn"] != math.inf
                else float("inf"))
            self._g_verdict.set(float(VERDICTS.index(state)))
        return {
            "verdict": state,
            "count": self.count,
            "errors": self.errors,
            "p95_s": self.latency.quantile(0.95),
            "target_p95_s": self.target_p95_s,
            "target_error_rate": self.target_error_rate,
            **burns,
        }
