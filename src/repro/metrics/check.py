"""Snapshot self-consistency: the invariants a healthy engine satisfies.

A metrics snapshot is only trustworthy if it agrees with itself -- and
with the trace artifact of the same run.  This module checks both:

  * **Structural**: every series well-formed, histogram bucket counts
    summing to the series count, no duplicate (name, labels) identity.
  * **Serving conservation**: ``submitted == completed + failed +
    rejected + in_flight``; latency-histogram counts equal to the
    completed counter; phase sums (queue + execute) equal to the total
    within float tolerance; ``waves x E == admitted elements + pad``.
  * **Trace reconciliation**: the engine's pad/wave/request counters
    must agree *exactly* with the tracer's ``COUNTER_PAD_ELEMENTS`` /
    ``COUNTER_SERVE_WAVES`` / ``COUNTER_SERVE_REQUESTS`` totals from the
    same run's ``--trace`` file -- two independent instrumentation paths
    observing identical events.

Violations raise :class:`~repro.metrics.registry.MetricsError` naming
the failing identity and both sides of the failed equality; CI pipes
the serve smoke's snapshot through ``python -m repro.metrics --check``
and fails the build on any breach.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

from .registry import MetricsError

SCHEMA = "repro.metrics/v1"

_REL_EPS = 1e-9
_ABS_EPS = 1e-6

Key = Tuple[str, Tuple[Tuple[str, str], ...]]


def index_metrics(snap: Dict[str, Any]) -> Dict[Key, Dict[str, Any]]:
    """Snapshot series by (name, sorted labels); duplicate identities
    are a structural violation."""
    if snap.get("schema") != SCHEMA:
        raise MetricsError(
            f"snapshot schema {snap.get('schema')!r} != {SCHEMA!r}"
        )
    idx: Dict[Key, Dict[str, Any]] = {}
    for m in snap.get("metrics", []):
        for field in ("name", "type", "labels"):
            if field not in m:
                raise MetricsError(f"metric missing {field!r}: {m}")
        key = (m["name"], tuple(sorted(
            (str(k), str(v)) for k, v in m["labels"].items()
        )))
        if key in idx:
            raise MetricsError(
                f"duplicate metric identity {m['name']}"
                f"{dict(key[1])}"
            )
        idx[key] = m
    return idx


def _value(idx: Dict[Key, Dict[str, Any]], name: str, **labels) -> float:
    m = idx.get((name, tuple(sorted((k, str(v)) for k, v in labels.items()))))
    return float(m["value"]) if m else 0.0


def _series(idx: Dict[Key, Dict[str, Any]], name: str) -> List[Dict[str, Any]]:
    return [m for (n, _), m in sorted(idx.items()) if n == name]


def _hist(idx: Dict[Key, Dict[str, Any]], name: str,
          **labels) -> Optional[Dict[str, Any]]:
    return idx.get(
        (name, tuple(sorted((k, str(v)) for k, v in labels.items())))
    )


def _ident(name: str, labels: Dict[str, str]) -> str:
    return f"{name}{labels}" if labels else name


def _require(ok: bool, msg: str) -> None:
    if not ok:
        raise MetricsError(msg)


def _close(a: float, b: float) -> bool:
    return abs(a - b) <= _ABS_EPS + _REL_EPS * max(abs(a), abs(b))


def check_structure(snap: Dict[str, Any]) -> List[str]:
    """Every series well-formed for its type; histogram bucket counts
    must sum to the series count."""
    idx = index_metrics(snap)
    for (name, labels), m in idx.items():
        ident = _ident(name, dict(labels))
        kind = m["type"]
        if kind in ("counter", "gauge"):
            _require("value" in m, f"{ident}: {kind} missing value")
            if kind == "counter":
                _require(float(m["value"]) >= 0,
                         f"{ident}: counter value {m['value']} < 0")
        elif kind == "histogram":
            for field in ("count", "sum", "buckets"):
                _require(field in m, f"{ident}: histogram missing {field!r}")
            bucket_sum = sum(int(b["count"]) for b in m["buckets"])
            _require(
                bucket_sum == int(m["count"]),
                f"{ident}: bucket counts sum to {bucket_sum}, "
                f"count is {m['count']}"
            )
            les = [b["le"] for b in m["buckets"]]
            _require(
                les and les[-1] == "+Inf",
                f"{ident}: histogram buckets must end with +Inf"
            )
        else:
            raise MetricsError(f"{ident}: unknown metric type {kind!r}")
    return ["structure"]


def check_serving(snap: Dict[str, Any]) -> List[str]:
    """The serving-layer conservation laws (no-op for snapshots from a
    run that never served -- e.g. a flow CLI batch job)."""
    idx = index_metrics(snap)
    if not _series(idx, "serve_requests_total"):
        return []
    checked = []
    req = {e: _value(idx, "serve_requests_total", event=e)
           for e in ("submitted", "completed", "failed", "rejected")}
    in_flight = _value(idx, "serve_in_flight_requests")
    finished = req["completed"] + req["failed"] + req["rejected"]
    _require(
        req["submitted"] == finished + in_flight,
        f"request conservation: submitted({req['submitted']:g}) != "
        f"completed({req['completed']:g}) + failed({req['failed']:g}) + "
        f"rejected({req['rejected']:g}) + in_flight({in_flight:g})"
    )
    checked.append("request-conservation")

    hists = {
        phase: _hist(idx, "serve_request_latency_seconds", phase=phase)
        for phase in ("total", "queue", "execute")
    }
    if any(h is not None for h in hists.values()):
        for phase, h in hists.items():
            _require(
                h is not None,
                f"serve_request_latency_seconds{{phase={phase}}} missing "
                f"while other phases are present"
            )
        _require(
            int(hists["total"]["count"]) == int(req["completed"]),
            f"serve_request_latency_seconds{{phase=total}} count"
            f"({hists['total']['count']}) != serve_requests_total"
            f"{{event=completed}}({req['completed']:g})"
        )
        for phase in ("queue", "execute"):
            _require(
                int(hists[phase]["count"]) == int(hists["total"]["count"]),
                f"serve_request_latency_seconds{{phase={phase}}} count"
                f"({hists[phase]['count']}) != phase=total count"
                f"({hists['total']['count']})"
            )
        decomposed = float(hists["queue"]["sum"]) + float(
            hists["execute"]["sum"])
        _require(
            _close(decomposed, float(hists["total"]["sum"])),
            f"latency decomposition: queue+execute sum({decomposed:g}) != "
            f"total sum({float(hists['total']['sum']):g})"
        )
        checked.append("latency-decomposition")

    waves = _value(idx, "serve_waves_total")
    e = _value(idx, "serve_batch_elements")
    if waves and e:
        admitted = _value(idx, "serve_admitted_elements_total")
        pad = _value(idx, "serve_pad_elements_total", kind="wave")
        _require(
            waves * e == admitted + pad,
            f"wave elements: waves({waves:g}) x E({e:g}) != "
            f"admitted({admitted:g}) + pad({pad:g})"
        )
        checked.append("wave-elements")
        wave_hist = _hist(idx, "admission_wave_size_elements")
        if wave_hist is not None:
            _require(
                int(wave_hist["count"]) == int(waves),
                f"admission_wave_size_elements count({wave_hist['count']}) "
                f"!= serve_waves_total({waves:g})"
            )
            flushes = sum(
                float(m["value"])
                for m in _series(idx, "admission_flush_total")
            )
            _require(
                flushes == waves,
                f"admission_flush_total over reasons({flushes:g}) != "
                f"serve_waves_total({waves:g})"
            )
            checked.append("admission-accounting")
    return checked


def trace_counter_totals(trace: Dict[str, Any]) -> Dict[str, Dict[str, float]]:
    """Final cumulative counter totals from an exported Chrome trace
    document (its ``C`` events carry running totals; the last sample
    per counter name is the run's sum).  Delegates to the tracer side's
    :func:`repro.trace.attribution.chrome_counter_totals` -- one parser
    for the format both layers agreed on."""
    from ..trace.attribution import chrome_counter_totals  # lazy import

    return chrome_counter_totals(trace)


def check_trace_reconciliation(snap: Dict[str, Any],
                               trace: Dict[str, Any]) -> List[str]:
    """The snapshot's serve counters must agree exactly with the trace's
    cumulative counter totals from the same run."""
    idx = index_metrics(snap)
    if not _series(idx, "serve_requests_total"):
        return []
    totals = trace_counter_totals(trace)

    def t(counter: str, key: str) -> float:
        return totals.get(counter, {}).get(key, 0.0)

    pairs = [
        ("serve_pad_elements_total{kind=wave}",
         _value(idx, "serve_pad_elements_total", kind="wave"),
         "pad_elements[wave]", t("pad_elements", "wave")),
        ("serve_pad_elements_total{kind=plan}",
         _value(idx, "serve_pad_elements_total", kind="plan"),
         "pad_elements[pad]", t("pad_elements", "pad")),
        ("serve_waves_total", _value(idx, "serve_waves_total"),
         "serve_waves[waves]", t("serve_waves", "waves")),
    ]
    for event in ("submitted", "admitted", "completed", "failed", "rejected"):
        pairs.append((
            f"serve_requests_total{{event={event}}}",
            _value(idx, "serve_requests_total", event=event),
            f"serve_requests[{event}]", t("serve_requests", event),
        ))
    for m_ident, m_val, t_ident, t_val in pairs:
        _require(
            m_val == t_val,
            f"trace reconciliation: {m_ident}({m_val:g}) != "
            f"trace {t_ident}({t_val:g})"
        )
    return ["trace-reconciliation"]


def check_snapshot(snap: Dict[str, Any],
                   trace: Optional[Dict[str, Any]] = None) -> List[str]:
    """Run every applicable invariant; returns the list of checks that
    ran.  Raises :class:`MetricsError` naming the first failure."""
    checked = check_structure(snap)
    checked += check_serving(snap)
    if trace is not None:
        checked += check_trace_reconciliation(snap, trace)
    return checked


def diff_snapshots(a: Dict[str, Any], b: Dict[str, Any]) -> List[str]:
    """Human-readable per-series differences between two snapshots
    (counter/gauge value deltas, histogram count/sum deltas)."""
    ia, ib = index_metrics(a), index_metrics(b)
    lines: List[str] = []
    for key in sorted(set(ia) | set(ib)):
        name, labels = key
        ident = _ident(name, dict(labels))
        ma, mb = ia.get(key), ib.get(key)
        if ma is None:
            lines.append(f"+ {ident} (only in second)")
        elif mb is None:
            lines.append(f"- {ident} (only in first)")
        elif ma["type"] == "histogram":
            da = int(mb["count"]) - int(ma["count"])
            ds = float(mb["sum"]) - float(ma["sum"])
            if da or ds:
                lines.append(f"~ {ident}: count {ma['count']} -> "
                             f"{mb['count']} (+{da}), sum +{ds:g}")
        else:
            if float(ma["value"]) != float(mb["value"]):
                lines.append(
                    f"~ {ident}: {float(ma['value']):g} -> "
                    f"{float(mb['value']):g}"
                )
    return lines
