"""Always-on serving metrics: counters, gauges, histograms, one registry.

``repro.trace`` (PR 6) captures bounded, after-the-fact trace files; a
long-lived serving engine needs the complement -- *always-on* telemetry
it can report at any instant without ever filling a buffer.  This module
is that layer's core: three Prometheus-shaped primitives and a registry
that hands them out by (name, labels) identity.

Design points, in the same spirit as ``trace.Tracer``:

  * **Lock-cheap hot path.**  ``Counter.inc`` / ``Gauge.set`` /
    ``Histogram.observe`` are a handful of attribute ops under the GIL
    -- no locks, no allocation.  Only registry *creation* (get-or-create
    of a metric series) takes a lock, and instrumented code hoists that
    to init time.
  * **Falsy null object.**  :data:`NULL_REGISTRY` mirrors
    ``trace.NULL``: ``bool(NULL_REGISTRY)`` is False, every factory
    method returns one shared no-op metric, so disabled metering costs
    one truthiness check and allocates nothing per call site.
  * **Fixed log-spaced buckets.**  Histograms bucket into a fixed
    geometric ladder (:func:`log_buckets`), so exposition is O(buckets)
    regardless of observation count; exact quantiles come from a bounded
    recent window (the one quantile implementation in the codebase --
    ``runtime.RequestLatency`` delegates here).
"""
from __future__ import annotations

import bisect
import math
import re
import threading
from collections import deque
from typing import Any, Dict, Iterable, List, Optional, Tuple


class MetricsError(ValueError):
    """A metrics identity or invariant was violated (bad metric name,
    type conflict on re-registration, snapshot self-check failure)."""


_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")


def log_buckets(lo: float, hi: float, per_decade: int = 3) -> Tuple[float, ...]:
    """A fixed geometric bucket ladder: ``per_decade`` upper bounds per
    decade from ``lo`` up to (at least) ``hi``, inclusive."""
    if lo <= 0 or hi <= lo:
        raise MetricsError(f"need 0 < lo < hi, got lo={lo} hi={hi}")
    if per_decade < 1:
        raise MetricsError(f"per_decade must be >= 1, got {per_decade}")
    out: List[float] = []
    k = 0
    while True:
        b = lo * 10.0 ** (k / per_decade)
        # round to 3 significant figures: exposition-friendly bounds
        # (consecutive rungs differ >2x, so rounding cannot collide)
        b = float(f"{b:.2e}")
        out.append(b)
        if b >= hi:
            return tuple(out)
        k += 1


def linear_buckets(lo: float, hi: float, n: int) -> Tuple[float, ...]:
    """``n`` evenly spaced upper bounds covering ``(lo, hi]`` -- for
    bounded ratios where a log ladder wastes resolution."""
    if n < 1:
        raise MetricsError(f"n must be >= 1, got {n}")
    step = (hi - lo) / n
    return tuple(lo + step * (i + 1) for i in range(n))


#: default histogram ladder: 1 us .. 100 s, 3 buckets per decade --
#: wide enough for a dispatch tick and a cold compile alike
DEFAULT_TIME_BUCKETS = log_buckets(1e-6, 100.0, 3)


def _check_name(name: str) -> None:
    if not _NAME_RE.match(name or ""):
        raise MetricsError(f"invalid metric name {name!r}")


def _label_items(labels: Dict[str, Any]) -> Tuple[Tuple[str, str], ...]:
    for k in labels:
        if not _LABEL_RE.match(k) or k.startswith("__"):
            raise MetricsError(f"invalid label name {k!r}")
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


class Counter:
    """Monotonically increasing count.  ``inc`` with a negative amount
    is a :class:`MetricsError` -- use a :class:`Gauge` for levels."""

    __slots__ = ("name", "help", "labels", "value")
    kind = "counter"

    def __init__(self, name: str = "", help: str = "",
                 labels: Tuple[Tuple[str, str], ...] = ()) -> None:
        self.name = name
        self.help = help
        self.labels = labels
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise MetricsError(
                f"counter {self.name!r} cannot decrease (inc {n})"
            )
        self.value += n

    def data(self) -> Dict[str, Any]:
        return {"value": self.value}


class Gauge:
    """A level that goes up and down (queue depth, in-flight count)."""

    __slots__ = ("name", "help", "labels", "value")
    kind = "gauge"

    def __init__(self, name: str = "", help: str = "",
                 labels: Tuple[Tuple[str, str], ...] = ()) -> None:
        self.name = name
        self.help = help
        self.labels = labels
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def dec(self, n: float = 1.0) -> None:
        self.value -= n

    def data(self) -> Dict[str, Any]:
        return {"value": self.value}


class Histogram:
    """Fixed-bucket distribution with exact recent-window quantiles.

    ``buckets`` is an ascending tuple of upper bounds; one implicit
    ``+Inf`` overflow bucket closes the ladder.  ``observe`` is a bisect
    plus four attribute updates.  ``quantile`` is nearest-rank over the
    most recent ``window`` raw observations -- exact where it matters
    (a serving engine reports p95 over recent traffic, not its whole
    lifetime) and the codebase's single quantile implementation.
    """

    __slots__ = ("name", "help", "labels", "buckets", "bucket_counts",
                 "count", "sum", "min", "max", "_recent")
    kind = "histogram"

    def __init__(self, name: str = "", help: str = "",
                 labels: Tuple[Tuple[str, str], ...] = (),
                 buckets: Optional[Iterable[float]] = None,
                 window: int = 1024) -> None:
        bounds = tuple(buckets) if buckets is not None else DEFAULT_TIME_BUCKETS
        if list(bounds) != sorted(bounds) or len(set(bounds)) != len(bounds):
            raise MetricsError(
                f"histogram {name!r} buckets must be strictly ascending"
            )
        self.name = name
        self.help = help
        self.labels = labels
        self.buckets = bounds
        self.bucket_counts = [0] * (len(bounds) + 1)  # last bucket: +Inf
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._recent: deque = deque(maxlen=max(1, window))

    def observe(self, x: float) -> None:
        self.bucket_counts[bisect.bisect_left(self.buckets, x)] += 1
        self.count += 1
        self.sum += x
        if x < self.min:
            self.min = x
        if x > self.max:
            self.max = x
        self._recent.append(x)

    def quantile(self, q: float) -> float:
        """q-quantile (nearest-rank) over the recent window; 0 if empty."""
        if not self._recent:
            return 0.0
        xs = sorted(self._recent)
        return xs[min(len(xs) - 1, int(q * len(xs)))]

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def summary(self) -> Dict[str, float]:
        return {
            "count": float(self.count),
            "mean": self.mean,
            "min": self.min if self.count else 0.0,
            "max": self.max if self.count else 0.0,
            "p50": self.quantile(0.50),
            "p95": self.quantile(0.95),
        }

    def data(self) -> Dict[str, Any]:
        return {
            "count": self.count,
            "sum": self.sum,
            "min": self.min if self.count else 0.0,
            "max": self.max if self.count else 0.0,
            "buckets": [
                {"le": le, "count": c}
                for le, c in zip(self.buckets, self.bucket_counts)
            ] + [{"le": "+Inf", "count": self.bucket_counts[-1]}],
        }


class MetricsRegistry:
    """Get-or-create home for metric series, keyed (name, labels).

    Repeat registration with the same name and labels returns the same
    object (the instrumented layers each grab their series at init);
    re-registering a name as a different metric kind is a
    :class:`MetricsError` -- one name, one type, as in Prometheus.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._metrics: Dict[Tuple[str, Tuple[Tuple[str, str], ...]], Any] = {}
        self._kinds: Dict[str, str] = {}

    def __bool__(self) -> bool:
        return True

    def _get(self, cls, name: str, help: str, labels: Dict[str, Any],
             **kwargs) -> Any:
        _check_name(name)
        key = (name, _label_items(labels))
        with self._lock:
            m = self._metrics.get(key)
            if m is not None:
                if m.kind != cls.kind:
                    raise MetricsError(
                        f"metric {name!r} already registered as {m.kind}, "
                        f"requested {cls.kind}"
                    )
                return m
            prior = self._kinds.get(name)
            if prior is not None and prior != cls.kind:
                raise MetricsError(
                    f"metric {name!r} already registered as {prior}, "
                    f"requested {cls.kind}"
                )
            m = cls(name, help, key[1], **kwargs)
            self._metrics[key] = m
            self._kinds[name] = cls.kind
            return m

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        return self._get(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        return self._get(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",
                  buckets: Optional[Iterable[float]] = None,
                  window: int = 1024, **labels) -> Histogram:
        return self._get(Histogram, name, help, labels,
                         buckets=buckets, window=window)

    def collect(self) -> List[Any]:
        """Every live series, sorted by (name, labels) for stable output."""
        with self._lock:
            return [self._metrics[k] for k in sorted(self._metrics)]

    def snapshot(self) -> Dict[str, Any]:
        """The registry as one JSON-ready dict (`python -m repro.metrics`
        validates these; ``repro.metrics.check`` runs the invariants)."""
        return {
            "schema": "repro.metrics/v1",
            "metrics": [
                {
                    "name": m.name,
                    "type": m.kind,
                    "help": m.help,
                    "labels": dict(m.labels),
                    **m.data(),
                }
                for m in self.collect()
            ],
        }


class _NullMetric:
    """The one no-op metric behind :class:`NullRegistry`: accepts every
    mutator, reports zeros, allocates nothing per call site."""

    __slots__ = ()
    kind = "null"
    name = ""
    help = ""
    labels: Tuple[Tuple[str, str], ...] = ()
    value = 0.0
    count = 0
    sum = 0.0
    min = 0.0
    max = 0.0
    buckets: Tuple[float, ...] = ()

    def __bool__(self) -> bool:
        return False

    def inc(self, n: float = 1.0) -> None:
        pass

    def dec(self, n: float = 1.0) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, x: float) -> None:
        pass

    def quantile(self, q: float) -> float:
        return 0.0

    def summary(self) -> Dict[str, float]:
        return {}

    def data(self) -> Dict[str, Any]:
        return {}


_NULL_METRIC = _NullMetric()


class NullRegistry:
    """Falsy no-op registry, the ``trace.NullTracer`` of metrics.

    Every factory method returns the same shared :class:`_NullMetric`,
    so an unmetered hot path costs one truthiness check and zero
    allocations -- pass :data:`NULL_REGISTRY` (or nothing) wherever a
    ``metrics=`` parameter is accepted.
    """

    __slots__ = ()

    def __bool__(self) -> bool:
        return False

    def counter(self, name: str, help: str = "", **labels) -> _NullMetric:
        return _NULL_METRIC

    def gauge(self, name: str, help: str = "", **labels) -> _NullMetric:
        return _NULL_METRIC

    def histogram(self, name: str, help: str = "",
                  buckets: Optional[Iterable[float]] = None,
                  window: int = 1024, **labels) -> _NullMetric:
        return _NULL_METRIC

    def collect(self) -> List[Any]:
        return []

    def snapshot(self) -> Dict[str, Any]:
        return {"schema": "repro.metrics/v1", "metrics": []}


NULL_REGISTRY = NullRegistry()
