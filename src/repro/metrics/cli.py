"""``python -m repro.metrics``: validate, check, diff, pretty-print
metrics snapshots.

    python -m repro.metrics m.json                    # structural check
    python -m repro.metrics m.json --check            # + invariants
    python -m repro.metrics m.json --check --trace t.json   # + reconcile
    python -m repro.metrics m.json --diff other.json  # what changed
    python -m repro.metrics m.json --pretty           # human summary

Exit codes: 0 valid, 1 invariant/structure violation, 2 usage or
unreadable input -- the same contract as ``python -m repro.trace``, so
CI treats both artifacts alike.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List

from .check import (check_snapshot, check_structure, diff_snapshots,
                    index_metrics)
from .registry import MetricsError


def _load(path: str) -> Dict[str, Any]:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        print(f"error: cannot load {path}: {e}", file=sys.stderr)
        raise SystemExit(2)


def _pretty(snap: Dict[str, Any]) -> List[str]:
    lines: List[str] = []
    idx = index_metrics(snap)
    for (name, labels), m in sorted(idx.items()):
        tag = "".join(f" {k}={v}" for k, v in labels)
        if m["type"] == "histogram":
            count = int(m["count"])
            mean = float(m["sum"]) / count if count else 0.0
            lines.append(
                f"  {name}{tag}: count={count} sum={float(m['sum']):.6g} "
                f"mean={mean:.6g} min={float(m.get('min', 0)):.6g} "
                f"max={float(m.get('max', 0)):.6g}"
            )
        else:
            lines.append(f"  {name}{tag}: {float(m['value']):g}")
    if "slo" in snap:
        s = snap["slo"]
        lines.append(
            f"  slo: verdict={s.get('verdict')} p95={s.get('p95_s', 0):.6g}s "
            f"target={s.get('target_p95_s', 0):g}s "
            f"latency_burn={s.get('latency_burn', 0):.3g} "
            f"error_burn={s.get('error_burn', 0):.3g}"
        )
    return lines


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.metrics",
        description="Validate / check / diff repro metrics snapshots.",
    )
    ap.add_argument("snapshot", help="metrics snapshot JSON (--metrics out)")
    ap.add_argument("--check", action="store_true",
                    help="run the serving invariants, not just structure")
    ap.add_argument("--trace", metavar="TRACE.json",
                    help="reconcile serve counters against this Chrome "
                         "trace's cumulative counter totals")
    ap.add_argument("--diff", metavar="OTHER.json",
                    help="print per-series differences vs another snapshot")
    ap.add_argument("--pretty", action="store_true",
                    help="print a human-readable series summary")
    args = ap.parse_args(argv)

    snap = _load(args.snapshot)
    try:
        if args.check or args.trace:
            trace = _load(args.trace) if args.trace else None
            checked = check_snapshot(snap, trace)
        else:
            checked = check_structure(snap)
    except MetricsError as e:
        print(f"INVARIANT VIOLATION: {e}", file=sys.stderr)
        return 1

    n = len(snap.get("metrics", []))
    print(f"{args.snapshot}: {n} series ok "
          f"({', '.join(checked) if checked else 'no checks applicable'})")
    if args.pretty:
        for line in _pretty(snap):
            print(line)
    if args.diff:
        other = _load(args.diff)
        try:
            lines = diff_snapshots(snap, other)
        except MetricsError as e:
            print(f"INVARIANT VIOLATION: {e}", file=sys.stderr)
            return 1
        for line in lines:
            print(line)
        print(f"diff: {len(lines)} series changed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
