"""Exposition: the registry as Prometheus text format or snapshot JSON.

``export_prometheus`` emits the text exposition format (version 0.0.4):
one ``# HELP`` / ``# TYPE`` header per metric name, one sample line per
series, histogram series expanded into cumulative ``_bucket{le=...}``
plus ``_sum`` / ``_count``.  Label values are escaped per the spec
(backslash, double-quote, newline) and label names are emitted in
sorted order so output is byte-stable across runs -- both properties
are pinned by tests.

``write_snapshot`` is the JSON side: the registry's :meth:`snapshot`
dict (plus any extra top-level sections, e.g. an SLO verdict) to a
file, ready for ``python -m repro.metrics``.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional

from .registry import Histogram


def _escape_label(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _escape_help(v: str) -> str:
    return v.replace("\\", "\\\\").replace("\n", "\\n")


def _fmt(v: float) -> str:
    if v != v:
        return "NaN"
    if v == float("inf"):
        return "+Inf"
    if v == float("-inf"):
        return "-Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def _labels_text(items) -> str:
    if not items:
        return ""
    inner = ",".join(f'{k}="{_escape_label(v)}"' for k, v in items)
    return "{" + inner + "}"


def export_prometheus(registry) -> str:
    """The registry in Prometheus text exposition format."""
    lines: List[str] = []
    seen_header = set()
    for m in registry.collect():
        if m.name not in seen_header:
            seen_header.add(m.name)
            if m.help:
                lines.append(f"# HELP {m.name} {_escape_help(m.help)}")
            lines.append(f"# TYPE {m.name} {m.kind}")
        if isinstance(m, Histogram):
            cum = 0
            for le, c in zip(m.buckets, m.bucket_counts):
                cum += c
                items = m.labels + (("le", _fmt(le)),)
                lines.append(f"{m.name}_bucket{_labels_text(items)} {cum}")
            cum += m.bucket_counts[-1]
            items = m.labels + (("le", "+Inf"),)
            lines.append(f"{m.name}_bucket{_labels_text(items)} {cum}")
            lines.append(f"{m.name}_sum{_labels_text(m.labels)} {_fmt(m.sum)}")
            lines.append(f"{m.name}_count{_labels_text(m.labels)} {m.count}")
        else:
            lines.append(f"{m.name}{_labels_text(m.labels)} {_fmt(m.value)}")
    return "\n".join(lines) + ("\n" if lines else "")


def write_snapshot(registry, path: str,
                   extra: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Write the registry snapshot (plus ``extra`` top-level sections,
    e.g. ``{"slo": tracker.verdict()}``) as JSON; returns the dict."""
    snap = registry.snapshot()
    if extra:
        for k, v in extra.items():
            if k in snap:
                raise ValueError(f"extra section {k!r} collides with snapshot")
            snap[k] = v
    with open(path, "w") as f:
        json.dump(snap, f, indent=1, sort_keys=True)
        f.write("\n")
    return snap
