"""Sharded AdamW (built in-repo: no optax in the container).

Optimizer state mirrors parameter sharding (first/second moments inherit
each param's PartitionSpec through GSPMD propagation), i.e. the memory
behaves like ZeRO along the TP axis for sharded params.  Moments are kept
in f32 regardless of param dtype (mixed-precision master-moment style).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def cosine_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, cfg.warmup_steps)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        1.0, cfg.total_steps - cfg.warmup_steps
    )
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog)
    )
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params: Any) -> Any:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def adamw_update(
    cfg: AdamWConfig,
    grads: Any,
    opt_state: Any,
    params: Any,
) -> Tuple[Any, Any, dict]:
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    lr = cosine_schedule(cfg, step)
    b1t = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2t = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * clip
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mhat = mu / b1t
        nhat = nu / b2t
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        wd = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        new_p = p.astype(jnp.float32) - lr * (delta + wd)
        return new_p.astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(opt_state["mu"])
    flat_nu = treedef.flatten_up_to(opt_state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_state = {
        "mu": treedef.unflatten([o[1] for o in out]),
        "nu": treedef.unflatten([o[2] for o in out]),
        "step": step,
    }
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics
