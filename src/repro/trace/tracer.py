"""Span/counter tracer: the low-overhead event recorder the whole
execution stack threads through.

The executor is judged by how close it gets to the plan's roofline, so
the recorder mirrors the planner's vocabulary: nested **spans** (chain
run -> stage -> batch-slot dispatch/compute/handoff) carry explicit
begin/end timestamps from an injectable clock, and monotone **counters**
(bytes per pseudo-channel, pad elements, CU-group occupancy) accumulate
the deterministic quantities the plan predicts -- so a trace can be
diffed against a :class:`~repro.memory.chain.ChainPlan` term by term
(``repro.trace.attribution``).

Spans live on integer *tracks* (one per pipeline stage plus track 0 for
the host side); within a track they must nest strictly -- :meth:`end`
enforces LIFO order, so a malformed instrumentation site fails loudly at
record time instead of producing an unreadable trace.

When tracing is off, callers hold the module-level :data:`NULL`
:class:`NullTracer` (or plain ``None``): it is falsy, so the hot loops
guard every instrumentation site with ``if tracer:`` and a disabled run
pays one truthiness check per site -- no allocation, no clock read.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, List, Optional

#: Host-side track: staging, retire syncs, and the root run span.
HOST_TRACK = 0


@dataclasses.dataclass
class SpanEvent:
    """One closed (or still-open) span.  ``t1 < 0`` means still open."""

    name: str
    cat: str
    track: int
    t0: float
    t1: float = -1.0
    args: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def duration(self) -> float:
        return max(0.0, self.t1 - self.t0) if self.t1 >= 0 else 0.0

    @property
    def open(self) -> bool:
        return self.t1 < 0


@dataclasses.dataclass(frozen=True)
class CounterEvent:
    """One counter sample: the *cumulative* series values at ``t``."""

    name: str
    track: int
    t: float
    values: Dict[str, float]


class TraceError(RuntimeError):
    """Malformed instrumentation: spans ended out of order / never begun."""


class NullTracer:
    """The disabled tracer: falsy, every method a no-op.

    Executors write ``if tracer: tracer.begin(...)`` so a disabled run
    never allocates an event or reads the clock; passing :data:`NULL`
    (or ``None``) is equivalent everywhere.
    """

    __slots__ = ()

    def __bool__(self) -> bool:
        return False

    def name_track(self, track: int, name: str) -> None:
        pass

    def begin(self, name: str, cat: str = "", track: int = 0,
              **args: Any) -> None:
        return None

    def end(self, span: Any = None) -> None:
        pass

    @contextlib.contextmanager
    def span(self, name: str, cat: str = "", track: int = 0,
             **args: Any) -> Iterator[None]:
        yield None

    def bump(self, name: str, values: Dict[str, float],
             track: int = 0) -> None:
        pass

    def totals(self, name: str) -> Dict[str, float]:
        return {}


#: Shared disabled-tracer instance (``tracer or NULL`` normalizes None).
NULL = NullTracer()


class Tracer:
    """Records nested spans and cumulative counters with explicit
    timestamps from ``clock`` (injectable so tests are deterministic).

    One tracer records one run; it is not thread-safe -- the executors it
    instruments are single-threaded host loops (JAX's async dispatch
    happens behind the runtime's own threads, which the spans deliberately
    do *not* enter: a span measures the host-side cost of a dispatch or
    sync, the quantity the plan's host/fill terms predict).
    """

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self.clock = clock
        self.spans: List[SpanEvent] = []
        self.counters: List[CounterEvent] = []
        self.track_names: Dict[int, str] = {}
        self.meta: Dict[str, Any] = {}
        self._stacks: Dict[int, List[SpanEvent]] = {}
        self._totals: Dict[str, Dict[str, float]] = {}

    def __bool__(self) -> bool:
        return True

    # -- spans --------------------------------------------------------------
    def name_track(self, track: int, name: str) -> None:
        """Label a track (rendered as the thread name in Perfetto)."""
        self.track_names[track] = name

    def begin(self, name: str, cat: str = "", track: int = 0,
              **args: Any) -> SpanEvent:
        sp = SpanEvent(name=name, cat=cat, track=track, t0=self.clock(),
                       args=dict(args))
        self.spans.append(sp)
        self._stacks.setdefault(track, []).append(sp)
        return sp

    def end(self, span: SpanEvent) -> None:
        """Close ``span``; must be the innermost open span of its track
        (strict nesting is enforced at record time)."""
        stack = self._stacks.get(span.track, [])
        if not stack or stack[-1] is not span:
            raise TraceError(
                f"span {span.name!r} ended out of order on track "
                f"{span.track} (open: {[s.name for s in stack]})"
            )
        stack.pop()
        span.t1 = self.clock()

    @contextlib.contextmanager
    def span(self, name: str, cat: str = "", track: int = 0,
             **args: Any) -> Iterator[SpanEvent]:
        sp = self.begin(name, cat, track, **args)
        try:
            yield sp
        finally:
            self.end(sp)

    def open_spans(self) -> List[SpanEvent]:
        return [s for st in self._stacks.values() for s in st]

    # -- counters -----------------------------------------------------------
    def bump(self, name: str, values: Dict[str, float],
             track: int = 0) -> None:
        """Add ``values`` to the counter's running totals and record a
        cumulative sample (monotone counters render as rate tracks in
        Perfetto; :meth:`totals` gives the end-of-run sums)."""
        tot = self._totals.setdefault(name, {})
        for k, v in values.items():
            tot[str(k)] = tot.get(str(k), 0) + v
        self.counters.append(
            CounterEvent(name=name, track=track, t=self.clock(),
                         values=dict(tot))
        )

    def totals(self, name: str) -> Dict[str, float]:
        """End-of-run cumulative totals for one counter series."""
        return dict(self._totals.get(name, {}))

    # -- queries ------------------------------------------------------------
    def spans_by(self, *, cat: Optional[str] = None,
                 track: Optional[int] = None) -> List[SpanEvent]:
        return [
            s for s in self.spans
            if (cat is None or s.cat == cat)
            and (track is None or s.track == track)
        ]

    @property
    def t_start(self) -> float:
        ts = [s.t0 for s in self.spans] + [c.t for c in self.counters]
        return min(ts) if ts else 0.0

    @property
    def t_end(self) -> float:
        ts = [s.t1 for s in self.spans if s.t1 >= 0]
        ts += [c.t for c in self.counters]
        return max(ts) if ts else 0.0
