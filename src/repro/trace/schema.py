"""Trace-schema validation: the checks CI runs on every emitted trace
and the helpers the tests assert with.

A valid trace document is Chrome-trace JSON whose duration events nest
strictly within each (pid, tid) track: for any two events on one track,
their time intervals are either disjoint or one contains the other --
never partially overlapping.  Counter events must carry numeric series.
These are exactly the invariants ``repro.trace.attribution`` relies on
when it sums per-stage span time against the plan.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Tuple

#: Interval slack in us: guards float round-off from the s -> us scaling,
#: far below any real span duration.
_EPS_US = 1e-3


def validate(doc: Any) -> List[str]:
    """Validate a Chrome-trace document; returns a list of problems
    (empty = valid)."""
    errors: List[str] = []
    if not isinstance(doc, dict):
        return [f"trace document must be a JSON object, got {type(doc).__name__}"]
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    if not events:
        errors.append("traceEvents is empty")

    durations: Dict[Tuple[Any, Any], List[Tuple[float, float, str]]] = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errors.append(f"event {i} is not an object")
            continue
        ph = ev.get("ph")
        if ph not in ("X", "C", "M", "B", "E", "i", "I"):
            errors.append(f"event {i}: unknown phase {ph!r}")
            continue
        if ph == "M":
            continue
        if "pid" not in ev or "tid" not in ev:
            errors.append(f"event {i} ({ev.get('name')!r}): missing pid/tid")
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < -_EPS_US:
            errors.append(
                f"event {i} ({ev.get('name')!r}): bad ts {ts!r}"
            )
            continue
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(
                    f"event {i} ({ev.get('name')!r}): bad dur {dur!r}"
                )
                continue
            if not ev.get("name"):
                errors.append(f"event {i}: X event without a name")
            durations.setdefault((ev["pid"], ev["tid"]), []).append(
                (float(ts), float(ts) + float(dur), str(ev.get("name")))
            )
        elif ph == "C":
            args = ev.get("args")
            if not isinstance(args, dict) or not args:
                errors.append(
                    f"event {i} ({ev.get('name')!r}): counter without "
                    "series args"
                )
            elif not all(
                isinstance(v, (int, float)) for v in args.values()
            ):
                errors.append(
                    f"event {i} ({ev.get('name')!r}): non-numeric "
                    "counter series"
                )

    for (pid, tid), ivals in durations.items():
        errors.extend(_check_nesting(pid, tid, ivals))
    return errors


def _check_nesting(
    pid: Any, tid: Any, ivals: List[Tuple[float, float, str]]
) -> List[str]:
    """Intervals on one track must strictly nest (no partial overlap).

    Sweep in start order (longer spans first on ties, so a parent is
    visited before children that start at the same timestamp); a stack
    of enclosing intervals catches any child poking past its parent.
    """
    errors: List[str] = []
    stack: List[Tuple[float, float, str]] = []
    for t0, t1, name in sorted(ivals, key=lambda iv: (iv[0], -iv[1])):
        while stack and stack[-1][1] <= t0 + _EPS_US:
            stack.pop()
        if stack and t1 > stack[-1][1] + _EPS_US:
            errors.append(
                f"track ({pid},{tid}): span {name!r} "
                f"[{t0:.3f},{t1:.3f}]us partially overlaps "
                f"{stack[-1][2]!r} [{stack[-1][0]:.3f},{stack[-1][1]:.3f}]us"
            )
            continue
        stack.append((t0, t1, name))
    return errors


def validate_file(path: str) -> List[str]:
    """Load + validate a trace JSON file (parse errors are reported,
    not raised)."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"cannot load {path}: {e}"]
    return validate(doc)


def assert_valid(doc_or_tracer: Any) -> None:
    """Raise AssertionError listing every schema violation (test helper;
    accepts a Tracer, a trace dict, or a path)."""
    from .chrome import to_chrome
    from .tracer import Tracer

    if isinstance(doc_or_tracer, Tracer):
        errors = validate(to_chrome(doc_or_tracer))
    elif isinstance(doc_or_tracer, str):
        errors = validate_file(doc_or_tracer)
    else:
        errors = validate(doc_or_tracer)
    assert not errors, "invalid trace:\n" + "\n".join(errors)
