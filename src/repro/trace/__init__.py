"""repro.trace: span-level execution tracing with pred-vs-measured
attribution and a persistent per-machine profile store.

The observability layer of the tool flow: a :class:`Tracer` threads
through ``pipeline.run_pipelined`` / ``run_stage_pipelined``,
``simulation.run_chain`` and ``flow.CompiledSystem.run()``; the recorded
spans/counters export to Chrome-trace JSON (:func:`write_chrome`, view
in Perfetto), fold against the plan's cost model
(:func:`attribution_report`), and feed the on-disk
:class:`ProfileStore` that ``explore_chain(profile=...)`` ranks with.

This package never imports ``repro.memory`` at module level -- the
executors it instruments depend on staying import-light.
"""
from .attribution import (Attribution, StageAttribution, attribute,
                          attribution_report, chrome_counter_totals,
                          host_channel_bytes, samples_from_trace)
from .chrome import to_chrome, write_chrome
from .profile import (PROFILE_ENV, ProfileStore, default_profile_path,
                      machine_fingerprint)
from .schema import assert_valid, validate, validate_file
from .tracer import (HOST_TRACK, NULL, CounterEvent, NullTracer, SpanEvent,
                     TraceError, Tracer)

__all__ = [
    "Attribution",
    "CounterEvent",
    "HOST_TRACK",
    "NULL",
    "NullTracer",
    "PROFILE_ENV",
    "ProfileStore",
    "SpanEvent",
    "StageAttribution",
    "TraceError",
    "Tracer",
    "assert_valid",
    "attribute",
    "attribution_report",
    "chrome_counter_totals",
    "default_profile_path",
    "host_channel_bytes",
    "machine_fingerprint",
    "samples_from_trace",
    "to_chrome",
    "validate",
    "validate_file",
    "write_chrome",
]
