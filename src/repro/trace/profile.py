"""Persistent per-machine profile store: traced runs become planner
feedback that survives the process.

Every traced ladder/benchmark run deposits (predicted, measured,
bottleneck) samples keyed by ``(machine fingerprint, target name, plan
signature)``.  ``explore_chain(profile=...)`` later asks the store for a
:class:`~repro.memory.dse.CostCorrection` refit from this machine's
samples -- exact plan signature first, target-wide fallback -- so DSE
ranking starts from learned per-term factors instead of cold.

The store is one JSON file, ``~/.cache/repro/profile.json`` by default,
overridable with the ``REPRO_PROFILE`` environment variable (point it at
a scratch path in tests/CI).  Writes are atomic (tmp + rename) and the
per-key sample history is FIFO-bounded, so concurrent benchmark runs
cannot corrupt it or grow it without bound.

Staleness is bounded by a *code epoch*, not just the FIFO: every sample
is stamped with :func:`cost_model_epoch` (the planner's
``COST_MODEL_VERSION``) at record time, queries and ``correction()``
refits only see current-epoch samples, and recording prunes the rest --
so bumping the cost model orphans all pre-bump feedback instead of
letting it steer the new model.  Store files written before epochs
existed load fine; their unstamped samples are simply ignored.

The epoch is a declared version, and planner edits rarely remember to
bump it -- so samples are *also* stamped with :func:`plan_code_digest`,
a digest of the planner's own source (``memory.chain`` / ``memory.dse``
/ ``memory.pipeline``).  When the plan *code* changes under an
unchanged ``COST_MODEL_VERSION``, queries stop surfacing the old
samples and recording prunes them.  Samples without a ``src`` stamp
(older store files) are tolerated: the digest gates code drift, it does
not orphan history that predates the stamp.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, List, Optional, Union

from .attribution import samples_from_trace
from .tracer import Tracer

#: Environment variable overriding the store path.
PROFILE_ENV = "REPRO_PROFILE"
#: Samples kept per (fingerprint, target, signature) key (FIFO).
MAX_SAMPLES_PER_KEY = 200
_VERSION = 1


def cost_model_epoch() -> str:
    """The epoch tag stamped on recorded samples: the planner's
    ``COST_MODEL_VERSION``.  A sample only means "the model was off by
    r on this machine" for the model that predicted it."""
    try:
        from ..memory.dse import COST_MODEL_VERSION  # lazy: no cycle
    except Exception:  # pragma: no cover - partial installs
        return "v0"
    return f"v{COST_MODEL_VERSION}"


_PLAN_CODE_DIGEST: Optional[str] = None


def plan_code_digest() -> str:
    """Digest of the planner's own source code (``memory.chain``,
    ``memory.dse``, ``memory.pipeline``), cached per process.  A sample
    calibrates the model *as coded*: when the planner changes without a
    ``COST_MODEL_VERSION`` bump, this digest changes and the old
    feedback ages out anyway."""
    global _PLAN_CODE_DIGEST
    if _PLAN_CODE_DIGEST is None:
        import hashlib
        import inspect

        try:
            from ..memory import chain, dse, pipeline  # lazy: no cycle

            blob = "\n".join(
                inspect.getsource(m) for m in (chain, dse, pipeline)
            )
            _PLAN_CODE_DIGEST = hashlib.sha1(
                blob.encode()
            ).hexdigest()[:12]
        except Exception:  # pragma: no cover - partial installs
            _PLAN_CODE_DIGEST = "src0"
    return _PLAN_CODE_DIGEST


def default_profile_path() -> str:
    env = os.environ.get(PROFILE_ENV)
    if env:
        return env
    return os.path.join(
        os.path.expanduser("~"), ".cache", "repro", "profile.json"
    )


def machine_fingerprint() -> str:
    """Short stable id of *this* machine + runtime: learned factors are
    only valid where they were measured."""
    import hashlib
    import platform

    parts = [
        platform.system(), platform.machine(), platform.node(),
        str(os.cpu_count() or 0),
    ]
    try:  # the backend changes what "measured" means as much as the host
        import jax

        parts += [jax.default_backend(), str(len(jax.devices()))]
    except Exception:
        parts.append("nojax")
    return hashlib.sha1("|".join(parts).encode()).hexdigest()[:12]


class ProfileStore:
    """On-disk (predicted, measured) sample archive + correction refit.

    Samples are dicts with at least ``predicted_s``, ``measured_s`` and
    ``bottleneck`` (a ``CostBreakdown.bottleneck`` label); ``scope``
    says what was measured (``chain``, ``stage:<name>``, ``bench:<rung>``).
    """

    def __init__(self, path: Optional[str] = None,
                 fingerprint: Optional[str] = None,
                 epoch: Optional[str] = None,
                 src: Optional[str] = None):
        self.path = path or default_profile_path()
        self.fingerprint = fingerprint or machine_fingerprint()
        #: samples are stamped with this at record time and only
        #: same-epoch samples feed queries/refits (tests override it to
        #: simulate a cost-model bump)
        self.epoch = epoch or cost_model_epoch()
        #: the planner-source digest stamped alongside the epoch;
        #: samples carrying a *different* digest are stale even when the
        #: declared epoch never moved (tests override it to simulate a
        #: silent planner edit)
        self.src = src or plan_code_digest()
        self.data: Dict[str, Any] = {"version": _VERSION, "entries": {}}
        self._load()

    @classmethod
    def open(cls, profile: Union["ProfileStore", str, bool, None]
             ) -> Optional["ProfileStore"]:
        """Normalize ``explore_chain(profile=...)``'s argument: a store,
        a path, or ``True`` for the default location."""
        if profile is None or profile is False:
            return None
        if isinstance(profile, ProfileStore):
            return profile
        if profile is True:
            return cls()
        return cls(path=str(profile))

    # -- persistence --------------------------------------------------------
    def _load(self) -> None:
        try:
            with open(self.path) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError):
            return
        if isinstance(doc, dict) and isinstance(doc.get("entries"), dict):
            self.data = {"version": _VERSION, "entries": doc["entries"]}

    def save(self) -> None:
        """Atomic write: a crashed benchmark never leaves a torn file."""
        d = os.path.dirname(self.path) or "."
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(self.data, f, indent=1, sort_keys=True)
                f.write("\n")
            os.replace(tmp, self.path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    # -- recording ----------------------------------------------------------
    def _key(self, target_name: str, signature: str) -> str:
        return f"{self.fingerprint}/{target_name}/{signature}"

    def record(self, target_name: str, signature: str,
               samples: List[Dict[str, Any]], *, save: bool = True) -> int:
        """Append samples under (this machine, target, signature),
        stamped with the current code epoch and planner-source digest;
        FIFO-bounded.  Samples already in the bucket that carry a stale
        epoch or a mismatched source digest are pruned on the way (the
        file shrinks back as post-change feedback arrives).  Returns how
        many were accepted."""
        good = [
            dict(s, epoch=self.epoch, src=self.src) for s in samples
            if isinstance(s.get("predicted_s"), (int, float))
            and isinstance(s.get("measured_s"), (int, float))
            and s["predicted_s"] > 0 and s["measured_s"] > 0
        ]
        if not good:
            return 0
        entries = self.data["entries"]
        key = self._key(target_name, signature)
        bucket = [
            s for s in entries.get(key, ())
            if isinstance(s, dict) and s.get("epoch") == self.epoch
            and s.get("src", self.src) == self.src
        ]
        entries[key] = bucket
        bucket.extend(good)
        del bucket[:-MAX_SAMPLES_PER_KEY]
        if save:
            self.save()
        return len(good)

    def record_trace(self, tracer: Tracer, plan, *,
                     save: bool = True) -> int:
        """Refit fodder from one traced chain run: per-stage and chain-
        level (predicted, measured) pairs via ``attribution``."""
        return self.record(
            plan.target.name, plan.signature,
            samples_from_trace(tracer, plan), save=save,
        )

    def record_measurement(self, plan, predicted_s: float,
                           measured_s: float, *, scope: str = "bench",
                           save: bool = True) -> int:
        """One measured run without a trace (the benchmark ladders)."""
        return self.record(
            plan.target.name, plan.signature,
            [{
                "scope": scope,
                "predicted_s": float(predicted_s),
                "measured_s": float(measured_s),
                "bottleneck": plan.cost.bottleneck,
            }],
            save=save,
        )

    # -- queries ------------------------------------------------------------
    def samples(self, target_name: str,
                signature: Optional[str] = None) -> List[Dict[str, Any]]:
        """This machine's *current-epoch* samples for a target: exact
        signature when it has history, otherwise everything recorded for
        the target (a new plan still benefits from the machine's overall
        bias).  Samples stamped with another epoch -- or none, from a
        store file predating epochs -- never surface, and neither do
        samples whose planner-source digest no longer matches the code
        that is running: the correction refit must not be steered by an
        obsolete cost model."""

        def live(v) -> List[Dict[str, Any]]:
            return [
                s for s in v
                if isinstance(s, dict) and s.get("epoch") == self.epoch
                and s.get("src", self.src) == self.src
            ]

        entries = self.data["entries"]
        if signature is not None:
            exact = live(entries.get(self._key(target_name, signature), ()))
            if exact:
                return exact
        prefix = f"{self.fingerprint}/{target_name}/"
        out: List[Dict[str, Any]] = []
        for k, v in sorted(entries.items()):
            if k.startswith(prefix) and isinstance(v, list):
                out.extend(live(v))
        return out

    def correction(self, target_name: str,
                   signature: Optional[str] = None):
        """Refit a :class:`~repro.memory.dse.CostCorrection` from the
        stored samples (identity correction when the store is cold)."""
        import math

        from ..memory.dse import CostCorrection  # lazy: no import cycle

        ratios: List[float] = []
        by_term: Dict[str, List[float]] = {}
        for s in self.samples(target_name, signature):
            r = s["measured_s"] / s["predicted_s"]
            ratios.append(r)
            by_term.setdefault(str(s.get("bottleneck", "")), []).append(r)
        if not ratios:
            return CostCorrection()

        def geo(rs: Optional[List[float]]) -> Optional[float]:
            if not rs:
                return None
            return math.exp(sum(math.log(r) for r in rs) / len(rs))

        return CostCorrection(
            factor=geo(ratios) or 1.0, n_samples=len(ratios),
            host_factor=geo(by_term.get("host-link")),
            hbm_factor=geo(by_term.get("hbm")),
            compute_factor=geo(by_term.get("compute")),
        )

    def __len__(self) -> int:
        return sum(len(v) for v in self.data["entries"].values())
