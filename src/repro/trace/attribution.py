"""Predicted-vs-measured attribution: overlay a traced run against its
plan's :class:`~repro.memory.chain.ChainCost`.

The planner predicts a per-batch time from three device terms plus
pipeline fill; the trace records what the executor actually spent, span
by span.  :func:`attribute` folds the two together per stage --
``sum(dispatch spans)`` against the stage's predicted steady-state time
-- and names the measured bottleneck in the planner's own vocabulary
(``host`` / ``hbm`` / ``compute`` / ``fill-drain``), so a 5x
pred-vs-measured gap stops being one opaque ratio and becomes "stage
helmholtz is 4.1x slower than its compute term, everything else is on
model".  :func:`attribution_report` renders the ``measured:`` section
appended to the Fig.-14-style plan report; ``stable_only=True`` keeps
only deterministic fields (structure, predictions, counter sums) so the
section can be golden-tested.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

from .tracer import HOST_TRACK, Tracer

# -- the span vocabulary the executors emit ---------------------------------
CAT_RUN = "run"            # root span, host track
CAT_STAGE = "stage"        # per-stage umbrella span, track 1+i
CAT_SLOT = "slot"          # one (stage, batch) dispatch slot
CAT_DISPATCH = "dispatch"  # the stage-fn call inside a slot
CAT_HANDOFF = "handoff"    # cross-group reshard inside a slot
CAT_STAGE_HOST = "stage-host"  # host-side staging of one batch
CAT_SYNC = "sync"          # host sync (device_get) retiring a batch
CAT_REQUEST = "request"    # serve-layer per-request span, submit->finish

#: Counter names (``Tracer.bump`` series).
COUNTER_CHANNEL_BYTES = "channel_bytes"
COUNTER_PAD_ELEMENTS = "pad_elements"
COUNTER_OCCUPANCY = "cu_occupancy"
#: Serving-layer series (``repro.serve``).  All cumulative, like every
#: counter here: queue depth at time t is submitted - admitted, plan-
#: cache hit rate is hit / (hit + miss).
COUNTER_PLAN_CACHE = "plan_cache"        # keys: hit / miss
COUNTER_SERVE_REQUESTS = "serve_requests"  # submitted/admitted/completed/
                                           # failed/rejected
COUNTER_SERVE_WAVES = "serve_waves"      # coalesced waves admitted


def host_channel_bytes(buffers) -> Dict[int, int]:
    """Per-pseudo-channel host-streamed bytes for one batch, from the
    plan's buffer table.  Integer remainders land on a buffer's first
    channels, so the values sum *exactly* to ``host_stream_bytes`` --
    the invariant the schema tests pin."""
    out: Dict[int, int] = {}
    for b in buffers:
        if b.role not in ("in", "out") or not b.channels:
            continue
        n = len(b.channels)
        base, rem = divmod(b.batch_bytes, n)
        for j, ch in enumerate(b.channels):
            out[ch] = out.get(ch, 0) + base + (1 if j < rem else 0)
    return out


@dataclasses.dataclass
class StageAttribution:
    """One stage's predicted-vs-measured ledger."""

    index: int
    name: str
    slots: int                  # batches this stage dispatched
    fill_slots: int             # of those, in the fill/drain window
    measured_s: float           # sum of the stage's dispatch spans
    handoff_s: float            # sum of its cross-group reshard spans
    pred_s_per_batch: float
    pred_bottleneck: str

    @property
    def measured_s_per_batch(self) -> float:
        return self.measured_s / self.slots if self.slots else 0.0

    @property
    def ratio(self) -> float:
        """measured / predicted per batch (1.0 = the model was right)."""
        if self.pred_s_per_batch <= 0 or not self.slots:
            return 0.0
        return self.measured_s_per_batch / self.pred_s_per_batch


@dataclasses.dataclass
class Attribution:
    """A whole traced run folded against its plan."""

    wall_s: float
    n_batches: int
    pred_s_per_batch: float
    host_s: float               # staging + retire syncs on the host track
    fill_s: float               # slot time inside the fill/drain window
    stages: List[StageAttribution]
    #: end-of-run counter totals (str channel id -> bytes)
    channel_bytes: Dict[str, float]
    pad_elements: float = 0.0
    straggler_batches: Tuple[int, ...] = ()

    @property
    def measured_s_per_batch(self) -> float:
        return self.wall_s / self.n_batches if self.n_batches else 0.0

    @property
    def ratio(self) -> float:
        if self.pred_s_per_batch <= 0 or not self.n_batches:
            return 0.0
        return self.measured_s_per_batch / self.pred_s_per_batch

    @property
    def bottleneck(self) -> str:
        """Where the measured time actually went: the slowest stage's
        device term, the host side, or pipeline fill/drain."""
        terms: List[Tuple[float, str]] = [
            (self.host_s, "host"),
            (self.fill_s, "fill-drain"),
        ]
        for s in self.stages:
            term = s.pred_bottleneck
            label = "host" if term == "host-link" else term
            terms.append((s.measured_s, f"{s.name}:{label}"))
        return max(terms, key=lambda kv: kv[0])[1] if terms else ""


def attribute(tracer: Tracer, plan) -> Attribution:
    """Fold a traced chain run against its ChainPlan.

    ``plan`` is a :class:`~repro.memory.chain.ChainPlan`; the tracer must
    hold the spans ``repro.memory.pipeline.run_stage_pipelined`` emits
    (slot spans carrying ``stage``/``batch``/``tick`` args).
    """
    slots = [s for s in tracer.spans if s.cat == CAT_SLOT and not s.open]
    dispatch = [
        s for s in tracer.spans if s.cat == CAT_DISPATCH and not s.open
    ]
    handoff = [
        s for s in tracer.spans if s.cat == CAT_HANDOFF and not s.open
    ]
    n_batches = 1 + max(
        (int(s.args.get("batch", 0)) for s in slots), default=-1
    )
    max_skew = 0
    pipe = getattr(plan, "pipeline", None)
    if pipe is not None:
        max_skew = pipe.stage_skews[-1]

    def in_fill(span) -> bool:
        t = int(span.args.get("tick", 0))
        return t < max_skew or t >= n_batches

    cost = plan.cost
    pred_stage = (
        list(cost.stage_steady_times) if cost.pipelined_stages
        else [c.t_pipelined for c in cost.stages]
    )
    stages: List[StageAttribution] = []
    for i, sp in enumerate(plan.stages):
        my_slots = [s for s in slots if int(s.args.get("stage", -1)) == i]
        my_disp = [s for s in dispatch if int(s.args.get("stage", -1)) == i]
        my_hand = [s for s in handoff if int(s.args.get("stage", -1)) == i]
        stages.append(StageAttribution(
            index=i, name=sp.name, slots=len(my_slots),
            fill_slots=sum(1 for s in my_slots if in_fill(s)),
            measured_s=sum(s.duration for s in my_disp),
            handoff_s=sum(s.duration for s in my_hand),
            pred_s_per_batch=pred_stage[i] if i < len(pred_stage) else 0.0,
            pred_bottleneck=sp.cost.bottleneck,
        ))

    host_s = sum(
        s.duration for s in tracer.spans
        if s.cat in (CAT_STAGE_HOST, CAT_SYNC) and not s.open
    )
    fill_s = sum(s.duration for s in slots if in_fill(s))
    runs = [s for s in tracer.spans if s.cat == CAT_RUN and not s.open]
    wall = (
        sum(s.duration for s in runs) if runs
        else max(0.0, tracer.t_end - tracer.t_start)
    )
    stragglers = tuple(sorted(
        int(s.args["batch"]) for s in tracer.spans
        if s.cat == CAT_SYNC and s.args.get("straggler")
        and "batch" in s.args
    ))
    return Attribution(
        wall_s=wall, n_batches=n_batches,
        pred_s_per_batch=cost.t_pipelined,
        host_s=host_s, fill_s=fill_s, stages=stages,
        channel_bytes=tracer.totals(COUNTER_CHANNEL_BYTES),
        pad_elements=sum(
            tracer.totals(COUNTER_PAD_ELEMENTS).values()
        ),
        straggler_batches=stragglers,
    )


def attribution_report(
    tracer: Tracer, plan, *, stable_only: bool = False
) -> str:
    """Render the ``measured:`` section for a traced run of ``plan``.

    ``stable_only=True`` drops every timing-derived field (wall times,
    ratios, bottleneck attribution) and keeps the deterministic ones --
    structure, predictions, counter sums -- for golden tests."""
    a = attribute(tracer, plan)
    ms = lambda s: f"{s * 1e3:.3f}"
    lines: List[str] = []
    if stable_only:
        lines.append(
            f"measured: {a.n_batches} batches traced   "
            f"predicted {ms(a.pred_s_per_batch)} ms/batch"
        )
    else:
        lines.append(
            f"measured: {a.n_batches} batches traced   wall "
            f"{ms(a.wall_s)} ms ({ms(a.measured_s_per_batch)} ms/batch)   "
            f"predicted {ms(a.pred_s_per_batch)} ms/batch   "
            f"[x{a.ratio:.2f}]"
        )
        lines.append(
            f"  attribution: {a.bottleneck}   host {ms(a.host_s)} ms   "
            f"fill/drain {ms(a.fill_s)} ms"
        )
        if a.straggler_batches:
            lines.append(
                "  stragglers: batches "
                f"[{','.join(str(b) for b in a.straggler_batches)}]"
            )
    hdr = (
        f"  {'stage':<12} {'slots':>5} {'fill':>4} {'pred ms/b':>10} "
        f"{'meas ms/b':>10} {'ratio':>7}  pred-bound"
    )
    lines.append(hdr)
    for s in a.stages:
        meas = "-" if stable_only else ms(s.measured_s_per_batch)
        ratio = "-" if stable_only else f"x{s.ratio:.2f}"
        lines.append(
            f"  {s.name:<12} {s.slots:>5} {s.fill_slots:>4} "
            f"{ms(s.pred_s_per_batch):>10} {meas:>10} {ratio:>7}  "
            f"{s.pred_bottleneck}"
        )
    total = sum(a.channel_bytes.values())
    per_batch = total / a.n_batches if a.n_batches else 0.0
    want = getattr(plan, "host_stream_bytes", 0)
    tick = "ok" if int(round(per_batch)) == want else "MISMATCH"
    lines.append(
        f"  counters: host stream {per_batch / 2**20:.2f} MiB/batch over "
        f"{len(a.channel_bytes)} channels (plan: "
        f"{want / 2**20:.2f} MiB/batch -> {tick})   "
        f"pad {int(a.pad_elements)} elem"
    )
    occupancy = tracer.totals(COUNTER_OCCUPANCY)
    if occupancy:
        vec = ",".join(
            str(int(occupancy[k])) for k in sorted(occupancy)
        )
        lines.append(f"  cu occupancy: [{vec}]")
    return "\n".join(lines)


def chrome_counter_totals(
    trace: Dict[str, Any]
) -> Dict[str, Dict[str, float]]:
    """Final cumulative counter totals from an exported Chrome-trace
    document.  ``Tracer.bump`` counters export as ``ph: "C"`` events
    each carrying the *running* totals, so the last event per counter
    name is the run's sum -- the totals ``repro.metrics`` reconciles
    its own counters against (``python -m repro.metrics --check
    --trace``)."""
    totals: Dict[str, Dict[str, float]] = {}
    for ev in trace.get("traceEvents", []):
        if ev.get("ph") == "C":
            totals[ev["name"]] = {
                str(k): float(v) for k, v in ev.get("args", {}).items()
            }
    return totals


def samples_from_trace(tracer: Tracer, plan) -> List[Dict[str, Any]]:
    """Per-term (predicted, measured) pairs a profile store learns from:
    one sample per stage with measured slot time, attributed to the
    stage's predicted bottleneck term, plus one chain-level sample."""
    a = attribute(tracer, plan)
    samples: List[Dict[str, Any]] = []
    for s in a.stages:
        if not s.slots or s.pred_s_per_batch <= 0 or s.measured_s <= 0:
            continue
        samples.append({
            "scope": f"stage:{s.name}",
            "predicted_s": s.pred_s_per_batch,
            "measured_s": s.measured_s_per_batch,
            "bottleneck": s.pred_bottleneck,
        })
    if a.n_batches and a.pred_s_per_batch > 0 and a.wall_s > 0:
        samples.append({
            "scope": "chain",
            "predicted_s": a.pred_s_per_batch,
            "measured_s": a.measured_s_per_batch,
            "bottleneck": plan.cost.bottleneck,
        })
    return samples
