"""Validate trace files from the command line (the CI smoke step):

    python -m repro.trace out.json [more.json ...]

Exit 0 when every file is schema-valid Chrome-trace JSON, 1 otherwise,
listing each violation.
"""
from __future__ import annotations

import sys

from .schema import validate_file


def main(argv=None) -> int:
    paths = list(sys.argv[1:] if argv is None else argv)
    if not paths:
        print("usage: python -m repro.trace <trace.json> [...]",
              file=sys.stderr)
        return 2
    bad = 0
    for p in paths:
        errors = validate_file(p)
        if errors:
            bad += 1
            print(f"{p}: INVALID")
            for e in errors:
                print(f"  {e}")
        else:
            print(f"{p}: ok")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
