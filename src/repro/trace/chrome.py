"""Chrome-trace-format export: one traced run -> a Perfetto-loadable
JSON document (the ``--trace out.json`` artifact of the flow CLI).

The format is the Trace Event Format's JSON-object flavor: complete
("X") duration events for spans, cumulative ("C") counter events, and
"M" metadata events naming the process and per-stage tracks.  Times are
microseconds relative to the run's first event, so traces from different
machines diff cleanly.
"""
from __future__ import annotations

import json
from typing import Any, Dict, Optional

from .tracer import Tracer

#: Process id every event carries (one traced run = one logical process).
PID = 1


def to_chrome(tracer: Tracer,
              metadata: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Render a tracer's events as a Chrome-trace JSON object."""
    base = tracer.t_start
    us = lambda t: (t - base) * 1e6
    events = [
        {
            "ph": "M", "name": "process_name", "pid": PID, "tid": 0,
            "args": {"name": "repro"},
        },
    ]
    for track in sorted(
        set(tracer.track_names)
        | {s.track for s in tracer.spans}
        | {c.track for c in tracer.counters}
    ):
        events.append({
            "ph": "M", "name": "thread_name", "pid": PID, "tid": track,
            "args": {"name": tracer.track_names.get(track, f"track{track}")},
        })
        # Perfetto orders threads by sort_index, not tid
        events.append({
            "ph": "M", "name": "thread_sort_index", "pid": PID,
            "tid": track, "args": {"sort_index": track},
        })
    for s in tracer.spans:
        if s.open:
            continue  # an aborted run's dangling spans are dropped
        events.append({
            "ph": "X", "name": s.name, "cat": s.cat or "span",
            "pid": PID, "tid": s.track,
            "ts": us(s.t0), "dur": max(0.0, us(s.t1) - us(s.t0)),
            "args": dict(s.args),
        })
    for c in tracer.counters:
        events.append({
            "ph": "C", "name": c.name, "pid": PID, "tid": c.track,
            "ts": us(c.t), "args": dict(c.values),
        })
    doc: Dict[str, Any] = {
        "traceEvents": events,
        "displayTimeUnit": "ms",
    }
    meta = dict(tracer.meta)
    if metadata:
        meta.update(metadata)
    if meta:
        doc["otherData"] = meta
    return doc


def write_chrome(tracer: Tracer, path: str,
                 metadata: Optional[Dict[str, Any]] = None) -> None:
    """Serialize :func:`to_chrome` to ``path`` (load in Perfetto or
    ``chrome://tracing``)."""
    with open(path, "w") as f:
        json.dump(to_chrome(tracer, metadata), f, indent=1)
        f.write("\n")
