"""Sharded, atomic, async checkpointing (no external deps).

Layout:  <dir>/step_<N>/   arrays.npz-style one .npy per leaf
                           manifest.json  (paths, shapes, dtypes, step)
         <dir>/LATEST      -> step_<N>    (atomic rename + pointer swap)

Fault-tolerance contract:
  * a checkpoint directory becomes visible only after all leaves and the
    manifest are fully written (write to ``.tmp`` then ``os.rename``);
  * LATEST is updated last, so a crash mid-save leaves the previous
    checkpoint intact;
  * ``save(..., blocking=False)`` hands the host copy to a writer thread
    (training continues; ``wait()`` joins before exit);
  * restore() takes an optional shardings pytree to place leaves directly
    onto the production mesh.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return ".".join(parts)


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3) -> None:
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------
    def save(self, state: Any, *, step: int, blocking: bool = True) -> None:
        # materialize on host first (cheap copy; device buffers stay put)
        flat = jax.tree_util.tree_flatten_with_path(state)[0]
        host = [(_path_str(p), np.asarray(jax.device_get(v))) for p, v in flat]
        if blocking:
            self._write(host, step)
        else:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(host, step), daemon=True
            )
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, host, step: int) -> None:
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "leaves": []}
        for name, arr in host:
            fname = name.replace("/", "_") + ".npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"].append(
                {"name": name, "file": fname, "shape": list(arr.shape),
                 "dtype": str(arr.dtype)}
            )
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        # pointer swap (atomic on POSIX)
        latest_tmp = os.path.join(self.dir, "LATEST.tmp")
        with open(latest_tmp, "w") as f:
            f.write(os.path.basename(final))
        os.replace(latest_tmp, os.path.join(self.dir, "LATEST"))
        self._gc()

    def _gc(self) -> None:
        steps = sorted(
            d for d in os.listdir(self.dir) if d.startswith("step_")
            and not d.endswith(".tmp")
        )
        for d in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    # -- restore -------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        latest = os.path.join(self.dir, "LATEST")
        if not os.path.exists(latest):
            return None
        with open(latest) as f:
            name = f.read().strip()
        return int(name.split("_")[1])

    def restore(self, like: Any, *, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs).  ``shardings``: matching pytree of Shardings."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoint under {self.dir}")
        cdir = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(cdir, "manifest.json")) as f:
            manifest = json.load(f)
        by_name = {l["name"]: l for l in manifest["leaves"]}

        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        shard_flat = (
            treedef.flatten_up_to(shardings) if shardings is not None
            else [None] * len(flat)
        )
        out = []
        for (path, leaf), sh in zip(flat, shard_flat):
            name = _path_str(path)
            if name not in by_name:
                raise KeyError(f"checkpoint missing leaf {name!r}")
            arr = np.load(os.path.join(cdir, by_name[name]["file"]))
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(
                    f"{name}: checkpoint shape {arr.shape} != {leaf.shape}"
                )
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jax.numpy.asarray(arr))
        return treedef.unflatten(out)
