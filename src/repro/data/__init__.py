from .pipeline import PrefetchPipeline
from .synthetic import TokenStream, cfd_element_stream

__all__ = ["PrefetchPipeline", "TokenStream", "cfd_element_stream"]
