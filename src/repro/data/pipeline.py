"""Double-buffered host->device prefetch (the paper's ping/pong channels,
Fig. 14a, at the host-runtime level).

A background thread stages batch k+1 onto devices (device_put against the
batch shardings) while step k computes; the queue depth of 2 is exactly
the paper's even/odd channel pair.  ``state()`` exposes the source step
counter for checkpoint/resume.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Dict, Iterator, Optional

import jax


class PrefetchPipeline:
    def __init__(
        self,
        source: Iterator[Dict[str, Any]],
        *,
        shardings: Any = None,
        depth: int = 2,
    ) -> None:
        self.source = source
        self.shardings = shardings
        self.depth = depth
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _stage(self, batch: Dict[str, Any]) -> Dict[str, Any]:
        if self.shardings is None:
            return {k: jax.device_put(v) for k, v in batch.items()}
        return {
            k: jax.device_put(v, self.shardings[k]) for k, v in batch.items()
        }

    def _worker(self) -> None:
        try:
            for batch in self.source:
                if self._stop.is_set():
                    return
                staged = self._stage(batch)
                while not self._stop.is_set():
                    try:
                        self._q.put(staged, timeout=0.1)
                        break
                    except queue.Full:
                        continue
        except BaseException as e:  # surfaced on next __next__
            self._err = e
        finally:
            self._q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is None:
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item

    def state(self) -> Optional[Dict[str, int]]:
        return self.source.state() if hasattr(self.source, "state") else None

    def close(self) -> None:
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
