"""Deterministic, resumable synthetic data sources.

Every batch is a pure function of (seed, step): restart-after-failure
resumes bit-identically from the checkpointed step counter -- the data-
side half of the fault-tolerance story.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from ..models.config import ModelConfig


@dataclasses.dataclass
class TokenStream:
    """Zipf-ish synthetic LM tokens with shifted labels."""

    vocab: int
    batch: int
    seq_len: int
    seed: int = 0
    start_step: int = 0
    cfg: Optional[ModelConfig] = None  # enc-dec archs get frames too

    def __post_init__(self) -> None:
        self.step = self.start_step

    def state(self) -> Dict[str, int]:
        return {"step": self.step, "seed": self.seed}

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step])
        )
        # zipf-like marginal, clipped into vocab
        raw = rng.zipf(1.3, size=(self.batch, self.seq_len + 1))
        tokens = (raw % self.vocab).astype(np.int32)
        out = {
            "tokens": tokens[:, :-1],
            "labels": tokens[:, 1:],
        }
        if self.cfg is not None and self.cfg.is_encdec:
            out["frames"] = rng.normal(
                size=(self.batch, self.cfg.n_audio_frames, self.cfg.d_model)
            ).astype(np.float32)
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        b = self.batch_at(self.step)
        self.step += 1
        return b


def cfd_element_stream(
    p: int, batch_elements: int, *, seed: int = 0, start_batch: int = 0
) -> Iterator[Dict[str, np.ndarray]]:
    """[-1, 1]-normalized CFD element batches (paper's data contract)."""
    b = start_batch
    while True:
        rng = np.random.default_rng(np.random.SeedSequence([seed, b]))
        yield {
            "D": rng.uniform(-1, 1, (batch_elements, p, p, p)).astype(np.float32),
            "u": rng.uniform(-1, 1, (batch_elements, p, p, p)).astype(np.float32),
        }
        b += 1
