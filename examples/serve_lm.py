"""Batched serving example: the slot-based continuous-batching engine
over a smoke-config model -- prefill into slots, lockstep batched decode,
per-slot cache positions.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import sys

sys.path.insert(0, "src")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro import configs  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.runtime.serve import Request, ServeEngine  # noqa: E402


def main() -> None:
    cfg = configs.get_smoke("internlm2-1.8b")
    model = build_model(cfg, attn_impl="xla")
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, slots=4, max_len=64)

    rng = np.random.default_rng(0)
    reqs = [
        Request(
            rid=i,
            prompt=rng.integers(1, cfg.vocab, size=rng.integers(3, 9)).astype(
                np.int32
            ),
            max_new_tokens=8,
        )
        for i in range(6)  # more requests than slots: queueing kicks in
    ]
    for r in reqs:
        engine.submit(r)
    engine.run_until_drained()
    for r in reqs:
        print(f"request {r.rid}: prompt={list(r.prompt)} -> {r.output}")


if __name__ == "__main__":
    main()
