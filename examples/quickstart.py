"""Quickstart: the paper's headline flow in ~30 lines.

CFDlang source -> MLIR-style pipeline (parse -> factorize -> schedule ->
emit) -> batched executable, validated against the Eq. (1) oracle.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro.cfd import reference  # noqa: E402
from repro.core import api, dsl, rewrite, schedule  # noqa: E402

P = 11
SRC = dsl.INVERSE_HELMHOLTZ_SRC.format(p=P)

print("--- CFDlang source (paper Fig. 2) ---")
print(SRC)

# 1. parse + middle-end: the factorization rewrite takes the literal
#    O(p^6) contraction to the paper's (12p+1)p^3 GEMM chain.
prog = dsl.parse(SRC, element_vars=("u", "D", "v"))
opt = rewrite.optimize(prog)
print(f"literal flops/element:    {prog.total_flops():>12,}")
print(f"factorized flops/element: {opt.total_flops():>12,}"
      f"   (paper model: {(12 * P + 1) * P ** 3:,})")

# 2. operator scheduling: the dataflow groups of paper section 3.4.3
sch = schedule.schedule(opt, bytes_per_scalar=4)
print("\n--- dataflow schedule ---")
print(sch.summary())

# 3. compile + run a batch of elements
compiled = api.compile_cfdlang(SRC, element_vars=("u", "D", "v"))
rng = np.random.default_rng(0)
E = 64
S = rng.uniform(-1, 1, (P, P)).astype(np.float32)
D = rng.uniform(-1, 1, (E, P, P, P)).astype(np.float32)
u = rng.uniform(-1, 1, (E, P, P, P)).astype(np.float32)
v = np.asarray(compiled(S=S, D=D, u=u)["v"])

want = reference.inverse_helmholtz_batch(
    S.astype(np.float64), D.astype(np.float64), u.astype(np.float64)
)
print(f"\nbatched run: v{v.shape}, max |err| vs Eq.(1) oracle: "
      f"{np.abs(v - want).max():.2e}")
