"""End-to-end LM training driver: train a ~100M-class model for a few
hundred steps with the full runtime stack (prefetch pipeline, AdamW,
checkpointing, straggler monitor).

The default profile is sized for this CPU container (a reduced-width
qwen3-family model, --profile smoke); --profile 100m selects a genuine
~100M-parameter config (slow on CPU, the TPU-shaped path).

Run:  PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax  # noqa: E402

from repro import configs  # noqa: E402
from repro.checkpoint import CheckpointManager  # noqa: E402
from repro.data import PrefetchPipeline, TokenStream  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.models.config import ModelConfig  # noqa: E402
from repro.optim import AdamWConfig  # noqa: E402
from repro.runtime.train import (LoopConfig, TrainLoop,  # noqa: E402
                                 init_train_state, make_train_step)


def profile_100m() -> ModelConfig:
    """~100M params, qwen3-family (qk_norm + GQA)."""
    return ModelConfig(
        arch_id="qwen3-100m", family="dense", n_layers=12, d_model=640,
        n_heads=10, n_kv_heads=2, d_ff=1792, vocab=50304, head_dim=64,
        qk_norm=True, act="swiglu", norm="rmsnorm",
        param_dtype="float32", compute_dtype="float32",
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--profile", choices=["smoke", "100m"], default="smoke")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = (profile_100m() if args.profile == "100m"
           else configs.get_smoke("qwen3-14b"))
    model = build_model(cfg, attn_impl="xla")
    n_params = sum(
        x.size for x in jax.tree.leaves(
            jax.eval_shape(model.init, jax.random.PRNGKey(0))
        )
    )
    print(f"arch={cfg.arch_id}  params={n_params / 1e6:.1f}M")

    state = init_train_state(model, jax.random.PRNGKey(0))
    opt = AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps)
    step = jax.jit(make_train_step(model, opt))
    ckpt = CheckpointManager(args.ckpt_dir)

    start_step = 0
    if args.resume and ckpt.latest_step() is not None:
        like = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state
        )
        state = ckpt.restore(like)
        start_step = int(state["step"])
        print(f"resumed from checkpoint step {start_step}")

    stream = TokenStream(
        vocab=cfg.vocab, batch=args.batch, seq_len=args.seq_len,
        cfg=cfg, start_step=start_step,
    )
    data = PrefetchPipeline(stream)  # the double-buffered host path

    def on_straggler(step_idx, dt):
        print(f"  [monitor] step {step_idx} straggled ({dt:.2f}s)")

    loop = TrainLoop(
        step, state, data,
        cfg=LoopConfig(total_steps=args.steps, checkpoint_every=50,
                       log_every=10),
        checkpointer=ckpt,
        on_straggler=on_straggler,
    )
    final = loop.run()
    data.close()
    for h in loop.history[:: max(1, len(loop.history) // 10)]:
        print(f"step {h['step']:>5}  loss {h['loss']:.4f}  {h['dt']*1e3:.0f}ms")
    print(f"final step {int(final['step'])}, "
          f"loss {loop.history[-1]['loss']:.4f} "
          f"(from {loop.history[0]['loss']:.4f})")


if __name__ == "__main__":
    main()
