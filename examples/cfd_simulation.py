"""End-to-end CFD driver, now on top of ``repro.flow``: a CFDlang source
file goes in, a planned memory architecture plus a pipelined execution
comes out.  The default program is the paper's full application
(``examples/cfd_pipeline.cfd``: interpolation -> gradient -> inverse
Helmholtz); point --program at any ``.cfd`` file.  The single-operator
path of earlier revisions is ``--program examples/inverse_helmholtz.cfd``.

Run:  PYTHONPATH=src python examples/cfd_simulation.py --n-eq 4096 --show-plan
"""
import argparse
import os
import sys

sys.path.insert(0, "src")

import jax  # noqa: E402

from repro import flow  # noqa: E402
from repro.cfd import reference  # noqa: E402

_HERE = os.path.dirname(os.path.abspath(__file__))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--program",
                    default=os.path.join(_HERE, "cfd_pipeline.cfd"),
                    help="CFDlang source file to compile and run")
    ap.add_argument("--n-eq", type=int, default=4096)
    ap.add_argument("--batch-elements", type=int, default=0,
                    help="override E (0 = let the memory planner size it)")
    ap.add_argument("--prefetch-depth", type=int, default=1,
                    help="K batches staged ahead (0 = serial baseline); "
                    "K>0 also turns on cross-batch stage pipelining")
    ap.add_argument("--serial-stages", action="store_true",
                    help="force the back-to-back stage schedule "
                    "(bitwise-equal; isolates the pipelining win)")
    ap.add_argument("--policy", default="float32")
    ap.add_argument("--backend", default="xla",
                    help="per-stage backend: xla | staged | pallas")
    ap.add_argument("--max-stages", type=int, default=None)
    ap.add_argument("--dse", action="store_true",
                    help="sweep chain design points, run the winner")
    ap.add_argument("--show-plan", action="store_true",
                    help="print the full system report before running")
    args = ap.parse_args()

    with open(args.program) as f:
        source = f.read()
    system = flow.compile(
        source,
        name=os.path.basename(args.program).removesuffix(".cfd"),
        policy=args.policy,
        backend=args.backend,
        max_stages=args.max_stages,
        batch_elements=args.batch_elements or None,
        prefetch_depth=args.prefetch_depth,
        cu_count=jax.device_count(),
        n_eq=args.n_eq,
        dse=args.dse,
    )
    if args.show_plan:
        print(system.report())
        print()
    plan = system.plan
    print(f"simulating {args.n_eq:,} elements through "
          f"{len(system.stage_names)} stages "
          f"({'->'.join(system.stage_names)}) in "
          f"{plan.batches_for(args.n_eq)} batches of "
          f"{plan.batch_elements}")
    res = system.run(
        n_eq=args.n_eq,
        pipeline_stages=False if args.serial_stages else None,
    )
    flops = res.elements * sum(
        s.program.total_flops() for s in system.chain.stages
    )
    print(f"wall: {res.wall_s:.3f}s  "
          f"({'stage-pipelined' if res.pipelined_stages else 'serial'} "
          "schedule)")
    for q, v in sorted(res.checksums.items()):
        print(f"  checksum {q} = {v:.4f}")
    print(f"GFLOPS (paper Eq. 2 accounting): "
          f"{flops / res.wall_s / 1e9 if res.wall_s else 0.0:.3f}")
    # context: the p=11 single-operator count the paper reports
    print(f"(paper flops/element at p=11: "
          f"{reference.paper_flops_per_element(11)})")


if __name__ == "__main__":
    main()
