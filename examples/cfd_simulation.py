"""End-to-end CFD driver: the paper's 2M-element simulation, scaled by
--n-eq (default small enough for CPU).  Reports GFLOPS under the paper's
Eq. (2)-(3) accounting, with double buffering and precision selectable --
the knobs of the paper's evaluation.

Run:  PYTHONPATH=src python examples/cfd_simulation.py --n-eq 4096
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.cfd.simulation import (SimConfig, achieved_gflops,  # noqa: E402
                                  run_simulation)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--p", type=int, default=11)
    ap.add_argument("--n-eq", type=int, default=4096)
    ap.add_argument("--batch-elements", type=int, default=512)
    ap.add_argument("--policy", default="float32")
    ap.add_argument("--no-double-buffer", action="store_true")
    args = ap.parse_args()

    cfg = SimConfig(
        p=args.p,
        n_eq=args.n_eq,
        batch_elements=args.batch_elements,
        policy=args.policy,
        double_buffer=not args.no_double_buffer,
    )
    print(f"simulating {cfg.n_eq:,} elements (p={cfg.p}) in "
          f"{cfg.n_batches} batches of {cfg.batch_elements}")
    res = run_simulation(cfg)
    print(f"wall: {res.wall_s:.3f}s  checksum: {res.checksum:.4f}")
    print(f"GFLOPS (paper Eq.2 accounting): "
          f"{achieved_gflops(res, cfg.p):.3f}")


if __name__ == "__main__":
    main()
