"""End-to-end CFD driver: the paper's 2M-element simulation, scaled by
--n-eq (default small enough for CPU).  The memory architecture -- batch
size E, prefetch depth, channel placement -- is resolved by the
``repro.memory`` planner (pass --batch-elements to override E); use
--show-plan to print the Fig.-14-style dump.  Reports GFLOPS under the
paper's Eq. (2)-(3) accounting.

Run:  PYTHONPATH=src python examples/cfd_simulation.py --n-eq 4096 --show-plan
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax  # noqa: E402

from repro.cfd.simulation import (SimConfig, achieved_gflops,  # noqa: E402
                                  plan_config, run_simulation)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--p", type=int, default=11)
    ap.add_argument("--n-eq", type=int, default=4096)
    ap.add_argument("--batch-elements", type=int, default=0,
                    help="override E (0 = let the memory planner size it)")
    ap.add_argument("--prefetch-depth", type=int, default=None,
                    help="K batches staged ahead (default: double buffer)")
    ap.add_argument("--policy", default="float32")
    ap.add_argument("--no-double-buffer", action="store_true")
    ap.add_argument("--show-plan", action="store_true",
                    help="print the MemoryPlan report before running")
    args = ap.parse_args()

    cfg = SimConfig(
        p=args.p,
        n_eq=args.n_eq,
        batch_elements=args.batch_elements or None,
        policy=args.policy,
        double_buffer=not args.no_double_buffer,
        prefetch_depth=args.prefetch_depth,
    )
    plan = plan_config(cfg, cu_count=jax.device_count())
    if args.show_plan:
        print(plan.report())
        print()
    print(f"simulating {cfg.n_eq:,} elements (p={cfg.p}) in "
          f"{cfg.n_eq // plan.batch_elements} batches of "
          f"{plan.batch_elements} (prefetch K={plan.prefetch_depth})")
    res = run_simulation(cfg, plan=plan)
    print(f"wall: {res.wall_s:.3f}s  checksum: {res.checksum:.4f}")
    print(f"GFLOPS (paper Eq.2 accounting): "
          f"{achieved_gflops(res, cfg.p):.3f}")


if __name__ == "__main__":
    main()
