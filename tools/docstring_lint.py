"""Docstring lint for the public planner API (no pip dependencies).

A hand-rolled pydocstyle subset: every public module, class, function,
and method under the linted packages must carry a non-empty docstring.
"Public" means the name (and every enclosing scope) does not start with
an underscore; ``__init__`` is exempt when its class is documented,
other dunders are exempt always.  Purely structural wrappers are not
exempt -- if it is importable and callable, it is documented.

Usage::

    python tools/docstring_lint.py [PATH ...]

With no arguments, lints the planner stack: ``src/repro/flow`` and
``src/repro/memory``.  Exit 0 when clean, 1 with one ``path:line: name``
violation per line, 2 on usage/parse errors.

Run by CI's test job and by ``tests/test_docs.py``; see
``docs/ARCHITECTURE.md`` for what counts as the public planner API.
"""
from __future__ import annotations

import ast
import pathlib
import sys
from typing import Iterator, List, Tuple

DEFAULT_PATHS = ("src/repro/flow", "src/repro/memory")

Violation = Tuple[pathlib.Path, int, str]


def _public(name: str) -> bool:
    return not name.startswith("_")


def _has_docstring(node) -> bool:
    doc = ast.get_docstring(node, clean=False)
    return bool(doc and doc.strip())


def _walk_scope(
    node, qualname: str, path: pathlib.Path
) -> Iterator[Violation]:
    """Yield violations for every public def/class directly inside
    ``node``, recursing only through public scopes (private containers
    make everything inside them private)."""
    for child in ast.iter_child_nodes(node):
        if isinstance(
            child, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)
        ):
            name = child.name
            if name.startswith("__") and name.endswith("__"):
                continue  # dunders ride on their class's docstring
            if not _public(name):
                continue
            q = f"{qualname}.{name}" if qualname else name
            if not _has_docstring(child):
                yield (path, child.lineno, q)
            if isinstance(child, ast.ClassDef):
                yield from _walk_scope(child, q, path)
            # function bodies are local scope: nothing inside is public


def lint_file(path: pathlib.Path) -> List[Violation]:
    tree = ast.parse(path.read_text(), filename=str(path))
    out: List[Violation] = []
    if not _has_docstring(tree):
        out.append((path, 1, "<module>"))
    out.extend(_walk_scope(tree, "", path))
    return out


def lint_paths(paths) -> List[Violation]:
    """Lint every ``*.py`` file under each path (files accepted too)."""
    out: List[Violation] = []
    for p in paths:
        p = pathlib.Path(p)
        files = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        if not files or not p.exists():
            raise FileNotFoundError(f"no Python files under {p}")
        for f in files:
            out.extend(lint_file(f))
    return out


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    paths = argv or [
        str(pathlib.Path(__file__).resolve().parent.parent / d)
        for d in DEFAULT_PATHS
    ]
    try:
        violations = lint_paths(paths)
    except (OSError, SyntaxError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    for path, line, name in violations:
        print(f"{path}:{line}: missing docstring: {name}")
    if violations:
        print(
            f"{len(violations)} public name(s) without docstrings "
            "(see tools/docstring_lint.py)",
            file=sys.stderr,
        )
        return 1
    print(f"docstring lint clean ({', '.join(str(p) for p in paths)})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
