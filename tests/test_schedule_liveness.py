"""Operator scheduling (dataflow groups) + Mnemosyne-style liveness."""
import pytest

from repro.core import dsl, ir, liveness, rewrite, schedule


def _helmholtz(p=7):
    return rewrite.optimize(dsl.inverse_helmholtz_program(p))


def test_default_schedule_is_seven_stages():
    """The paper's 7-loop-nest structure: most aggressive partition keeps
    7 singleton groups (3 GEMM + Hadamard + 3 GEMM)."""
    sch = schedule.schedule(_helmholtz(), bytes_per_scalar=8)
    assert len(sch.groups) == 7
    assert all(len(g.nodes) == 1 for g in sch.groups)


@pytest.mark.parametrize("target", [1, 2, 3])
def test_max_groups_collapse(target):
    """The paper's Dataflow 1/2/3-compute variants via max_groups."""
    sch = schedule.schedule(
        _helmholtz(), bytes_per_scalar=8, max_groups=target
    )
    assert len(sch.groups) <= max(target, 1) + 1


def test_groups_topologically_ordered():
    sch = schedule.schedule(_helmholtz(), bytes_per_scalar=8)
    seen = set()
    for g in sch.groups:
        for n in g.nodes:
            for op in n.operands():
                if not isinstance(op, ir.Input):
                    assert op.uid in seen or any(
                        op.uid == m.uid for m in g.nodes
                    )
            seen.add(n.uid)


def test_critical_flops_bounds_throughput():
    sch = schedule.schedule(_helmholtz(11), bytes_per_scalar=8)
    assert sch.critical_flops == max(g.flops for g in sch.groups)
    # paper: each contraction stage costs 2p^4
    assert sch.critical_flops == 2 * 11 ** 4


def test_working_set_respects_budget():
    budget = 10 ** 6
    sch = schedule.schedule(
        _helmholtz(11), vmem_budget=budget, bytes_per_scalar=8
    )
    for g in sch.groups:
        assert g.working_set(8) <= budget


def test_liveness_sharing_on_collapsed_group():
    """Collapsed single group: the t/r intermediates have disjoint
    lifetimes with later stages -> sharing saves memory (paper
    'Mem Sharing' row: only applies to the 1-compute variant)."""
    sch1 = schedule.schedule(
        _helmholtz(11), bytes_per_scalar=8, max_groups=1
    )
    plans = liveness.plan_program(sch1.groups, 8)
    total_savings = sum(p.naive_bytes - p.shared_bytes for p in plans.values())
    assert total_savings > 0

    # singleton groups: no internal temporaries -> nothing to share
    # (matches the paper: sharing "cannot be applied" to 7-compute)
    sch7 = schedule.schedule(_helmholtz(11), bytes_per_scalar=8)
    plans7 = liveness.plan_program(sch7.groups, 8)
    assert all(p.naive_bytes == 0 for p in plans7.values())


def test_stream_bytes_accounting():
    sch = schedule.schedule(_helmholtz(7), bytes_per_scalar=8)
    for g in sch.groups[:-1]:
        assert len(g.out_streams) >= 1
    # last group streams the program output
    assert sch.groups[-1].out_streams[0].shape == (7, 7, 7)


def test_stream_bytes_default_follows_policy_width():
    """Regression: byte-count methods default to the scalar width the
    schedule was built for, instead of a silent 4-byte assumption that
    disagreed with low-precision policies."""
    prog = _helmholtz(7)
    for bps in (2, 4, 8):
        sch = schedule.schedule(prog, bytes_per_scalar=bps)
        assert sch.bytes_per_scalar == bps
        assert sch.stream_bytes() == sch.stream_bytes(bps)
        assert sch.stream_io_bytes() == sch.stream_io_bytes(bps)
        for g in sch.groups:
            assert g.bytes_per_scalar == bps
            assert g.in_stream_bytes() == g.in_stream_bytes(bps)
            assert g.out_stream_bytes() == g.out_stream_bytes(bps)
            assert g.working_set() == g.working_set(bps)
            # explicit widths still override the default
            assert g.out_stream_bytes(1) * bps == g.out_stream_bytes(bps)
    bf16 = schedule.schedule(prog, bytes_per_scalar=2)
    f32 = schedule.schedule(prog, bytes_per_scalar=4)
    assert all(
        bf16.stream_bytes()[k] * 2 == f32.stream_bytes()[k]
        for k in bf16.stream_bytes()
    )
    assert bf16.summary() != f32.summary()


def test_stage_partition_duplicates_element_free_group_into_all_consumers():
    """Regression (PR-4 review gap a): an element-free group consumed by
    two element-dependent stages is duplicated into *both*, so no stage
    reads an element-free cross-stage stream."""
    src = (
        "var input M : [4 4]\n"
        "var input elem x : [4 4]\n"
        "var input elem y : [4 4]\n"
        "var output elem u : [4 4]\n"
        "var output elem v : [4 4]\n"
        "var q : [4 4]\n"
        "q = M * M\n"
        "u = q # x . [[1 2]]\n"
        "v = q * y\n"
    )
    prog = rewrite.optimize(dsl.parse(src))
    sch = schedule.schedule(prog, bytes_per_scalar=4)
    parts = schedule.stage_partition(sch)
    elem_dep = prog.element_dependent_uids()
    q_uid = prog.temps["q"].uid
    assert q_uid not in elem_dep
    holders = [
        i for i, nodes in enumerate(parts)
        if any(n.uid == q_uid for n in nodes)
    ]
    assert len(holders) == 2  # one copy per consumer stage
    # every stage still streams elements, and no stage's boundary input
    # is an element-free value produced by another stage
    classes = liveness.classify_boundary_streams(prog, parts)
    assert q_uid not in classes
    for nodes in parts:
        assert any(n.uid in elem_dep for n in nodes)
