"""The docs satellite's contracts: the planner docs exist and are
linked from the README, and the hand-rolled docstring lint both works
and passes on the public planner API (also a standalone CI step)."""
import importlib.util
import pathlib

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent


def _lint():
    spec = importlib.util.spec_from_file_location(
        "docstring_lint", REPO / "tools" / "docstring_lint.py"
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.mark.parametrize(
    "doc", ["ARCHITECTURE.md", "COST_MODEL.md", "CLI.md"]
)
def test_docs_exist_and_are_linked(doc):
    path = REPO / "docs" / doc
    assert path.is_file() and path.stat().st_size > 1000, doc
    readme = (REPO / "README.md").read_text()
    assert f"docs/{doc}" in readme, f"README does not link docs/{doc}"


def test_docs_cross_link_each_other():
    """Each doc points at its two companions (the 'docs site' glue)."""
    docs = {d: (REPO / "docs" / d).read_text()
            for d in ("ARCHITECTURE.md", "COST_MODEL.md", "CLI.md")}
    for name, text in docs.items():
        for other in docs:
            if other != name:
                assert other in text, f"{name} does not link {other}"


def test_docstring_lint_clean_on_planner_packages():
    mod = _lint()
    violations = mod.lint_paths(
        [REPO / "src" / "repro" / "flow", REPO / "src" / "repro" / "memory"]
    )
    assert violations == [], "\n".join(
        f"{p}:{line}: {name}" for p, line, name in violations
    )


def test_docstring_lint_catches_violations(tmp_path):
    """The lint is not vacuous: undocumented public names are flagged,
    private/dunder names and documented ones are not."""
    f = tmp_path / "mod.py"
    f.write_text(
        '"""Documented module."""\n'
        "def public_no_doc():\n    pass\n"
        "def _private():\n    pass\n"
        "class Documented:\n"
        '    """Yes."""\n'
        "    def __init__(self):\n        pass\n"
        "    def method_no_doc(self):\n        pass\n"
    )
    mod = _lint()
    got = {name for _, _, name in mod.lint_paths([f])}
    assert got == {"public_no_doc", "Documented.method_no_doc"}

    bare = tmp_path / "bare.py"
    bare.write_text("x = 1\n")
    assert {n for _, _, n in mod.lint_paths([bare])} == {"<module>"}
