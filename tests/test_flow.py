"""repro.flow: the end-to-end tool flow.  Acceptance: the flow-compiled
Fig. 2 program is bitwise-equal at float32 to the directly compiled
operator (and matches the float64 oracle), the flow-compiled pipeline
subsumes the hand stage cuts bitwise, the CLI's system report is
golden-checked, and every derived ProgramChain validates (hypothesis).
"""
import os
import pathlib

import numpy as np
import pytest

from repro import flow
from repro.cfd import operators, reference, simulation
from repro.core import dsl, liveness
from repro.memory import chain as mchain
from repro.memory import channels, dse

GOLDEN_DIR = pathlib.Path(__file__).parent / "golden"
EXAMPLES = pathlib.Path(__file__).parent.parent / "examples"


def _chain_run(system, inputs_by_var, shared, **kw):
    """Run a system, routing full input arrays to whichever stage hosts
    each element stream (stage names differ between auto/named cuts)."""
    ch = system.chain
    inputs = {}
    for i, s in enumerate(ch.stages):
        for n, _ in ch.host_element_inputs(i):
            inputs[f"{s.name}.{n}"] = inputs_by_var[n]
    return system.run(
        inputs=inputs, shared=shared, collect_outputs=True, **kw
    )


# ---------------------------------------------------------------------------
# acceptance: Fig. 2 end-to-end, zero hand-written operator code
# ---------------------------------------------------------------------------


def test_flow_fig2_bitwise_and_oracle(rng):
    """flow.compile on the paper's Fig. 2 source yields a ChainPlan plus
    an executable bitwise-identical at float32 to the directly compiled
    operator, and numerically matching the float64 reference oracle."""
    p, E, n_b = 5, 8, 3
    n = E * n_b
    src = dsl.INVERSE_HELMHOLTZ_SRC.format(p=p)
    system = flow.compile(
        src, name="fig2", element_vars=("u", "D", "v"),
        target=channels.CPU_HOST, batch_elements=E, n_eq=n,
    )
    assert system.plan.feasible
    assert len(system.chain.stages) == len(system.plan.stages)
    S = rng.uniform(-1, 1, (p, p)).astype(np.float32)
    D = rng.uniform(-1, 1, (n, p, p, p)).astype(np.float32)
    u = rng.uniform(-1, 1, (n, p, p, p)).astype(np.float32)
    res = _chain_run(system, {"u": u, "D": D}, {"S": S})
    (vq,) = [q for q in res.outputs if q.endswith(".v")]
    got = res.outputs[vq]

    hand = operators.build_inverse_helmholtz(p)
    want = np.asarray(hand.batched_fn({"S": S, "D": D, "u": u})["v"])
    assert got.dtype == want.dtype == np.float32
    assert np.array_equal(got, want)

    oracle = reference.inverse_helmholtz_batch(
        S.astype(np.float64), D.astype(np.float64), u.astype(np.float64)
    )
    np.testing.assert_allclose(got, oracle, rtol=3e-4, atol=3e-4)


def test_flow_pipeline_auto_stages_subsume_hand_cuts(rng):
    """The fully automatic (schedule-derived) pipeline and the named
    hand-granularity cuts produce bitwise-identical outputs."""
    p, E, n_b = 5, 16, 2
    n = E * n_b
    src = operators.CFD_PIPELINE_SRC.format(p=p)
    auto = flow.compile(
        src, target=channels.CPU_HOST, batch_elements=E, n_eq=n
    )
    assert len(auto.chain.stages) > 3  # finer than the hand cuts
    u = rng.uniform(-1, 1, (n, p, p, p)).astype(np.float32)
    D = rng.uniform(-1, 1, (n, p, p, p)).astype(np.float32)
    shared = {
        name: rng.uniform(-1, 1, (p, p)).astype(np.float32)
        for name in ("A", "Dx", "Dy", "Dz", "S")
    }
    got = _chain_run(auto, {"u": u, "D": D}, shared)

    hand = operators.build_cfd_chain(p)
    plan = mchain.plan_chain(
        hand, target=channels.CPU_HOST, batch_elements=E, n_eq=n
    )
    want = simulation.run_chain(
        hand, plan,
        inputs={"interp.u": u, "helmholtz.D": D},
        shared=shared, collect_outputs=True,
    )
    for out_var in ("gy", "gz", "v"):
        (gq,) = [q for q in got.outputs if q.endswith("." + out_var)]
        (wq,) = [q for q in want.outputs if q.endswith("." + out_var)]
        assert np.array_equal(got.outputs[gq], want.outputs[wq]), out_var


def test_flow_named_cuts_match_hand_structure():
    """The named-stage pipeline reproduces the paper's operator
    granularity, with both bound streams HBM-resident and the Pallas
    Helmholtz stage dispatched by structural match."""
    system = operators.compile_cfd_pipeline(
        5, backends=("xla", "xla", "pallas"), target=channels.ALVEO_U280
    )
    assert system.stage_names == ("interp", "grad", "helmholtz")
    assert system.backends == ("xla", "xla", "pallas")
    resident = {
        s.name: s.klass for s in system.streams
        if s.klass == liveness.STREAM_RESIDENT
    }
    assert sorted(resident) == ["gx", "w"]
    rep = system.report()
    assert "repro.flow system" in rep
    assert "ChainPlan interp->grad->helmholtz" in rep


def test_flow_pallas_covers_interp_and_grad_stages():
    """The tiled GEMM-chain kernel class covers the interpolation and
    gradient stages, so 'pallas' no longer falls back to xla there."""
    system = flow.compile(
        operators.CFD_PIPELINE_SRC.format(p=5),
        stages=operators.CFD_PIPELINE_STAGES,
        backends=("pallas", "pallas", "pallas"),
        target=channels.ALVEO_U280,
    )
    assert system.backends == ("pallas", "pallas", "pallas")


def test_flow_pallas_fallback_when_no_kernel_matches():
    """A 'pallas' stage with no matching hand-tiled kernel falls back to
    xla (emit's documented dispatch rule) instead of failing.  An
    element-tensor x element-tensor product with a contraction is outside
    every kernel class (the GEMM chain needs a shared (p,p) matrix)."""
    src = (
        "var input elem a : [4 4]\n"
        "var input elem b : [4 4]\n"
        "var output elem y : [4 4]\n"
        "y = a # b . [[1 2]]\n"
    )
    system = flow.compile(
        src, backend="pallas", target=channels.CPU_HOST,
        batch_elements=4, n_eq=8,
    )
    assert system.backends == ("xla",)


def test_flow_output_consumed_downstream_reaches_host(rng):
    """A program output that later stages also consume is classified
    'both': exported once for the host and once (under a _res alias)
    for the resident consumer -- the host still receives it."""
    src = (
        "var input M : [3 3]\n"
        "var input elem x : [3 3]\n"
        "var output elem y : [3 3]\n"
        "var output elem z : [3 3]\n"
        "y = M # x . [[1 2]]\n"
        "z = y * x\n"
    )
    system = flow.compile(
        src, target=channels.CPU_HOST, batch_elements=4, n_eq=8
    )
    classes = {s.name: s.klass for s in system.streams}
    assert classes == {
        "y": liveness.STREAM_BOTH, "z": liveness.STREAM_HOST,
    }
    M = rng.uniform(-1, 1, (3, 3)).astype(np.float32)
    x = rng.uniform(-1, 1, (8, 3, 3)).astype(np.float32)
    res = _chain_run(system, {"x": x}, {"M": M})
    assert sorted(q.split(".")[1] for q in res.outputs) == ["y", "z"]
    want_y = np.einsum("ab,ebc->eac", M, x).astype(np.float32)
    (yq,) = [q for q in res.outputs if q.endswith(".y")]
    (zq,) = [q for q in res.outputs if q.endswith(".z")]
    np.testing.assert_allclose(res.outputs[yq], want_y, atol=1e-6)
    np.testing.assert_allclose(
        res.outputs[zq], want_y * x, atol=1e-6
    )


def test_flow_shared_precompute_consumed_by_two_stages(rng):
    """Regression (PR-4 review gap a): a shared precomputed operand
    (element-free q = M * M) consumed by two auto-derived stages used to
    make flow.compile reject the program ('does not depend on any
    element input'); the partitioner now duplicates the element-free
    nodes into every consumer stage."""
    src = (
        "var input M : [4 4]\n"
        "var input elem x : [4 4]\n"
        "var input elem y : [4 4]\n"
        "var output elem u : [4 4]\n"
        "var output elem v : [4 4]\n"
        "var q : [4 4]\n"
        "q = M * M\n"
        "u = q # x . [[1 2]]\n"
        "v = q * y\n"
    )
    system = flow.compile(
        src, target=channels.CPU_HOST, batch_elements=4, n_eq=8
    )
    assert len(system.chain.stages) == 2
    # both stages recompute q from the shared M; nothing element-free
    # crosses a stage boundary
    for s in system.chain.stages:
        assert "M" in s.program.inputs
    assert all(s.klass != liveness.STREAM_RESIDENT or s.name != "q"
               for s in system.streams)
    M = rng.uniform(-1, 1, (4, 4)).astype(np.float32)
    x = rng.uniform(-1, 1, (8, 4, 4)).astype(np.float32)
    y = rng.uniform(-1, 1, (8, 4, 4)).astype(np.float32)
    res = _chain_run(system, {"x": x, "y": y}, {"M": M})
    q = M * M
    (uq,) = [k for k in res.outputs if k.endswith(".u")]
    (vq,) = [k for k in res.outputs if k.endswith(".v")]
    np.testing.assert_allclose(
        res.outputs[uq], np.einsum("ab,ebc->eac", q, x), atol=1e-5
    )
    np.testing.assert_allclose(res.outputs[vq], q[None] * y, atol=1e-5)


def test_flow_rejects_degenerate_programs():
    with pytest.raises(dsl.ParseError, match="empty program"):
        flow.compile("// comment only\n")
    with pytest.raises(flow.FlowError, match="no outputs"):
        flow.compile("var input elem x : [2 2]")
    with pytest.raises(flow.FlowError, match="element"):
        flow.compile(
            "var input a : [2 2]\nvar output b : [2 2]\nb = a * a"
        )
    # an output computed purely from shared operands cannot stream
    with pytest.raises(flow.FlowError, match="does not depend"):
        flow.compile(
            "var input a : [2 2]\nvar input elem x : [2 2]\n"
            "var output y : [2 2]\nvar output elem z : [2 2]\n"
            "y = a * a\nz = x * x"
        )
    with pytest.raises(flow.FlowError, match="unknown target"):
        flow.compile(
            dsl.INVERSE_HELMHOLTZ_SRC.format(p=3),
            element_vars=("u", "D", "v"), target="nosuch",
        )


def test_flow_stage_cut_validation():
    src = operators.CFD_PIPELINE_SRC.format(p=3)
    with pytest.raises(flow.FlowError, match="unknown value"):
        flow.compile(src, stages=[("a", ("nosuch",))])
    with pytest.raises(flow.FlowError, match="cover output"):
        flow.compile(src, stages=[("a", ("w",))])
    with pytest.raises(flow.FlowError, match="duplicate stage"):
        flow.compile(src, stages=[
            ("a", ("w",)), ("a", ("gx", "gy", "gz", "v")),
        ])
    # cutting against the dataflow leaves a later stage empty
    with pytest.raises(flow.FlowError, match="empty"):
        flow.compile(src, stages=[
            ("a", ("gy", "gz", "v")), ("b", ("w",)),
        ])


def test_flow_dse_adopts_feasible_plan():
    system = flow.compile(
        operators.CFD_PIPELINE_SRC.format(p=5),
        stages=operators.CFD_PIPELINE_STAGES,
        target=channels.ALVEO_U280, n_eq=1 << 12,
        dse=True,
        dse_space=dse.ChainDesignSpace(
            backends=("xla", "staged"), batch_divisors=(1, 2),
            prefetch_depths=(0, 1), max_backend_combos=4,
        ),
    )
    assert system.candidates
    best = next(c for c in system.candidates if c.plan.feasible)
    assert system.plan == best.plan
    # the executable chain was rebuilt to match the winning backends
    assert tuple(s.backend for s in system.chain.stages) == tuple(
        sp.backend for sp in system.plan.stages
    )


def test_flow_dse_recompiles_pallas_block_on_e_change(monkeypatch):
    """Regression (PR-4 review gap b): a DSE winner with the *same*
    backends+policy but a different E/block used to skip the recompile,
    leaving the Pallas kernel's baked block out of sync with the plan's
    block_elements.  The winner's block must reach the kernel."""
    from repro.kernels.helmholtz import ops as hops

    seen = []
    real = hops.make_pallas_impl

    def spy(impl="auto", block_elements=hops.DEFAULT_BLOCK_ELEMENTS):
        seen.append(block_elements)
        return real(impl=impl, block_elements=block_elements)

    monkeypatch.setattr(
        "repro.flow.patterns.helmholtz_ops.make_pallas_impl", spy
    )
    system = flow.compile(
        dsl.INVERSE_HELMHOLTZ_SRC.format(p=5),
        element_vars=("u", "D", "v"), backend="pallas", max_stages=1,
        target=channels.ALVEO_U280, n_eq=1 << 12, dse=True,
        dse_space=dse.ChainDesignSpace(
            backends=("pallas",), batch_divisors=(2,),
            prefetch_depths=(1,), max_backend_combos=1,
        ),
    )
    assert system.backends == ("pallas",)
    blk = system.plan.stages[0].block_elements
    assert blk > 0
    # first call: the pre-DSE compile at the kernel default; second: the
    # adoption recompile threading the winning plan's VMEM block
    assert len(seen) == 2
    assert seen[0] == hops.DEFAULT_BLOCK_ELEMENTS
    assert seen[-1] == blk
    assert system.plan.batch_elements % blk == 0


def test_flow_tune_blocks_measures_and_records(tmp_path):
    """flow.compile(tune_blocks=True) times the candidate VMEM blocks of
    each Pallas stage, adopts a winner consistent with the plan, and
    deposits the measured sample in the profile store keyed by the plan
    signature."""
    from repro.trace.profile import ProfileStore

    prof = str(tmp_path / "prof.json")
    system = flow.compile(
        dsl.INVERSE_HELMHOLTZ_SRC.format(p=5),
        element_vars=("u", "D", "v"), backend="pallas", max_stages=1,
        target=channels.CPU_HOST, batch_elements=8, n_eq=16,
        tune_blocks=True, profile=prof,
    )
    assert system.backends == ("pallas",)
    blk = system.plan.stages[0].block_elements
    assert blk in (1, 2, 4, 8)
    assert system.plan.batch_elements % blk == 0
    store = ProfileStore(path=prof)
    got = store.samples(channels.CPU_HOST.name, system.plan.signature)
    tuned = [s for s in got if s.get("scope") == "tune"]
    assert tuned and tuned[0]["block_elements"] == blk
    assert tuned[0]["measured_s"] > 0


def test_flow_dse_replans_when_winner_backend_unrealizable():
    """A winning backend combo that no kernel can realize (pallas on an
    element-by-element contraction, outside every kernel class) is
    re-planned at the winner's design point with the backends that
    actually compiled -- plan and executable always agree, so run_chain
    never warns about a mismatch."""
    src = (
        "var input elem a : [4 4]\n"
        "var input elem b : [4 4]\n"
        "var input M : [4 4]\n"
        "var output elem z : [4 4]\n"
        "var y : [4 4]\n"
        "y = a # b . [[1 2]]\n"
        "z = M # y . [[1 2]]\n"
    )
    system = flow.compile(
        src,
        stages=[("mix", ["y"]), ("proj", ["z"])],
        target=channels.ALVEO_U280, n_eq=1 << 12, dse=True,
        dse_space=dse.ChainDesignSpace(
            backends=("pallas",), batch_divisors=(1,),
            prefetch_depths=(1,), max_backend_combos=1,
        ),
    )
    planned = tuple(sp.backend for sp in system.plan.stages)
    compiled = tuple(s.backend for s in system.chain.stages)
    assert planned == compiled == system.backends
    assert planned == ("xla", "pallas")


# ---------------------------------------------------------------------------
# golden system reports (the CLI's output, checked like plan goldens)
# ---------------------------------------------------------------------------


def _check_golden(name: str, rendered: str) -> None:
    path = GOLDEN_DIR / name
    if os.environ.get("REGEN_GOLDENS"):
        GOLDEN_DIR.mkdir(exist_ok=True)
        path.write_text(rendered)
        pytest.skip(f"regenerated {name}")
    assert path.exists(), (
        f"golden file {name} missing -- run with REGEN_GOLDENS=1"
    )
    assert rendered == path.read_text(), (
        f"{name} drifted from the checked-in golden.  If intentional, "
        "regenerate with REGEN_GOLDENS=1 and review the diff."
    )


@pytest.mark.parametrize("example", ["inverse_helmholtz", "cfd_pipeline"])
def test_flow_cli_report_golden(example, capsys):
    """The CLI on examples/*.cfd emits the golden-checked architecture
    report (the same invocation CI's flow smoke job diffs)."""
    rc = flow.cli.main([
        str(EXAMPLES / f"{example}.cfd"), "--target", "alveo-u280",
    ])
    out = capsys.readouterr().out
    assert rc == 0
    _check_golden(f"flow_{example}.txt", out)


def test_example_sources_match_library_constants():
    """The checked-in .cfd examples compute the library's source
    constants at p=11 (same structure, names, and element streams), so
    the CLI goldens and the in-library tests validate the same
    programs."""
    from repro.flow.patterns import program_signature

    pairs = [
        ((EXAMPLES / "cfd_pipeline.cfd").read_text(), (),
         operators.CFD_PIPELINE_SRC.format(p=11), ()),
        ((EXAMPLES / "inverse_helmholtz.cfd").read_text(), (),
         dsl.INVERSE_HELMHOLTZ_SRC.format(p=11), ("u", "D", "v")),
    ]
    for src_a, ev_a, src_b, ev_b in pairs:
        a = dsl.parse(src_a, element_vars=ev_a)
        b = dsl.parse(src_b, element_vars=ev_b)
        assert program_signature(a) == program_signature(b)
        assert sorted(a.inputs) == sorted(b.inputs)
        assert sorted(a.outputs) == sorted(b.outputs)
        assert set(a.element_vars) == set(b.element_vars)


def test_target_normalization_dash_underscore_identical(capsys):
    """CI passes --target alveo-u280, the Python API historically used
    alveo_u280: both spellings (any case, stray whitespace) must resolve
    to the same datasheet, in the library and through the CLI."""
    for name in ("alveo-u280", "alveo_u280", "ALVEO_U280", " Alveo-U280 "):
        assert channels.resolve_target(name) is channels.ALVEO_U280
        assert flow.build.resolve_target(name) is channels.ALVEO_U280
    assert channels.resolve_target(None) is channels.detect_target()
    assert channels.resolve_target(channels.TPU_V5E) is channels.TPU_V5E
    src = str(EXAMPLES / "inverse_helmholtz.cfd")
    outs = []
    for spelling in ("alveo-u280", "alveo_u280"):
        assert flow.cli.main([src, "--target", spelling]) == 0
        outs.append(capsys.readouterr().out)
    assert outs[0] == outs[1]


def test_target_typo_lists_known_targets():
    with pytest.raises(
        channels.UnknownTargetError, match="alveo-u280.*cpu-host.*tpu-v5e"
    ):
        channels.resolve_target("alveo-u28")
    with pytest.raises(flow.FlowError, match="known targets"):
        flow.build.resolve_target("alveo-u28")
    # UnknownTargetError is a ValueError: existing CLI/compile callers
    # that catch ValueError keep working
    assert issubclass(channels.UnknownTargetError, ValueError)
    # near misses get a did-you-mean hint; garbage does not
    with pytest.raises(
        channels.UnknownTargetError, match="did you mean 'tpu-v5e'"
    ):
        channels.resolve_target("tpu_v5x")
    try:
        channels.resolve_target("qqqqqq")
    except channels.UnknownTargetError as e:
        assert "did you mean" not in str(e)


def test_flow_cli_target_typo_exits_2_with_suggestion(capsys):
    rc = flow.cli.main([
        str(EXAMPLES / "inverse_helmholtz.cfd"), "--target", "tpu_v5x",
    ])
    assert rc == 2
    err = capsys.readouterr().err
    assert "unknown target" in err and "did you mean 'tpu-v5e'" in err


def test_flow_cli_error_paths(tmp_path, capsys):
    empty = tmp_path / "empty.cfd"
    empty.write_text("// nothing here\n")
    assert flow.cli.main([str(empty)]) == 2
    assert "empty program" in capsys.readouterr().err
    assert flow.cli.main([str(tmp_path / "missing.cfd")]) == 2
    bad = tmp_path / "bad.cfd"
    bad.write_text(
        "var input elem x : [2 2]\nvar output elem y : [2 2]\ny = - x\n"
    )
    assert flow.cli.main([str(bad)]) == 2
    assert "binary operator" in capsys.readouterr().err


# ---------------------------------------------------------------------------
# hypothesis: every derived ProgramChain validates
# ---------------------------------------------------------------------------


def _random_pipeline_source(k: int, steps) -> str:
    """A random CFDlang pipeline: a chain of matrix applications and
    Hadamard products over (k, k) element streams."""
    lines = [f"var input elem x0 : [{k} {k}]"]
    n_mats = sum(1 for s in steps if s == "mat")
    n_elem = sum(1 for s in steps if s == "had")
    for i in range(n_mats):
        lines.append(f"var input M{i} : [{k} {k}]")
    for i in range(n_elem):
        lines.append(f"var input elem e{i} : [{k} {k}]")
    for i in range(len(steps) - 1):
        lines.append(f"var y{i} : [{k} {k}]")
    lines.append(f"var output elem z : [{k} {k}]")
    prev, mi, ei = "x0", 0, 0
    for i, s in enumerate(steps):
        dst = "z" if i == len(steps) - 1 else f"y{i}"
        if s == "mat":
            lines.append(f"{dst} = M{mi} # {prev} . [[1 2]]")
            mi += 1
        else:
            lines.append(f"{dst} = {prev} * e{ei}")
            ei += 1
        prev = dst
    return "\n".join(lines) + "\n"


def _check_derived_chain_validates(k, steps, e):
    """Property body: for a random pipeline, the flow-derived
    ProgramChain constructs without dangling bindings, its plan is
    deterministic, and HBM-resident streams strictly reduce host-link
    bytes versus planning every stage standalone (equal only when
    nothing is resident)."""
    src = _random_pipeline_source(k, steps)
    t = channels.ALVEO_U280
    system = flow.compile(src, target=t, batch_elements=e)
    chain = system.chain  # ProgramChain.__init__ validates bindings
    assert system.plan == flow.compile(
        src, target=t, batch_elements=e
    ).plan
    n_resident = sum(
        1 for s in system.streams
        if s.klass in (liveness.STREAM_RESIDENT, liveness.STREAM_BOTH)
    )
    assert n_resident == len(chain.stages) - 1  # a linear pipeline
    standalone = sum(
        dse.make_plan(
            s.program, target=t, batch_elements=e, operator_name=s.name
        ).host_stream_bytes
        for s in chain.stages
    )
    if n_resident:
        assert system.plan.host_stream_bytes < standalone
    else:
        assert system.plan.host_stream_bytes == standalone


try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI installs hypothesis
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @given(
        k=st.integers(2, 5),
        steps=st.lists(
            st.sampled_from(["mat", "had"]), min_size=1, max_size=5
        ),
        e=st.integers(1, 512),
    )
    @settings(max_examples=40, deadline=None)
    def test_flow_derived_chains_validate(k, steps, e):
        _check_derived_chain_validates(k, steps, e)

else:  # deterministic fallback so the property still runs everywhere

    @pytest.mark.parametrize("k,steps,e", [
        (2, ("mat",), 1),
        (3, ("mat", "had"), 17),
        (4, ("had", "mat", "mat"), 509),   # prime-ish explicit E
        (5, ("mat", "had", "mat", "had", "mat"), 512),
    ])
    def test_flow_derived_chains_validate(k, steps, e):
        _check_derived_chain_validates(k, list(steps), e)
