"""Placement layer: DeviceTopology / PlacementPlan semantics, the
contention-aware chain cost, joint per-stage DSE placement search, and
the multi-device stage-pipeline executor.

Acceptance (ISSUE 5): explore_chain ranks per-stage (cu, depth)
placements; the top-ranked multi-device placement executes bitwise-equal
to the serial single-device baseline via run_chain; t_overlapped never
beats the per-stage roofline bound; the DSE frontier is monotone in
device count.
"""
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from conftest import subprocess_env
from repro.cfd import operators, simulation
from repro.memory import chain as mchain
from repro.memory import channels, dse, pipeline as mempipe
from repro.memory.placement import (DeviceTopology, PlacementError,
                                    PlacementPlan, StagePlacement,
                                    assign_device_groups, place_chain)


# ---------------------------------------------------------------------------
# placement data model
# ---------------------------------------------------------------------------


def test_topology_and_stage_placement_validation():
    with pytest.raises(PlacementError):
        DeviceTopology(n_devices=0)
    with pytest.raises(PlacementError):
        StagePlacement(cu_count=0, prefetch_depth=1, devices=())
    with pytest.raises(PlacementError):
        StagePlacement(cu_count=2, prefetch_depth=1, devices=(0,))
    with pytest.raises(PlacementError):
        StagePlacement(cu_count=2, prefetch_depth=1, devices=(0, 0))
    with pytest.raises(PlacementError):
        StagePlacement(cu_count=1, prefetch_depth=-1, devices=(0,))
    with pytest.raises(PlacementError):  # device outside the topology
        PlacementPlan(
            topology=DeviceTopology(1),
            stages=(StagePlacement(1, 1, (3,)),),
        )
    with pytest.raises(PlacementError):  # empty plan
        PlacementPlan(topology=DeviceTopology(1), stages=())


def test_assign_device_groups_disjoint_when_they_fit():
    t = DeviceTopology(4)
    groups = assign_device_groups(t, [1, 2, 1])
    assert groups == [(0,), (1, 2), (3,)]
    place = place_chain(t, [1, 2, 1], 1)
    assert place.contention == (1, 1, 1)
    assert place.disjoint()


def test_assign_device_groups_wrap_and_contention():
    t = DeviceTopology(2)
    groups = assign_device_groups(t, [1, 2, 1])
    assert groups == [(0,), (1, 0), (1,)]
    place = place_chain(t, [1, 2, 1], (2, 1, 1))
    # stage 1 owns both devices, so it overlaps both neighbors; each
    # neighbor overlaps stage 1 and itself
    assert place.contention == (2, 3, 2)
    assert not place.disjoint()
    assert place.cu_counts == (1, 2, 1)
    assert place.prefetch_depths == (2, 1, 1)
    # single device: everything piles onto device 0
    one = place_chain(DeviceTopology(1), [1, 1, 1], 1)
    assert one.device_groups == ((0,), (0,), (0,))
    assert one.contention == (3, 3, 3)


def test_place_chain_clamps_cu_to_topology():
    place = place_chain(DeviceTopology(2), [4, 1], (1, 1))
    assert place.cu_counts == (2, 1)
    with pytest.raises(PlacementError):
        place_chain(DeviceTopology(2), 1, (1, 1, 1))  # scalar needs n_stages
    broadcast = place_chain(DeviceTopology(2), 2, 0, n_stages=3)
    assert broadcast.cu_counts == (2, 2, 2)
    assert broadcast.prefetch_depths == (0, 0, 0)


# ---------------------------------------------------------------------------
# contention-aware chain cost
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def cfd_chain():
    return operators.build_cfd_chain(5)


def test_plan_chain_per_stage_cu_and_report(cfd_chain):
    plan = mchain.plan_chain(
        cfd_chain, target=channels.ALVEO_U280, batch_elements=256,
        prefetch_depth=(1, 1, 1), cu_count=(1, 2, 1),
        topology=DeviceTopology.homogeneous(4), n_eq=1 << 12,
    )
    assert plan.cu_counts == (1, 2, 1)
    assert plan.cu_count == 2  # widest stage (the legacy scalar view)
    assert [sp.cu_count for sp in plan.stages] == [1, 2, 1]
    assert plan.placement.disjoint()
    rep = plan.report()
    assert "placement: 4 device(s)" in rep
    assert "per-stage cu [1,2,1]" in rep
    assert "contention [1,1,1]" in rep
    assert "CU=2" in rep
    # determinism
    again = mchain.plan_chain(
        cfd_chain, target=channels.ALVEO_U280, batch_elements=256,
        prefetch_depth=(1, 1, 1), cu_count=(1, 2, 1),
        topology=DeviceTopology.homogeneous(4), n_eq=1 << 12,
    )
    assert plan == again and rep == again.report()


def test_plan_chain_shard_snap_preserves_block_alignment():
    """Regression: snapping the auto-sized E down to the CU-group LCM
    must not undo pad_batch_for_block's work -- a bare multiple of 3
    would collapse every stage's Pallas block divisor."""
    from repro.memory import layout

    ch = operators.build_cfd_chain(11)
    plan = mchain.plan_chain(
        ch, target=channels.ALVEO_U280, cu_count=(1, 3, 1),
        topology=DeviceTopology.homogeneous(4), n_eq=1 << 20,
    )
    assert plan.feasible and plan.batch_elements % 3 == 0
    for sp, s in zip(plan.stages, ch.stages):
        cap = layout.vmem_block_elements(
            s.program, channels.ALVEO_U280, bytes_per_scalar=4
        )
        # the padder's contract survives sharding: the block divisor is
        # never below half the stage's VMEM cap
        assert 2 * sp.block_elements >= min(cap, plan.batch_elements)


def test_plan_chain_batch_shards_evenly(cfd_chain):
    # auto-sized E is snapped down to a multiple of every CU group size
    auto = mchain.plan_chain(
        cfd_chain, target=channels.ALVEO_U280, cu_count=(1, 4, 2),
        topology=DeviceTopology.homogeneous(8), n_eq=1 << 12,
    )
    assert auto.feasible
    assert auto.batch_elements % 4 == 0
    # an explicit E that cannot shard evenly is reported, not silently run
    odd = mchain.plan_chain(
        cfd_chain, target=channels.ALVEO_U280, batch_elements=33,
        cu_count=2, topology=DeviceTopology.homogeneous(2),
    )
    assert not odd.feasible
    assert "shard evenly" in odd.infeasible_reason


def test_contention_prices_replication_vs_overlap(cfd_chain):
    """The same per-stage depths cost more on a shared device than on
    disjoint groups, and sharding a stage over g devices divides its
    device-side terms by g."""
    kw = dict(target=channels.ALVEO_U280, batch_elements=256, n_eq=1 << 12)
    shared1 = mchain.plan_chain(
        cfd_chain, prefetch_depth=1,
        topology=DeviceTopology.homogeneous(1), **kw
    )
    disjoint = mchain.plan_chain(
        cfd_chain, prefetch_depth=1,
        topology=DeviceTopology.homogeneous(3), **kw
    )
    assert shared1.cost.contention == (3, 3, 3)
    assert disjoint.cost.contention == (1, 1, 1)
    assert disjoint.cost.t_steady <= shared1.cost.t_steady * (1 + 1e-12)
    assert disjoint.cost.t_overlapped <= (
        shared1.cost.t_overlapped * (1 + 1e-12)
    )
    # overlap never beats back-to-back even fully contended
    assert shared1.cost.t_overlapped <= (
        shared1.cost.t_back_to_back * (1 + 1e-12)
    )
    # element sharding: cu=2 on stage 1 halves its compute/hbm terms
    wide = mchain.plan_chain(
        cfd_chain, prefetch_depth=1, cu_count=(1, 2, 1),
        topology=DeviceTopology.homogeneous(4), **kw
    )
    base = mchain.plan_chain(
        cfd_chain, prefetch_depth=1, cu_count=1,
        topology=DeviceTopology.homogeneous(4), **kw
    )
    assert wide.stages[1].cost.t_compute == pytest.approx(
        base.stages[1].cost.t_compute / 2
    )
    assert wide.stages[1].cost.t_hbm == pytest.approx(
        base.stages[1].cost.t_hbm / 2
    )


# ---------------------------------------------------------------------------
# hypothesis property: t_overlapped never beats the per-stage roofline
# ---------------------------------------------------------------------------


def _check_overlap_roofline_bound(cus, depths, n_devices, e, n_eq):
    chain = operators.build_cfd_chain(5)
    plan = mchain.plan_chain(
        chain, target=channels.ALVEO_U280, batch_elements=e,
        prefetch_depth=list(depths), cu_count=list(cus),
        topology=DeviceTopology.homogeneous(n_devices), n_eq=n_eq,
    )
    cost = plan.cost
    # per-stage roofline: no schedule can beat any stage's own
    # three-term bound at its granted CU count
    roofline = max(
        max(c.t_host, c.t_compute, c.t_hbm) + c.t_overhead
        for c in cost.stages
    )
    assert cost.t_overlapped >= roofline * (1 - 1e-12)
    # and the steady state never beats the contended per-stage bound
    assert cost.t_steady == max(cost.stage_steady_times)
    # pipelining never loses to back-to-back
    assert cost.t_overlapped <= cost.t_back_to_back * (1 + 1e-12)


try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI installs hypothesis
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @given(
        cus=st.tuples(*[st.sampled_from([1, 2, 4])] * 3),
        depths=st.tuples(*[st.integers(0, 3)] * 3),
        n_devices=st.integers(1, 8),
        e=st.sampled_from([64, 256, 512]),
        n_eq=st.sampled_from([512, 4096]),
    )
    @settings(max_examples=60, deadline=None)
    def test_t_overlapped_never_beats_stage_roofline(
        cus, depths, n_devices, e, n_eq
    ):
        _check_overlap_roofline_bound(cus, depths, n_devices, e, n_eq)

else:  # deterministic fallback so the property still runs everywhere

    @pytest.mark.parametrize("cus,depths,n_devices,e,n_eq", [
        ((1, 1, 1), (1, 1, 1), 1, 256, 4096),
        ((1, 2, 4), (2, 0, 1), 4, 512, 4096),
        ((4, 4, 4), (0, 0, 0), 2, 64, 512),
        ((2, 1, 2), (3, 2, 1), 8, 256, 512),
    ])
    def test_t_overlapped_never_beats_stage_roofline(
        cus, depths, n_devices, e, n_eq
    ):
        _check_overlap_roofline_bound(cus, depths, n_devices, e, n_eq)


# ---------------------------------------------------------------------------
# DSE: joint per-stage search + frontier monotonicity in device count
# ---------------------------------------------------------------------------


def test_explore_chain_ranks_per_stage_placements(cfd_chain):
    space = dse.ChainDesignSpace(
        backends=("xla",), batch_divisors=(1,),
        prefetch_depths=(0, 1), cu_counts=(1, 2), max_placements=8,
    )
    cands = dse.explore_chain(
        cfd_chain, target=channels.ALVEO_U280, n_eq=1 << 14, space=space,
        topology=DeviceTopology.homogeneous(4),
    )
    assert cands
    # the sweep emits genuinely per-stage vectors, every plan carries
    # its placement, and the ranking is by the contention-aware term
    assert any(len(set(c.plan.cu_counts)) > 1 for c in cands)
    assert any(
        len({sp.prefetch_depth for sp in c.plan.stages}) > 1
        for c in cands
    )
    for c in cands:
        assert c.plan.placement.topology.n_devices == 4
        assert c.predicted_s_per_element == pytest.approx(
            c.plan.cost.t_pipelined / c.plan.batch_elements
        )
    feas = [c for c in cands if c.plan.feasible]
    pred = [c.predicted_s_per_element for c in feas]
    assert pred == sorted(pred)


def test_explore_chain_frontier_monotone_in_device_count(cfd_chain):
    """More devices never rank a slower best plan: options only grow
    and contention only falls (the monotone frontier the issue asks
    for)."""
    space = dse.ChainDesignSpace(
        backends=("xla",), batch_divisors=(1,),
        prefetch_depths=(0, 1, 2), cu_counts=(1, 2, 4), max_placements=8,
    )
    best_by_n = []
    for n in (1, 2, 3, 4, 8):
        cands = dse.explore_chain(
            cfd_chain, target=channels.ALVEO_U280, n_eq=1 << 14,
            space=space, topology=DeviceTopology.homogeneous(n),
        )
        best = next(c for c in cands if c.plan.feasible)
        best_by_n.append(best.predicted_s_per_element)
    for prev, cur in zip(best_by_n, best_by_n[1:]):
        assert cur <= prev * (1 + 1e-12)


def test_search_stage_placements_prunes_but_keeps_best():
    """The branch-and-bound search finds the same best vector as brute
    force over a small joint space."""
    import itertools

    from repro.memory.dse import _search_stage_placements
    from repro.memory.placement import place_chain as place

    chain = operators.build_cfd_chain(5)
    topo = DeviceTopology.homogeneous(2)
    space = dse.ChainDesignSpace(
        backends=("xla",), batch_divisors=(1,),
        prefetch_depths=(0, 1), cu_counts=(1, 2), max_placements=4,
    )
    ref = mchain.plan_chain(
        chain, target=channels.ALVEO_U280, batch_elements=256,
        prefetch_depth=1, cu_count=1, topology=topo, n_eq=1 << 12,
    )
    got = _search_stage_placements(
        [sp.cost for sp in ref.stages], space, topo, 256
    )
    assert 0 < len(got) <= 4
    # brute-force the full joint space through the real planner and
    # check the search's best vector prices within it
    def plan_t(cus, depths):
        p = mchain.plan_chain(
            chain, target=channels.ALVEO_U280, batch_elements=256,
            prefetch_depth=list(depths), cu_count=list(cus),
            topology=topo, n_eq=1 << 12,
        )
        return p.cost.t_pipelined

    opts = list(itertools.product((1, 2), (0, 1)))
    brute = min(
        plan_t(cus, depths)
        for joint in itertools.product(opts, repeat=3)
        for cus, depths in [tuple(zip(*joint))]
    )
    best_searched = min(plan_t(cus, depths) for cus, depths in got)
    assert best_searched <= brute * 1.05  # proxy-scored, near-exact here


# ---------------------------------------------------------------------------
# executor: place_fns hook + single-device fallback
# ---------------------------------------------------------------------------


def test_run_stage_pipelined_place_fns_hook():
    """place_fns runs before each stage consumes a batch and its
    rewrites are what the stage sees (the reshard hook)."""
    calls = []

    def place0(staged, carry):
        calls.append(("p0", staged))
        return staged + 100, carry

    def stage0(staged, carry):
        return staged

    def stage1(staged, carry):
        return carry * 2

    out = mempipe.run_stage_pipelined(
        [stage0, stage1], range(3), depths=(0, 1),
        place_fns=[place0, None],
    )
    assert out == [200, 202, 204]
    assert [c[1] for c in calls] == [0, 1, 2]
    with pytest.raises(ValueError, match="place fns"):
        mempipe.run_stage_pipelined(
            [stage0, stage1], range(2), depths=0, place_fns=[place0],
        )


def test_placement_meshes_single_device_degenerates():
    place = place_chain(DeviceTopology(1), [1, 1, 1], 1)
    assert mempipe.placement_meshes(place) is None  # today's path
    big = place_chain(DeviceTopology(4), [1, 2, 1], 1)
    assert mempipe.placement_meshes(big, devices=["d0"]) is None  # too few
    got = mempipe.placement_meshes(big, devices=["d0", "d1", "d2", "d3"])
    assert got == [("d0",), ("d1", "d2"), ("d3",)]


def test_run_chain_single_device_fallback_bitwise(cfd_chain, rng):
    """On one device every placement degenerates to the pre-placement
    path: same results bitwise, no placement groups recorded."""
    p, E, n_b = 5, 16, 3
    n = E * n_b
    inputs = {
        "interp.u": rng.uniform(-1, 1, (n, p, p, p)).astype(np.float32),
        "helmholtz.D": rng.uniform(-1, 1, (n, p, p, p)).astype(np.float32),
    }
    shared = {
        name: rng.uniform(-1, 1, node.shape).astype(np.float32)
        for name, node in sorted(cfd_chain.shared_operands().items())
    }
    plain = mchain.plan_chain(
        cfd_chain, target=channels.CPU_HOST, batch_elements=E, n_eq=n,
        prefetch_depth=(2, 1, 1),
    )
    a = simulation.run_chain(
        cfd_chain, plain, inputs=inputs, shared=shared,
        collect_outputs=True,
    )
    assert a.placement_groups is None
    # a plan placed for a bigger machine than this one falls back to the
    # local mesh with a warning -- and still matches bitwise
    wide = mchain.plan_chain(
        cfd_chain, target=channels.CPU_HOST, batch_elements=E, n_eq=n,
        prefetch_depth=(2, 1, 1), cu_count=(1, 2, 1),
        topology=DeviceTopology.homogeneous(2),
    )
    with pytest.warns(RuntimeWarning, match="are local"):
        b = simulation.run_chain(
            cfd_chain, wide, inputs=inputs, shared=shared,
            collect_outputs=True,
        )
    assert b.placement_groups is None
    for q in a.outputs:
        assert np.array_equal(a.outputs[q], b.outputs[q]), q


# ---------------------------------------------------------------------------
# acceptance: multi-device placement executes bitwise-equal (subprocess)
# ---------------------------------------------------------------------------

SHARDED_SCRIPT = textwrap.dedent("""
    import json
    import numpy as np
    import jax

    from repro.cfd import operators, simulation
    from repro.memory import chain as mchain
    from repro.memory import channels, dse
    from repro.memory.placement import DeviceTopology

    assert jax.device_count() == 2, jax.devices()
    p, E, n_b = 5, 16, 4
    n = E * n_b
    chain = operators.build_cfd_chain(p)
    rng = np.random.default_rng(0)
    inputs = {
        "interp.u": rng.uniform(-1, 1, (n, p, p, p)).astype(np.float32),
        "helmholtz.D": rng.uniform(-1, 1, (n, p, p, p)).astype(np.float32),
    }
    shared = {
        name: rng.uniform(-1, 1, node.shape).astype(np.float32)
        for name, node in sorted(chain.shared_operands().items())
    }

    # the DSE ranks joint per-stage placements over the 2-device
    # topology; execute its top-ranked multi-device candidate
    space = dse.ChainDesignSpace(
        backends=("xla",), batch_divisors=(1,),
        prefetch_depths=(0, 1, 2), cu_counts=(1, 2), max_placements=8,
    )
    cands = dse.explore_chain(
        chain, target=channels.CPU_HOST, n_eq=n, space=space,
        topology=DeviceTopology.homogeneous(2),
    )
    top_multi = next(
        c for c in cands
        if c.plan.feasible and len(set(c.plan.placement.devices_used)) > 1
    )
    plan = mchain.plan_chain(
        chain, target=channels.CPU_HOST, batch_elements=E, n_eq=n,
        placement=top_multi.plan.placement,
    )
    piped = simulation.run_chain(
        chain, plan, inputs=inputs, shared=shared, collect_outputs=True,
    )
    assert piped.placement_groups is not None

    # serial single-device baseline: same chain, stages back-to-back on
    # one device, no staging
    base_plan = mchain.plan_chain(
        chain, target=channels.CPU_HOST, batch_elements=E, n_eq=n,
        prefetch_depth=0,
    )
    base = simulation.run_chain(
        chain, base_plan, inputs=inputs, shared=shared,
        collect_outputs=True, pipeline_stages=False,
    )
    assert base.placement_groups is None and not base.pipelined_stages

    equal = all(
        np.array_equal(base.outputs[q], piped.outputs[q])
        for q in base.outputs
    )
    print(json.dumps({
        "equal": bool(equal),
        "groups": [list(g) for g in piped.placement_groups],
        "pipelined": bool(piped.pipelined_stages),
        "cu_counts": list(plan.cu_counts),
    }))
""")


@pytest.mark.slow
def test_top_ranked_multi_device_placement_bitwise_equal_subprocess():
    """Acceptance: the DSE's top multi-device placement executes
    bitwise-equal to the serial single-device baseline (2 forced host
    devices; sharded intra-stage, resharded handoff between groups)."""
    import json

    res = subprocess.run(
        [sys.executable, "-c", SHARDED_SCRIPT],
        env=subprocess_env(2), capture_output=True, text=True, timeout=420,
    )
    assert res.returncode == 0, res.stderr[-3000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert out["equal"] is True
    assert len(out["groups"]) == 3
    assert any(len(set(g)) > 1 for g in out["groups"]) or (
        len({tuple(g) for g in out["groups"]}) > 1
    )
