"""repro.memory: planner determinism, paper batch sizing, DSE cost-model
monotonicity, and end-to-end prefetch-pipeline equivalence."""
import warnings

import jax
import numpy as np
import pytest

from repro.cfd import operators, simulation
from repro.cfd.simulation import SimConfig
from repro.core import dsl, emit, rewrite, schedule
from repro.memory import channels, dse, layout
from repro.memory import pipeline as mempipe


def _helmholtz_prog(p):
    return rewrite.optimize(
        dsl.parse(
            dsl.INVERSE_HELMHOLTZ_SRC.format(p=p),
            element_vars=("u", "D", "v"),
        )
    )


# ---------------------------------------------------------------------------
# plan construction
# ---------------------------------------------------------------------------


def test_plan_determinism():
    """Same inputs -> identical plan (buffers, channels, cost, report)."""
    a = dse.make_plan(11, target=channels.ALVEO_U280)
    b = dse.make_plan(11, target=channels.ALVEO_U280)
    assert a == b
    assert a.report() == b.report()


def test_auto_batch_matches_paper_channel_sizing():
    """The planner's E equals SimConfig.batch_for_channel for the paper's
    256 MB pseudo-channel (Alveo U280: 8 GiB HBM2 / 32 channels), up to
    the block padding that keeps E a VMEM-block multiple (prime-ish
    channel quotients must never force the Pallas block divisor tiny)."""
    t = channels.ALVEO_U280
    assert t.channel_bytes == 256 * 2 ** 20
    for p in (7, 11):
        plan = dse.make_plan(p, target=t, policy="float32")
        base = plan.batch_elements - plan.batch_pad_elements
        assert base == SimConfig.batch_for_channel(p, t.channel_bytes, 4)
        # padding did its job: E is block-composite, never block-starved
        assert plan.batch_elements % plan.block_elements == 0
        assert plan.block_elements * 2 >= layout.vmem_block_elements(
            rewrite.optimize(dsl.inverse_helmholtz_program(p)), t,
            bytes_per_scalar=4,
        )
        if plan.batch_pad_elements:
            assert "E auto-padded" in plan.report()


def test_auto_batch_capped_by_problem_size():
    plan = dse.make_plan(11, target=channels.ALVEO_U280, n_eq=1000)
    assert plan.batch_elements == 1000


def test_pad_batch_for_block():
    """The E auto-padding rule: prime-ish batches round up to a block
    multiple, composite-enough batches are left alone, and a problem-
    size limit snaps down instead of padding past the data."""
    assert layout.pad_batch_for_block(1021, 128) == (1024, 3)   # prime
    assert layout.pad_batch_for_block(1000, 512) == (1000, 0)   # 500 | E
    assert layout.pad_batch_for_block(100, 128) == (100, 0)     # E <= cap
    assert layout.pad_batch_for_block(7, 1) == (7, 0)
    assert layout.pad_batch_for_block(1021, 128, limit=1023) == (896, -125)
    # chain form: E composite for the largest cap can still starve a
    # smaller-cap stage (1018 = 2 * 509: fine for 512, block 2 for 256)
    assert layout.pad_batch_for_block(1018, 512) == (1018, 0)
    assert layout.pad_batch_for_block(
        1018, 512, caps=(512, 256)
    ) == (1024, 6)


def test_plan_buffers_and_channels():
    plan = dse.make_plan(11, target=channels.ALVEO_U280, prefetch_depth=1)
    roles = {b.name: b.role for b in plan.buffers}
    assert roles == {"D": "in", "u": "in", "v": "out", "S": "shared"}
    ins = [b for b in plan.buffers if b.role == "in"]
    # K=1 prefetch: ping/pong pair + the retiring batch JAX frees only
    # after its async compute completes = 3 resident replicas
    assert all(b.replicas == 3 for b in ins)
    serial = dse.make_plan(
        11, target=channels.ALVEO_U280, prefetch_depth=0
    )
    assert all(
        b.replicas == 1 for b in serial.buffers if b.role == "in"
    )
    # burst packing: padded to the 64 B AXI quantum, never smaller
    for b in plan.buffers:
        assert b.padded_bytes >= b.element_bytes
        assert b.padded_bytes % channels.ALVEO_U280.burst_bytes == 0
    assert 0 < plan.channels_used <= channels.ALVEO_U280.n_channels
    assert plan.feasible


def test_staged_plan_has_intermediate_buffers():
    plan = dse.make_plan(11, target=channels.ALVEO_U280, backend="staged")
    inters = [b for b in plan.buffers if b.role == "inter"]
    assert inters, "staged backend must expose group-boundary streams"
    # intermediates cross HBM twice (write + read back)
    assert plan.hbm_stream_bytes > plan.host_stream_bytes


def test_infeasible_plan_reported_not_raised():
    tiny = channels.ALVEO_U280.with_(hbm_bytes=2 ** 20, n_channels=4)
    plan = dse.make_plan(11, target=tiny, batch_elements=4096)
    assert not plan.feasible
    assert "exceeds" in plan.infeasible_reason
    assert "NO" in plan.report()


# ---------------------------------------------------------------------------
# DSE cost model
# ---------------------------------------------------------------------------


def test_cost_monotone_in_bandwidth():
    """More bandwidth must never predict a slower plan."""
    base_t = channels.ALVEO_U280
    points = [
        dict(backend="xla", prefetch_depth=0),
        dict(backend="xla", prefetch_depth=1),
        dict(backend="xla", prefetch_depth=4, cu_count=4),
        dict(backend="staged", prefetch_depth=1),
        dict(backend="staged", prefetch_depth=2, policy="bfloat16"),
    ]
    for kw in points:
        prev = dse.make_plan(11, target=base_t, n_eq=1 << 16, **kw)
        for scale in (2.0, 4.0, 16.0):
            t = base_t.with_(
                hbm_bw=base_t.hbm_bw * scale,
                host_link_bw=base_t.host_link_bw * scale,
            )
            cur = dse.make_plan(11, target=t, n_eq=1 << 16, **kw)
            assert cur.cost.t_pipelined <= prev.cost.t_pipelined * (1 + 1e-12)
            assert cur.cost.t_serial <= prev.cost.t_serial * (1 + 1e-12)
            prev = cur


def test_cost_overlap_never_slower_than_serial():
    for depth in (1, 2, 4):
        plan = dse.make_plan(
            11, target=channels.ALVEO_U280, prefetch_depth=depth,
            n_eq=1 << 20,
        )
        assert plan.cost.t_pipelined <= plan.cost.t_serial * (1 + 1e-12)
        assert plan.cost.overlap_speedup >= 1.0 - 1e-12


def test_explore_returns_ranked_set():
    cands = dse.explore(11, target=channels.ALVEO_U280, n_eq=1 << 16)
    assert len(cands) > 20
    feas = [c for c in cands if c.plan.feasible]
    assert feas, "the paper's operating point must be feasible"
    # ranked: feasible first, then by predicted time per element
    pred = [c.predicted_s_per_element for c in feas]
    assert pred == sorted(pred)
    assert all(c.plan.feasible for c in cands[: len(feas)])
    front = dse.pareto_front(cands)
    assert front
    assert all(c.plan.feasible for c in front)
    assert set(id(c) for c in front) <= set(id(c) for c in cands)


@pytest.mark.slow
def test_explore_measures_top_candidate():
    space = dse.DesignSpace(
        backends=("xla",), policies=("float32",), batch_divisors=(1,),
        prefetch_depths=(0, 1), cu_counts=(1,),
    )
    cands = dse.explore(
        5, target=channels.CPU_HOST, n_eq=256, space=space, measure_top=1,
        measure_batches=2,
    )
    assert any(c.verified for c in cands)
    best = next(c for c in cands if c.verified)
    assert best.measured_s_per_element > 0


# ---------------------------------------------------------------------------
# transfer pipeline
# ---------------------------------------------------------------------------


def test_prefetch_depth_semantics():
    staged_log = []
    consumed = []

    def stage(x):
        staged_log.append(x)
        return x

    for x in mempipe.prefetch(range(5), stage, depth=2):
        # when item k is consumed, items up to k+2 are already staged
        consumed.append(x)
        assert len(staged_log) >= min(5, len(consumed) + 2)
    assert consumed == list(range(5))
    with pytest.raises(ValueError):
        list(mempipe.prefetch(range(3), stage, depth=-1))


def test_pipelined_run_bitwise_matches_serial(rng):
    """K-deep prefetch + deferred sync must be bit-identical to the
    serial baseline (paper Fig. 14a: ping/pong changes nothing)."""
    p, E = 5, 16
    c = operators.build_inverse_helmholtz(p)
    S = rng.uniform(-1, 1, (p, p)).astype(np.float32)
    batches = [
        {
            "D": rng.uniform(-1, 1, (E, p, p, p)).astype(np.float32),
            "u": rng.uniform(-1, 1, (E, p, p, p)).astype(np.float32),
        }
        for _ in range(4)
    ]

    def compute(staged):
        return c.batched_fn({"S": S, **staged})["v"]

    stage = lambda b: {k: jax.device_put(v) for k, v in b.items()}
    serial = mempipe.run_pipelined(
        compute, batches, stage_fn=stage, depth=0
    )
    deep = mempipe.run_pipelined(
        compute, batches, stage_fn=stage, depth=2
    )
    assert len(serial) == len(deep) == 4
    for a, b in zip(serial, deep):
        assert a.dtype == b.dtype
        assert np.array_equal(np.asarray(a), np.asarray(b))


def _record_stage(log, name, transform):
    """A stage fn that logs its dispatch order and applies a pure
    transform to the carry (stage 0 receives carry=None)."""

    def fn(staged, carry):
        log.append((name, staged))
        return transform(staged if carry is None else carry)

    return fn


def test_stage_pipelined_depth0_matches_serial_composition():
    """All-zero depths degrade to the back-to-back schedule: results and
    dispatch order are exactly the serial composition's."""
    log = []
    fns = [
        _record_stage(log, "s0", lambda x: x * 10),
        _record_stage(log, "s1", lambda x: x + 1),
    ]
    out = mempipe.run_stage_pipelined(fns, range(3), depths=0)
    assert out == [1, 11, 21]
    assert log == [
        ("s0", 0), ("s1", 0), ("s0", 1), ("s1", 1), ("s0", 2), ("s1", 2),
    ]


def test_stage_pipelined_skews_dispatch_order():
    """With inter-stage ring depth 1, stage 1 of batch k-1 is dispatched
    in the same tick as stage 0 of batch k -- the tentpole's software-
    pipelined interleaving -- and results still come back in batch
    order."""
    log = []
    fns = [
        _record_stage(log, "s0", lambda x: x * 10),
        _record_stage(log, "s1", lambda x: x + 1),
    ]
    out = mempipe.run_stage_pipelined(fns, range(4), depths=(1, 1))
    assert out == [1, 11, 21, 31]
    assert log == [
        ("s0", 0),
        ("s0", 1), ("s1", 0),
        ("s0", 2), ("s1", 1),
        ("s0", 3), ("s1", 2),
        ("s1", 3),
    ]


def test_stage_pipelined_fill_drain_with_fewer_batches_than_depth():
    """n_batches < total skew: every batch still flows through every
    stage exactly once, in order, and the drain retires them in batch
    order."""
    log = []
    fns = [
        _record_stage(log, "s0", lambda x: x + 1),
        _record_stage(log, "s1", lambda x: x * 2),
        _record_stage(log, "s2", lambda x: x - 3),
    ]
    out = mempipe.run_stage_pipelined(fns, range(2), depths=(4, 3, 3))
    assert out == [(0 + 1) * 2 - 3, (1 + 1) * 2 - 3]
    for k in range(2):
        assert [n for n, s in log if s == k] == ["s0", "s1", "s2"]
    assert out == mempipe.run_stage_pipelined(fns, range(2), depths=0)
    # an empty batch source is a no-op at any depth
    assert mempipe.run_stage_pipelined(fns, [], depths=(4, 3, 3)) == []


def test_stage_pipelined_reduce_and_defer_sync():
    """reduce_fn maps the last stage's carry before any sync; deferred
    sync holds exactly one realized value back until the next batch."""
    events = []

    def reduce_fn(x):
        events.append(("reduce", x.v))
        return x

    class Traced:
        """Quacks enough like a device value to observe device_get."""

        def __init__(self, v):
            self.v = v

        def __array__(self, *a, **kw):  # jax.device_get realizes via this
            events.append(("sync", self.v))
            return np.asarray(self.v)

    fns = [lambda staged, carry: Traced(staged * 10)]
    out = mempipe.run_stage_pipelined(
        fns, range(3), depths=1, reduce_fn=reduce_fn, defer_sync=True
    )
    assert [int(x) for x in out] == [0, 10, 20]
    # deferred: batch k's sync happens only after batch k+1 was reduced
    # (the dispatch queue never drains mid-run)
    assert events == [
        ("reduce", 0), ("reduce", 10), ("sync", 0),
        ("reduce", 20), ("sync", 10), ("sync", 20),
    ]
    # defer_sync=False realizes each batch immediately after its reduce
    events.clear()
    out = mempipe.run_stage_pipelined(
        fns, range(2), depths=0, reduce_fn=reduce_fn
    )
    assert [int(x) for x in out] == [0, 10]
    assert events == [
        ("reduce", 0), ("sync", 0), ("reduce", 10), ("sync", 10),
    ]


def test_stage_pipelined_validates_arguments():
    fns = [lambda s, c: s]
    with pytest.raises(ValueError, match="at least one stage"):
        mempipe.run_stage_pipelined([], range(2))
    with pytest.raises(ValueError, match=">= 0"):
        mempipe.run_stage_pipelined(fns, range(2), depths=-1)
    with pytest.raises(ValueError, match="stage depths"):
        mempipe.run_stage_pipelined(fns, range(2), depths=(1, 1))


def test_stage_pipelined_bitwise_matches_serial_on_device(rng):
    """The skewed schedule changes dispatch order only: device results
    are bit-identical to the serial composition (paper Fig. 14a
    generalized across stages)."""
    p, E = 5, 8
    c = operators.build_inverse_helmholtz(p)
    S = rng.uniform(-1, 1, (p, p)).astype(np.float32)
    batches = [
        {
            "D": rng.uniform(-1, 1, (E, p, p, p)).astype(np.float32),
            "u": rng.uniform(-1, 1, (E, p, p, p)).astype(np.float32),
        }
        for _ in range(4)
    ]
    fns = [
        lambda staged, carry: c.batched_fn({"S": S, **staged})["v"],
        lambda staged, carry: carry * 2.0,
    ]
    stage = lambda b: {k: jax.device_put(v) for k, v in b.items()}
    serial = mempipe.run_stage_pipelined(
        fns, batches, stage_fn=stage, depths=0
    )
    skewed = mempipe.run_stage_pipelined(
        fns, batches, stage_fn=stage, depths=(2, 1)
    )
    assert len(serial) == len(skewed) == 4
    for a, b in zip(serial, skewed):
        assert a.dtype == b.dtype
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_simulation_driver_plan_resolves_batch():
    """No hardcoded E: the planner sizes the batch from the channel model."""
    cfg = SimConfig(p=5, n_eq=512)  # batch_elements unset
    res = simulation.run_simulation(cfg, max_batches=2)
    assert res.plan is not None
    assert res.plan.batch_elements >= 1
    assert res.elements == res.batches * res.plan.batch_elements
    assert np.isfinite(res.checksum)
    with pytest.raises(ValueError):
        cfg.n_batches  # unresolved config cannot count batches


def test_simulation_checksum_invariant_to_prefetch_depth():
    res = {}
    for depth in (0, 1, 3):
        cfg = SimConfig(
            p=5, n_eq=256, batch_elements=64, prefetch_depth=depth
        )
        res[depth] = simulation.run_simulation(cfg, max_batches=4).checksum
    assert res[0] == pytest.approx(res[1], abs=1e-6)
    assert res[0] == pytest.approx(res[3], abs=1e-6)


# ---------------------------------------------------------------------------
# wiring: schedule stream bytes, emit donation, roofline constants
# ---------------------------------------------------------------------------


def test_schedule_exposes_stream_bytes():
    sch = schedule.schedule(_helmholtz_prog(7), bytes_per_scalar=4)
    io = sch.stream_io_bytes(4)
    assert set(io) == {g.name for g in sch.groups}
    for g in sch.groups:
        ins, outs = io[g.name]
        assert ins == g.in_stream_bytes(4) > 0
        assert outs == g.out_stream_bytes(4) > 0
    # the last group streams the program output: p^3 scalars
    assert sch.groups[-1].out_stream_bytes(4) >= 7 ** 3 * 4
    assert sch.stream_bytes(4)[sch.groups[-1].name] >= 7 ** 3 * 4


def test_emit_accepts_donation_hints(rng):
    p, E = 5, 8
    prog = _helmholtz_prog(p)
    plain = emit.compile_program(prog)
    with warnings.catch_warnings():
        # CPU backends may ignore donation with a warning; the hint must
        # never change results
        warnings.simplefilter("ignore")
        donated = emit.compile_program(prog, donate_args=("D", "u"))
        assert donated.donate_args == ("D", "u")
        S = rng.uniform(-1, 1, (p, p)).astype(np.float32)
        env = {
            "S": S,
            "D": rng.uniform(-1, 1, (E, p, p, p)).astype(np.float32),
            "u": rng.uniform(-1, 1, (E, p, p, p)).astype(np.float32),
        }
        want = np.asarray(plain.batched_fn(dict(env))["v"])
        got = np.asarray(
            donated.batched_fn(
                {k: jax.device_put(v) for k, v in env.items()}
            )["v"]
        )
    assert np.array_equal(want, got)
    with pytest.raises(ValueError):
        emit.compile_program(prog, donate_args=("nope",))


def test_roofline_shares_channel_constants():
    from repro.analysis import roofline

    assert roofline.PEAK_FLOPS_BF16 == channels.TPU_V5E.peak_flops
    assert roofline.HBM_BW == channels.TPU_V5E.hbm_bw
    assert roofline.ICI_LINK_BW == channels.TPU_V5E.ici_bw


def test_layout_stream_bytes_match_simconfig_model():
    prog = _helmholtz_prog(11)
    assert layout.stream_bytes_per_element(prog, 4) == SimConfig(
        p=11
    ).bytes_per_element(4)
