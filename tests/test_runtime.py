"""Runtime: checkpoint atomicity, data determinism/resume, fault-tolerant
loop, monitor, optimizer."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.data import PrefetchPipeline, TokenStream
from repro.models import build_model
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from repro.runtime.monitor import (StepMonitor, plan_elastic_remesh,
                                   rebalance_batch)
from repro.runtime.train import (LoopConfig, TrainLoop, init_train_state,
                                 make_train_step)


def _tiny_model():
    cfg = configs.get_smoke("internlm2-1.8b")
    return cfg, build_model(cfg, attn_impl="xla")


# -- optimizer ----------------------------------------------------------------

def test_adamw_minimizes_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    opt = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=200, grad_clip=100.0)
    state = adamw_init(params)
    for _ in range(150):
        g = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(opt, g, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_grad_clip_and_schedule():
    opt = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(cosine_schedule(opt, jnp.int32(0))) == 0.0
    assert abs(float(cosine_schedule(opt, jnp.int32(10))) - 1.0) < 1e-6
    assert float(cosine_schedule(opt, jnp.int32(100))) == pytest.approx(
        opt.min_lr_frac, rel=1e-5
    )
    params = {"w": jnp.ones(3)}
    state = adamw_init(params)
    _, _, metrics = adamw_update(
        opt, {"w": jnp.full(3, 1e6)}, state, params
    )
    assert float(metrics["grad_norm"]) > 1e6  # reported unclipped


# -- checkpoint ----------------------------------------------------------------

def test_checkpoint_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"a": jnp.arange(5), "nested": {"b": jnp.ones((2, 3))}}
    for s in (1, 2, 3):
        mgr.save(state, step=s)
    assert mgr.latest_step() == 3
    dirs = [d for d in os.listdir(tmp_path) if d.startswith("step_")]
    assert len(dirs) == 2  # gc kept 2
    like = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state
    )
    restored = mgr.restore(like)
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.arange(5))


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save({"x": jnp.zeros(4)}, step=7, blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 7


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save({"x": jnp.zeros(4)}, step=1)
    with pytest.raises(ValueError):
        mgr.restore({"x": jax.ShapeDtypeStruct((5,), jnp.float32)})


# -- data ----------------------------------------------------------------------

def test_tokenstream_deterministic_and_resumable():
    a = TokenStream(vocab=64, batch=2, seq_len=8, seed=3)
    b1 = [next(a) for _ in range(3)]
    resumed = TokenStream(vocab=64, batch=2, seq_len=8, seed=3, start_step=2)
    b2 = next(resumed)
    np.testing.assert_array_equal(b1[2]["tokens"], b2["tokens"])


def test_prefetch_matches_source():
    src = TokenStream(vocab=64, batch=2, seq_len=8, seed=5)
    ref = TokenStream(vocab=64, batch=2, seq_len=8, seed=5)
    pf = PrefetchPipeline(src)
    for _ in range(3):
        a, b = next(pf), next(ref)
        np.testing.assert_array_equal(np.asarray(a["tokens"]), b["tokens"])
    pf.close()


# -- loop + fault tolerance ------------------------------------------------------

def test_trainloop_checkpoint_resume(tmp_path):
    cfg, model = _tiny_model()
    opt = AdamWConfig(lr=1e-3)
    step = jax.jit(make_train_step(model, opt))
    data = TokenStream(vocab=cfg.vocab, batch=2, seq_len=16, cfg=cfg)
    mgr = CheckpointManager(str(tmp_path))
    state = init_train_state(model, jax.random.PRNGKey(0))
    loop = TrainLoop(
        step, state, iter(data),
        cfg=LoopConfig(total_steps=4, checkpoint_every=2),
        checkpointer=mgr,
    )
    final = loop.run()
    assert mgr.latest_step() == 4

    # resume from checkpoint: step counter and params come back
    like = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), final
    )
    restored = mgr.restore(like)
    assert int(restored["step"]) == 4
    data2 = TokenStream(
        vocab=cfg.vocab, batch=2, seq_len=16, cfg=cfg, start_step=4
    )
    loop2 = TrainLoop(
        step, restored, iter(data2),
        cfg=LoopConfig(total_steps=6, checkpoint_every=10),
        checkpointer=mgr,
    )
    loop2.run()
    assert len(loop2.history) == 2  # steps 4,5


def test_trainloop_retry_then_checkpoint_on_failure(tmp_path):
    cfg, model = _tiny_model()
    opt = AdamWConfig(lr=1e-3)
    real_step = jax.jit(make_train_step(model, opt))
    calls = {"n": 0}

    def flaky_step(state, batch):
        calls["n"] += 1
        if calls["n"] == 2:  # transient fault once
            raise RuntimeError("simulated device failure")
        return real_step(state, batch)

    data = TokenStream(vocab=cfg.vocab, batch=2, seq_len=16, cfg=cfg)
    mgr = CheckpointManager(str(tmp_path))
    loop = TrainLoop(
        flaky_step, init_train_state(model, jax.random.PRNGKey(0)),
        iter(data), cfg=LoopConfig(total_steps=3, max_retries=1),
        checkpointer=mgr,
    )
    loop.run()
    assert len(loop.history) == 3  # recovered via retry


# -- monitor / elastic ---------------------------------------------------------

def test_straggler_detection():
    mon = StepMonitor(straggler_factor=2.0, warmup=0)
    assert not mon.record(1.0)
    for _ in range(5):
        assert not mon.record(1.0)
    assert mon.record(5.0)          # flagged
    assert not mon.record(1.0)      # ewma not poisoned


def test_flagged_step_still_updates_ewma_damped():
    """A flagged step moves the EWMA -- at the damped weight, not the
    normal one -- so one outlier cannot poison the baseline but a
    persistent slowdown eventually re-baselines."""
    mon = StepMonitor(straggler_factor=2.0, warmup=0)
    mon.record(1.0)  # seeds the EWMA
    before = mon.ewma
    assert mon.record(10.0)          # flagged...
    assert mon.ewma > before         # ...but the EWMA still moved
    # and by the damped weight, not the full alpha
    expect = (1 - mon.flagged_alpha) * before + mon.flagged_alpha * 10.0
    assert mon.ewma == pytest.approx(expect)
    assert mon.ewma < (1 - mon.alpha) * before + mon.alpha * 10.0


def test_persistent_slowdown_rebaselines():
    mon = StepMonitor(straggler_factor=2.0, warmup=0, flagged_alpha=0.3)
    mon.record(1.0)
    flags = [mon.record(5.0) for _ in range(30)]
    assert flags[0]          # the jump is flagged at first...
    assert not flags[-1]     # ...but not forever: the baseline adapted
    assert mon.flags         # flag history kept for the trace annotations


def test_elastic_remesh_plan():
    assert plan_elastic_remesh(256, model_axis=16) == (16, 16)
    assert plan_elastic_remesh(248, model_axis=16) == (15, 16)
    with pytest.raises(ValueError):
        plan_elastic_remesh(8, model_axis=16)
    assert rebalance_batch(256, 15) == 255


def test_request_latency_delegates_to_metrics_histogram():
    """RequestLatency is a facade over repro.metrics.Histogram -- same
    counts, same window, same nearest-rank quantile -- with its public
    summary() keys unchanged."""
    from repro.metrics import Histogram
    from repro.runtime.monitor import RequestLatency

    rl = RequestLatency(window=8)
    ref = Histogram(window=8)
    xs = [0.01 * (i + 1) for i in range(20)]
    for x in xs:
        rl.record(x)
        ref.observe(x)
    # whole-run aggregates delegate exactly
    assert rl.count == ref.count == 20
    assert rl.total_s == ref.sum
    assert rl.max_s == ref.max
    # quantiles are the histogram's (recent-window nearest rank)
    for q in (0.0, 0.5, 0.95, 1.0):
        assert rl.quantile(q) == ref.quantile(q)
    s = rl.summary()
    assert sorted(s) == ["count", "max_s", "mean_s", "p50_s", "p95_s"]
    assert s["count"] == 20.0
    assert s["mean_s"] == pytest.approx(sum(xs) / len(xs))
    assert s["p95_s"] == ref.quantile(0.95)
    # empty tracker reports zeros, not NaNs
    assert RequestLatency().summary() == {
        "count": 0.0, "mean_s": 0.0, "p50_s": 0.0, "p95_s": 0.0,
        "max_s": 0.0}


def test_step_monitor_summary_histogram_backed():
    mon = StepMonitor(straggler_factor=2.0, warmup=0)
    for _ in range(6):
        mon.record(1.0)
    mon.record(5.0)  # flagged
    s = mon.summary()
    assert s["count"] == 7.0 and s["max_s"] == 5.0
    assert s["flagged"] == 1.0
    assert s["flag_rate"] == pytest.approx(1 / 7)
    assert s["p50_s"] == 1.0
