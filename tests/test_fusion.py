"""Cost-driven stage fusion (memory.fusion).  Acceptance: mechanical
merging is bitwise-neutral through the real chain driver, ``max_stages=1``
fully fuses, named cuts are never merged across, fused stages re-enter
Pallas pattern matching, and the greedy decision never adopts a plan the
cost model prices worse than the unfused baseline."""
import numpy as np
import pytest

from repro import flow
from repro.cfd import operators, simulation
from repro.flow import patterns
from repro.memory import chain as mchain
from repro.memory import channels, dse, fusion


def _run(chain, plan, inputs_by_var, shared, n):
    """Route full input arrays to whichever stage hosts each element
    stream (stage names differ between fused and unfused chains)."""
    inputs = {}
    for i, s in enumerate(chain.stages):
        for name, _ in chain.host_element_inputs(i):
            inputs[f"{s.name}.{name}"] = inputs_by_var[name]
    res = simulation.run_chain(
        chain, plan, inputs=inputs, shared=shared, collect_outputs=True,
    )
    return {q.split(".", 1)[1]: v for q, v in res.outputs.items()}


def _cfd_data(rng, p, n):
    u = rng.uniform(-1, 1, (n, p, p, p)).astype(np.float32)
    D = rng.uniform(-1, 1, (n, p, p, p)).astype(np.float32)
    shared = {
        name: rng.uniform(-1, 1, (p, p)).astype(np.float32)
        for name in ("A", "Dx", "Dy", "Dz", "S")
    }
    return {"u": u, "D": D}, shared


# ---------------------------------------------------------------------------
# mechanical merging (fuse_chain)
# ---------------------------------------------------------------------------


def test_fuse_chain_bitwise_neutral(rng):
    """Merging interp+grad changes the stage structure, drops the
    internal 'w' handoff, and leaves every output bitwise-identical."""
    p, E, n = 5, 8, 16
    chain = operators.build_cfd_chain(p)
    t = channels.CPU_HOST
    elems, shared = _cfd_data(rng, p, n)

    plan = mchain.plan_chain(chain, target=t, batch_elements=E, n_eq=n)
    want = _run(chain, plan, elems, shared, n)

    fused = fusion.fuse_chain(chain, [(0, 1), (2,)])
    assert [s.name for s in fused.stages] == ["interp+grad", "helmholtz"]
    # the w handoff became internal: no longer a stage output
    assert "w" not in fused.stages[0].program.outputs
    fplan = mchain.plan_chain(fused, target=t, batch_elements=E, n_eq=n)
    got = _run(fused, fplan, elems, shared, n)

    assert sorted(got) == sorted(want) == ["gy", "gz", "v"]
    for out_var in ("gy", "gz", "v"):
        assert np.array_equal(got[out_var], want[out_var]), out_var


def test_fuse_chain_rejects_bad_groups():
    chain = operators.build_cfd_chain(3)
    with pytest.raises(ValueError, match="partition"):
        fusion.fuse_chain(chain, [(0,), (2, 1)])   # out of order
    with pytest.raises(ValueError, match="partition"):
        fusion.fuse_chain(chain, [(0, 1)])         # incomplete


def test_fused_stage_rematches_pallas():
    """A merged interp+grad program still fits the tiled GEMM-chain
    kernel class, so the fused stage keeps backend='pallas' instead of
    falling back to xla (the point of re-running pattern matching)."""
    system = operators.compile_cfd_pipeline(
        5, backends=("pallas", "pallas", "pallas"),
        target=channels.ALVEO_U280,
    )
    assert system.backends == ("pallas", "pallas", "pallas")
    fused = fusion.fuse_chain(system.chain, [(0, 1), (2,)])
    assert fused.stages[0].backend == "pallas"
    assert patterns.match_gemm_chain(fused.stages[0].program) is not None


# ---------------------------------------------------------------------------
# the greedy decision (fuse_chain_auto)
# ---------------------------------------------------------------------------


def test_fuse_auto_max_stages_one_fully_fuses():
    chain = operators.build_cfd_chain(5)
    plan = fusion.fuse_chain_auto(
        chain, max_stages=1, target=channels.ALVEO_U280, n_eq=1 << 12,
    )
    assert plan.fusion is not None
    assert plan.fusion.n_stages_after == len(plan.stages) == 1
    assert plan.fusion.groups == (("interp", "grad", "helmholtz"),)
    assert plan.fusion.fused


def test_fuse_auto_never_merges_across_barrier():
    chain = operators.build_cfd_chain(5)
    plan = fusion.fuse_chain_auto(
        chain, max_stages=1, barriers=("interp",),
        target=channels.ALVEO_U280, n_eq=1 << 12,
    )
    # the boundary after 'interp' survives even under a 1-stage budget
    assert plan.fusion.groups[0] == ("interp",)
    assert len(plan.fusion.groups) == 2
    with pytest.raises(ValueError, match="unknown stages"):
        fusion.fuse_chain_auto(chain, barriers=("nosuch",))


def test_fuse_auto_cost_monotonic():
    """The greedy pass only adopts merges the planner prices strictly
    better, so the fused prediction never exceeds the unfused one -- and
    on the dispatch-dominated 13-stage auto schedule it does fuse."""
    system = flow.compile(
        operators.CFD_PIPELINE_SRC.format(p=5),
        target=channels.TPU_V5E, n_eq=1 << 14,
    )
    assert len(system.chain.stages) > 3
    plan = fusion.fuse_chain_auto(
        system.chain, target=channels.TPU_V5E, n_eq=1 << 14,
    )
    spec = plan.fusion
    assert spec.fused
    assert spec.t_fused < spec.t_unfused
    assert spec.saved_handoff_bytes > 0
    assert plan.cost.t_pipelined == spec.t_fused
    # the fused chain rides along for execution but stays out of equality
    assert spec.chain is not None
    assert len(spec.chain.stages) == spec.n_stages_after


# ---------------------------------------------------------------------------
# planner/DSE surface (plan_chain fuse=..., explore_chain fuse=...)
# ---------------------------------------------------------------------------


def test_plan_chain_fuse_param():
    chain = operators.build_cfd_chain(5)
    t = channels.ALVEO_U280
    off = mchain.plan_chain(chain, target=t, n_eq=1 << 12, fuse="off")
    assert off.fusion is None
    auto = mchain.plan_chain(chain, target=t, n_eq=1 << 12, fuse="auto")
    assert auto.fusion is not None
    assert auto.fusion.n_stages_before == 3
    # a stage budget below the chain length triggers fusion on its own
    budget = mchain.plan_chain(chain, target=t, n_eq=1 << 12, max_stages=1)
    assert len(budget.stages) == 1
    with pytest.raises(ValueError, match="fuse"):
        mchain.plan_chain(chain, target=t, n_eq=1 << 12, fuse="nosuch")


def test_explore_chain_prefuses():
    chain = operators.build_cfd_chain(5)
    cands = dse.explore_chain(
        chain, target=channels.TPU_V5E, n_eq=1 << 14, fuse="auto",
        space=dse.ChainDesignSpace(
            backends=("xla",), batch_divisors=(1, 2),
            prefetch_depths=(1,), max_backend_combos=1,
        ),
    )
    assert cands
    for c in cands:
        assert c.plan.fusion is not None


# ---------------------------------------------------------------------------
# flow integration (flow.compile fuse=...)
# ---------------------------------------------------------------------------


def test_flow_fuse_auto_bitwise_vs_unfused(rng):
    """flow.compile(fuse='auto') on the auto-scheduled CFD pipeline
    merges stages yet reproduces the unfused outputs bitwise."""
    p, E, n = 5, 16, 32
    src = operators.CFD_PIPELINE_SRC.format(p=p)
    t = channels.TPU_V5E
    base = flow.compile(src, target=t, batch_elements=E, n_eq=n)
    fused = flow.compile(
        src, target=t, batch_elements=E, n_eq=n, fuse="auto",
    )
    assert fused.plan.fusion is not None and fused.plan.fusion.fused
    assert len(fused.chain.stages) < len(base.chain.stages)
    assert "fusion: auto" in fused.report()
    assert "fusion:" in fused.plan.report()

    elems, shared = _cfd_data(rng, p, n)
    want = _run(base.chain, base.plan, elems, shared, n)
    got = _run(fused.chain, fused.plan, elems, shared, n)
    for out_var in ("gy", "gz", "v"):
        assert np.array_equal(got[out_var], want[out_var]), out_var


def test_flow_named_cuts_are_fusion_barriers():
    """Explicit stage cuts are promises: fuse='auto' never merges across
    them, so the named pipeline comes back structurally untouched."""
    system = flow.compile(
        operators.CFD_PIPELINE_SRC.format(p=5),
        stages=operators.CFD_PIPELINE_STAGES,
        target=channels.ALVEO_U280, fuse="auto",
    )
    assert system.stage_names == ("interp", "grad", "helmholtz")
    spec = system.plan.fusion
    assert spec is not None and not spec.fused
    assert set(spec.barriers) == {"interp", "grad", "helmholtz"}


def test_flow_fuse_validation():
    with pytest.raises(flow.FlowError, match="fuse"):
        flow.compile(
            operators.CFD_PIPELINE_SRC.format(p=3),
            target=channels.CPU_HOST, fuse="nosuch",
        )


# ---------------------------------------------------------------------------
# CI gate: the auto-fused rung's ratio cap in benchmarks/compare.py
# ---------------------------------------------------------------------------


def test_bench_compare_enforces_max_ratio_cap():
    """A baseline row carrying max_ratio_vs/max_ratio caps the current
    run's us/batch against another current rung -- the machine-
    independent gate keeping auto-fused within 1.2x of the hand cuts."""
    import importlib.util
    import pathlib

    spec = importlib.util.spec_from_file_location(
        "bench_compare",
        pathlib.Path(__file__).parent.parent / "benchmarks" / "compare.py",
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    base = {"rows": [
        {"name": "hand_stage_cuts", "us_per_batch": 100.0},
        {"name": "chain_auto_fused", "us_per_batch": 105.0,
         "max_ratio_vs": "hand_stage_cuts", "max_ratio": 1.2},
    ]}
    ok = {"rows": [
        {"name": "hand_stage_cuts", "us_per_batch": 200.0},
        {"name": "chain_auto_fused", "us_per_batch": 230.0},
    ]}
    fails, _ = mod.compare(base, ok, threshold=10.0)
    assert fails == []
    # 300/200 = 1.5x > 1.2x cap, even though 300 < baseline*(1+thr)
    bad = {"rows": [
        {"name": "hand_stage_cuts", "us_per_batch": 200.0},
        {"name": "chain_auto_fused", "us_per_batch": 300.0},
    ]}
    fails, _ = mod.compare(base, bad, threshold=10.0)
    assert any("above the 1.2x cap" in f for f in fails)
    # a vanished reference rung is itself a failure
    fails, _ = mod.compare(
        base, {"rows": [
            {"name": "chain_auto_fused", "us_per_batch": 100.0},
        ]}, threshold=10.0,
    )
    assert any("missing" in f for f in fails)
