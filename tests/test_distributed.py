"""Distribution layer: sharding rules (inline) + multi-device semantics
(subprocess with forced host device count)."""
import json
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from conftest import subprocess_env
from repro.distributed import compression, sharding
from repro.launch import mesh as mesh_mod


# -- sharding rules (single device: rules are pure functions) -----------------

def _mesh11():
    return mesh_mod.make_local_mesh(1)


def test_param_rules_match_expected_axes():
    mesh = _mesh11()
    cases = {
        "embed/tok": (("model", None), 2),
        "blocks/attn/wq/w": ((None, "model"), 2),
        "blocks/attn/wo/w": (("model", None), 2),
        "blocks/mlp/gate/w": ((None, "model"), 2),
        "blocks/mlp/down/w": (("model", None), 2),
        "blocks/moe/w_gate": (("model", None, None), 3),
        "blocks/mamba/in_proj/w": ((None, "model"), 2),
        "blocks/ln1/scale": ((), 1),
    }
    for path, (want, ndim) in cases.items():
        spec = sharding.spec_for_param(path, ndim, mesh)
        got = tuple(spec) + (None,) * (ndim - len(tuple(spec)))
        want_padded = (None,) * (ndim - len(want)) + tuple(
            w if w in mesh.axis_names else None for w in want
        )
        assert got == want_padded, (path, got, want_padded)


def test_stacked_leading_axis_left_unsharded():
    mesh = _mesh11()
    spec = sharding.spec_for_param("blocks/attn/wq/w", 3, mesh)
    assert tuple(spec)[0] is None


def test_divisibility_fallback():
    mesh = mesh_mod.make_local_mesh(1)  # model axis size 1: all divisible
    spec = sharding._divisible((6, 64), P(None, "model"), mesh)
    assert tuple(spec) == (None, "model")


def test_quantize_roundtrip_bound(rng):
    g = rng.normal(size=(128,)).astype(np.float32)
    q, scale = compression.quantize(g)
    back = np.asarray(compression.dequantize(q, scale))
    assert np.abs(back - g).max() <= float(scale) * 0.5 + 1e-7


def test_error_feedback_reduces_bias(rng):
    """With error feedback the time-averaged quantized gradient converges
    to the true mean (unbiasedness over steps)."""
    g = rng.normal(size=(256,)).astype(np.float32) * 0.01
    import jax.numpy as jnp
    err = jnp.zeros_like(g)
    acc = np.zeros_like(g)
    n = 50
    for _ in range(n):
        q, s, err = compression.compress_with_feedback(jnp.asarray(g), err)
        acc += np.asarray(compression.dequantize(q, s))
    assert np.abs(acc / n - g).max() < 1e-4


# -- multi-device semantics (subprocess) ---------------------------------------

MULTIDEV_SCRIPT = textwrap.dedent("""
    import os, json
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro import configs
    from repro.models import build_model
    from repro.optim import AdamWConfig
    from repro.runtime.train import make_train_step, init_train_state
    from repro.distributed import sharding as sr, pipeline as pp, compression
    from repro.launch import mesh as mesh_mod
    from repro.distributed.pipeline import shard_map  # version-portable

    out = {}
    assert len(jax.devices()) == 8, jax.devices()

    # (a) sharded train step == single-device train step
    cfg = configs.get_smoke("internlm2-1.8b")
    model = build_model(cfg, attn_impl="xla")
    state = init_train_state(model, jax.random.PRNGKey(0))
    batch = {
        "tokens": jnp.tile(jnp.arange(16, dtype=jnp.int32)[None], (8, 1)),
        "labels": jnp.tile(jnp.arange(16, dtype=jnp.int32)[None], (8, 1)),
    }
    step = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3)))
    _, m_single = step(state, batch)

    mesh = mesh_mod.make_local_mesh(model_axis=2)   # (4, 2)
    params_sh = sr.param_shardings(state["params"], mesh)
    state_sh = {
        "params": params_sh,
        "opt_state": {"mu": params_sh, "nu": params_sh,
                      "step": sr.replicated(mesh)},
        "step": sr.replicated(mesh),
    }
    batch_sh = sr.batch_shardings(batch, mesh)
    with mesh:
        step_sharded = jax.jit(
            make_train_step(model, AdamWConfig(lr=1e-3)),
            in_shardings=(state_sh, batch_sh),
        )
        state_dev = jax.device_put(state, state_sh)
        batch_dev = jax.device_put(batch, batch_sh)
        _, m_shard = step_sharded(state_dev, batch_dev)
    out["loss_single"] = float(m_single["loss"])
    out["loss_sharded"] = float(m_shard["loss"])

    # (b) pipeline_forward == direct stacked apply
    S, L, mb, M, d = 4, 4, 2, 4, 8
    meshp = jax.sharding.Mesh(np.array(jax.devices()[:4]), ("pod",))
    key = jax.random.PRNGKey(1)
    w = jax.random.normal(key, (S, d, d)) * 0.3

    def stage_fn(wi, x):
        return jnp.tanh(x @ wi)

    x = jax.random.normal(jax.random.PRNGKey(2), (M, mb, d))
    got = pp.pipeline_forward(stage_fn, w, x, mesh=meshp, stage_axis="pod")
    want = x
    for s in range(S):
        want = jnp.tanh(want @ w[s])
    out["pp_err"] = float(jnp.abs(got - want).max())

    # (c) compressed psum over 'pod'
    meshc = jax.sharding.Mesh(np.array(jax.devices()[:4]), ("pod",))
    g = jax.random.normal(jax.random.PRNGKey(3), (4, 64)) * 0.01
    err0 = jnp.zeros((4, 64))

    def red(gl, el):
        m, ne = compression.compressed_psum(gl[0], el[0], "pod")
        return m[None], ne[None]

    mfn = shard_map(red, mesh=meshc, in_specs=(P("pod"), P("pod")),
                    out_specs=(P("pod"), P("pod")), check_vma=False)
    mean, _ = mfn(g, err0)
    true_mean = jnp.mean(g, axis=0)
    out["psum_err"] = float(jnp.abs(mean[0] - true_mean).max())
    print(json.dumps(out))
""")


@pytest.mark.slow
def test_multidevice_semantics():
    res = subprocess.run(
        [sys.executable, "-c", MULTIDEV_SCRIPT],
        env=subprocess_env(8), capture_output=True, text=True, timeout=420,
    )
    assert res.returncode == 0, res.stderr[-3000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert abs(out["loss_single"] - out["loss_sharded"]) < 1e-3
    assert out["pp_err"] < 1e-5
    assert out["psum_err"] < 2e-4
