"""base2-analogue precision policies: fixed-point formats and the paper's
MSE claims (ap_fixed<64,24> ~ 9.39e-22, ap_fixed<32,8> ~ 3.58e-12)."""
import jax
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import dsl, emit, rewrite
from repro.core.precision import (FIXED32, FIXED64, FixedPointPolicy,
                                  enable_x64)


def test_formats():
    assert FIXED32.total_bits == 32 and FIXED32.frac_bits == 24
    assert FIXED64.total_bits == 64 and FIXED64.frac_bits == 40
    with pytest.raises(ValueError):
        FixedPointPolicy(16, 8)
    with pytest.raises(ValueError):
        FixedPointPolicy(32, 40)


def test_encode_decode_roundtrip():
    with enable_x64(True):
        x = np.linspace(-0.99, 0.99, 101)
        for pol in (FIXED32, FIXED64):
            err = np.abs(np.asarray(pol.decode(pol.encode(x))) - x).max()
            assert err <= 2.0 ** (-pol.frac_bits)


@given(st.floats(-1, 1), st.floats(-1, 1))
@settings(max_examples=50, deadline=None)
def test_fmul_within_ulp(a, b):
    with enable_x64(True):
        for pol, tol in ((FIXED32, 2 ** -22), (FIXED64, 2 ** -38)):
            qa, qb = pol.encode(np.float64(a)), pol.encode(np.float64(b))
            got = float(pol.decode(pol.fmul(qa, qb)))
            assert abs(got - a * b) < tol


def test_fixed64_large_magnitude():
    """Q24.40 must handle the paper's 24 integer bits (values up to
    ~2^23): products of large x small stay accurate."""
    with enable_x64(True):
        a, b = 3000.5, 0.125
        qa, qb = FIXED64.encode(np.float64(a)), FIXED64.encode(np.float64(b))
        got = float(FIXED64.decode(FIXED64.fmul(qa, qb)))
        assert abs(got - a * b) < 1e-6


@pytest.mark.parametrize(
    "pol,paper_mse,slack",
    [(FIXED32, 3.58e-12, 100.0), (FIXED64, 9.39e-22, 100.0)],
)
def test_helmholtz_mse_matches_paper_order(pol, paper_mse, slack, rng):
    """End-to-end fixed-point Inverse Helmholtz on [-1,1] data must land
    within two orders of the paper's reported MSE."""
    p = 7
    prog = rewrite.optimize(dsl.inverse_helmholtz_program(p))
    S = rng.uniform(-1, 1, (p, p))
    D = rng.uniform(-1, 1, (p, p, p))
    u = rng.uniform(-1, 1, (p, p, p))
    t = np.einsum("il,jm,kn,lmn->ijk", S, S, S, u)
    v = np.einsum("li,mj,nk,lmn->ijk", S, S, S, D * t)
    with enable_x64(True):
        c = emit.compile_program(prog, policy=pol, jit=False)
        env = {k: pol.encode(val) for k, val in
               {"S": S, "D": D, "u": u}.items()}
        got = np.asarray(pol.decode(c.element_fn(env)["v"]))
    mse = float(np.mean((got - v) ** 2))
    assert mse < paper_mse * slack
    assert mse > 0  # fixed point is not exact


def test_fixed_point_requires_factorized_program():
    prog = dsl.inverse_helmholtz_program(3)  # literal: 4-ary einsum
    flat = rewrite.flatten_products(prog)
    with enable_x64(True):
        c = emit.compile_program(flat, policy=FIXED32, jit=False)
        env = {
            k: FIXED32.encode(np.zeros(v.shape))
            for k, v in prog.inputs.items()
        }
        with pytest.raises(Exception):
            c.element_fn(env)
