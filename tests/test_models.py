"""Per-architecture smoke tests (REDUCED configs, as assigned): one
forward/train step on CPU asserting output shapes + no NaNs; plus
prefill/decode consistency checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import build_model
from repro.models import ssm, transformer
from repro.optim import AdamWConfig
from repro.runtime.train import init_train_state, make_train_step


def _batch(cfg, rng, B=2, T=16):
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, T)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, T)), jnp.int32),
    }
    if cfg.is_encdec:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_audio_frames, cfg.d_model)),
            jnp.float32,
        )
    return batch


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_smoke_forward(arch, rng):
    cfg = configs.get_smoke(arch)
    model = build_model(cfg, attn_impl="xla")
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, rng)
    logits = model.forward(params, batch)
    assert logits.shape == (2, 16, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_smoke_train_step(arch, rng):
    cfg = configs.get_smoke(arch)
    model = build_model(cfg, attn_impl="xla")
    state = init_train_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3)))
    state, metrics = step(state, _batch(cfg, rng))
    assert np.isfinite(float(metrics["loss"]))
    assert int(state["step"]) == 1


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_smoke_prefill_decode(arch, rng):
    cfg = configs.get_smoke(arch)
    model = build_model(cfg, attn_impl="xla")
    params = model.init(jax.random.PRNGKey(0))
    B, T = 2, 8
    batch = _batch(cfg, rng, B, T)
    cache = model.init_cache(B, 32)
    logits, cache = model.prefill(params, batch, cache)
    assert logits.shape == (B, cfg.vocab)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, cache = model.decode_step(params, tok, cache, jnp.int32(T))
    assert logits2.shape == (B, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits2)))


@pytest.mark.parametrize(
    "arch", ["internlm2-1.8b", "qwen3-14b", "qwen2-7b", "olmoe-1b-7b"]
)
def test_decode_matches_forward_teacher_forcing(arch, rng):
    """Greedy decode logits must equal full-forward logits position by
    position (cache correctness)."""
    cfg = configs.get_smoke(arch)
    model = build_model(cfg, attn_impl="xla")
    params = model.init(jax.random.PRNGKey(1))
    B, T = 2, 8
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, T)), jnp.int32)
    full = model.forward(params, {"tokens": tokens})  # (B, T, V)

    cache = model.init_cache(B, T + 4)
    lg, cache = model.prefill(params, {"tokens": tokens[:, :4]}, cache)
    np.testing.assert_allclose(
        np.asarray(lg), np.asarray(full[:, 3]), rtol=2e-3, atol=2e-3
    )
    for t in range(4, T):
        lg, cache = model.decode_step(
            params, tokens[:, t], cache, jnp.int32(t)
        )
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(full[:, t]), rtol=2e-3, atol=2e-3
        )


def test_xlstm_stateful_equals_stateless(rng):
    """Running the xLSTM one token at a time through the recurrent state
    must reproduce the parallel forward (O(1)-state decode contract)."""
    cfg = configs.get_smoke("xlstm-125m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, T = 2, 6
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, T)), jnp.int32)
    full = model.forward(params, {"tokens": tokens})
    states = transformer.xlstm_init_states(cfg, B)
    for t in range(T):
        lg, states = model.decode_step(
            params, tokens[:, t], states, jnp.int32(t)
        )
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(full[:, t]), rtol=2e-3, atol=2e-3
        )


def test_mamba_stateful_equals_stateless(rng):
    cfg = configs.get_smoke("jamba-1.5-large-398b")
    B, T, d = 2, 6, cfg.d_model
    key = jax.random.PRNGKey(0)
    p = ssm.mamba_init(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, d), jnp.float32)
    y_full, _ = ssm.mamba_apply(p, x, cfg)
    state = ssm.mamba_init_state(cfg, B)
    ys = []
    for t in range(T):
        y_t, state = ssm.mamba_apply(p, x[:, t:t + 1], cfg, state=state)
        ys.append(y_t)
    y_inc = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(
        np.asarray(y_inc), np.asarray(y_full), rtol=3e-3, atol=3e-3
    )


def test_param_counts_match_published_sizes():
    expect = {
        "command-r-plus-104b": 104e9,
        "dbrx-132b": 132e9,
        "jamba-1.5-large-398b": 398e9,
        "chameleon-34b": 34e9,
        "qwen2-7b": 7.6e9,
        "internlm2-1.8b": 1.9e9,
    }
    for arch, want in expect.items():
        got = configs.get(arch).param_count()
        assert abs(got - want) / want < 0.12, (arch, got)
