"""repro.trace: span/counter tracing, Chrome export, schema invariants,
pred-vs-measured attribution, and the persistent profile store.

The schema tests pin the executor's tracing contract: spans nest and
never overlap within a track, per-channel byte counters sum *exactly*
to the plan's host_stream_bytes, disabling the tracer changes nothing
bitwise, and the Chrome JSON round-trips ``json.loads``.  The golden
test locks the deterministic (non-timing) fields of the ``measured:``
report section.
"""
import json
import os
import pathlib

import numpy as np
import pytest

from repro import trace
from repro.cfd import operators
from repro.cfd.simulation import run_chain
from repro.memory import chain as mchain
from repro.memory import channels, dse
from repro.runtime.monitor import StepMonitor
from repro.trace.attribution import (
    CAT_DISPATCH, CAT_SLOT, CAT_SYNC, COUNTER_CHANNEL_BYTES,
    COUNTER_OCCUPANCY, host_channel_bytes,
)

GOLDEN_DIR = pathlib.Path(__file__).parent / "golden"

P, E, N_B = 5, 128, 3


def _golden_check(name: str, rendered: str) -> None:
    path = GOLDEN_DIR / name
    if os.environ.get("REGEN_GOLDENS"):
        GOLDEN_DIR.mkdir(exist_ok=True)
        path.write_text(rendered + "\n")
        pytest.skip(f"regenerated {name}")
    assert path.exists(), (
        f"golden file {name} missing -- run with REGEN_GOLDENS=1"
    )
    want = path.read_text().rstrip("\n")
    assert rendered.rstrip("\n") == want, (
        f"{name} drifted from the checked-in golden.\n"
        "If the change is intentional, regenerate with REGEN_GOLDENS=1 "
        "and review the diff.\n"
        f"--- golden ---\n{want}\n--- current ---\n{rendered}"
    )


@pytest.fixture(scope="module")
def cfd_chain():
    return operators.build_cfd_chain(P)


def _chain_data(chain, n, rng):
    inputs = {
        "interp.u": rng.uniform(-1, 1, (n, P, P, P)).astype(np.float32),
        "helmholtz.D": rng.uniform(-1, 1, (n, P, P, P)).astype(np.float32),
    }
    shared = {
        name: rng.uniform(-1, 1, node.shape).astype(np.float32)
        for name, node in sorted(chain.shared_operands().items())
    }
    return inputs, shared


@pytest.fixture(scope="module")
def traced_run(cfd_chain):
    """One stage-pipelined 3-batch run with tracing on, reused by the
    schema/attribution/profile tests below."""
    plan = mchain.plan_chain(
        cfd_chain, target=channels.ALVEO_U280, batch_elements=E,
        prefetch_depth=1, n_eq=E * N_B,
    )
    rng = np.random.default_rng(3)
    inputs, shared = _chain_data(cfd_chain, E * N_B, rng)
    tracer = trace.Tracer()
    res = run_chain(
        cfd_chain, plan, inputs=inputs, shared=shared, n_eq=E * N_B,
        max_batches=N_B, pipeline_stages=True, tracer=tracer,
    )
    return plan, tracer, res


# ---------------------------------------------------------------------------
# tracer core
# ---------------------------------------------------------------------------


def test_tracer_spans_nest_lifo():
    tr = trace.Tracer()
    outer = tr.begin("outer", "run", 0)
    inner = tr.begin("inner", "slot", 0)
    tr.end(inner)
    tr.end(outer)
    assert not outer.open and not inner.open
    assert inner.t0 >= outer.t0 and inner.t1 <= outer.t1


def test_tracer_rejects_out_of_order_end():
    tr = trace.Tracer()
    outer = tr.begin("outer", "run", 0)
    tr.begin("inner", "slot", 0)
    with pytest.raises(trace.TraceError):
        tr.end(outer)  # inner is still open on the same track


def test_tracer_rejects_end_without_begin():
    tr = trace.Tracer()
    sp = tr.begin("a", "run", 0)
    tr.end(sp)
    with pytest.raises(trace.TraceError):
        tr.end(sp)


def test_null_tracer_is_falsy_noop():
    assert not trace.NULL
    assert not trace.NullTracer()
    with trace.NULL.span("x", "run", 0) as sp:
        assert sp is None
    trace.NULL.bump("c", {"a": 1.0})  # must not raise


def test_counter_totals_accumulate():
    tr = trace.Tracer()
    tr.bump("bytes", {"0": 10.0, "1": 5.0})
    tr.bump("bytes", {"0": 10.0})
    assert tr.totals("bytes") == {"0": 20.0, "1": 5.0}


# ---------------------------------------------------------------------------
# traced chain run: schema invariants
# ---------------------------------------------------------------------------


def test_traced_chain_schema_valid(traced_run, tmp_path):
    _, tracer, _ = traced_run
    doc = trace.to_chrome(tracer)
    assert trace.validate(doc) == []
    # Chrome JSON round-trips json.loads, via the actual file writer
    path = tmp_path / "trace.json"
    trace.write_chrome(tracer, str(path))
    loaded = json.loads(path.read_text())
    assert trace.validate(loaded) == []
    assert {e["ph"] for e in loaded["traceEvents"]} >= {"X", "C", "M"}


def test_traced_chain_no_open_spans(traced_run):
    _, tracer, _ = traced_run
    assert tracer.open_spans() == []


def test_channel_counters_sum_exactly_to_plan(traced_run):
    plan, tracer, res = traced_run
    per_ch = tracer.totals(COUNTER_CHANNEL_BYTES)
    assert per_ch, "no channel_bytes counters recorded"
    assert sum(per_ch.values()) == res.batches * plan.host_stream_bytes
    # and the per-channel split helper is exact on its own
    split = host_channel_bytes(plan.buffers)
    assert sum(split.values()) == plan.host_stream_bytes


def test_occupancy_counter_matches_plan(traced_run):
    plan, tracer, _ = traced_run
    occ = tracer.totals(COUNTER_OCCUPANCY)
    assert occ == {
        sp.name: float(sp.cu_count) for sp in plan.stages
    }


def test_tracer_off_is_bitwise_identical(cfd_chain):
    plan = mchain.plan_chain(
        cfd_chain, target=channels.ALVEO_U280, batch_elements=64,
        prefetch_depth=1, n_eq=128,
    )
    rng = np.random.default_rng(5)
    inputs, shared = _chain_data(cfd_chain, 128, rng)
    kw = dict(inputs=inputs, shared=shared, n_eq=128, max_batches=2,
              pipeline_stages=True)
    plain = run_chain(cfd_chain, plan, **kw)
    traced = run_chain(cfd_chain, plan, tracer=trace.Tracer(), **kw)
    nulled = run_chain(cfd_chain, plan, tracer=trace.NULL, **kw)
    assert plain.checksums == traced.checksums == nulled.checksums


# ---------------------------------------------------------------------------
# attribution
# ---------------------------------------------------------------------------


def test_attribution_matches_span_sums(traced_run):
    plan, tracer, _ = traced_run
    a = trace.attribute(tracer, plan)
    assert a.n_batches == N_B
    assert len(a.stages) == len(plan.stages)
    for i, s in enumerate(a.stages):
        assert s.name == plan.stages[i].name
        assert s.slots == N_B  # every stage dispatched every batch
        disp = [
            sp for sp in tracer.spans
            if sp.cat == CAT_DISPATCH and int(sp.args["stage"]) == i
        ]
        assert s.measured_s == pytest.approx(
            sum(sp.duration for sp in disp)
        )
        assert s.measured_s > 0
    # per-stage slot spans partition the run: one per (stage, batch)
    slots = [sp for sp in tracer.spans if sp.cat == CAT_SLOT]
    assert len(slots) == N_B * len(plan.stages)
    assert a.wall_s > 0 and a.pred_s_per_batch > 0


def test_attribution_report_renders(traced_run):
    plan, tracer, _ = traced_run
    rep = trace.attribution_report(tracer, plan)
    assert rep.startswith("measured:")
    for sp in plan.stages:
        assert sp.name in rep
    assert "-> ok)" in rep  # counter sum matched the plan


def test_golden_measured_section_stable(traced_run):
    """The deterministic fields of the measured: section (structure,
    predictions, counter sums -- no wall times) are golden-locked."""
    plan, tracer, _ = traced_run
    rep = trace.attribution_report(tracer, plan, stable_only=True)
    _golden_check("trace_measured_cfd_p5_alveo.txt", rep)


# ---------------------------------------------------------------------------
# straggler monitoring -> trace annotations
# ---------------------------------------------------------------------------


def test_monitor_flags_become_span_annotations(cfd_chain):
    plan = mchain.plan_chain(
        cfd_chain, target=channels.ALVEO_U280, batch_elements=64,
        prefetch_depth=1, n_eq=192,
    )
    rng = np.random.default_rng(9)
    inputs, shared = _chain_data(cfd_chain, 192, rng)
    tracer = trace.Tracer()
    # factor 0 flags every post-seed step: deterministic on any machine
    mon = StepMonitor(straggler_factor=0.0, warmup=0)
    res = run_chain(
        cfd_chain, plan, inputs=inputs, shared=shared, n_eq=192,
        max_batches=3, pipeline_stages=True, tracer=tracer, monitor=mon,
    )
    assert res.straggler_batches == (1, 2)
    flagged = sorted(
        int(sp.args["batch"]) for sp in tracer.spans
        if sp.cat == CAT_SYNC and sp.args.get("straggler")
    )
    assert flagged == [1, 2]
    assert trace.attribute(tracer, plan).straggler_batches == (1, 2)


# ---------------------------------------------------------------------------
# profile store
# ---------------------------------------------------------------------------


def test_profile_store_roundtrip(traced_run, tmp_path):
    plan, tracer, _ = traced_run
    path = str(tmp_path / "profile.json")
    store = trace.ProfileStore(path=path, fingerprint="testfp")
    n = store.record_trace(tracer, plan)
    assert n == len(plan.stages) + 1  # per-stage + chain-level samples

    # persists: a fresh store reloads the samples and refits from them
    store2 = trace.ProfileStore(path=path, fingerprint="testfp")
    assert len(store2) == n
    corr = store2.correction(plan.target.name, plan.signature)
    assert corr.n_samples == n
    assert corr.factor > 0 and corr.factor != pytest.approx(1.0)
    assert any(
        f is not None for f in
        (corr.host_factor, corr.hbm_factor, corr.compute_factor)
    )
    # a different machine's fingerprint sees none of it
    other = trace.ProfileStore(path=path, fingerprint="elsewhere")
    assert other.samples(plan.target.name) == []


def test_profile_store_env_override(tmp_path, monkeypatch):
    p = str(tmp_path / "env_profile.json")
    monkeypatch.setenv(trace.PROFILE_ENV, p)
    assert trace.default_profile_path() == p
    store = trace.ProfileStore()
    assert store.path == p


def test_explore_chain_warm_profile_reranks(traced_run, cfd_chain,
                                            tmp_path):
    """The acceptance round-trip: trace -> store -> refit -> the DSE
    ranking is re-priced by the learned per-term corrections."""
    plan, tracer, _ = traced_run
    path = str(tmp_path / "profile.json")
    store = trace.ProfileStore(path=path, fingerprint="testfp")
    assert store.record_trace(tracer, plan) > 0

    space = dse.ChainDesignSpace(
        backends=("xla", "staged"), batch_divisors=(1, 2),
        prefetch_depths=(0, 1), cu_counts=(1,), max_placements=2,
    )
    cold = dse.explore_chain(
        cfd_chain, target=channels.ALVEO_U280, n_eq=1 << 10, space=space,
    )
    warm = dse.explore_chain(
        cfd_chain, target=channels.ALVEO_U280, n_eq=1 << 10, space=space,
        profile=store,
    )
    assert all(c.corrected_s_per_element is None for c in cold)
    feas = [c for c in warm if c.plan.feasible]
    assert feas and all(
        c.corrected_s_per_element is not None for c in feas
    )
    # the correction actually moved the predictions...
    assert any(
        c.corrected_s_per_element != c.predicted_s_per_element
        for c in feas
    )
    # ...and the warm ranking is ordered by the corrected cost
    vals = [c.corrected_s_per_element for c in feas]
    assert vals == sorted(vals)


def test_per_term_correction_can_reorder(cfd_chain):
    """Per-term factors are not a monotone rescale: penalizing the cold
    leader's own bottleneck term demotes it below a candidate bound by a
    different term."""
    space = dse.ChainDesignSpace(
        backends=("xla", "staged"), batch_divisors=(1,),
        prefetch_depths=(0, 1), cu_counts=(1,), max_placements=2,
    )
    # cpu-host is the one datasheet whose chain candidates split between
    # hbm- and compute-bound (ALVEO streaming is always host-link-bound)
    cands = dse.explore_chain(
        cfd_chain, target=channels.CPU_HOST, n_eq=1 << 10, space=space,
    )
    feas = [c for c in cands if c.plan.feasible]
    leader = feas[0]
    term = leader.plan.cost.bottleneck
    if all(c.plan.cost.bottleneck == term for c in feas):
        pytest.skip("design space has a single bottleneck term")
    kw = {
        "host-link": "host_factor", "hbm": "hbm_factor",
        "compute": "compute_factor",
    }[term]
    corr = dse.CostCorrection(factor=1.0, n_samples=1, **{kw: 1e3})
    reranked = dse.apply_correction(list(feas), corr)
    assert reranked[0].plan is not leader.plan


def test_profile_store_fifo_bound(tmp_path):
    from repro.trace.profile import MAX_SAMPLES_PER_KEY

    store = trace.ProfileStore(
        path=str(tmp_path / "p.json"), fingerprint="fp"
    )
    samples = [
        {"predicted_s": 1.0, "measured_s": 2.0, "bottleneck": "hbm"}
        for _ in range(MAX_SAMPLES_PER_KEY + 50)
    ]
    store.record("t", "sig", samples, save=False)
    assert len(store) == MAX_SAMPLES_PER_KEY
