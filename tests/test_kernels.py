"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU), with
shape/dtype sweeps as required per kernel."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cfd import reference
from repro.kernels import gemm
from repro.kernels.attention import ops as attn_ops
from repro.kernels.attention.ref import attention_ref
from repro.kernels.helmholtz import ops as hh_ops


# ---------------------------------------------------------------------------
# helmholtz kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("p", [3, 5, 7, 11])
@pytest.mark.parametrize("be", [2, 4])
def test_helmholtz_kernel_shapes(p, be, rng):
    E = 8
    S = rng.uniform(-1, 1, (p, p)).astype(np.float32)
    D = rng.uniform(-1, 1, (E, p, p, p)).astype(np.float32)
    u = rng.uniform(-1, 1, (E, p, p, p)).astype(np.float32)
    got = np.asarray(
        hh_ops.inverse_helmholtz(S, D, u, impl="interpret", block_elements=be)
    )
    want = reference.inverse_helmholtz_batch(
        S.astype(np.float64), D.astype(np.float64), u.astype(np.float64)
    )
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)


def test_helmholtz_kernel_bf16(rng):
    p, E = 7, 4
    S = rng.uniform(-1, 1, (p, p)).astype(np.float32)
    D = rng.uniform(-1, 1, (E, p, p, p)).astype(np.float32)
    u = rng.uniform(-1, 1, (E, p, p, p)).astype(np.float32)
    got = np.asarray(
        hh_ops.inverse_helmholtz(
            jnp.asarray(S, jnp.bfloat16), jnp.asarray(D, jnp.bfloat16),
            jnp.asarray(u, jnp.bfloat16), impl="interpret", block_elements=4,
        ).astype(jnp.float32)
    )
    want = reference.inverse_helmholtz_batch(
        S.astype(np.float64), D.astype(np.float64), u.astype(np.float64)
    )
    # bf16 storage, f32 accumulation: coarse bound
    np.testing.assert_allclose(got, want, rtol=0.15, atol=0.3)


def test_helmholtz_kernel_rejects_ragged_blocks(rng):
    p = 5
    S = rng.uniform(-1, 1, (p, p)).astype(np.float32)
    D = rng.uniform(-1, 1, (6, p, p, p)).astype(np.float32)
    u = rng.uniform(-1, 1, (6, p, p, p)).astype(np.float32)
    with pytest.raises(ValueError):
        hh_ops.inverse_helmholtz(S, D, u, impl="interpret", block_elements=4)


# ---------------------------------------------------------------------------
# flash attention kernel
# ---------------------------------------------------------------------------

SWEEP = [
    # B, Hq, Hkv, Tq, Tk, d, causal
    (2, 4, 2, 64, 64, 32, True),
    (1, 8, 2, 32, 128, 16, True),     # GQA 4:1, cross-length causal
    (2, 2, 2, 64, 64, 64, False),
    (1, 4, 1, 128, 128, 32, True),    # MQA
    (1, 2, 2, 16, 16, 128, True),
]


@pytest.mark.parametrize("case", SWEEP)
def test_flash_attention_vs_oracle(case, rng):
    B, Hq, Hkv, Tq, Tk, d, causal = case
    q = rng.normal(size=(B, Hq, Tq, d)).astype(np.float32)
    k = rng.normal(size=(B, Hkv, Tk, d)).astype(np.float32)
    v = rng.normal(size=(B, Hkv, Tk, d)).astype(np.float32)
    want = np.asarray(
        attention_ref(
            q.reshape(B * Hq, Tq, d), k.reshape(B * Hkv, Tk, d),
            v.reshape(B * Hkv, Tk, d),
            n_q_heads=Hq, n_kv_heads=Hkv, causal=causal,
        )
    ).reshape(B, Hq, Tq, d)
    got = np.asarray(
        attn_ops.multi_head_attention(
            q, k, v, causal=causal, impl="interpret",
            block_q=16, block_k=32,
        )
    )
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_flash_attention_block_size_invariance(rng):
    B, Hq, Hkv, T, d = 1, 2, 1, 128, 32
    q = rng.normal(size=(B, Hq, T, d)).astype(np.float32)
    k = rng.normal(size=(B, Hkv, T, d)).astype(np.float32)
    v = rng.normal(size=(B, Hkv, T, d)).astype(np.float32)
    outs = [
        np.asarray(attn_ops.multi_head_attention(
            q, k, v, impl="interpret", block_q=bq, block_k=bk,
        ))
        for bq, bk in [(16, 16), (32, 64), (128, 128)]
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=2e-5, atol=2e-5)


def test_flash_attention_xla_path_matches(rng):
    B, Hq, Hkv, T, d = 2, 4, 2, 64, 32
    q = rng.normal(size=(B, Hq, T, d)).astype(np.float32)
    k = rng.normal(size=(B, Hkv, T, d)).astype(np.float32)
    v = rng.normal(size=(B, Hkv, T, d)).astype(np.float32)
    a = np.asarray(attn_ops.multi_head_attention(q, k, v, impl="xla"))
    b = np.asarray(attn_ops.multi_head_attention(
        q, k, v, impl="interpret", block_q=16, block_k=16))
    np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# tiled GEMM-chain kernel
# ---------------------------------------------------------------------------

def _interp_recipe(p):
    """Interpolation: three mode contractions of A against u."""
    return gemm.GemmRecipe(
        p=p,
        inputs=(("A", (p, p), False), ("u", (p, p, p), True)),
        ops=(
            ("contract", 1, 0, 0, 0, (0, 1, 2)),
            ("contract", 2, 0, 1, 0, (0, 1, 2)),
            ("contract", 3, 0, 2, 0, (0, 1, 2)),
        ),
        outputs=(("w", 4),),
    )


def _interp_oracle(A, u):
    return np.einsum("li,mj,nk,elmn->eijk", A, A, A, u)


@pytest.mark.parametrize("p", [3, 5, 11])
@pytest.mark.parametrize("be", [2, 4])
def test_gemm_chain_interpolation_vs_oracle(p, be, rng):
    E = 8
    A = rng.uniform(-1, 1, (p, p)).astype(np.float32)
    u = rng.uniform(-1, 1, (E, p, p, p)).astype(np.float32)
    recipe = _interp_recipe(p)
    want = _interp_oracle(A.astype(np.float64), u.astype(np.float64))
    for impl in ("xla", "interpret"):
        got = np.asarray(gemm.gemm_chain(
            recipe, {"A": A, "u": u}, impl=impl, block_elements=be,
        )["w"])
        np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)


def test_gemm_chain_perm_moves_free_axis(rng):
    """A gradient-style contraction whose output reorders the element
    axes: y[e,f,a,c] = sum_l M[l,f] u[e,a,l,c] (free axis moved to the
    front via the recipe's perm, not left in place)."""
    p, E = 5, 4
    M = rng.uniform(-1, 1, (p, p)).astype(np.float32)
    u = rng.uniform(-1, 1, (E, p, p, p)).astype(np.float32)
    recipe = gemm.GemmRecipe(
        p=p,
        inputs=(("M", (p, p), False), ("u", (p, p, p), True)),
        ops=(("contract", 1, 0, 1, 0, (1, 0, 2)),),
        outputs=(("y", 2),),
    )
    want = np.einsum("lf,ealc->efac", M, u)
    for impl in ("xla", "interpret"):
        got = np.asarray(gemm.gemm_chain(
            recipe, {"M": M, "u": u}, impl=impl, block_elements=2,
        )["y"])
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_gemm_chain_ewise_and_multi_output(rng):
    """Elementwise ops between matched values plus two outputs sharing
    the chain: w = A.u (mode 0), z = (w * u) scaled by 0.5."""
    p, E = 4, 8
    A = rng.uniform(-1, 1, (p, p)).astype(np.float32)
    u = rng.uniform(-1, 1, (E, p, p, p)).astype(np.float32)
    recipe = gemm.GemmRecipe(
        p=p,
        inputs=(("A", (p, p), False), ("u", (p, p, p), True)),
        ops=(
            ("contract", 1, 0, 0, 0, (0, 1, 2)),
            ("ewise", "mul", 2, 1, None),
            ("ewise", "scale", 3, -1, 0.5),
        ),
        outputs=(("w", 2), ("z", 4)),
    )
    w = np.einsum("li,elmn->eimn", A, u)
    for impl in ("xla", "interpret"):
        got = gemm.gemm_chain(
            recipe, {"A": A, "u": u}, impl=impl, block_elements=4,
        )
        np.testing.assert_allclose(
            np.asarray(got["w"]), w, rtol=1e-5, atol=1e-5
        )
        np.testing.assert_allclose(
            np.asarray(got["z"]), 0.5 * w * u, rtol=1e-5, atol=1e-5
        )


def test_gemm_chain_block_size_invariance(rng):
    p, E = 5, 8
    A = rng.uniform(-1, 1, (p, p)).astype(np.float32)
    u = rng.uniform(-1, 1, (E, p, p, p)).astype(np.float32)
    recipe = _interp_recipe(p)
    outs = [
        np.asarray(gemm.gemm_chain(
            recipe, {"A": A, "u": u}, impl="interpret", block_elements=be,
        )["w"])
        for be in (1, 2, 8)
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-6, atol=1e-6)


def test_gemm_chain_rejects_ragged_blocks(rng):
    p = 3
    A = rng.uniform(-1, 1, (p, p)).astype(np.float32)
    u = rng.uniform(-1, 1, (6, p, p, p)).astype(np.float32)
    with pytest.raises(ValueError, match="not divisible"):
        gemm.gemm_chain(
            _interp_recipe(p), {"A": A, "u": u},
            impl="interpret", block_elements=4,
        )


def test_gemm_recipe_flops_match_ir():
    """The recipe's flop model agrees with the IR's on the matched
    interpolation stage (3 contractions of 2*p^4 each)."""
    p = 7
    recipe = _interp_recipe(p)
    assert recipe.flops_per_element() == 3 * 2 * p ** 4
    assert recipe.slot_shape(4) == (p, p, p)


# ---------------------------------------------------------------------------
# CHARM-style tile candidates (cdse/cdac)
# ---------------------------------------------------------------------------

def test_tile_candidates_filter_class_and_rank():
    recipe = _interp_recipe(11)
    vmem = 16 * 2 ** 20
    cands = gemm.tile_candidates(
        recipe, vmem_bytes=vmem, peak_flops=1e12, hbm_bandwidth=400e9,
    )
    assert cands
    budget = vmem * 0.5
    for c in cands:
        # the VMEM constraint is honored and the working set is exact
        assert c.working_set_bytes <= budget
        assert c.working_set_bytes == gemm.block_working_set_bytes(
            recipe, c.block_elements
        )
        expect = (
            "cdse" if c.working_set_bytes
            > budget * gemm.cdse_cdac.LARGE_CLASS_FRACTION else "cdac"
        )
        assert c.klass == expect
    # ranked best-first by modeled throughput
    ths = [c.predicted_throughput for c in cands]
    assert ths == sorted(ths, reverse=True)
    # both classes are represented across the block range
    assert {c.klass for c in cands} == {"cdse", "cdac"}


def test_tile_candidates_respect_batch_divisibility():
    recipe = _interp_recipe(5)
    cands = gemm.tile_candidates(
        recipe, vmem_bytes=64 * 2 ** 20, peak_flops=1e12,
        hbm_bandwidth=400e9, batch_elements=96,
    )
    assert cands
    for c in cands:
        assert 96 % c.block_elements == 0
    assert max(c.block_elements for c in cands) == 32


def test_tile_candidates_empty_when_vmem_too_small():
    assert gemm.tile_candidates(
        _interp_recipe(11), vmem_bytes=4096, peak_flops=1e12,
        hbm_bandwidth=400e9,
    ) == []


def test_block_elements_for_vmem_monotone():
    recipe = _interp_recipe(7)
    small = gemm.block_elements_for_vmem(recipe, 2 ** 20)
    large = gemm.block_elements_for_vmem(recipe, 2 ** 24)
    assert 1 <= small < large
    # the chosen block actually fits half the budget
    assert gemm.block_working_set_bytes(recipe, large) <= 2 ** 23
