"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU), with
shape/dtype sweeps as required per kernel."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cfd import reference
from repro.kernels.attention import ops as attn_ops
from repro.kernels.attention.ref import attention_ref
from repro.kernels.helmholtz import ops as hh_ops


# ---------------------------------------------------------------------------
# helmholtz kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("p", [3, 5, 7, 11])
@pytest.mark.parametrize("be", [2, 4])
def test_helmholtz_kernel_shapes(p, be, rng):
    E = 8
    S = rng.uniform(-1, 1, (p, p)).astype(np.float32)
    D = rng.uniform(-1, 1, (E, p, p, p)).astype(np.float32)
    u = rng.uniform(-1, 1, (E, p, p, p)).astype(np.float32)
    got = np.asarray(
        hh_ops.inverse_helmholtz(S, D, u, impl="interpret", block_elements=be)
    )
    want = reference.inverse_helmholtz_batch(
        S.astype(np.float64), D.astype(np.float64), u.astype(np.float64)
    )
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)


def test_helmholtz_kernel_bf16(rng):
    p, E = 7, 4
    S = rng.uniform(-1, 1, (p, p)).astype(np.float32)
    D = rng.uniform(-1, 1, (E, p, p, p)).astype(np.float32)
    u = rng.uniform(-1, 1, (E, p, p, p)).astype(np.float32)
    got = np.asarray(
        hh_ops.inverse_helmholtz(
            jnp.asarray(S, jnp.bfloat16), jnp.asarray(D, jnp.bfloat16),
            jnp.asarray(u, jnp.bfloat16), impl="interpret", block_elements=4,
        ).astype(jnp.float32)
    )
    want = reference.inverse_helmholtz_batch(
        S.astype(np.float64), D.astype(np.float64), u.astype(np.float64)
    )
    # bf16 storage, f32 accumulation: coarse bound
    np.testing.assert_allclose(got, want, rtol=0.15, atol=0.3)


def test_helmholtz_kernel_rejects_ragged_blocks(rng):
    p = 5
    S = rng.uniform(-1, 1, (p, p)).astype(np.float32)
    D = rng.uniform(-1, 1, (6, p, p, p)).astype(np.float32)
    u = rng.uniform(-1, 1, (6, p, p, p)).astype(np.float32)
    with pytest.raises(ValueError):
        hh_ops.inverse_helmholtz(S, D, u, impl="interpret", block_elements=4)


# ---------------------------------------------------------------------------
# flash attention kernel
# ---------------------------------------------------------------------------

SWEEP = [
    # B, Hq, Hkv, Tq, Tk, d, causal
    (2, 4, 2, 64, 64, 32, True),
    (1, 8, 2, 32, 128, 16, True),     # GQA 4:1, cross-length causal
    (2, 2, 2, 64, 64, 64, False),
    (1, 4, 1, 128, 128, 32, True),    # MQA
    (1, 2, 2, 16, 16, 128, True),
]


@pytest.mark.parametrize("case", SWEEP)
def test_flash_attention_vs_oracle(case, rng):
    B, Hq, Hkv, Tq, Tk, d, causal = case
    q = rng.normal(size=(B, Hq, Tq, d)).astype(np.float32)
    k = rng.normal(size=(B, Hkv, Tk, d)).astype(np.float32)
    v = rng.normal(size=(B, Hkv, Tk, d)).astype(np.float32)
    want = np.asarray(
        attention_ref(
            q.reshape(B * Hq, Tq, d), k.reshape(B * Hkv, Tk, d),
            v.reshape(B * Hkv, Tk, d),
            n_q_heads=Hq, n_kv_heads=Hkv, causal=causal,
        )
    ).reshape(B, Hq, Tq, d)
    got = np.asarray(
        attn_ops.multi_head_attention(
            q, k, v, causal=causal, impl="interpret",
            block_q=16, block_k=32,
        )
    )
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_flash_attention_block_size_invariance(rng):
    B, Hq, Hkv, T, d = 1, 2, 1, 128, 32
    q = rng.normal(size=(B, Hq, T, d)).astype(np.float32)
    k = rng.normal(size=(B, Hkv, T, d)).astype(np.float32)
    v = rng.normal(size=(B, Hkv, T, d)).astype(np.float32)
    outs = [
        np.asarray(attn_ops.multi_head_attention(
            q, k, v, impl="interpret", block_q=bq, block_k=bk,
        ))
        for bq, bk in [(16, 16), (32, 64), (128, 128)]
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=2e-5, atol=2e-5)


def test_flash_attention_xla_path_matches(rng):
    B, Hq, Hkv, T, d = 2, 4, 2, 64, 32
    q = rng.normal(size=(B, Hq, T, d)).astype(np.float32)
    k = rng.normal(size=(B, Hkv, T, d)).astype(np.float32)
    v = rng.normal(size=(B, Hkv, T, d)).astype(np.float32)
    a = np.asarray(attn_ops.multi_head_attention(q, k, v, impl="xla"))
    b = np.asarray(attn_ops.multi_head_attention(
        q, k, v, impl="interpret", block_q=16, block_k=16))
    np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)
