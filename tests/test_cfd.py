"""CFD operators end-to-end vs the numpy oracles, all backends."""
import numpy as np
import pytest

from repro.cfd import operators, reference, simulation
from repro.cfd.simulation import SimConfig


@pytest.mark.parametrize("backend", ["xla", "staged"])
@pytest.mark.parametrize("p", [5, 7])
def test_inverse_helmholtz_backends(backend, p, rng):
    c = operators.build_inverse_helmholtz(p, backend=backend)
    E = 6
    S = rng.uniform(-1, 1, (p, p)).astype(np.float32)
    D = rng.uniform(-1, 1, (E, p, p, p)).astype(np.float32)
    u = rng.uniform(-1, 1, (E, p, p, p)).astype(np.float32)
    got = np.asarray(c.batched_fn({"S": S, "D": D, "u": u})["v"])
    want = reference.inverse_helmholtz_batch(
        S.astype(np.float64), D.astype(np.float64), u.astype(np.float64)
    )
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


def test_interpolation(rng):
    n, m = 7, 9
    c = operators.build_interpolation(n, m)
    A = rng.uniform(-1, 1, (m, n)).astype(np.float32)
    u = rng.uniform(-1, 1, (3, n, n, n)).astype(np.float32)
    got = np.asarray(c.batched_fn({"A": A, "u": u})["v"])
    for e in range(3):
        want = reference.interpolation(
            A.astype(np.float64), u[e].astype(np.float64)
        )
        np.testing.assert_allclose(got[e], want, rtol=3e-4, atol=3e-4)


def test_gradient(rng):
    nx, ny, nz = 8, 7, 6
    c = operators.build_gradient(nx, ny, nz)
    Dx = rng.uniform(-1, 1, (nx, nx)).astype(np.float32)
    Dy = rng.uniform(-1, 1, (ny, ny)).astype(np.float32)
    Dz = rng.uniform(-1, 1, (nz, nz)).astype(np.float32)
    u = rng.uniform(-1, 1, (2, nx, ny, nz)).astype(np.float32)
    out = c.batched_fn({"Dx": Dx, "Dy": Dy, "Dz": Dz, "u": u})
    for e in range(2):
        gx, gy, gz = reference.gradient(
            *(a.astype(np.float64) for a in (Dx, Dy, Dz)),
            u[e].astype(np.float64),
        )
        np.testing.assert_allclose(np.asarray(out["gx"])[e], gx, rtol=3e-4, atol=3e-4)
        np.testing.assert_allclose(np.asarray(out["gy"])[e], gy, rtol=3e-4, atol=3e-4)
        np.testing.assert_allclose(np.asarray(out["gz"])[e], gz, rtol=3e-4, atol=3e-4)


def test_simulation_driver_batching():
    cfg = SimConfig(p=5, n_eq=512, batch_elements=128)
    assert cfg.n_batches == 4
    res = simulation.run_simulation(cfg, max_batches=2)
    assert res.elements == 256
    assert np.isfinite(res.checksum)


def test_simulation_double_buffer_equivalence():
    """Ping/pong staging must not change results (paper Fig. 14a)."""
    a = simulation.run_simulation(
        SimConfig(p=5, n_eq=256, batch_elements=64, double_buffer=True),
        max_batches=3,
    )
    b = simulation.run_simulation(
        SimConfig(p=5, n_eq=256, batch_elements=64, double_buffer=False),
        max_batches=3,
    )
    assert abs(a.checksum - b.checksum) < 1e-3


def test_batch_for_channel_matches_paper_sizing():
    """Paper: E = elements whose I/O fits one 256 MB HBM pseudo-channel."""
    E = SimConfig.batch_for_channel(11, bytes_per_scalar=8)
    assert E == (256 * 2 ** 20) // (3 * 11 ** 3 * 8)


def test_opcount_model():
    assert reference.paper_flops_per_element(11) == 177023
    assert reference.paper_flops_per_element(7) == 29155
