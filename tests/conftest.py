"""Shared fixtures.  NOTE: no XLA_FLAGS here -- tests run on the real
device count (1 CPU); multi-device tests spawn subprocesses that set
--xla_force_host_platform_device_count themselves."""
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def subprocess_env(n_devices: int) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_devices}"
    )
    env["PYTHONPATH"] = (
        os.path.join(os.path.dirname(__file__), "..", "src")
        + os.pathsep + env.get("PYTHONPATH", "")
    )
    return env
