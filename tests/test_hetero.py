"""Heterogeneous topology: per-device kind derivation, kind-aware
placement + pricing, per-stage E with re-block handoffs, and the
placement-aware channel assignment.

Acceptance (ISSUE 10): ``from_jax`` derives kinds per device and rejects
unsupported mixes; ``explore_chain`` over a mixed 2-kind topology never
ranks behind the best homogeneous-restricted plan on the same device
budget; re-blocked heterogeneous execution is bitwise-equal to the
single-mesh serial reference for random per-stage E vectors (hypothesis
property with a deterministic fallback); a forced-2-kind subprocess run
executes the cross-kind handoff on a real 2-device mesh.
"""
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from conftest import subprocess_env
from repro.cfd import operators, simulation
from repro.core import dsl
from repro.flow import build
from repro.flow import cli as flow_cli
from repro.memory import chain as mchain
from repro.memory import channels, dse
from repro.memory.placement import (DeviceTopology, PlacementError,
                                    resolve_kind_target)


# ---------------------------------------------------------------------------
# from_jax: per-device kind derivation (the satellite bugfix)
# ---------------------------------------------------------------------------


class _FakeDev:
    """Just enough of a jax.Device for from_jax: a .platform."""

    def __init__(self, platform):
        self.platform = platform


def test_from_jax_mixed_pool_derives_per_device_kinds():
    """Regression: the topology used to assume devs[0].platform for the
    whole fleet; a mixed pool must become one group per kind, each
    carrying its own datasheet."""
    devs = [_FakeDev("cpu"), _FakeDev("tpu"), _FakeDev("tpu")]
    topo = DeviceTopology.from_jax(devs)
    assert [g.kind for g in topo.groups] == ["cpu-host", "tpu-v5e"]
    assert [g.n_devices for g in topo.groups] == [1, 2]
    assert topo.groups[0].target is channels.CPU_HOST
    assert topo.groups[1].target is channels.TPU_V5E
    assert topo.device_kind == "mixed"
    assert topo.heterogeneous_kinds
    assert topo.spec_string() == "cpu-host:1+tpu-v5e:2"


def test_from_jax_homogeneous_pool_keeps_legacy_single_group():
    homo = DeviceTopology.from_jax([_FakeDev("cpu")] * 3)
    assert len(homo.groups) == 1
    assert homo.groups[0].target is None  # plan-wide target still rules
    assert homo.device_kind == "cpu"
    assert not homo.heterogeneous_kinds


def test_from_jax_rejects_unsupported_mixes_clearly():
    with pytest.raises(PlacementError, match="interleave"):
        DeviceTopology.from_jax(
            [_FakeDev("cpu"), _FakeDev("tpu"), _FakeDev("cpu")]
        )
    with pytest.raises(PlacementError, match="no memory datasheet"):
        DeviceTopology.from_jax([_FakeDev("cpu"), _FakeDev("quantum")])
    with pytest.raises(PlacementError, match=">= 1 device"):
        DeviceTopology.from_jax([])


def test_parse_spec_strings_and_kind_aliases():
    topo = DeviceTopology.parse("cpu:2,tpu:4")
    assert topo.n_devices == 6
    assert topo.spec_string() == "cpu-host:2+tpu-v5e:4"
    assert DeviceTopology.parse("4").spec_string() == "4xgeneric"
    assert resolve_kind_target("alveo") is channels.ALVEO_U280
    assert resolve_kind_target("host") is channels.CPU_HOST
    assert resolve_kind_target("generic") is None
    for bad in ("", "cpu-2", "cpu:", ":2", "cpu:x"):
        with pytest.raises(PlacementError):
            DeviceTopology.parse(bad)


# ---------------------------------------------------------------------------
# kind-aware pricing, per-stage E, channels, signature
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def cfd_chain():
    return operators.build_cfd_chain(5)


def _hetero_plan(cfd_chain, **kw):
    args = dict(
        target=channels.ALVEO_U280, batch_elements=256,
        prefetch_depth=(2, 1, 1), cu_count=(1, 2, 1),
        topology=DeviceTopology.parse("cpu:1,alveo:2"),
        stage_groups=(0, 1, 1), n_eq=1 << 12,
    )
    args.update(kw)
    return mchain.plan_chain(cfd_chain, **args)


def test_per_stage_targets_price_each_group(cfd_chain):
    plan = _hetero_plan(cfd_chain)
    assert plan.feasible
    assert [sp.kind for sp in plan.stages] == [
        "cpu-host", "alveo-u280", "alveo-u280"]
    # the cpu-host stage is priced against the host datasheet: same
    # stage planned on the alveo group is strictly faster on HBM
    alveo = _hetero_plan(cfd_chain, stage_groups=(1, 1, 1), cu_count=1)
    cpu0 = plan.stages[0].cost
    alv0 = alveo.stages[0].cost
    assert cpu0.t_hbm > alv0.t_hbm


def test_channel_assignment_per_group_bases(cfd_chain):
    """Each stream's channels come from the producing stage's group:
    cpu-host ids stay inside [0, 4), alveo ids inside [4, 36)."""
    plan = _hetero_plan(cfd_chain)
    n_cpu = channels.CPU_HOST.n_channels
    for i, sp in enumerate(plan.stages):
        ids = {c for b in sp.buffers for c in b.channels}
        assert ids, sp.name
        if plan.placement.stage_kind(i) == "cpu-host":
            assert max(ids) < n_cpu
        else:
            assert min(ids) >= n_cpu
    rep = plan.report()
    total = plan.placement.topology.total_channels(plan.target)
    assert total == n_cpu + channels.ALVEO_U280.n_channels
    assert f"/{total} used" in rep
    # per-stage (kind, E, channels) lines in the placement section
    assert "stage interp: kind=cpu-host" in rep
    assert "kind=alveo-u280" in rep


def test_reblock_term_prices_e_and_kind_changes(cfd_chain):
    """A handoff across an E or kind change carries an explicit
    re-block term billed to the consumer stage; uniform same-kind plans
    carry none."""
    uniform = mchain.plan_chain(
        cfd_chain, target=channels.ALVEO_U280, batch_elements=256,
        prefetch_depth=1, n_eq=1 << 12,
    )
    assert uniform.cost.t_reblock == ()
    assert uniform.cost.t_reblock_total == 0.0
    hetero = _hetero_plan(cfd_chain, stage_batch_elements=(64, 256, 256))
    assert hetero.stage_batch_elements == (64, 256, 256)
    assert hetero.stage_e(0) == 64 and hetero.stage_e(2) == 256
    rb = hetero.cost.t_reblock
    assert rb and rb[0] == 0.0       # nothing flows into stage 0
    assert rb[1] > 0.0               # E change AND kind change at 0->1
    assert rb[2] == 0.0              # same E, same kind at 1->2
    assert hetero.cost.t_serial >= sum(rb)
    assert "re-block handoffs:" in hetero.report()
    # kind change alone (uniform E) still pays the slower link
    kind_only = _hetero_plan(cfd_chain)
    assert kind_only.cost.t_reblock[1] > 0.0


def test_signature_hashes_hetero_spec_and_stage_e(cfd_chain):
    """Plans differing only in group assignment or per-stage E must not
    share a signature (the profile store and serve cache key on it)."""
    uniform = mchain.plan_chain(
        cfd_chain, target=channels.ALVEO_U280, batch_elements=256,
        prefetch_depth=(2, 1, 1), cu_count=(1, 2, 1),
        topology=DeviceTopology.homogeneous(3), n_eq=1 << 12,
    )
    hetero = _hetero_plan(cfd_chain)
    swapped = _hetero_plan(cfd_chain, stage_groups=(1, 1, 0),
                           cu_count=(2, 1, 1))
    blocked = _hetero_plan(cfd_chain, stage_batch_elements=(64, 256, 256))
    sigs = {uniform.signature, hetero.signature, swapped.signature,
            blocked.signature}
    assert len(sigs) == 4


def test_snap_stage_elements_divides_and_aligns():
    snap = mchain.snap_stage_elements
    assert snap(256, 64, 1) == 64
    assert snap(256, 100, 1) == 64   # largest divisor <= request
    assert snap(256, 64, 8) == 64    # already a multiple of cu
    assert snap(240, 50, 4) == 48    # divisor of 240, multiple of 4
    assert snap(256, 1, 4) == 4      # floor at cu
    assert snap(7, 3, 2) == 7        # no aligned divisor: whole batch


# ---------------------------------------------------------------------------
# acceptance: hetero DSE never ranks behind homogeneous-restricted
# ---------------------------------------------------------------------------


def test_explore_chain_hetero_beats_homogeneous_restricted(cfd_chain):
    """The mixed 2-kind winner's predicted pipelined time is <= the
    best plan with every stage pinned to one kind group (same device
    budget): the hetero search sweeps each group's uniform grid
    explicitly, so the restricted optimum is in its candidate set."""
    topo = DeviceTopology.parse("cpu:2,alveo:2")
    space = dse.ChainDesignSpace(
        backends=("xla",), batch_divisors=(1,),
        prefetch_depths=(0, 1), cu_counts=(1, 2), max_placements=8,
    )
    cands = dse.explore_chain(
        cfd_chain, target=channels.ALVEO_U280, n_eq=1 << 14,
        space=space, topology=topo,
    )
    best = next(c for c in cands if c.plan.feasible)
    n = len(cfd_chain.stages)
    restricted = []
    for gi in range(len(topo.groups)):
        for cu in (1, 2):
            for depth in (0, 1):
                p = mchain.plan_chain(
                    cfd_chain, target=channels.ALVEO_U280,
                    prefetch_depth=depth, cu_count=cu, topology=topo,
                    stage_groups=[gi] * n, n_eq=1 << 14,
                )
                if p.feasible:
                    restricted.append(
                        p.cost.t_pipelined / p.batch_elements
                    )
    assert restricted
    assert best.predicted_s_per_element <= min(restricted) * (1 + 1e-9)
    # and the sweep really used both kinds somewhere in the ranking
    kinds_seen = {
        c.plan.placement.stage_kind(i)
        for c in cands for i in range(n)
    }
    assert {"cpu-host", "alveo-u280"} <= kinds_seen


def test_explore_chain_hetero_candidates_are_executable_specs(cfd_chain):
    """Every ranked hetero candidate carries a single-kind group per
    stage and a stage E that divides the chain E and shards on its
    group."""
    topo = DeviceTopology.parse("cpu:1,alveo:2")
    space = dse.ChainDesignSpace(
        backends=("xla",), batch_divisors=(1, 4),
        prefetch_depths=(0, 1), cu_counts=(1, 2), max_placements=8,
    )
    cands = dse.explore_chain(
        cfd_chain, target=channels.ALVEO_U280, n_eq=1 << 14,
        space=space, topology=topo,
    )
    assert cands
    for c in cands:
        plan = c.plan
        for i, sp in enumerate(plan.stages):
            e_s = plan.stage_e(i)
            assert plan.batch_elements % e_s == 0
            assert e_s % sp.cu_count == 0
            gi = plan.placement.stage_group_index(i)
            assert sp.cu_count <= topo.groups[gi].n_devices


# ---------------------------------------------------------------------------
# property: re-blocked execution bitwise-equal to the serial reference
# ---------------------------------------------------------------------------

_REF_CACHE = {}


def _reblock_fixture():
    if "ref" not in _REF_CACHE:
        p, E, n_b = 5, 16, 2
        n = E * n_b
        ch = operators.build_cfd_chain(p)
        rng = np.random.default_rng(3)
        inputs = {
            "interp.u": rng.uniform(
                -1, 1, (n, p, p, p)).astype(np.float32),
            "helmholtz.D": rng.uniform(
                -1, 1, (n, p, p, p)).astype(np.float32),
        }
        shared = {
            name: rng.uniform(-1, 1, node.shape).astype(np.float32)
            for name, node in sorted(ch.shared_operands().items())
        }
        base_plan = mchain.plan_chain(
            ch, target=channels.CPU_HOST, batch_elements=E, n_eq=n,
            prefetch_depth=0,
        )
        base = simulation.run_chain(
            ch, base_plan, inputs=inputs, shared=shared,
            collect_outputs=True, pipeline_stages=False,
        )
        _REF_CACHE["ref"] = (ch, E, n, inputs, shared, base.outputs)
    return _REF_CACHE["ref"]


def _check_reblocked_bitwise(divs, depths):
    ch, E, n, inputs, shared, want = _reblock_fixture()
    plan = mchain.plan_chain(
        ch, target=channels.CPU_HOST, batch_elements=E, n_eq=n,
        prefetch_depth=list(depths),
        stage_batch_elements=[E // d for d in divs],
    )
    assert plan.feasible
    got = simulation.run_chain(
        ch, plan, inputs=inputs, shared=shared, collect_outputs=True,
    )
    for q in want:
        assert np.array_equal(want[q], got.outputs[q]), (q, divs, depths)


try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI installs hypothesis
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @given(
        divs=st.tuples(*[st.sampled_from([1, 2, 4, 8])] * 3),
        depths=st.tuples(*[st.integers(0, 2)] * 3),
    )
    @settings(max_examples=10, deadline=None)
    def test_reblocked_execution_bitwise_equal_property(divs, depths):
        _check_reblocked_bitwise(divs, depths)

else:  # deterministic fallback so the property still runs everywhere

    @pytest.mark.parametrize("divs,depths", [
        ((1, 1, 1), (1, 1, 1)),
        ((2, 1, 4), (2, 0, 1)),
        ((8, 2, 1), (0, 1, 2)),
        ((4, 4, 4), (1, 1, 1)),
        ((1, 8, 2), (2, 2, 2)),
    ])
    def test_reblocked_execution_bitwise_equal_property(divs, depths):
        _check_reblocked_bitwise(divs, depths)


# ---------------------------------------------------------------------------
# flow + CLI + cache key: the hetero spec threads end-to-end
# ---------------------------------------------------------------------------

P = 3
SRC = dsl.INVERSE_HELMHOLTZ_SRC.format(p=P)
FLOW_KW = dict(
    element_vars=("u", "D", "v"), target=channels.CPU_HOST,
    batch_elements=4, n_eq=8,
)


def test_flow_compile_accepts_hetero_devices_spec():
    system = build.compile(SRC, devices="cpu:1,alveo:1", **FLOW_KW)
    topo = system.plan.placement.topology
    assert len(topo.groups) == 2
    assert topo.spec_string() == "cpu-host:1+alveo-u280:1"
    assert "kind=" in system.report()
    with pytest.raises(build.FlowError, match="kind:count"):
        build.compile(SRC, devices="cpu-2", **FLOW_KW)


def test_topology_fingerprint_hashes_hetero_spec():
    assert build.topology_fingerprint(None) == "auto"
    assert build.topology_fingerprint(3) == "3xgeneric"
    assert (build.topology_fingerprint("cpu:1,alveo:2")
            == "cpu-host:1+alveo-u280:2")
    assert (build.topology_fingerprint(
        DeviceTopology.parse("cpu:1,alveo:2"))
        == "cpu-host:1+alveo-u280:2")
    # the serve cache key separates hetero specs from same-size pools
    k_hetero = build.cache_key(SRC, devices="cpu:1,alveo:2", **FLOW_KW)
    k_flat = build.cache_key(SRC, devices=3, **FLOW_KW)
    k_other = build.cache_key(SRC, devices="cpu:2,alveo:1", **FLOW_KW)
    assert len({k_hetero, k_flat, k_other}) == 3


def test_flow_cli_devices_spec(tmp_path, capsys):
    src = tmp_path / "p.cfd"
    src.write_text(SRC)
    rc = flow_cli.main([
        str(src), "--element-vars", "u,D,v", "--target", "cpu-host",
        "--devices", "cpu:1,alveo:1",
    ])
    assert rc == 0
    out = capsys.readouterr().out
    assert "kind=cpu-host" in out or "kind=alveo-u280" in out
    rc = flow_cli.main([
        str(src), "--element-vars", "u,D,v", "--target", "cpu-host",
        "--devices", "cpu-2",
    ])
    assert rc == 2
    assert "kind:count" in capsys.readouterr().err


# ---------------------------------------------------------------------------
# acceptance: forced-2-kind subprocess executes the cross-kind handoff
# ---------------------------------------------------------------------------

HETERO_SCRIPT = textwrap.dedent("""
    import json
    import numpy as np
    import jax

    from repro.cfd import operators, simulation
    from repro.memory import chain as mchain
    from repro.memory import channels
    from repro.memory.placement import DeviceTopology

    assert jax.device_count() == 2, jax.devices()
    p, E, n_b = 5, 16, 4
    n = E * n_b
    chain = operators.build_cfd_chain(p)
    rng = np.random.default_rng(0)
    inputs = {
        "interp.u": rng.uniform(-1, 1, (n, p, p, p)).astype(np.float32),
        "helmholtz.D": rng.uniform(-1, 1, (n, p, p, p)).astype(np.float32),
    }
    shared = {
        name: rng.uniform(-1, 1, node.shape).astype(np.float32)
        for name, node in sorted(chain.shared_operands().items())
    }

    # a declared 2-kind fleet over the 2 forced host devices: stage 0 on
    # the cpu-host group at half E, the rest on the alveo group -- the
    # 0->1 handoff re-blocks AND crosses kinds
    topo = DeviceTopology.parse("cpu:1,alveo:1")
    plan = mchain.plan_chain(
        chain, target=channels.CPU_HOST, batch_elements=E, n_eq=n,
        prefetch_depth=(2, 1, 1), cu_count=1, topology=topo,
        stage_groups=(0, 1, 1), stage_batch_elements=(E // 2, E, E),
    )
    assert plan.feasible, plan.infeasible_reason
    assert plan.placement.stage_kind(0) == "cpu-host"
    assert plan.placement.stage_kind(1) == "alveo-u280"
    assert plan.cost.t_reblock[1] > 0.0
    piped = simulation.run_chain(
        chain, plan, inputs=inputs, shared=shared, collect_outputs=True,
    )
    assert piped.placement_groups is not None

    base_plan = mchain.plan_chain(
        chain, target=channels.CPU_HOST, batch_elements=E, n_eq=n,
        prefetch_depth=0,
    )
    base = simulation.run_chain(
        chain, base_plan, inputs=inputs, shared=shared,
        collect_outputs=True, pipeline_stages=False,
    )
    equal = all(
        np.array_equal(base.outputs[q], piped.outputs[q])
        for q in base.outputs
    )
    print(json.dumps({
        "equal": bool(equal),
        "groups": [list(g) for g in piped.placement_groups],
        "kinds": [plan.placement.stage_kind(i) for i in range(3)],
        "stage_e": list(plan.stage_batch_elements),
    }))
""")


@pytest.mark.slow
def test_two_kind_placement_bitwise_equal_subprocess():
    """Acceptance: a 2-kind placement with a re-blocked cross-kind
    handoff executes bitwise-equal to the serial single-mesh reference
    on a real 2-device mesh (mirrors the forced-2-device homogeneous
    test)."""
    import json

    res = subprocess.run(
        [sys.executable, "-c", HETERO_SCRIPT],
        env=subprocess_env(2), capture_output=True, text=True, timeout=420,
    )
    assert res.returncode == 0, res.stderr[-3000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert out["equal"] is True
    assert out["kinds"] == ["cpu-host", "alveo-u280", "alveo-u280"]
    assert out["stage_e"] == [8, 16, 16]
    assert out["groups"][0] != out["groups"][1]  # distinct kind groups
