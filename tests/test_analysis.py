"""Roofline analysis: collective-bytes HLO parsing + report math +
small-scale dry-run (the real 512-way dry-run runs via launch.dryrun)."""
import numpy as np
import pytest

from repro.analysis import roofline


def test_collective_parser_basic():
    hlo = """
  %all-reduce.1 = f32[1024,512]{1,0} all-reduce(%add.3), channel_id=1
  %ag = bf16[8,256]{1,0} all-gather(%p0), dimensions={0}
  %rs = f32[128]{0} reduce-scatter(%x), dimensions={0}
  %cp = f32[64,64]{1,0} collective-permute(%y), source_target_pairs={{0,1}}
  %unrelated = f32[2,2]{1,0} add(%a, %b)
"""
    got = roofline.collective_bytes(hlo)
    assert got["all-reduce"] == 1024 * 512 * 4
    assert got["all-gather"] == 8 * 256 * 2
    assert got["reduce-scatter"] == 128 * 4
    assert got["collective-permute"] == 64 * 64 * 4


def test_collective_parser_tuple_and_async():
    hlo = """
  %a2a = (f32[8,16]{1,0}, f32[8,16]{1,0}) all-to-all(%x, %y), dimensions={0}
  %ar-start = f32[100]{0} all-reduce-start(%z), channel_id=3
  %ar-done = f32[100]{0} all-reduce-done(%ar-start)
"""
    got = roofline.collective_bytes(hlo)
    assert got["all-to-all"] == 2 * 8 * 16 * 4
    assert got["all-reduce"] == 100 * 4  # start counted, done not


def test_report_terms_and_bottleneck():
    r = roofline.RooflineReport(
        arch="a", shape="s", mesh="single", chips=256,
        device_flops=197e12,          # exactly 1s of compute
        device_bytes=819e9 * 0.5,     # 0.5s of memory
        coll_bytes=50e9 * 0.25,       # 0.25s of collectives
        coll_breakdown={}, bytes_per_device=10,
        model_flops=197e12 * 256 * 0.8,
    )
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(0.5)
    assert r.t_collective == pytest.approx(0.25)
    assert r.bottleneck == "compute"
    assert r.useful_flops_ratio == pytest.approx(0.8)
    assert r.roofline_fraction == pytest.approx(1.0)


def test_model_flops():
    assert roofline.model_flops(params=10, tokens=5, kind="train") == 300
    assert roofline.model_flops(params=10, tokens=5, kind="prefill") == 100
    assert roofline.model_flops(
        params=10, tokens=5, kind="train", active_params=4
    ) == 120


def test_format_table_runs():
    r = roofline.RooflineReport(
        arch="x", shape="train_4k", mesh="single", chips=256,
        device_flops=1e12, device_bytes=1e12, coll_bytes=1e9,
        coll_breakdown={}, bytes_per_device=2 ** 30, model_flops=1e14,
    )
    s = roofline.format_table([r])
    assert "train_4k" in s and "memory" in s
