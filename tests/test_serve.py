"""repro.serve: plan cache, admission coalescing, the serving engine.

Acceptance (ISSUE PR 7): a repeat compile is a cache hit and never
re-plans (``plan_chain`` spy), coalesced waves produce outputs
bitwise-identical to per-request serial runs, wave padding is accounted
exactly through the ``batch_pad_elements`` counter machinery,
backpressure blocks or rejects at the configured window, drain raises
on an exhausted tick budget instead of returning silently, and shutdown
surfaces per-request errors instead of wedging the ring.  Satellites:
profile-store epoch aging, DSE ``profile=`` threading, CLI flag
validation, and driver resume-across-feeds.
"""
import json
import os

import numpy as np
import pytest

from repro import trace as trace_mod
from repro.core import dsl
from repro.flow import build
from repro.flow import cli as flow_cli
from repro.memory import channels
from repro.memory.pipeline import StagePipelineDriver, run_stage_pipelined
from repro.serve import (AdmissionQueue, Backpressure, DrainTimeout,
                         EngineShutdown, PlanCache, ServeEngine,
                         ServeRequest)
from repro.trace.attribution import (COUNTER_PAD_ELEMENTS,
                                     COUNTER_PLAN_CACHE,
                                     COUNTER_SERVE_REQUESTS,
                                     COUNTER_SERVE_WAVES)

P = 3
E = 4
SRC = dsl.INVERSE_HELMHOLTZ_SRC.format(p=P)
KW = dict(
    name="serve-fig2", element_vars=("u", "D", "v"),
    target=channels.CPU_HOST, batch_elements=E, n_eq=2 * E,
)


@pytest.fixture(scope="module")
def system():
    return build.compile(SRC, **KW)


def _requests(engine, sizes, seed=7, fill=None):
    rng = np.random.default_rng(seed)
    out = []
    for n in sizes:
        out.append({
            q: (np.full((n,) + shape, fill, np.float32) if fill is not None
                else rng.uniform(-1, 1, (n,) + shape).astype(np.float32))
            for q, shape in sorted(engine.in_specs.items())
        })
    return out


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


# ---------------------------------------------------------------------------
# plan cache: compile once, zero re-plans after the first compile
# ---------------------------------------------------------------------------

def test_plan_cache_hit_never_replans(monkeypatch):
    calls = []
    real = build.plan_chain

    def spy(*a, **kw):
        calls.append(1)
        return real(*a, **kw)

    monkeypatch.setattr(build, "plan_chain", spy)
    tracer = trace_mod.Tracer()
    cache = PlanCache(tracer=tracer)
    first = cache.get_or_compile(SRC, **KW)
    assert (cache.hits, cache.misses) == (0, 1)
    assert len(calls) == 1
    again = cache.get_or_compile(SRC, **KW)
    assert again is first
    assert (cache.hits, cache.misses) == (1, 1)
    assert cache.hit_rate == 0.5
    # the acceptance bar: ZERO re-plans after the first compile -- the
    # repeat compile AND standing up + serving an engine never plan again
    eng = ServeEngine(first, seed=0)
    for inp in _requests(eng, [E, 3]):
        eng.submit(inp)
    eng.drain()
    assert len(calls) == 1
    assert tracer.totals(COUNTER_PLAN_CACHE) == {"hit": 1.0, "miss": 1.0}


def test_cache_key_semantics():
    k1 = build.cache_key(SRC, **{k: v for k, v in KW.items() if k != "name"})
    # stable across calls; formatting is gone post-rewrite
    assert k1 == build.cache_key(
        "\n\n" + SRC.replace("\n", "\n\n"),
        **{k: v for k, v in KW.items() if k != "name"})
    kw2 = {k: v for k, v in KW.items() if k != "name"}
    kw2["policy"] = "float64"
    assert build.cache_key(SRC, **kw2) != k1
    kw3 = {k: v for k, v in KW.items() if k != "name"}
    kw3["batch_elements"] = 2 * E
    assert build.cache_key(SRC, **kw3) != k1
    # name= is presentation, not architecture: same key
    assert PlanCache().key(SRC, **KW) == build.cache_key(
        SRC, **{k: v for k, v in KW.items() if k != "name"})


def test_plan_cache_fifo_bound(system, monkeypatch):
    cache = PlanCache(max_systems=1)
    monkeypatch.setattr(build, "compile", lambda src, **kw: system)
    monkeypatch.setattr(PlanCache, "key", lambda self, src, **kw: src)
    cache.get_or_compile("a = 1")
    cache.get_or_compile("b = 2")
    assert len(cache) == 1
    cache.get_or_compile("b = 2")
    assert (cache.hits, cache.misses) == (1, 2)


# ---------------------------------------------------------------------------
# admission queue (pure host logic)
# ---------------------------------------------------------------------------

def _req(rid, n):
    return ServeRequest(rid=rid, inputs={}, n_elements=n)


def test_queue_coalesces_fifo_and_splits_large():
    q = AdmissionQueue(4)
    r0, r1, r2 = _req(0, 3), _req(1, 2), _req(2, 4)
    q.push(r0)
    assert not q.ready()           # 3 < E and no latency knob
    q.push(r1)
    q.push(r2)
    w1 = q.pop_wave()
    assert [(p.request.rid, p.lo, p.hi, p.dst) for p in w1.parts] == [
        (0, 0, 3, 0), (1, 0, 1, 3)]
    assert w1.pad_elements == 0
    w2 = q.pop_wave()              # r1's tail keeps FIFO order
    assert [(p.request.rid, p.lo, p.hi, p.dst) for p in w2.parts] == [
        (1, 1, 2, 0), (2, 0, 3, 1)]
    assert q.pop_wave() is None    # 1 element left: not due
    w3 = q.pop_wave(force=True)
    assert [(p.request.rid, p.lo, p.hi, p.dst) for p in w3.parts] == [
        (2, 3, 4, 0)]
    assert w3.pad_elements == 3
    assert (r0.parts, r1.parts, r2.parts) == (1, 2, 2)
    assert not q.pending_requests


def test_queue_max_wait_flushes_undersized_wave():
    clk = FakeClock()
    q = AdmissionQueue(4, max_wait_s=5.0, clock=clk)
    q.push(_req(0, 2))
    assert not q.ready()
    clk.t = 5.0
    assert q.ready()
    assert q.pop_wave().pad_elements == 2


def test_queue_remove_only_before_admission():
    q = AdmissionQueue(4)
    big = _req(0, 6)
    q.push(big)
    q.pop_wave(force=True)
    assert not q.remove(big)       # already partially admitted
    fresh = _req(1, 1)
    q.push(fresh)
    assert q.remove(fresh)
    assert q.pending_requests == [big]


# ---------------------------------------------------------------------------
# engine: coalesced == serial, bitwise
# ---------------------------------------------------------------------------

def test_coalesced_waves_bitwise_equal_serial(system):
    sizes = [3, 1, E, 2, 2 * E + 1, 1, 1, E - 1]
    coalesced = ServeEngine(system, seed=0)
    inputs = _requests(coalesced, sizes)
    served = [coalesced.submit(inp) for inp in inputs]
    coalesced.drain()
    assert all(r.error is None for r in served)
    total = sum(sizes)
    assert coalesced.stats["waves"] == -(-total // E)

    serial = ServeEngine(system, seed=0)
    for r, n, inp in zip(served, sizes, inputs):
        ref = serial.submit(inp)
        serial.drain()
        assert ref.error is None
        assert set(r.outputs) == set(coalesced.out_names)
        for q in coalesced.out_names:
            assert r.outputs[q].shape[0] == n
            assert np.array_equal(r.outputs[q], ref.outputs[q]), q


def test_engine_output_matches_direct_chain_eval(system):
    """Not just self-consistent: a request's outputs equal evaluating
    the chain's stage programs directly on its rows."""
    eng = ServeEngine(system, seed=0)
    (inp,) = _requests(eng, [E])
    req = eng.submit(inp)
    eng.drain()
    chain = system.chain
    live = {}
    for i, s in enumerate(chain.stages):
        env = {}
        for name in s.program.inputs:
            if name in chain.resolved[i]:
                pi, oname = chain.resolved[i][name]
                env[name] = live[f"{chain.stages[pi].name}.{oname}"]
            elif f"{s.name}.{name}" in inp:
                env[name] = inp[f"{s.name}.{name}"]
            else:
                env[name] = eng.shared_host[name]
        for oname, val in s.compiled.batched_fn(env).items():
            live[f"{s.name}.{oname}"] = np.asarray(val)
    for q in eng.out_names:
        assert np.array_equal(req.outputs[q], live[q]), q


def test_wave_pad_accounted_exactly(system):
    tracer = trace_mod.Tracer()
    eng = ServeEngine(system, tracer=tracer, seed=0)
    sizes = [3, E, 2]              # 9 elements -> 3 waves, 3 pad rows
    for inp in _requests(eng, sizes):
        eng.submit(inp)
    eng.drain()
    total = sum(sizes)
    waves = -(-total // E)
    pad = tracer.totals(COUNTER_PAD_ELEMENTS)
    assert pad.get("wave", 0.0) == float(waves * E - total)
    assert eng.stats["pad_elements"] == waves * E - total
    # the planner's own snap pad flows through the same counter, one
    # bump per wave, exactly batch_pad_elements each
    assert pad.get("pad", 0.0) == float(
        waves * system.plan.batch_pad_elements)
    assert eng.stats["plan_pad_elements"] == (
        waves * system.plan.batch_pad_elements)
    assert tracer.totals(COUNTER_SERVE_WAVES) == {"waves": float(waves)}
    reqs = tracer.totals(COUNTER_SERVE_REQUESTS)
    assert reqs["submitted"] == reqs["completed"] == float(len(sizes))


# ---------------------------------------------------------------------------
# backpressure, drain, shutdown semantics
# ---------------------------------------------------------------------------

def test_backpressure_blocks_at_window(system):
    eng = ServeEngine(system, window=1, seed=0)
    served = []
    for inp in _requests(eng, [E, E, E]):
        served.append(eng.submit(inp))
        assert len(eng._wave_parts) <= 1
    eng.drain()
    assert all(r.error is None and r.done for r in served)


def test_backpressure_rejects_at_window(system):
    eng = ServeEngine(system, window=1, reject=True, seed=0)
    first_inp, second_inp = _requests(eng, [E, E])
    first = eng.submit(first_inp)
    with pytest.raises(Backpressure):
        eng.submit(second_inp)
    assert eng.stats["rejected"] == 1
    rejected = [r for r in (first,) if isinstance(r.error, Backpressure)]
    assert not rejected            # the *first* request was admitted
    eng.drain()
    assert first.error is None and first.done
    # the rejected request is gone from the queue, not half-admitted
    assert eng.queue.pending_requests == []
    assert eng.stats["completed"] == 1


def test_drain_budget_exhaustion_raises_with_undrained(system):
    eng = ServeEngine(system, seed=0)
    (inp,) = _requests(eng, [E])
    req = eng.submit(inp)
    with pytest.raises(DrainTimeout) as ei:
        eng.drain(max_ticks=1)
    assert ei.value.undrained == [req]
    assert not req.done            # NOT silently "served"
    eng.drain()                    # a real budget finishes it
    assert req.done and req.error is None


def test_shutdown_surfaces_inflight_errors(system):
    eng = ServeEngine(system, seed=0)
    reqs = [eng.submit(inp) for inp in _requests(eng, [E, 2])]
    leftovers = eng.shutdown()
    assert set(id(r) for r in leftovers) <= set(id(r) for r in reqs)
    assert leftovers               # something was in flight
    for r in leftovers:
        assert isinstance(r.error, EngineShutdown) and r.done
    with pytest.raises(RuntimeError):
        eng.submit(_requests(eng, [1])[0])


def test_stage_error_poisons_only_its_wave(system):
    eng = ServeEngine(system, seed=0)
    q0 = sorted(eng.in_specs)[0]
    orig = eng.driver.stage_fns[0]

    def boom(staged, carry):
        if float(np.asarray(staged[q0]).ravel()[0]) == 777.0:
            raise RuntimeError("injected stage failure")
        return orig(staged, carry)

    eng.driver.stage_fns[0] = boom
    good1_inp, bad_inp, good2_inp = (
        _requests(eng, [E])[0],
        _requests(eng, [E], fill=777.0)[0],
        _requests(eng, [E], seed=11)[0],
    )
    good1 = eng.submit(good1_inp)
    bad = eng.submit(bad_inp)
    good2 = eng.submit(good2_inp)
    eng.drain()                    # the ring never wedges
    assert good1.error is None and good1.outputs is not None
    assert good2.error is None and good2.outputs is not None
    assert isinstance(bad.error, RuntimeError)
    assert "injected stage failure" in str(bad.error)
    assert eng.stats["failed"] == 1 and eng.stats["completed"] == 2


def test_max_wait_knob_flushes_partial_wave(system):
    clk = FakeClock()
    eng = ServeEngine(system, max_wait_s=5.0, seed=0, clock=clk)
    (inp,) = _requests(eng, [2])
    req = eng.submit(inp)
    for _ in range(4):
        eng.poll()
    assert eng.stats["waves"] == 0         # undersized, still young
    clk.t = 6.0
    eng.poll()
    assert eng.stats["waves"] == 1         # latency knob flushed it
    eng.drain()
    assert req.done and req.error is None
    assert req.outputs[eng.out_names[0]].shape[0] == 2


def test_submit_validates_request_shape(system):
    eng = ServeEngine(system, seed=0)
    (inp,) = _requests(eng, [2])
    with pytest.raises(ValueError):
        eng.submit({})                      # missing streams
    bad = dict(inp)
    q0 = sorted(eng.in_specs)[0]
    bad[q0] = bad[q0][:, :-1]               # wrong row shape
    with pytest.raises(ValueError):
        eng.submit(bad)


# ---------------------------------------------------------------------------
# driver: resume across feeds (the serve engine's contract)
# ---------------------------------------------------------------------------

def _arith_stages():
    def s0(staged, carry):
        return staged * 1.0

    def s1(staged, carry):
        return carry * 3.0

    return [s0, s1]


def test_driver_incremental_feed_matches_batch_run():
    want = run_stage_pipelined(
        _arith_stages(), [float(x) for x in range(6)], depths=[2, 1]
    )
    drv = StagePipelineDriver(_arith_stages(), depths=[2, 1])
    fed = 0
    # feed two, let the ring go COMPLETELY idle, then resume with four
    for _ in range(2):
        drv.feed(float(fed))
        fed += 1
    for _ in range(30):
        drv.tick()
    assert drv.idle and drv.in_flight == 2  # delivered, waiting in take()
    for _ in range(4):
        assert drv.wants_input or drv.tick() or True
        drv.feed(float(fed))
        fed += 1
    drv.close()
    while not drv.idle:
        drv.tick()
    got = drv.take()
    assert [k for k, _ in got] == list(range(6))
    assert [v for _, v in got] == want


def test_driver_capture_errors_poisons_and_delivers():
    def s0(staged, carry):
        if staged == 2.0:
            raise ValueError("bad batch")
        return staged * 3.0

    drv = StagePipelineDriver([s0], depths=[1], capture_errors=True)
    for x in range(4):
        drv.feed(float(x))
    drv.close()
    while not drv.idle:
        drv.tick()
    got = dict(drv.take())
    assert got[0] == 0.0 and got[1] == 3.0 and got[3] == 9.0
    assert isinstance(got[2], ValueError)


# ---------------------------------------------------------------------------
# satellites: profile epoch aging, DSE profile threading, CLI validation
# ---------------------------------------------------------------------------

def test_profile_epoch_aging_on_cost_model_bump(tmp_path, monkeypatch):
    from repro.memory import dse
    from repro.trace.profile import ProfileStore

    p = str(tmp_path / "prof.json")
    store = ProfileStore(path=p, fingerprint="fp")
    assert store.epoch == f"v{dse.COST_MODEL_VERSION}"
    n = store.record("tgt", "sig", [
        {"predicted_s": 1.0, "measured_s": 2.0, "bottleneck": "hbm"}])
    assert n == 1 and len(store.samples("tgt", "sig")) == 1
    assert store.correction("tgt", "sig").factor == pytest.approx(2.0)

    # cost model changes -> old (predicted, measured) ratios are ratios
    # against the WRONG predictions; the refit must not see them
    monkeypatch.setattr(dse, "COST_MODEL_VERSION", dse.COST_MODEL_VERSION + 1)
    bumped = ProfileStore(path=p, fingerprint="fp")
    assert bumped.epoch != store.epoch
    assert bumped.samples("tgt", "sig") == []
    corr = bumped.correction("tgt", "sig")
    assert corr.factor == 1.0 and corr.n_samples == 0
    # recording post-bump prunes the stale bucket in the file
    bumped.record("tgt", "sig", [
        {"predicted_s": 1.0, "measured_s": 3.0, "bottleneck": "hbm"}])
    assert [s["measured_s"] for s in bumped.samples("tgt", "sig")] == [3.0]
    on_disk = json.load(open(p))["entries"]["fp/tgt/sig"]
    assert len(on_disk) == 1 and on_disk[0]["epoch"] == bumped.epoch


def test_profile_pre_epoch_store_loads_gracefully(tmp_path):
    from repro.trace.profile import ProfileStore

    p = str(tmp_path / "old.json")
    with open(p, "w") as f:        # a store written before epochs existed
        json.dump({"version": 1, "entries": {"fp/tgt/sig": [
            {"predicted_s": 1.0, "measured_s": 9.0, "bottleneck": "hbm",
             "scope": "chain"}]}}, f)
    store = ProfileStore(path=p, fingerprint="fp")
    assert store.samples("tgt", "sig") == []
    assert store.correction("tgt", "sig").factor == 1.0
    assert store.record("tgt", "sig", [
        {"predicted_s": 1.0, "measured_s": 2.0, "bottleneck": "hbm"}]) == 1
    assert len(store.samples("tgt", "sig")) == 1


def test_compile_threads_profile_into_dse(tmp_path, monkeypatch):
    from repro.memory import dse as dse_mod
    from repro.trace.profile import ProfileStore

    store = ProfileStore(path=str(tmp_path / "p.json"), fingerprint="fp")
    seen = {}
    real = dse_mod.explore_chain

    def spy(*a, **kw):
        seen["profile"] = kw.get("profile")
        return real(*a, **kw)

    monkeypatch.setattr(dse_mod, "explore_chain", spy)
    system = build.compile(SRC, dse=True, profile=store, **KW)
    assert seen["profile"] is store
    assert system.plan.feasible


def test_profile_src_digest_aging_on_planner_edit(tmp_path):
    """A planner-source change under an unchanged COST_MODEL_VERSION
    still ages out old samples: the src stamp gates code drift, not
    just declared epochs."""
    from repro.trace.profile import ProfileStore, plan_code_digest

    p = str(tmp_path / "prof.json")
    store = ProfileStore(path=p, fingerprint="fp")
    assert store.src == plan_code_digest()
    store.record("tgt", "sig", [
        {"predicted_s": 1.0, "measured_s": 2.0, "bottleneck": "hbm"}])
    assert len(store.samples("tgt", "sig")) == 1
    on_disk = json.load(open(p))["entries"]["fp/tgt/sig"]
    assert on_disk[0]["src"] == store.src

    # same epoch, different planner source -> the old ratios measured a
    # different planner; the refit must not see them
    edited = ProfileStore(path=p, fingerprint="fp", src="feedbeefcafe")
    assert edited.epoch == store.epoch
    assert edited.samples("tgt", "sig") == []
    assert edited.correction("tgt", "sig").n_samples == 0
    # recording post-edit prunes the stale bucket in the file
    edited.record("tgt", "sig", [
        {"predicted_s": 1.0, "measured_s": 4.0, "bottleneck": "hbm"}])
    on_disk = json.load(open(p))["entries"]["fp/tgt/sig"]
    assert len(on_disk) == 1 and on_disk[0]["src"] == "feedbeefcafe"


def test_profile_src_unstamped_samples_tolerated(tmp_path):
    """Samples recorded before the src stamp existed (right epoch, no
    src key) still surface: the digest gates drift, it does not orphan
    pre-stamp history."""
    from repro.trace.profile import ProfileStore, cost_model_epoch

    p = str(tmp_path / "old.json")
    with open(p, "w") as f:
        json.dump({"version": 1, "entries": {"fp/tgt/sig": [
            {"predicted_s": 1.0, "measured_s": 2.0, "bottleneck": "hbm",
             "epoch": cost_model_epoch()}]}}, f)
    store = ProfileStore(path=p, fingerprint="fp")
    assert len(store.samples("tgt", "sig")) == 1
    assert store.correction("tgt", "sig").factor == pytest.approx(2.0)


def test_plan_cache_warm_hit_picks_up_profile_refit(tmp_path, monkeypatch):
    """profile= threads through warm hits: the cache key excludes it,
    so a hit must re-apply the store's *current* correction -- feedback
    recorded after the original compile reaches the next compile."""
    from repro.memory import dse as dse_mod
    from repro.trace.profile import ProfileStore

    store = ProfileStore(path=str(tmp_path / "p.json"), fingerprint="fp")
    cache = PlanCache()
    kw = dict(KW, dse=True, profile=store)
    first = cache.get_or_compile(SRC, **kw)
    assert cache.misses == 1 and first.candidates

    # feedback lands in the store between the two compiles
    store.record(first.target.name, first.plan.signature, [
        {"predicted_s": 1.0, "measured_s": 3.0, "bottleneck": "hbm"}])

    applied = {}
    real = dse_mod.apply_correction

    def spy(cands, corr):
        applied["corr"] = corr
        return real(cands, corr)

    monkeypatch.setattr(dse_mod, "apply_correction", spy)
    again = cache.get_or_compile(SRC, **kw)
    assert (cache.hits, cache.misses) == (1, 1)  # profile= not in the key
    assert again is first
    assert applied["corr"].n_samples >= 1       # refit reached the hit
    assert all(
        c.corrected_s_per_element is not None for c in again.candidates
    )
    # without a profile the hit path stays untouched
    cold = PlanCache()
    kw2 = dict(KW, dse=True)
    one = cold.get_or_compile(SRC, **kw2)
    applied.clear()
    assert cold.get_or_compile(SRC, **kw2) is one
    assert not applied


def test_flow_cli_profile_requires_trace_or_dse(tmp_path, capsys):
    src = tmp_path / "p.cfd"
    src.write_text(SRC)
    rc = flow_cli.main([str(src), "--element-vars", "u,D,v",
                        "--target", "cpu-host", "--profile"])
    assert rc == 2
    err = capsys.readouterr().err
    assert "--profile" in err and "--trace" in err and "--dse" in err


def test_flow_cli_per_stage_prefetch_vector(tmp_path, capsys, system):
    n_stages = len(system.plan.stages)
    src = tmp_path / "p.cfd"
    src.write_text(SRC)
    vec = ",".join(["1"] * n_stages)
    rc = flow_cli.main([
        str(src), "--element-vars", "u,D,v", "--target", "cpu-host",
        "--batch-elements", str(E), "--n-eq", str(2 * E),
        "--prefetch-depth", vec,
    ])
    assert rc == 0
    assert "pipeline:" in capsys.readouterr().out
    rc = flow_cli.main([str(src), "--prefetch-depth", "1,x"])
    assert rc == 2
    assert "--prefetch-depth" in capsys.readouterr().err


def test_serve_cli_smoke(tmp_path, capsys):
    from repro.serve import cli as serve_cli

    src = tmp_path / "p.cfd"
    src.write_text(SRC)
    trace_out = str(tmp_path / "serve.json")
    rc = serve_cli.main([
        str(src), "--element-vars", "u,D,v", "--target", "cpu-host",
        "--requests", "5", "--batch-elements", str(E),
        "--n-eq", str(2 * E), "--smoke", "--trace", trace_out,
    ])
    out = capsys.readouterr().out
    assert rc == 0, out
    assert "plan_cache: hits=1 misses=1" in out
    assert "bitwise ok" in out
    assert os.path.exists(trace_out)
    doc = json.load(open(trace_out))
    assert doc["traceEvents"]


# ---------------------------------------------------------------------------
# always-on metrics: metering changes nothing, and the snapshot holds
# ---------------------------------------------------------------------------

def test_metered_engine_bitwise_identical_to_unmetered(system):
    from repro import metrics as metrics_mod

    reg = metrics_mod.MetricsRegistry()
    slo = metrics_mod.SLOTracker(5.0, registry=reg)
    metered = ServeEngine(system, seed=0, metrics=reg, slo=slo)
    plain = ServeEngine(system, seed=0)
    sizes = [1, 3, 2, 6, 4]
    inputs = _requests(metered, sizes)
    got = [metered.submit(inp) for inp in inputs]
    metered.drain()
    want = [plain.submit(inp) for inp in inputs]
    plain.drain()
    for g, w in zip(got, want):
        assert g.error is None and w.error is None
        for q in metered.out_names:
            np.testing.assert_array_equal(g.outputs[q], w.outputs[q])
    # and the two engines agree on every serving stat
    assert metered.stats == plain.stats

    # the live snapshot satisfies every serving invariant
    snap = reg.snapshot()
    checked = metrics_mod.check_snapshot(snap)
    assert "request-conservation" in checked
    assert "latency-decomposition" in checked
    assert "wave-elements" in checked
    assert "admission-accounting" in checked
    # SLO saw every finished request
    v = slo.verdict()
    assert v["count"] == len(sizes)
    assert v["verdict"] == "ok"  # synthetic runs are well under 5 s


def test_metered_engine_reconciles_with_trace(system):
    from repro import metrics as metrics_mod
    from repro.trace.chrome import to_chrome

    reg = metrics_mod.MetricsRegistry()
    tracer = trace_mod.Tracer()
    eng = ServeEngine(system, seed=0, metrics=reg, tracer=tracer)
    for inp in _requests(eng, [3, E, 2]):
        eng.submit(inp)
    eng.drain()
    doc = to_chrome(tracer)
    checked = metrics_mod.check_snapshot(reg.snapshot(), doc)
    assert "trace-reconciliation" in checked


def test_queue_metrics_wait_age_and_flush_reasons():
    from repro import metrics as metrics_mod

    reg = metrics_mod.MetricsRegistry()
    clk = FakeClock()
    q = AdmissionQueue(4, max_wait_s=5.0, clock=clk, metrics=reg)
    q.push(_req(0, 4))
    assert q.pop_wave() is not None        # full wave at t=0
    q.push(_req(1, 2))
    clk.t = 6.0
    assert q.pop_wave() is not None        # expired undersized wave
    q.push(_req(2, 1))
    assert q.pop_wave(force=True) is not None
    idx = {(m["name"], tuple(sorted(m["labels"].items()))): m
           for m in reg.snapshot()["metrics"]}
    flush = {lbl[0][1]: m["value"] for (n, lbl), m in idx.items()
             if n == "admission_flush_total"}
    assert flush == {"full": 1.0, "max_wait": 1.0, "force": 1.0}
    wait = idx[("admission_wait_age_seconds", ())]
    assert wait["count"] == 3 and wait["max"] == 6.0
    fill = idx[("admission_wave_fill_ratio", ())]
    assert fill["count"] == 3
    assert fill["sum"] == pytest.approx(1.0 + 0.5 + 0.25)
