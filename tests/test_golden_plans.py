"""Golden-plan regression tests: rendered plan reports are checked in
under tests/golden/ so any cost-model, layout, padding, or report drift
shows up as a reviewable diff.  Regenerate intentionally with

    REGEN_GOLDENS=1 PYTHONPATH=src python -m pytest tests/test_golden_plans.py

All golden plans target the paper's fixed Alveo U280 datasheet, so they
are machine-independent (pure-python planning, no jax numerics).
"""
import os
import pathlib

import pytest

from repro.cfd import operators
from repro.memory import chain as mchain
from repro.memory import channels, dse

GOLDEN_DIR = pathlib.Path(__file__).parent / "golden"


def _check(name: str, rendered: str) -> None:
    path = GOLDEN_DIR / name
    if os.environ.get("REGEN_GOLDENS"):
        GOLDEN_DIR.mkdir(exist_ok=True)
        path.write_text(rendered + "\n")
        pytest.skip(f"regenerated {name}")
    assert path.exists(), (
        f"golden file {name} missing -- run with REGEN_GOLDENS=1"
    )
    want = path.read_text().rstrip("\n")
    got = rendered.rstrip("\n")
    assert got == want, (
        f"{name} drifted from the checked-in golden.\n"
        "If the change is intentional, regenerate with REGEN_GOLDENS=1 "
        "and review the diff.\n"
        f"--- golden ---\n{want}\n--- current ---\n{got}"
    )


def test_golden_single_op_plan():
    plan = dse.make_plan(
        7, target=channels.ALVEO_U280, policy="float32",
        prefetch_depth=1, n_eq=1 << 16,
    )
    _check("plan_helmholtz_p7_alveo.txt", plan.report())


def test_golden_staged_plan():
    plan = dse.make_plan(
        7, target=channels.ALVEO_U280, policy="float32",
        backend="staged", prefetch_depth=2, n_eq=1 << 16,
    )
    _check("plan_helmholtz_p7_staged_alveo.txt", plan.report())


def test_golden_bf16_plan():
    """Locks the policy-width threading: a bfloat16 plan's byte counts
    are half the float32 plan's."""
    plan = dse.make_plan(
        7, target=channels.ALVEO_U280, policy="bfloat16",
        prefetch_depth=1, n_eq=1 << 16,
    )
    _check("plan_helmholtz_p7_bf16_alveo.txt", plan.report())


def test_golden_chain_plan():
    chain = operators.build_cfd_chain(5)
    plan = mchain.plan_chain(
        chain, target=channels.ALVEO_U280, policy="float32",
        batch_elements=512, prefetch_depth=1, n_eq=1 << 12,
    )
    _check("chain_cfd_p5_alveo.txt", plan.report())


def test_golden_chain_mixed_backends():
    chain = operators.build_cfd_chain(5)
    plan = mchain.plan_chain(
        chain, target=channels.ALVEO_U280, policy="float32",
        backends=("xla", "xla", "staged"), batch_elements=256,
        prefetch_depth=(1, 1, 2), n_eq=1 << 12,
    )
    _check("chain_cfd_p5_mixed_alveo.txt", plan.report())


def test_golden_chain_sharded_placement():
    """Locks the placement layer's report: per-stage CU groups over an
    explicit topology, the contention vector, and the contention-aware
    overlap pricing (stage groups wrap on a 2-device topology, so the
    middle stage time-slices with both neighbors)."""
    from repro.memory.placement import DeviceTopology

    chain = operators.build_cfd_chain(5)
    plan = mchain.plan_chain(
        chain, target=channels.ALVEO_U280, policy="float32",
        batch_elements=256, prefetch_depth=(2, 1, 1),
        cu_count=(1, 2, 1), topology=DeviceTopology.homogeneous(2),
        n_eq=1 << 12,
    )
    _check("chain_cfd_p5_sharded_alveo.txt", plan.report())


def test_golden_chain_hetero_placement():
    """Locks the heterogeneous report: per-stage (kind, E, channels)
    lines in the placement section, per-group channel-id bases (cpu-host
    ids before the alveo block), and the re-block handoff line for the
    E- and kind-crossing 0->1 boundary."""
    from repro.memory.placement import DeviceTopology

    chain = operators.build_cfd_chain(5)
    plan = mchain.plan_chain(
        chain, target=channels.ALVEO_U280, policy="float32",
        batch_elements=256, prefetch_depth=(2, 1, 1),
        cu_count=(1, 2, 1),
        topology=DeviceTopology.parse("cpu:1,alveo:2"),
        stage_groups=(0, 1, 1), stage_batch_elements=(64, 256, 256),
        n_eq=1 << 12,
    )
    _check("chain_cfd_p5_hetero_alveo.txt", plan.report())
