"""repro.metrics: registry primitives, Prometheus exposition, SLO
tracking, snapshot invariants + trace reconciliation, and the CLI.

The exposition tests pin the byte-level contract (label escaping, sorted
label order, cumulative buckets) and the check tests pin that every
invariant violation raises :class:`MetricsError` *naming the failing
series identity* -- the property CI relies on to produce a debuggable
failure instead of a bare nonzero exit.
"""
import copy
import json

import pytest

from repro import metrics as M
from repro.metrics import cli as mcli
from repro.metrics.registry import _NULL_METRIC


# ---------------------------------------------------------------------------
# registry primitives
# ---------------------------------------------------------------------------

def test_counter_monotone_and_gauge_levels():
    reg = M.MetricsRegistry()
    c = reg.counter("reqs_total", "requests")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(M.MetricsError):
        c.inc(-1)
    g = reg.gauge("depth")
    g.set(4)
    g.inc()
    g.dec(2)
    assert g.value == 3


def test_registry_identity_same_object_any_label_order():
    reg = M.MetricsRegistry()
    a = reg.counter("x_total", "x", stage="s0", event="hit")
    b = reg.counter("x_total", "x", event="hit", stage="s0")
    assert a is b
    assert reg.counter("x_total", "x", event="miss") is not a
    # one name, one type -- even across label sets
    with pytest.raises(M.MetricsError):
        reg.gauge("x_total", "x", other="1")
    with pytest.raises(M.MetricsError):
        reg.histogram("x_total", "x", stage="s0", event="hit")


def test_registry_rejects_bad_names():
    reg = M.MetricsRegistry()
    with pytest.raises(M.MetricsError):
        reg.counter("bad-name")
    with pytest.raises(M.MetricsError):
        reg.counter("ok_name", "", **{"0bad": "v"})


def test_histogram_buckets_quantiles_and_window():
    h = M.Histogram(name="lat", buckets=(0.1, 1.0, 10.0), window=4)
    for x in (0.05, 0.5, 5.0, 50.0, 0.5):
        h.observe(x)
    assert h.count == 5
    assert sum(h.bucket_counts) == h.count
    assert h.bucket_counts == [1, 2, 1, 1]  # last slot: +Inf overflow
    # quantiles are nearest-rank over the *recent window* (4 here), so
    # the evicted 0.05 no longer contributes
    assert h.quantile(0.0) == 0.5
    assert h.quantile(0.95) == 50.0
    s = h.summary()
    assert s["count"] == 5.0 and s["max"] == 50.0 and s["min"] == 0.05
    with pytest.raises(M.MetricsError):
        M.Histogram(buckets=(1.0, 1.0))  # not strictly ascending


def test_bucket_ladders():
    b = M.log_buckets(1e-3, 1.0, per_decade=3)
    assert b[0] == 1e-3 and b[-1] >= 1.0
    assert list(b) == sorted(b)
    # rounded to 3 significant figures: exposition stays readable
    assert all(float(f"{x:.2e}") == x for x in b)
    assert M.linear_buckets(0.0, 1.0, 4) == (0.25, 0.5, 0.75, 1.0)


def test_null_registry_falsy_and_allocation_free():
    assert not M.NULL_REGISTRY
    assert M.NULL_REGISTRY.snapshot()["metrics"] == []
    # every factory returns THE shared null metric: no per-series alloc
    mets = [
        M.NULL_REGISTRY.counter("a_total", event="x"),
        M.NULL_REGISTRY.gauge("b"),
        M.NULL_REGISTRY.histogram("c_seconds", window=2),
    ]
    for m in mets:
        assert m is _NULL_METRIC
        assert not m
    # mutators all accept and record nothing
    m = mets[0]
    m.inc()
    m.dec()
    m.set(3.0)
    m.observe(1.0)
    assert m.value == 0.0 and m.count == 0 and m.quantile(0.95) == 0.0


# ---------------------------------------------------------------------------
# Prometheus exposition
# ---------------------------------------------------------------------------

def test_prometheus_label_escaping_and_sorted_order():
    reg = M.MetricsRegistry()
    nasty = 'a\\b"c\nd'
    # labels handed over in non-sorted order on purpose
    reg.counter("svc_total", "requests served", zone=nasty, app="x").inc(2)
    text = M.export_prometheus(reg)
    # sorted label names, escaped value: backslash, quote, newline
    assert 'svc_total{app="x",zone="a\\\\b\\"c\\nd"} 2' in text
    assert text.count("# TYPE svc_total counter") == 1
    assert "# HELP svc_total requests served" in text


def test_prometheus_one_header_per_name():
    reg = M.MetricsRegistry()
    reg.counter("ev_total", "events", kind="a").inc()
    reg.counter("ev_total", "events", kind="b").inc(3)
    text = M.export_prometheus(reg)
    assert text.count("# TYPE ev_total counter") == 1
    assert 'ev_total{kind="a"} 1' in text
    assert 'ev_total{kind="b"} 3' in text


def test_prometheus_histogram_cumulative_buckets():
    reg = M.MetricsRegistry()
    h = reg.histogram("lat_seconds", "latency", buckets=(0.1, 1.0))
    for x in (0.05, 0.5, 5.0):
        h.observe(x)
    text = M.export_prometheus(reg)
    assert 'lat_seconds_bucket{le="0.1"} 1' in text
    assert 'lat_seconds_bucket{le="1"} 2' in text
    assert 'lat_seconds_bucket{le="+Inf"} 3' in text
    assert "lat_seconds_count 3" in text
    assert "lat_seconds_sum 5.55" in text


# ---------------------------------------------------------------------------
# snapshot checks: every violation names the failing identity
# ---------------------------------------------------------------------------

def _serving_snapshot():
    """A minimal self-consistent serving snapshot (3 requests, 2 waves
    of E=2, 1 element of wave pad)."""
    reg = M.MetricsRegistry()
    for event, n in (("submitted", 3), ("admitted", 3), ("completed", 3)):
        reg.counter("serve_requests_total", "", event=event).inc(n)
    reg.counter("serve_requests_total", "", event="failed")
    reg.counter("serve_requests_total", "", event="rejected")
    reg.gauge("serve_in_flight_requests")
    reg.counter("serve_waves_total").inc(2)
    reg.gauge("serve_batch_elements").set(2)
    reg.counter("serve_admitted_elements_total").inc(3)
    reg.counter("serve_pad_elements_total", "", kind="wave").inc(1)
    reg.counter("serve_pad_elements_total", "", kind="plan")
    for phase, xs in (("total", (1.0, 2.0, 3.0)),
                      ("queue", (0.25, 0.5, 1.0)),
                      ("execute", (0.75, 1.5, 2.0))):
        h = reg.histogram(
            "serve_request_latency_seconds", "", phase=phase)
        for x in xs:
            h.observe(x)
    return reg.snapshot()


def test_check_snapshot_accepts_consistent_serving_run():
    checked = M.check_snapshot(_serving_snapshot())
    assert "request-conservation" in checked
    assert "latency-decomposition" in checked
    assert "wave-elements" in checked


def test_structure_violation_names_series():
    snap = _serving_snapshot()
    h = next(m for m in snap["metrics"]
             if m["name"] == "serve_request_latency_seconds"
             and m["labels"] == {"phase": "total"})
    h["buckets"][0]["count"] += 1  # bucket sum no longer matches count
    with pytest.raises(M.MetricsError) as ei:
        M.check_snapshot(snap)
    assert "serve_request_latency_seconds" in str(ei.value)


def test_duplicate_identity_rejected():
    snap = _serving_snapshot()
    snap["metrics"].append(copy.deepcopy(snap["metrics"][0]))
    with pytest.raises(M.MetricsError) as ei:
        M.check_snapshot(snap)
    assert "duplicate metric identity" in str(ei.value)


def test_request_conservation_violation():
    snap = _serving_snapshot()
    sub = next(m for m in snap["metrics"]
               if m["name"] == "serve_requests_total"
               and m["labels"] == {"event": "submitted"})
    sub["value"] += 1
    with pytest.raises(M.MetricsError) as ei:
        M.check_snapshot(snap)
    assert "request conservation" in str(ei.value)


def test_latency_decomposition_violation():
    snap = _serving_snapshot()
    q = next(m for m in snap["metrics"]
             if m["name"] == "serve_request_latency_seconds"
             and m["labels"] == {"phase": "queue"})
    q["sum"] += 0.5
    with pytest.raises(M.MetricsError) as ei:
        M.check_snapshot(snap)
    assert "latency decomposition" in str(ei.value)


def test_wave_element_conservation_violation():
    snap = _serving_snapshot()
    pad = next(m for m in snap["metrics"]
               if m["name"] == "serve_pad_elements_total"
               and m["labels"] == {"kind": "wave"})
    pad["value"] += 1
    with pytest.raises(M.MetricsError) as ei:
        M.check_snapshot(snap)
    assert "wave elements" in str(ei.value)


def test_trace_reconciliation_exact():
    snap = _serving_snapshot()
    trace = {"traceEvents": [
        {"ph": "C", "name": "pad_elements", "args": {"wave": 1, "pad": 0}},
        {"ph": "C", "name": "serve_waves", "args": {"waves": 2}},
        {"ph": "C", "name": "serve_requests",
         "args": {"submitted": 3, "admitted": 3, "completed": 3}},
    ]}
    checked = M.check_snapshot(snap, trace)
    assert "trace-reconciliation" in checked
    # the C events carry cumulative totals: only the LAST one counts
    trace["traceEvents"].append(
        {"ph": "C", "name": "serve_waves", "args": {"waves": 1}}
    )
    with pytest.raises(M.MetricsError) as ei:
        M.check_snapshot(snap, trace)
    assert "serve_waves_total" in str(ei.value)


def test_diff_snapshots():
    a = _serving_snapshot()
    b = copy.deepcopy(a)
    next(m for m in b["metrics"]
         if m["name"] == "serve_waves_total")["value"] = 5
    lines = M.diff_snapshots(a, b)
    assert any("serve_waves_total" in ln and "2 -> 5" in ln
               for ln in lines)
    b["metrics"] = [m for m in b["metrics"]
                    if m["name"] != "serve_batch_elements"]
    lines = M.diff_snapshots(a, b)
    assert any(ln.startswith("- serve_batch_elements") for ln in lines)


# ---------------------------------------------------------------------------
# SLO tracking
# ---------------------------------------------------------------------------

def test_slo_validates_targets():
    with pytest.raises(M.MetricsError):
        M.SLOTracker(0.0)
    with pytest.raises(M.MetricsError):
        M.SLOTracker(1.0, target_error_rate=1.0)


def test_slo_verdict_transitions_and_gauges():
    reg = M.MetricsRegistry()
    slo = M.SLOTracker(1.0, 0.5, window=16, min_count=4, registry=reg)
    # below min_count: no judgement even on terrible latency
    slo.observe(100.0)
    assert slo.verdict()["verdict"] == "ok"
    for _ in range(8):
        slo.observe(0.1)
    v = slo.verdict()
    # 1 of 9 over target -> latency burn 1/9/0.05 > 1: still breach;
    # push the violation out of the window with more good traffic
    for _ in range(8):
        slo.observe(0.1)
    v = slo.verdict()
    assert v["verdict"] == "ok" and v["latency_burn"] == 0.0
    # sustained over-target traffic burns the 5% allowance immediately
    for _ in range(16):
        slo.observe(2.0)
    v = slo.verdict()
    assert v["verdict"] == "breach"
    assert v["latency_burn"] == pytest.approx(1.0 / 0.05)
    # the exported gauges carry the same state
    assert M.export_prometheus(reg)
    snap = {m["name"]: m for m in reg.snapshot()["metrics"]}
    assert snap["slo_verdict"]["value"] == float(M.VERDICTS.index("breach"))
    assert snap["slo_target_p95_seconds"]["value"] == 1.0


def test_slo_error_burn():
    slo = M.SLOTracker(10.0, 0.5, window=8, min_count=2)
    slo.observe(0.1, error=True)
    slo.observe(0.1)
    v = slo.verdict()
    assert v["errors"] == 1
    assert v["error_burn"] == pytest.approx((1 / 2) / 0.5)
    assert v["verdict"] == "breach"
    # a zero error budget burns infinitely on the first failure
    strict = M.SLOTracker(10.0, 0.0, window=8, min_count=1)
    strict.observe(0.1, error=True)
    assert strict.verdict()["error_burn"] == float("inf")
    assert strict.verdict()["verdict"] == "breach"


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def _write(tmp_path, name, doc):
    p = tmp_path / name
    p.write_text(json.dumps(doc))
    return str(p)


def test_cli_ok_and_violation_exit_codes(tmp_path, capsys):
    good = _write(tmp_path, "good.json", _serving_snapshot())
    assert mcli.main([good, "--check"]) == 0
    out = capsys.readouterr().out
    assert "series ok" in out and "request-conservation" in out

    snap = _serving_snapshot()
    next(m for m in snap["metrics"]
         if m["name"] == "serve_requests_total"
         and m["labels"] == {"event": "submitted"})["value"] += 1
    bad = _write(tmp_path, "bad.json", snap)
    assert mcli.main([bad, "--check"]) == 1
    assert "INVARIANT VIOLATION" in capsys.readouterr().err


def test_cli_unreadable_input_exits_2(tmp_path):
    with pytest.raises(SystemExit) as ei:
        mcli.main([str(tmp_path / "nope.json")])
    assert ei.value.code == 2


def test_cli_pretty_and_diff(tmp_path, capsys):
    a = _write(tmp_path, "a.json", _serving_snapshot())
    snap = _serving_snapshot()
    next(m for m in snap["metrics"]
         if m["name"] == "serve_waves_total")["value"] = 7
    b = _write(tmp_path, "b.json", snap)
    assert mcli.main([a, "--pretty", "--diff", b]) == 0
    out = capsys.readouterr().out
    assert "serve_waves_total: 2" in out      # pretty line
    assert "~ serve_waves_total" in out       # diff line
    assert "1 series changed" in out
